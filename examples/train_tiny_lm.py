"""End-to-end training example: a reduced qwen2 on synthetic data with
checkpoint/restart fault drill, microbatching, ZeRO-1 and the MEMSCOPE
placement advisory — the full driver stack on one CPU.

    PYTHONPATH=src python examples/train_tiny_lm.py
"""
import sys

from repro.launch import train

sys.exit(train.main([
    "--arch", "qwen2-1.5b", "--reduced",
    "--steps", "60",
    "--batch", "8", "--seq", "64",
    "--microbatches", "2",
    "--lr", "3e-3",
    "--checkpoint-every", "20",
    "--ckpt-dir", "/tmp/repro_example_ckpt",
    "--inject-fault-at", "30",      # chaos drill: recover from step-20 ckpt
    "--log-every", "15",
]))
