"""The SPMD sandwich, executed: multi-observer contention on a mesh.

The paper's Multi-Engine Synchronizer guarantees that the measured
region only opens after EVERY engine passed the start barrier and only
closes after every engine finished.  The ``spmd`` backend is the
collective edition of that spin-lock sandwich: each ladder rung is one
fused ``shard_map`` dispatch over an ("engine",) mesh — engine 0 runs
the observer, engines 1..k the stressors, the rest idle — and the
barrier psums are threaded into the activities' operands, so the fence
is enforced by dataflow, not convention.

    PYTHONPATH=src python examples/spmd_contention.py
"""
import os

# must happen before jax initialises (it locks the device count);
# append to any pre-existing XLA_FLAGS rather than skipping the forcing
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = \
        f"{_flags} --xla_force_host_platform_device_count=8".strip()

import jax  # noqa: E402

from repro.core.characterize import curvedb_from_result  # noqa: E402
from repro.core.coordinator import (CoreCoordinator,  # noqa: E402
                                    measured_region_is_fenced)
from repro.core.scenarios import (ObserverSpec, ScenarioSpec,  # noqa: E402
                                  StressorSpec, TrafficShape)

print(f"== engine mesh: {len(jax.devices())} host devices ==")

BUF = 128 << 10

# one scenario, TWO observers measured at once (bandwidth on hbm,
# latency on host), against a mixed-ratio write stressor ensemble.
# coupled=True (the default): each observer's rungs carry the OTHER
# observer as a live engine — siblings are part of each other's
# measured region, and the rung activities are the real Pallas kernels
# (compat-probed; pure-jnp loops where Pallas is unavailable)
spec = ScenarioSpec(
    "spmd-demo",
    (ObserverSpec("r", "hbm", (BUF,)),
     ObserverSpec("l", "host", (BUF,))),
    (StressorSpec("w", "hbm", BUF),
     StressorSpec("b", "hbm", BUF, TrafficShape.mixed(1, 1))),
    iters=10, max_stressors=3)

coord = CoreCoordinator(backend="spmd")
res = coord.run_matrix([spec])
print(f"\n{res.stats.spmd_rungs} ladder rungs -> "
      f"{res.stats.measure_dispatches} stacked SPMD dispatches "
      f"(ONE per distinct role-program signature — here one per "
      f"observer curve, {res.stats.n_ladders} curves; per-rung "
      f"elapsed from in-dispatch device clocks)")

for run in res.runs:
    print(f"\n-- curve {run.key} "
          f"(executed rungs {run.execution['executed_rungs']}, "
          f"activity={run.execution['activity']}, "
          f"coupled={run.execution['coupled']}, "
          f"fenced={run.execution['fenced']}, "
          f"timing={run.execution['timing_source']})")
    for s in run.scenarios:
        val = (f"{s.main.latency_ns:8.1f} ns/tx"
               if run.observer.strategy == "l"
               else f"{s.main.bandwidth_gbps:8.4f} GB/s")
        print(f"   k={s.n_stressors}: {val}   [{s.source}]")

# the curves we already executed persist with executed-vs-modeled
# provenance (curvedb_from_result: no re-execution)
db = curvedb_from_result(res, coord.platform.name, backend="spmd")
db.save("/tmp/spmd_curves.json")
key = spec.key()
print(f"\nCurveDB v2 saved to /tmp/spmd_curves.json; "
      f"provenance[{key!r}]['execution'] = "
      f"{db.provenance[key]['execution']}")
