"""The paper's Fig. 1 -> Fig. 14 loop, end to end:

  1. characterize every (pool x strategy x contention) performance curve,
  2. hand the curve database to the PlacementAdvisor,
  3. place a serving workload's memory objects (params, KV cache) under
     two contention assumptions and watch the decision flip,
  4. sweep a full bandwidth–latency surface (CurveDB v3) and query it
     at the decode workload's actual traffic coordinates.

    PYTHONPATH=src python examples/characterize_and_place.py
"""
from repro.configs.base import get_config
from repro.core.characterize import (CurveDB, characterize,
                                     characterize_surface, mlp_table)
from repro.core.coordinator import CoreCoordinator
from repro.core.placement import (ContentionSpec, MemObject,
                                  PlacementAdvisor, kv_cache_object,
                                  params_object)
from repro.serve.engine import cache_bytes, decode_rw_mix

coord = CoreCoordinator(backend="simulate")

print("== 1. characterize (full ladder cross-product) ==")
db = characterize(coord, pools=["hbm", "host"],
                  obs_strategies=("r", "w", "l"),
                  stress_strategies=("r", "w", "y"))
print(f"curves collected: {len(db.curves)}")
db.save("/tmp/memscope_curves.json")
print("persisted to /tmp/memscope_curves.json (reloadable: CurveDB.load)")

print("\n== 2. Little's-law MLP per pool ==")
print(mlp_table(db, coord.platform))

print("\n== 3. placement decisions ==")
cfg = get_config("glm4-9b")
adv = PlacementAdvisor(db, coord.platform, pools=["hbm", "host"])
kv = kv_cache_object("kv_cache", cache_bytes(cfg, batch=32, max_len=32768),
                     bytes_read_per_token=float(
                         cache_bytes(cfg, 32, 32768)))
objs = [
    params_object("params", 2 * cfg.n_params(), reads_per_step=1.0),
    kv,
    MemObject("activations", 8 << 30, bytes_per_step=float(16 << 30)),
]
caps = {"hbm": 256 << 30, "host": 2 << 40}   # a 16-chip slice's HBM

for label, contention in (
        ("quiet system", ContentionSpec(0, "hbm", "w")),
        ("7 writers hammering HBM", ContentionSpec(7, "hbm", "y"))):
    plan = adv.advise(objs, contention, capacities=dict(caps))
    print(f"\n-- contention: {label}")
    print(plan.report())
    print(f"   predicted step total: "
          f"{plan.total_predicted_ns() / 1e6:.2f} ms")

print("\n== 4. bandwidth-latency surface (CurveDB v3) ==")
sdb = characterize_surface(coord, pools=["hbm", "host"],
                           stress_pools=["hbm"], iters=100)
key, surf = next(iter(sorted(sdb.surfaces.items())))
print(f"surfaces: {len(sdb.surfaces)}; {key.to_string()!r} grid shape "
      f"{surf.shape} (n_stressors x rw_ratio x inject_rate)")
mix = decode_rw_mix(batch=32, max_len=32768)
q = sdb.query("hbm", 3, stress_strat="b", rw_ratio=mix, inject_rate=0.8)
print(f"decode mix rw={mix:.3f}, 3 stressors at 80% duty -> "
      f"{q.bandwidth_gbps:.1f} GB/s "
      f"(interpolated; extrapolated={q.extrapolated})")
q_off = sdb.query("hbm", 99, stress_strat="b")
print(f"off-grid (99 stressors) -> {q_off.bandwidth_gbps:.1f} GB/s, "
      f"flagged extrapolated={q_off.extrapolated}")
adv_s = PlacementAdvisor(sdb, coord.platform)
plan = adv_s.advise([kv], ContentionSpec(3, "hbm", "b", rw_ratio=mix),
                    capacities=dict(caps))
print("placement at the decode surface coordinates:")
print(plan.report())
