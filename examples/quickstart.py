"""Quickstart: MEMSCOPE-JAX in ~30 lines.

Detect the platform memory tree, run one contention-ladder experiment
(observed core reads HBM while stressors write it), and print the
performance curve + Little's-law MLP.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core.coordinator import (ActivitySpec, CoreCoordinator,
                                    ExperimentConfig)

coord = CoreCoordinator(backend="simulate")   # CPU container: modeled v5e

print("== detected memory pools (device tree) ==")
print(coord.pools.status())

print("\n== experiment: observed (r, hbm, 64M) vs stressors (w, hbm, 64M) ==")
result = coord.run(ExperimentConfig(
    main=ActivitySpec("r", "hbm", 64 << 20),
    stress=ActivitySpec("w", "hbm", 64 << 20),
    iters=500))

print("stressors  bandwidth GB/s")
for n, bw in result.bandwidth_curve():
    print(f"{n:9d}  {bw:10.1f}")

lat = coord.run(ExperimentConfig(
    main=ActivitySpec("l", "hbm", 64 << 20),
    stress=ActivitySpec("w", "hbm", 64 << 20)))
worst_lat = lat.latency_curve()[-1][1]
worst_bw = result.bandwidth_curve()[-1][1]
mlp = worst_lat * worst_bw / coord.platform.line_bytes
print(f"\nLittle's law @ worst case: {worst_lat:.0f} ns x "
      f"{worst_bw:.0f} GB/s / {coord.platform.line_bytes}B line "
      f"=> MLP ~= {mlp:.1f}")
