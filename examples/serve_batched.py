"""Batched serving example: prefill + decode on a reduced gemma3 (local
sliding-window attention + ring KV caches), with the MEMSCOPE advisor
choosing the KV-cache pool.

    PYTHONPATH=src python examples/serve_batched.py
"""
import sys

from repro.launch import serve

sys.exit(serve.main([
    "--arch", "gemma3-1b", "--reduced",
    "--batch", "4",
    "--prompt-len", "24",
    "--new-tokens", "24",
    "--kv-placement", "auto",
]))
