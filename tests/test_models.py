"""Per-architecture smoke tests (assignment requirement):

Every assigned arch instantiates a REDUCED same-family config and runs
one forward + one train step on CPU, asserting output shapes and no NaNs.
Also covers the period decomposition and analytic parameter counts.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS
from repro.configs.base import ShapeSpec, TrainConfig, get_config
from repro.models import blocks, lm
from repro.parallel.sharding import make_rules
from repro.train import step as step_mod

B, S = 2, 32


def _frontend(cfg, b, s, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.frontend == "audio":
        return {"frame_embeds": jnp.asarray(
            rng.standard_normal((b, s, cfg.d_model), np.float32) * 0.02)}
    if cfg.frontend == "vlm":
        return {"prefix_embeds": jnp.asarray(
            rng.standard_normal((b, cfg.n_prefix_embeds, cfg.d_model),
                                np.float32) * 0.02)}
    return None


@pytest.fixture(scope="module")
def host_mesh():
    from repro.launch.mesh import make_host_mesh
    return make_host_mesh(1, 1)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_forward_smoke(arch):
    cfg = get_config(arch).reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jnp.arange(B * S, dtype=jnp.int32).reshape(B, S) % cfg.vocab_size
    h, caches, aux = lm.forward(params, tokens, cfg=cfg, mode="train",
                                frontend=_frontend(cfg, B, S))
    assert h.shape == (B, S, cfg.d_model)
    assert caches is None
    assert not np.isnan(np.asarray(h, np.float32)).any(), arch
    logits = lm.unembed_logits(params, h, cfg)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert not np.isnan(np.asarray(logits)).any(), arch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_train_step_smoke(arch, host_mesh):
    cfg = get_config(arch).reduced()
    tcfg = TrainConfig(total_steps=4, warmup_steps=1, microbatches=1,
                       remat="layer", loss_chunk=16)
    rules = make_rules(cfg, host_mesh, global_batch=B, shape_kind="train")
    state = step_mod.init_state(cfg, tcfg, jax.random.PRNGKey(0))
    step = jax.jit(step_mod.make_train_step(cfg, rules, tcfg))
    tokens = jnp.arange(B * S, dtype=jnp.int32).reshape(B, S) % cfg.vocab_size
    labels = jnp.roll(tokens, -1, axis=1)
    new_state, metrics = step(state, tokens, labels, _frontend(cfg, B, S))
    loss = float(metrics["loss"])
    assert np.isfinite(loss), arch
    assert float(metrics["grad_norm"]) > 0.0, arch
    # params actually moved
    delta = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32) -
                                      b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(new_state["params"]),
                                jax.tree.leaves(state["params"])))
    assert delta > 0.0, arch


# ---------------------------------------------------------------------------
# Period decomposition
# ---------------------------------------------------------------------------


def test_period_gemma3():
    cfg = get_config("gemma3-4b")
    plan = blocks.make_plan(cfg)
    assert plan.period == 6              # 5 local : 1 global
    assert plan.n_layers == cfg.n_layers
    # layer 5, 11, ... are global
    assert cfg.layer_is_global_attn(5)
    assert not cfg.layer_is_global_attn(0)


def test_period_jamba():
    cfg = get_config("jamba-v0.1-52b")
    plan = blocks.make_plan(cfg)
    assert plan.period == 8              # attn at idx 4 of each 8 block
    assert cfg.layer_kind(4) == "attn"
    assert cfg.layer_kind(0) == "ssm"
    assert cfg.layer_is_moe(1) and not cfg.layer_is_moe(0)


def test_period_dense():
    for arch in ("qwen2-1.5b", "glm4-9b", "mamba2-370m"):
        assert blocks.make_plan(get_config(arch)).period == 1


def test_scan_equals_unrolled():
    """The period-scanned forward must equal a layer-by-layer unroll."""
    cfg = get_config("gemma3-1b").reduced()   # period 2 reduced
    params = lm.init_params(cfg, jax.random.PRNGKey(1))
    tokens = jnp.arange(B * S, dtype=jnp.int32).reshape(B, S) % cfg.vocab_size
    h_scan, _, _ = lm.forward(params, tokens, cfg=cfg, mode="train")

    # manual unroll using the same per-layer apply
    from repro.models.common import rmsnorm
    plan = blocks.make_plan(cfg)
    x = lm.embed_tokens(params, tokens, cfg)
    for r in range(plan.n_full):
        for p in range(plan.period):
            lp = jax.tree.map(lambda a: a[r], params["scan"][f"p{p}"])
            x, _, _ = blocks.layer_apply(lp, x, cfg=cfg, layer_idx=p,
                                         mode="train")
    for j in range(plan.n_tail):
        x, _, _ = blocks.layer_apply(
            params["tail"][f"t{j}"], x, cfg=cfg,
            layer_idx=plan.tail_layer_idx(j), mode="train")
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    np.testing.assert_allclose(np.asarray(h_scan), np.asarray(x),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# Analytic parameter counts vs actual pytrees
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_param_count_matches_pytree(arch):
    cfg = get_config(arch).reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    actual = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    analytic = cfg.n_params()
    assert abs(actual - analytic) / max(actual, 1) < 0.02, \
        (arch, actual, analytic)


def test_full_config_param_counts_plausible():
    """Sanity: full configs land near their published sizes."""
    expect = {"qwen2-1.5b": (1.2e9, 2.1e9),
              "glm4-9b": (8.0e9, 10.5e9),
              "gemma3-4b": (3.0e9, 4.8e9),
              "olmoe-1b-7b": (6.0e9, 7.8e9),
              "mamba2-370m": (3.3e8, 4.6e8),
              "jamba-v0.1-52b": (4.6e10, 5.6e10),
              "phi3.5-moe-42b-a6.6b": (3.9e10, 4.5e10)}
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).n_params()
        assert lo <= n <= hi, (arch, n)


def test_active_params_moe():
    cfg = get_config("olmoe-1b-7b")
    assert cfg.n_active_params() < 0.35 * cfg.n_params()


def test_long500k_applicability():
    runnable = {a for a in ALL_ARCHS if get_config(a).sub_quadratic}
    assert runnable == {"gemma3-4b", "gemma3-1b", "mamba2-370m",
                        "jamba-v0.1-52b"}
