"""Distribution tests that need a multi-device mesh.

jax fixes the device count at first init, and the main pytest process
must keep seeing ONE device (assignment requirement), so each test here
spawns a fresh interpreter with ``xla_force_host_platform_device_count``
set — the same mechanism launch/dryrun.py uses.
"""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def run_forced(body: str, n_devices: int = 8, timeout: int = 480) -> str:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count={n_devices}"
        {textwrap.indent(textwrap.dedent(body), '        ').strip()}
        print("SUBPROC_OK")
    """)
    env = dict(os.environ, PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=timeout, env=env)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-3000:]}"
    assert "SUBPROC_OK" in r.stdout
    return r.stdout


def test_main_process_sees_one_device():
    import jax
    assert len(jax.devices()) == 1


def test_production_mesh_shapes():
    run_forced("""
    import jax
    from repro.launch.mesh import make_production_mesh
    m = make_production_mesh()
    assert m.devices.shape == (16, 16) and m.axis_names == ("data", "model")
    m2 = make_production_mesh(multi_pod=True)
    assert m2.devices.shape == (2, 16, 16)
    assert m2.axis_names == ("pod", "data", "model")
    """, n_devices=512)


def test_dp_tp_train_step_matches_single_device():
    """The same reduced train step on a (2,2) mesh and on 1 device must
    produce identical losses and parameter updates — the sharding rules
    change placement, never math."""
    out = run_forced("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs.base import TrainConfig, get_config
    from repro.models import lm
    from repro.parallel.sharding import make_rules
    from repro.train import step as step_mod

    cfg = get_config("qwen2-1.5b").reduced()
    tcfg = TrainConfig(total_steps=5, warmup_steps=1, loss_chunk=16)
    B, S = 4, 32
    tokens = (jnp.arange(B * S, dtype=jnp.int32).reshape(B, S) * 13) % cfg.vocab_size
    labels = jnp.roll(tokens, -1, axis=1)

    results = {}
    for shape in ((1, 1), (2, 2), (4, 2)):
        from repro import compat
        mesh = compat.make_mesh(shape, ("data", "model"))
        rules = make_rules(cfg, mesh, global_batch=B, shape_kind="train")
        state = step_mod.init_state(cfg, tcfg, jax.random.PRNGKey(0))
        specs = step_mod.state_specs(cfg, rules, tcfg, state["params"])
        sh = jax.tree.map(lambda s, sp: NamedSharding(mesh, sp), state, specs)
        state = jax.tree.map(lambda x, s: jax.device_put(x, s), state, sh)
        bsh = NamedSharding(mesh, P(rules.batch if rules.batch else None, None))
        tk = jax.device_put(tokens, bsh)
        lb = jax.device_put(labels, bsh)
        step = jax.jit(step_mod.make_train_step(cfg, rules, tcfg))
        new_state, metrics = step(state, tk, lb, None)
        results[shape] = (float(metrics["loss"]),
                          np.asarray(jax.device_get(
                              jax.tree.leaves(new_state["params"])[0]),
                              np.float32))
    base_loss, base_p = results[(1, 1)]
    for shape in ((2, 2), (4, 2)):
        loss, p = results[shape]
        assert abs(loss - base_loss) < 3e-4, (shape, loss, base_loss)
        np.testing.assert_allclose(p, base_p, atol=3e-4)
    print("losses", {k: v[0] for k, v in results.items()})
    """, n_devices=8)
    assert "losses" in out


def test_decode_step_matches_single_device():
    run_forced("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs.base import get_config
    from repro.models import lm
    from repro.parallel.sharding import make_rules
    from repro.serve import engine as eng

    cfg = get_config("gemma3-1b").reduced()
    B, PROMPT = 2, 12
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    tokens = (jnp.arange(B * PROMPT, dtype=jnp.int32).reshape(B, PROMPT) * 7) % cfg.vocab_size

    outs = {}
    for shape in ((1, 1), (2, 4)):
        from repro import compat
        mesh = compat.make_mesh(shape, ("data", "model"))
        rules = make_rules(cfg, mesh, global_batch=B, shape_kind="decode")
        prefill = jax.jit(eng.make_prefill_step(cfg, rules, max_len=PROMPT + 4))
        decode = jax.jit(eng.make_decode_step(cfg, rules))
        caches, logits = prefill(params, tokens, None)
        caches, logits2 = decode(params, caches, tokens[:, -1:],
                                 jnp.int32(PROMPT), None)
        outs[shape] = np.asarray(logits2)
    np.testing.assert_allclose(outs[(2, 4)], outs[(1, 1)], atol=3e-4)
    """, n_devices=8)


def test_gpipe_matches_sequential():
    run_forced("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.parallel.pipeline import make_gpipe, reference_pipeline
    from repro import compat
    mesh = compat.make_mesh((4,), ("stage",))
    def apply_stage(p, x):
        return jnp.tanh(x @ p["w"] + p["b"])
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (4, 8, 8)) * 0.5,
              "b": jnp.zeros((4, 1, 8))}
    x = jax.random.normal(jax.random.PRNGKey(1), (7, 2, 8))
    run = jax.jit(make_gpipe(mesh, apply_stage, n_micro=7, x_spec=P()))
    y = run(params, x)
    yref = reference_pipeline(apply_stage, params, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref), atol=1e-5)
    """, n_devices=4)


def test_compressed_psum_matches_f32_psum():
    run_forced("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.parallel import compression
    from repro import compat
    mesh = compat.make_mesh((8,), ("data",))

    def f(g):
        err = jax.tree.map(lambda x: jnp.zeros_like(x), g)
        mean, _ = compression.compressed_psum(g, err, "data")
        exact = jax.tree.map(lambda x: jax.lax.pmean(x, "data"), g)
        return mean, exact

    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (8, 64))}
    fm = compat.shard_map(f, mesh=mesh, in_specs=({"w": P("data")},),
                          out_specs=({"w": P("data")}, {"w": P("data")}))
    mean, exact = fm(g)
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    np.testing.assert_allclose(np.asarray(mean["w"]),
                               np.asarray(exact["w"]), atol=scale)
    """, n_devices=8)


def test_dryrun_cell_on_8_devices():
    """The full dry-run path (lower+compile+analyze) on a small mesh."""
    out = run_forced("""
    import jax
    # reuse the dryrun cell machinery on a (2,4) mesh via monkeypatching
    import repro.launch.dryrun as dr
    import repro.launch.mesh as mesh_mod
    def small_mesh(*, multi_pod=False):
        shape = (2, 2, 2) if multi_pod else (2, 4)
        axes = ("pod", "data", "model") if multi_pod else ("data", "model")
        from repro import compat
        return compat.make_mesh(shape, axes)
    dr.make_production_mesh = small_mesh
    from repro.configs.base import get_config, SHAPES
    import dataclasses
    # shrink the shape so CPU lowering is fast
    SHAPES["train_4k"] = dataclasses.replace(
        SHAPES["train_4k"], seq_len=128, global_batch=8)
    cfg = get_config("qwen2-1.5b")
    object.__setattr__(cfg, "n_layers", 2)
    lowered, compiled, meta = dr.lower_cell("qwen2-1.5b", "train_4k")
    rec = dr.analyze_cell(compiled, meta, cfg, SHAPES["train_4k"])
    assert rec["hlo_flops"] > 0
    assert rec["bytes_per_device"] > 0
    assert rec["bottleneck"] in ("compute", "memory", "collective")
    print("bottleneck", rec["bottleneck"])
    """, n_devices=8)
    assert "bottleneck" in out


def test_spmd_backend_executes_fenced_ladder_on_8_devices():
    """ISSUE-2/4 acceptance: on an 8-virtual-device CPU mesh the spmd
    backend executes a k=0..3 ladder as ONE fused whole-ladder dispatch
    (DispatchStats proves it: one host-synchronous dispatch per ladder,
    per-rung elapsed from in-dispatch device clocks), the barrier
    dependency holds structurally on every scanned rung, and a
    multi-observer spec measuring two pools yields per-observer CurveDB
    curves whose every point was executed."""
    run_forced("""
    import jax
    from repro.core.characterize import characterize_matrix
    from repro.core.coordinator import (CoreCoordinator,
                                        build_rung_program,
                                        measured_region_is_fenced,
                                        _spmd_branch_fn)
    from repro.core.scenarios import (ObserverSpec, ScenarioSpec,
                                      StressorSpec)
    import numpy as np
    assert len(jax.devices()) == 8

    BUF = 64 << 10
    spec = ScenarioSpec(
        "spmd-multi",
        (ObserverSpec("r", "hbm", (BUF,)),      # bandwidth observer
         ObserverSpec("l", "host", (BUF,))),    # latency observer
        (StressorSpec("w", "hbm", BUF),),
        iters=3, max_stressors=3)

    c = CoreCoordinator(backend="spmd")
    res = c.run_matrix([spec])
    # 2 observers x 4 rungs (k=0..3), ONE fused dispatch per LADDER
    assert res.stats.n_scenarios == 1
    assert res.stats.n_ladders == 2
    assert res.stats.spmd_rungs == 8
    assert res.stats.measure_dispatches == 2
    assert res.stats.host_sync_dispatches == \
        2 + res.stats.noisy_remeasures
    for run in res.runs:
        assert run.execution["backend"] == "spmd"
        assert run.execution["executed_rungs"] == [0, 1, 2, 3]
        assert run.execution["modeled_rungs"] == []
        assert run.execution["n_engines"] == 8
        assert run.execution["timing_source"] == "device"
        assert run.execution["dispatches"] == \
            1 + run.execution["remeasures"]
        assert len(run.execution["rung_time_spread_ns"]) == 4
        for s in run.scenarios:
            assert s.source == "executed"
            assert s.main.elapsed_ns > 0

    # the executed program really carries the barrier dependency edge
    fns = [_spmd_branch_fn("r", None, 128, 3),
           _spmd_branch_fn("w", None, 128, 3),
           _spmd_branch_fn("i", None, 1, 3)]
    _mesh, f = build_rung_program(8, fns, [0, 1, 1, 1, 2, 2, 2, 2])
    xf = np.ones((8, 128, 128), np.float32)
    xi = np.zeros((8, 128, 128), np.int32)
    assert measured_region_is_fenced(f, xf, xi)

    # per-observer curves, executed provenance, in CurveDB
    db = characterize_matrix(c, [spec])
    assert set(db.curves) == {"hbm:r|hbm:w", "host:l|hbm:w"}
    for key in db.curves:
        assert len(db.curves[key]) == 4
        ex = db.provenance[key]["execution"]
        assert ex["backend"] == "spmd" and ex["fenced"]
        assert ex["executed_rungs"] == [0, 1, 2, 3]
    assert all(p.bandwidth_gbps > 0 for p in db.curves["hbm:r|hbm:w"])
    assert all(p.latency_ns > 0 for p in db.curves["host:l|hbm:w"])
    print("spmd ladder OK")
    """, n_devices=8)
