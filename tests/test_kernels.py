"""Per-kernel correctness: shape/dtype sweeps vs the pure-jnp oracles.

Every Pallas kernel runs in interpret mode (the kernel body executes in
Python on CPU) and must match ref.py to numerical tolerance.
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import chase, compute_probe, flash_attention, ref, stream

I = dict(interpret=True)


def _arr(shape, dtype=jnp.float32, seed=0, scale=1.0):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)
    return (x * scale).astype(dtype)


# ---------------------------------------------------------------------------
# stream kernels
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rows,block", [(128, 128), (512, 128), (1024, 512)])
def test_stream_read(rows, block):
    x = _arr((rows, 128))
    out = stream.read_hbm(x, block_rows=block, **I)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref.read_ref(x)),
                               rtol=2e-6)


@pytest.mark.parametrize("rows,block", [(256, 128), (512, 512)])
def test_stream_write(rows, block):
    out = stream.write_hbm(rows, value=2.5, block_rows=block, **I)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref.write_ref(rows, 2.5)))


@pytest.mark.parametrize("rows", [128, 512])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_stream_rmw(rows, dtype):
    x = _arr((rows, 128), dtype)
    out = stream.rmw_hbm(x, block_rows=128, **I)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref.rmw_ref(x), np.float32),
        rtol=1e-2 if dtype == jnp.bfloat16 else 1e-6)


@pytest.mark.parametrize("rows", [128, 1024])
def test_stream_copy(rows):
    x = _arr((rows, 128))
    out = stream.copy_hbm(x, block_rows=128, **I)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))


def test_stream_triad():
    b, c = _arr((512, 128), seed=1), _arr((512, 128), seed=2)
    out = stream.triad_hbm(b, c, scalar=3.0, block_rows=128, **I)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.triad_ref(b, c, 3.0)),
                               atol=2e-6)


@pytest.mark.parametrize("rf", [1.0, 2 / 3, 0.5, 1 / 3, 0.0])
def test_stream_mixed(rf):
    """Mixed r/w kernel: read_fraction of the blocks are sum-reduced,
    the rest written — and nothing else touches memory, so the realized
    read:write line ratio is exactly the configured one."""
    rows, block = 1024, 128
    n = rows // block
    x = _arr((rows, 128))
    s, out = stream.mixed_hbm(x, read_fraction=rf, block_rows=block, **I)
    n_r = int(round(n * rf))
    exp_sum = float(np.asarray(x[:n_r * block]).sum())
    np.testing.assert_allclose(float(s), exp_sum, rtol=2e-5)
    assert out.shape == ((n - n_r) * block, 128)   # written lines only
    if n_r < n:
        assert (np.asarray(out) == 1.0).all()


@pytest.mark.parametrize("repeats", [1, 4])
def test_vmem_read_write(repeats):
    x = _arr((256, 128))
    out = stream.read_vmem(x, repeats=repeats, **I)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.read_vmem_ref(x, repeats)),
        rtol=2e-6)
    w = stream.write_vmem(256, repeats=repeats, **I)
    np.testing.assert_array_equal(
        np.asarray(w), np.asarray(ref.write_vmem_ref(256, repeats)))


# ---------------------------------------------------------------------------
# pointer chase
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_lines", [2, 16, 64, 257])
@pytest.mark.parametrize("seed", [0, 3])
def test_chase_vmem_matches_ref(n_lines, seed):
    buf = jnp.asarray(chase.chain_buffer(n_lines, seed))
    for steps in (1, n_lines // 2 or 1, n_lines):
        out = chase.chase_vmem(buf, n_steps=steps, **I)
        assert int(out) == ref.chase_ref(np.asarray(buf), steps)


@pytest.mark.parametrize("n_lines", [8, 64])
def test_chase_hbm_matches_ref(n_lines):
    buf = jnp.asarray(chase.chain_buffer(n_lines, 1))
    out = chase.chase_hbm(buf, n_steps=n_lines, **I)
    assert int(out) == ref.chase_ref(np.asarray(buf), n_lines) == 0


def test_chain_is_single_cycle():
    for n in (1, 2, 7, 64, 100):
        nxt = chase.make_chain(n, seed=2)
        seen, idx = set(), 0
        for _ in range(n):
            assert idx not in seen
            seen.add(idx)
            idx = int(nxt[idx])
        assert idx == 0 and len(seen) == n


@pytest.mark.parametrize("stride", [1, 4, 8, 50])
def test_strided_chain_is_single_cycle(stride):
    for n in (1, 2, 7, 64, 100):
        nxt = chase.make_strided_chain(n, stride)
        seen, idx = set(), 0
        for _ in range(n):
            assert idx not in seen
            seen.add(idx)
            idx = int(nxt[idx])
        assert idx == 0 and len(seen) == n


def test_strided_chain_constant_hop():
    nxt = chase.make_strided_chain(64, 8)
    hops = {(int(nxt[i]) - i) % 64 for i in range(64)}
    assert len(hops) == 1            # every hop covers the same distance
    buf = jnp.asarray(chase.strided_chain_buffer(64, 8))
    out = chase.chase_vmem(buf, n_steps=64, **I)
    assert int(out) == 0             # full cycle returns home


# ---------------------------------------------------------------------------
# compute probe
# ---------------------------------------------------------------------------


def test_mxu_probe():
    a = jnp.eye(128, dtype=jnp.float32) * 0.5
    out = compute_probe.mxu_probe(a, iters=3, **I)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.mxu_probe_ref(a, 3)),
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# flash attention: sweep (B, H, KVH, S, D) x causal x window x dtype
# ---------------------------------------------------------------------------

CASES = [
    # b, h, kvh, sq, d, causal, window
    (1, 1, 1, 128, 64, True, 0),
    (2, 4, 2, 256, 64, True, 0),       # GQA
    (1, 4, 1, 256, 128, True, 0),      # MQA
    (1, 2, 2, 256, 64, False, 0),      # bidirectional
    (1, 4, 2, 512, 64, True, 128),     # sliding window
    (2, 2, 1, 256, 32, True, 64),      # window + GQA + small head
]


@pytest.mark.parametrize("b,h,kvh,s,d,causal,window", CASES)
def test_flash_attention_vs_ref(b, h, kvh, s, d, causal, window):
    q = _arr((b, h, s, d), seed=1, scale=0.5)
    k = _arr((b, kvh, s, d), seed=2, scale=0.5)
    v = _arr((b, kvh, s, d), seed=3, scale=0.5)
    out = flash_attention.flash_attention(
        q, k, v, causal=causal, window=window, block_q=128, block_k=128,
        **I)
    expect = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-5)


@pytest.mark.parametrize("dtype,atol", [(jnp.bfloat16, 2e-2)])
def test_flash_attention_bf16(dtype, atol):
    q = _arr((1, 2, 256, 64), dtype, seed=1, scale=0.5)
    k = _arr((1, 1, 256, 64), dtype, seed=2, scale=0.5)
    v = _arr((1, 1, 256, 64), dtype, seed=3, scale=0.5)
    out = flash_attention.flash_attention(q, k, v, causal=True, **I)
    expect = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), atol=atol)


def test_flash_attention_block_shape_independence():
    """Result must not depend on the BlockSpec tiling — including when
    the sequence does NOT divide the block shape (padded kv tail)."""
    q = _arr((1, 2, 512, 64), seed=4, scale=0.3)
    k = _arr((1, 2, 512, 64), seed=5, scale=0.3)
    v = _arr((1, 2, 512, 64), seed=6, scale=0.3)
    outs = [
        np.asarray(flash_attention.flash_attention(
            q, k, v, causal=True, block_q=bq, block_k=bk, **I))
        for bq, bk in ((128, 128), (256, 128), (128, 256), (512, 512),
                       (96, 160), (200, 200))]     # seq % block != 0
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], atol=1e-5)


@pytest.mark.parametrize("s,causal,window", [(192, True, 0), (320, True, 64),
                                             (160, False, 0)])
def test_flash_attention_ragged_seq_vs_ref(s, causal, window):
    """seq % 128 != 0: padding + masking must still match the oracle."""
    q = _arr((1, 2, s, 64), seed=1, scale=0.5)
    k = _arr((1, 2, s, 64), seed=2, scale=0.5)
    v = _arr((1, 2, s, 64), seed=3, scale=0.5)
    out = flash_attention.flash_attention(
        q, k, v, causal=causal, window=window, block_q=128, block_k=128,
        **I)
    expect = ref.attention_ref(q, k, v, causal=causal, window=window)
    assert out.shape == q.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-5)
