"""repro.compat shim behaviour on the installed JAX, plus the
grep-based drift lint: version-sensitive JAX symbols must not appear
outside compat.py (the ISSUE-1 "0 occurrences" acceptance criterion).
"""
import os
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


# ---------------------------------------------------------------------------
# Shim behaviour
# ---------------------------------------------------------------------------


def test_make_mesh_works_on_this_jax():
    m = compat.make_mesh((1, 1), ("data", "model"))
    assert m.axis_names == ("data", "model")
    assert m.devices.shape == (1, 1)


def test_make_mesh_from_devices():
    m = compat.make_mesh_from_devices(jax.devices()[:1], ("engine",))
    assert m.axis_names == ("engine",)


def test_shard_map_resolves_and_runs():
    from jax.sharding import PartitionSpec as P
    mesh = compat.make_mesh((1,), ("d",))
    f = compat.shard_map(lambda x: x * 2, mesh=mesh,
                         in_specs=(P(),), out_specs=P())
    np.testing.assert_array_equal(
        np.asarray(f(jnp.ones((4,)))), 2 * np.ones((4,)))


def test_pvary_is_safe_everywhere():
    """compat.pvary must be a value-preserving no-op on every JAX —
    exercised where the axis is actually bound (inside shard_map), so
    newer JAX's real pvary has a mesh context to resolve against."""
    from jax.sharding import PartitionSpec as P
    mesh = compat.make_mesh((1,), ("data",))
    # psum re-replicates the device-varying value pvary produces on
    # newer JAX (identity on a 1-device axis), so one body works on
    # every version
    f = compat.shard_map(
        lambda x: jax.lax.psum(compat.pvary(x, ("data",)), "data"),
        mesh=mesh, in_specs=(P(),), out_specs=P())
    x = jnp.ones((2,))
    np.testing.assert_array_equal(np.asarray(f(x)), np.asarray(x))


def test_tpu_compiler_params_constructs():
    p = compat.tpu_compiler_params(
        dimension_semantics=("parallel", "arbitrary"))
    assert p is not None
    # unknown kwargs are dropped, not fatal (field drift tolerance)
    p2 = compat.tpu_compiler_params(
        dimension_semantics=("parallel",),
        definitely_not_a_real_field_xyz=1)
    assert p2 is not None


def test_memory_kind_shardings_degrade_gracefully():
    dev = jax.devices()[0]
    s = compat.single_device_sharding(dev, "pinned_host")
    x = jax.device_put(jnp.ones((2, 2)), s)
    assert x.shape == (2, 2)
    mesh = compat.make_mesh((1,), ("d",))
    from jax.sharding import PartitionSpec as P
    ns = compat.named_sharding(mesh, P(), "pinned_host")
    assert ns.mesh is mesh


def test_optimization_barrier_preserves_values():
    x = jnp.ones((2, 2))
    y = jnp.float32(3.0)
    xx, yy = compat.optimization_barrier((x, y))
    np.testing.assert_array_equal(np.asarray(xx), np.asarray(x))
    assert float(yy) == 3.0


def test_cost_analysis_returns_dict():
    compiled = jax.jit(lambda x: x @ x).lower(
        jnp.ones((8, 8))).compile()
    ca = compat.cost_analysis(compiled)
    assert isinstance(ca, dict)
    assert ca.get("flops", 0) > 0


def test_device_clock_shim():
    """The in-dispatch timestamp probe: a declared source, plausible
    monotonic [s, ns] parts under jit, and strict ordering when the
    stamp's VALUE is threaded into the dependent computation (the
    async-fill contract the fused spmd ladder relies on)."""
    src = compat.device_clock_source()
    assert src in ("device", "callback", "none")
    if src == "none":
        pytest.skip("no timestamp source on this install")

    def f(x):
        t0 = compat.device_clock(x[0])
        # value-thread the stamp (exact zero at runtime) into the work
        y = jnp.sum(x + jnp.minimum(t0[0] + t0[1], 0).astype(x.dtype))
        t1 = compat.device_clock(y)
        return y, t0, t1

    y, t0, t1 = jax.jit(f)(jnp.ones((128,)))
    t0, t1 = np.asarray(t0).astype(np.int64), np.asarray(t1).astype(np.int64)
    assert t0.shape == (2,) and t0.dtype == np.int64
    assert 0 <= t0[1] < 1_000_000_000 and 0 <= t1[1] < 1_000_000_000
    assert t1[0] * 10**9 + t1[1] > t0[0] * 10**9 + t0[1]
    assert float(y) == 128.0                  # the zero really is exact


def test_donation_supported_probe():
    """The donation probe returns a stable bool and never raises."""
    assert compat.donation_supported() in (True, False)
    assert compat.donation_supported() == compat.donation_supported()


def test_aot_trace_and_compile_shims():
    """The AOT pipeline shims: one trace feeds both the jaxpr consumer
    (the fence checker) and lower().compile(); the compiled executable
    computes the same values; non-stageable callables degrade to None
    instead of raising."""
    f = jax.jit(lambda x: x * 2 + 1)
    x = jnp.ones((8,))
    traced = compat.aot_trace(f, x)
    if traced is not None:
        assert hasattr(traced, "jaxpr")
    compiled = compat.aot_compile(f, x, traced=traced)
    if compiled is None:
        pytest.skip("no AOT lower/compile pipeline on this install")
    np.testing.assert_allclose(np.asarray(compiled(x)),
                               3.0 * np.ones(8))
    # a bare Python callable has no AOT stages: None, not an exception
    assert compat.aot_trace(lambda v: v, x) is None
    assert compat.aot_compile(lambda v: v, x) is None


def test_persistent_cache_shim(tmp_path):
    """compat.persistent_cache enables JAX's on-disk compile cache (and
    reports honestly whether it took effect): a freshly-compiled
    callback-free program lands in the directory."""
    import os

    old = getattr(jax.config, "jax_compilation_cache_dir", None)
    enabled = compat.persistent_cache(str(tmp_path))
    try:
        assert enabled in (True, False)
        if not enabled:
            pytest.skip("persistent compilation cache unavailable")
        x = jnp.ones((32, 32))
        jax.block_until_ready(jax.jit(lambda v: v @ v + 1.75)(x))
        assert any(n.endswith("-cache") for n in os.listdir(tmp_path))
    finally:
        try:
            jax.config.update("jax_compilation_cache_dir", old)
        except Exception:
            pass


def test_psum_grouped_shim():
    """compat.psum_grouped: a plain global all-reduce when no groups
    are given (executed here), and with groups the axis_index_groups
    partition must land in the traced program — trace-level is what
    matters, because the packed fence checker reads the grouping back
    out of the jaxpr params.  (Grouped psum only LOWERS on a real
    multi-engine mesh; the packed-execution tests cover that leg.)"""
    from jax.sharding import PartitionSpec as P
    mesh = compat.make_mesh((1,), ("engine",))

    def body(groups):
        # check_rep=False: shard_map's replication-rewrite mode has no
        # rule for grouped psum; the ladder programs trace this way too
        return compat.shard_map(
            lambda x: compat.psum_grouped(x, "engine", groups),
            mesh=mesh, in_specs=(P(),), out_specs=P(), check_rep=False)

    x = jnp.arange(4.0)
    np.testing.assert_array_equal(np.asarray(body(None)(x)),
                                  np.asarray(x))
    jaxpr = jax.make_jaxpr(body(((0,),)))(x)
    found = [e.params.get("axis_index_groups")
             for sub in jax.core.subjaxprs(jaxpr.jaxpr)
             for e in sub.eqns if "psum" in e.primitive.name]
    # lists-of-group-indices normalise across releases; compare as sets
    assert found and tuple(map(tuple, found[0])) == ((0,),)


# ---------------------------------------------------------------------------
# Module-size lint: the exec pipeline must not regrow a monolith
# ---------------------------------------------------------------------------


def test_exec_pipeline_module_size_lint():
    """The coordinator split is enforced structurally: no module in
    ``src/repro/core/exec/`` may exceed 600 lines, and the coordinator
    facade must stay under 700 — a stage that outgrows its budget
    needs a new seam, not a bigger file."""
    exec_dir = os.path.join(ROOT, "src", "repro", "core", "exec")
    offenders = []
    for name in sorted(os.listdir(exec_dir)):
        if not name.endswith(".py"):
            continue
        path = os.path.join(exec_dir, name)
        with open(path, encoding="utf-8") as f:
            n = sum(1 for _ in f)
        if n > 600:
            offenders.append(f"core/exec/{name}: {n} lines (max 600)")
    coord = os.path.join(ROOT, "src", "repro", "core", "coordinator.py")
    with open(coord, encoding="utf-8") as f:
        n = sum(1 for _ in f)
    if n >= 700:
        offenders.append(f"core/coordinator.py: {n} lines (max 699)")
    assert not offenders, "monolith regrowth:\n" + "\n".join(offenders)


# ---------------------------------------------------------------------------
# Drift lint: grep the tree for version-sensitive symbols
# ---------------------------------------------------------------------------

# Symbols that have drifted across JAX releases.  Spelled with [] splits
# so this file does not match itself.
_FORBIDDEN = [
    r"jax\.sharding\.Axis" + r"Type",
    r"\bAxis" + r"Type\b",
    r"axis_" + r"types\s*=",
    r"\bTPUCompiler" + r"Params\b",
    r"pltpu\.Compiler" + r"Params\b",
    r"jax\.shard" + r"_map\b",
    r"jax\.experimental\s+import\s+shard" + r"_map",
    r"jax\.experimental\.shard" + r"_map",
    r"jax\.lax\.pv" + r"ary\b",
    # drift-prone method call; compat.cost_analysis(...) is the shim
    r"(?<!compat)\.cost_an" + r"alysis\(\)",
    r"SingleDeviceSharding\(.*memory" + r"_kind",
    r"NamedSharding\(.*memory" + r"_kind",
    # lax.switch's `operand=` kwarg is deprecated drift: operands are
    # passed positionally everywhere.  Two spellings: same-line, and a
    # bare continuation line (the historical bug had the kwarg on its
    # own wrapped line, which a same-line pattern cannot see)
    r"lax\.switch\(.*oper" + r"and\s*=",
    r"^\s*oper" + r"and\s*=",
    # optimization_barrier moved namespaces across releases; the shim
    # in compat.py is the only allowed spelling
    r"lax\.optimization_" + r"barrier\b",
    # io_callback graduated from host_callback and its fill semantics
    # are backend-dependent; compat.device_clock is the only consumer
    r"\bio_call" + r"back\b",
    # the persistent compilation cache's config spellings drifted
    # (config keys on current JAX, compilation_cache.set_cache_dir on
    # older); compat.persistent_cache is the only allowed consumer
    r"jax_compilation_" + r"cache_dir",
    r"jax_persistent_" + r"cache_min",
    r"\bset_cache_" + r"dir\b",
    r"jax\.experimental\.compilation_" + r"cache",
    # grouped collectives: the axis_index_groups kwarg's spelling and
    # validation rules drift across releases; compat.psum_grouped is
    # the only allowed consumer (reading the param back OUT of a
    # traced jaxpr — params.get(...) — carries no "=" and stays legal)
    r"axis_index_" + r"groups\s*=",
]

_SCAN_DIRS = ("src", "tests", "benchmarks", "examples")
_EXEMPT = (os.path.join("src", "repro", "compat.py"),
           os.path.join("tests", "test_compat.py"))


def _py_files():
    for d in _SCAN_DIRS:
        for root, _dirs, files in os.walk(os.path.join(ROOT, d)):
            for f in files:
                if f.endswith(".py"):
                    yield os.path.join(root, f)


def test_no_version_sensitive_jax_symbols_outside_compat():
    pats = [re.compile(p) for p in _FORBIDDEN]
    offenders = []
    for path in _py_files():
        rel = os.path.relpath(path, ROOT)
        if rel in _EXEMPT:
            continue
        with open(path, encoding="utf-8") as f:
            for lineno, line in enumerate(f, 1):
                for pat in pats:
                    if pat.search(line):
                        offenders.append(
                            f"{rel}:{lineno}: {line.strip()}"
                            f"  [{pat.pattern}]")
    assert not offenders, (
        "version-sensitive JAX symbols outside repro/compat.py "
        "(route through the compat shim):\n" + "\n".join(offenders))
