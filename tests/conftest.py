"""Shared fixtures.  NOTE: no XLA_FLAGS device forcing here — smoke tests
and benches must see 1 device (only launch/dryrun.py forces 512).  Tests
that need a multi-device mesh spawn subprocesses (test_distribution.py).
"""
import os
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
if SRC not in sys.path:
    sys.path.insert(0, os.path.abspath(SRC))


@pytest.fixture(scope="session")
def rng_key():
    import jax
    return jax.random.PRNGKey(0)
