"""Hypothesis property tests on system invariants."""
import math

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (see requirements-test.txt)")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.analysis import hlo
from repro.core import simulate as sim
from repro.core.devicetree import TPU_V5E, ZCU102
from repro.core.exec import resilience as resil
from repro.core.interface import format_experiment, parse_experiment
from repro.core.pools import PoolError, PoolManager
from repro.kernels.chase import make_chain

FAST = settings(max_examples=30, deadline=None,
                suppress_health_check=[HealthCheck.too_slow])


# ---------------------------------------------------------------------------
# Sattolo chain: single full cycle for every n, every seed
# ---------------------------------------------------------------------------


@FAST
@given(n=st.integers(1, 512), seed=st.integers(0, 2**31 - 1))
def test_chain_full_cycle(n, seed):
    nxt = make_chain(n, seed)
    assert sorted(nxt.tolist()) == list(range(n))     # a permutation
    idx, seen = 0, 0
    for _ in range(n):
        idx = int(nxt[idx])
        seen += 1
        if idx == 0:
            break
    assert seen == n                                   # single cycle


@FAST
@given(n=st.integers(2, 256), seed=st.integers(0, 1000))
def test_chain_no_fixed_points(n, seed):
    """Sattolo guarantees a cyclic permutation: no self-loops."""
    nxt = make_chain(n, seed)
    assert not (nxt == np.arange(n)).any()


# ---------------------------------------------------------------------------
# Pool allocator invariants
# ---------------------------------------------------------------------------


@FAST
@given(sizes=st.lists(st.integers(1, 1 << 22), min_size=1, max_size=20))
def test_pool_never_exceeds_capacity(sizes):
    mgr = PoolManager()
    pool = mgr.pool("vmem")                  # 128 MiB, smallest real pool
    live = []
    for s in sizes:
        rows = max(1, s // 512)
        try:
            live.append(pool.alloc((rows, 128), tag="prop"))
        except PoolError:
            assert pool.allocated + rows * 128 * 4 > pool.capacity
    assert 0 <= pool.allocated <= pool.capacity
    for a in live:
        pool.free(a)
    assert pool.allocated == 0


# ---------------------------------------------------------------------------
# Queueing model: physics invariants for arbitrary scenarios
# ---------------------------------------------------------------------------


@FAST
@given(
    mem=st.sampled_from(["hbm", "host", "peer"]),
    obs=st.sampled_from(["r", "w", "l"]),
    stress=st.sampled_from(["r", "w", "y"]),
)
def test_ladder_monotonicity(mem, obs, stress):
    plat = TPU_V5E
    ladder = sim.scenario_ladder(plat, obs_node=plat.node(mem),
                                 obs_strategy=obs,
                                 stress_node=plat.node(mem),
                                 stress_strategy=stress)
    bws = [r["obs"].bw_gbps for r in ladder]
    lats = [r["obs"].lat_ns for r in ladder]
    for a, b in zip(bws, bws[1:]):
        assert b <= a * 1.0001
    for a, b in zip(lats, lats[1:]):
        assert b >= a * 0.9999
    # sanity: all positive, below module peak
    peak = plat.node(mem).peak_bw_gbps
    traffic = sim.STRATEGY_TRAFFIC[obs]
    for bw in bws:
        assert 0 < bw <= peak / max(traffic, 1.0) * 1.0001


@FAST
@given(
    n_classes=st.integers(1, 4),
    seed=st.integers(0, 999),
)
def test_simulate_throughput_conservation(n_classes, seed):
    """Sum of station utilizations never exceeds capacity: each class's
    useful bandwidth <= module peak / traffic multiplier."""
    rng = np.random.default_rng(seed)
    plat = ZCU102
    mems = [m for m in plat.memories.values() if m.kind != "cache"]
    classes = []
    for i in range(n_classes):
        node = mems[rng.integers(len(mems))]
        strat = ["r", "w", "s", "x", "y"][rng.integers(5)]
        classes.append(sim.ActivityClass(f"c{i}", node, strat,
                                         int(rng.integers(1, 4))))
    res = sim.simulate_scenario(plat, classes)
    per_mem = {}
    for c in classes:
        r = res[c.name]
        assert r.bw_gbps >= 0 and math.isfinite(r.bw_gbps)
        assert r.r_ns > 0
        per_mem.setdefault(c.node.name, 0.0)
        per_mem[c.node.name] += r.bw_gbps * sim.STRATEGY_TRAFFIC[c.strategy]
    for mem_name, raw_bw in per_mem.items():
        assert raw_bw <= plat.memories[mem_name].peak_bw_gbps * 1.01


# ---------------------------------------------------------------------------
# TrafficShape.tag(): injective over the shape space (no key aliasing)
# ---------------------------------------------------------------------------

# shapes drawn through the public constructors (the canonical per-kind
# parameter spaces); floats go through the same exact-spelling machinery
# the CurveDB keys rely on
def _shape_strategy():
    from repro.core.scenarios import TrafficShape
    return st.one_of(
        st.just(TrafficShape.steady()),
        st.tuples(st.integers(0, 97), st.integers(0, 97))
        .filter(lambda t: t[0] + t[1] > 0)
        .map(lambda t: TrafficShape.mixed(*t)),
        st.tuples(st.floats(0.001, 1.0, allow_nan=False,
                            allow_infinity=False),
                  st.integers(1, 1024))
        .map(lambda t: TrafficShape.burst(*t)),
        st.integers(1, 4096).map(TrafficShape.strided),
    )


@FAST
@given(data=st.data())
def test_traffic_shape_tag_injective(data):
    """Distinct shapes MUST NOT alias one CurveDB key component: the
    tag is injective over the constructor-reachable shape space (the
    historical 2-decimal rounding bug aliased mixed(2,1) with
    mixed(67,33))."""
    a = data.draw(_shape_strategy())
    b = data.draw(_shape_strategy())
    assert (a == b) == (a.tag() == b.tag()), (a, b)


# ---------------------------------------------------------------------------
# Sweep-level grouping: the ladder signature determines the role tables
# ---------------------------------------------------------------------------


@FAST
@given(
    ostrat=st.sampled_from(["r", "w", "l", "c"]),
    sstrat=st.sampled_from(["r", "w", "y"]),
    obs_pool=st.sampled_from(["hbm", "host"]),
    stress_pool=st.sampled_from(["hbm", "host"]),
    iters=st.integers(1, 40),
    buf_kb=st.sampled_from([64, 128, 256]),
    duty=st.sampled_from([1.0, 0.5, 0.25]),
    max_stressors=st.integers(1, 3),
    n_eng=st.sampled_from([2, 4, 8]),
)
def test_ladder_signature_determines_role_tables(
        ostrat, sstrat, obs_pool, stress_pool, iters, buf_kb, duty,
        max_stressors, n_eng):
    """Megabatching soundness: ``ladder_signature`` is (a) a pure
    function of the role-relevant fields — a dict-round-tripped spec
    signs identically, pool renames don't change it — and (b) a
    sufficient statistic for the per-rung role tables: two specs with
    equal signatures expand to identical (strategy, shape, rows,
    iters) tables at every mesh size; perturbing iters or the buffer
    always changes the signature."""
    import json as _json

    from repro.core.coordinator import CoreCoordinator
    from repro.core.scenarios import (ObserverSpec, ScenarioSpec,
                                      StressorSpec, TrafficShape)

    buf = buf_kb << 10
    shape = (TrafficShape.steady() if duty == 1.0
             else TrafficShape.burst(duty))
    spec = ScenarioSpec(
        "sig", ObserverSpec(ostrat, obs_pool, (buf,), shape),
        (StressorSpec(sstrat, stress_pool, buf),),
        iters=iters, max_stressors=max_stressors)
    sig = spec.ladder_signature(spec.observer, buf)

    # (a) purity: serialization round-trip signs identically; a pool
    # rename (same roles) signs identically too
    back = ScenarioSpec.from_dict(_json.loads(_json.dumps(
        spec.to_dict())))
    assert back.ladder_signature(back.observer, buf) == sig
    other_pool = "host" if obs_pool == "hbm" else "hbm"
    renamed = ScenarioSpec(
        "ren", ObserverSpec(ostrat, other_pool, (buf,), shape),
        (StressorSpec(sstrat, stress_pool, buf),),
        iters=iters, max_stressors=max_stressors)
    assert renamed.ladder_signature(renamed.observer, buf) == sig

    # (b) equal signature => identical role tables at every mesh size
    coord = CoreCoordinator(backend="simulate")
    for k in range(min(max_stressors + 1, n_eng)):
        roles_a, _pa = coord._rung_roles(spec, spec.observer, buf, k,
                                         n_eng)
        roles_b, _pb = coord._rung_roles(back, back.observer, buf, k,
                                         n_eng)
        roles_c, _pc = coord._rung_roles(renamed, renamed.observer,
                                         buf, k, n_eng)
        assert roles_a == roles_b == roles_c

    # role-relevant perturbations always split
    assert ScenarioSpec(
        "it", ObserverSpec(ostrat, obs_pool, (buf,), shape),
        (StressorSpec(sstrat, stress_pool, buf),),
        iters=iters + 1, max_stressors=max_stressors,
    ).ladder_signature(spec.observer, buf) != sig
    assert spec.ladder_signature(spec.observer, 2 * buf) != sig


# ---------------------------------------------------------------------------
# CurveDB v3: save -> load -> save is byte-idempotent (execution incl.)
# ---------------------------------------------------------------------------


@FAST
@given(
    ostrat=st.sampled_from(["r", "w", "l"]),
    sstrat=st.sampled_from(["r", "w", "y", "c"]),
    kind=st.sampled_from(["steady", "mixed", "burst", "strided"]),
    coupled=st.booleans(),
    n_co=st.integers(0, 2),
    max_stressors=st.integers(0, 3),
)
def test_curvedb_v3_save_load_save_idempotent(ostrat, sstrat, kind,
                                              coupled, n_co,
                                              max_stressors):
    """A CurveDB written, loaded, and written again must produce the
    identical file — including the ``execution`` provenance fields
    (backend, activity, coupled, rung lists) introduced with the
    coupled spmd backend.  The v2 downgrade leg must also load and
    preserve the curve values."""
    import json
    import tempfile

    from repro.core.characterize import characterize_matrix
    from repro.core.coordinator import CoreCoordinator
    from repro.core.scenarios import (ObserverSpec, ScenarioSpec,
                                      StressorSpec, TrafficShape)

    shape = {"steady": TrafficShape.steady(),
             "mixed": TrafficShape.mixed(2, 1),
             "burst": TrafficShape.burst(0.5),
             "strided": TrafficShape.strided(8)}[kind]
    BUF = 1 << 20
    observers = tuple([ObserverSpec(ostrat, "hbm", (BUF,))]
                      + [ObserverSpec("r", "host", ((j + 2) * BUF,))
                         for j in range(n_co)])
    spec = ScenarioSpec(
        "prop", observers,
        (StressorSpec(sstrat, "hbm", BUF, shape),),
        iters=3, max_stressors=max_stressors, coupled=coupled)
    db = characterize_matrix(CoreCoordinator(backend="simulate"), [spec])
    for entry in db.provenance.values():
        ex = entry["execution"]
        assert ex["activity"] == "none" and ex["backend"] == "simulate"
        assert ex["coupled"] == (coupled and n_co > 0)
    with tempfile.TemporaryDirectory() as d:
        p1, p2 = f"{d}/a.json", f"{d}/b.json"
        db.save(p1)
        db2 = type(db).load(p1)
        db2.save(p2)
        with open(p1) as f1, open(p2) as f2:
            t1, t2 = f1.read(), f2.read()
        assert t1 == t2
        assert json.loads(t1)["schema"] == 3
        # the downgrade leg: schema-2 save loads with identical curves
        p3 = f"{d}/v2.json"
        db.save(p3, schema=2)
        assert json.load(open(p3))["schema"] == 2
        old = type(db).load(p3)
        assert old.curves.keys() == db.curves.keys()
        for k, pts in db.curves.items():
            assert [vars(p) for p in old.curves[k]] == \
                [vars(p) for p in pts]


# ---------------------------------------------------------------------------
# v1 curve files: forward-load on the current CurveDB
# ---------------------------------------------------------------------------


@FAST
@given(
    n_points=st.integers(1, 8),
    bw0=st.floats(1.0, 4000.0, allow_nan=False, allow_infinity=False),
    lat0=st.floats(1.0, 5000.0, allow_nan=False, allow_infinity=False),
    pools=st.lists(st.sampled_from(["hbm", "host", "peer"]),
                   min_size=1, max_size=3, unique=True),
)
def test_curvedb_v1_forward_load(n_points, bw0, lat0, pools):
    """Any schema-less (seed-format) curve file loads as schema 1 with
    empty provenance, serves lookups, and re-saves without mutating its
    schema or values."""
    import json
    import tempfile

    from repro.core.characterize import CurveDB

    curves = {}
    for pool in pools:
        for strat in ("r", "l"):
            curves[f"{pool}:{strat}|{pool}:w"] = [
                {"n_stressors": k,
                 "bandwidth_gbps": bw0 / (k + 1),
                 "latency_ns": lat0 * (k + 1)}
                for k in range(n_points)]
    with tempfile.TemporaryDirectory() as d:
        p = f"{d}/v1.json"
        with open(p, "w") as f:
            json.dump({"platform": "tpu-v5e", "curves": curves}, f)
        db = CurveDB.load(p)
        assert db.schema == 1 and db.provenance == {}
        for pool in pools:
            assert db.effective_bw(pool, n_points - 1) == \
                bw0 / n_points
            assert db.effective_lat(pool, 0) == lat0
            # shaped lookups fall back to the steady curves on v1
            assert db.effective_bw(pool, 0, shape_tag="dc0.50") == bw0
        p2 = f"{d}/v1-resaved.json"
        db.save(p2)
        db2 = CurveDB.load(p2)
        assert db2.schema == 1
        assert {k: [vars(pt) for pt in v] for k, v in db2.curves.items()} \
            == curves


# ---------------------------------------------------------------------------
# Interface grammar roundtrip
# ---------------------------------------------------------------------------


@FAST
@given(
    strat1=st.sampled_from(list("rwlsxmy")),
    strat2=st.sampled_from(list("rwlsxmyi")),
    pool1=st.sampled_from(["hbm", "host", "vmem", "peer"]),
    pool2=st.sampled_from(["hbm", "host"]),
    nbytes=st.integers(1, 1 << 28),
    iters=st.integers(1, 10_000),
)
def test_experiment_string_roundtrip(strat1, strat2, pool1, pool2, nbytes,
                                     iters):
    cfg = parse_experiment(
        f"{strat1},{pool1},{nbytes} {strat2},{pool2},{nbytes} "
        f"iters={iters}")
    cfg2 = parse_experiment(format_experiment(cfg))
    assert cfg2 == cfg


# ---------------------------------------------------------------------------
# HLO shape parsing
# ---------------------------------------------------------------------------


@FAST
@given(
    dt=st.sampled_from(["f32", "bf16", "s32", "u8", "pred", "f16"]),
    dims=st.lists(st.integers(1, 4096), min_size=0, max_size=4),
)
def test_shape_bytes(dt, dims):
    text = f"{dt}[{','.join(map(str, dims))}]{{{','.join('0' * 0)}}}"
    expect = int(np.prod(dims)) if dims else 1
    expect *= hlo.DTYPE_BYTES[dt]
    assert hlo.shape_bytes(text) == expect


@FAST
@given(
    m=st.integers(1, 64), n=st.integers(1, 64), k=st.integers(1, 64),
)
def test_dot_flops_parse(m, n, k):
    text = f"""
ENTRY %main (p0: f32[{m},{k}], p1: f32[{k},{n}]) -> f32[{m},{n}] {{
  %p0 = f32[{m},{k}]{{1,0}} parameter(0)
  %p1 = f32[{k},{n}]{{1,0}} parameter(1)
  ROOT %dot = f32[{m},{n}]{{1,0}} dot(%p0, %p1), lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}
}}
"""
    cost = hlo.analyze(text)
    assert cost.flops == 2.0 * m * n * k


# ---------------------------------------------------------------------------
# Fault injector: seeded schedules are byte-reproducible (PR 9)
# ---------------------------------------------------------------------------


@FAST
@given(
    seed=st.integers(0, 2**31 - 1),
    rates=st.lists(st.floats(0.0, 1.0, allow_nan=False), min_size=4,
                   max_size=4),
    visits=st.lists(
        st.tuples(st.sampled_from(["a", "b", "c", "site-x"]),
                  st.sampled_from(["compile", "dispatch", "decode"])),
        min_size=1, max_size=60),
)
def test_fault_schedule_byte_reproducible(seed, rates, visits):
    """Two injectors from the same FaultSpec replay IDENTICAL fault
    schedules over any site-visit sequence — serialised to bytes, the
    schedules are equal — and draws are pure functions of
    (seed, site, phase, attempt), independent of injector state."""
    spec = resil.FaultSpec(compile_error=rates[0], runtime_error=rates[1],
                           timeout=rates[2], corrupt_timing=rates[3],
                           seed=seed)
    a, b = spec.injector(), spec.injector()
    sched_a = [a.check(s, p) for s, p in visits]
    sched_b = [b.check(s, p) for s, p in visits]
    enc = lambda sch: "\x00".join(k or "-" for k in sch).encode()
    assert enc(sched_a) == enc(sched_b)
    # each fired kind belongs to the phase that drew it
    for (site, phase), kind in zip(visits, sched_a):
        if kind is not None:
            assert kind in resil._PHASE_KINDS[phase]
    # draws are stateless: a third injector agrees draw-for-draw even
    # after its counters were advanced by unrelated sites
    c = spec.injector()
    for _ in range(5):
        c.check("unrelated", "dispatch")
    for site, phase in visits[:10]:
        for attempt in (0, 1, 7):
            assert a.draw(site, phase, attempt) == \
                c.draw(site, phase, attempt)
    # rate-0 kinds never fire
    for (site, phase), kind in zip(visits, sched_a):
        if kind is not None:
            assert spec.rate(kind) > 0.0
