"""ScenarioSpec DSL + CurveDB v2 + batched matrix runner.

Covers the ISSUE-1 acceptance criteria: spec round-trip serialization,
schema versioning (v1 curve files still load), the shaped smoke sweep on
the ``simulate`` backend, real-kernel execution on ``interpret``, and
the batched runner's dispatch advantage on a >= 64-scenario sweep.
"""
import json

import pytest

from repro.core.characterize import (CurveDB, CurvePoint, characterize,
                                     characterize_matrix)
from repro.core.coordinator import CoreCoordinator, ValidationError
from repro.core.placement import ContentionSpec, MemObject, PlacementAdvisor
from repro.core.scenarios import (DEFAULT_STRESS_SHAPES, ObserverSpec,
                                  ScenarioSpec, StressorSpec, TrafficShape,
                                  load_matrix, save_matrix, scenario_matrix)

BUF = 1 << 20


def _spec(name="s", ostrat="r", sstrat="w", shape=None,
          buffers=(BUF,)) -> ScenarioSpec:
    return ScenarioSpec(
        name=name,
        observer=ObserverSpec(ostrat, "hbm", tuple(buffers)),
        stressors=(StressorSpec(sstrat, "hbm", BUF,
                                shape or TrafficShape.steady()),),
        iters=5)


# ---------------------------------------------------------------------------
# TrafficShape
# ---------------------------------------------------------------------------


def test_traffic_shape_constructors_and_tags():
    assert TrafficShape.steady().tag() == ""
    # exactly-representable parameters keep the short 2-decimal form
    assert TrafficShape.mixed(1, 1).read_fraction == 0.5
    assert TrafficShape.mixed(1, 1).tag() == "rf0.50"
    assert TrafficShape.burst(0.5).tag() == "dc0.50"
    assert TrafficShape.strided(8).tag() == "st8"
    # non-terminating ratios widen until the spelling round-trips
    assert TrafficShape.mixed(2, 1).tag() == f"rf{2 / 3!r}"
    assert float(TrafficShape.mixed(2, 1).tag()[2:]) == 2 / 3


def test_burst_len_is_part_of_the_tag():
    """Regression: burst shapes differing only in burst_len aliased one
    key, so a burst-length sweep tripped the collision guard."""
    assert TrafficShape.burst(0.5).tag() == "dc0.50"          # default len
    assert TrafficShape.burst(0.5, 128).tag() == "dc0.50x128"
    c = CoreCoordinator(backend="simulate")
    db = characterize_matrix(c, [
        _spec("b64", shape=TrafficShape.burst(0.5, 64)),
        _spec("b128", shape=TrafficShape.burst(0.5, 128)),
    ])
    assert len(db.curves) == 2


def test_key_for_matches_for_equal_observers():
    """Regression: sibling detection compared by identity, so a
    reconstructed (equal, non-identical) observer got a spurious buf=
    suffix and missed the stored curve key."""
    spec = ScenarioSpec(
        "multi",
        (ObserverSpec("r", "hbm", (BUF,)),
         ObserverSpec("l", "host", (BUF,))),
        (StressorSpec("w", "hbm", BUF),), iters=5)
    stored = spec.key_for(spec.observers[1], BUF)
    rebuilt = spec.key_for(ObserverSpec("l", "host", (BUF,)), BUF)
    assert stored == rebuilt == "host:l|hbm:w"


def test_tag_precision_cannot_alias_distinct_ratios():
    """Regression: rf/dc spellings used to round to 2 decimals, so
    mixed(2,1) and mixed(67,33) aliased one CurveDB key and tripped the
    characterize_matrix collision guard."""
    a, b = TrafficShape.mixed(2, 1), TrafficShape.mixed(67, 33)
    assert a.read_fraction != b.read_fraction
    assert a.tag() != b.tag()
    assert TrafficShape.burst(2 / 3).tag() != TrafficShape.burst(0.67).tag()
    # ...and through the full matrix path: distinct keys, no collision
    c = CoreCoordinator(backend="simulate")
    db = characterize_matrix(c, [
        _spec("two-one", shape=TrafficShape.mixed(2, 1)),
        _spec("sixtyseven", shape=TrafficShape.mixed(67, 33)),
    ])
    assert len(db.curves) == 2


def test_traffic_shape_validation():
    with pytest.raises(ValueError):
        TrafficShape(kind="nope")
    with pytest.raises(ValueError):
        TrafficShape(kind="burst", duty_cycle=0.0)
    with pytest.raises(ValueError):
        TrafficShape(kind="mixed", read_fraction=1.5)
    with pytest.raises(ValueError):
        TrafficShape.strided(0)
    with pytest.raises(ValueError):
        TrafficShape.mixed(0, 0)


# ---------------------------------------------------------------------------
# ScenarioSpec round-trip
# ---------------------------------------------------------------------------


def test_spec_dict_roundtrip():
    spec = ScenarioSpec(
        name="shaped",
        observer=ObserverSpec("r", "hbm", (BUF, 2 * BUF)),
        stressors=(
            StressorSpec("w", "host", BUF, TrafficShape.burst(0.25)),
            StressorSpec("r", "hbm", BUF, TrafficShape.mixed(1, 2)),
            StressorSpec("m", "hbm", BUF, TrafficShape.strided(16)),
        ),
        iters=42, max_stressors=3)
    d = spec.to_dict()
    back = ScenarioSpec.from_dict(json.loads(json.dumps(d)))
    assert back == spec


def test_matrix_file_roundtrip(tmp_path):
    specs = scenario_matrix(pools=["hbm", "host"], buffer_bytes=BUF,
                            obs_strategies=("r", "l"),
                            stress_shapes=DEFAULT_STRESS_SHAPES, iters=5)
    p = str(tmp_path / "matrix.json")
    save_matrix(specs, p)
    assert load_matrix(p) == specs


def test_v1_compatible_keys():
    """Steady single-stressor scenarios must key exactly like the seed."""
    assert _spec().key() == "hbm:r|hbm:w"
    assert CurveDB.key("hbm", "r", "hbm", "w").to_string() == "hbm:r|hbm:w"
    shaped = _spec(shape=TrafficShape.burst(0.5))
    assert shaped.key() == "hbm:r|hbm:w@dc0.50"
    assert CurveDB.key("hbm", "r", "hbm", "w",
                       "dc0.50").to_string() == shaped.key()


def test_spec_validation():
    c = CoreCoordinator(backend="simulate")
    c.validate_spec(_spec())
    with pytest.raises(ValidationError):
        c.validate_spec(_spec(ostrat="z"))
    with pytest.raises(ValidationError):
        bad = ScenarioSpec("b", ObserverSpec("r", "hbm", (BUF,)),
                           iters=0)
        c.validate_spec(bad)


# ---------------------------------------------------------------------------
# CurveDB schema versioning
# ---------------------------------------------------------------------------


def test_curvedb_v3_roundtrip_with_provenance(tmp_path):
    c = CoreCoordinator(backend="simulate")
    specs = [_spec(), _spec("shaped", shape=TrafficShape.mixed(1, 1))]
    db = characterize_matrix(c, specs)
    assert db.schema == 3
    assert set(db.provenance) == set(db.curves)
    p = str(tmp_path / "v3.json")
    db.save(p)
    db2 = CurveDB.load(p)
    assert db2.schema == 3
    assert db2.curves.keys() == db.curves.keys()
    k = "hbm:r|hbm:w@rf0.50"
    assert ScenarioSpec.from_dict(db2.provenance[k]).stressors[0].shape \
        == TrafficShape.mixed(1, 1)
    assert db2.meta["model_evals"] > 0


def test_curvedb_v1_files_still_load(tmp_path):
    """A seed-format (schema-less) curve file must load and serve
    lookups, including the shaped-tag fallback to steady curves."""
    v1 = {"platform": "tpu-v5e",
          "curves": {"hbm:r|hbm:w": [
              {"n_stressors": 0, "bandwidth_gbps": 800.0,
               "latency_ns": 100.0},
              {"n_stressors": 1, "bandwidth_gbps": 400.0,
               "latency_ns": 200.0}],
              "hbm:l|hbm:w": [
              {"n_stressors": 0, "bandwidth_gbps": 1.0,
               "latency_ns": 390.0},
              {"n_stressors": 1, "bandwidth_gbps": 0.5,
               "latency_ns": 800.0}]}}
    p = str(tmp_path / "v1.json")
    with open(p, "w") as f:
        json.dump(v1, f)
    db = CurveDB.load(p)
    assert db.schema == 1
    assert db.provenance == {}
    assert db.effective_bw("hbm", 1) == 400.0
    # shaped lookup falls back to the steady curve on a v1 db
    assert db.effective_bw("hbm", 1, shape_tag="dc0.50") == 400.0
    assert db.effective_lat("hbm", 1, shape_tag="rf0.33") == 800.0


# ---------------------------------------------------------------------------
# Shaped smoke sweep (simulate backend physics)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def shaped_db():
    c = CoreCoordinator(backend="simulate")
    db = characterize(c, pools=["hbm", "host"],
                      obs_strategies=("r", "l"),
                      stress_strategies=("r", "w"),
                      stress_shapes=DEFAULT_STRESS_SHAPES, iters=5)
    return db, c


RF21 = TrafficShape.mixed(2, 1).tag()
RF11 = TrafficShape.mixed(1, 1).tag()
RF12 = TrafficShape.mixed(1, 2).tag()


def test_shaped_sweep_produces_new_curves(shaped_db):
    db, _ = shaped_db
    tags = {k.tag for k in db.surfaces if k.tag}
    assert {RF21, RF11, RF12, "dc0.50", "st8"} <= tags
    # copy stressor curves exist under the steady key format
    assert "hbm:r|hbm:c" in db.curves


def test_mixed_ratio_interpolates_read_write(shaped_db):
    """More write share in the mix -> more WAWB traffic -> lower
    observed bandwidth, bracketed by the pure-read and pure-write
    steady curves."""
    db, _ = shaped_db
    worst = -1
    bw_r = db.curves["hbm:r|hbm:r"][worst].bandwidth_gbps
    bw_21 = db.curves[f"hbm:r|hbm:r@{RF21}"][worst].bandwidth_gbps
    bw_11 = db.curves[f"hbm:r|hbm:r@{RF11}"][worst].bandwidth_gbps
    bw_12 = db.curves[f"hbm:r|hbm:r@{RF12}"][worst].bandwidth_gbps
    assert bw_r >= bw_21 >= bw_11 >= bw_12


def test_burst_stress_degrades_less_than_steady(shaped_db):
    db, _ = shaped_db
    steady = db.curves["hbm:r|hbm:w"]
    burst = db.curves["hbm:r|hbm:w@dc0.50"]
    assert burst[-1].bandwidth_gbps > steady[-1].bandwidth_gbps
    # both still monotonically degrade with stressor count
    bws = [p.bandwidth_gbps for p in burst]
    assert all(a >= b - 1e-9 for a, b in zip(bws, bws[1:]))


def test_strided_chase_modeled_distinctly():
    """The strided shape must reach the queueing model: a strided chase
    observer sees higher latency than a unit-stride chase (lost
    row-buffer/prefetch locality), so '@st8' curves are not duplicates
    of the steady chase curves."""
    from repro.core import simulate as sim
    from repro.core.devicetree import TPU_V5E
    node = TPU_V5E.node("hbm")
    plain = sim.simulate_scenario(
        TPU_V5E, [sim.ActivityClass("obs", node, "m", 1)])
    strided = sim.simulate_scenario(
        TPU_V5E, [sim.ActivityClass("obs", node, "m", 1, stride=8)])
    assert strided["obs"].lat_ns > plain["obs"].lat_ns
    # and through the full matrix path: a strided observer's modeled
    # latency curve sits above the unit-stride one
    c = CoreCoordinator(backend="simulate")
    runs = c.run_matrix([
        ScenarioSpec("plain", ObserverSpec("m", "hbm", (BUF,)),
                     (StressorSpec("w", "hbm", BUF),), iters=5),
        ScenarioSpec("strided", ObserverSpec(
            "m", "hbm", (BUF,), TrafficShape.strided(8)),
            (StressorSpec("w", "hbm", BUF),), iters=5),
    ]).runs
    lat_plain = [p[1] for p in runs[0].latency_curve()]
    lat_strided = [p[1] for p in runs[1].latency_curve()]
    assert all(s > p for s, p in zip(lat_strided, lat_plain))


def test_batched_chase_latency_matches_naive():
    """The batched chase pass splits group wall time /g — valid only if
    the g chains execute back-to-back within the vmapped pass.  Guard
    that assumption by comparing against the naive single-chase path."""
    from repro.core.pools import PoolManager
    from repro.core.workloads import make_workload, measure_group
    mgr = PoolManager()
    wl = make_workload("l", mgr.pool("hbm"), 64 << 10)
    try:
        naive = wl.run(10)
    finally:
        wl.release()
    batched, _ = measure_group("l", mgr.pool("hbm"), 64 << 10, 6, 10)
    # loose bound: wall-clock noise under full-suite load is real, but
    # a broken /g split (g=6 here) would still be ~6x off
    assert batched[0].latency_ns == pytest.approx(naive.latency_ns,
                                                  rel=1.0)


def test_copy_stress_between_read_and_write(shaped_db):
    """Copy traffic (1.5 Tx/line) must hurt more than pure reads
    (1 Tx/line) and no more than allocating writes (2 Tx/line)."""
    db, _ = shaped_db
    bw_r = db.curves["hbm:r|hbm:r"][-1].bandwidth_gbps
    bw_c = db.curves["hbm:r|hbm:c"][-1].bandwidth_gbps
    bw_w = db.curves["hbm:r|hbm:w"][-1].bandwidth_gbps
    assert bw_w <= bw_c <= bw_r


def test_placement_consumes_shaped_curves(shaped_db):
    db, c = shaped_db
    adv = PlacementAdvisor(db, c.platform, pools=["hbm", "host"])
    obj = MemObject("heap", BUF, bytes_per_step=float(BUF))
    steady = adv.predict_ns(obj, "hbm", ContentionSpec(7, "hbm", "w"))
    burst = adv.predict_ns(
        obj, "hbm",
        ContentionSpec.shaped(7, "hbm", "w", TrafficShape.burst(0.5)))
    assert burst < steady          # duty-cycled stress hurts less


# ---------------------------------------------------------------------------
# Batched matrix runner on real (interpret-mode) kernels
# ---------------------------------------------------------------------------


def test_interpret_matrix_runs_real_kernels():
    c = CoreCoordinator(backend="interpret")
    specs = [
        ScenarioSpec("copy", ObserverSpec("c", "hbm", (64 << 10,)),
                     (StressorSpec("w", "hbm", 64 << 10),),
                     iters=2, max_stressors=1),
        ScenarioSpec("mixed", ObserverSpec(
            "r", "hbm", (64 << 10,), TrafficShape.mixed(1, 1)),
            (StressorSpec("w", "hbm", 64 << 10),),
            iters=2, max_stressors=1),
        ScenarioSpec("strided", ObserverSpec(
            "m", "hbm", (64 << 10,), TrafficShape.strided(8)),
            (StressorSpec("w", "hbm", 64 << 10),),
            iters=2, max_stressors=1),
    ]
    res = c.run_matrix(specs)
    for run in res.runs:
        assert run.scenarios[0].main.bytes_moved > 0
        assert run.scenarios[0].main.elapsed_ns > 0
    # strided chase reports per-transaction latency
    assert res.runs[2].scenarios[0].main.latency_ns > 0
    for p in c.pools.pools():
        assert p.allocated == 0


def test_batched_runner_fewer_dispatches_64():
    """>= 64-scenario sweep: the batched runner must dispatch
    demonstrably fewer measured passes than the per-point loop."""
    c = CoreCoordinator(backend="interpret")
    specs = scenario_matrix(pools=["hbm", "host"],
                            buffer_bytes=64 << 10,
                            obs_strategies=("r", "w"),
                            stress_shapes=DEFAULT_STRESS_SHAPES[:8],
                            iters=2, max_stressors=1)
    assert len(specs) >= 64
    batched = c.run_matrix(specs, batched=True)
    naive = c.run_matrix(specs, batched=False)
    assert naive.stats.measure_dispatches == len(specs)
    assert batched.stats.measure_dispatches < naive.stats.measure_dispatches
    assert batched.stats.measure_dispatches <= 8
    # both modes measured every scenario
    assert batched.stats.n_scenarios == naive.stats.n_scenarios == len(specs)
    for run in batched.runs:
        assert run.scenarios[0].main.elapsed_ns > 0


def test_ladder_signature_grouping_never_merges_distinct_roles():
    """Sweep-level grouping soundness (ISSUE-5 satellite), on a concrete
    grid: any two (spec, observer, buffer) triples landing in one
    `_spmd_group_key` group must expand to IDENTICAL per-rung role
    tables at every mesh size, identical iteration budgets, and
    identical effective memory kinds — and every role-relevant field
    (strategy, shape, buffer, iters) must split groups.  Pools that
    differ only in name but share one effective memory kind are the
    ONLY legal merge."""
    coord = CoreCoordinator(backend="simulate")
    specs = []
    for strat in ("r", "w"):
        for pool in ("hbm", "host"):
            for iters in (5, 9):
                for shape in (TrafficShape.steady(),
                              TrafficShape.burst(0.5)):
                    for buf in (64 << 10, 128 << 10):
                        specs.append(ScenarioSpec(
                            f"g.{strat}.{pool}.{iters}."
                            f"{shape.tag() or 'steady'}.{buf}",
                            ObserverSpec(strat, pool, (buf,), shape),
                            (StressorSpec("w", "hbm", 64 << 10),),
                            iters=iters, max_stressors=2))
    triples = [(s, o, b) for s in specs for o in s.observers
               for b in o.buffers]
    groups = {}
    for t in triples:
        groups.setdefault(coord._spmd_group_key(*t), []).append(t)

    kinds_equal = (coord.pools.pool("hbm").effective_memory_kind()
                   == coord.pools.pool("host").effective_memory_kind())
    # 2 strategies x 2 iters x 2 shapes x 2 buffers always split; the
    # pool axis merges exactly when the effective kinds agree
    assert len(groups) == (16 if kinds_equal else 32)
    for members in groups.values():
        ref = members[0]
        for m in members[1:]:
            assert ref[0].iters == m[0].iters
            for n_eng in (2, 4):
                for k in range(min(3, n_eng)):
                    roles_ref, pools_ref = coord._rung_roles(
                        ref[0], ref[1], ref[2], k, n_eng)
                    roles_m, pools_m = coord._rung_roles(
                        m[0], m[1], m[2], k, n_eng)
                    assert roles_ref == roles_m     # identical tables
                    assert [coord.pools.pool(p).effective_memory_kind()
                            for p in pools_ref] \
                        == [coord.pools.pool(p).effective_memory_kind()
                            for p in pools_m]


def test_ladder_signature_covers_siblings_and_stressors():
    """The signature must split on everything outside the observer too:
    stressor ensembles, sibling observers, coupling, max_stressors."""
    BUF2 = 64 << 10
    obs = ObserverSpec("r", "hbm", (BUF2,))
    base = ScenarioSpec("base", obs, (StressorSpec("w", "hbm", BUF2),),
                        iters=5, max_stressors=2)
    sig = base.ladder_signature(obs, BUF2)
    # different stressor strategy / shape / buffer
    for s in (StressorSpec("y", "hbm", BUF2),
              StressorSpec("w", "hbm", BUF2, TrafficShape.burst(0.5)),
              StressorSpec("w", "hbm", 2 * BUF2)):
        other = ScenarioSpec("o", obs, (s,), iters=5, max_stressors=2)
        assert other.ladder_signature(obs, BUF2) != sig
    # a coupled sibling changes the signature; uncoupling removes it
    sib = ObserverSpec("l", "hbm", (BUF2,))
    multi = ScenarioSpec("m", (obs, sib),
                         (StressorSpec("w", "hbm", BUF2),),
                         iters=5, max_stressors=2)
    assert multi.ladder_signature(obs, BUF2) != sig
    unc = ScenarioSpec("u", (obs, sib), (StressorSpec("w", "hbm", BUF2),),
                       iters=5, max_stressors=2, coupled=False)
    assert unc.ladder_signature(obs, BUF2) == sig
    # ladder depth is part of the identity
    deeper = ScenarioSpec("d", obs, (StressorSpec("w", "hbm", BUF2),),
                          iters=5, max_stressors=3)
    assert deeper.ladder_signature(obs, BUF2) != sig
    # ...and pool names are deliberately NOT (the kind refinement in
    # _spmd_group_key handles placement)
    hosted = ScenarioSpec("h", ObserverSpec("r", "host", (BUF2,)),
                          (StressorSpec("w", "hbm", BUF2),),
                          iters=5, max_stressors=2)
    assert hosted.ladder_signature(hosted.observer, BUF2) == sig


def test_multi_observer_spec_roundtrip_and_keys():
    """A tuple of observers normalizes into observer + co_observers,
    round-trips through dicts, and keys one curve per observer."""
    spec = ScenarioSpec(
        "multi",
        (ObserverSpec("r", "hbm", (BUF,)),
         ObserverSpec("l", "host", (BUF,))),
        (StressorSpec("w", "hbm", BUF),), iters=5)
    assert spec.observer == ObserverSpec("r", "hbm", (BUF,))
    assert spec.co_observers == (ObserverSpec("l", "host", (BUF,)),)
    assert len(spec.observers) == 2
    # primary key stays v1-compatible; co-observer keys its own curve
    assert spec.key() == "hbm:r|hbm:w"
    assert spec.key_for(spec.observers[1]) == "host:l|hbm:w"
    back = ScenarioSpec.from_dict(json.loads(json.dumps(spec.to_dict())))
    assert back == spec
    with pytest.raises(ValueError):
        ScenarioSpec("empty", ())


def test_multi_observer_single_vmapped_pass():
    """Two observers measuring two pools (whose placement lands in the
    same physical memory on this container) collapse into ONE vmapped
    measured pass, each yielding its own correctly-labeled curve."""
    c = CoreCoordinator(backend="interpret")
    spec = ScenarioSpec(
        "multi",
        (ObserverSpec("r", "hbm", (64 << 10,)),
         ObserverSpec("r", "host", (64 << 10,))),
        (StressorSpec("w", "hbm", 64 << 10),),
        iters=2, max_stressors=1)
    res = c.run_matrix([spec])
    assert res.stats.n_ladders == 2
    assert res.stats.measure_dispatches == 1     # one pass, two pools
    keys = {run.key for run in res.runs}
    assert keys == {"hbm:r|hbm:w", "host:r|hbm:w"}
    for run in res.runs:
        assert run.scenarios[0].main.pool == run.observer.pool
        assert run.scenarios[0].main.elapsed_ns > 0
    # ...and per-observer curves land in CurveDB
    db = characterize_matrix(c, [spec])
    assert set(db.curves) == keys


def test_multi_observer_same_pool_keys_do_not_alias():
    """Regression: two observers differing only in buffer size used to
    key the same curve ('hbm:r|hbm:w'), and the collision guard (which
    compared spec dicts — identical here) silently overwrote the first
    observer's curve with the second's."""
    spec = ScenarioSpec(
        "twin",
        (ObserverSpec("r", "hbm", (BUF,)),
         ObserverSpec("r", "hbm", (2 * BUF,))),
        (StressorSpec("w", "hbm", BUF),), iters=5, max_stressors=1)
    keys = {spec.key_for(o, o.buffers[0]) for o in spec.observers}
    assert keys == {f"hbm:r|hbm:w|buf={BUF}",
                    f"hbm:r|hbm:w|buf={2 * BUF}"}
    c = CoreCoordinator(backend="simulate")
    db = characterize_matrix(c, [spec])
    assert set(db.curves) == keys          # both curves survive
    for key in keys:
        assert db.provenance[key]["curve"]["buffer_bytes"] in (BUF,
                                                               2 * BUF)


def test_batched_groups_split_by_iters():
    """Regression: members of one signature group used to be measured
    (and stamped) at the group-max iteration budget.  Groups now split
    by iters, so every result carries its own spec's budget."""
    c = CoreCoordinator(backend="interpret")
    specs = [
        ScenarioSpec("short", ObserverSpec("r", "hbm", (64 << 10,)),
                     (StressorSpec("w", "hbm", 64 << 10),),
                     iters=2, max_stressors=1),
        ScenarioSpec("long", ObserverSpec("r", "hbm", (64 << 10,)),
                     (StressorSpec("y", "hbm", 64 << 10),),
                     iters=7, max_stressors=1),
    ]
    res = c.run_matrix(specs)
    stamps = {run.spec.name: run.scenarios[0].main.iters
              for run in res.runs}
    assert stamps == {"short": 2, "long": 7}


def test_dispatch_stats_count_scenarios_not_pairs():
    """Regression: n_scenarios used to count (spec, buffer) pairs; the
    ladder expansion now lives in n_ladders."""
    c = CoreCoordinator(backend="simulate")
    spec = ScenarioSpec(
        "ladder", ObserverSpec("r", "hbm", (BUF, 2 * BUF)),
        (StressorSpec("w", "hbm", BUF),), iters=5, max_stressors=1)
    multi = ScenarioSpec(
        "multi",
        (ObserverSpec("r", "hbm", (BUF,)),
         ObserverSpec("l", "host", (BUF,))),
        (StressorSpec("w", "hbm", BUF),), iters=5, max_stressors=1)
    res = c.run_matrix([spec, multi])
    assert res.stats.n_scenarios == 2          # two ScenarioSpecs...
    assert res.stats.n_ladders == 4            # ...expanding to 4 curves


def test_buffer_ladder_keys_are_distinct():
    c = CoreCoordinator(backend="simulate")
    spec = ScenarioSpec(
        "ladder", ObserverSpec("r", "hbm", (BUF, 2 * BUF)),
        (StressorSpec("w", "hbm", BUF),), iters=5, max_stressors=1)
    res = c.run_matrix([spec])
    keys = [r.key for r in res.runs]
    assert len(keys) == 2 and len(set(keys)) == 2
    assert all("buf=" in k for k in keys)
