"""Unit tests for the §Perf attention paths: blocked sliding-window,
one-shot global, and delta-cache decode — each against a dense oracle.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as A

B, H, KV, HD = 2, 4, 2, 16


def _qkv(s, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, s, H, HD)) * 0.5
    k = jax.random.normal(ks[1], (B, s, KV, HD)) * 0.5
    v = jax.random.normal(ks[2], (B, s, KV, HD)) * 0.5
    return q, k, v


def _dense_window_oracle(q, k, v, window, scale):
    b, s, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, s, kv, g, hd)
    sc = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32) * scale
    pos = jnp.arange(s)
    mask = (pos[None, :] <= pos[:, None]) & (pos[None, :] > pos[:, None] -
                                             window)
    sc = jnp.where(mask[None, None, None], sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p.astype(v.dtype), v)
    return o.reshape(b, s, h, hd)


@pytest.mark.parametrize("s,window,block", [
    (64, 16, 8), (64, 16, 16), (128, 32, 16), (96, 24, 8),
    (64, 8, 32),      # block > window
])
def test_blocked_window_matches_dense(s, window, block):
    q, k, v = _qkv(s)
    scale = HD ** -0.5
    out = A.window_attention(q, k, v, window=window, scale=scale,
                             q_chunk=block)
    ref = _dense_window_oracle(q, k, v, window, scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_blocked_window_edge_first_block():
    """First block's left extent is clipped: positions < window."""
    q, k, v = _qkv(32, seed=3)
    out = A.window_attention(q, k, v, window=8, scale=0.25, q_chunk=8)
    ref = _dense_window_oracle(q, k, v, 8, 0.25)
    np.testing.assert_allclose(np.asarray(out[:, :8]),
                               np.asarray(ref[:, :8]), atol=2e-5)


@pytest.mark.parametrize("ring", [False, True])
def test_delta_decode_matches_full_decode(ring):
    """decode_attention_delta(old_cache, k_new) == decode_attention over
    the cache with the token written in."""
    s_buf = 16
    pos = s_buf - 1 if not ring else s_buf + 5   # ring: wrapped past end
    window = s_buf if ring else 0
    q1 = jax.random.normal(jax.random.PRNGKey(0), (B, 1, H, HD)) * 0.5
    ck = jax.random.normal(jax.random.PRNGKey(1), (B, s_buf, KV, HD)) * 0.5
    cv = jax.random.normal(jax.random.PRNGKey(2), (B, s_buf, KV, HD)) * 0.5
    kn = jax.random.normal(jax.random.PRNGKey(3), (B, 1, KV, HD)) * 0.5
    vn = jax.random.normal(jax.random.PRNGKey(4), (B, 1, KV, HD)) * 0.5
    wp = jnp.int32(pos)

    out_delta = A.decode_attention_delta(
        q1, ck, cv, kn, vn, write_pos=wp, scale=0.25, ring=ring,
        window=window if ring else 0)

    slot = pos % s_buf if ring else min(pos, s_buf - 1)
    ck2 = ck.at[:, slot].set(kn[:, 0])
    cv2 = cv.at[:, slot].set(vn[:, 0])
    out_full = A.decode_attention(q1, ck2, cv2, write_pos=wp, scale=0.25,
                                  ring=ring, window=0)
    np.testing.assert_allclose(np.asarray(out_delta),
                               np.asarray(out_full), atol=2e-5)


def test_one_shot_matches_chunked_causal():
    """The S<=8192 one-shot train path equals chunked causal attention."""
    s = 64
    q, k, v = _qkv(s, seed=7)
    pos = jnp.arange(s, dtype=jnp.int32)
    chunked = A.causal_attention(q, k, v, q_positions=pos, k_positions=pos,
                                 scale=0.25, q_chunk=16)
    kv = k.shape[2]
    mask = pos[None, :] <= pos[:, None]
    mask = jnp.broadcast_to(mask[None], (B, s, s))
    one = A._merge_heads(A._gqa_attend(
        A._split_heads(q, kv), k, v, mask, 0.25, 0.0))
    np.testing.assert_allclose(np.asarray(one), np.asarray(chunked),
                               atol=2e-5)


def test_movement_bytes_split():
    """copy/convert-only fusions land in movement_bytes, not bytes."""
    from repro.analysis import hlo
    text = """
%conv_only (p0: bf16[64,64]) -> f32[64,64] {
  %p0 = bf16[64,64]{1,0} parameter(0)
  ROOT %cv = f32[64,64]{1,0} convert(%p0)
}

%real (p1: f32[64,64], p2: f32[64,64]) -> f32[64,64] {
  %p1 = f32[64,64]{1,0} parameter(0)
  %p2 = f32[64,64]{1,0} parameter(1)
  ROOT %m = f32[64,64]{1,0} multiply(%p1, %p2)
}

ENTRY %e (x: bf16[64,64]) -> f32[64,64] {
  %x = bf16[64,64]{1,0} parameter(0)
  %f1 = f32[64,64]{1,0} fusion(%x), kind=kLoop, calls=%conv_only
  ROOT %f2 = f32[64,64]{1,0} fusion(%f1, %f1), kind=kLoop, calls=%real
}
"""
    cost = hlo.analyze(text)
    conv_bytes = 64 * 64 * 2 + 64 * 64 * 4
    assert cost.movement_bytes == conv_bytes
    assert cost.bytes == 3 * 64 * 64 * 4          # two reads + one write


def test_profiler_smoke():
    from repro.analysis import profile as prof

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=5)
        return y

    c = jax.jit(f).lower(jax.ShapeDtypeStruct((8, 32), jnp.float32),
                         jax.ShapeDtypeStruct((32, 32), jnp.float32)
                         ).compile()
    p = prof.profile(c.as_text())
    assert p["total_flops"] == 5 * 2 * 8 * 32 * 32
    assert prof.render(p)
