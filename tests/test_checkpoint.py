"""Checkpointing + fault tolerance: atomicity, async, GC, restore,
resilient-loop recovery, elastic re-mesh, straggler monitor."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import MANIFEST, CheckpointManager
from repro.runtime.fault_tolerance import (InjectedFault, ResilientLoop,
                                           StragglerMonitor, elastic_remesh)


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 8)),
                       "b": jnp.zeros((8,))},
            "step": jnp.int32(7)}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    s = _state()
    mgr.save(s, 10)
    assert mgr.steps() == [10]
    struct = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), s)
    r = mgr.restore(struct)
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_rejects_shape_mismatch(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(_state(), 1)
    bad = {"params": {"w": jax.ShapeDtypeStruct((4, 4), jnp.float32),
                      "b": jax.ShapeDtypeStruct((8,), jnp.float32)},
           "step": jax.ShapeDtypeStruct((), jnp.int32)}
    with pytest.raises(ValueError):
        mgr.restore(bad)


def test_atomicity_tmp_never_visible(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    mgr.save(_state(), 1)
    # a stale .tmp (killed job) must not be listed or restored
    stale = os.path.join(str(tmp_path), "step_00000002.tmp")
    os.makedirs(stale)
    with open(os.path.join(stale, "x.npy"), "w") as f:
        f.write("junk")
    assert mgr.steps() == [1]
    assert mgr.latest_step() == 1
    # a directory without manifest (partial rename impossible, but guard)
    partial = os.path.join(str(tmp_path), "step_00000003")
    os.makedirs(partial)
    assert mgr.steps() == [1]


def test_gc_keeps_newest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(_state(s), s)
    assert mgr.steps() == [3, 4]


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save_async(_state(), 5)
    mgr.wait()
    assert mgr.steps() == [5]
    with open(os.path.join(str(tmp_path), "step_00000005",
                           MANIFEST)) as f:
        man = json.load(f)
    assert man["step"] == 5 and "params/w" in man["leaves"]


def test_resilient_loop_recovers(tmp_path):
    """Fault at step 7 -> restore from checkpoint at 5 -> complete."""
    calls = {"n": 0}

    def step_fn(state, batch):
        calls["n"] += 1
        return {"x": state["x"] + batch}, {"loss": float(state["x"][0])}

    def batch_fn(step):
        return jnp.full((2,), float(step))

    fired = {"done": False}

    def fault(step):
        if step == 7 and not fired["done"]:
            fired["done"] = True
            raise InjectedFault("runtime_error", "test-chaos")

    mgr = CheckpointManager(str(tmp_path))
    loop = ResilientLoop(step_fn, batch_fn, mgr, checkpoint_every=5,
                         fault_hook=fault, async_checkpoint=False)
    res = loop.run({"x": jnp.zeros((2,))}, 10)
    assert res.final_step == 10
    assert res.restarts == 1
    # deterministic replay: x = sum of 0..9 regardless of the restart
    final = mgr.restore({"x": jax.ShapeDtypeStruct((2,), jnp.float32)}, 10)
    np.testing.assert_allclose(np.asarray(final["x"]),
                               np.full(2, sum(range(10))))


def test_resilient_loop_gives_up(tmp_path):
    def step_fn(state, batch):
        return state, {}

    def fault(step):
        raise InjectedFault("runtime_error", "test-always")

    mgr = CheckpointManager(str(tmp_path))
    loop = ResilientLoop(step_fn, lambda s: None, mgr, max_restarts=2,
                         fault_hook=fault, async_checkpoint=False)
    with pytest.raises(InjectedFault):
        loop.run({"x": jnp.zeros(1)}, 5)


def test_resilient_loop_shared_fault_seam(tmp_path):
    """faults="runtime=1.0,..." goes through the SAME FaultSpec machinery
    as the sweep dispatcher: deterministic injection at every step until
    max_restarts is exhausted, counted in LoopResult.faults_injected."""
    def step_fn(state, batch):
        return state, {}

    mgr = CheckpointManager(str(tmp_path))
    loop = ResilientLoop(step_fn, lambda s: None, mgr, max_restarts=2,
                         faults="runtime=1.0,seed=3",
                         async_checkpoint=False)
    with pytest.raises(InjectedFault) as ei:
        loop.run({"x": jnp.zeros(1)}, 5)
    assert ei.value.kind == "runtime_error"
    assert "train-step-0" in str(ei.value)


def test_resilient_loop_fault_env_resolution(tmp_path, monkeypatch):
    """faults=None resolves REPRO_FAULT_SPEC — one env var for the whole
    repo.  An injected fault recovers exactly like a hook-raised one
    because it IS the same exception type."""
    monkeypatch.setenv("REPRO_FAULT_SPEC", "runtime=1.0,seed=3")

    def step_fn(state, batch):
        return state, {}

    mgr = CheckpointManager(str(tmp_path))
    loop = ResilientLoop(step_fn, lambda s: None, mgr, max_restarts=1,
                         faults=None, async_checkpoint=False)
    with pytest.raises(InjectedFault):
        loop.run({"x": jnp.zeros(1)}, 5)
    # pinned off -> env ignored, loop completes cleanly
    loop_off = ResilientLoop(step_fn, lambda s: None, mgr,
                             faults=False, async_checkpoint=False)
    res = loop_off.run({"x": jnp.zeros(1)}, 5)
    assert res.final_step == 5 and res.faults_injected == 0


def test_straggler_monitor():
    mon = StragglerMonitor(threshold=3.0)
    for i in range(8):
        assert mon.record(i, 0.1) is None
    ev = mon.record(8, 1.0)                 # 10x the median
    assert ev is not None and ev.step == 8
    assert len(mon.events) == 1


def test_elastic_remesh_single_device():
    s = {"w": jnp.arange(16.0).reshape(4, 4)}
    sh = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    out = elastic_remesh(s, {"w": sh})
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(s["w"]))
