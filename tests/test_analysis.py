"""HLO cost parser + roofline math, validated against live-compiled
programs with analytically known costs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import hlo, roofline
from repro.configs.base import SHAPES, get_config


def _compiled(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_dot_flops_exact():
    c = _compiled(lambda a, b: a @ b,
                  jax.ShapeDtypeStruct((64, 128), jnp.float32),
                  jax.ShapeDtypeStruct((128, 32), jnp.float32))
    cost = hlo.analyze(c.as_text())
    assert cost.flops == 2 * 64 * 128 * 32


def test_scan_trip_multiplier():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=11)
        return y

    c = _compiled(f, jax.ShapeDtypeStruct((8, 64), jnp.float32),
                  jax.ShapeDtypeStruct((64, 64), jnp.float32))
    cost = hlo.analyze(c.as_text())
    assert cost.flops == 11 * 2 * 8 * 64 * 64
    assert list(cost.while_trips.values()) == [11]
    assert not cost.unknown_trip_whiles


def test_nested_scan_trips_multiply():
    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    c = _compiled(f, jax.ShapeDtypeStruct((4, 32), jnp.float32),
                  jax.ShapeDtypeStruct((32, 32), jnp.float32))
    cost = hlo.analyze(c.as_text())
    assert cost.flops == 15 * 2 * 4 * 32 * 32


def test_stacked_param_scan_bytes_not_inflated():
    """Reading one (64,64) layer slice per trip must cost ~1 slice, not
    the whole (24,64,64) stack per trip."""
    def f(x, stack):
        def body(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, stack)
        return y

    c = _compiled(f, jax.ShapeDtypeStruct((8, 64), jnp.float32),
                  jax.ShapeDtypeStruct((24, 64, 64), jnp.float32))
    cost = hlo.analyze(c.as_text())
    stack_bytes = 24 * 64 * 64 * 4
    # generous bound: well under trips x stack (24x overcount would be 9.4MB)
    assert cost.bytes < 6 * stack_bytes, cost.bytes


def test_batch_dot_flops():
    c = _compiled(lambda a, b: jnp.einsum("bij,bjk->bik", a, b),
                  jax.ShapeDtypeStruct((4, 16, 32), jnp.float32),
                  jax.ShapeDtypeStruct((4, 32, 8), jnp.float32))
    cost = hlo.analyze(c.as_text())
    assert cost.flops == 2 * 4 * 16 * 32 * 8


def test_shape_parse_tuple():
    shapes = hlo.parse_shape("(s32[], f32[8,4]{1,0}, pred[], bf16[2,2])")
    assert ("f32", (8, 4)) in shapes and ("bf16", (2, 2)) in shapes
    assert hlo.shape_bytes("(f32[8,4], bf16[2,2])") == 8 * 4 * 4 + 2 * 2 * 2


def test_collective_parse_synthetic():
    text = """
ENTRY %e (p: f32[16,16]) -> f32[16,16] {
  %p = f32[16,16]{1,0} parameter(0)
  %ar = f32[16,16]{1,0} all-reduce(%p), channel_id=1, replica_groups=[2,4]<=[8], to_apply=%add
  ROOT %cp = f32[16,16]{1,0} collective-permute(%ar), source_target_pairs={{0,1},{1,0}}
}
"""
    cost = hlo.analyze(text)
    assert cost.collective_bytes == 2 * 16 * 16 * 4
    kinds = cost.collective_summary()
    assert kinds["all-reduce"] == 16 * 16 * 4
    assert kinds["collective-permute"] == 16 * 16 * 4
    assert cost.collectives[0].group_size == 4


# ---------------------------------------------------------------------------
# Roofline math
# ---------------------------------------------------------------------------


def test_roofline_terms_and_bottleneck():
    cfg = get_config("qwen2-1.5b")
    shape = SHAPES["train_4k"]
    cost = hlo.HloCost(flops=1e15, bytes=1e12, collective_bytes=1e10)
    t = roofline.compute_terms(cost, cfg=cfg, shape=shape,
                               mesh_desc="test", n_devices=256)
    assert t.t_compute == pytest.approx(1e15 / roofline.PEAK_FLOPS)
    assert t.t_memory == pytest.approx(1e12 / roofline.HBM_BW)
    assert t.t_collective == pytest.approx(
        1e10 / (roofline.ICI_BW * roofline.N_ICI_LINKS))
    assert t.bottleneck == "compute"
    assert t.t_bound == t.t_compute
    assert 0 < t.roofline_fraction <= 1.5


def test_model_flops_by_kind():
    cfg = get_config("qwen2-1.5b")
    n = cfg.n_active_params()
    assert roofline.model_flops(cfg, SHAPES["train_4k"]) == \
        6.0 * n * SHAPES["train_4k"].tokens
    assert roofline.model_flops(cfg, SHAPES["prefill_32k"]) == \
        2.0 * n * SHAPES["prefill_32k"].tokens
    assert roofline.model_flops(cfg, SHAPES["decode_32k"]) == \
        2.0 * n * SHAPES["decode_32k"].global_batch


def test_moe_active_flops_smaller():
    moe = get_config("phi3.5-moe-42b-a6.6b")
    assert roofline.model_flops(moe, SHAPES["train_4k"]) < \
        6.0 * moe.n_params() * SHAPES["train_4k"].tokens * 0.5


def test_terms_save_load(tmp_path):
    cfg = get_config("qwen2-1.5b")
    t = roofline.compute_terms(
        hlo.HloCost(flops=1e12, bytes=1e11, collective_bytes=1e9),
        cfg=cfg, shape=SHAPES["train_4k"], mesh_desc="m", n_devices=4)
    p = str(tmp_path / "t.json")
    roofline.save_terms(t, p)
    d = roofline.load_terms(p)
    assert d["bottleneck"] == t.bottleneck
    table = roofline.table([d])
    assert "qwen2-1.5b" in table
