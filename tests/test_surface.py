"""CurveDB v3 surfaces: the typed coordinate system behind every
consumer (ISSUE 6).

Concrete-grid coverage for what tests/test_property.py checks
statistically (hypothesis is optional in CI): byte-idempotent v3
round-trips, v1/v2 forward-load to 1-axis surfaces, interpolation
exactness at grid points and bracketing between cells, extrapolation
flags, the placement/roofline/simulate/serve consumers, and the grep
lint that keeps key string-splitting out of every consumer.
"""
import dataclasses
import json
import logging
import os
import re

import pytest

from repro.core.characterize import (AXIS_IR, AXIS_N, AXIS_RW, CurveDB,
                                     CurvePoint, Surface, SurfaceAxis,
                                     SurfaceCoord, SurfaceKey,
                                     characterize, characterize_surface)
from repro.core.coordinator import CoreCoordinator
from repro.core.placement import (ContentionSpec, MemObject,
                                  PlacementAdvisor)
from repro.core.scenarios import TrafficShape

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

RWS = (0.0, 0.5, 1.0)
IRS = (0.25, 0.5, 1.0)


@pytest.fixture(scope="module")
def coord():
    return CoreCoordinator(backend="simulate")


@pytest.fixture(scope="module")
def surface_db(coord):
    """A measured 3-axis surface grid (simulate backend physics)."""
    return characterize_surface(coord, pools=["hbm", "host"],
                                stress_pools=["hbm"], rw_ratios=RWS,
                                inject_rates=IRS, iters=5)


@pytest.fixture(scope="module")
def legacy_db(coord):
    """A steady letter-keyed characterization (1-axis surfaces)."""
    return characterize(coord, pools=["hbm", "host"],
                        obs_strategies=("r", "l"),
                        stress_strategies=("r", "w"), iters=5)


# ---------------------------------------------------------------------------
# Axes, coordinates, keys
# ---------------------------------------------------------------------------


def test_surface_axis_locate_brackets_and_clamps():
    ax = SurfaceAxis("n_stressors", (0.0, 1.0, 4.0))
    lo, hi, t, cl = ax.locate(1.0)                  # exact grid point
    assert (lo, t, cl) == (1, 0.0, False)
    lo, hi, t, cl = ax.locate(2.5)
    assert (lo, hi, cl) == (1, 2, False) and t == pytest.approx(0.5)
    assert ax.locate(-1.0) == (0, 0, 0.0, True)     # clamped low
    assert ax.locate(9.0) == (2, 2, 0.0, True)      # clamped high
    assert ax.locate(0.0) == (0, 0, 0.0, False)     # edge is NOT a clamp
    with pytest.raises(ValueError):
        SurfaceAxis("bad", (1.0, 1.0))              # not strictly ascending


def test_surface_coord_drops_none():
    c = SurfaceCoord.of(n_stressors=2, rw_ratio=None, inject_rate=0.5)
    assert c.names() == ("n_stressors", "inject_rate")
    assert c.get("rw_ratio") is None
    assert c.to_dict() == {"n_stressors": 2.0, "inject_rate": 0.5}


@pytest.mark.parametrize("key", [
    "hbm:r|hbm:w",
    "hbm:l|host:y@rf0.50",
    "hbm:r|hbm:w@dc0.50",
    # non-canonical legacy spellings survive via the qualifier
    "hbm:r@st8|hbm:w",
    "hbm:r|hbm:w+host:r",
    "hbm:r|hbm:w|buf=1048576",
])
def test_surface_key_string_roundtrip(key):
    k = SurfaceKey.from_string(key)
    assert k.to_string() == key
    # the typed fields are populated even for qualified spellings
    assert k.obs_pool == "hbm" and k.stress_strat in ("w", "y")


def test_surface_key_is_typed_not_stringly():
    k = CurveDB.key("hbm", "r", "host", "y", "rf0.50")
    assert (k.obs_pool, k.obs_strat, k.stress_pool, k.stress_strat,
            k.tag) == ("hbm", "r", "host", "y", "rf0.50")
    assert k == SurfaceKey.from_string("hbm:r|host:y@rf0.50")


# ---------------------------------------------------------------------------
# Interpolation: exact at grid points, bracketed between cells
# ---------------------------------------------------------------------------


def _planar_surface():
    """bw = 100 - 10n + 20rw + 5ir (linear => multilinear interp is
    exact everywhere, not only at grid points)."""
    ns, rws, irs = (0.0, 1.0, 2.0, 4.0), RWS, IRS

    def bw(n, rw, ir):
        return 100.0 - 10.0 * n + 20.0 * rw + 5.0 * ir

    def lat(n, rw, ir):
        return 50.0 + 25.0 * n - 5.0 * rw - 2.0 * ir

    grid_bw = [[[bw(n, rw, ir) for ir in irs] for rw in rws] for n in ns]
    grid_lat = [[[lat(n, rw, ir) for ir in irs] for rw in rws] for n in ns]
    return Surface(axes=(SurfaceAxis(AXIS_N, ns), SurfaceAxis(AXIS_RW, rws),
                         SurfaceAxis(AXIS_IR, irs)),
                   bandwidth_gbps=grid_bw, latency_ns=grid_lat), bw, lat


def test_interpolation_exact_at_grid_points():
    surf, bw, lat = _planar_surface()
    for n in (0.0, 1.0, 2.0, 4.0):
        for rw in RWS:
            for ir in IRS:
                q = surf.query(SurfaceCoord.of(
                    n_stressors=n, rw_ratio=rw, inject_rate=ir))
                assert q.bandwidth_gbps == pytest.approx(bw(n, rw, ir))
                assert q.latency_ns == pytest.approx(lat(n, rw, ir))
                assert not q.extrapolated


def test_interpolation_exact_off_grid_for_planar_data():
    surf, bw, lat = _planar_surface()
    for n, rw, ir in [(0.5, 0.25, 0.75), (3.0, 0.9, 0.3), (1.7, 0.1, 1.0)]:
        q = surf.query(SurfaceCoord.of(
            n_stressors=n, rw_ratio=rw, inject_rate=ir))
        assert q.bandwidth_gbps == pytest.approx(bw(n, rw, ir))
        assert q.latency_ns == pytest.approx(lat(n, rw, ir))
        assert not q.extrapolated


def test_interpolation_bracketed_and_monotone(surface_db):
    """On measured (monotone-in-n) data, an off-grid query lies between
    its bracketing grid values."""
    surf = surface_db.surfaces[CurveDB.key("hbm", "r", "hbm", "b")]
    n_vals = surf.axis(AXIS_N).values
    for i in range(len(n_vals) - 1):
        a = surf.query(SurfaceCoord.of(
            n_stressors=n_vals[i], rw_ratio=1.0, inject_rate=1.0))
        b = surf.query(SurfaceCoord.of(
            n_stressors=n_vals[i + 1], rw_ratio=1.0, inject_rate=1.0))
        mid = surf.query(SurfaceCoord.of(
            n_stressors=(n_vals[i] + n_vals[i + 1]) / 2.0,
            rw_ratio=1.0, inject_rate=1.0))
        lo, hi = sorted((a.bandwidth_gbps, b.bandwidth_gbps))
        assert lo <= mid.bandwidth_gbps <= hi
        lo, hi = sorted((a.latency_ns, b.latency_ns))
        assert lo <= mid.latency_ns <= hi


def test_query_missing_axis_coordinate_raises():
    surf, _, _ = _planar_surface()
    with pytest.raises(ValueError, match="rw_ratio"):
        surf.query(SurfaceCoord.of(n_stressors=1.0, inject_rate=1.0))


# ---------------------------------------------------------------------------
# Extrapolation flags (the silent-clamp fix)
# ---------------------------------------------------------------------------


def test_out_of_grid_query_flags_extrapolated(surface_db):
    q_in = surface_db.query("hbm", 1, stress_strat="b")
    assert not q_in.extrapolated
    q_out = surface_db.query("hbm", 99, stress_strat="b")
    assert q_out.extrapolated
    # the clamp still answers with the edge value (monotone ladder:
    # the worst characterized point), it just says so
    n_max = surface_db.surfaces[
        CurveDB.key("hbm", "r", "hbm", "b")].axis(AXIS_N).values[-1]
    assert q_out.bandwidth_gbps == pytest.approx(
        surface_db.query("hbm", n_max, stress_strat="b").bandwidth_gbps)


def test_requested_axis_missing_on_legacy_surface_flags(legacy_db):
    # no explicit coordinates: a legacy 1-axis lookup is NOT extrapolated
    assert not legacy_db.query("hbm", 1).extrapolated
    # an explicitly-requested mix coordinate cannot be honoured by a
    # 1-axis curve: flagged instead of silently dropped
    assert legacy_db.query("hbm", 1, rw_ratio=0.8).extrapolated
    assert legacy_db.query("hbm", 1, inject_rate=0.5).extrapolated


def test_letter_strategies_map_to_surface_edges(surface_db):
    """One measured mixed surface answers legacy letter-keyed queries:
    'w' stressors are the rw=0 edge, 'r' the rw=1 edge."""
    bw_w = surface_db.effective_bw("hbm", 2, stress_strat="w")
    bw_r = surface_db.effective_bw("hbm", 2, stress_strat="r")
    edge_w = surface_db.query("hbm", 2, stress_strat="b",
                              rw_ratio=0.0).bandwidth_gbps
    edge_r = surface_db.query("hbm", 2, stress_strat="b",
                              rw_ratio=1.0).bandwidth_gbps
    assert bw_w == pytest.approx(edge_w)
    assert bw_r == pytest.approx(edge_r)
    # WAWB: write-heavy stressors cost more module traffic
    assert bw_w < bw_r
    # off-edge mixes interpolate strictly between the edges
    mid = surface_db.effective_bw("hbm", 2, stress_strat="b",
                                  rw_ratio=0.75)
    assert min(edge_w, edge_r) < mid < max(edge_w, edge_r)


# ---------------------------------------------------------------------------
# Persistence: v3 round-trips, v1/v2 forward-load, v2 downgrade
# ---------------------------------------------------------------------------


def test_v3_save_load_save_idempotent(surface_db, tmp_path):
    p1, p2 = str(tmp_path / "a.json"), str(tmp_path / "b.json")
    surface_db.save(p1)
    db2 = CurveDB.load(p1)
    db2.save(p2)
    t1, t2 = open(p1).read(), open(p2).read()
    assert t1 == t2
    assert json.loads(t1)["schema"] == 3
    # and the loaded surfaces answer identically
    assert db2.query("hbm", 1.5, stress_strat="b", rw_ratio=0.3,
                     inject_rate=0.7).bandwidth_gbps == pytest.approx(
        surface_db.query("hbm", 1.5, stress_strat="b", rw_ratio=0.3,
                         inject_rate=0.7).bandwidth_gbps)


def test_v2_downgrade_save_loads_with_same_answers(surface_db, tmp_path):
    p = str(tmp_path / "v2.json")
    surface_db.save(p, schema=2)
    doc = json.load(open(p))
    assert doc["schema"] == 2
    old = CurveDB.load(p)
    assert old.schema == 2
    # every grid point survives the slicing losslessly
    for rw in RWS:
        for ir in IRS:
            tag = TrafficShape.traffic(rw, ir).tag()
            want = surface_db.query("hbm", 2, stress_strat="b",
                                    rw_ratio=rw,
                                    inject_rate=ir).bandwidth_gbps
            got = old.effective_bw("hbm", 2, stress_strat="b",
                                   shape_tag=tag)
            assert got == pytest.approx(want)


def test_v1_forward_loads_to_1axis_surfaces(tmp_path):
    v1 = {"platform": "tpu-v5e",
          "curves": {"hbm:r|hbm:w": [
              {"n_stressors": 0, "bandwidth_gbps": 800.0,
               "latency_ns": 100.0},
              {"n_stressors": 2, "bandwidth_gbps": 400.0,
               "latency_ns": 200.0}]}}
    p = str(tmp_path / "v1.json")
    json.dump(v1, open(p, "w"))
    db = CurveDB.load(p)
    assert db.schema == 1
    surf = db.surfaces[SurfaceKey.from_string("hbm:r|hbm:w")]
    assert [ax.name for ax in surf.axes] == [AXIS_N]
    # interpolates BETWEEN ladder rungs now (the seed indexed/clamped)
    assert db.effective_bw("hbm", 1) == pytest.approx(600.0)
    # beyond the ladder: clamped AND flagged
    q = db.query("hbm", 5)
    assert q.bandwidth_gbps == 400.0 and q.extrapolated


def test_v2_forward_loads_with_provenance(tmp_path):
    v2 = {"schema": 2, "platform": "sim",
          "curves": {"hbm:r|hbm:w@rf0.50": [
              {"n_stressors": 0, "bandwidth_gbps": 100.0,
               "latency_ns": 10.0}]},
          "provenance": {"hbm:r|hbm:w@rf0.50": {"name": "x"}},
          "meta": {}}
    p = str(tmp_path / "v2.json")
    json.dump(v2, open(p, "w"))
    db = CurveDB.load(p)
    assert db.schema == 2
    k = CurveDB.key("hbm", "r", "hbm", "w", "rf0.50")
    assert len(db.surfaces[k].axes) == 1
    assert db.surfaces[k].provenance == {"name": "x"}
    assert db.effective_bw("hbm", 0, stress_strat="w",
                           shape_tag="rf0.50") == 100.0


# ---------------------------------------------------------------------------
# Consumers: placement, roofline, simulate, serve
# ---------------------------------------------------------------------------


def test_contention_spec_carries_surface_coords():
    spec = ContentionSpec.shaped(
        3, "hbm", "b", TrafficShape(kind="mixed", read_fraction=0.75,
                                    duty_cycle=0.5))
    assert spec.rw_ratio == 0.75 and spec.inject_rate == 0.5
    assert spec.stress_shape_tag == "rf0.75dc0.50"
    steady = ContentionSpec.shaped(3, "hbm", "w", TrafficShape.steady())
    assert steady.rw_ratio is None and steady.inject_rate is None


def test_placement_interpolates_surface_coords(surface_db, coord):
    adv = PlacementAdvisor(surface_db, coord.platform)
    obj = MemObject("buf", 1 << 20, bytes_per_step=1e9)
    t_read = adv.predict_ns(obj, "hbm",
                            ContentionSpec(2, "hbm", "b", rw_ratio=1.0))
    t_write = adv.predict_ns(obj, "hbm",
                             ContentionSpec(2, "hbm", "b", rw_ratio=0.0))
    t_mid = adv.predict_ns(obj, "hbm",
                           ContentionSpec(2, "hbm", "b", rw_ratio=0.4))
    assert min(t_read, t_write) < t_mid < max(t_read, t_write)


def test_placement_records_and_warns_on_extrapolation(
        surface_db, coord, caplog):
    adv = PlacementAdvisor(surface_db, coord.platform, pools=["hbm"])
    obj = MemObject("buf", 1 << 20, bytes_per_step=1e9)
    with caplog.at_level(logging.WARNING, "repro.core.placement"):
        plan = adv.advise([obj], ContentionSpec(99, "hbm", "b"))
    assert plan.decisions["buf"].extrapolated
    assert any("EXTRAPOLATED" in r.message for r in caplog.records)
    caplog.clear()
    with caplog.at_level(logging.WARNING, "repro.core.placement"):
        plan = adv.advise([obj], ContentionSpec(1, "hbm", "b"))
    assert not plan.decisions["buf"].extrapolated
    assert not caplog.records


def test_advise_raises_clearly_when_no_candidate_pools(
        surface_db, coord):
    """Regression: disjoint advisor/capacity pools used to surface as an
    opaque IndexError out of the regret sort."""
    adv = PlacementAdvisor(surface_db, coord.platform, pools=["hbm"])
    obj = MemObject("buf", 1 << 20, bytes_per_step=1e9)
    with pytest.raises(RuntimeError, match="no candidate pools"):
        adv.advise([obj], ContentionSpec(0, "hbm", "b"),
                   capacities={"host": 1 << 30})
    # pinned objects bypass the cost table and still place
    pinned = MemObject("pin", 1 << 20, bytes_per_step=1e9,
                       pinned_pool="host")
    plan = adv.advise([pinned], ContentionSpec(0, "hbm", "b"),
                      capacities={"host": 1 << 30})
    assert plan.pool_of("pin") == "host"


def test_roofline_memory_term_at_workload_mix(surface_db):
    from repro.analysis.roofline import effective_hbm_bw, workload_rw_mix

    class _Shape:
        kind = "decode"
    mix = workload_rw_mix(_Shape())
    assert mix == pytest.approx(0.9)
    bw_mix = effective_hbm_bw(surface_db, n_stressors=2,
                              stress_strategy="b", rw_ratio=mix)
    bw_w = effective_hbm_bw(surface_db, n_stressors=2,
                            stress_strategy="b", rw_ratio=0.0)
    bw_r = effective_hbm_bw(surface_db, n_stressors=2,
                            stress_strategy="b", rw_ratio=1.0)
    assert bw_w < bw_mix <= bw_r


def test_simulate_calibrates_to_surface_edge(surface_db, coord):
    """The surface-calibrated mode: a deliberately mis-specified
    platform re-fit to the measured surface reproduces the executed
    uncontended edge (fidelity against executed points)."""
    from repro.core.simulate import calibrate_to_surface

    plat = coord.platform
    mems = dict(plat.memories)
    for p in ("hbm", "host"):
        n = mems[p]
        mems[p] = dataclasses.replace(
            n, peak_bw_gbps=n.peak_bw_gbps * 1.8,
            base_latency_ns=n.base_latency_ns * 0.5)
    wrong = dataclasses.replace(plat, memories=mems)
    cal = calibrate_to_surface(wrong, surface_db)
    for pool in ("hbm", "host"):
        # the fit must land on the measured edge...
        assert cal.residual_bw[pool] < 0.01
        assert cal.residual_lat[pool] < 0.01
        # ...by pulling both knobs back toward the truth (the exact
        # factors are coupled through the queueing model: latency
        # feeds the single-reader bandwidth edge)
        assert 0.4 < cal.scale_bw[pool] < 0.7
        assert 1.4 < cal.scale_lat[pool] < 2.5
    # the true platform is (near) a fixed point
    cal0 = calibrate_to_surface(plat, surface_db)
    assert cal0.scale_bw["hbm"] == pytest.approx(1.0, rel=0.02)
    assert cal0.scale_lat["hbm"] == pytest.approx(1.0, rel=0.02)


def test_serve_decode_mix_is_read_dominated():
    from repro.serve.engine import decode_rw_mix
    assert decode_rw_mix(4, 64) == pytest.approx(64 / 65)
    assert decode_rw_mix(1, 1) == pytest.approx(0.5)
    # longer contexts -> more read-dominated
    assert decode_rw_mix(4, 2048) > decode_rw_mix(4, 64) > 0.9


# ---------------------------------------------------------------------------
# Edge-boundary bugfixes: on-edge queries, qualified keys, calibration
# ---------------------------------------------------------------------------


def test_surface_axis_edge_and_float_noise_not_clamped():
    """A coordinate on (or within float noise of) a grid edge is
    in-range; truly out-of-range values still flag."""
    ax = SurfaceAxis("rw_ratio", (0.0, 0.5, 1.0))
    assert ax.locate(1.0) == (2, 2, 0.0, False)
    assert ax.locate(0.0) == (0, 0, 0.0, False)
    # float-noise landing just past the edge (0.1 * 3 > 0.3)
    noisy = SurfaceAxis("rw_ratio", (0.0, 0.1 * 3))
    assert 0.1 * 3 > 0.3
    assert noisy.locate(0.3)[3] is False
    assert SurfaceAxis("x", (0.0, 0.3)).locate(0.1 * 3)[3] is False
    # single-point axes: the one value is the whole in-range set
    single = SurfaceAxis("inject_rate", (1.0,))
    assert single.locate(1.0) == (0, 0, 0.0, False)
    assert single.locate(1.0 + 1e-12)[3] is False
    assert single.locate(2.0)[3] is True
    assert single.locate(0.5)[3] is True
    # genuinely out of range still flags
    assert ax.locate(1.001)[3] is True
    assert ax.locate(-0.001)[3] is True


def test_query_on_axis_edges_not_extrapolated(surface_db):
    """rw_ratio=1.0 / inject_rate=1.0 on grids ending at 1.0, and the
    last characterized stressor count, are measurements — not
    extrapolations."""
    pts = surface_db.get("hbm", "r", "hbm", "b", "rf0.50")
    n_max = pts[-1].n_stressors
    q = surface_db.query("hbm", n_max, stress_strat="b", rw_ratio=1.0,
                         inject_rate=1.0)
    assert not q.extrapolated
    q = surface_db.query("hbm", 0, stress_strat="b", rw_ratio=0.0,
                         inject_rate=IRS[0])
    assert not q.extrapolated
    assert surface_db.query("hbm", n_max + 1, stress_strat="b").extrapolated


@pytest.mark.parametrize("key", [
    "hbm:r|hbm:b#worstcase",
    "hbm:l|host:b@rf0.50#worstcase",
])
def test_surface_key_structured_qualifier_roundtrip(key):
    k = SurfaceKey.from_string(key)
    assert k.qualifier == "worstcase"
    assert k.to_string() == key
    # distinct from its unqualified sibling
    assert k != SurfaceKey(k.obs_pool, k.obs_strat, k.stress_pool,
                           k.stress_strat, tag=k.tag)


def test_curvedb_prefers_qualified_surface_and_flags_fallback():
    db = CurveDB(platform="test")
    mean = Surface(axes=(SurfaceAxis(AXIS_N, (0.0, 2.0)),),
                   bandwidth_gbps=[100.0, 60.0], latency_ns=[100.0, 200.0])
    env = Surface(axes=(SurfaceAxis(AXIS_N, (0.0, 2.0)),),
                  bandwidth_gbps=[90.0, 10.0], latency_ns=[120.0, 900.0])
    db.surfaces[SurfaceKey("hbm", "r", "hbm", "b")] = mean
    db.surfaces[SurfaceKey("hbm", "r", "hbm", "b",
                           qualifier="worstcase")] = env
    q = db.query("hbm", 2, stress_strat="w", qualifier="worstcase")
    assert q.bandwidth_gbps == 10.0 and not q.extrapolated
    assert db.query("hbm", 2, stress_strat="w").bandwidth_gbps == 60.0
    # qualifier requested but only the mean exists: answer from the
    # mean, honestly flagged
    q = db.query("hbm", 2, obs_strat="r", stress_pool="hbm",
                 stress_strat="w", qualifier="nosuch")
    assert q.bandwidth_gbps == 60.0 and q.extrapolated
    # a save/load round-trip keeps the qualified key distinct
    assert SurfaceKey.from_string(
        db.surfaces and "hbm:r|hbm:b#worstcase").qualifier == "worstcase"


def _edge_db():
    """Two stressor pairings for hbm: the alphabetically-first one has
    no n=0 point (extrapolates at the edge), the second measures it."""
    db = CurveDB(platform="test")
    for ostrat, clipped, full in (("r", [50.0, 40.0], [100.0, 70.0]),
                                  ("l", [500.0, 600.0], [200.0, 350.0])):
        db.surfaces[SurfaceKey("hbm", ostrat, "aaa", "w")] = Surface(
            axes=(SurfaceAxis(AXIS_N, (1.0, 2.0)),),
            bandwidth_gbps=clipped, latency_ns=clipped)
        db.surfaces[SurfaceKey("hbm", ostrat, "hbm", "w")] = Surface(
            axes=(SurfaceAxis(AXIS_N, (0.0, 2.0)),),
            bandwidth_gbps=full, latency_ns=full)
    return db


def test_calibrate_edge_prefers_non_extrapolated_pairing(coord):
    """The regression: ``edge()`` used to return the FIRST pairing even
    when its n=0 query was clamped off-grid — the fit then anchored on
    an extrapolated edge."""
    from repro.core.simulate import _modeled_edge, calibrate_to_surface

    cal = calibrate_to_surface(coord.platform, _edge_db(), pools=["hbm"])
    bw, lat = _modeled_edge(cal.platform, "hbm")
    # fit landed on the measured (non-extrapolated) pairing's edge
    assert bw == pytest.approx(100.0, rel=0.05)
    assert lat == pytest.approx(200.0, rel=0.05)


def test_calibrate_warns_and_skips_uncovered_pools(coord, caplog):
    from repro.core.simulate import calibrate_to_surface

    db = CurveDB(platform="test")
    db.surfaces[SurfaceKey("hbm", "r", "hbm", "w")] = Surface(
        axes=(SurfaceAxis(AXIS_N, (0.0, 2.0)),),
        bandwidth_gbps=[100.0, 70.0], latency_ns=[0.0, 0.0])
    with caplog.at_level(logging.WARNING, "repro.core.simulate"):
        cal = calibrate_to_surface(coord.platform, db,
                                   pools=["hbm", "host"])
    # hbm has no latency probe, host nothing at all: both skipped LOUDLY
    assert not cal.scale_bw
    msgs = [r.message for r in caplog.records]
    assert any("skipping pool 'hbm'" in m for m in msgs)
    assert any("skipping pool 'host'" in m
               and "at all" in m for m in msgs)


def test_calibrate_resolves_tagged_only_pairings(coord):
    """A pool characterized only under a shape tag used to KeyError out
    of the fit (the steady-key ladder missed it); the tagged pairing
    now resolves."""
    from repro.core.simulate import calibrate_to_surface

    db = CurveDB(platform="test")
    for ostrat, vals in (("r", [80.0, 50.0]), ("l", [250.0, 400.0])):
        db.surfaces[SurfaceKey("hbm", ostrat, "hbm", "w",
                               tag="st8")] = Surface(
            axes=(SurfaceAxis(AXIS_N, (0.0, 2.0)),),
            bandwidth_gbps=vals, latency_ns=vals)
    cal = calibrate_to_surface(coord.platform, db, pools=["hbm"])
    assert "hbm" in cal.scale_bw and cal.residual_bw["hbm"] < 0.05


def test_calibration_ignores_worstcase_envelopes(coord):
    """The fit anchors on the mean surface's edge even when a search
    envelope (same pool, qualified key) is installed."""
    from repro.core.simulate import _modeled_edge, calibrate_to_surface

    db = _edge_db()
    db.surfaces[SurfaceKey("hbm", "r", "hbm", "b",
                           qualifier="worstcase")] = Surface(
        axes=(SurfaceAxis(AXIS_N, (0.0, 2.0)),),
        bandwidth_gbps=[10.0, 5.0], latency_ns=[0.0, 0.0])
    cal = calibrate_to_surface(coord.platform, db, pools=["hbm"])
    bw, _lat = _modeled_edge(cal.platform, "hbm")
    assert bw == pytest.approx(100.0, rel=0.05)


# ---------------------------------------------------------------------------
# Pessimistic placement: advise against the worst-case envelope
# ---------------------------------------------------------------------------


def _pessimism_db():
    """Mean surfaces make hbm the obvious pick; the adversarial
    envelopes reveal hbm collapses under worst-case contention while
    host degrades gracefully."""
    db = CurveDB(platform="test")

    def surf(bw0, bw2, lat0, lat2):
        return Surface(axes=(SurfaceAxis(AXIS_N, (0.0, 2.0)),),
                       bandwidth_gbps=[bw0, bw2], latency_ns=[lat0, lat2])

    db.surfaces[SurfaceKey("hbm", "r", "hbm", "b")] = surf(
        100.0, 80.0, 0.0, 0.0)
    db.surfaces[SurfaceKey("hbm", "l", "hbm", "b")] = surf(
        0.0, 0.0, 100.0, 150.0)
    db.surfaces[SurfaceKey("host", "r", "hbm", "b")] = surf(
        60.0, 50.0, 0.0, 0.0)
    db.surfaces[SurfaceKey("host", "l", "hbm", "b")] = surf(
        200.0, 200.0, 250.0, 300.0)
    for pool, bw, lat in (("hbm", [90.0, 8.0], [110.0, 2000.0]),
                          ("host", [55.0, 40.0], [260.0, 400.0])):
        db.surfaces[SurfaceKey(pool, "r", "hbm", "b",
                               qualifier="worstcase")] = surf(
            bw[0], bw[1], 0.0, 0.0)
        db.surfaces[SurfaceKey(pool, "l", "hbm", "b",
                               qualifier="worstcase")] = surf(
            0.0, 0.0, lat[0], lat[1])
    return db


def test_pessimistic_placement_advises_against_envelope(coord):
    db = _pessimism_db()
    obj = MemObject("kv", 1 << 20, bytes_per_step=1e9)
    contention = ContentionSpec(2, "hbm", "w")
    mean_plan = PlacementAdvisor(db, coord.platform).advise(
        [obj], contention)
    worst_plan = PlacementAdvisor(db, coord.platform,
                                  pessimistic=True).advise(
        [obj], contention)
    assert mean_plan.pool_of("kv") == "hbm"
    assert worst_plan.pool_of("kv") == "host"
    assert not worst_plan.decisions["kv"].extrapolated
    # the pessimistic cost is the envelope's, not the mean's
    assert worst_plan.decisions["kv"].predicted_step_ns == \
        pytest.approx(1e9 / 40.0)


def test_pessimistic_placement_ignores_mix_coordinates(coord):
    """The envelope already maximized over the mix knobs: pessimistic
    queries must not flag (or fail on) rw/ir coordinates the 1-axis
    envelope does not carry."""
    adv = PlacementAdvisor(_pessimism_db(), coord.platform,
                           pessimistic=True)
    obj = MemObject("kv", 1 << 20, bytes_per_step=1e9)
    plan = adv.advise([obj], ContentionSpec(
        2, "hbm", "b", rw_ratio=0.25, inject_rate=0.5,
        stress_shape_tag="rf0.25dc0.50"))
    assert plan.pool_of("kv") == "host"
    assert not plan.decisions["kv"].extrapolated


def test_pessimistic_placement_flags_missing_envelope(coord, caplog):
    db = _pessimism_db()
    db.surfaces = {k: s for k, s in db.surfaces.items()
                   if k.qualifier != "worstcase"}
    adv = PlacementAdvisor(db, coord.platform, pessimistic=True)
    obj = MemObject("kv", 1 << 20, bytes_per_step=1e9)
    with caplog.at_level(logging.WARNING, "repro.core.placement"):
        plan = adv.advise([obj], ContentionSpec(2, "hbm", "w"))
    # falls back to the mean surface, honestly flagged + warned
    assert plan.decisions["kv"].extrapolated
    assert any("EXTRAPOLATED" in r.message for r in caplog.records)


# ---------------------------------------------------------------------------
# The lint: consumers never string-split keys
# ---------------------------------------------------------------------------

# .split/.partition on the legacy key separators, spelled with []
# concatenation so this file does not match itself
_FORBIDDEN = [
    r"\.spl" + r"it\(\s*['\"][|:@]['\"]",
    r"\.rspl" + r"it\(\s*['\"][|:@]['\"]",
    r"\.part" + r"ition\(\s*['\"][|:@]['\"]",
    r"\.rpart" + r"ition\(\s*['\"][|:@]['\"]",
]

_SCAN_DIRS = ("src", "tests", "benchmarks", "examples")
# SurfaceKey.from_string is the single allowed parsing boundary
_EXEMPT = (os.path.join("src", "repro", "core", "characterize.py"),
           os.path.join("tests", "test_surface.py"))


def _py_files():
    for d in _SCAN_DIRS:
        for root, _dirs, files in os.walk(os.path.join(ROOT, d)):
            for f in files:
                if f.endswith(".py"):
                    yield os.path.join(root, f)


def test_no_consumer_string_splits_curve_keys():
    pats = [re.compile(p) for p in _FORBIDDEN]
    offenders = []
    for path in _py_files():
        rel = os.path.relpath(path, ROOT)
        if rel in _EXEMPT:
            continue
        with open(path, encoding="utf-8") as f:
            for lineno, line in enumerate(f, 1):
                for pat in pats:
                    if pat.search(line):
                        offenders.append(f"{rel}:{lineno}: {line.strip()}")
    assert not offenders, (
        "curve-key string-splitting outside SurfaceKey.from_string "
        "(query through the typed coordinate API):\n"
        + "\n".join(offenders))
