"""Train-step correctness: CE chunking, microbatch equivalence, AdamW
reference, gradient compression, optimizer specs, overfit sanity."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TrainConfig, get_config
from repro.launch.mesh import make_host_mesh
from repro.models import lm
from repro.optim import adamw
from repro.parallel import compression
from repro.parallel.sharding import make_rules
from repro.train import step as step_mod

B, S = 4, 32


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen2-1.5b").reduced()
    mesh = make_host_mesh(1, 1)
    rules = make_rules(cfg, mesh, global_batch=B, shape_kind="train")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    tokens = (jnp.arange(B * S, dtype=jnp.int32).reshape(B, S) * 13
              ) % cfg.vocab_size
    labels = jnp.roll(tokens, -1, axis=1)
    return cfg, mesh, rules, params, tokens, labels


# ---------------------------------------------------------------------------
# Chunked cross-entropy == dense cross-entropy
# ---------------------------------------------------------------------------


def test_chunked_ce_matches_dense(setup):
    cfg, mesh, rules, params, tokens, labels = setup
    hidden, _, _ = lm.forward(params, tokens, cfg=cfg, mode="train")
    for chunk in (4, 8, 32, 64):     # incl. chunk > S and remainder cases
        ls, cnt = step_mod.chunked_ce(params, hidden, labels, cfg=cfg,
                                      chunk=chunk, cst=lambda x, n: x)
        logits = lm.unembed_logits(params, hidden, cfg)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
        dense = jnp.sum(lse - ll)
        np.testing.assert_allclose(float(ls), float(dense), rtol=1e-5)
        assert int(cnt) == B * S


def test_ce_label_masking(setup):
    cfg, mesh, rules, params, tokens, labels = setup
    hidden, _, _ = lm.forward(params, tokens, cfg=cfg, mode="train")
    masked = labels.at[:, :8].set(-1)
    ls, cnt = step_mod.chunked_ce(params, hidden, masked, cfg=cfg,
                                  chunk=16, cst=lambda x, n: x)
    assert int(cnt) == B * (S - 8)
    assert np.isfinite(float(ls))


# ---------------------------------------------------------------------------
# Microbatch equivalence: mb=1 vs mb=2/4 produce the same update
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mb", [2, 4])
def test_microbatch_equivalence(setup, mb):
    cfg, mesh, rules, params, tokens, labels = setup
    tcfg = TrainConfig(total_steps=10, warmup_steps=1, grad_clip=0.0,
                       loss_chunk=16)
    state = {"params": params, "opt": adamw.init_opt_state(params)}
    s1 = jax.jit(step_mod.make_train_step(cfg, rules, tcfg,
                                          microbatches=1))
    sm = jax.jit(step_mod.make_train_step(cfg, rules, tcfg,
                                          microbatches=mb))
    n1, m1 = s1(state, tokens, labels, None)
    nm, mm = sm(state, tokens, labels, None)
    np.testing.assert_allclose(float(m1["loss"]), float(mm["loss"]),
                               rtol=2e-5)
    for a, b in zip(jax.tree.leaves(n1["params"]),
                    jax.tree.leaves(nm["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=3e-5)


# ---------------------------------------------------------------------------
# AdamW against a hand-rolled reference
# ---------------------------------------------------------------------------


def test_adamw_reference_step():
    tcfg = TrainConfig(learning_rate=1e-2, warmup_steps=0, total_steps=10,
                       weight_decay=0.1, grad_clip=0.0)
    p = {"w": jnp.array([1.0, -2.0, 3.0], jnp.float32)}
    g = {"w": jnp.array([0.1, 0.2, -0.3], jnp.float32)}
    opt = adamw.init_opt_state(p)
    newp, newopt, stats = adamw.adamw_update(p, g, opt, tcfg,
                                             lr_fn=lambda s: 1e-2)
    m = (1 - tcfg.beta1) * np.asarray(g["w"])
    v = (1 - tcfg.beta2) * np.asarray(g["w"]) ** 2
    mhat = m / (1 - tcfg.beta1)
    vhat = v / (1 - tcfg.beta2)
    expect = (np.asarray(p["w"]) - 1e-2 *
              (mhat / (np.sqrt(vhat) + tcfg.eps)
               + 0.1 * np.asarray(p["w"])))
    np.testing.assert_allclose(np.asarray(newp["w"]), expect, rtol=1e-5)
    assert int(newopt.step) == 1
    np.testing.assert_allclose(
        float(stats["grad_norm"]),
        float(np.linalg.norm(np.asarray(g["w"]))), rtol=1e-6)


def test_grad_clip_scales_update():
    tcfg = TrainConfig(grad_clip=0.1, warmup_steps=0, total_steps=10)
    p = {"w": jnp.ones((4,), jnp.float32)}
    g = {"w": jnp.full((4,), 10.0, jnp.float32)}
    opt = adamw.init_opt_state(p)
    _, _, stats = adamw.adamw_update(p, g, opt, tcfg)
    assert float(stats["update_scale"]) < 1.0


def test_warmup_cosine_schedule():
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=10,
                       total_steps=100)
    lr = adamw.warmup_cosine(tcfg)
    assert float(lr(jnp.int32(0))) < 2e-4
    assert float(lr(jnp.int32(9))) == pytest.approx(1e-3, rel=0.01)
    assert float(lr(jnp.int32(99))) == pytest.approx(1e-4, rel=0.05)
    # monotone decay after warmup
    vals = [float(lr(jnp.int32(s))) for s in range(10, 100, 10)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))


# ---------------------------------------------------------------------------
# Gradient compression: error feedback is unbiased over repeats
# ---------------------------------------------------------------------------


def test_int8_ef_roundtrip_error_bounded():
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (256,)) * 0.01}
    err = compression.init_error_state(g)
    out, err = compression.compress_decompress(g, err)
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    assert float(jnp.max(jnp.abs(out["w"] - g["w"]))) <= scale * 0.5 + 1e-9


def test_int8_ef_accumulates_error():
    """Constant tiny gradient below one quantization step must still get
    through via error feedback within a few rounds."""
    g = {"w": jnp.concatenate([jnp.full((1,), 1.0),
                               jnp.full((63,), 1e-3)])}
    err = compression.init_error_state(g)
    through = np.zeros(64)
    rounds = 200
    for _ in range(rounds):
        out, err = compression.compress_decompress(g, err)
        through += np.asarray(out["w"])
    # quantum = 1/127 ~ 7.9e-3: 1e-3 passes only via error feedback;
    # truncation after `rounds` rounds is at most one quantum
    np.testing.assert_allclose(through / rounds, np.asarray(g["w"]),
                               atol=(1.0 / 127.0) / rounds + 1e-6)


def test_train_step_with_compression(setup):
    cfg, mesh, rules, params, tokens, labels = setup
    tcfg = TrainConfig(total_steps=10, warmup_steps=1,
                       grad_compression="int8_ef", loss_chunk=16)
    state = step_mod.init_state(cfg, tcfg, jax.random.PRNGKey(0))
    step = jax.jit(step_mod.make_train_step(cfg, rules, tcfg))
    state, metrics = step(state, tokens, labels, None)
    assert np.isfinite(float(metrics["loss"]))
    assert "err" in state


# ---------------------------------------------------------------------------
# ZeRO-1 spec shapes
# ---------------------------------------------------------------------------


def test_zero1_specs_shard_largest_axis():
    import jax.sharding as shd
    from repro import compat
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    P = shd.PartitionSpec
    specs = {"w": P(None, "model")}
    structs = {"w": jax.ShapeDtypeStruct((128, 64), jnp.float32)}

    class FakeMesh:
        shape = {"data": 8, "model": 4}

    out = adamw.zero1_specs(specs, structs, FakeMesh())
    assert tuple(out["w"]) == ("data", "model")


# ---------------------------------------------------------------------------
# End-to-end: tiny model overfits a repeated batch
# ---------------------------------------------------------------------------


def test_overfit_tiny_batch(setup):
    cfg, mesh, rules, params, tokens, labels = setup
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=5, total_steps=60,
                       loss_chunk=16)
    state = step_mod.init_state(cfg, tcfg, jax.random.PRNGKey(1))
    step = jax.jit(step_mod.make_train_step(cfg, rules, tcfg),
                   donate_argnums=(0,))
    losses = []
    for _ in range(60):
        state, metrics = step(state, tokens, labels, None)
        losses.append(float(metrics["ce_loss"]))
    assert losses[-1] < losses[0] - 1.0, (losses[0], losses[-1])
