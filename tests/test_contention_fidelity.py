"""Contention-fidelity suite (ISSUE 3): the spmd backend's curves must
stay honest as the rung activities get real.

Covers three fidelity claims:

* **Backend consistency** — the same scenario run on the ``interpret``
  and ``spmd`` backends produces the same curve keys and the same
  (deterministic) modeled ladder, and the modeled ladder is monotone on
  both: executing rungs must not change what the curves *mean*.
* **Co-observer coupling** — a coupled multi-observer scenario shifts
  each observer's curve versus the uncoupled baseline (the sibling is
  live inside the measured region / queueing network), and CurveDB
  provenance records ``coupled`` and ``activity`` for every curve.
* **Fenced Pallas activities** — with rung activities promoted from jnp
  loops to real Pallas kernels, ``measured_region_is_fenced`` still
  verifies the barrier dataflow edge, now *through* the ``pallas_call``
  boundary: a kernel fed only by constants (a no-operand write stream)
  is rejected even though the switch output downstream still depends on
  the fence.

Multi-device execution happens in forced-device subprocesses (the main
pytest process must keep seeing ONE device); the device count follows
the ``REPRO_SPMD_DEVICES`` env var so CI can exercise a 2-device and an
8-device mesh (see .github/workflows/ci.yml).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

# CI matrix knob: how many host devices the spmd subprocesses force
N_DEV = max(2, int(os.environ.get("REPRO_SPMD_DEVICES", "8")))


def run_forced(body: str, n_devices: int = N_DEV, timeout: int = 480) -> str:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count={n_devices}"
        {textwrap.indent(textwrap.dedent(body), '        ').strip()}
        print("SUBPROC_OK")
    """)
    env = dict(os.environ, PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=timeout, env=env)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-3000:]}"
    assert "SUBPROC_OK" in r.stdout
    return r.stdout


# ---------------------------------------------------------------------------
# spmd vs interpret: same scenario, same curve identity, sane ladder
# ---------------------------------------------------------------------------


def test_spmd_vs_interpret_consistency():
    """The same ScenarioSpec on both executable backends: identical
    curve keys, identical modeled rung values (the queueing network is
    deterministic and backend-independent), monotone modeled ladder,
    and executed spmd points present and positive for every rung the
    mesh could hold."""
    run_forced("""
    import jax
    from repro.core.coordinator import CoreCoordinator
    from repro.core.scenarios import (ObserverSpec, ScenarioSpec,
                                      StressorSpec)

    BUF = 64 << 10
    K = 3
    spec = ScenarioSpec(
        "consistency", ObserverSpec("r", "hbm", (BUF,)),
        (StressorSpec("w", "hbm", BUF),), iters=3, max_stressors=K)

    n_dev = len(jax.devices())
    interp = CoreCoordinator(backend="interpret").run_matrix([spec])
    spmd = CoreCoordinator(backend="spmd").run_matrix([spec])

    # curve identity agrees
    assert [r.key for r in interp.runs] == [r.key for r in spmd.runs]
    ri, rs = interp.runs[0], spmd.runs[0]
    # the spmd ladder is capped at the rungs its mesh can hold;
    # interpret models the full requested depth
    depth = max(1, min(K + 1, n_dev))
    assert len(ri.scenarios) == K + 1
    assert len(rs.scenarios) == depth

    # the modeled rung values are backend-independent (common prefix)
    for si, ss in zip(ri.scenarios, rs.scenarios):
        assert si.modeled_bw_gbps == ss.modeled_bw_gbps
        assert si.modeled_lat_ns == ss.modeled_lat_ns
    # ...and the modeled ladder is monotone (bw down, latency up)
    bws = [s.modeled_bw_gbps for s in ri.scenarios]
    lats = [s.modeled_lat_ns for s in ri.scenarios]
    assert all(b <= a * 1.0001 for a, b in zip(bws, bws[1:]))
    assert all(b >= a * 0.9999 for a, b in zip(lats, lats[1:]))

    # the spmd backend executed every rung the mesh could hold, and
    # the executed points are real measurements
    assert rs.execution["executed_rungs"] == list(range(depth))
    assert rs.execution["activity"] in ("pallas", "jnp")
    assert rs.execution["fenced"]
    for s in rs.scenarios:
        assert s.source == "executed"
        assert s.main.elapsed_ns > 0
        assert s.main.bandwidth_gbps > 0
    print("consistency OK on", n_dev, "devices")
    """)


def test_coupled_execution_on_mesh():
    """Coupled multi-observer spmd execution: every sibling occupies a
    live engine inside each observer's rung, so the executable ladder
    depth shrinks by one engine per sibling; provenance records
    coupled/activity per curve; the jnp fallback activity is selectable
    and stamps itself honestly."""
    run_forced("""
    import jax
    from repro.core.coordinator import CoreCoordinator
    from repro.core.scenarios import (ObserverSpec, ScenarioSpec,
                                      StressorSpec)

    BUF = 64 << 10
    K = 3
    obs = (ObserverSpec("r", "hbm", (BUF,)),
           ObserverSpec("l", "host", (BUF,)))
    stress = (StressorSpec("w", "hbm", BUF),)
    coupled = ScenarioSpec("coupled", obs, stress, iters=3,
                           max_stressors=K)
    uncoupled = ScenarioSpec("uncoupled", obs, stress, iters=3,
                             max_stressors=K, coupled=False)

    n_dev = len(jax.devices())
    c = CoreCoordinator(backend="spmd")
    res = c.run_matrix([coupled, uncoupled])
    assert res.stats.n_ladders == 4

    depth_c = max(1, min(K + 1, n_dev - 1))   # 1 engine per sibling
    depth_u = max(1, min(K + 1, n_dev))
    for run in res.runs:
        ex = run.execution
        assert ex["fenced"]
        assert ex["activity"] in ("pallas", "jnp")
        if run.spec.name == "coupled":
            assert ex["coupled"] is True
            assert ex["executed_rungs"] == list(range(depth_c))
        else:
            assert ex["coupled"] is False
            assert ex["executed_rungs"] == list(range(depth_u))
        for s in run.scenarios:
            if s.source == "executed":
                assert s.main.elapsed_ns > 0

    # forcing the jnp fallback stamps the provenance honestly
    cj = CoreCoordinator(backend="spmd", spmd_activity="jnp")
    resj = cj.run_matrix([coupled])
    assert all(r.execution["activity"] == "jnp" for r in resj.runs)
    assert all(r.execution["fenced"] for r in resj.runs)
    print("coupled execution OK on", n_dev, "devices")
    """)


# ---------------------------------------------------------------------------
# Coupling shifts curves (deterministic: the queueing model)
# ---------------------------------------------------------------------------


def test_coupled_vs_uncoupled_curves_differ_under_load():
    """A live sibling bandwidth observer inside the measured region
    must cost the observer bandwidth at EVERY rung — including rung 0,
    where the uncoupled scenario sees no contention at all.  Modeled
    backend: deterministic, so the comparison is exact."""
    from repro.core.characterize import characterize_matrix
    from repro.core.coordinator import CoreCoordinator

    coupled, uncoupled = _twin_specs()
    c = CoreCoordinator(backend="simulate")
    db_c = characterize_matrix(c, [coupled])
    db_u = characterize_matrix(c, [uncoupled])
    key = "hbm:r|hbm:w"
    bw_c = [p.bandwidth_gbps for p in db_c.curves[key]]
    bw_u = [p.bandwidth_gbps for p in db_u.curves[key]]
    lat_c = [p.latency_ns for p in db_c.curves[key]]
    lat_u = [p.latency_ns for p in db_u.curves[key]]
    assert all(cc < uu for cc, uu in zip(bw_c, bw_u))
    assert all(cc > uu for cc, uu in zip(lat_c, lat_u))
    # provenance records which semantics produced each curve
    assert db_c.provenance[key]["execution"]["coupled"] is True
    assert db_u.provenance[key]["execution"]["coupled"] is False
    assert db_c.provenance[key]["coupled"] is True
    assert db_u.provenance[key]["coupled"] is False


def test_coupling_term_in_scenario_ladder():
    """The queueing model's standalone ladder API carries the same
    co-observer term: a coupled sibling read stream depresses the
    observer at every rung."""
    from repro.core import simulate as sim
    from repro.core.devicetree import TPU_V5E

    node = TPU_V5E.node("hbm")
    plain = sim.scenario_ladder(
        TPU_V5E, obs_node=node, obs_strategy="r", stress_node=node,
        stress_strategy="w", max_stressors=3)
    coupled = sim.scenario_ladder(
        TPU_V5E, obs_node=node, obs_strategy="r", stress_node=node,
        stress_strategy="w", max_stressors=3,
        co_observers=[(node, "r")])
    for p, q in zip(plain, coupled):
        assert q["obs"].bw_gbps < p["obs"].bw_gbps
        assert "co0" in q and "co0" not in p


def test_uncoupled_spec_roundtrips_and_defaults_coupled():
    """``coupled`` is part of the spec identity: it round-trips through
    dicts, and absent keys (pre-coupling spec files) default to the new
    coupled semantics."""
    import json

    from repro.core.scenarios import ScenarioSpec

    coupled, uncoupled = _twin_specs()
    for spec in (coupled, uncoupled):
        back = ScenarioSpec.from_dict(json.loads(json.dumps(
            spec.to_dict())))
        assert back == spec and back.coupled == spec.coupled
    legacy = coupled.to_dict()
    del legacy["coupled"]
    assert ScenarioSpec.from_dict(legacy).coupled is True


def _twin_specs():
    from repro.core.scenarios import (ObserverSpec, ScenarioSpec,
                                      StressorSpec)
    BUF = 1 << 20
    obs = (ObserverSpec("r", "hbm", (BUF,)),
           ObserverSpec("r", "host", (BUF,)))
    stress = (StressorSpec("w", "hbm", BUF),)
    return (ScenarioSpec("twin-c", obs, stress, iters=5, max_stressors=3),
            ScenarioSpec("twin-u", obs, stress, iters=5, max_stressors=3,
                         coupled=False))


def test_duplicate_observers_rejected():
    """Two observers identical in every field would alias one curve key
    per buffer and silently overwrite each other's ladders in CurveDB —
    validate_spec must reject the spec up front (twins differing in any
    field, e.g. buffer ladders, stay legal and key distinctly)."""
    from repro.core.coordinator import CoreCoordinator, ValidationError
    from repro.core.scenarios import (ObserverSpec, ScenarioSpec,
                                      StressorSpec)

    BUF = 1 << 20
    o = ObserverSpec("r", "hbm", (BUF,))
    dup = ScenarioSpec("dup", (o, ObserverSpec("r", "hbm", (BUF,))),
                       (StressorSpec("w", "hbm", BUF),), iters=5)
    c = CoreCoordinator(backend="simulate")
    with pytest.raises(ValidationError, match="duplicate observer"):
        c.validate_spec(dup)
    # same instance listed twice is the same duplicate
    with pytest.raises(ValidationError, match="duplicate observer"):
        c.validate_spec(ScenarioSpec(
            "dup2", (o, o), (StressorSpec("w", "hbm", BUF),), iters=5))
    # differing buffer ladders remain legal
    c.validate_spec(ScenarioSpec(
        "ok", (o, ObserverSpec("r", "hbm", (2 * BUF,))),
        (StressorSpec("w", "hbm", BUF),), iters=5))


def test_coupled_siblings_resolve_for_reconstructed_observers():
    """_coupled_siblings drops exactly one occurrence of the measured
    observer — including for a deserialized (equal, non-identical)
    observer — so twins differing only in buffers still see each
    other."""
    from repro.core.coordinator import CoreCoordinator
    from repro.core.scenarios import (ObserverSpec, ScenarioSpec,
                                      StressorSpec)

    BUF = 1 << 20
    a = ObserverSpec("r", "hbm", (BUF,))
    b = ObserverSpec("r", "hbm", (2 * BUF,))
    spec = ScenarioSpec("twins", (a, b),
                        (StressorSpec("w", "hbm", BUF),), iters=5)
    sib = CoreCoordinator._coupled_siblings
    assert sib(spec, a) == (b,)
    assert sib(spec, b) == (a,)
    # reconstructed equal observer resolves by value
    assert sib(spec, ObserverSpec("r", "hbm", (BUF,))) == (b,)
    assert sib(spec, ObserverSpec("r", "hbm", (2 * BUF,))) == (a,)


# ---------------------------------------------------------------------------
# Pallas rung activities keep the fence (jaxpr check crosses pallas_call)
# ---------------------------------------------------------------------------

ROWS = 16


def _operands(n_eng: int):
    xf = np.ones((n_eng, ROWS, 128), np.float32)
    xi = np.zeros((n_eng, ROWS, 128), np.int32)
    xi[:, :ROWS, 0] = np.roll(np.arange(ROWS), 1)     # a valid cycle
    return xf, xi


@pytest.mark.parametrize("strategy", ["r", "w", "y", "c", "b", "l", "t"])
def test_pallas_branch_fns_execute_and_stay_fenced(strategy):
    """Every Pallas rung activity traces under the rung program, runs
    to a finite result, and the measured region remains structurally
    fenced — the dataflow edge from the start-barrier psum reaches
    every pallas_call's operands."""
    from repro.core.coordinator import (_spmd_branch_fn,
                                        build_rung_program,
                                        measured_region_is_fenced)
    from repro.core.scenarios import TrafficShape

    shape = {"b": TrafficShape.mixed(1, 1),
             "t": TrafficShape.strided(4)}.get(strategy)
    fns = [_spmd_branch_fn(strategy, shape, ROWS, 2, activity="pallas")]
    _mesh, f = build_rung_program(1, fns, [0])
    xf, xi = _operands(1)
    out, _barrier = f(xf, xi)
    assert np.isfinite(np.asarray(out)).all()
    assert measured_region_is_fenced(f, xf, xi)


def test_pallas_activity_programs_contain_pallas_calls():
    """The promoted rung program really is pallas_call-backed (and the
    jnp fallback really is not): the activity provenance claim is
    structural, not a label."""
    import jax

    from repro.core.coordinator import _spmd_branch_fn, build_rung_program

    def has_pallas(activity):
        fns = [_spmd_branch_fn("r", None, ROWS, 2, activity=activity)]
        _mesh, f = build_rung_program(1, fns, [0])
        return "pallas_call" in str(jax.make_jaxpr(f)(*_operands(1)))

    assert has_pallas("pallas")
    assert not has_pallas("jnp")


def test_fence_checker_rejects_unfenced_pallas_kernel():
    """A pallas_call fed only by constants (write_hbm takes no operands
    at all) is real memory traffic with NO dataflow edge from the start
    barrier — XLA may hoist it above the fence.  The extended checker
    must reject it even though the switch output downstream still
    depends on the barrier through other equations."""
    from repro.core.coordinator import (build_rung_program,
                                        measured_region_is_fenced)
    from repro.kernels import stream as _kstream

    def unfenced(xf, xi):
        out = _kstream.write_hbm(ROWS, block_rows=ROWS, interpret=True)
        return out[0, 0] + xf[0, 0] * 0.0     # "depends" on the fence

    _mesh, f = build_rung_program(1, [unfenced], [0])
    assert not measured_region_is_fenced(f, *_operands(1))


def test_mixed_stream_write_half_needs_the_seed():
    """Regression (found by the extended checker): the mixed stream's
    write half is a no-operand kernel, so an unseeded mix inside the
    measured region is structurally unfenced; the seeded mix routes the
    stores through write_hbm_seeded and restores the edge."""
    import jax.numpy as jnp

    from repro import compat
    from repro.core.coordinator import (build_rung_program,
                                        measured_region_is_fenced)
    from repro.kernels import stream as _kstream

    def mk(seeded):
        def mixed(xf, xi):
            x = compat.optimization_barrier(xf[:ROWS])
            s, out = _kstream.mixed_hbm(
                x, read_fraction=0.5, block_rows=ROWS // 8,
                interpret=True, seed=x[:1, :1] if seeded else None)
            return s + jnp.sum(out[:1])
        return mixed

    _m, f_seeded = build_rung_program(1, [mk(True)], [0])
    _m, f_bare = build_rung_program(1, [mk(False)], [0])
    xf, xi = _operands(1)
    assert measured_region_is_fenced(f_seeded, xf, xi)
    assert not measured_region_is_fenced(f_bare, xf, xi)


# ---------------------------------------------------------------------------
# Fused whole-ladder dispatch (ISSUE 4): accounting, equivalence, cache
# ---------------------------------------------------------------------------


def test_fused_dispatch_accounting():
    """DispatchStats under fusion: the fused path blocks the host ONCE
    per (triple, ladder) — versus 4 per RUNG on the legacy path — and
    the execution provenance records the timing source, the per-rung
    sample spreads, and the per-ladder dispatch count."""
    run_forced("""
    import jax
    from repro.core.coordinator import CoreCoordinator
    from repro.core.scenarios import (ObserverSpec, ScenarioSpec,
                                      StressorSpec)

    BUF = 64 << 10
    K = 2
    spec = ScenarioSpec(
        "acct", (ObserverSpec("r", "hbm", (BUF,)),
                 ObserverSpec("w", "hbm", (BUF,))),
        (StressorSpec("w", "hbm", BUF),), iters=3, max_stressors=K)
    n_dev = len(jax.devices())
    depth = max(1, min(K + 1, n_dev - 1))     # 1 engine per sibling

    fused = CoreCoordinator(backend="spmd").run_matrix([spec])
    st = fused.stats
    assert st.n_ladders == 2
    assert st.spmd_rungs == 2 * depth
    assert st.measure_dispatches == st.n_ladders          # 1 per ladder
    # quality-gate re-measures (rare: a real noise event during the
    # run) each add one honest host sync on top of the 1-per-ladder
    assert st.host_sync_dispatches == st.n_ladders + st.noisy_remeasures
    for run in fused.runs:
        ex = run.execution
        assert ex["timing_source"] == "device"
        assert ex["dispatches"] == 1 + ex["remeasures"]
        assert ex["attempts"] == 1 and ex["degraded_from"] is None
        assert ex["samples"] == 3
        assert len(ex["rung_time_spread_ns"]) == depth
        assert all(s >= 0 for s in ex["rung_time_spread_ns"])

    legacy = CoreCoordinator(backend="spmd",
                             spmd_dispatch="rung").run_matrix([spec])
    st = legacy.stats
    assert st.spmd_rungs == 2 * depth
    assert st.measure_dispatches == 2 * depth             # K per ladder
    assert st.host_sync_dispatches == 4 * 2 * depth       # warm + 3 timed
    for run in legacy.runs:
        ex = run.execution
        assert ex["timing_source"] == "host"
        assert ex["dispatches"] == 4 * depth
        assert len(ex["rung_time_spread_ns"]) == depth
    print("accounting OK on", n_dev, "devices")
    """)


def test_fused_vs_per_rung_curve_equivalence():
    """The fused whole-ladder dispatch must produce the SAME curves as
    the legacy per-rung path: identical keys, every rung executed and
    fenced on both, and the measured timings within a (generous — this
    is shared-CPU wall time on tiny budgets) agreement band."""
    run_forced("""
    import jax
    from repro.core.coordinator import CoreCoordinator
    from repro.core.scenarios import (ObserverSpec, ScenarioSpec,
                                      StressorSpec)

    BUF = 128 << 10
    K = 3
    spec = ScenarioSpec(
        "equiv", ObserverSpec("r", "hbm", (BUF,)),
        (StressorSpec("w", "hbm", BUF),), iters=20, max_stressors=K)
    n_dev = len(jax.devices())
    depth = max(1, min(K + 1, n_dev))

    fused = CoreCoordinator(backend="spmd").run_matrix([spec])
    legacy = CoreCoordinator(backend="spmd",
                             spmd_dispatch="rung").run_matrix([spec])
    assert [r.key for r in fused.runs] == [r.key for r in legacy.runs]
    rf, rl = fused.runs[0], legacy.runs[0]
    assert rf.execution["fenced"] and rl.execution["fenced"]
    assert rf.execution["executed_rungs"] == list(range(depth))
    assert rl.execution["executed_rungs"] == list(range(depth))
    for sf, sl in zip(rf.scenarios, rl.scenarios):
        assert sf.source == sl.source == "executed"
        assert sf.main.strategy == sl.main.strategy
        assert sf.main.bytes_moved == sl.main.bytes_moved
        assert sf.main.elapsed_ns > 0 and sl.main.elapsed_ns > 0
        ratio = sf.main.elapsed_ns / sl.main.elapsed_ns
        assert 1 / 50 < ratio < 50, (sf.n_stressors, ratio)
    print("equivalence OK on", n_dev, "devices")
    """)


def test_batched_sweep_equivalence_and_accounting():
    """Sweep-level megabatching (ISSUE 5): a mixed sweep whose ladders
    repeat a role-program signature costs ONE host-synchronous dispatch
    per distinct signature — and produces curves IDENTICAL in keys,
    resolved strategies, bytes and fence state to the same sweep with
    batching off (one fused dispatch per ladder)."""
    run_forced("""
    import jax
    from repro.core.coordinator import CoreCoordinator
    from repro.core.scenarios import (ObserverSpec, ScenarioSpec,
                                      StressorSpec)

    BUF = 64 << 10
    K = 2

    def mk(name, pool, iters):
        return ScenarioSpec(name, ObserverSpec("r", pool, (BUF,)),
                            (StressorSpec("w", "hbm", BUF),),
                            iters=iters, max_stressors=K)

    # 4 ladders, 2 distinct signatures: hbm/host observers share one
    # effective memory kind on this container (so they stack), while
    # differing iteration budgets MUST split
    specs = [mk("a", "hbm", 3), mk("b", "host", 3),
             mk("c", "hbm", 5), mk("d", "host", 5)]
    n_dev = len(jax.devices())
    depth = max(1, min(K + 1, n_dev))

    c = CoreCoordinator(backend="spmd")
    bat = c.run_matrix(specs)
    st = bat.stats
    assert st.n_ladders == 4
    assert st.spmd_groups == 2
    # one per SIGNATURE (+ any rare quality-gate re-measures)
    assert st.host_sync_dispatches == 2 + st.noisy_remeasures
    assert st.measure_dispatches == 2
    assert st.spmd_rungs == 4 * depth          # every rung executed
    assert st.programs_built == 2              # one program per group
    for run in bat.runs:
        ex = run.execution
        assert ex["batched"] is True
        assert ex["group_size"] == 2
        assert ex["timing_source"] == "device"
        assert ex["dispatches"] == 1 + ex["remeasures"]
        assert ex["fenced"]
        assert isinstance(ex["aot"], bool)

    # batching off: same coordinator API, one fused dispatch per ladder
    unb = CoreCoordinator(backend="spmd").run_matrix(specs,
                                                     batched=False)
    assert unb.stats.host_sync_dispatches == \
        4 + unb.stats.noisy_remeasures           # one per LADDER
    assert unb.stats.spmd_groups == 0
    assert [r.key for r in bat.runs] == [r.key for r in unb.runs]
    for rb, ru in zip(bat.runs, unb.runs):
        assert ru.execution["batched"] is False
        assert ru.execution["group_size"] == 1
        assert ru.execution["fenced"]
        assert rb.execution["executed_rungs"] \
            == ru.execution["executed_rungs"]
        for sb, su in zip(rb.scenarios, ru.scenarios):
            assert sb.source == su.source == "executed"
            assert sb.main.strategy == su.main.strategy
            assert sb.main.bytes_moved == su.main.bytes_moved
            assert sb.main.elapsed_ns > 0 and su.main.elapsed_ns > 0
    print("batched equivalence OK on", n_dev, "devices")
    """)


def test_packed_sweep_accounting_and_equivalence():
    """Engine-subset width-packing (ISSUE 7): a sweep of narrow
    same-signature ladders runs them SIDE BY SIDE on disjoint engine
    subsets of one dispatch — the accounting must show the packing
    (stats.packed_ladders / subset_width, per-curve subset slots), the
    dispatch count must stay at one per signature, and the curves must
    be IDENTICAL in keys, bytes and fence state to the same sweep with
    packing forced off."""
    run_forced("""
    import jax
    from repro.core.coordinator import CoreCoordinator
    from repro.core.scenarios import (ObserverSpec, ScenarioSpec,
                                      StressorSpec)

    BUF = 64 << 10

    def mk(name):
        # max_stressors=1 -> 2-rung, width-2 ladders (observer +
        # one stressor engine); all four share one signature
        return ScenarioSpec(name, ObserverSpec("r", "hbm", (BUF,)),
                            (StressorSpec("w", "hbm", BUF),),
                            iters=3, max_stressors=1)

    specs = [mk(n) for n in "abcd"]
    n_dev = len(jax.devices())
    depth = max(1, min(2, n_dev))
    width = depth                   # 1 observer + (depth-1) scenarios
    # the planner packs iff a full second subset fits
    n_subsets = min(n_dev // width, 4) if n_dev >= 2 * width else 1

    c = CoreCoordinator(backend="spmd")
    res = c.run_matrix(specs)
    st = res.stats
    assert st.n_ladders == 4
    assert st.spmd_groups == 1                 # one signature
    assert st.host_sync_dispatches == \
        1 + st.noisy_remeasures                # ...one dispatch
    assert st.programs_built == 1
    assert st.spmd_rungs == 4 * depth          # every rung executed
    if n_subsets > 1:
        assert st.packed_ladders == 4
        assert st.subset_width == width
    else:
        assert st.packed_ladders == 0
    seen_subsets = set()
    for run in res.runs:
        ex = run.execution
        assert ex["batched"] is True
        assert ex["group_size"] == 4
        assert ex["fenced"]
        assert ex["packed"] is (n_subsets > 1)
        assert ex["subset_width"] == (width if n_subsets > 1
                                      else n_dev)
        assert 0 <= ex["subset_index"] < n_subsets
        seen_subsets.add(ex["subset_index"])
    # packed ladders really occupy DISTINCT subsets of the mesh
    assert len(seen_subsets) == min(n_subsets, 4)

    # packing off: same sweep, same grouping, scan-stacked instead
    off = CoreCoordinator(backend="spmd", spmd_pack="off")
    unp = off.run_matrix(specs)
    assert unp.stats.packed_ladders == 0
    assert unp.stats.host_sync_dispatches == \
        1 + unp.stats.noisy_remeasures
    assert [r.key for r in res.runs] == [r.key for r in unp.runs]
    for rp, ru in zip(res.runs, unp.runs):
        assert ru.execution["packed"] is False
        assert ru.execution["fenced"]
        assert rp.execution["executed_rungs"] \\
            == ru.execution["executed_rungs"]
        for sp, su in zip(rp.scenarios, ru.scenarios):
            assert sp.source == su.source == "executed"
            assert sp.main.strategy == su.main.strategy
            assert sp.main.bytes_moved == su.main.bytes_moved
            assert sp.main.elapsed_ns > 0 and su.main.elapsed_ns > 0
            ratio = sp.main.elapsed_ns / su.main.elapsed_ns
            assert 1 / 50 < ratio < 50, (rp.key, sp.n_stressors,
                                         ratio)
    print("packed OK:", n_subsets, "subsets on", n_dev, "devices")
    """)


def test_lru_eviction_deletes_operand_buffers():
    """Satellite regression: the spmd program cache cap is a MEMORY
    bound — evicting an entry must delete its placed operand device
    buffers eagerly, not just drop the dict reference (a capped cache
    must not pin device memory for programs it no longer holds)."""
    import jax
    import jax.numpy as jnp

    from repro.core.coordinator import CoreCoordinator

    c = CoreCoordinator(backend="simulate", spmd_cache_cap=1)

    def entry():
        xf = jax.device_put(jnp.ones((4, 4), jnp.float32))
        xi = jax.device_put(jnp.zeros((4, 4), jnp.int32))
        return [None, None, True, xf, xi, False]

    e1, e2 = entry(), entry()
    c._program_cache_put(("k1",), e1)
    c._program_cache_put(("k2",), e2)
    assert list(c._spmd_programs) == [("k2",)]
    # the evicted entry's device buffers are gone NOW, not at GC time
    assert e1[3].is_deleted() and e1[4].is_deleted()
    # the resident entry's buffers are untouched
    assert not e2[3].is_deleted() and not e2[4].is_deleted()


def test_program_cache_reuse_across_run_matrix():
    """The spmd program cache lives on the COORDINATOR: a second
    run_matrix call reuses every compiled program (and its placed,
    donated operand buffers) instead of re-tracing, and the
    DispatchStats counter proves it."""
    run_forced("""
    import jax
    from repro.core.coordinator import CoreCoordinator
    from repro.core.scenarios import (ObserverSpec, ScenarioSpec,
                                      StressorSpec)

    BUF = 64 << 10
    spec = ScenarioSpec(
        "cache", ObserverSpec("r", "hbm", (BUF,)),
        (StressorSpec("w", "hbm", BUF),), iters=3, max_stressors=2)

    depth = max(1, min(3, len(jax.devices())))
    for mode, n_programs in (("batched", 1), ("ladder", 1),
                             ("rung", depth)):
        c = CoreCoordinator(backend="spmd", spmd_dispatch=mode)
        first = c.run_matrix([spec])
        assert first.stats.program_cache_hits == 0
        assert first.stats.programs_built == n_programs
        again = c.run_matrix([spec])
        # every program the second run needs is already cached: ONE
        # stacked/whole-ladder program, or one per rung on the legacy
        # path
        assert again.stats.program_cache_hits == n_programs
        assert again.stats.programs_built == 0
        for run in again.runs:
            assert run.execution["fenced"]
            for s in run.scenarios:
                assert s.main.elapsed_ns > 0

    # spmd_cache_cap=1 under eviction churn (the per-rung path needs
    # `depth` programs): every eviction must delete the evicted
    # operand buffers, execution must stay correct, and the single
    # resident entry must keep live buffers
    c1 = CoreCoordinator(backend="spmd", spmd_dispatch="rung",
                         spmd_cache_cap=1)
    for _ in range(2):
        r1 = c1.run_matrix([spec])
        assert len(c1._spmd_programs) == 1
        live = next(iter(c1._spmd_programs.values()))
        assert not live[3].is_deleted() and not live[4].is_deleted()
        for run in r1.runs:
            assert run.execution["fenced"]
            for s in run.scenarios:
                assert s.main.elapsed_ns > 0
    print("cache reuse OK")
    """)


def test_spmd_ladder_refuses_pinned_single_device():
    """Regression: with XLA_FLAGS already pinning the host device count
    below 2, benchmarks.spmd_ladder used to re-exec itself with the
    same environment — unbounded process recursion.  It must fail fast
    with an actionable message instead."""
    env = dict(os.environ, PYTHONPATH=SRC,
               XLA_FLAGS="--xla_force_host_platform_device_count=1")
    root = os.path.dirname(SRC)
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.spmd_ladder"],
        capture_output=True, text=True, timeout=240, env=env, cwd=root)
    assert r.returncode != 0
    assert "already pins" in r.stderr


def test_jnp_fallback_branches_still_fenced():
    """The compat fallback (pure-jnp loops) keeps the original fence
    guarantee — the checker extension must not regress it."""
    from repro.core.coordinator import (_spmd_branch_fn,
                                        build_rung_program,
                                        measured_region_is_fenced)

    fns = [_spmd_branch_fn("r", None, ROWS, 2, activity="jnp"),
           _spmd_branch_fn("w", None, ROWS, 2, activity="jnp")]
    _mesh, f = build_rung_program(1, fns, [0])
    assert measured_region_is_fenced(f, *_operands(1))
