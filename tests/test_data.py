"""Data pipeline: determinism, restart safety, memmap source, frontend
stubs, prefetch iterator."""
import numpy as np
import pytest

from repro.configs.base import ShapeSpec, get_config
from repro.data.pipeline import DataLoader, MemmapSource, SyntheticSource


def test_synthetic_restart_safe():
    """batch(step) is a pure function of (seed, step) — the fault-recovery
    contract of the resilient loop."""
    s = SyntheticSource(1000, seed=3)
    a = s.batch(17, 4, 32)
    b = SyntheticSource(1000, seed=3).batch(17, 4, 32)
    np.testing.assert_array_equal(a, b)
    c = s.batch(18, 4, 32)
    assert not np.array_equal(a, c)
    assert a.shape == (4, 33)               # seq+1 for labels
    assert a.min() >= 0 and a.max() < 1000


def test_memmap_source(tmp_path):
    toks = np.arange(10_000, dtype=np.uint16) % 500
    p = str(tmp_path / "tokens.bin")
    toks.tofile(p)
    src = MemmapSource(p, vocab_size=500)
    b = src.batch(0, 2, 16)
    assert b.shape == (2, 17)
    assert (b < 500).all()
    b2 = MemmapSource(p, vocab_size=500).batch(0, 2, 16)
    np.testing.assert_array_equal(b, b2)


def test_loader_labels_shifted():
    cfg = get_config("qwen2-1.5b").reduced()
    loader = DataLoader(cfg, ShapeSpec("t", 16, 2, "train"), seed=0)
    batch = loader.host_batch(0)
    np.testing.assert_array_equal(np.asarray(batch.tokens)[:, 1:],
                                  np.asarray(batch.labels)[:, :-1])
    assert batch.frontend is None


def test_loader_vlm_masks_prefix():
    cfg = get_config("internvl2-26b").reduced()
    loader = DataLoader(cfg, ShapeSpec("t", 16, 2, "train"), seed=0)
    b = loader.host_batch(0)
    p = cfg.n_prefix_embeds
    assert b.frontend["prefix_embeds"].shape == (2, p, cfg.d_model)
    assert (np.asarray(b.labels)[:, :p] == -1).all()


def test_loader_audio_frontend():
    cfg = get_config("musicgen-large").reduced()
    loader = DataLoader(cfg, ShapeSpec("t", 16, 2, "train"), seed=0)
    b = loader.host_batch(0)
    assert b.frontend["frame_embeds"].shape == (2, 16, cfg.d_model)


def test_prefetch_iterator():
    cfg = get_config("qwen2-1.5b").reduced()
    loader = DataLoader(cfg, ShapeSpec("t", 8, 2, "train"), seed=1,
                        prefetch=2)
    it = iter(loader)
    batches = [next(it) for _ in range(3)]
    ref = loader.device_batch(1)
    np.testing.assert_array_equal(np.asarray(batches[1].tokens),
                                  np.asarray(ref.tokens))
