"""Resilient sweep execution (PR 9): fault spec parsing, the
deterministic injector, the retry-with-degradation ladder
(packed -> batched -> fused ladder -> per-rung -> modeled), the
measurement quality gate, GroupExecutionError context, atomic
CurveDB.save, and crash-resumable sweep journals.

The ladder tests drive :func:`repro.core.exec.resilience.run_group`
with REAL DispatchPlans (the planner is pure data) and a scripted
FakeDispatcher, so every degradation step is exercised fast and
deterministically without a device mesh.  End-to-end chaos behaviour
on a real mesh runs in forced-device subprocesses at the bottom.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.exec import journal as exec_journal
from repro.core.exec import plan as exec_plan
from repro.core.exec import resilience as res
from repro.core.exec.dispatch import DispatchStats
from repro.core.pools import PoolManager
from repro.core.scenarios import ObserverSpec, ScenarioSpec, StressorSpec

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
N_DEV = max(2, int(os.environ.get("REPRO_SPMD_DEVICES", "8")))

BUF = 1 << 16
NOOP = lambda _s: None          # noqa: E731 — retry backoff stub


def _spec(name, buf=BUF, ostrat="r", K=1):
    return ScenarioSpec(name, ObserverSpec(ostrat, "hbm", (buf,)),
                        (StressorSpec("w", "hbm", buf),), iters=3,
                        max_stressors=K)


def _plan(names=("a", "b", "c", "d"), n_eng=8, packed=False, buf=BUF):
    pm = PoolManager()
    triples = [(s, s.observer, buf) for s in (_spec(n, buf) for n in names)]
    plan = exec_plan.build_plan(triples, n_eng, pm,
                                pm.platform.n_engines)
    if packed:
        plan = exec_plan.pack_engine_subsets(plan)
    return plan


class FakeDispatcher:
    """Scripted Dispatcher stand-in.  ``behaviors`` is a queue consumed
    one element per run_planned/run_rung call:

    - ``"ok"``            good timings
    - ``"corrupt"``       non-positive timings (validation fault)
    - ``("noisy", s)``    good timings with sample spread ``s``
    - a fault-kind string (``"timeout"`` ...)  raises InjectedFault
    - an exception instance                    raised verbatim

    When the queue drains, ``default`` repeats forever.
    """

    def __init__(self, behaviors=(), default="ok", samples=3):
        self.behaviors = list(behaviors)
        self.default = default
        self.samples = samples
        self.planned_calls = []
        self.rung_calls = []

    def _next(self):
        b = self.behaviors.pop(0) if self.behaviors else self.default
        if isinstance(b, BaseException):
            raise b
        if isinstance(b, str) and b in res.FAULT_KINDS:
            raise res.InjectedFault(b, "fake-site")
        return b

    def run_planned(self, planned, n_eng, activity, mode, stats):
        self.planned_calls.append(planned)
        b = self._next()
        g, k = planned.group, planned.n_scen
        stats.host_sync_dispatches += 1
        stats.measure_dispatches += 1
        stats.spmd_rungs += g * k
        if planned.packed:
            stats.packed_ladders += g
        if b == "corrupt":
            return (np.full((g, k), -1.0), np.zeros((g, k)), True, True)
        spread = b[1] if isinstance(b, tuple) else 10.0
        return (np.full((g, k), 1000.0), np.full((g, k), float(spread)),
                True, True)

    def run_rung(self, roles, n_eng, activity, kind, stats):
        self.rung_calls.append(roles)
        b = self._next()
        stats.host_sync_dispatches += 1 + self.samples
        if b == "corrupt":
            return (-5.0, True, 3, True)
        return (2000.0, True, 3, True)


def _run(disp, plan, policy=None, gate=None, stats=None):
    stats = stats or DispatchStats()
    outs = []
    for planned in plan.dispatches:
        outs.extend(res.run_group(
            disp, planned, n_eng=plan.n_engines, activity="jnp",
            mode="batched", stats=stats,
            policy=policy or res.RetryPolicy(backoff_s=0, sleep=NOOP),
            gate=gate))
    return outs, stats


# ---------------------------------------------------------------------------
# FaultSpec: parsing, env resolution, validation
# ---------------------------------------------------------------------------


def test_fault_spec_parse_spellings():
    s = res.FaultSpec.parse("mixed=0.4,seed=7")
    assert s.seed == 7
    assert all(s.rate(k) == pytest.approx(0.1) for k in res.FAULT_KINDS)
    s = res.FaultSpec.parse("compile=0.5,corrupt=0.25")
    assert (s.compile_error, s.corrupt_timing) == (0.5, 0.25)
    assert s.runtime_error == s.timeout == 0.0
    # explicit rates win over the mixed remainder
    s = res.FaultSpec.parse("mixed=0.8,timeout=0.0")
    assert s.timeout == 0.0 and s.compile_error == pytest.approx(0.2)


def test_fault_spec_rejects_garbage():
    with pytest.raises(ValueError):
        res.FaultSpec.parse("bogus=1")
    with pytest.raises(ValueError):
        res.FaultSpec.parse("compile")            # no '='
    with pytest.raises(ValueError):
        res.FaultSpec(compile_error=1.5)          # rate outside [0, 1]
    with pytest.raises(ValueError):
        res.FaultSpec(timeout=-0.1)


def test_fault_spec_from_env_and_resolution():
    E = res.ENV_FAULT_SPEC
    assert res.FaultSpec.from_env({}) is None
    for off in ("", "0", "off", "none", "OFF"):
        assert res.FaultSpec.from_env({E: off}) is None
    s = res.FaultSpec.from_env({E: "mixed=0.25,seed=3"})
    assert s.seed == 3 and s.rate("timeout") == pytest.approx(0.0625)

    # coordinator-side resolution
    assert res.resolve_faults(False) is None
    assert res.resolve_faults("off") is None
    assert res.resolve_faults(None, environ={}) is None
    assert res.resolve_faults(None, environ={E: "timeout=1"}).timeout == 1
    assert res.resolve_faults("runtime=0.5").runtime_error == 0.5
    assert res.resolve_faults(s) is s
    with pytest.raises(TypeError):
        res.resolve_faults(123)


def test_quality_gate_resolution():
    assert isinstance(res.resolve_gate(None), res.QualityGate)
    assert isinstance(res.resolve_gate("auto"), res.QualityGate)
    assert res.resolve_gate(False) is None
    assert res.resolve_gate("off") is None
    g = res.QualityGate(rel_spread=2.0)
    assert res.resolve_gate(g) is g
    with pytest.raises(TypeError):
        res.resolve_gate(1.0)


def test_injector_determinism_and_rates():
    spec = res.FaultSpec.parse("mixed=0.5,seed=11")
    a, b = spec.injector(), spec.injector()
    visits = [(f"site{i % 7}", ph) for i in range(300)
              for ph in ("compile", "dispatch", "decode")]
    seq_a = [a.check(s, p) for s, p in visits]
    seq_b = [b.check(s, p) for s, p in visits]
    assert seq_a == seq_b                     # same seed, same schedule
    fired = [k for k in seq_a if k]
    assert all(k in res.FAULT_KINDS for k in fired)
    # mixed=0.5 splits 0.125/kind; a phase draws only its own kinds
    # (compile: 0.125, dispatch: 0.25, decode: 0.125) -> ~1/6 a visit
    frac = len(fired) / len(seq_a)
    assert 0.08 < frac < 0.28

    # a different seed reshuffles the schedule
    c = res.FaultSpec.parse("mixed=0.5,seed=12").injector()
    assert [c.check(s, p) for s, p in visits] != seq_a

    # rate edges: 0 never fires, 1 always fires the phase's kind
    z = res.FaultSpec(seed=5).injector()
    assert all(z.check("s", "dispatch") is None for _ in range(50))
    one = res.FaultSpec(compile_error=1.0, seed=5).injector()
    assert all(one.check("s", "compile") == "compile_error"
               for _ in range(50))

    # a retry (same site, next attempt) sees a FRESH draw
    spec = res.FaultSpec(timeout=0.5, seed=0)
    inj = spec.injector()
    seq = [inj.check("retry-site", "dispatch") for _ in range(40)]
    assert "timeout" in seq and None in seq


def test_injector_classification_helpers():
    assert res.classify_fault(res.InjectedFault("timeout", "s")) == \
        "timeout"
    assert res.classify_fault(TimeoutError("t")) == "timeout"
    assert res.classify_fault(RuntimeError("x")) == "runtime_error"


# ---------------------------------------------------------------------------
# run_group: retry, quality gate, and every degradation level
# ---------------------------------------------------------------------------


def test_zero_fault_path_exact_accounting():
    disp = FakeDispatcher()
    outs, st = _run(disp, _plan(packed=True))
    assert len(outs) == 4
    for o in outs:
        assert o.med == [1000.0, 1000.0]
        t = o.timing
        assert t["timing_source"] == "device"
        assert t["dispatches"] == 1 and t["remeasures"] == 0
        assert t["attempts"] == 1 and t["degraded_from"] is None
        assert t["fault_kind"] is None and t["noisy"] is False
    assert st.resilience_clean()
    assert st.host_sync_dispatches == 1       # one packed dispatch


def test_retry_recovers_without_degradation():
    disp = FakeDispatcher(behaviors=["timeout", "ok"])
    outs, st = _run(disp, _plan(packed=True))
    assert st.retried_dispatches == 1 and st.degraded_ladders == 0
    for o in outs:
        assert o.timing["attempts"] == 2
        assert o.timing["degraded_from"] is None
        assert o.timing["fault_kind"] == "timeout"   # noted, recovered
        assert o.med == [1000.0, 1000.0]


def test_corrupt_timing_detected_and_retried():
    disp = FakeDispatcher(behaviors=["corrupt", "ok"])
    outs, st = _run(disp, _plan(packed=True))
    assert st.retried_dispatches == 1
    for o in outs:
        assert o.timing["fault_kind"] == "corrupt_timing"
        assert all(m > 0 for m in o.med)


def test_packed_degrades_to_unpacked():
    # packed dispatch fails once; the unpacked re-plan succeeds
    disp = FakeDispatcher(behaviors=["runtime_error", "ok"])
    pol = res.RetryPolicy(retries=0, backoff_s=0, sleep=NOOP)
    outs, st = _run(disp, _plan(packed=True), policy=pol)
    assert [d.packed for d in disp.planned_calls] == [True, False]
    assert st.degraded_ladders == 4
    for o in outs:
        assert o.timing["timing_source"] == "device"
        assert o.timing["degraded_from"] == "packed"
        assert o.timing["attempts"] == 2
        assert o.med == [1000.0, 1000.0]


def test_batched_split_isolates_failure_to_one_ladder():
    # the 4-ladder group dispatch fails; after the split, ladder 'c'
    # keeps failing and lands on the host-timed per-rung floor while
    # a, b, d recover as single fused ladders
    disp = FakeDispatcher(behaviors=[
        "runtime_error",                      # group dispatch
        "ok", "ok",                           # singles a, b
        "runtime_error",                      # single c -> rung floor
        "ok", "ok",                           # c rung 0, rung 1
        "ok"])                                # single d
    pol = res.RetryPolicy(retries=0, backoff_s=0, sleep=NOOP)
    outs, st = _run(disp, _plan(packed=False), policy=pol)
    by_name = {o.entry.spec.name: o for o in outs}
    for n in ("a", "b", "d"):
        t = by_name[n].timing
        assert t["timing_source"] == "device"
        assert t["degraded_from"] == "batched" and t["group_size"] == 1
    c = by_name["c"].timing
    assert c["timing_source"] == "host"
    assert c["degraded_from"] == "batched"
    assert c["fault_kind"] == "runtime_error"
    assert c["attempts"] == 4          # group + single + 2 rungs
    assert by_name["c"].med == [2000.0, 2000.0]
    assert st.degraded_ladders == 4 and st.modeled_floor_ladders == 0


def test_full_ladder_to_modeled_floor():
    # every dispatch AND every rung faults: packed -> unpacked ->
    # split -> per-rung -> modeled, isolating nothing but losing
    # nothing either (one outcome per entry, med=None)
    disp = FakeDispatcher(default="timeout")
    outs, st = _run(disp, _plan(packed=True))
    assert len(outs) == 4
    for o in outs:
        assert o.med == [None, None]
        assert o.fenced is False
        assert o.timing["timing_source"] == "none"
        assert o.timing["degraded_from"] == "packed"
        assert o.timing["fault_kind"] == "timeout"
    assert st.modeled_floor_ladders == 4
    assert st.degraded_ladders == 4
    assert not st.resilience_clean()


def test_rung_floor_partial_rung_loss():
    # single-ladder plan degraded to rungs: rung 0 measures, rung 1
    # exhausts retries and is modeled; the ladder keeps rung 0
    disp = FakeDispatcher(behaviors=[
        "runtime_error", "runtime_error",     # fused ladder, retry
        "ok",                                 # rung 0
        "timeout", "timeout"])                # rung 1, retry -> None
    outs, st = _run(disp, _plan(names=("solo",), packed=False))
    (o,) = outs
    assert o.med == [2000.0, None]
    assert o.timing["timing_source"] == "host"
    assert o.timing["degraded_from"] == "ladder"
    assert st.modeled_floor_ladders == 0      # something still measured
    assert st.degraded_ladders == 1


def test_degrade_disabled_goes_straight_to_floor():
    disp = FakeDispatcher(default="timeout")
    pol = res.RetryPolicy(retries=0, degrade=False, backoff_s=0,
                          sleep=NOOP)
    outs, st = _run(disp, _plan(packed=True), policy=pol)
    assert all(o.med == [None, None] for o in outs)
    assert len(disp.planned_calls) == 1       # no ladder walked
    assert st.modeled_floor_ladders == 4 and st.degraded_ladders == 0


def test_modeled_floor_disabled_raises_group_error():
    disp = FakeDispatcher(default="timeout")
    pol = res.RetryPolicy(retries=0, degrade=False, modeled_floor=False,
                          backoff_s=0, sleep=NOOP)
    with pytest.raises(res.GroupExecutionError):
        _run(disp, _plan(packed=True), policy=pol)


def test_backoff_is_capped_exponential():
    slept = []
    pol = res.RetryPolicy(retries=4, backoff_s=0.05, backoff_cap_s=0.15,
                          sleep=slept.append)
    disp = FakeDispatcher(behaviors=["timeout"] * 4 + ["ok"])
    _run(disp, _plan(names=("solo",)), policy=pol)
    assert slept == [0.05, 0.1, 0.15, 0.15]   # doubled, then capped


def test_non_retryable_carries_group_context():
    disp = FakeDispatcher(behaviors=[ValueError("bad roles table")])
    with pytest.raises(res.GroupExecutionError) as ei:
        _run(disp, _plan(packed=True))
    err = ei.value
    msg = str(err)
    for name in ("a", "b", "c", "d"):
        assert f"'{name}'" in msg             # every member spec named
    assert "hbm:r" in msg and str(BUF) in msg
    assert isinstance(err.cause, ValueError)
    assert err.context.startswith("dispatch group")
    assert isinstance(err.__cause__, ValueError)
    assert len(disp.planned_calls) == 1       # no retry, no degradation


def test_quality_gate_remeasures_and_keeps_calmer_set():
    gate = res.QualityGate(rel_spread=2.0, remeasure=2, min_spread_ns=1.0)
    disp = FakeDispatcher(behaviors=[("noisy", 5000.0), "ok"])
    outs, st = _run(disp, _plan(packed=True), gate=gate)
    assert st.noisy_remeasures == 1 and st.noisy_rungs == 0
    # logical counters stay stable; the honest cost is host syncs
    assert st.measure_dispatches == 1 and st.host_sync_dispatches == 2
    for o in outs:
        t = o.timing
        assert t["noisy"] is False and t["remeasures"] == 1
        assert t["dispatches"] == 2
        assert max(t["rung_time_spread_ns"]) <= 10


def test_quality_gate_flags_stubbornly_noisy_rungs():
    gate = res.QualityGate(rel_spread=2.0, remeasure=2, min_spread_ns=1.0)
    disp = FakeDispatcher(default=("noisy", 5000.0))
    outs, st = _run(disp, _plan(packed=True), gate=gate)
    assert st.noisy_remeasures == 2           # budget spent
    assert st.noisy_rungs == 8                # 4 ladders x 2 rungs
    for o in outs:
        assert o.timing["noisy"] is True
        assert o.timing["noisy_rungs"] == [0, 1]
        assert o.med == [1000.0, 1000.0]      # still persisted, flagged


def test_quality_gate_off_never_remeasures():
    disp = FakeDispatcher(default=("noisy", 1e9))
    outs, st = _run(disp, _plan(packed=True), gate=None)
    assert st.noisy_remeasures == 0 and st.noisy_rungs == 0
    assert all(o.timing["noisy"] is False for o in outs)


# ---------------------------------------------------------------------------
# Atomic CurveDB.save
# ---------------------------------------------------------------------------


def _tiny_db():
    from repro.core.characterize import CurveDB, CurvePoint, Surface
    db = CurveDB(platform="test")
    key = CurveDB.key("hbm", "r", "hbm", "w")
    db.surfaces[key] = Surface.from_points(
        [CurvePoint(n_stressors=0, bandwidth_gbps=10.0, latency_ns=100.0),
         CurvePoint(n_stressors=1, bandwidth_gbps=5.0, latency_ns=200.0)])
    return db


def test_curvedb_save_is_atomic(tmp_path, monkeypatch):
    from repro.core import characterize
    db = _tiny_db()
    path = str(tmp_path / "curves.json")
    db.save(path)
    before = open(path).read()

    # a fault mid-serialisation must leave the old file byte-intact
    def boom(*a, **kw):
        raise res.InjectedFault("runtime_error", "curvedb-save")
    monkeypatch.setattr(characterize.json, "dump", boom)
    with pytest.raises(res.InjectedFault):
        db.save(path)
    assert open(path).read() == before
    # ...and no temp litter survives the failed attempt
    assert [p for p in os.listdir(tmp_path)
            if p.startswith(".curvedb-")] == []
    monkeypatch.undo()
    rt = characterize.CurveDB.load(path)
    assert set(rt.surfaces) == set(db.surfaces)


# ---------------------------------------------------------------------------
# SweepJournal: crash-resume at the unit level
# ---------------------------------------------------------------------------


def _exec(plan, disp, journal, stats=None):
    stats = stats or DispatchStats()
    maps = exec_journal.execute_plan(
        disp, plan, n_eng=plan.n_engines, activity="jnp", mode="batched",
        stats=stats, policy=res.RetryPolicy(backoff_s=0, sleep=NOOP),
        gate=None, journal=journal)
    return maps, stats


def test_journal_resume_is_value_equal_and_free(tmp_path):
    plan = _plan(names=("a", "b"), packed=False, buf=BUF)
    jpath = str(tmp_path / "sweep.journal")
    maps1, st1 = _exec(plan, FakeDispatcher(), jpath)
    assert st1.resumed_ladders == 0

    # resume from the complete journal: zero dispatches, equal values
    disp2 = FakeDispatcher(default=RuntimeError("must not dispatch"))
    maps2, st2 = _exec(plan, disp2, jpath)
    assert disp2.planned_calls == []
    assert st2.resumed_ladders == 2
    assert st2.host_sync_dispatches == 0
    executed1, fenced1, timing1 = maps1
    executed2, fenced2, timing2 = maps2
    assert fenced1 == fenced2 and timing1 == timing2
    assert set(executed1) == set(executed2)
    for k in executed1:
        assert executed1[k] == executed2[k]   # exact float round-trip


def test_journal_rejects_foreign_fingerprint(tmp_path):
    jpath = str(tmp_path / "sweep.journal")
    _exec(_plan(names=("a", "b")), FakeDispatcher(), jpath)
    with pytest.raises(ValueError, match="different sweep"):
        _exec(_plan(names=("a", "zzz")), FakeDispatcher(), jpath)


def test_killed_sweep_resumes_skipping_finished_groups(tmp_path):
    # distinct buffers -> distinct signatures -> three groups
    pm = PoolManager()
    triples = [(s, s.observer, s.observer.buffers[0])
               for s in (_spec("a", BUF), _spec("b", 2 * BUF),
                         _spec("c", 4 * BUF))]
    plan = exec_plan.build_plan(triples, 8, pm, pm.platform.n_engines)
    assert len(plan.dispatches) == 3
    jpath = str(tmp_path / "sweep.journal")

    # the sweep dies mid-flight after journaling the first group
    disp = FakeDispatcher(behaviors=["ok", KeyboardInterrupt()])
    with pytest.raises(KeyboardInterrupt):
        _exec(plan, disp, jpath)
    assert len(disp.planned_calls) == 2       # group 2 died un-journaled

    # resume: group 1 restores, groups 2+3 execute
    disp2 = FakeDispatcher()
    maps2, st2 = _exec(plan, disp2, jpath)
    assert st2.resumed_ladders == 1
    assert len(disp2.planned_calls) == 2
    executed2, fenced2, _t = maps2
    assert len(fenced2) == 3                  # every ladder present
    assert {i for i, _k in executed2} == {0, 1, 2}

    # third run resumes everything — the journal is now complete
    disp3 = FakeDispatcher(default=RuntimeError("no"))
    maps3, st3 = _exec(plan, disp3, jpath)
    assert st3.resumed_ladders == 3 and disp3.planned_calls == []
    assert maps3[0] == maps2[0] and maps3[2] == maps2[2]


def test_journal_skips_torn_tail_line(tmp_path):
    plan = _plan(names=("a", "b"))
    jpath = str(tmp_path / "sweep.journal")
    _exec(plan, FakeDispatcher(), jpath)
    with open(jpath, "a") as f:
        f.write('{"entries": [{"key": "torn')  # crash mid-append
    disp = FakeDispatcher(default=RuntimeError("no"))
    _maps, st = _exec(plan, disp, jpath)
    assert st.resumed_ladders == 2            # intact prefix restored
    assert disp.planned_calls == []


def test_journal_records_modeled_floor_outcomes(tmp_path):
    # even fully-degraded groups journal (med=None round-trips), so a
    # resume does not retry known-dead work
    plan = _plan(names=("solo",))
    jpath = str(tmp_path / "sweep.journal")
    _maps, st1 = _exec(plan, FakeDispatcher(default="timeout"), jpath)
    assert st1.modeled_floor_ladders == 1
    disp2 = FakeDispatcher(default=RuntimeError("no"))
    maps2, st2 = _exec(plan, disp2, jpath)
    assert st2.resumed_ladders == 1 and disp2.planned_calls == []
    _executed, _fenced, timing = maps2
    assert timing[0]["timing_source"] == "none"
    assert timing[0]["fault_kind"] == "timeout"


# ---------------------------------------------------------------------------
# End-to-end on a real mesh (forced-device subprocesses)
# ---------------------------------------------------------------------------


def run_forced(body: str, n_devices: int = N_DEV, timeout: int = 480,
               extra_env=None) -> str:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count={n_devices}"
        {textwrap.indent(textwrap.dedent(body), '        ').strip()}
        print("SUBPROC_OK")
    """)
    env = dict(os.environ, PYTHONPATH=SRC, **(extra_env or {}))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=timeout, env=env)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-3000:]}"
    assert "SUBPROC_OK" in r.stdout
    return r.stdout


def test_chaos_sweep_completes_with_every_curve():
    """A mixed-fault sweep on the real mesh finishes with EVERY curve
    present — faults retry or degrade, never silently drop points —
    and the resilience trail lands in provenance + CurveDB meta."""
    out = run_forced("""
    import json
    from repro.core.coordinator import CoreCoordinator
    from repro.core.characterize import curvedb_from_result
    from repro.core.scenarios import (ObserverSpec, ScenarioSpec,
                                      StressorSpec)

    BUF = 64 << 10
    specs = [ScenarioSpec(f"chaos-{o}-{s}-{p}",
                          ObserverSpec(o, "hbm", (BUF,)),
                          (StressorSpec(s, p, BUF),),
                          iters=3, max_stressors=1)
             for o in ("r", "w") for s in ("r", "w")
             for p in ("hbm", "host")]
    coord = CoreCoordinator(backend="spmd",
                            faults="mixed=0.35,seed=7", quality="off")
    res = coord.run_matrix(specs)
    assert len(res.runs) == len(specs), "a faulted curve went missing"
    for run in res.runs:
        ex = run.execution
        assert ex["attempts"] >= 1
        assert "degraded_from" in ex and "fault_kind" in ex
        assert all(s.modeled_bw_gbps > 0 for s in run.scenarios)
    db = curvedb_from_result(res, coord.platform.name, backend="spmd")
    meta = db.meta
    print("FAULTS", json.dumps({
        k: meta[k] for k in ("faults_injected", "retried_dispatches",
                             "degraded_ladders", "modeled_floor_ladders")}))
    assert meta["faults_injected"] > 0, "chaos seed injected nothing"
    assert len(db.surfaces) > 0
    """)
    faults = json.loads(out.split("FAULTS ", 1)[1].splitlines()[0])
    assert faults["faults_injected"] > 0


def test_sweep_journal_end_to_end_resume():
    """Real-mesh crash/resume: a sweep that dies mid-flight resumes
    from its journal, re-executing only unfinished groups, and the
    journaled prefix restores value-identically; a second resume of
    the complete journal executes nothing and reproduces the CurveDB
    byte-for-byte."""
    run_forced("""
    import json, os, tempfile
    from repro.core.coordinator import CoreCoordinator
    from repro.core.characterize import characterize_matrix
    from repro.core.exec import journal as exec_journal
    from repro.core.scenarios import (ObserverSpec, ScenarioSpec,
                                      StressorSpec)

    BUF = 64 << 10
    specs = [ScenarioSpec(f"jrn-{i}", ObserverSpec(o, "hbm", (BUF,)),
                          (StressorSpec("w", p, BUF),),
                          iters=3, max_stressors=1)
             for i, (o, p) in enumerate(
                 [("r", "hbm"), ("w", "hbm"), ("r", "host")])]
    tmp = tempfile.mkdtemp()
    jpath = os.path.join(tmp, "sweep.journal")

    # crash after the first journaled group
    real_record = exec_journal.SweepJournal.record
    calls = {"n": 0}
    def dying_record(self, planned, outcomes):
        real_record(self, planned, outcomes)
        calls["n"] += 1
        if calls["n"] >= 1:
            raise KeyboardInterrupt("simulated mid-sweep crash")
    exec_journal.SweepJournal.record = dying_record
    coord = CoreCoordinator(backend="spmd", faults=False, quality="off")
    try:
        characterize_matrix(coord, specs, journal=jpath)
        raise SystemExit("sweep should have crashed")
    except KeyboardInterrupt:
        pass
    finally:
        exec_journal.SweepJournal.record = real_record
    with open(jpath) as f:
        prefix = [json.loads(l) for l in f.read().splitlines()[1:]]
    assert len(prefix) == 1

    # resume: finishes the sweep, restoring the journaled group
    # (which may stack several same-signature ladders)
    db1 = characterize_matrix(coord, specs, journal=jpath)
    assert db1.meta["resumed_ladders"] == len(prefix[0]["entries"])
    assert len(db1.surfaces) >= 1

    # a complete journal makes the next run pure restore, value-equal
    db2 = characterize_matrix(coord, specs, journal=jpath)
    assert db2.meta["resumed_ladders"] == len(specs)
    assert db2.meta["measure_dispatches"] == 0
    def doc(db):
        d = {k.to_string(): s.to_dict()
             for k, s in db.surfaces.items()}
        return json.dumps(d, sort_keys=True)
    assert doc(db1) == doc(db2)
    """)


def test_env_fault_spec_reaches_dispatcher():
    """REPRO_FAULT_SPEC wires chaos into a default-constructed
    coordinator (the CI chaos leg's contract), and faults=False
    overrides it for hermetic runs."""
    run_forced("""
    from repro.core.coordinator import CoreCoordinator
    from repro.core.scenarios import (ObserverSpec, ScenarioSpec,
                                      StressorSpec)
    c = CoreCoordinator(backend="spmd")
    assert c.fault_spec is not None and c.fault_spec.seed == 7
    assert c._dispatcher.faults is not None
    off = CoreCoordinator(backend="spmd", faults=False)
    assert off.fault_spec is None and off._dispatcher.faults is None

    BUF = 64 << 10
    spec = ScenarioSpec("envchaos", ObserverSpec("r", "hbm", (BUF,)),
                        (StressorSpec("w", "hbm", BUF),), iters=3,
                        max_stressors=1)
    res = c.run_matrix([spec])
    assert len(res.runs) == 1       # chaos on, curve still complete
    """, extra_env={"REPRO_FAULT_SPEC": "mixed=0.3,seed=7"})
