"""The SPMD sandwich, enforced structurally.

The paper's invariant (1) — measurement starts only after every engine
passed the start barrier — used to be advisory in
``build_scenario_program``: the ``ready`` psum had no dataflow edge into
the measured activity, so JAX folded it away at trace time and XLA was
free to begin the observed work before the stressors were running.
These tests pin the fix down by inspecting the traced jaxpr for the
dependency edge (they run on the single-device main process; the mesh
size does not change the program structure).  The multi-device
*execution* of the spmd backend is covered in test_distribution.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.coordinator import (_effective_duty, _spmd_branch_fn,
                                    build_ladder_program,
                                    build_rung_program,
                                    build_scenario_program,
                                    measured_region_is_fenced)

ROWS = 16


def _operands(n_eng: int):
    xf = np.ones((n_eng, ROWS, 128), np.float32)
    xi = np.zeros((n_eng, ROWS, 128), np.int32)
    return xf, xi


# ---------------------------------------------------------------------------
# The checker itself: it must reject an unfenced program
# ---------------------------------------------------------------------------


def test_checker_rejects_advisory_barrier():
    """A psum nothing depends on (the historical bug) is NOT a fence."""
    mesh = compat.make_mesh_from_devices(jax.devices()[:1], ("engine",))

    def buggy(x):
        x = x[0]
        ready = jax.lax.psum(x[0], "engine")   # no edge into `out`
        out = x * 2.0
        return out[None], ready

    f = compat.shard_map(buggy, mesh=mesh, in_specs=(P("engine"),),
                         out_specs=(P("engine"), P()))
    assert not measured_region_is_fenced(f, np.ones((1, 8), np.float32))


def test_checker_requires_a_shard_map():
    assert not measured_region_is_fenced(lambda x: x * 2,
                                         jnp.ones((4,)))


# ---------------------------------------------------------------------------
# The fixed programs carry the dependency edge
# ---------------------------------------------------------------------------


def test_rung_program_measured_region_is_fenced():
    fns = [_spmd_branch_fn("r", None, ROWS, 2),
           _spmd_branch_fn("w", None, ROWS, 2)]
    _mesh, f = build_rung_program(1, fns, [0])
    assert measured_region_is_fenced(f, *_operands(1))


def test_scenario_program_measured_region_is_fenced():
    """Regression for the build_scenario_program barrier-ordering bug:
    `out` must have a data dependency on the start-barrier psum."""
    _mesh, f = build_scenario_program(
        1, 0,
        main_fn=lambda m: jnp.sum(m, axis=-1, keepdims=True),
        stress_fn=lambda s: jnp.sum(s * 2, axis=-1, keepdims=True),
        idle_fn=lambda s: jnp.sum(s * 0, axis=-1, keepdims=True))
    assert measured_region_is_fenced(f, np.ones((1, 8), np.float32),
                                     np.ones((1, 8), np.float32))


def test_scenario_program_executes():
    """The fixed program still runs and produces per-engine outputs
    (single-device mesh: engine 0 = observed, no stressors)."""
    _mesh, f = build_scenario_program(
        1, 0,
        main_fn=lambda m: m * 3.0,
        stress_fn=lambda s: s * 2.0,
        idle_fn=lambda s: s * 0.0)
    x = np.ones((1, 8), np.float32)
    out, barrier = f(x, x)
    np.testing.assert_allclose(np.asarray(out), 3.0 * x)
    assert np.asarray(barrier).shape == ()


# ---------------------------------------------------------------------------
# Every spmd branch traces and runs (single engine, every strategy kind)
# ---------------------------------------------------------------------------


# ---------------------------------------------------------------------------
# The fused whole-ladder program: scanned sandwiches + in-dispatch clocks
# ---------------------------------------------------------------------------


def test_ladder_program_measured_region_is_fenced():
    """Every scanned rung of the fused ladder carries its own verified
    psum sandwich — the checker recurses into the scan body and
    requires the step carry to consume the stop barrier."""
    fns = [_spmd_branch_fn("r", None, ROWS, 2),
           _spmd_branch_fn("w", None, ROWS, 2)]
    _mesh, f = build_ladder_program(1, fns, [[0], [1]], samples=2)
    assert measured_region_is_fenced(f, *_operands(1))


def test_ladder_program_executes_with_monotone_clock():
    """The fused ladder runs end to end and its in-dispatch stamp pairs
    bracket every sample: stop strictly after start (the value-threaded
    device_clock fills must serialize), and consecutive samples must
    not overlap."""
    if compat.device_clock_source() == "none":
        pytest.skip("no in-dispatch timestamp source on this install")
    fns = [_spmd_branch_fn("r", None, ROWS, 4),
           _spmd_branch_fn("w", None, ROWS, 4)]
    K, S = 3, 2
    _mesh, f = build_ladder_program(1, fns, [[0], [1], [0]], samples=S)
    xf, xi = _operands(1)
    outs, t0s, t1s, xf2, xi2 = f(xf, xi)
    assert np.isfinite(np.asarray(outs)).all()
    # operands pass through unchanged (the cache rebinds them)
    np.testing.assert_array_equal(np.asarray(xf2), xf)
    np.testing.assert_array_equal(np.asarray(xi2), xi)
    t0 = np.asarray(t0s[0]).astype(np.int64)
    t1 = np.asarray(t1s[0]).astype(np.int64)
    start = t0[:, 0] * 10**9 + t0[:, 1]
    stop = t1[:, 0] * 10**9 + t1[:, 1]
    assert t0.shape == (K * S, 2)
    assert (stop > start).all()                 # every sample bracketed
    assert (start[1:] >= stop[:-1]).all()       # samples serialized


def test_stacked_ladder_program_is_fenced_and_times_per_ladder():
    """The sweep-batched STACKED program — the fused ladder's scan
    table tiled with a leading scenario axis (G ladders x K rungs) —
    still verifies structurally (one scanned body serves every rung of
    every stacked ladder), and its stamp pairs decode per (ladder,
    rung, sample) with every sample bracketed and the whole stack
    serialized (ladder g+1 cannot open before ladder g retired:
    invariant 4 across the group)."""
    G, K, S = 3, 2, 2
    # rung 0 is cheap, rung 1 deliberately orders of magnitude
    # heavier: the (G, K, S) decode is only correct if the flat scan
    # order really is ladder-major, which the cost asymmetry makes
    # observable above clock/dispatch noise
    fns = [_spmd_branch_fn("r", None, ROWS, 2),
           _spmd_branch_fn("r", None, ROWS, 50_000)]
    table = np.tile(np.asarray([[0], [1]], np.int32), (G, 1))
    _mesh, f = build_ladder_program(1, fns, table, samples=S)
    assert measured_region_is_fenced(f, *_operands(1))
    if compat.device_clock_source() == "none":
        return                       # structure verified; no stamps
    xf, xi = _operands(1)
    outs, t0s, t1s, xf2, xi2 = f(xf, xi)
    assert np.isfinite(np.asarray(outs)).all()
    t0 = np.asarray(t0s)[0].astype(np.int64)
    t1 = np.asarray(t1s)[0].astype(np.int64)
    assert t0.shape == (G * K * S, 2)
    start = t0[:, 0] * 10**9 + t0[:, 1]
    stop = t1[:, 0] * 10**9 + t1[:, 1]
    assert (stop > start).all()                 # every sample bracketed
    assert (start[1:] >= stop[:-1]).all()       # stack fully serialized
    # ladder-major order, for real: decoded as (G, K, S) like the
    # coordinator does, EVERY stacked ladder must show its heavy rung
    # heavier than its cheap rung (a rung-major flat order — e.g.
    # np.repeat instead of np.tile in the builder — interleaves the
    # costs and breaks this for G != K)
    d = (stop - start).reshape(G, K, S)
    med = np.median(d, axis=2)                  # (G, K)
    assert (med[:, 1] > med[:, 0]).all(), med


def test_stacked_checker_rejects_unfenced_stacked_scan():
    """Negative: a stacked multi-ladder scan whose steps carry no psum
    sandwich (or only an advisory one) must NOT verify — batching
    ladders must not dilute the fence requirement."""
    mesh = compat.make_mesh_from_devices(jax.devices()[:1], ("engine",))
    G, K = 3, 2

    def advisory_stack(xf, xi):
        xf, xi = xf[0], xi[0]

        def step(carry, _):
            ready = jax.lax.psum(xf[0, 0], "engine")   # nothing uses it
            out = jnp.sum(xf) + carry
            return carry + 1.0, (out, ready)

        _c, (outs, _r) = jax.lax.scan(step, jnp.float32(0.0),
                                      jnp.arange(G * K))
        return outs[None]

    f = compat.shard_map(advisory_stack, mesh=mesh,
                         in_specs=(P("engine"), P("engine")),
                         out_specs=P("engine", None))
    assert not measured_region_is_fenced(f, *_operands(1))


def test_fence_check_accepts_pretraced_jaxpr():
    """The single-trace AOT pipeline hands the checker an existing
    ClosedJaxpr (compat.aot_trace) instead of paying a second
    make_jaxpr trace; both spellings must agree."""
    fns = [_spmd_branch_fn("r", None, ROWS, 2)]
    _mesh, f = build_rung_program(1, fns, [0])
    xf, xi = _operands(1)
    traced = compat.aot_trace(f, xf, xi)
    if traced is None:
        pytest.skip("no AOT Traced stage on this install")
    assert measured_region_is_fenced(f, jaxpr=traced.jaxpr)
    assert measured_region_is_fenced(f, xf, xi) \
        == measured_region_is_fenced(f, jaxpr=traced.jaxpr)


def test_ladder_checker_rejects_unfenced_scan():
    """A scanned ladder whose steps carry no psum sandwich (or only an
    advisory one nothing depends on) must NOT verify."""
    from repro.core.coordinator import _shard_map_bodies

    mesh = compat.make_mesh_from_devices(jax.devices()[:1], ("engine",))

    def no_fence(xf, xi):
        xf, xi = xf[0], xi[0]

        def step(carry, _):
            out = jnp.sum(xf) + carry
            return carry + 1.0, out

        _c, outs = jax.lax.scan(step, jnp.float32(0.0), jnp.arange(3))
        return outs[None]

    f = compat.shard_map(no_fence, mesh=mesh,
                         in_specs=(P("engine"), P("engine")),
                         out_specs=P("engine", None))
    assert not measured_region_is_fenced(f, *_operands(1))

    def advisory(xf, xi):
        xf, xi = xf[0], xi[0]

        def step(carry, _):
            ready = jax.lax.psum(xf[0, 0], "engine")   # nothing uses it
            out = jnp.sum(xf) + carry
            return carry + 1.0, (out, ready)

        _c, (outs, _r) = jax.lax.scan(step, jnp.float32(0.0),
                                      jnp.arange(3))
        return outs[None]

    f2 = compat.shard_map(advisory, mesh=mesh,
                          in_specs=(P("engine"), P("engine")),
                          out_specs=P("engine", None))
    assert not measured_region_is_fenced(f2, *_operands(1))


def test_effective_duty_guard_unified():
    """All three work-balancing call sites and the n_active stamping
    share one duty helper: absent shapes and degenerate 0/None duties
    count as always-on, real duty cycles pass through."""
    from repro.core.scenarios import TrafficShape

    assert _effective_duty(None) == 1.0
    assert _effective_duty(TrafficShape.steady()) == 1.0
    assert _effective_duty(TrafficShape.burst(0.5)) == 0.5

    class DuckShape:        # a deserialized/foreign shape with 0 duty
        duty_cycle = 0.0

    assert _effective_duty(DuckShape()) == 1.0


@pytest.mark.parametrize("strategy", ["r", "w", "c", "b", "l", "t", "i"])
def test_spmd_branch_fns_execute(strategy):
    from repro.core.scenarios import TrafficShape
    shape = {"b": TrafficShape.mixed(1, 1),
             "t": TrafficShape.strided(4)}.get(strategy)
    fns = [_spmd_branch_fn(strategy, shape, ROWS, 2)]
    _mesh, f = build_rung_program(1, fns, [0])
    xf, xi = _operands(1)
    xi[0, :ROWS, 0] = np.roll(np.arange(ROWS), 1)   # a valid cycle
    out, barrier = f(xf, xi)
    assert np.isfinite(np.asarray(out)).all()
    assert measured_region_is_fenced(f, xf, xi)


# ---------------------------------------------------------------------------
# Width-packing: per-subset fence isolation (needs a >=4-engine mesh,
# so this one test runs in a forced-host-device subprocess)
# ---------------------------------------------------------------------------


def test_packed_fence_subset_isolation():
    """A packed program is fenced only if EVERY collective in the
    measured region respects the declared engine subsets: its own
    grouped-psum sandwich passes; a cross-subset psum group, a
    declaration that does not match the traced grouping, a global-psum
    program claimed as packed, and a post-barrier cross-subset
    ppermute leak must all be rejected."""
    import os
    import subprocess
    import sys
    import textwrap

    src = os.path.join(os.path.dirname(__file__), "..", "src")
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro import compat
        from repro.core.exec.fence import measured_region_is_fenced
        from repro.core.exec.program import (build_ladder_program,
                                             spmd_branch_fn)

        fns = [spmd_branch_fn("r", None, 4, 2),
               spmd_branch_fn("i", None, 1, 2)]
        table = [[0, 1, 0, 1]]     # two width-2 ladders side by side
        subsets = ((0, 1), (2, 3))
        xf = np.ones((4, 4, 16), np.float32)
        xi = np.zeros((4, 4, 16), np.int32)

        _m, fn = build_ladder_program(4, fns, table, samples=1,
                                      subsets=subsets)
        # the packed program's sandwich isolates its own subsets...
        assert measured_region_is_fenced(fn, xf, xi, subsets=subsets)
        # ...but is NOT a fence for any other partition of the mesh
        assert not measured_region_is_fenced(
            fn, xf, xi, subsets=((0, 2), (1, 3)))
        assert not measured_region_is_fenced(
            fn, xf, xi, subsets=((0, 1, 2, 3),))

        # a GLOBAL-psum program claimed as packed must be rejected
        # (each subset's barrier would wait on the other's engines);
        # the same program is a perfectly good unpacked fence
        _m2, fn2 = build_ladder_program(4, fns, table, samples=1,
                                        subsets=None)
        assert not measured_region_is_fenced(fn2, xf, xi,
                                             subsets=subsets)
        assert measured_region_is_fenced(fn2, xf, xi)

        # correct sandwich + a cross-subset ppermute INSIDE the
        # measured region: data leaks between packed ladders
        def leaky():
            m = compat.make_mesh_from_devices(jax.devices()[:4],
                                              ("engine",))
            def per_engine(xf, xi):
                xf = xf[0]
                token = compat.psum_grouped(xf[0, 0], "engine",
                                            subsets)
                xf, _t = compat.optimization_barrier(
                    (xf + token * 0, token))
                stolen = jax.lax.ppermute(
                    xf[0, 0], "engine",
                    perm=[(2, 0), (0, 2), (1, 3), (3, 1)])
                out = jnp.sum(xf) + stolen
                done = compat.psum_grouped(out, "engine", subsets)
                return (out + done * 0)[None]
            f = compat.shard_map(per_engine, mesh=m,
                                 in_specs=(P("engine"), P("engine")),
                                 out_specs=P("engine"),
                                 check_rep=False)
            return jax.jit(f)
        assert not measured_region_is_fenced(leaky(), xf, xi,
                                             subsets=subsets)
        print("PACKED_FENCE_OK")
    """)
    env = dict(os.environ, PYTHONPATH=src)
    r = subprocess.run([sys.executable, "-c", code],
                       capture_output=True, text=True, timeout=480,
                       env=env)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-3000:]}"
    assert "PACKED_FENCE_OK" in r.stdout
