"""The adversarial worst-case search and its planner transform.

Main-process tests exercise ``plan.probe_batch`` as pure data (no mesh
needed) and the search loop on the deterministic modeled path; the
multi-device execution — per-probe psum sandwiches in the stacked
dispatch, one host sync per probe batch, the full search loop — runs in
subprocesses with forced host devices (the main pytest process must
keep seeing one device; see conftest).
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.core.characterize import (AXIS_N, CurveDB, Surface, SurfaceAxis,
                                     SurfaceKey)
from repro.core.coordinator import CoreCoordinator
from repro.core.exec import plan as exec_plan
from repro.core.scenarios import (ObserverSpec, ScenarioSpec, StressorSpec,
                                  TrafficShape)
from repro.core.search import (DEFAULT_ARMS, SearchArm, SearchSpec,
                               WORSTCASE_QUALIFIER, worst_case_search)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
BUF = 64 << 10


@pytest.fixture(scope="module")
def coord():
    return CoreCoordinator(backend="simulate")


def _spec(strat="b", rw=0.5, ir=1.0, stride=16, iters=8, max_stressors=3):
    if strat == "t":
        shape = TrafficShape(kind="strided", stride=stride, duty_cycle=ir)
    else:
        shape = TrafficShape.traffic(rw, ir)
    return ScenarioSpec(
        name=f"probe.hbm.r|hbm.{strat}@{shape.tag()}",
        observer=ObserverSpec("r", "hbm", (BUF,)),
        stressors=(StressorSpec(strat, "hbm", BUF, shape),),
        iters=iters, max_stressors=max_stressors)


def _probes(specs_ks):
    return [(s, s.observer, BUF, k) for s, k in specs_ks]


# ---------------------------------------------------------------------------
# probe_batch: pure planning, no mesh required
# ---------------------------------------------------------------------------


def test_probe_batch_packs_slots_and_idle_fills_ragged_wave(coord):
    probes = _probes([(_spec(rw=rw), 1) for rw in
                      (0.0, 0.25, 0.5, 0.75, 1.0)])
    planned = exec_plan.probe_batch(probes, 8, coord.pools,
                                    coord.platform.n_engines)
    assert planned.probe and planned.packed
    assert (planned.subset_width, planned.n_subsets,
            planned.waves, planned.n_scen) == (2, 4, 2, 1)
    assert planned.group == 5
    # probe g runs in wave g // P on subset g % P
    assert planned.member_slot(0) == (0, 0)
    assert planned.member_slot(4) == (1, 0)
    # every row spans the full packed width; the ragged last wave
    # idle-fills its three spare slots
    assert all(len(row) == 8 for row in planned.rungs)
    last = planned.rungs[-1]
    assert all(r[0] == "i" for r in last[2:])
    assert last[0][0] != "i"        # probe 4's observer is live


def test_probe_batch_degenerate_slot_is_global(coord):
    # a probe needing the whole mesh forces the one-slot geometry:
    # one probe per wave behind a global psum sandwich
    probes = _probes([(_spec(), 3), (_spec(rw=1.0), 3)])
    planned = exec_plan.probe_batch(probes, 4, coord.pools,
                                    coord.platform.n_engines)
    assert planned.probe and not planned.packed
    assert (planned.subset_width, planned.n_subsets,
            planned.waves) == (4, 1, 2)
    assert planned.subsets() is None


def test_probe_batch_rejects_out_of_depth_rungs(coord):
    with pytest.raises(ValueError, match="ladder depth"):
        exec_plan.probe_batch(_probes([(_spec(max_stressors=2), 3)]),
                              8, coord.pools, coord.platform.n_engines)
    with pytest.raises(ValueError, match="at least one probe"):
        exec_plan.probe_batch([], 8, coord.pools,
                              coord.platform.n_engines)


def test_probe_batch_rejects_conflicting_chase_chains(coord):
    # probes 0 and 4 share slot 0 across waves: one operand cannot
    # seed both an 8-stride and a 64-stride chain
    probes = _probes([(_spec("t", stride=8), 1)] * 4
                     + [(_spec("t", stride=64), 1)])
    with pytest.raises(ValueError, match="conflicting chase chains"):
        exec_plan.probe_batch(probes, 8, coord.pools,
                              coord.platform.n_engines)
    # the same stride everywhere shares one chain legally
    ok = _probes([(_spec("t", stride=8), 1)] * 5)
    planned = exec_plan.probe_batch(ok, 8, coord.pools,
                                    coord.platform.n_engines)
    assert planned.probe and planned.waves == 2


def test_merge_probe_operand_roles_covers_every_engine():
    chase = ("l", None, 8, 4)
    stream = ("r", None, 16, 4)
    idle = ("i", None, 1, 4)
    rows = [(chase, stream), (stream, idle)]
    merged = exec_plan.merge_probe_operand_roles(rows)
    # engine 0 keeps its chain-seeding chase; engine 1 the widest
    # chain-free role; never-covered positions materialize as idle
    assert merged[0] == chase and merged[1] == stream
    merged = exec_plan.merge_probe_operand_roles([(idle, idle)])
    assert merged == [idle, idle]


def test_probe_batch_cache_key_and_packing_pass_through(coord):
    probes = _probes([(_spec(), 1), (_spec(rw=1.0), 1)])
    planned = exec_plan.probe_batch(probes, 8, coord.pools,
                                    coord.platform.n_engines)
    key = planned.cache_key("batched", 8, "jnp", 3)
    assert key[-2] is True          # the probe flag is part of identity
    # width-packing must not re-plan an already-packed probe batch
    plan = exec_plan.DispatchPlan(8, (planned,))
    packed = exec_plan.pack_engine_subsets(plan)
    assert packed.dispatches[0] is planned


# ---------------------------------------------------------------------------
# The search loop (modeled path: deterministic, single device)
# ---------------------------------------------------------------------------


def _envelope_bytes(result):
    return json.dumps(
        {k.to_string(): s.to_dict() for k, s in result.envelope.items()},
        sort_keys=True).encode()


def test_search_is_seed_deterministic(coord):
    spec = SearchSpec(iterations=5, batch=3, max_stressors=3, seed=11)
    a = worst_case_search(coord, spec, execute=False)
    b = worst_case_search(coord, spec, execute=False)
    assert _envelope_bytes(a) == _envelope_bytes(b)
    assert [t["candidates"] for t in a.trace] == \
        [t["candidates"] for t in b.trace]
    # a different seed explores a different trajectory
    c = worst_case_search(
        coord, SearchSpec(iterations=5, batch=3, max_stressors=3,
                          seed=12), execute=False)
    assert [t["candidates"] for t in a.trace] != \
        [t["candidates"] for t in c.trace]


def test_search_save_load_search_is_idempotent(coord, tmp_path):
    """The satellite property test: searching against a database, then
    against its save->load round-trip, yields byte-identical
    envelopes."""
    db = CurveDB(platform="test")
    db.surfaces[SurfaceKey("hbm", "r", "hbm", "b")] = Surface(
        axes=(SurfaceAxis(AXIS_N, (0.0, 1.0, 3.0)),),
        bandwidth_gbps=[90.0, 55.0, 30.0], latency_ns=[0.0, 0.0, 0.0])
    db.surfaces[SurfaceKey("hbm", "l", "hbm", "b")] = Surface(
        axes=(SurfaceAxis(AXIS_N, (0.0, 1.0, 3.0)),),
        bandwidth_gbps=[1.0, 1.0, 1.0], latency_ns=[120.0, 300.0, 700.0])
    spec = SearchSpec(iterations=4, batch=2, max_stressors=3, seed=5)
    first = worst_case_search(coord, spec, db, execute=False)
    path = os.path.join(tmp_path, "db.json")
    first.install(db)
    db.save(path)
    reloaded = CurveDB.load(path)
    # the installed envelope round-tripped under its qualified key
    key = SurfaceKey("hbm", "r", "hbm", "b",
                     qualifier=WORSTCASE_QUALIFIER)
    assert reloaded.surfaces[key].to_dict() == \
        db.surfaces[key].to_dict()
    second = worst_case_search(coord, spec, reloaded, execute=False)
    assert _envelope_bytes(first) == _envelope_bytes(second)


def test_search_envelope_is_worst_per_stressor_count(coord):
    spec = SearchSpec(iterations=6, batch=3, max_stressors=3, seed=2)
    r = worst_case_search(coord, spec, execute=False)
    for key, surf in r.envelope.items():
        assert key.qualifier == WORSTCASE_QUALIFIER
        assert surf.axes[0].name == AXIS_N
        prov = surf.provenance["worstcase"]
        assert prov["seed"] == 2 and len(prov["acquisition_trace"]) == 6
        for i, n in enumerate(surf.axes[0].values):
            same_n = [p for p in r.points
                      if p.obs_strat == key.obs_strat
                      and p.n_stressors == int(n)]
            if key.obs_strat == "l":
                assert surf.latency_ns[i] == pytest.approx(
                    max(p.latency_ns for p in same_n))
            else:
                assert surf.bandwidth_gbps[i] == pytest.approx(
                    min(p.bandwidth_gbps for p in same_n))
    # worst() agrees with the provenance record
    worst = r.worst("r")
    key = SurfaceKey("hbm", "r", "hbm", "b",
                     qualifier=WORSTCASE_QUALIFIER)
    assert r.envelope[key].provenance["worstcase"]["worst"] == \
        worst.to_dict()


def test_search_bandit_plays_every_arm_then_exploits(coord):
    spec = SearchSpec(iterations=len(DEFAULT_ARMS) + 2, batch=2,
                      max_stressors=3, seed=1)
    r = worst_case_search(coord, spec, execute=False)
    played = [t["arm"] for t in r.trace]
    assert sorted(played[:len(DEFAULT_ARMS)]) == \
        sorted(a.label() for a in DEFAULT_ARMS)
    # exploitation rounds replay known arms
    assert set(played[len(DEFAULT_ARMS):]) <= set(played)


def test_search_arm_shapes_honour_coordinates():
    assert SearchArm("t", 32).shape(0.5, 0.5) == TrafficShape(
        kind="strided", stride=32, duty_cycle=0.5)
    assert SearchArm("y").shape(0.5, 1.0) == TrafficShape.steady()
    assert SearchArm("y").shape(0.5, 0.5).duty_cycle == 0.5
    assert SearchArm("b").shape(0.75, 0.5) == TrafficShape.traffic(
        0.75, 0.5)
    assert SearchArm("b").read_fraction(0.75) == 0.75
    assert SearchArm("y").read_fraction(0.75) is None


# ---------------------------------------------------------------------------
# Multi-device execution (subprocess: forced host devices)
# ---------------------------------------------------------------------------


def _run_forced(code: str, sentinel: str, devices: int = 4):
    preamble = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = \\
            "--xla_force_host_platform_device_count={devices}"
    """)
    env = dict(os.environ, PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c",
                        preamble + textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=480,
                       env=env)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-3000:]}"
    assert sentinel in r.stdout


def test_probe_batch_dispatch_fences_every_probe():
    """The stacked probe dispatch is ONE host sync whose program
    carries a verified psum sandwich for every probe slot — and the
    packed fence is NOT valid for any other mesh partition."""
    _run_forced("""
        import jax
        from repro import compat
        from repro.core.coordinator import CoreCoordinator
        from repro.core.exec import plan as exec_plan
        from repro.core.exec.dispatch import DispatchStats
        from repro.core.exec.fence import measured_region_is_fenced
        from repro.core.exec.program import build_ladder_entry
        from repro.core.scenarios import (ObserverSpec, ScenarioSpec,
                                          StressorSpec, TrafficShape)

        # keep the raw traceable fn (an AOT executable cannot be
        # re-walked with different subsets below)
        compat.aot_compile = lambda *a, **k: None

        BUF = 64 << 10
        coord = CoreCoordinator(backend="spmd")

        def spec_for(rw):
            shape = TrafficShape.traffic(rw, 1.0)
            return ScenarioSpec(
                name=f"p@{shape.tag()}",
                observer=ObserverSpec("r", "hbm", (BUF,)),
                stressors=(StressorSpec("b", "hbm", BUF, shape),),
                iters=8, max_stressors=3)

        probes = [(s, s.observer, BUF, 1)
                  for s in (spec_for(0.0), spec_for(0.5),
                            spec_for(1.0))]
        planned = exec_plan.probe_batch(probes, 4, coord.pools,
                                        coord.platform.n_engines)
        assert planned.packed and planned.n_subsets == 2
        stats = DispatchStats()
        entry = build_ladder_entry(planned, 4, "jnp", 2, stats)
        assert entry.fenced
        # the packed probe program's sandwich is per-subset: the same
        # program is NOT a fence for a different partition
        assert not measured_region_is_fenced(
            entry.call, entry.xf, entry.xi, subsets=((0, 2), (1, 3)))
        med, _s, fenced, aot = coord._dispatcher.run_planned(
            planned, 4, "jnp", "batched", stats)
        assert fenced and not aot
        assert stats.host_sync_dispatches == 1
        assert med.shape == (3, 1) and (med > 0).all()
        print("PROBE_FENCE_OK")
    """, "PROBE_FENCE_OK")


def test_worst_case_search_one_dispatch_per_iteration():
    """Acceptance: each search iteration is exactly one host-sync
    batched dispatch, asserted via DispatchStats on a live mesh."""
    _run_forced("""
        import jax
        from repro.core.coordinator import CoreCoordinator
        from repro.core.search import SearchSpec, worst_case_search

        coord = CoreCoordinator(backend="spmd")
        spec = SearchSpec(iterations=3, batch=2, max_stressors=2,
                          seed=9, buffer_bytes=64 << 10, iters=8)
        r = worst_case_search(coord, spec)
        assert r.executed and r.fenced
        assert r.stats.host_sync_dispatches == \\
            spec.iterations + r.stats.noisy_remeasures
        assert sum(t["host_sync_dispatches"] for t in r.trace) == \\
            r.stats.host_sync_dispatches
        assert {k.obs_strat for k in r.envelope} == {"r", "l"}
        assert all(k.qualifier == "worstcase" for k in r.envelope)
        print("SEARCH_DISPATCH_OK")
    """, "SEARCH_DISPATCH_OK")
