"""Serving correctness: prefill+decode must equal the teacher-forced
forward pass — the strongest end-to-end invariant the KV-cache/ring-
buffer/SSM-state machinery has.  Covered for a full-attention arch, a
sliding-window arch (ring caches), an SSM arch and the hybrid.

The second half covers the ONLINE loop: the contention watchdog, the
resilient background probe sweep (flag-never-raise, journal resume),
and the guarded KV migration with hysteresis + rollback.
"""
import os
import subprocess
import sys
import textwrap
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ServeConfig, get_config
from repro.core.characterize import (AXIS_N, ONLINE_QUALIFIER, CurveDB,
                                     Surface, SurfaceAxis, SurfaceKey)
from repro.core.devicetree import detect_platform
from repro.core.placement import ContentionSpec, kv_cache_object
from repro.core.pools import PoolManager
from repro.launch.mesh import make_host_mesh
from repro.models import lm
from repro.parallel.sharding import make_rules
from repro.serve import engine as eng
from repro.serve import monitor as smon

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
N_DEV = max(2, int(os.environ.get("REPRO_SPMD_DEVICES", "8")))

PROMPT, NEW = 12, 4
ARCHS = ["qwen2-1.5b", "gemma3-1b", "mamba2-370m", "jamba-v0.1-52b"]


def _logits_all(cfg, params, tokens):
    h, _, _ = lm.forward(params, tokens, cfg=cfg, mode="train")
    return lm.unembed_logits(params, h, cfg)


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    mesh = make_host_mesh(1, 1)
    rules = make_rules(cfg, mesh, global_batch=2, shape_kind="decode")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))

    total = PROMPT + NEW
    tokens = (jnp.arange(2 * total, dtype=jnp.int32).reshape(2, total) * 7
              ) % cfg.vocab_size

    # oracle: teacher-forced full forward
    ref_logits = np.asarray(_logits_all(cfg, params, tokens))

    # prefill on the prompt, then decode the remaining positions
    prefill = eng.make_prefill_step(cfg, rules, max_len=total)
    decode = eng.make_decode_step(cfg, rules)
    caches, logits_p = prefill(params, tokens[:, :PROMPT], None)
    np.testing.assert_allclose(np.asarray(logits_p),
                               ref_logits[:, PROMPT - 1], atol=3e-3)
    for i in range(NEW - 1):
        pos = PROMPT + i
        caches, logits_d = decode(params, caches, tokens[:, pos:pos + 1],
                                  jnp.int32(pos), None)
        np.testing.assert_allclose(np.asarray(logits_d), ref_logits[:, pos],
                                   atol=3e-3, err_msg=f"{arch} pos={pos}")


def test_ring_cache_window_semantics():
    """Sliding-window ring cache: decoding far past the window must match
    a fresh forward over the same context."""
    cfg = get_config("gemma3-1b").reduced()       # window = 8
    assert cfg.sliding_window == 8
    mesh = make_host_mesh(1, 1)
    rules = make_rules(cfg, mesh, global_batch=1, shape_kind="decode")
    params = lm.init_params(cfg, jax.random.PRNGKey(2))

    total = 24                                    # 3x the window
    tokens = (jnp.arange(total, dtype=jnp.int32)[None] * 5) % cfg.vocab_size
    ref_logits = np.asarray(_logits_all(cfg, params, tokens))

    prefill = eng.make_prefill_step(cfg, rules, max_len=total)
    decode = eng.make_decode_step(cfg, rules)
    caches, _ = prefill(params, tokens[:, :PROMPT], None)
    for pos in range(PROMPT, total - 1):
        caches, logits_d = decode(params, caches, tokens[:, pos:pos + 1],
                                  jnp.int32(pos), None)
        np.testing.assert_allclose(np.asarray(logits_d), ref_logits[:, pos],
                                   atol=3e-3, err_msg=f"pos={pos}")


def test_engine_generate_greedy():
    """engine.generate: shapes, vocabulary range, determinism, and the
    first greedy token agrees with the teacher-forced oracle."""
    cfg = get_config("qwen2-1.5b").reduced()
    mesh = make_host_mesh(1, 1)
    rules = make_rules(cfg, mesh, global_batch=2, shape_kind="decode")
    params = lm.init_params(cfg, jax.random.PRNGKey(1))
    engine = eng.ServeEngine(cfg, params, rules, ServeConfig())
    prompts = (jnp.arange(2 * PROMPT, dtype=jnp.int32).reshape(2, PROMPT) * 3
               ) % cfg.vocab_size
    out = engine.generate(prompts, max_new_tokens=NEW, temperature=0.0)
    assert out.tokens.shape == (2, NEW)
    assert out.kv_pool == "hbm"
    toks = np.asarray(out.tokens)
    assert ((0 <= toks) & (toks < cfg.padded_vocab)).all()

    # deterministic under greedy decoding
    out2 = engine.generate(prompts, max_new_tokens=NEW, temperature=0.0)
    np.testing.assert_array_equal(toks, np.asarray(out2.tokens))

    # first token: compare against the oracle where argmax is unambiguous
    ref_logits = np.asarray(_logits_all(cfg, params, prompts))[:, -1]
    top2 = np.sort(ref_logits, -1)[:, -2:]
    margin_ok = (top2[:, 1] - top2[:, 0]) > 1e-3
    expect = ref_logits.argmax(-1)
    for b in range(2):
        if margin_ok[b]:
            assert toks[b, 0] == expect[b]


def test_cache_bytes_and_pool_choice():
    cfg = get_config("qwen2-1.5b").reduced()
    nbytes = eng.cache_bytes(cfg, batch=4, max_len=64)
    # 2 layers x k+v x (4, 64, kv, hd) bf16
    from repro.models.lm import cache_struct
    struct = cache_struct(cfg, 4, 64)
    manual = sum(np.prod(s.shape) * 2 for s in jax.tree.leaves(struct))
    assert nbytes == manual
    assert eng.choose_kv_pool(cfg, 4, 64) == "hbm"   # no advisor -> default
    assert eng.choose_kv_pool(
        cfg, 4, 64, scfg=ServeConfig(kv_placement="host")) == "host"


# ---------------------------------------------------------------------------
# Online loop plumbing: jit caching + capacity derivation
# ---------------------------------------------------------------------------


class _SpyAdvisor:
    """Records every advise() call; always answers "hbm"."""

    def __init__(self, pools):
        self.pools = list(pools)
        self.platform = detect_platform()
        self.calls = []

    def advise(self, objects, contention, capacities=None):
        from repro.core.placement import PlacementDecision, PlacementPlan
        self.calls.append((list(objects), contention, capacities))
        plan = PlacementPlan()
        for o in objects:
            plan.decisions[o.name] = PlacementDecision("hbm", 1.0, {})
        return plan


def test_prefill_trace_cached_across_generate_calls(monkeypatch):
    """The seed re-jitted prefill on EVERY generate call; the engine
    must build one prefill per max_len and reuse it."""
    cfg = get_config("qwen2-1.5b").reduced()
    mesh = make_host_mesh(1, 1)
    rules = make_rules(cfg, mesh, global_batch=2, shape_kind="decode")
    params = lm.init_params(cfg, jax.random.PRNGKey(1))

    builds = {"n": 0}
    real = eng.make_prefill_step

    def counting(cfg_, rules_, **kw):
        builds["n"] += 1
        return real(cfg_, rules_, **kw)

    monkeypatch.setattr(eng, "make_prefill_step", counting)
    engine = eng.ServeEngine(cfg, params, rules, ServeConfig())
    prompts = (jnp.arange(2 * PROMPT, dtype=jnp.int32).reshape(2, PROMPT)
               * 3) % cfg.vocab_size

    out1 = engine.generate(prompts, max_new_tokens=NEW)
    out2 = engine.generate(prompts, max_new_tokens=NEW)
    assert builds["n"] == 1, "prefill was re-jitted on a repeated shape"
    assert len(engine._prefill_cache) == 1
    assert engine._prefill(PROMPT + NEW) is engine._prefill(PROMPT + NEW)
    np.testing.assert_array_equal(np.asarray(out1.tokens),
                                  np.asarray(out2.tokens))

    engine.generate(prompts, max_new_tokens=NEW + 2)   # new max_len
    assert builds["n"] == 2

    # the engine feeds its observed decode duty cycle back into the
    # placement solve as the inject_rate coordinate
    spy = _SpyAdvisor(["hbm", "host"])
    engine.advisor = spy
    engine._duty = 0.37
    engine.generate(prompts, max_new_tokens=NEW)
    _objs, cont, caps = spy.calls[-1]
    assert cont.inject_rate == 0.37
    assert cont.rw_ratio == pytest.approx(
        eng.decode_rw_mix(2, PROMPT + NEW))
    assert caps is None                  # no manager, no free-bytes hint


def test_choose_kv_pool_derives_capacities():
    """Capacities come from live pool accounting (or the platform),
    never from an invented constant (the seed hard-coded host=256GiB)."""
    cfg = get_config("qwen2-1.5b").reduced()
    pm = PoolManager()

    spy = _SpyAdvisor(["hbm", "host"])
    assert eng.choose_kv_pool(cfg, 4, 64, advisor=spy, pool_mgr=pm,
                              inject_rate=0.7) == "hbm"
    _objs, cont, caps = spy.calls[-1]
    assert cont.inject_rate == 0.7
    assert cont.rw_ratio == pytest.approx(eng.decode_rw_mix(4, 64))
    assert set(caps) == {"hbm", "host"}
    for p, c in caps.items():
        assert c == pm.pool(p).available

    # without a manager: platform nameplate capacities, with the hbm
    # entry overridden by the caller's live free-bytes figure
    spy2 = _SpyAdvisor(["hbm", "host"])
    eng.choose_kv_pool(cfg, 4, 64, advisor=spy2, hbm_free_bytes=123 << 20)
    caps2 = spy2.calls[-1][2]
    assert caps2["hbm"] == 123 << 20
    assert caps2["host"] == detect_platform().memories["host"].size_bytes


# ---------------------------------------------------------------------------
# Watchdog -> probe sweep -> guarded migration (synthetic surfaces)
# ---------------------------------------------------------------------------


def _flat_surface(bw, lat=100.0):
    return Surface(axes=(SurfaceAxis(AXIS_N, (0.0, 8.0)),),
                   bandwidth_gbps=[bw, bw], latency_ns=[lat, lat])


def _synth_db(hbm_bw=1000.0, host_bw=100.0):
    """Offline surfaces: hbm fast, host slow — serving starts on hbm."""
    db = CurveDB(platform="synthetic")
    for pool, bw in (("hbm", hbm_bw), ("host", host_bw)):
        for strat in ("r", "l"):
            db.surfaces[SurfaceKey(pool, strat, "hbm", "b")] = \
                _flat_surface(bw)
    return db


def _imprint_online(db, hbm_bw, host_bw):
    """What a probe sweep would store: online-qualified cells."""
    keys = []
    for pool, bw in (("hbm", hbm_bw), ("host", host_bw)):
        for strat in ("r", "l"):
            k = SurfaceKey(pool, strat, "hbm", "b",
                           qualifier=ONLINE_QUALIFIER)
            db.surfaces[k] = _flat_surface(bw)
            keys.append(k)
    return keys


class _StubCoord:
    backend = "simulate"


def _drift_monitor(db, refresh, *, cooldown=24, cooldown_steps=10):
    adv = smon.ServeMonitor.online_advisor(db, detect_platform(),
                                           pools=["hbm", "host"])
    rechar = smon.OnlineRecharacterizer(_StubCoord(), db,
                                        pools=["hbm", "host"],
                                        refresh=refresh)
    mon = smon.ServeMonitor(
        adv, rechar,
        watchdog=smon.WatchdogConfig(band=1.5, rearm=1.2, sustain=3,
                                     warmup=4, cooldown=cooldown),
        guard=smon.GuardConfig(min_gain_frac=0.1,
                               cooldown_steps=cooldown_steps,
                               verify_steps=4, regress_band=1.1),
        capacities={"hbm": 1 << 30, "host": 1 << 30})
    mon.bind(kv_bytes=1 << 20, rw_mix=0.9, pool="hbm", inject_rate=1.0)
    return mon


CALM_NS, DRIFT_NS = 1.0e6, 3.0e6


def test_drift_triggers_exactly_one_probe_sweep():
    """Sustained deviation fires ONE drift event and ONE probe sweep at
    the live coordinates; the refreshed surface flips the advisor and
    the guarded migration verifies clean."""
    db = _synth_db()
    calls = []

    def refresh(coord, db_, **kw):
        calls.append(kw)
        return _imprint_online(db_, 50.0, 100.0), {"stub": True}

    mon = _drift_monitor(db, refresh)
    for _ in range(7):                       # warmup + calm
        assert mon.on_step(CALM_NS) is None
    acts = [mon.on_step(DRIFT_NS) for _ in range(14)]

    kinds = [a.kind for a in acts if a is not None]
    assert kinds == ["migrate"], "expected exactly one clean migration"
    assert len(mon.drift_events) == 1
    assert len(calls) == 1, "drift must trigger exactly one probe sweep"
    assert mon.pool == "host"

    mig = mon.migrations[0]
    assert (mig.from_pool, mig.to_pool) == ("hbm", "host")
    assert not mig.rolled_back
    assert mig.reason.startswith("verified")

    # the sweep ran at the LIVE coordinates, carrying drift evidence
    kw = calls[0]
    assert kw["rw_ratio"] == 0.9 and kw["inject_rate"] == 1.0
    assert kw["drift"]["pool"] == "hbm"
    assert kw["drift"]["ratio"] > 1.5

    # the refreshed cell resolves under the online qualifier (offline
    # surface untouched underneath)
    q = db.query("hbm", 0, stress_strat="w", rw_ratio=0.9,
                 qualifier=ONLINE_QUALIFIER)
    assert q.bandwidth_gbps == 50.0
    assert db.query("hbm", 0, stress_strat="w").bandwidth_gbps == 1000.0


def test_faulted_probe_sweep_flags_instead_of_raising():
    """A probe sweep that dies (e.g. injected faults exhausting the
    degradation ladder) must flag and leave serving on the stale
    surface — never raise into the decode loop."""
    from repro.core.exec.resilience import GroupExecutionError
    db = _synth_db()

    def refresh(coord, db_, **kw):
        raise GroupExecutionError("probe group online.hbm",
                                  RuntimeError("injected"))

    mon = _drift_monitor(db, refresh)
    for _ in range(7):
        mon.on_step(CALM_NS)
    for _ in range(10):
        assert mon.on_step(DRIFT_NS) is None   # no action ever escapes

    assert len(mon.drift_events) == 1          # cooldown: no event storm
    assert len(mon.refreshes) == 1
    assert mon.refreshes[0].failed
    assert "GroupExecutionError" in mon.refreshes[0].error
    assert mon.pool == "hbm" and not mon.migrations


def test_migration_hysteresis_holds_marginal_gain():
    """A refreshed surface that flips the decision by a hair stays put:
    the predicted gain must clear the hysteresis floor."""
    db = _synth_db()

    def refresh(coord, db_, **kw):
        # online: hbm only 5% worse than host — below the 10% floor
        return _imprint_online(db_, 95.0, 100.0), {}

    mon = _drift_monitor(db, refresh)
    for _ in range(7):
        mon.on_step(CALM_NS)
    for _ in range(10):
        assert mon.on_step(DRIFT_NS) is None

    assert len(mon.refreshes) == 1 and not mon.refreshes[0].failed
    assert not mon.migrations and mon.pool == "hbm"
    assert mon.held and "hysteresis floor" in mon.held[0][1]


def test_migration_rolls_back_on_regression():
    """A migration whose verification window regresses beyond the band
    is rolled back — caches return to the source pool."""
    db = _synth_db()

    def refresh(coord, db_, **kw):
        return _imprint_online(db_, 50.0, 100.0), {}

    mon = _drift_monitor(db, refresh)
    for _ in range(7):
        mon.on_step(CALM_NS)

    acts = []
    wall = DRIFT_NS
    for _ in range(10):
        a = mon.on_step(wall)
        acts.append(a)
        if a is not None and a.kind == "migrate":
            wall = 5.0e6        # post-migration steps WORSE than drift

    kinds = [a.kind for a in acts if a is not None]
    assert kinds == ["migrate", "rollback"]
    assert mon.pool == "hbm"
    mig = mon.migrations[0]
    assert mig.rolled_back and "regressed" in mig.reason


def test_readvise_hysteresis_and_forced_moves():
    """The advisor-level re-advise arithmetic under the online
    qualifier: clean flip, no-op, held, and forced (capacity-lost)
    moves."""
    db = _synth_db()
    _imprint_online(db, 50.0, 100.0)
    adv = smon.ServeMonitor.online_advisor(db, detect_platform(),
                                           pools=["hbm", "host"])
    obj = kv_cache_object("kv", 1 << 20,
                          bytes_read_per_token=float(1 << 20))
    spec = ContentionSpec(0, rw_ratio=0.9, inject_rate=1.0)
    caps = {"hbm": 1 << 30, "host": 1 << 30}

    dec = adv.readvise([obj], spec, {"kv": "hbm"}, capacities=caps)
    assert dec.moves == {"kv": ("hbm", "host")}
    assert dec.predicted_gain_frac == pytest.approx(0.5)

    # already on the winning pool: nothing to move, nothing held
    dec2 = adv.readvise([obj], spec, {"kv": "host"}, capacities=caps)
    assert not dec2.moves and not dec2.held

    # a floor above the predicted gain holds the flip
    dec3 = adv.readvise([obj], spec, {"kv": "hbm"}, capacities=caps,
                        min_gain_frac=0.6)
    assert not dec3.moves and "kv" in dec3.held

    # current pool no longer a candidate: forced move, no hysteresis
    dec4 = adv.readvise([obj], spec, {"kv": "peer"}, capacities=caps)
    assert dec4.moves == {"kv": ("peer", "host")}


# ---------------------------------------------------------------------------
# The monitored engine loop
# ---------------------------------------------------------------------------


def test_monitored_loop_matches_scan_tokens():
    """The python (monitored) decode loop is token-identical to the
    fused lax.scan path — same split order, same emission bookkeeping —
    including under sampling."""
    cfg = get_config("qwen2-1.5b").reduced()
    mesh = make_host_mesh(1, 1)
    rules = make_rules(cfg, mesh, global_batch=2, shape_kind="decode")
    params = lm.init_params(cfg, jax.random.PRNGKey(1))
    engine = eng.ServeEngine(cfg, params, rules, ServeConfig())
    prompts = (jnp.arange(2 * PROMPT, dtype=jnp.int32).reshape(2, PROMPT)
               * 3) % cfg.vocab_size

    for temp in (0.0, 0.7):
        ref = engine.generate(prompts, max_new_tokens=NEW,
                              temperature=temp, seed=3)
        steps = []
        out = engine.generate(
            prompts, max_new_tokens=NEW, temperature=temp, seed=3,
            on_step=lambda step, pool: steps.append((step, pool)))
        np.testing.assert_array_equal(np.asarray(ref.tokens),
                                      np.asarray(out.tokens))
        assert steps == [(PROMPT + i, "hbm") for i in range(NEW - 1)]
        assert out.probe_sweeps == 0 and not out.drift_events


def test_engine_monitored_drift_migrates_end_to_end():
    """Full loop through the REAL engine: pool-dependent contention
    (injected inside the timed step window) drifts the watchdog, the
    probe sweep flips the online surface, and the engine migrates the
    live caches to the pool where the contention vanishes — with the
    provenance trail landing in GenerateResult and tokens unchanged."""
    cfg = get_config("qwen2-1.5b").reduced()
    mesh = make_host_mesh(1, 1)
    rules = make_rules(cfg, mesh, global_batch=2, shape_kind="decode")
    params = lm.init_params(cfg, jax.random.PRNGKey(1))
    total_new = 17

    db = _synth_db()                 # offline: hbm wins -> start there

    def refresh(coord, db_, **kw):
        # the probe sweep "measures" hbm contended, host clean
        return _imprint_online(db_, 2.0, 1000.0), {"stub": True}

    adv = smon.ServeMonitor.online_advisor(db, detect_platform(),
                                           pools=["hbm", "host"])
    rechar = smon.OnlineRecharacterizer(_StubCoord(), db,
                                        pools=["hbm", "host"],
                                        refresh=refresh)
    mon = smon.ServeMonitor(
        adv, rechar,
        watchdog=smon.WatchdogConfig(band=2.5, rearm=1.5, sustain=3,
                                     warmup=4, cooldown=64),
        # generous regress_band: post-migration steps are compared to
        # the DRIFTED pre-median, and jit timing jitters on CI
        guard=smon.GuardConfig(min_gain_frac=0.1, cooldown_steps=64,
                               verify_steps=3, regress_band=3.0),
        capacities={"hbm": 1 << 34, "host": 1 << 34})
    engine = eng.ServeEngine(cfg, params, rules, ServeConfig(),
                             advisor=adv, monitor=mon)
    prompts = (jnp.arange(2 * PROMPT, dtype=jnp.int32).reshape(2, PROMPT)
               * 3) % cfg.vocab_size

    def contention(step, pool):
        # external load hits hbm-resident caches from decode step 8 on;
        # migrating to host escapes it
        if pool == "hbm" and step - PROMPT >= 8:
            time.sleep(0.3)

    res = engine.generate(prompts, max_new_tokens=total_new,
                          on_step=contention)

    assert res.kv_pool == "host"
    assert len(res.drift_events) == 1
    assert res.probe_sweeps == 1
    assert len(res.migrations) == 1
    assert not res.migrations[0].rolled_back
    assert (res.migrations[0].from_pool,
            res.migrations[0].to_pool) == ("hbm", "host")

    # the migration must not corrupt decoding: greedy tokens match a
    # plain unmonitored engine
    ref = eng.ServeEngine(cfg, params, rules, ServeConfig()).generate(
        prompts, max_new_tokens=total_new)
    np.testing.assert_array_equal(np.asarray(res.tokens),
                                  np.asarray(ref.tokens))


# ---------------------------------------------------------------------------
# Probe sweeps on the real mesh: resilience + journal resume
# ---------------------------------------------------------------------------


def run_forced(body: str, n_devices: int = N_DEV, timeout: int = 480,
               extra_env=None) -> str:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count={n_devices}"
        {textwrap.indent(textwrap.dedent(body), '        ').strip()}
        print("SUBPROC_OK")
    """)
    env = dict(os.environ, PYTHONPATH=SRC, **(extra_env or {}))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=timeout, env=env)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-3000:]}"
    assert "SUBPROC_OK" in r.stdout
    return r.stdout


def test_probe_sweep_journal_resume_value_identical():
    """Real-mesh probe sweeps: a journaled sweep restores
    value-identically; a sweep KILLED mid-flight resumes from its
    sidecar through the recharacterizer (which consumes the sidecar on
    success); and a chaos-faulted sweep completes flagged, with every
    refreshed cell present."""
    run_forced("""
    import json, os, tempfile
    from repro.core.characterize import CurveDB, refresh_surface_cells
    from repro.core.coordinator import CoreCoordinator
    from repro.core.exec import journal as exec_journal
    from repro.serve.monitor import OnlineRecharacterizer

    coord = CoreCoordinator(backend="spmd", faults=False, quality="off")
    tmp = tempfile.mkdtemp()
    jpath = os.path.join(tmp, "probe.journal")
    kw = dict(pools=["hbm", "host"], stress_pools=["hbm"], rw_ratio=0.7,
              inject_rate=0.9, buffer_bytes=64 << 10, iters=3,
              max_stressors=1)

    # 1. a complete journaled probe sweep ...
    db1 = CurveDB(platform=coord.platform.name)
    keys1, st1 = refresh_surface_cells(coord, db1, journal=jpath, **kw)
    assert len(keys1) == 4 and st1["resumed_ladders"] == 0

    # ... restores value-identically on the next run, executing nothing
    db2 = CurveDB(platform=coord.platform.name)
    keys2, st2 = refresh_surface_cells(coord, db2, journal=jpath, **kw)
    assert st2["measure_dispatches"] == 0
    assert st2["resumed_ladders"] > 0

    def doc(db):
        return json.dumps(
            {k.to_string(): [s.to_dict()["axes"],
                             s.to_dict()["bandwidth_gbps"],
                             s.to_dict()["latency_ns"]]
             for k, s in db.surfaces.items()}, sort_keys=True)
    assert doc(db1) == doc(db2), "journal resume was not value-identical"

    # 2. the serving path: a probe sweep killed mid-flight leaves its
    # sidecar; the restarted recharacterizer RESUMES it at the same
    # coordinates and deletes the sidecar after the merge
    jdir = os.path.join(tmp, "sidecars")
    db3 = CurveDB(platform=coord.platform.name)
    rc = OnlineRecharacterizer(coord, db3, pools=["hbm", "host"],
                               stress_pools=["hbm"],
                               buffer_bytes=64 << 10, iters=3,
                               max_stressors=1, journal_dir=jdir)
    real_record = exec_journal.SweepJournal.record
    calls = {"n": 0}
    def dying_record(self, planned, outcomes):
        real_record(self, planned, outcomes)
        calls["n"] += 1
        if calls["n"] >= 1:
            raise KeyboardInterrupt("simulated engine death")
    exec_journal.SweepJournal.record = dying_record
    try:
        rc.run(0.7, 0.9)
        raise SystemExit("probe sweep should have died mid-flight")
    except KeyboardInterrupt:
        pass
    finally:
        exec_journal.SweepJournal.record = real_record
    sidecar = rc._journal_path(0.7, 0.9)
    assert os.path.exists(sidecar), "dead sweep left no sidecar"
    assert not db3.surfaces, "a dead sweep must merge nothing"

    res = rc.run(0.7, 0.9)
    assert not res.failed
    assert res.stats["resumed_ladders"] > 0, "resume re-measured all"
    assert len(res.keys) == 4 and len(db3.surfaces) == 4
    assert not os.path.exists(sidecar), "sidecar must be consumed"

    # 3. chaos faults: every dispatch attempt faults (rate 1.0 — the
    # tiny probe sweep has too few dispatch sites for a probabilistic
    # rate to draw reliably), so the sweep must ride the retry /
    # degradation ladder and STILL deliver every refreshed cell
    coordf = CoreCoordinator(backend="spmd", faults="runtime=1.0,seed=3",
                             quality="off")
    dbf = CurveDB(platform=coordf.platform.name)
    rcf = OnlineRecharacterizer(coordf, dbf, pools=["hbm", "host"],
                                stress_pools=["hbm"],
                                buffer_bytes=64 << 10, iters=3,
                                max_stressors=1)
    resf = rcf.run(0.7, 0.9)
    assert not resf.failed, resf.error
    assert len(resf.keys) == 4
    assert resf.stats["faults_injected"] > 0, "chaos seed injected nothing"
    """)
