"""Serving correctness: prefill+decode must equal the teacher-forced
forward pass — the strongest end-to-end invariant the KV-cache/ring-
buffer/SSM-state machinery has.  Covered for a full-attention arch, a
sliding-window arch (ring caches), an SSM arch and the hybrid.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ServeConfig, get_config
from repro.launch.mesh import make_host_mesh
from repro.models import lm
from repro.parallel.sharding import make_rules
from repro.serve import engine as eng

PROMPT, NEW = 12, 4
ARCHS = ["qwen2-1.5b", "gemma3-1b", "mamba2-370m", "jamba-v0.1-52b"]


def _logits_all(cfg, params, tokens):
    h, _, _ = lm.forward(params, tokens, cfg=cfg, mode="train")
    return lm.unembed_logits(params, h, cfg)


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    mesh = make_host_mesh(1, 1)
    rules = make_rules(cfg, mesh, global_batch=2, shape_kind="decode")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))

    total = PROMPT + NEW
    tokens = (jnp.arange(2 * total, dtype=jnp.int32).reshape(2, total) * 7
              ) % cfg.vocab_size

    # oracle: teacher-forced full forward
    ref_logits = np.asarray(_logits_all(cfg, params, tokens))

    # prefill on the prompt, then decode the remaining positions
    prefill = eng.make_prefill_step(cfg, rules, max_len=total)
    decode = eng.make_decode_step(cfg, rules)
    caches, logits_p = prefill(params, tokens[:, :PROMPT], None)
    np.testing.assert_allclose(np.asarray(logits_p),
                               ref_logits[:, PROMPT - 1], atol=3e-3)
    for i in range(NEW - 1):
        pos = PROMPT + i
        caches, logits_d = decode(params, caches, tokens[:, pos:pos + 1],
                                  jnp.int32(pos), None)
        np.testing.assert_allclose(np.asarray(logits_d), ref_logits[:, pos],
                                   atol=3e-3, err_msg=f"{arch} pos={pos}")


def test_ring_cache_window_semantics():
    """Sliding-window ring cache: decoding far past the window must match
    a fresh forward over the same context."""
    cfg = get_config("gemma3-1b").reduced()       # window = 8
    assert cfg.sliding_window == 8
    mesh = make_host_mesh(1, 1)
    rules = make_rules(cfg, mesh, global_batch=1, shape_kind="decode")
    params = lm.init_params(cfg, jax.random.PRNGKey(2))

    total = 24                                    # 3x the window
    tokens = (jnp.arange(total, dtype=jnp.int32)[None] * 5) % cfg.vocab_size
    ref_logits = np.asarray(_logits_all(cfg, params, tokens))

    prefill = eng.make_prefill_step(cfg, rules, max_len=total)
    decode = eng.make_decode_step(cfg, rules)
    caches, _ = prefill(params, tokens[:, :PROMPT], None)
    for pos in range(PROMPT, total - 1):
        caches, logits_d = decode(params, caches, tokens[:, pos:pos + 1],
                                  jnp.int32(pos), None)
        np.testing.assert_allclose(np.asarray(logits_d), ref_logits[:, pos],
                                   atol=3e-3, err_msg=f"pos={pos}")


def test_engine_generate_greedy():
    """engine.generate: shapes, vocabulary range, determinism, and the
    first greedy token agrees with the teacher-forced oracle."""
    cfg = get_config("qwen2-1.5b").reduced()
    mesh = make_host_mesh(1, 1)
    rules = make_rules(cfg, mesh, global_batch=2, shape_kind="decode")
    params = lm.init_params(cfg, jax.random.PRNGKey(1))
    engine = eng.ServeEngine(cfg, params, rules, ServeConfig())
    prompts = (jnp.arange(2 * PROMPT, dtype=jnp.int32).reshape(2, PROMPT) * 3
               ) % cfg.vocab_size
    out = engine.generate(prompts, max_new_tokens=NEW, temperature=0.0)
    assert out.tokens.shape == (2, NEW)
    assert out.kv_pool == "hbm"
    toks = np.asarray(out.tokens)
    assert ((0 <= toks) & (toks < cfg.padded_vocab)).all()

    # deterministic under greedy decoding
    out2 = engine.generate(prompts, max_new_tokens=NEW, temperature=0.0)
    np.testing.assert_array_equal(toks, np.asarray(out2.tokens))

    # first token: compare against the oracle where argmax is unambiguous
    ref_logits = np.asarray(_logits_all(cfg, params, prompts))[:, -1]
    top2 = np.sort(ref_logits, -1)[:, -2:]
    margin_ok = (top2[:, 1] - top2[:, 0]) > 1e-3
    expect = ref_logits.argmax(-1)
    for b in range(2):
        if margin_ok[b]:
            assert toks[b, 0] == expect[b]


def test_cache_bytes_and_pool_choice():
    cfg = get_config("qwen2-1.5b").reduced()
    nbytes = eng.cache_bytes(cfg, batch=4, max_len=64)
    # 2 layers x k+v x (4, 64, kv, hd) bf16
    from repro.models.lm import cache_struct
    struct = cache_struct(cfg, 4, 64)
    manual = sum(np.prod(s.shape) * 2 for s in jax.tree.leaves(struct))
    assert nbytes == manual
    assert eng.choose_kv_pool(cfg, 4, 64) == "hbm"   # no advisor -> default
    assert eng.choose_kv_pool(
        cfg, 4, 64, scfg=ServeConfig(kv_placement="host")) == "host"
