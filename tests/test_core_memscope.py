"""MEMSCOPE core behaviour: pools, device tree, workloads, coordinator,
simulator physics, characterization, MLP, placement, user interface.

These tests assert the *paper's* qualitative findings hold in our
reproduction (Fig. 4-9 trends, Tables II/III MLP, Fig. 6/7 shared-queue
throttling, Fig. 13 write-stream collapse, Fig. 14 counter-intuitive
placement).
"""
import json

import numpy as np
import pytest

from repro.core import simulate as sim
from repro.core.characterize import CurveDB, characterize, mlp_table
from repro.core.coordinator import (ActivitySpec, CoreCoordinator,
                                    ExperimentConfig, ValidationError)
from repro.core.devicetree import (TPU_V5E, ZCU102, Platform,
                                   detect_platform, zcu102_partitioned)
from repro.core.interface import (MemscopeInterface, parse_experiment,
                                  parse_size)
from repro.core.placement import (ContentionSpec, MemObject,
                                  PlacementAdvisor, kv_cache_object)
from repro.core.pools import PoolError, PoolManager


# ---------------------------------------------------------------------------
# Device tree + pools
# ---------------------------------------------------------------------------


def test_detect_platform():
    p = detect_platform()
    assert p.name == "tpu-v5e"
    assert set(p.memories) == {"hbm", "vmem", "host", "peer"}
    assert detect_platform("zcu102").name == "zcu102"
    with pytest.raises(KeyError):
        detect_platform("nope")


def test_platform_json_roundtrip():
    p2 = Platform.from_json(TPU_V5E.to_json())
    assert p2.memories["hbm"].peak_bw_gbps == 819.0
    assert p2.n_engines == TPU_V5E.n_engines


def test_pool_alloc_free_capacity():
    mgr = PoolManager()
    pool = mgr.pool("hbm")
    a = pool.alloc((1024, 128), tag="t")
    assert pool.allocated == 1024 * 128 * 4
    pool.free(a)
    assert pool.allocated == 0
    with pytest.raises(PoolError):
        pool.free(a)                         # double free
    with pytest.raises(PoolError):
        mgr.pool("vmem").alloc((1 << 20, 128))   # exceeds 128 MiB
    with pytest.raises(PoolError):
        mgr.pool("nope")


def test_pool_ids_match_modules():
    mgr = PoolManager()
    for p in mgr.pools():
        assert mgr.pool(p.id) is p           # 1-to-1 id <-> module
    assert "pool" in mgr.status()


def test_upool_place():
    import jax.numpy as jnp
    mgr = PoolManager()
    up = mgr.upool("hbm")
    tree = {"x": jnp.ones((4, 4))}
    placed = up.place(tree)
    assert placed["x"].shape == (4, 4)
    assert up.name == "hbm"


# ---------------------------------------------------------------------------
# Simulator physics (the paper's findings)
# ---------------------------------------------------------------------------


def _bw_ladder(platform, mem, obs="r", stress="w"):
    res = sim.scenario_ladder(platform, obs_node=platform.node(mem),
                              obs_strategy=obs,
                              stress_node=platform.node(mem),
                              stress_strategy=stress)
    return [r["obs"].bw_gbps for r in res]


def test_bandwidth_monotonic_under_stress():
    """Fig. 4: observed bandwidth never increases with stressor count."""
    for mem in ("hbm", "host"):
        for stress in ("r", "w", "y"):
            bw = _bw_ladder(TPU_V5E, mem, "r", stress)
            assert all(b1 >= b2 - 1e-9 for b1, b2 in zip(bw, bw[1:])), \
                (mem, stress, bw)


def test_latency_monotonic_under_stress():
    """Fig. 5: observed latency never decreases with stressor count."""
    for mem in ("dram", "pl-dram"):
        res = sim.scenario_ladder(ZCU102, obs_node=ZCU102.node(mem),
                                  obs_strategy="l",
                                  stress_node=ZCU102.node(mem),
                                  stress_strategy="w")
        lat = [r["obs"].lat_ns for r in res]
        assert all(l1 <= l2 + 1e-9 for l1, l2 in zip(lat, lat[1:])), \
            (mem, lat)


def test_write_stress_worse_than_read_stress():
    """Fig. 4: (r,w) degrades more than (r,r) — WAWB write amplification."""
    bw_r = _bw_ladder(ZCU102, "dram", "r", "r")
    bw_w = _bw_ladder(ZCU102, "dram", "r", "w")
    assert bw_w[-1] < bw_r[-1]


def test_zcu102_mlp_matches_paper_tables():
    """Tables II/III: DRAM MLP ~4.5-4.9, PL-DRAM ~4.0-4.2 under stress."""
    plat = ZCU102
    for mem, lo, hi in (("dram", 3.0, 7.0), ("pl-dram", 2.5, 6.5)):
        res = sim.scenario_ladder(plat, obs_node=plat.node(mem),
                                  obs_strategy="l",
                                  stress_node=plat.node(mem),
                                  stress_strategy="r")
        lat = res[-1]["obs"].lat_ns
        bw = sim.scenario_ladder(plat, obs_node=plat.node(mem),
                                 obs_strategy="r",
                                 stress_node=plat.node(mem),
                                 stress_strategy="r")[-1]["obs"].bw_gbps
        mlp = lat * bw / plat.line_bytes
        assert lo <= mlp <= hi, (mem, mlp)


def test_heterogeneous_shared_queue_throttling():
    """Fig. 6/7: stressing the SLOW module degrades the FAST module's
    bandwidth (slow transactions hold shared CCI entries longer)."""
    plat = ZCU102
    alone = sim.scenario_ladder(
        plat, obs_node=plat.node("dram"), obs_strategy="s",
        stress_node=plat.node("pl-dram"), stress_strategy="i")[0]
    stressed = sim.scenario_ladder(
        plat, obs_node=plat.node("dram"), obs_strategy="s",
        stress_node=plat.node("pl-dram"), stress_strategy="x")[-1]
    assert stressed["obs"].bw_gbps < 0.9 * alone["obs"].bw_gbps
    # and the effect is asymmetric: PL-DRAM obs under DRAM stress suffers
    # proportionally less (paper Fig. 7 reverse case)
    pl_alone = sim.scenario_ladder(
        plat, obs_node=plat.node("pl-dram"), obs_strategy="s",
        stress_node=plat.node("dram"), stress_strategy="i")[0]
    pl_stressed = sim.scenario_ladder(
        plat, obs_node=plat.node("pl-dram"), obs_strategy="s",
        stress_node=plat.node("dram"), stress_strategy="x")[-1]
    drop_fast = stressed["obs"].bw_gbps / alone["obs"].bw_gbps
    drop_slow = pl_stressed["obs"].bw_gbps / pl_alone["obs"].bw_gbps
    assert drop_slow > drop_fast


def test_write_stream_bank_collapse():
    """Fig. 13: y-stress from >=2 engines collapses even cache-partitioned
    bandwidth; 1 stressor is comparable to the (r,w) case."""
    plat = zcu102_partitioned()
    obs = plat.node("pvtpool")
    ladder_w = sim.scenario_ladder(plat, obs_node=obs, obs_strategy="r",
                                   stress_node=plat.node("dram"),
                                   stress_strategy="w")
    ladder_y = sim.scenario_ladder(plat, obs_node=obs, obs_strategy="r",
                                   stress_node=plat.node("dram"),
                                   stress_strategy="y")
    bw_w = [r["obs"].bw_gbps for r in ladder_w]
    bw_y = [r["obs"].bw_gbps for r in ladder_y]
    assert bw_y[1] > 0.5 * bw_w[1]          # comparable at one stressor
    assert bw_y[3] < 0.25 * bw_w[3]         # collapse at three


def test_cache_partitioning_helps_miss_path_only():
    """Fig. 11/12: partitioning does NOT help when everyone hits (bank
    contention on the hit path), but DOES when stressors miss."""
    plat = zcu102_partitioned()
    # everyone hitting in the cache: partitioned obs still degrades
    hit_ladder = sim.scenario_ladder(
        plat, obs_node=plat.node("pvtpool"), obs_strategy="r",
        stress_node=plat.node("l2"), stress_strategy="r")
    hit_bw = [r["obs"].bw_gbps for r in hit_ladder]
    assert hit_bw[-1] < 0.8 * hit_bw[0]
    # stressors missing to DRAM, obs hits private partition: mild impact
    miss_ladder = sim.scenario_ladder(
        plat, obs_node=plat.node("pvtpool"), obs_strategy="r",
        stress_node=plat.node("dram"), stress_strategy="r")
    miss_bw = [r["obs"].bw_gbps for r in miss_ladder]
    assert miss_bw[-1] > hit_bw[-1]


# ---------------------------------------------------------------------------
# Coordinator + experiment structure
# ---------------------------------------------------------------------------


def test_coordinator_validation():
    c = CoreCoordinator(backend="simulate")
    good = ExperimentConfig(ActivitySpec("r", "hbm", 1 << 20),
                            ActivitySpec("w", "hbm", 1 << 20))
    c.validate(good)
    with pytest.raises(ValidationError):
        c.validate(ExperimentConfig(ActivitySpec("z", "hbm", 1),
                                    ActivitySpec("w", "hbm", 1)))
    with pytest.raises(ValidationError):
        c.validate(ExperimentConfig(
            ActivitySpec("r", "hbm", 1 << 20),
            ActivitySpec("w", "hbm", 1 << 20), iters=0))
    with pytest.raises(PoolError):
        c.validate(ExperimentConfig(ActivitySpec("r", "nope", 1),
                                    ActivitySpec("w", "hbm", 1)))


def test_scenario_ladder_structure():
    """§III-A: p scenarios, 0..p-1 stressors, teardown leaves pools clean."""
    c = CoreCoordinator(backend="simulate")
    res = c.run(ExperimentConfig(ActivitySpec("r", "hbm", 1 << 20),
                                 ActivitySpec("w", "hbm", 1 << 20)))
    assert [s.n_stressors for s in res.scenarios] == list(
        range(c.platform.n_engines))
    for p in c.pools.pools():
        assert p.allocated == 0              # post-experiment clean state
    curve = res.bandwidth_curve()
    assert curve[0][1] >= curve[-1][1]


def test_interpret_backend_runs_real_kernels():
    c = CoreCoordinator(backend="interpret")
    res = c.run(ExperimentConfig(ActivitySpec("r", "hbm", 256 << 10),
                                 ActivitySpec("i", "hbm", 0), iters=2,
                                 scenarios=1))
    assert res.scenarios[0].main.bytes_moved > 0
    assert res.scenarios[0].main.elapsed_ns > 0


# ---------------------------------------------------------------------------
# Characterization + placement (Fig. 14 loop)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def curve_db():
    c = CoreCoordinator(backend="simulate")
    return characterize(c, pools=["hbm", "host"],
                        obs_strategies=("r", "l"),
                        stress_strategies=("r", "w"), iters=5), c


def test_curvedb_roundtrip(curve_db, tmp_path):
    db, _ = curve_db
    p = str(tmp_path / "curves.json")
    db.save(p)
    db2 = CurveDB.load(p)
    assert db2.curves.keys() == db.curves.keys()
    k = next(iter(db.curves))
    assert db2.curves[k][0].bandwidth_gbps == db.curves[k][0].bandwidth_gbps


def test_mlp_table_renders(curve_db):
    db, c = curve_db
    txt = mlp_table(db, c.platform)
    assert "hbm" in txt and "MLP" in txt


def test_placement_prefers_uncontended_pool(curve_db):
    """Fig. 14: under heavy HBM stress, the advisor may place a
    latency-sensitive object in nominally-slower host memory."""
    db, c = curve_db
    adv = PlacementAdvisor(db, c.platform, pools=["hbm", "host"])
    obj = MemObject("heap", 1 << 20, bytes_per_step=1 << 20,
                    dependent_accesses=0.0)
    quiet = adv.advise([obj], ContentionSpec(0, "hbm", "w"))
    assert quiet.pool_of("heap") == "hbm"    # HBM wins uncontended
    # predicted cost under stress must rise
    stressed_cost = adv.predict_ns(obj, "hbm",
                                   ContentionSpec(7, "hbm", "w"))
    quiet_cost = adv.predict_ns(obj, "hbm", ContentionSpec(0, "hbm", "w"))
    assert stressed_cost > quiet_cost


def test_placement_capacity_fallback(curve_db):
    db, c = curve_db
    adv = PlacementAdvisor(db, c.platform, pools=["hbm", "host"])
    big = kv_cache_object("kv", 32 << 30, bytes_read_per_token=1 << 20)
    plan = adv.advise([big], ContentionSpec(0),
                      capacities={"hbm": 16 << 30, "host": 256 << 30})
    assert plan.pool_of("kv") == "host"      # does not fit HBM
    with pytest.raises(RuntimeError):
        adv.advise([MemObject("x", 1 << 40, 0.0)],
                   capacities={"hbm": 1, "host": 1})


def test_placement_pinning(curve_db):
    db, c = curve_db
    adv = PlacementAdvisor(db, c.platform, pools=["hbm", "host"])
    obj = MemObject("pinned", 1 << 10, 1.0, pinned_pool="host")
    assert adv.advise([obj]).pool_of("pinned") == "host"


# ---------------------------------------------------------------------------
# User interface (debugfs analog)
# ---------------------------------------------------------------------------


def test_parse_size():
    assert parse_size("4M") == 4 << 20
    assert parse_size("128K") == 128 << 10
    assert parse_size("1G") == 1 << 30
    assert parse_size("77") == 77
    with pytest.raises(ValueError):
        parse_size("4X")


def test_parse_experiment_roundtrip():
    cfg = parse_experiment("l,hbm,4M w,host,8K iters=100 scenarios=3")
    assert cfg.main == ActivitySpec("l", "hbm", 4 << 20)
    assert cfg.stress == ActivitySpec("w", "host", 8 << 10)
    assert cfg.iters == 100 and cfg.scenarios == 3
    with pytest.raises(ValueError):
        parse_experiment("r,hbm")
    with pytest.raises(ValueError):
        parse_experiment("r,hbm,1M w,hbm,1M bogus=1")


def test_interface_state_machine():
    iface = MemscopeInterface(CoreCoordinator(backend="simulate"))
    assert iface.write_cmd("start").startswith("ERR")
    iface.write_experiment("r,hbm,1M w,hbm,1M iters=5")
    assert iface.write_cmd("validate") == "OK valid"
    assert iface.write_cmd("start") == "OK complete"
    out = iface.read_results()
    assert "stressors" in out and "bw_GBps" in out
    assert iface.write_cmd("erase") == "OK erased"
    assert iface.read_results() == "(no results)"
    assert iface.write_cmd("reboot").startswith("ERR")
    assert "hbm" in iface.read_pools()
    iface.write_perfcount("WALL_NS,HLO_FLOPS")
    assert iface.read_perfcount() == "WALL_NS,HLO_FLOPS"
