"""Fig. 4 — homogeneous bandwidth ladders.

Paper: DRAM vs PL-DRAM on the ZCU102 under (r,r)/(r,w)/(w,r)/(w,w),
4 MiB buffers.  We reproduce the ZCU102 curves with the calibrated
queueing model AND produce the TPU-v5e equivalents (HBM vs host DRAM) —
the table the placement advisor consumes.
"""
from repro.core.coordinator import ActivitySpec
from benchmarks.common import coordinator, ladder_rows, print_table

BUF = 4 << 20
CASES = [("r", "r"), ("r", "w"), ("w", "r"), ("w", "w")]


def main() -> list:
    rows = []
    zc = coordinator("zcu102")
    for mem in ("dram", "pl-dram"):
        for a, b in CASES:
            rows += ladder_rows(
                zc, ActivitySpec(a, mem, BUF), ActivitySpec(b, mem, BUF),
                f"zcu102/{mem}/({a},{b})")
    v5e = coordinator()
    for mem in ("hbm", "host"):
        for a, b in CASES:
            rows += ladder_rows(
                v5e, ActivitySpec(a, mem, 64 << 20),
                ActivitySpec(b, mem, 64 << 20), f"v5e/{mem}/({a},{b})")
    print_table("Fig.4 homogeneous bandwidth (GB/s vs stressors)", rows)
    # headline checks mirrored from the paper's §IV-B(1) observations
    def bw(case, k):
        return next(r["bw_GBps"] for r in rows
                    if r["case"] == case and r["stressors"] == k)
    assert bw("zcu102/pl-dram/(r,r)", 0) < bw("zcu102/dram/(r,r)", 0)
    # paper obs (2): "a stressed DRAM — e.g. (r,w) — exhibits a bandwidth
    # COMPARABLE to that of a non-stressed PL-DRAM"
    assert bw("zcu102/dram/(r,w)", 3) < 1.25 * bw("zcu102/pl-dram/(r,r)", 0)
    # obs (3) [known model deviation, see EXPERIMENTS.md]: the paper sees
    # DRAM degrade proportionally MORE than PL-DRAM; our queueing model
    # (no DRAM bank/row-miss dynamics) gives similar proportional drops:
    d = bw("zcu102/dram/(r,w)", 3) / bw("zcu102/dram/(r,w)", 0)
    p = bw("zcu102/pl-dram/(r,w)", 3) / bw("zcu102/pl-dram/(r,w)", 0)
    print(f"obs(3) proportional drop: dram={d:.3f} pl-dram={p:.3f} "
          f"(paper: dram drops more; model lacks bank-level dynamics)")
    return rows


if __name__ == "__main__":
    main()
