"""Benchmark harness entry point: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run fig4 tab2  # substring filter
"""
import sys
import time
import traceback

from benchmarks import (fig4_homogeneous_bw, fig5_homogeneous_lat,
                        fig6_7_heterogeneous, fig8_9_scratchpad,
                        fig10_validation, fig11_13_partition,
                        fig14_applications, resilience_bench, roofline,
                        scenario_matrix, serve_bench, spmd_ladder,
                        surface_sweep, tab2_3_mlp, worstcase_search)

SUITES = [
    ("fig4_homogeneous_bw", fig4_homogeneous_bw.main),
    ("fig5_homogeneous_lat", fig5_homogeneous_lat.main),
    ("tab2_3_mlp", tab2_3_mlp.main),
    ("fig6_7_heterogeneous", fig6_7_heterogeneous.main),
    ("fig8_9_scratchpad", fig8_9_scratchpad.main),
    ("fig10_validation", fig10_validation.main),
    ("fig11_13_partition", fig11_13_partition.main),
    ("fig14_applications", fig14_applications.main),
    ("scenario_matrix", scenario_matrix.main),
    ("spmd_ladder", spmd_ladder.main),
    ("surface_sweep", surface_sweep.main),
    ("worstcase_search", worstcase_search.main),
    ("resilience_bench", resilience_bench.main),
    ("serve_bench", serve_bench.main),
    ("roofline", roofline.main),
]


def main() -> int:
    filters = sys.argv[1:]
    failures = []
    for name, fn in SUITES:
        if filters and not any(f in name for f in filters):
            continue
        t0 = time.time()
        print(f"\n{'=' * 70}\n=== {name}\n{'=' * 70}")
        try:
            fn()
            print(f"--- {name} OK ({time.time() - t0:.1f}s)")
        except Exception:
            traceback.print_exc()
            failures.append(name)
            print(f"--- {name} FAILED")
    if failures:
        print(f"\nFAILED: {failures}")
        return 1
    print("\nall benchmarks OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
