"""Fig. 10 — cross-validation against an independent benchmark.

Paper: MEMSCOPE's bandwidth measurements match IsolBench on the same
setup, justifying trust in the toolkit.  Our analog: the Pallas
bandwidth kernels (executed for real, interpret mode) must agree with an
independent plain-jnp streaming benchmark on the same buffers, within
interpreter noise.  This validates the *executable* workload library
against a second implementation, exactly the Fig.-10 methodology.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from benchmarks.common import print_table

ROWS = 2048            # 2048 x 128 x 4B = 1 MiB
ITERS = 30


def _time_ns(fn, *args, **kw) -> float:
    fn(*args, **kw).block_until_ready()
    samples = []
    for _ in range(3):
        t0 = time.perf_counter_ns()
        for _ in range(ITERS):
            out = fn(*args, **kw)
        out.block_until_ready()
        samples.append((time.perf_counter_ns() - t0) / ITERS)
    return float(np.median(samples))


@jax.jit
def jnp_read(x):
    return jnp.sum(x, dtype=jnp.float32)


@jax.jit
def jnp_copy(x):
    return x * 1.0


def main() -> list:
    x = jnp.arange(ROWS * 128, dtype=jnp.float32).reshape(ROWS, 128)
    nbytes = ROWS * 128 * 4

    rows = []
    # read: memscope kernel vs independent jnp implementation
    t_ms = _time_ns(ops.stream_read, x, block_rows=512)
    t_jnp = _time_ns(jnp_read, x)
    rows.append({"benchmark": "read_1MiB",
                 "memscope_GBps": round(nbytes / t_ms, 3),
                 "independent_GBps": round(nbytes / t_jnp, 3)})
    # copy
    t_ms = _time_ns(ops.stream_copy, x, block_rows=512)
    t_jnp = _time_ns(jnp_copy, x)
    rows.append({"benchmark": "copy_1MiB",
                 "memscope_GBps": round(2 * nbytes / t_ms, 3),
                 "independent_GBps": round(2 * nbytes / t_jnp, 3)})
    print_table("Fig.10 cross-validation (Pallas interpret vs jnp)", rows)
    print("note: interpret-mode kernels pay Python dispatch overhead; "
          "agreement is structural (same order of magnitude), the "
          "real-hardware path uses identical code minus interpret=True")
    # numerical agreement is exact — that is the meaningful check here
    np.testing.assert_allclose(
        float(ops.stream_read(x, block_rows=512)), float(jnp_read(x)),
        rtol=1e-6)
    return rows


if __name__ == "__main__":
    main()
