"""Adversarial worst-case search vs. an equal-budget fixed grid.

Runs :func:`repro.core.search.worst_case_search` on the spmd backend
and pits it against the obvious alternative — a fixed characterization
grid of the SAME probe budget, measured through the SAME
``measure_candidates`` batched-dispatch path (identical per-probe cost;
the search can only win by *steering*).  The claim under test: the
model-seeded acquisition finds a strictly worse contention corner than
the best point of the equal-budget grid, because the grid must spend
its budget uniformly while the search follows the queueing prior into
the posted-write / locality-defeating corners the grid's single mixed
arm never plays.

Writes ``BENCH_worstcase.json`` (the CI artifact): the search envelope
keys, the worst corner each method found, the improvement margin and
the structural dispatch counts (exactly one host sync per search
iteration and per baseline batch — asserted).

The spmd backend needs a multi-device mesh.  Standalone this module
forces host devices before touching jax:

    PYTHONPATH=src python -m benchmarks.worstcase_search [--smoke] \
        [--fail-if-not-worse] [--out BENCH_worstcase.json]

Under ``benchmarks.run`` (whose process must keep seeing ONE device) it
re-executes itself in a subprocess with the devices forced.
``--fail-if-not-worse`` turns the search-beats-grid claim into a hard
exit code (the 8-device CI leg gates on it).
"""
import argparse
import itertools
import json
import os
import subprocess
import sys

_FORCE = "--xla_force_host_platform_device_count"
_N_DEV = max(2, int(os.environ.get("REPRO_SPMD_DEVICES", "8")))

if __name__ == "__main__":
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = f"{flags} {_FORCE}={_N_DEV}".strip()

import jax  # noqa: E402  (after the device forcing above)

from benchmarks.common import print_table  # noqa: E402

BUF = 256 << 10
ITERS = 20


def _budget(smoke: bool):
    """(iterations, batch): both methods probe iterations*batch
    coordinates, each under both observer strategies."""
    return (3, 4) if smoke else (6, 6)


def _grid_coords(budget: int, max_n: int):
    """The equal-budget fixed grid: uniform (n, rw, ir) coverage, the
    way ``characterize_surface`` would spend the same probes."""
    ns = list(range(1, max_n + 1))
    rws = (0.0, 0.5, 1.0)
    irs = (0.5, 1.0)
    cells = list(itertools.product(ns, rws, irs))
    # truncate/cycle deterministically to exactly the probe budget
    return [cells[i % len(cells)] for i in range(budget)] \
        if len(cells) < budget else cells[:budget]


def _run(smoke: bool, out: str, fail_if_not_worse: bool) -> dict:
    from repro.core.coordinator import CoreCoordinator
    from repro.core.exec.dispatch import DispatchStats
    from repro.core.search import (SearchArm, SearchSpec, _badness,
                                   _modeled_edge, measure_candidates,
                                   worst_case_search)

    iterations, batch = _budget(smoke)
    coord = CoreCoordinator(backend="spmd", faults=False,
                            quality="off")
    max_n = min(3, len(jax.devices()) - 1)
    spec = SearchSpec(pool="hbm", iterations=iterations, batch=batch,
                      max_stressors=max_n, buffer_bytes=BUF,
                      iters=ITERS, seed=0)

    # -- the search -------------------------------------------------------
    res = worst_case_search(coord, spec, execute=True)
    assert res.executed and res.fenced
    assert res.stats.host_sync_dispatches == iterations, \
        (res.stats.host_sync_dispatches, iterations)

    # -- the equal-budget fixed grid (same measurement path) --------------
    edges = _modeled_edge(coord.platform, spec.pool)
    grid = _grid_coords(iterations * batch, max_n)
    grid_stats = DispatchStats()
    grid_pts = []
    arm = SearchArm("b")        # the grid's single mixed-stream arm
    for i in range(0, len(grid), batch):
        chunk = grid[i:i + batch]
        results, fenced = measure_candidates(coord, spec, arm, chunk,
                                             it=i // batch,
                                             stats=grid_stats)
        assert fenced
        for ci, (n, rw, ir) in enumerate(chunk):
            for o in spec.obs_strategies:
                bw, lat = results[(ci, o)]
                grid_pts.append({
                    "n_stressors": n, "rw_ratio": rw, "inject_rate": ir,
                    "obs_strat": o, "bandwidth_gbps": bw,
                    "latency_ns": lat,
                    "badness": _badness(o, bw, lat, edges)})
    n_batches = -(-len(grid) // batch)
    assert grid_stats.host_sync_dispatches == n_batches, \
        (grid_stats.host_sync_dispatches, n_batches)

    # -- compare worst corners, per observer and overall ------------------
    rows, per_obs = [], {}
    for o in spec.obs_strategies:
        sw = res.worst(o)
        gw = max((p for p in grid_pts if p["obs_strat"] == o),
                 key=lambda p: p["badness"])
        margin = 100.0 * (sw.measured_badness / gw["badness"] - 1.0)
        per_obs[o] = {
            "search": sw.to_dict(),
            "grid": gw,
            "margin_pct": round(margin, 2),
        }
        rows.append({
            "obs": o,
            "search_worst": round(sw.measured_badness, 3),
            "search_corner": (f"{sw.arm} n{sw.n_stressors} "
                              f"rw{sw.rw_ratio} ir{sw.inject_rate}"),
            "grid_worst": round(gw["badness"], 3),
            "grid_corner": (f"b n{gw['n_stressors']} "
                            f"rw{gw['rw_ratio']} ir{gw['inject_rate']}"),
            "margin_pct": round(margin, 1),
        })
    print_table(
        f"worst corner found, {iterations * batch}-probe budget each "
        f"({len(jax.devices())} host engines; badness: ~1 uncontended, "
        f"larger = worse)", rows)

    best_margin = max(v["margin_pct"] for v in per_obs.values())
    print(f"worstcase search: {iterations} iterations x {batch} probes "
          f"= {res.stats.host_sync_dispatches} host-sync dispatches "
          f"(one per iteration); grid: {n_batches} batches -> "
          f"{grid_stats.host_sync_dispatches} dispatches; "
          f"best margin {best_margin:+.1f}%")

    report = {
        "devices": len(jax.devices()),
        "smoke": smoke,
        "budget": {"iterations": iterations, "batch": batch,
                   "coords": iterations * batch},
        "search": {
            "host_sync_dispatches": res.stats.host_sync_dispatches,
            "fenced": res.fenced,
            "envelope_keys": [k.to_string() for k in
                              sorted(res.envelope)],
            "arms_played": sorted({p.arm for p in res.points}),
        },
        "grid": {"host_sync_dispatches":
                 grid_stats.host_sync_dispatches},
        "per_observer": per_obs,
        "search_beats_grid": bool(best_margin > 0.0),
    }
    if fail_if_not_worse:
        assert best_margin > 0.0, \
            (f"search found no worse corner than the equal-budget grid "
             f"(best margin {best_margin:+.2f}%)")

    with open(out, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {out}")
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small budget (CI)")
    ap.add_argument("--fail-if-not-worse", action="store_true",
                    help="hard-fail unless the search beats the grid")
    ap.add_argument("--out", default="BENCH_worstcase.json")
    # under benchmarks.run main() is called with no argv: parse
    # defaults, not the harness's own filter arguments
    args = ap.parse_args(argv if argv is not None else [])

    if len(jax.devices()) >= 2:
        _run(args.smoke, args.out, args.fail_if_not_worse)
        return 0
    # single-device harness process: re-exec with forced host devices
    # (same contract as benchmarks.surface_sweep)
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" in flags:
        raise RuntimeError(
            f"worst-case search needs >= 2 devices but XLA_FLAGS "
            f"already pins the host device count ({flags!r}); raise it "
            f"to >= 2 or unset the flag")
    env["XLA_FLAGS"] = f"{flags} {_FORCE}={_N_DEV}".strip()
    cmd = [sys.executable, "-m", "benchmarks.worstcase_search",
           "--out", args.out]
    if args.smoke:
        cmd.append("--smoke")
    if args.fail_if_not_worse:
        cmd.append("--fail-if-not-worse")
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=900,
                       env=env)
    sys.stdout.write(r.stdout)
    if r.returncode != 0:
        raise RuntimeError(f"worstcase_search subprocess failed:\n"
                           f"{r.stderr[-2000:]}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
