"""Executable bandwidth–latency surface sweep (CurveDB v3).

Characterizes a small rf x dc x stressor-count surface on the spmd
backend — every grid cell is a contention ladder whose rungs execute as
fused shard_map dispatches — and writes the resulting schema-3 surface
database (the CI artifact next to ``BENCH_spmd.json``).

The sweep is the tentpole's structural proof: the grid varies ONLY the
stressor ``TrafficShape``, the coordinator's sweep-batched dispatch
stacks every same-signature ladder into one host-synchronous dispatch,
and this module asserts ``host_sync_dispatches == distinct
signatures`` on the executed result.

The spmd backend needs a multi-device mesh.  Standalone this module
forces host devices before touching jax:

    PYTHONPATH=src python -m benchmarks.surface_sweep [--smoke] \
        [--out SURFACE_spmd.json]

Under ``benchmarks.run`` (whose process must keep seeing ONE device) it
re-executes itself in a subprocess with the devices forced.
"""
import argparse
import os
import subprocess
import sys

_FORCE = "--xla_force_host_platform_device_count"
_N_DEV = max(2, int(os.environ.get("REPRO_SPMD_DEVICES", "8")))

if __name__ == "__main__":
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = f"{flags} {_FORCE}={_N_DEV}".strip()

import jax  # noqa: E402  (after the device forcing above)

from benchmarks.common import print_table  # noqa: E402

BUF = 256 << 10
ITERS = 20


def _grids(smoke: bool):
    if smoke:
        return (0.0, 1.0), (0.5, 1.0)
    return (0.0, 0.5, 1.0), (0.25, 0.5, 1.0)


def _run(smoke: bool, out: str) -> dict:
    from repro.core.characterize import AXIS_N, CurveDB, characterize_surface
    from repro.core.coordinator import CoreCoordinator
    from repro.core.scenarios import surface_matrix

    rws, irs = _grids(smoke)
    coord = CoreCoordinator(backend="spmd", faults=False, quality="off")
    max_stressors = min(3, len(jax.devices()) - 1)
    db = characterize_surface(coord, pools=["hbm"], stress_pools=["hbm"],
                              buffer_bytes=BUF, rw_ratios=rws,
                              inject_rates=irs, iters=ITERS,
                              max_stressors=max_stressors)

    # the structural claim: ONE host-synchronous dispatch per distinct
    # role-program signature across the whole grid (each (rf, dc,
    # observer) cell is a distinct ladder signature here)
    specs = surface_matrix(pools=["hbm"], stress_pools=["hbm"],
                           buffer_bytes=BUF, rw_ratios=rws,
                           inject_rates=irs, iters=ITERS,
                           max_stressors=max_stressors)
    n_sig = len({coord._spmd_group_key(spec, obs, b)
                 for spec in specs for obs in spec.observers
                 for b in obs.buffers})
    st = db.meta
    print(f"surface sweep: {st['n_ladders']} ladders "
          f"({len(rws)}rf x {len(irs)}dc x "
          f"{max_stressors + 1} rungs x 2 observers) -> "
          f"{st['host_sync_dispatches']} host-sync dispatches, "
          f"{n_sig} distinct signatures, "
          f"{st['programs_built']} programs built "
          f"({st['aot_compiles']} AOT)")
    assert st["host_sync_dispatches"] == n_sig, \
        (st["host_sync_dispatches"], n_sig)

    rows = []
    for key, surf in sorted(db.surfaces.items()):
        for n in surf.axis(AXIS_N).values:
            for rw in (rws[0], rws[-1]):
                q = db.query(key.obs_pool, n, obs_strat=key.obs_strat,
                             stress_pool=key.stress_pool,
                             stress_strat=key.stress_strat, rw_ratio=rw)
                rows.append({
                    "surface": key.to_string(),
                    "k": int(n), "rw": rw,
                    "bw_GBps": round(q.bandwidth_gbps, 4),
                    "lat_ns": round(q.latency_ns, 1),
                })
    print_table(f"executed surface grid ({len(jax.devices())} host "
                f"engines), rw-axis edges", rows)

    db.save(out)
    print(f"wrote {out} (schema {CurveDB.load(out).schema}, "
          f"{len(db.surfaces)} surfaces, shape "
          f"{next(iter(db.surfaces.values())).shape})")
    return st


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="2x2 grid (CI)")
    ap.add_argument("--out", default="SURFACE_spmd.json")
    # under benchmarks.run main() is called with no argv: parse
    # defaults, not the harness's own filter arguments
    args = ap.parse_args(argv if argv is not None else [])

    if len(jax.devices()) >= 2:
        _run(args.smoke, args.out)
        return 0
    # single-device harness process: re-exec with forced host devices
    # (same contract as benchmarks.spmd_ladder)
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" in flags:
        raise RuntimeError(
            f"surface sweep needs >= 2 devices but XLA_FLAGS already "
            f"pins the host device count ({flags!r}); raise it to >= 2 "
            f"or unset the flag")
    env["XLA_FLAGS"] = f"{flags} {_FORCE}={_N_DEV}".strip()
    cmd = [sys.executable, "-m", "benchmarks.surface_sweep",
           "--out", args.out]
    if args.smoke:
        cmd.append("--smoke")
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=900,
                       env=env)
    sys.stdout.write(r.stdout)
    if r.returncode != 0:
        raise RuntimeError(f"surface_sweep subprocess failed:\n"
                           f"{r.stderr[-2000:]}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
