"""Resilience overhead + chaos completeness: the PR 9 benchmark.

Two legs, one committed ``BENCH_resilience.json``:

**zero-fault overhead** — the resilient executor
(:func:`repro.core.exec.journal.execute_plan`: retry wrapper, timing
validation, quality gate) versus the raw dispatch loop it replaced
(``Dispatcher.run_planned`` + fold, no resilience seam) over the SAME
warm DispatchPlan on the SAME dispatcher.  Both contenders hand
identical work to ``run_planned``, so each pass's MACHINERY cost is
its wall time minus the time spent inside ``run_planned`` (measured
by a timing proxy around the dispatcher) — the kernels' multi-percent
run-to-run jitter cancels out of the comparison instead of drowning
it.  The gate: with no faults injected the resilient machinery adds
**under 3%** of the warm sweep's wall time — resilience must be free
until the day it is needed.  (Whole-pass wall medians are reported
too, informationally.)

**chaos completeness** (``--chaos``) — the full 64-scenario sweep
(16 with ``--smoke``) under ~25% mixed fault injection: every curve
must still come back (retried, degraded or modeled — never dropped),
with the survived faults/retries/degradations recorded in the JSON.
The chaos coordinator resolves ``REPRO_FAULT_SPEC`` from the
environment when set (the CI chaos leg scopes it to this step), else
defaults to ``mixed=0.25,seed=7``.

The spmd backend needs a multi-device mesh.  Standalone this module
forces host devices before touching jax (``REPRO_SPMD_DEVICES``, CI's
matrix knob, picks the count); under ``benchmarks.run`` (whose process
must keep seeing ONE device) it re-executes itself in a subprocess:

    PYTHONPATH=src python -m benchmarks.resilience_bench \
        [--smoke] [--chaos] [--out BENCH_resilience.json] \
        [--fail-if-slower]
"""
import argparse
import json
import os
import subprocess
import sys
import time

N_DEV = max(2, int(os.environ.get("REPRO_SPMD_DEVICES", "8")))
_FORCE = f"--xla_force_host_platform_device_count={N_DEV}"

if __name__ == "__main__":
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = f"{flags} {_FORCE}".strip()

OVERHEAD_BAND = 0.03
GATE_CRITERION = ("zero-fault resilient machinery (pass wall minus "
                  "time inside Dispatcher.run_planned — both "
                  "contenders hand run_planned identical work on the "
                  f"same warm plan) adds <= {OVERHEAD_BAND:.0%} of "
                  "the warm sweep wall; the gated contender runs the "
                  "full retry/validation/gate-evaluation machinery "
                  "with re-measurement pinned off — a quality-gate "
                  "RE-MEASUREMENT is an extra measurement dispatch "
                  "taken in response to actually-noisy data, reported "
                  "separately, not overhead")
WARM_ROUNDS = 7
DEFAULT_CHAOS = "mixed=0.25,seed=7"


def _specs(smoke: bool):
    # the perf harness's committed sweep: 64 scenarios (16 smoke)
    from benchmarks.perf_harness import _sweep_specs
    return _sweep_specs(smoke)


def _build_warm(coord, specs):
    """The sweep's packed DispatchPlan + a dispatcher whose program
    cache already holds every plan program (one cold run_matrix)."""
    from repro.core.exec import plan as exec_plan
    coord.run_matrix(specs)                   # cold: trace + compile
    triples = [(spec, obs, b) for spec in specs
               for obs in spec.observers for b in obs.buffers]
    plan = exec_plan.build_plan(triples, coord._spmd_engines(),
                                coord.pools, coord.platform.n_engines)
    return exec_plan.pack_engine_subsets(plan)


def _direct_pass(disp, plan, n_eng, activity):
    """The pre-resilience executor shape: run each planned dispatch
    raw and fold — no retry wrapper, no validation, no gate."""
    from repro.core.exec.assemble import observer_result
    from repro.core.exec.dispatch import DispatchStats
    stats = DispatchStats()
    executed = {}
    for planned in plan.dispatches:
        med, _spread, _fenced, _aot = disp.run_planned(
            planned, n_eng, activity, "batched", stats)
        for g, e in enumerate(planned.entries):
            for k in range(planned.n_scen):
                executed[(e.index, k)] = observer_result(
                    e.observer, e.buffer_bytes, e.spec.iters,
                    float(max(med[g][k], 1.0)))
    return executed, stats


def _resilient_pass(disp, plan, n_eng, activity, policy, gate):
    from repro.core.exec import journal as exec_journal
    from repro.core.exec.dispatch import DispatchStats
    stats = DispatchStats()
    executed, _fenced, _timing = exec_journal.execute_plan(
        disp, plan, n_eng=n_eng, activity=activity, mode="batched",
        stats=stats, policy=policy, gate=gate)
    return executed, stats


class _TimedDispatcher:
    """Proxy accumulating wall time spent inside ``run_planned``.
    Pass wall minus this is the executor's own machinery cost; both
    contenders hand ``run_planned`` identical work, so the kernels'
    run-to-run jitter never enters the overhead comparison."""

    def __init__(self, disp):
        self._disp = disp
        self.dispatch_s = 0.0

    def __getattr__(self, name):
        return getattr(self._disp, name)

    def run_planned(self, *a, **kw):
        t0 = time.perf_counter()
        try:
            return self._disp.run_planned(*a, **kw)
        finally:
            self.dispatch_s += time.perf_counter() - t0


def _overhead_leg(smoke: bool) -> dict:
    from repro.core.coordinator import CoreCoordinator
    from repro.core.exec.resilience import QualityGate, RetryPolicy

    specs = _specs(smoke)
    # hermetic: the measured coordinator must not see a stray
    # REPRO_FAULT_SPEC (the CI chaos step's env) in its dispatcher
    coord = CoreCoordinator(backend="spmd", faults=False, quality="off")
    plan = _build_warm(coord, specs)
    n_eng = coord._spmd_engines()
    disp = coord._dispatcher
    activity = coord._resolved_activity()
    policy = RetryPolicy()
    # the GATED contender: full machinery — retry wrapper, timing
    # validation, per-cell noisy evaluation — with re-measurement
    # pinned off.  A re-measurement is an extra measurement dispatch
    # triggered by data that really was noisy: feature work, timed
    # separately below, not machinery overhead.
    eval_gate = QualityGate(remeasure=0)
    ship_gate = QualityGate()                 # the shipped default

    # one unmeasured pass per contender: all run on fully-warm caches
    base, _ = _direct_pass(disp, plan, n_eng, activity)
    resi, rstats = _resilient_pass(disp, plan, n_eng, activity, policy,
                                   eval_gate)
    assert set(base) == set(resi), "resilient path lost curve points"
    assert not (rstats.faults_injected or rstats.retried_dispatches
                or rstats.degraded_ladders), \
        "zero-fault leg saw resilience activity"

    def timed(fn, *fa):
        proxy = _TimedDispatcher(disp)
        t0 = time.perf_counter()
        out = fn(proxy, plan, n_eng, activity, *fa)
        wall = time.perf_counter() - t0
        return wall, wall - proxy.dispatch_s, out

    direct_s, resilient_s, shipped_s = [], [], []
    mach_d, mach_r, remeasures = [], [], 0
    for _ in range(WARM_ROUNDS):              # interleaved: shared
        wall, mach, _ = timed(_direct_pass)   # machine drift hits all
        direct_s.append(wall)
        mach_d.append(mach)
        wall, mach, _ = timed(_resilient_pass, policy, eval_gate)
        resilient_s.append(wall)
        mach_r.append(mach)
        wall, _mach, (_, sst) = timed(_resilient_pass, policy,
                                      ship_gate)
        shipped_s.append(wall)
        remeasures += sst.noisy_remeasures
    med = lambda xs: sorted(xs)[len(xs) // 2]
    d_wall, r_wall, s_wall = med(direct_s), med(resilient_s), \
        med(shipped_s)
    # the gated quantity: machinery time (wall minus run_planned) —
    # stable Python time, free of the kernels' wall-clock jitter
    overhead = (med(mach_r) - med(mach_d)) / d_wall
    return {
        "n_scenarios": len(specs),
        "n_dispatches": len(plan.dispatches),
        "rounds": WARM_ROUNDS,
        "direct_warm_s": round(d_wall, 4),
        "resilient_warm_s": round(r_wall, 4),
        "machinery_direct_s": round(med(mach_d), 4),
        "machinery_resilient_s": round(med(mach_r), 4),
        "overhead_frac": round(overhead, 4),
        # informational: the shipped config (re-measurement on) —
        # slower only when the machine really was noisy, and then by
        # exactly the extra measurement dispatches it chose to take
        "shipped_gate_warm_s": round(s_wall, 4),
        "shipped_gate_remeasures": remeasures,
        "gate": GATE_CRITERION,
        "pass": bool(overhead <= OVERHEAD_BAND),
    }


def _chaos_leg(smoke: bool) -> dict:
    from repro.core.coordinator import CoreCoordinator
    from repro.core.exec.resilience import FaultSpec

    spec_text = (os.environ.get("REPRO_FAULT_SPEC", "").strip()
                 or DEFAULT_CHAOS)
    fspec = FaultSpec.parse(spec_text)
    specs = _specs(smoke)
    n_curves = sum(len(o.buffers) for s in specs for o in s.observers)
    coord = CoreCoordinator(backend="spmd", faults=fspec)
    t0 = time.perf_counter()
    res = coord.run_matrix(specs)
    wall = time.perf_counter() - t0
    st = res.stats
    assert len(res.runs) == n_curves, \
        (f"chaos sweep dropped curves: {len(res.runs)} of {n_curves} "
         f"came back")
    for run in res.runs:                      # every rung has a value
        assert all(s.modeled_bw_gbps > 0 for s in run.scenarios), \
            f"curve {run.key} lost rung values under chaos"
        assert run.execution["attempts"] >= 1
    degraded = [run.key for run in res.runs
                if run.execution.get("degraded_from")]
    return {
        "fault_spec": spec_text,
        "n_scenarios": len(specs),
        "n_curves": len(res.runs),
        "wall_s": round(wall, 3),
        "faults_injected": st.faults_injected,
        "retried_dispatches": st.retried_dispatches,
        "degraded_ladders": st.degraded_ladders,
        "modeled_floor_ladders": st.modeled_floor_ladders,
        "noisy_remeasures": st.noisy_remeasures,
        "degraded_curves": degraded,
        "pass": True,                         # completing IS the gate
    }


def _reexec(argv) -> int:
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" in flags:
        raise RuntimeError(
            f"resilience bench needs >= 2 devices but XLA_FLAGS "
            f"already pins the host device count ({flags!r})")
    env["XLA_FLAGS"] = f"{flags} {_FORCE}".strip()
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.resilience_bench"] + argv,
        capture_output=True, text=True, timeout=900, env=env)
    sys.stdout.write(r.stdout)
    if r.returncode != 0:
        raise RuntimeError(f"resilience_bench subprocess failed:\n"
                           f"{r.stderr[-2000:]}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--chaos", action="store_true")
    ap.add_argument("--out", default="BENCH_resilience.json")
    ap.add_argument("--fail-if-slower", action="store_true")
    # under benchmarks.run main() is called with no argv: parse
    # defaults, not the harness's own filter arguments
    argv = argv if argv is not None else []
    args = ap.parse_args(argv)

    import jax
    if len(jax.devices()) < 2:
        return _reexec(argv)

    out = {
        "schema": 1,
        "bench": "resilience",
        "n_devices": len(jax.devices()),
        "smoke": args.smoke,
        "zero_fault": _overhead_leg(args.smoke),
    }
    zf = out["zero_fault"]
    print(f"zero-fault machinery: resilient "
          f"{zf['machinery_resilient_s']}s vs direct "
          f"{zf['machinery_direct_s']}s over {zf['n_dispatches']} "
          f"dispatches of a {zf['direct_warm_s']}s warm sweep "
          f"({zf['overhead_frac'] * 100:+.2f}% of wall, band "
          f"{OVERHEAD_BAND * 100:.0f}%) -> "
          f"{'PASS' if zf['pass'] else 'FAIL'}")
    if args.chaos:
        ch = out["chaos"] = _chaos_leg(args.smoke)
        print(f"chaos sweep [{ch['fault_spec']}]: {ch['n_curves']} "
              f"curves all present in {ch['wall_s']}s — "
              f"{ch['faults_injected']} faults, "
              f"{ch['retried_dispatches']} retries, "
              f"{ch['degraded_ladders']} degraded, "
              f"{ch['modeled_floor_ladders']} modeled "
              f"({len(ch['degraded_curves'])} curves degraded)")
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
        f.write("\n")
    print(f"wrote {args.out}")
    if args.fail_if_slower and not zf["pass"]:
        print(f"PERF GATE FAILED: {GATE_CRITERION}")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
