"""Fig. 14 — heterogeneous memory management for real applications.

Paper: SD-VBS benchmarks with the heap mapped via upools to DRAM or
PL-DRAM, under 3-stressor write interference targeting either pool; the
counterintuitive winner is "heap on the pool the stressors are NOT
hammering", even when that pool is nominally slower.

Our application is the framework itself: a decode step whose KV cache is
the placeable heap.  We (1) characterize the platform, (2) predict the
slowdown of each placement under each interference pattern with the
advisor's cost model, and (3) verify the advisor picks the pool the
stressors avoid — the Fig. 14 macro-trend.
"""
from repro.configs.base import get_config
from repro.core.characterize import characterize
from repro.core.placement import (ContentionSpec, PlacementAdvisor,
                                  kv_cache_object)
from repro.serve.engine import cache_bytes
from benchmarks.common import coordinator, print_table


def main() -> list:
    coord = coordinator()              # v5e tree: hbm + host pools
    db = characterize(coord, pools=["hbm", "host"],
                      obs_strategies=("r", "l"),
                      stress_strategies=("r", "w", "y"), iters=50)
    adv = PlacementAdvisor(db, coord.platform, pools=["hbm", "host"])

    cfg = get_config("qwen2-1.5b")
    kv = kv_cache_object(
        "kv", cache_bytes(cfg, batch=8, max_len=8192),
        bytes_read_per_token=float(cache_bytes(cfg, 8, 8192)))

    rows = []
    base = adv.predict_ns(kv, "hbm", ContentionSpec(0))
    for heap in ("hbm", "host"):
        for stress_pool in (None, "hbm", "host"):
            c = ContentionSpec(0 if stress_pool is None else 7,
                               stress_pool or "hbm", "w")
            t = adv.predict_ns(kv, heap, c)
            rows.append({
                "heap": heap,
                "interference": stress_pool or "none",
                "t_step_us": round(t / 1e3, 1),
                "slowdown_vs_hbm_quiet": round(t / base, 2),
            })
    print_table("Fig.14 predicted decode-step slowdown by placement",
                rows)

    def slow(heap, intf):
        return next(r["slowdown_vs_hbm_quiet"] for r in rows
                    if r["heap"] == heap and r["interference"] == intf)

    # the paper's macro-trend: under HBM-targeting stress, the stressed
    # pool's slowdown grows; the advisor must then prefer the quiet pool
    assert slow("hbm", "hbm") > slow("hbm", "none")
    plan_quiet = adv.advise([kv], ContentionSpec(0, "hbm", "w"))
    plan_hbm_stress = adv.advise([kv], ContentionSpec(7, "hbm", "y"))
    rows.append({"heap": "ADVISOR(quiet)",
                 "interference": "none",
                 "t_step_us": round(
                     plan_quiet.decisions["kv"].predicted_step_ns / 1e3, 1),
                 "slowdown_vs_hbm_quiet": plan_quiet.pool_of("kv")})
    rows.append({"heap": "ADVISOR(hbm-stressed)",
                 "interference": "hbm",
                 "t_step_us": round(
                     plan_hbm_stress.decisions["kv"].predicted_step_ns / 1e3,
                     1),
                 "slowdown_vs_hbm_quiet": plan_hbm_stress.pool_of("kv")})
    print(f"advisor picks: quiet={plan_quiet.pool_of('kv')} "
          f"hbm-stressed={plan_hbm_stress.pool_of('kv')}")
    return rows


if __name__ == "__main__":
    main()
