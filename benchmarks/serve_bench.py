"""Curve-driven serving vs static placement under drift: the PR 10 bench.

One emulated contention episode, two contenders, one committed
``BENCH_serve.json``:

**static** — the seed's serving shape: the KV cache is placed once
(HBM, the calm-regime winner) and the engine never looks back.  When
the emulated contention hits the HBM pool mid-stream, every remaining
decode step eats the full drifted delay.

**curve-driven** — the same engine with a
:class:`repro.serve.monitor.ServeMonitor`: the contention watchdog
detects the drift against the surface's expectation, a REAL resilient
probe sweep runs through the spmd coordinator
(:func:`repro.core.characterize.refresh_surface_cells` — retries,
degradation ladder, journal sidecar all live), and the migration guard
moves the live caches to the pool the refreshed surface prefers.

The contention is EMULATED and pool-dependent: an ``on_step`` hook
sleeps ``delay(step, pool)`` inside the engine's timed step window
(HBM: calm until ``drift_at``, heavily contended after; host: a flat
modest tax, immune to the drift).  Because the real probe kernels
measure this machine's actual memory — not the emulated contention —
the refreshed cell VALUES are overwritten with the emulated world's
truth after each sweep (spelled so predicted cost == emulated delay);
the sweep's EXECUTION (dispatch, faults, retries, journal) is real.
The JSON records this under ``emulated_world``.

The gate (``--fail-if-slower``): curve-driven tokens/sec >= static
tokens/sec on the same episode.  The chaos leg (``--chaos``) re-runs
the curve-driven episode with fault injection in the probe coordinator
(``REPRO_FAULT_SPEC`` when set, else ``mixed=0.25,seed=7``) and gates
on 100% request completion with zero serving-loop crashes — a faulted
probe sweep may flag and keep serving on the stale surface, but it
must never raise into the decode loop.

The spmd probe backend needs a multi-device mesh.  Standalone this
module forces host devices before touching jax (``REPRO_SPMD_DEVICES``
picks the count); under ``benchmarks.run`` it re-executes itself:

    PYTHONPATH=src python -m benchmarks.serve_bench \
        [--smoke] [--chaos] [--out BENCH_serve.json] [--fail-if-slower]
"""
import argparse
import json
import os
import subprocess
import sys
import tempfile
import time

N_DEV = max(2, int(os.environ.get("REPRO_SPMD_DEVICES", "8")))
_FORCE = f"--xla_force_host_platform_device_count={N_DEV}"

if __name__ == "__main__":
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = f"{flags} {_FORCE}".strip()

DEFAULT_CHAOS = "mixed=0.25,seed=7"
GATE_CRITERION = ("curve-driven serving (contention watchdog -> online "
                  "probe sweep -> guarded KV migration) sustains >= the "
                  "static-placement tokens/sec over the same emulated "
                  "drift episode; the chaos leg completes 100% of "
                  "requests with zero serving-loop crashes")

PROMPT = 12
BATCH = 2


class EmulatedWorld:
    """Scripted pool-dependent contention.

    ``delay_s(step, pool)`` is the extra wall a decode step experiences
    with its KV caches in ``pool`` (slept inside the engine's timed
    window).  ``online_bw(pool)`` is what a truthful post-drift probe
    would report, spelled so the advisor's predicted step cost for a
    pool EQUALS its emulated delay (cost_ns = kv_bytes / bw)."""

    def __init__(self, kv_bytes: int, drift_at: int, *,
                 drift_hbm_s: float = 0.12, host_s: float = 0.02):
        self.kv_bytes = kv_bytes
        self.drift_at = drift_at
        self.drift_hbm_s = drift_hbm_s
        self.host_s = host_s

    def delay_s(self, step: int, pool: str) -> float:
        if pool == "host":
            return self.host_s
        return self.drift_hbm_s if step >= self.drift_at else 0.0

    def online_bw(self, pool: str) -> float:
        delay = self.drift_hbm_s if pool == "hbm" else self.host_s
        return self.kv_bytes / (delay * 1e9)

    def hook(self):
        def on_step(step, pool):
            time.sleep(self.delay_s(step, pool))
        return on_step

    def describe(self) -> dict:
        return {
            "drift_at_step": self.drift_at,
            "hbm_calm_delay_s": 0.0,
            "hbm_drifted_delay_s": self.drift_hbm_s,
            "host_delay_s": self.host_s,
            "note": ("contention is emulated by an on_step sleep inside "
                     "the engine's timed window; probe sweeps EXECUTE "
                     "the real resilient spmd path but their refreshed "
                     "cell values are overwritten with this world's "
                     "truth, since real kernels cannot see the emulated "
                     "load"),
        }


def _offline_db():
    """Calm-regime surfaces: hbm fast, host slow — serving starts on
    hbm, exactly what the drift will punish."""
    from repro.core.characterize import (AXIS_N, CurveDB, Surface,
                                         SurfaceAxis, SurfaceKey)

    def flat(bw):
        return Surface(axes=(SurfaceAxis(AXIS_N, (0.0, 8.0)),),
                       bandwidth_gbps=[bw, bw], latency_ns=[100.0, 100.0])

    db = CurveDB(platform="serve-bench")
    for pool, bw in (("hbm", 1000.0), ("host", 10.0)):
        for strat in ("r", "l"):
            db.surfaces[SurfaceKey(pool, strat, "hbm", "b")] = flat(bw)
    return db


def _world_refresh(world: EmulatedWorld):
    """The recharacterizer's refresh seam: run the REAL probe sweep,
    then imprint the emulated world's truth over the refreshed cells
    (keeping the sweep's provenance — faults, retries, journal)."""
    from repro.core.characterize import (AXIS_N, Surface, SurfaceAxis,
                                         refresh_surface_cells)

    def refresh(coord, db, **kw):
        keys, stats = refresh_surface_cells(coord, db, **kw)
        for k in keys:
            bw = world.online_bw(k.obs_pool)
            truth = Surface(
                axes=(SurfaceAxis(AXIS_N, (0.0, 8.0)),),
                bandwidth_gbps=[bw, bw], latency_ns=[100.0, 100.0],
                provenance=db.surfaces[k].provenance)
            db.surfaces[k] = truth
        return keys, stats

    return refresh


def _build_model():
    import jax
    from repro.configs.base import get_config
    from repro.launch.mesh import make_host_mesh
    from repro.models import lm
    from repro.parallel.sharding import make_rules

    cfg = get_config("qwen2-1.5b").reduced()
    mesh = make_host_mesh(1, 1)
    rules = make_rules(cfg, mesh, global_batch=BATCH, shape_kind="decode")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    import jax.numpy as jnp
    prompts = (jnp.arange(BATCH * PROMPT,
                          dtype=jnp.int32).reshape(BATCH, PROMPT) * 3
               ) % cfg.vocab_size
    return cfg, rules, params, prompts


def _monitor(db, coord, world, journal_dir):
    from repro.core.devicetree import detect_platform
    from repro.serve.monitor import (GuardConfig, OnlineRecharacterizer,
                                     ServeMonitor, WatchdogConfig)

    adv = ServeMonitor.online_advisor(db, detect_platform(),
                                      pools=["hbm", "host"])
    rechar = OnlineRecharacterizer(
        coord, db, pools=["hbm", "host"], stress_pools=["hbm"],
        buffer_bytes=64 << 10, iters=3, max_stressors=1,
        journal_dir=journal_dir, refresh=_world_refresh(world))
    return ServeMonitor(
        adv, rechar,
        watchdog=WatchdogConfig(band=3.0, rearm=1.5, sustain=4,
                                warmup=5, cooldown=48),
        # rollback compares against the DRIFTED pre-median; a generous
        # band keeps CI timing jitter from faking a regression
        guard=GuardConfig(min_gain_frac=0.1, cooldown_steps=48,
                          verify_steps=4, regress_band=3.0),
        capacities={"hbm": 1 << 34, "host": 1 << 34}), adv, rechar


def _run_episode(engine, world, prompts, new_tokens):
    t0 = time.perf_counter()
    res = engine.generate(prompts, max_new_tokens=new_tokens,
                          on_step=world.hook())
    wall = time.perf_counter() - t0
    n_tok = BATCH * new_tokens
    return res, wall, n_tok / wall


def _refresh_stats(mon) -> dict:
    ok = [r for r in mon.refreshes if not r.failed]
    keep = ("faults_injected", "retried_dispatches", "degraded_ladders",
            "modeled_floor_ladders", "noisy_rungs", "resumed_ladders",
            "measure_dispatches")
    agg = {k: sum(int(r.stats.get(k, 0)) for r in ok) for k in keep}
    agg["sweeps"] = len(mon.refreshes)
    agg["sweeps_failed_flagged"] = sum(r.failed for r in mon.refreshes)
    return agg


def _serve_legs(smoke: bool) -> dict:
    from repro.configs.base import ServeConfig
    from repro.core.characterize import ONLINE_QUALIFIER
    from repro.core.coordinator import CoreCoordinator
    from repro.serve.engine import ServeEngine, cache_bytes

    new_tokens = 80 if smoke else 160
    cfg, rules, params, prompts = _build_model()
    kv_bytes = cache_bytes(cfg, BATCH, PROMPT + new_tokens)
    world = EmulatedWorld(kv_bytes, drift_at=PROMPT + 8)

    # -- static contender: placed once, never re-examined ------------------
    static = ServeEngine(cfg, params, rules, ServeConfig())
    sres, swall, stps = _run_episode(static, world, prompts, new_tokens)
    assert sres.kv_pool == "hbm"

    # -- curve-driven contender --------------------------------------------
    # probes run hermetically fault-free here; the chaos leg injects
    db = _offline_db()
    coord = CoreCoordinator(backend="spmd", faults=False, quality="off")
    jdir = tempfile.mkdtemp(prefix="serve-bench-journal-")
    mon, adv, rechar = _monitor(db, coord, world, jdir)

    # pre-warm the probe path (trace + compile) OUTSIDE the timed
    # episode, then drop the imprinted online cells so the episode
    # starts from the calm offline surface
    t0 = time.perf_counter()
    warm = rechar.run(0.9, 1.0)
    prewarm_s = time.perf_counter() - t0
    assert not warm.failed, f"probe pre-warm failed: {warm.error}"
    for k in [k for k in db.surfaces if k.qualifier == ONLINE_QUALIFIER]:
        del db.surfaces[k]

    curve = ServeEngine(cfg, params, rules, ServeConfig(),
                        advisor=adv, monitor=mon)
    cres, cwall, ctps = _run_episode(curve, world, prompts, new_tokens)

    assert cres.kv_pool == "host", \
        f"curve-driven engine never escaped the drift ({cres.kv_pool})"
    assert len(cres.drift_events) >= 1 and cres.probe_sweeps >= 1
    rollbacks = sum(m.rolled_back for m in cres.migrations)
    return {
        "n_new_tokens": new_tokens,
        "batch": BATCH,
        "emulated_world": world.describe(),
        "static": {
            "tokens_per_s": round(stps, 2),
            "wall_s": round(swall, 3),
            "kv_pool": sres.kv_pool,
        },
        "curve_driven": {
            "tokens_per_s": round(ctps, 2),
            "wall_s": round(cwall, 3),
            "kv_pool_final": cres.kv_pool,
            "probe_prewarm_s": round(prewarm_s, 3),
            "drift_events": [e.to_dict() for e in cres.drift_events],
            "probe_sweeps": cres.probe_sweeps,
            "migrations": [m.to_dict() for m in cres.migrations],
            "rollbacks": rollbacks,
            "held": len(mon.held),
            "refresh": _refresh_stats(mon),
        },
        "speedup": round(ctps / stps, 3),
        "gate": GATE_CRITERION,
        "pass": bool(ctps >= stps),
    }


def _chaos_leg(smoke: bool) -> dict:
    from repro.configs.base import ServeConfig
    from repro.core.coordinator import CoreCoordinator
    from repro.core.exec.resilience import FaultSpec
    from repro.serve.engine import ServeEngine, cache_bytes

    spec_text = (os.environ.get("REPRO_FAULT_SPEC", "").strip()
                 or DEFAULT_CHAOS)
    fspec = FaultSpec.parse(spec_text)
    new_tokens = 48 if smoke else 96
    n_calls = 3
    cfg, rules, params, prompts = _build_model()
    kv_bytes = cache_bytes(cfg, BATCH, PROMPT + new_tokens)
    world = EmulatedWorld(kv_bytes, drift_at=PROMPT + 8)

    db = _offline_db()
    coord = CoreCoordinator(backend="spmd", faults=fspec, quality="off")
    jdir = tempfile.mkdtemp(prefix="serve-bench-chaos-journal-")
    mon, adv, _rechar = _monitor(db, coord, world, jdir)
    engine = ServeEngine(cfg, params, rules, ServeConfig(),
                         advisor=adv, monitor=mon)

    # a request stream under chaos: the FIRST call rides the drift ->
    # faulted probe sweep -> migration; later calls serve from the
    # refreshed placement.  Every request must complete.
    completed = 0
    walls = []
    for _ in range(n_calls):
        t0 = time.perf_counter()
        res = engine.generate(prompts, max_new_tokens=new_tokens,
                              on_step=world.hook())
        walls.append(round(time.perf_counter() - t0, 3))
        assert res.tokens.shape == (BATCH, new_tokens), \
            f"truncated request under chaos: {res.tokens.shape}"
        completed += BATCH
    rollbacks = sum(m.rolled_back for m in mon.migrations)
    return {
        "fault_spec": spec_text,
        "n_requests": n_calls * BATCH,
        "completed_requests": completed,
        "serving_loop_crashes": 0,         # reaching here proves it
        "request_walls_s": walls,
        "drift_events": len(mon.drift_events),
        "probe_sweeps": len(mon.refreshes),
        "migrations": len(mon.migrations),
        "rollbacks": rollbacks,
        "kv_pool_final": mon.pool,
        "refresh": _refresh_stats(mon),
        "pass": bool(completed == n_calls * BATCH),
    }


def _reexec(argv) -> int:
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" in flags:
        raise RuntimeError(
            f"serve bench needs >= 2 devices but XLA_FLAGS already "
            f"pins the host device count ({flags!r})")
    env["XLA_FLAGS"] = f"{flags} {_FORCE}".strip()
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.serve_bench"] + argv,
        capture_output=True, text=True, timeout=1200, env=env)
    sys.stdout.write(r.stdout)
    if r.returncode != 0:
        raise RuntimeError(f"serve_bench subprocess failed:\n"
                           f"{r.stderr[-2000:]}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--chaos", action="store_true")
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--fail-if-slower", action="store_true")
    # under benchmarks.run main() is called with no argv: parse
    # defaults, not the harness's own filter arguments
    argv = argv if argv is not None else []
    args = ap.parse_args(argv)

    import jax
    if len(jax.devices()) < 2:
        return _reexec(argv)

    out = {
        "schema": 1,
        "bench": "serve",
        "n_devices": len(jax.devices()),
        "smoke": args.smoke,
    }
    out.update(_serve_legs(args.smoke))
    cd, st = out["curve_driven"], out["static"]
    print(f"drift episode: curve-driven {cd['tokens_per_s']} tok/s vs "
          f"static {st['tokens_per_s']} tok/s ({out['speedup']}x) — "
          f"{len(cd['drift_events'])} drift, {cd['probe_sweeps']} "
          f"sweeps, {len(cd['migrations'])} migrations "
          f"({cd['rollbacks']} rolled back) -> "
          f"{'PASS' if out['pass'] else 'FAIL'}")
    if args.chaos:
        ch = out["chaos"] = _chaos_leg(args.smoke)
        print(f"chaos [{ch['fault_spec']}]: "
              f"{ch['completed_requests']}/{ch['n_requests']} requests "
              f"completed, {ch['probe_sweeps']} sweeps "
              f"({ch['refresh']['sweeps_failed_flagged']} flagged), "
              f"{ch['migrations']} migrations, final pool "
              f"{ch['kv_pool_final']!r} -> "
              f"{'PASS' if ch['pass'] else 'FAIL'}")
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
        f.write("\n")
    print(f"wrote {args.out}")
    if args.fail_if_slower and not out["pass"]:
        print(f"PERF GATE FAILED: {GATE_CRITERION}")
        return 1
    if args.chaos and not out["chaos"]["pass"]:
        print("CHAOS GATE FAILED: a request did not complete")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
