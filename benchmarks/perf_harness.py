"""Perf harness: packed vs batched vs fused-per-ladder vs per-rung.

Times ``CoreCoordinator(backend="spmd")`` in four contender configs —
``packed`` (sweep-level megabatching + engine-subset width-packing:
narrow same-signature ladders run SIDE BY SIDE on disjoint engine
subsets of each stacked dispatch, the default), ``batched``
(megabatching with packing pinned off: one scan wave per stacked
ladder), ``fused`` (one dispatch per ladder, scanned psum sandwiches,
in-dispatch ``compat.device_clock`` rung timing) and ``per_rung`` (the
legacy 4-host-round-trips-per-rung path) — over a 64-scenario sweep
(16 with ``--smoke``) on 2- and 8-device meshes, plus a dedicated
WIDTH-PACKING section per leg (a sweep of 2-engine ladders, where
packing is at its strongest), and writes ``BENCH_spmd.json``
(schema 3): the committed perf trajectory for the spmd hot path.

    PYTHONPATH=src python -m benchmarks.perf_harness \
        [--smoke] [--out BENCH_spmd.json] [--fail-if-slower] \
        [--compile-cache-dir DIR]

Each mesh leg runs in a fresh subprocess (jax fixes the device count at
first init).  Per mode the sweep runs TWICE on one coordinator: the
cold pass pays tracing + fence verification + AOT compilation (ONE
program per distinct signature on the batched path, one per ladder
signature fused, K per signature per-rung), the warm pass is the
steady-state re-dispatch cost on cached programs.  Each mode reports
its distinct-program and AOT-compile counts next to its dispatch
counts, so the dispatch-vs-compile attribution is explicit rather than
inferred.  ``--compile-cache-dir`` opts into JAX's persistent
compilation cache (CI persists it across workflow runs via
actions/cache; host-callback-bearing programs are excluded by XLA —
see compat.persistent_cache).

``--smoke`` sizes the leg by ``REPRO_SPMD_DEVICES`` (the CI matrix
knob); ``--fail-if-slower`` exits non-zero when any measured leg fails
its perf gate (``GATE_CRITERION`` below: beat per-rung outright, stay
within a documented noise band of fused — whose dispatch-count
advantage is asserted structurally) — the gate verdict is recorded in
``BENCH_spmd.json`` either way.
"""
import argparse
import json
import os
import subprocess
import sys
import tempfile
import time

BUF = 256 << 10
ITERS = 40
# the smoke sweep is 4x smaller, so its per-ladder work must be larger
# for the warm-path gate to measure dispatch structure rather than
# scheduler noise: with tiny rungs the per-rung path's many cheap
# dispatches sit within noise of the batched path's few larger ones
SMOKE_ITERS = 120
MAX_STRESSORS = 3
CACHE_CAP = 128

# (name, spmd_dispatch, spmd_pack): packed is the shipped default
# config; batched pins packing off so the pair isolates what width-
# packing alone buys on the SAME grouped dispatch structure
MODES = (("packed", "batched", "auto"), ("batched", "batched", "off"),
         ("fused", "ladder", "off"), ("per_rung", "rung", "off"))
# The gate (both CI legs): the batched sweep must beat the per-rung
# path outright on the warm (steady-state) sweep, and must not lose to
# the fused-per-ladder path beyond a 10% noise band.  Batched and
# fused share identical in-dispatch work and differ only in dispatch
# count, so on smoke-sized sweeps their true wall-clock gap is a few
# milliseconds — smaller than shared-runner scheduler noise; the
# dispatch-count advantage itself is asserted STRUCTURALLY
# (host_sync_dispatches == distinct signatures, unconditionally), so a
# broken grouping fails the leg regardless of wall clock.  The
# committed full-sweep BENCH numbers show batched beating both paths
# outright on both legs.  The width-packing section adds its own gate:
# on a mesh wide enough to pack the 2-engine sweep (>= 2 subsets),
# packed must beat packing-off on the warm pass outright — packing
# strictly removes scan waves and idle-engine work from the dispatch.
FUSED_NOISE_BAND = 1.10
GATE_CRITERION = ("batched warm sweep < per_rung warm sweep AND "
                  "batched warm sweep <= fused warm sweep x "
                  f"{FUSED_NOISE_BAND} (noise band; dispatch advantage "
                  "asserted structurally) AND, where the mesh packs "
                  "the 2-engine sweep, packed warm < packing-off warm")


def _sweep_specs(smoke: bool):
    from repro.core.scenarios import TrafficShape, scenario_matrix
    shapes = [("w", TrafficShape.steady()),
              ("r", TrafficShape.mixed(1, 1)),
              ("c", TrafficShape.steady()),
              ("w", TrafficShape.burst(0.5)),
              ("y", TrafficShape.steady()),
              ("r", TrafficShape.mixed(2, 1)),
              ("m", TrafficShape.strided(8)),
              ("w", TrafficShape.burst(0.25))]
    if smoke:
        # 2 pools x 2 observers x 2 stress pools x 2 shapes = 16
        # scenarios — the pool axes repeat each role-program signature
        # (hbm/host share one effective memory kind here), so even the
        # smoke sweep exercises real >1-ladder stacking
        return scenario_matrix(pools=("hbm", "host"), buffer_bytes=BUF,
                               obs_strategies=("r", "w"),
                               stress_shapes=shapes[:2],
                               iters=SMOKE_ITERS,
                               max_stressors=MAX_STRESSORS)
    # 2 pools x 2 observers x 2 stress pools x 8 shapes = 64 scenarios
    return scenario_matrix(pools=("hbm", "host"), buffer_bytes=BUF,
                           obs_strategies=("r", "w"),
                           stress_shapes=shapes, iters=ITERS,
                           max_stressors=MAX_STRESSORS)


def _count_signatures(specs) -> int:
    """Distinct role-program signatures in the sweep (mode-independent:
    what the batched path stacks under, and the honest denominator for
    every mode's compiles-per-signature number)."""
    from repro.core.coordinator import CoreCoordinator
    coord = CoreCoordinator(backend="spmd")
    return len({coord._spmd_group_key(spec, obs, b)
                for spec in specs for obs in spec.observers
                for b in obs.buffers})


# 5 interleaved rounds, median per mode: on a shared 1-core runner
# single-run drift is a few percent — comparable to the true batched
# vs per-rung gap on the cheap 2-device full sweep — and a 3-sample
# median still let one slow outlier decide the gate
WARM_ROUNDS = 5


def _time_modes(specs, n_sig: int, cache_dir=None) -> dict:
    """Cold + warm timings for all four contenders.

    The cold pass runs once per mode; the warm (steady-state) passes
    are INTERLEAVED round-robin across the modes and reported as the
    per-mode median — the gate rides on the warm numbers, and on a
    shared runner the machine drifts (frequency, thread placement)
    on second timescales, so back-to-back blocks per mode would hand
    whichever mode ran during a fast phase a spurious win."""
    from repro.core.coordinator import CoreCoordinator
    # a cache cap that holds EVERY mode's full program set (per-rung
    # needs K programs per signature, fused/batched one): the
    # comparison must measure dispatch mechanics, not LRU evictions.
    # The default cap (32) is a memory bound; the batched and fused
    # paths fit it on this sweep, the per-rung path does not — which
    # is itself a consequence of fusing, recorded via the program
    # counts below.
    # absorb one-time PROCESS costs (backend init, compat probes, XLA
    # thread pools) before any timed pass: they belong to the process,
    # not to whichever contender happens to be timed first.  One
    # single-spec matrix on a throwaway coordinator; its program cache
    # dies with it, so no contender inherits compiled sweep programs.
    CoreCoordinator(backend="spmd").run_matrix(specs[:1])
    coords, colds, cold_stats = {}, {}, {}
    for name, dispatch, pack in MODES:
        # hermetic timing: faults pinned off (immune to a stray
        # REPRO_FAULT_SPEC in the environment) and the quality gate
        # off so no re-measure perturbs the dispatch accounting
        coord = CoreCoordinator(backend="spmd", spmd_dispatch=dispatch,
                                spmd_pack=pack,
                                spmd_cache_cap=CACHE_CAP,
                                compile_cache_dir=cache_dir,
                                faults=False, quality="off")
        t0 = time.perf_counter()
        cold_res = coord.run_matrix(specs)
        colds[name] = time.perf_counter() - t0
        cold_stats[name] = cold_res.stats
        coords[name] = coord
    warm_samples = {name: [] for name, _d, _p in MODES}
    warm_res = {}
    for _ in range(WARM_ROUNDS):
        for name, _dispatch, _pack in MODES:
            t0 = time.perf_counter()
            res = coords[name].run_matrix(specs)
            warm_samples[name].append(time.perf_counter() - t0)
            warm_res[name] = res
    modes = {}
    for name, dispatch, pack in MODES:
        st = warm_res[name].stats
        cst = cold_stats[name]
        warm = sorted(warm_samples[name])[WARM_ROUNDS // 2]
        # every executed rung of every curve must be the verified
        # sandwich
        assert all(run.execution["fenced"]
                   for run in warm_res[name].runs), \
            "unfenced executed ladder in the perf sweep"
        assert all(s.main.elapsed_ns > 0 for run in warm_res[name].runs
                   for s in run.scenarios if s.source == "executed")
        if dispatch == "batched":
            # the sweep-level claim: host-synchronous dispatches
            # collapse to the number of distinct program signatures —
            # width-packing reshapes dispatches, it never adds any
            assert st.host_sync_dispatches == st.spmd_groups == n_sig, \
                (st.host_sync_dispatches, st.spmd_groups, n_sig)
            assert all(run.execution["batched"]
                       for run in warm_res[name].runs)
        if pack == "off":
            assert st.packed_ladders == 0, (name, st.packed_ladders)
        modes[name] = {
            "wall_s_cold": round(colds[name], 3),
            "wall_s_warm": round(warm, 3),
            "wall_s_warm_samples": [round(w, 3)
                                    for w in warm_samples[name]],
            "wall_s_total": round(colds[name] + warm, 3),
            "n_ladders": st.n_ladders,
            "rungs_per_ladder": st.spmd_rungs // max(1, st.n_ladders),
            "measure_dispatches": st.measure_dispatches,
            "host_sync_dispatches": st.host_sync_dispatches,
            "host_sync_per_ladder": round(
                st.host_sync_dispatches / max(1, st.n_ladders), 3),
            "program_cache_hits": st.program_cache_hits,
            # compile attribution (cold pass): programs actually
            # built, how many AOT lower().compile()-ed, and the
            # per-signature compile count this mode pays
            "distinct_programs": cst.programs_built,
            "aot_compiles": cst.aot_compiles,
            "compiles_per_signature": round(
                cst.programs_built / max(1, n_sig), 3),
            "timing_source":
                warm_res[name].runs[0].execution["timing_source"],
            # width-packing accounting (0 unless this contender packs
            # and the mesh is wide enough for the sweep's ladders)
            "packed_ladders": st.packed_ladders,
            "subset_width": st.subset_width,
        }
    return modes


def _packing_section(n_dev: int, cache_dir=None) -> dict:
    """The width-packing showcase: a sweep of 2-engine ladders
    (observer + ONE stressor), where a wide mesh packs
    ``n_dev // 2`` ladders side by side per dispatch.  Times the
    default (packed) against the same grouped dispatch with packing
    pinned off; the structural claims (ladders per host sync, subset
    accounting) are asserted unconditionally, the wall-clock gate only
    where the mesh actually packs."""
    from repro.core.scenarios import TrafficShape, scenario_matrix
    from repro.core.coordinator import CoreCoordinator
    shapes = [("w", TrafficShape.steady()),
              ("r", TrafficShape.mixed(1, 1))]
    # 2 pools x 2 observers x 2 stress pools x 2 shapes = 16 narrow
    # ladders; the pool axes repeat each signature, so every group
    # stacks >= 2 ladders and a >= 4-engine mesh packs them
    specs = scenario_matrix(pools=("hbm", "host"), buffer_bytes=BUF,
                            obs_strategies=("r", "w"),
                            stress_shapes=shapes, iters=SMOKE_ITERS,
                            max_stressors=1)
    width = min(2, n_dev)
    n_subsets = n_dev // width if n_dev >= 2 * width else 1
    coords, section = {}, {}
    for name, pack in (("packed", "auto"), ("packing_off", "off")):
        coords[name] = CoreCoordinator(backend="spmd",
                                       spmd_pack=pack,
                                       spmd_cache_cap=CACHE_CAP,
                                       compile_cache_dir=cache_dir,
                                       faults=False, quality="off")
        t0 = time.perf_counter()
        coords[name].run_matrix(specs)
        section[name] = {"wall_s_cold":
                         round(time.perf_counter() - t0, 3)}
    warm_samples = {name: [] for name in coords}
    warm_res = {}
    for _ in range(WARM_ROUNDS):
        for name, coord in coords.items():
            t0 = time.perf_counter()
            warm_res[name] = coord.run_matrix(specs)
            warm_samples[name].append(time.perf_counter() - t0)
    for name, res in warm_res.items():
        st = res.stats
        assert all(run.execution["fenced"] for run in res.runs)
        section[name].update({
            "wall_s_warm": sorted(warm_samples[name])[WARM_ROUNDS // 2],
            "wall_s_warm_samples": [round(w, 3)
                                    for w in warm_samples[name]],
            "host_sync_dispatches": st.host_sync_dispatches,
            "ladders_per_dispatch": round(
                st.n_ladders / max(1, st.host_sync_dispatches), 2),
            "packed_ladders": st.packed_ladders,
            "subset_width": st.subset_width,
        })
    packed, off = section["packed"], section["packing_off"]
    # packing reshapes the stacked dispatches, it never adds any: both
    # configs sync once per signature, with every ladder on board
    assert packed["host_sync_dispatches"] == off["host_sync_dispatches"]
    assert off["packed_ladders"] == 0
    if n_subsets > 1:
        # every narrow ladder really ran in a width-`width` subset...
        assert packed["packed_ladders"] == len(specs), packed
        assert packed["subset_width"] == width, packed
        # ...and a wide mesh runs >= 4 ladders per host sync (the
        # stacked groups guarantee >= 2 even unpacked)
        if n_dev >= 4 * width:
            assert packed["ladders_per_dispatch"] >= 4, packed
    else:
        assert packed["packed_ladders"] == 0, packed
    gate_pass = (n_subsets == 1
                 or packed["wall_s_warm"] < off["wall_s_warm"])
    section.update({
        "n_scenarios": len(specs),
        "iters": SMOKE_ITERS,
        "ladder_width": width,
        "n_subsets": n_subsets,
        "speedup_packed_warm": round(
            off["wall_s_warm"] / max(packed["wall_s_warm"], 1e-9), 3),
        "gate": {"active": n_subsets > 1, "pass": gate_pass,
                 "packed_warm_s": round(packed["wall_s_warm"], 3),
                 "packing_off_warm_s": round(off["wall_s_warm"], 3)},
    })
    for name in coords:
        section[name]["wall_s_warm"] = round(
            section[name]["wall_s_warm"], 3)
    return section


def _run_leg(smoke: bool, cache_dir=None) -> dict:
    import jax
    n_dev = len(jax.devices())
    assert n_dev >= 2, "perf harness leg needs a multi-device mesh"
    specs = _sweep_specs(smoke)
    n_sig = _count_signatures(specs)
    cache_prewarmed = bool(cache_dir and os.path.isdir(cache_dir)
                           and os.listdir(cache_dir))
    modes = _time_modes(specs, n_sig, cache_dir)
    packed, batched, fused, per_rung = (modes["packed"],
                                        modes["batched"],
                                        modes["fused"],
                                        modes["per_rung"])
    assert packed["timing_source"] == "device", packed
    assert batched["timing_source"] == "device", batched
    assert fused["timing_source"] == "device", fused
    assert per_rung["timing_source"] == "host", per_rung
    k = fused["rungs_per_ladder"]

    def _ratios(a, b):
        return {kk: round(b[f"wall_s_{kk}"] / a[f"wall_s_{kk}"], 3)
                for kk in ("cold", "warm", "total")}

    packing = _packing_section(n_dev, cache_dir)
    gate_pass = (batched["wall_s_warm"] < per_rung["wall_s_warm"]
                 and batched["wall_s_warm"]
                 <= fused["wall_s_warm"] * FUSED_NOISE_BAND
                 and packing["gate"]["pass"])
    leg = {
        "devices": n_dev,
        "n_scenarios": len(specs),
        "ladder_rungs": k,
        "distinct_signatures": n_sig,
        "persistent_cache": bool(cache_dir),
        "cache_prewarmed": cache_prewarmed,
        "packed": packed,
        "batched": batched,
        "fused": fused,
        "per_rung": per_rung,
        # the dedicated 2-engine-ladder sweep: width-packing's best
        # case, with its own warm-pass gate where the mesh packs it
        "width_packing": packing,
        # the sweep cost a characterization run actually pays: tracing
        # + fence verification + AOT compile + dispatch (cold) and the
        # steady-state re-dispatch on cached programs (warm).  The
        # batched path compiles ONE program per distinct signature and
        # blocks the host once per signature per sweep, where fused
        # blocks once per ladder and per-rung 4K times per ladder.
        "speedup_batched_vs_fused": _ratios(batched, fused),
        "speedup_batched_vs_per_rung": _ratios(batched, per_rung),
        "speedup_fused_vs_per_rung": _ratios(fused, per_rung),
        "speedup_packed_vs_batched": _ratios(packed, batched),
        "dispatch_reduction_vs_fused": round(
            fused["host_sync_dispatches"]
            / batched["host_sync_dispatches"], 2),
        "dispatch_reduction_vs_per_rung": round(
            per_rung["host_sync_dispatches"]
            / batched["host_sync_dispatches"], 2),
        # the perf gate verdict (CI fails the leg on it with
        # --fail-if-slower): steady-state sweep, batched vs both
        "gate": {
            "criterion": GATE_CRITERION,
            "pass": gate_pass,
            "batched_warm_s": batched["wall_s_warm"],
            "fused_warm_s": fused["wall_s_warm"],
            "per_rung_warm_s": per_rung["wall_s_warm"],
            "packing_gate": packing["gate"],
        },
    }
    # the structural claims hold regardless of machine noise: the
    # batched sweep syncs once per SIGNATURE (packed or not), fused
    # once per LADDER, per-rung 4 times per RUNG
    assert packed["host_sync_dispatches"] == n_sig, leg
    assert batched["host_sync_dispatches"] == n_sig, leg
    assert fused["host_sync_per_ladder"] <= 2, leg
    assert per_rung["host_sync_per_ladder"] == 4 * k, leg
    assert leg["dispatch_reduction_vs_per_rung"] >= 3, leg
    # and the batched path compiles exactly one program per signature
    assert batched["distinct_programs"] <= n_sig, leg
    # the main sweep's ladders occupy k engines; the mesh packs them
    # exactly when a second k-engine subset fits
    assert (packed["packed_ladders"] > 0) == (n_dev >= 2 * k), leg
    return leg


_FORCE = "--xla_force_host_platform_device_count"


def _spawn_leg(n_dev: int, smoke: bool, cache_dir=None) -> dict:
    """One mesh size = one fresh interpreter (the harness process never
    initialises jax, so every leg gets its own device count)."""
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" in flags:
        raise RuntimeError(
            f"XLA_FLAGS already pins the host device count ({flags!r}); "
            f"unset it — the perf harness forces its own mesh per leg")
    env["XLA_FLAGS"] = f"{flags} {_FORCE}={n_dev}".strip()
    with tempfile.TemporaryDirectory() as d:
        frag = os.path.join(d, "leg.json")
        cmd = [sys.executable, "-m", "benchmarks.perf_harness",
               "--_leg", str(n_dev), "--_fragment", frag]
        if smoke:
            cmd.append("--smoke")
        if cache_dir:
            cmd += ["--compile-cache-dir", cache_dir]
        r = subprocess.run(cmd, env=env, timeout=1800)
        if r.returncode != 0:
            raise RuntimeError(f"perf harness {n_dev}-device leg failed")
        with open(frag) as f:
            return json.load(f)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small sweep, single leg (CI)")
    ap.add_argument("--out", default="BENCH_spmd.json")
    ap.add_argument("--fail-if-slower", action="store_true",
                    help="exit 1 if any measured leg fails its perf "
                         "gate (batched must beat per-rung warm and "
                         "stay within the fused noise band)")
    ap.add_argument("--compile-cache-dir", default=None,
                    help="enable JAX's persistent compilation cache "
                         "at this directory (CI persists it across "
                         "runs)")
    ap.add_argument("--_leg", type=int, default=None,
                    help=argparse.SUPPRESS)
    ap.add_argument("--_fragment", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args(argv)

    if args._leg is not None:            # subprocess mode: one mesh leg
        leg = _run_leg(args.smoke, args.compile_cache_dir)
        with open(args._fragment, "w") as f:
            json.dump(leg, f)
        return 0

    if args.smoke:
        legs = [max(2, int(os.environ.get("REPRO_SPMD_DEVICES", "8")))]
    else:
        legs = [2, 8]
    out = {
        "schema": 3,
        "bench": "spmd_packed_vs_batched_vs_fused_vs_per_rung",
        "generated_by": "benchmarks/perf_harness.py"
                        + (" --smoke" if args.smoke else ""),
        "n_scenarios": 16 if args.smoke else 64,
        "iters": SMOKE_ITERS if args.smoke else ITERS,
        "buffer_bytes": BUF,
        "spmd_cache_cap": CACHE_CAP,
        "gate_criterion": GATE_CRITERION,
        "legs": {},
    }

    def _write():
        with open(args.out, "w") as f:
            json.dump(out, f, indent=1)
            f.write("\n")

    for n_dev in legs:
        print(f"== perf harness: {n_dev}-device leg "
              f"({out['n_scenarios']} scenarios) ==")
        leg = _spawn_leg(n_dev, args.smoke, args.compile_cache_dir)
        out["legs"][str(n_dev)] = leg
        for mode, _dispatch, _pack in MODES:
            m = leg[mode]
            print(f"   {mode:8s} cold {m['wall_s_cold']:7.3f}s  warm "
                  f"{m['wall_s_warm']:7.3f}s  "
                  f"{m['host_sync_dispatches']} syncs/sweep  "
                  f"{m['distinct_programs']} programs "
                  f"({m['aot_compiles']} AOT)  [{m['timing_source']}]")
        print(f"   {leg['distinct_signatures']} distinct signatures; "
              f"batched warm speedup: "
              f"{leg['speedup_batched_vs_fused']['warm']}x vs fused, "
              f"{leg['speedup_batched_vs_per_rung']['warm']}x vs "
              f"per-rung; gate "
              f"{'PASS' if leg['gate']['pass'] else 'FAIL'}")
        wp = leg["width_packing"]
        print(f"   width-packing ({wp['n_scenarios']} x "
              f"{wp['ladder_width']}-engine ladders, "
              f"{wp['n_subsets']} subsets): packed warm "
              f"{wp['packed']['wall_s_warm']:.3f}s vs off "
              f"{wp['packing_off']['wall_s_warm']:.3f}s "
              f"({wp['speedup_packed_warm']}x), "
              f"{wp['packed']['ladders_per_dispatch']} ladders/sync")
    _write()
    print(f"wrote {args.out}")

    if args.fail_if_slower:
        for n_dev in legs:
            leg = out["legs"][str(n_dev)]
            if not leg["gate"]["pass"]:
                # the structural claims (sync-per-signature, program
                # counts) are asserted unconditionally inside every
                # leg; the wall-clock sign additionally rides on a
                # noisy shared runner, so re-measure once before
                # declaring a regression
                print(f"{n_dev}-device gate failed "
                      f"({leg['gate']}); re-measuring once to "
                      f"separate regression from noise")
                retry = _spawn_leg(n_dev, args.smoke,
                                   args.compile_cache_dir)
                if retry["gate"]["pass"]:
                    out["legs"][str(n_dev)] = retry
                    _write()
            if not out["legs"][str(n_dev)]["gate"]["pass"]:
                print(f"FAIL: perf gate on the {n_dev}-device leg: "
                      f"{out['legs'][str(n_dev)]['gate']}",
                      file=sys.stderr)
                return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
