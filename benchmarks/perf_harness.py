"""Perf harness: fused whole-ladder dispatch vs legacy per-rung spmd.

Times ``CoreCoordinator(backend="spmd")`` in both dispatch modes —
``spmd_dispatch="ladder"`` (ONE fused dispatch per ladder, scanned psum
sandwiches, in-dispatch ``compat.device_clock`` rung timing) against
``"rung"`` (the legacy 4-host-round-trips-per-rung path) — over a
64-scenario sweep (8 with ``--smoke``) on 2- and 8-device meshes, and
writes ``BENCH_spmd.json``: the committed perf trajectory for the spmd
hot path.

    PYTHONPATH=src python -m benchmarks.perf_harness \
        [--smoke] [--out BENCH_spmd.json] [--fail-if-slower]

Each mesh leg runs in a fresh subprocess (jax fixes the device count at
first init).  Per mode the sweep runs TWICE on one coordinator: the
cold pass pays tracing + fence verification + compilation (the fused
path builds ONE program per ladder where the per-rung path builds K),
the warm pass is the steady-state re-dispatch cost on cached programs.
``--smoke`` sizes the leg by ``REPRO_SPMD_DEVICES`` (the CI matrix
knob); ``--fail-if-slower`` exits non-zero when the fused TOTAL sweep
(cold + warm) is slower than the per-rung one on the largest leg — the
CI perf gate.
"""
import argparse
import json
import os
import subprocess
import sys
import tempfile
import time

BUF = 256 << 10
ITERS = 40
MAX_STRESSORS = 3
CACHE_CAP = 128


def _sweep_specs(smoke: bool):
    from repro.core.scenarios import TrafficShape, scenario_matrix
    shapes = [("w", TrafficShape.steady()),
              ("r", TrafficShape.mixed(1, 1)),
              ("c", TrafficShape.steady()),
              ("w", TrafficShape.burst(0.5)),
              ("y", TrafficShape.steady()),
              ("r", TrafficShape.mixed(2, 1)),
              ("m", TrafficShape.strided(8)),
              ("w", TrafficShape.burst(0.25))]
    if smoke:
        # 1 pool x 2 observers x 1 stress pool x 4 shapes = 8 scenarios
        return scenario_matrix(pools=("hbm",), buffer_bytes=BUF,
                               obs_strategies=("r", "w"),
                               stress_shapes=shapes[:4], iters=ITERS,
                               max_stressors=MAX_STRESSORS)
    # 2 pools x 2 observers x 2 stress pools x 8 shapes = 64 scenarios
    return scenario_matrix(pools=("hbm", "host"), buffer_bytes=BUF,
                           obs_strategies=("r", "w"),
                           stress_shapes=shapes, iters=ITERS,
                           max_stressors=MAX_STRESSORS)


def _time_mode(dispatch: str, specs) -> dict:
    from repro.core.coordinator import CoreCoordinator
    # a cache cap that holds BOTH paths' full program sets (the
    # per-rung path needs K programs per ladder signature, the fused
    # path one): the comparison must measure dispatch mechanics, not
    # LRU evictions.  The default cap (32) is a memory bound; the
    # fused path fits it on this sweep, the per-rung path does not —
    # which is itself a consequence of fusing, recorded via
    # program_cache_hits.
    coord = CoreCoordinator(backend="spmd", spmd_dispatch=dispatch,
                            spmd_cache_cap=CACHE_CAP)
    t0 = time.perf_counter()
    coord.run_matrix(specs)
    cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    warm_res = coord.run_matrix(specs)
    warm = time.perf_counter() - t0
    st = warm_res.stats
    # every executed rung of every curve must be the verified sandwich
    assert all(run.execution["fenced"] for run in warm_res.runs), \
        "unfenced executed ladder in the perf sweep"
    assert all(s.main.elapsed_ns > 0 for run in warm_res.runs
               for s in run.scenarios if s.source == "executed")
    return {
        "wall_s_cold": round(cold, 3),
        "wall_s_warm": round(warm, 3),
        "wall_s_total": round(cold + warm, 3),
        "n_ladders": st.n_ladders,
        "rungs_per_ladder": st.spmd_rungs // max(1, st.n_ladders),
        "measure_dispatches": st.measure_dispatches,
        "host_sync_dispatches": st.host_sync_dispatches,
        "host_sync_per_ladder": round(
            st.host_sync_dispatches / max(1, st.n_ladders), 3),
        "program_cache_hits": st.program_cache_hits,
        "timing_source": warm_res.runs[0].execution["timing_source"],
    }


def _run_leg(smoke: bool) -> dict:
    import jax
    n_dev = len(jax.devices())
    assert n_dev >= 2, "perf harness leg needs a multi-device mesh"
    specs = _sweep_specs(smoke)
    fused = _time_mode("ladder", specs)
    per_rung = _time_mode("rung", specs)
    assert fused["timing_source"] == "device", fused
    assert per_rung["timing_source"] == "host", per_rung
    k = fused["rungs_per_ladder"]
    leg = {
        "devices": n_dev,
        "n_scenarios": len(specs),
        "ladder_rungs": k,
        "fused": fused,
        "per_rung": per_rung,
        # the sweep cost a characterization run actually pays: tracing
        # + fence verification + compile + dispatch (cold) and the
        # steady-state re-dispatch on cached programs (warm).  The
        # fused path builds/verifies/compiles ONE program per ladder
        # where the per-rung path builds K, and dispatches once where
        # it blocks 4K times — "total" is what the CI gate holds.
        "speedup_cold": round(
            per_rung["wall_s_cold"] / fused["wall_s_cold"], 3),
        "speedup_warm": round(
            per_rung["wall_s_warm"] / fused["wall_s_warm"], 3),
        "speedup_total": round(
            per_rung["wall_s_total"] / fused["wall_s_total"], 3),
        "dispatch_reduction_per_ladder": round(
            per_rung["host_sync_per_ladder"]
            / fused["host_sync_per_ladder"], 2),
    }
    # the structural claims hold regardless of machine noise:
    # 4 host-synchronous dispatches per RUNG collapse to <= 2 per LADDER
    assert fused["host_sync_per_ladder"] <= 2, leg
    assert per_rung["host_sync_per_ladder"] == 4 * k, leg
    assert leg["dispatch_reduction_per_ladder"] >= 3, leg
    return leg


_FORCE = "--xla_force_host_platform_device_count"


def _spawn_leg(n_dev: int, smoke: bool) -> dict:
    """One mesh size = one fresh interpreter (the harness process never
    initialises jax, so every leg gets its own device count)."""
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" in flags:
        raise RuntimeError(
            f"XLA_FLAGS already pins the host device count ({flags!r}); "
            f"unset it — the perf harness forces its own mesh per leg")
    env["XLA_FLAGS"] = f"{flags} {_FORCE}={n_dev}".strip()
    with tempfile.TemporaryDirectory() as d:
        frag = os.path.join(d, "leg.json")
        cmd = [sys.executable, "-m", "benchmarks.perf_harness",
               "--_leg", str(n_dev), "--_fragment", frag]
        if smoke:
            cmd.append("--smoke")
        r = subprocess.run(cmd, env=env, timeout=1800)
        if r.returncode != 0:
            raise RuntimeError(f"perf harness {n_dev}-device leg failed")
        with open(frag) as f:
            return json.load(f)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small sweep, single leg (CI)")
    ap.add_argument("--out", default="BENCH_spmd.json")
    ap.add_argument("--fail-if-slower", action="store_true",
                    help="exit 1 if fused is slower than per-rung on "
                         "the largest leg")
    ap.add_argument("--_leg", type=int, default=None,
                    help=argparse.SUPPRESS)
    ap.add_argument("--_fragment", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args(argv)

    if args._leg is not None:            # subprocess mode: one mesh leg
        leg = _run_leg(args.smoke)
        with open(args._fragment, "w") as f:
            json.dump(leg, f)
        return 0

    if args.smoke:
        legs = [max(2, int(os.environ.get("REPRO_SPMD_DEVICES", "8")))]
    else:
        legs = [2, 8]
    out = {
        "schema": 1,
        "bench": "spmd_fused_ladder_vs_per_rung",
        "generated_by": "benchmarks/perf_harness.py"
                        + (" --smoke" if args.smoke else ""),
        "n_scenarios": 8 if args.smoke else 64,
        "iters": ITERS,
        "buffer_bytes": BUF,
        "spmd_cache_cap": CACHE_CAP,
        "legs": {},
    }
    for n_dev in legs:
        print(f"== perf harness: {n_dev}-device leg "
              f"({out['n_scenarios']} scenarios) ==")
        leg = _spawn_leg(n_dev, args.smoke)
        out["legs"][str(n_dev)] = leg
        for mode in ("fused", "per_rung"):
            m = leg[mode]
            print(f"   {mode:8s} cold {m['wall_s_cold']:7.3f}s  warm "
                  f"{m['wall_s_warm']:7.3f}s  "
                  f"{m['host_sync_per_ladder']:.1f} sync "
                  f"dispatches/ladder  [{m['timing_source']}]")
        print(f"   speedup: cold {leg['speedup_cold']}x, warm "
              f"{leg['speedup_warm']}x, total {leg['speedup_total']}x; "
              f"dispatch reduction "
              f"{leg['dispatch_reduction_per_ladder']}x")
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
        f.write("\n")
    print(f"wrote {args.out}")

    gate_leg = str(max(legs))
    if args.fail_if_slower and out["legs"][gate_leg]["speedup_total"] < 1.0:
        # the structural claims (dispatch_reduction >= 3x, <= 2 syncs
        # per ladder) are asserted unconditionally inside every leg;
        # the wall-clock sign additionally rides on a noisy shared
        # runner, so re-measure once before declaring the fused path
        # slower
        print(f"gate leg measured speedup_total "
              f"{out['legs'][gate_leg]['speedup_total']} < 1.0; "
              f"re-measuring once to separate regression from noise")
        retry = _spawn_leg(max(legs), args.smoke)
        if retry["speedup_total"] > out["legs"][gate_leg]["speedup_total"]:
            out["legs"][gate_leg] = retry
            with open(args.out, "w") as f:
                json.dump(out, f, indent=1)
                f.write("\n")
        if out["legs"][gate_leg]["speedup_total"] < 1.0:
            print(f"FAIL: fused path slower than per-rung on the "
                  f"{gate_leg}-device leg (total-sweep speedup "
                  f"{out['legs'][gate_leg]['speedup_total']})",
                  file=sys.stderr)
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
