"""Tables II/III — MLP via Little's law.

Paper (ZCU102, worst-case scenario): DRAM (l,r)x(r,r) lat 161.9 ns, MLP
4.85; DRAM (l,w)x(r,w) lat 318.6 ns, MLP 4.45; PL-DRAM 399.5 ns / 3.99
and 1386.8 ns / 4.16.  The reproduction must land in the same regime —
comparable MLP for both modules despite very different latencies (the
shared-CCI-entry insight that drives §IV-B(4)).
"""
from repro.core import simulate as sim
from repro.core.devicetree import TPU_V5E, ZCU102
from benchmarks.common import print_table

PAPER = {  # (lat_ns, mlp) at worst case, for reference
    ("dram", "r"): (161.89, 4.85), ("dram", "w"): (318.56, 4.45),
    ("pl-dram", "r"): (399.49, 3.99), ("pl-dram", "w"): (1386.80, 4.16),
}


def mlp_row(plat, mem: str, stress: str) -> dict:
    lat = sim.scenario_ladder(
        plat, obs_node=plat.node(mem), obs_strategy="l",
        stress_node=plat.node(mem), stress_strategy=stress)[-1]["obs"].lat_ns
    bw = sim.scenario_ladder(
        plat, obs_node=plat.node(mem), obs_strategy="r",
        stress_node=plat.node(mem), stress_strategy=stress)[-1]["obs"].bw_gbps
    tx = bw / plat.line_bytes
    row = {"platform": plat.name, "pool": mem,
           "pairing": f"(l,{stress})x(r,{stress})",
           "lat_ns_per_tx": round(lat, 2),
           "bw_tx_per_ns": round(tx, 4),
           "mlp": round(lat * tx, 2)}
    ref = PAPER.get((mem, stress))
    if ref:
        row["paper_lat_ns"] = ref[0]
        row["paper_mlp"] = ref[1]
    return row


def main() -> list:
    rows = [mlp_row(ZCU102, mem, s)
            for mem in ("dram", "pl-dram") for s in ("r", "w")]
    rows += [mlp_row(TPU_V5E, mem, s)
             for mem in ("hbm", "host") for s in ("r", "w")]
    print_table("Tables II/III — Little's-law MLP (worst-case scenario)",
                rows)
    # the paper's key observation: comparable MLP across modules
    z = [r["mlp"] for r in rows if r["platform"] == "zcu102"]
    assert max(z) / min(z) < 2.5, z
    return rows


if __name__ == "__main__":
    main()
