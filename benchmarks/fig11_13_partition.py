"""Fig. 11-13 — cache partitioning and bank contention.

Paper (ZCU102 + Jailhouse page coloring, 25% private L2 as pvtpool):
  Fig. 11: partitioning does NOT help when all cores hit in L2 —
           hit-path bank contention survives partitioning.
  Fig. 12: partitioning DOES help when stressors miss to DRAM —
           except (r,w)/(w,w) miss-path bank contention.
  Fig. 13: >=2 write-streaming (y) stressors collapse bandwidth ~40x
           despite partitioning (writeback-buffer exhaustion).
"""
from repro.core.coordinator import ActivitySpec
from benchmarks.common import coordinator, ladder_rows, print_table

HIT = 256 << 10          # fits the 1 MiB L2 / 256 KiB partition
MISS = 4 << 20           # forces DRAM misses


def main() -> list:
    shared = coordinator("zcu102")
    import repro.core.devicetree as dt
    from repro.core.coordinator import CoreCoordinator
    from repro.core.pools import PoolManager
    part_plat = dt.zcu102_partitioned()
    part = CoreCoordinator(PoolManager(part_plat), part_plat,
                           backend="simulate")

    rows = []
    # Fig. 11: all-hit, partition off vs on
    for a, b in (("r", "r"), ("r", "w")):
        rows += ladder_rows(shared, ActivitySpec(a, "dram", HIT),
                            ActivitySpec(b, "dram", HIT),
                            f"fig11/shared/({a},{b})")
        rows += ladder_rows(part, ActivitySpec(a, "pvtpool", HIT),
                            ActivitySpec(b, "dram", HIT),
                            f"fig11/pvtpool/({a},{b})")
    # Fig. 12: obs hits private pool, stressors miss to DRAM
    for a, b in (("r", "r"), ("r", "w"), ("w", "w")):
        rows += ladder_rows(part, ActivitySpec(a, "pvtpool", HIT),
                            ActivitySpec(b, "dram", MISS),
                            f"fig12/pvtpool/({a},{b})")
    # Fig. 13: normal write stress vs write-streaming stress
    rows += ladder_rows(part, ActivitySpec("r", "pvtpool", HIT),
                        ActivitySpec("w", "dram", MISS), "fig13/(r,w*)=w")
    rows += ladder_rows(part, ActivitySpec("r", "pvtpool", HIT),
                        ActivitySpec("y", "dram", MISS), "fig13/(r,w*)=y")
    print_table("Fig.11-13 cache partitioning / bank contention", rows)

    def bw(case, k):
        return next(r["bw_GBps"] for r in rows
                    if r["case"] == case and r["stressors"] == k)

    # Fig. 11: hit-path contention: partitioned still degrades notably
    assert bw("fig11/pvtpool/(r,r)", 3) < 0.75 * bw("fig11/pvtpool/(r,r)", 0)
    # Fig. 12: partitioning helps for read-miss stressors...
    assert bw("fig12/pvtpool/(r,r)", 3) > bw("fig11/shared/(r,r)", 3)
    # Fig. 13: y-streams collapse bandwidth at >=2 stressors, identical at 1
    assert bw("fig13/(r,w*)=y", 1) > 0.5 * bw("fig13/(r,w*)=w", 1)
    assert bw("fig13/(r,w*)=y", 3) < 0.2 * bw("fig13/(r,w*)=w", 3)
    return rows


if __name__ == "__main__":
    main()
