"""Fig. 5 — homogeneous latency ladders + buffer-size sweeps.

Paper: (l,r)/(l,w) ladders for DRAM and PL-DRAM, plus latency-vs-buffer-
size line plots where caching effects vanish beyond the effective cache
share (1 MiB at 0 stressors, 256 KiB at 3).  The sweep reproduces that
knee: cacheable chases below the L2 share resolve at cache latency.
"""
from repro.core.coordinator import ActivitySpec
from benchmarks.common import coordinator, ladder_rows, print_table

BUF = 4 << 20


def main() -> list:
    zc = coordinator("zcu102")
    rows = []
    for mem in ("dram", "pl-dram"):
        for stress in ("r", "w"):
            rows += ladder_rows(
                zc, ActivitySpec("l", mem, BUF),
                ActivitySpec(stress, mem, BUF),
                f"zcu102/{mem}/(l,{stress})")
    print_table("Fig.5 homogeneous latency ladders (ns vs stressors)",
                rows)

    sweep = []
    for kib in (64, 128, 256, 512, 1024, 2048, 4096):
        buf = kib << 10
        for stressors, label in ((1, "0stress"), (4, "3stress")):
            import dataclasses
            from repro.core.coordinator import ExperimentConfig
            res = zc.run(ExperimentConfig(
                main=ActivitySpec("l", "dram", buf),
                stress=ActivitySpec("w", "dram", BUF), iters=100,
                scenarios=stressors))
            sweep.append({"case": f"dram/(l,w)/{label}",
                          "buffer_KiB": kib,
                          "lat_ns": round(
                              res.scenarios[-1].modeled_lat_ns, 2)})
    print_table("Fig.5 (bottom) latency vs buffer size", sweep)
    # knee check: small cacheable buffers resolve in cache, big ones in DRAM
    small = next(r for r in sweep
                 if r["buffer_KiB"] == 256 and "0stress" in r["case"])
    big = next(r for r in sweep
               if r["buffer_KiB"] == 4096 and "0stress" in r["case"])
    assert small["lat_ns"] < 0.6 * big["lat_ns"], (small, big)
    return rows + sweep


if __name__ == "__main__":
    main()
