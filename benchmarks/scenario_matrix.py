"""Scenario-matrix characterization v2 — the full shaped sweep.

Runs the declarative scenario matrix (mixed read/write ratios,
bursty/duty-cycled stress, copy streams, strided chases — on top of the
seed's steady ladder) end-to-end:

  1. >= 64-scenario sweep on the ``simulate`` backend -> CurveDB v2
     (schema-tagged, provenance-carrying), consumed by the placement
     advisor below;
  2. the same matrix class on the ``interpret`` backend, measuring real
     Pallas kernels, comparing the batched runner's dispatch count
     against the naive per-point loop;
  3. a placement decision driven by a *shaped* contention spec.
"""
from repro.core.characterize import characterize_matrix
from repro.core.coordinator import CoreCoordinator
from repro.core.placement import ContentionSpec, MemObject, PlacementAdvisor
from repro.core.scenarios import (DEFAULT_STRESS_SHAPES, TrafficShape,
                                  scenario_matrix)
from benchmarks.common import coordinator, print_table

BUF = 64 << 20


def main() -> list:
    # -- 1. shaped sweep, simulate backend --------------------------------
    coord = coordinator()
    specs = scenario_matrix(pools=["hbm", "host"], buffer_bytes=BUF,
                            obs_strategies=("r", "w", "l"),
                            stress_shapes=DEFAULT_STRESS_SHAPES,
                            iters=50)
    assert len(specs) >= 64, len(specs)
    db = characterize_matrix(coord, specs)
    rows = []
    for key in sorted(db.curves):
        pts = db.curves[key]
        rows.append({
            "scenario": key,
            "bw0_GBps": round(pts[0].bandwidth_gbps, 1),
            "bwN_GBps": round(pts[-1].bandwidth_gbps, 1),
            "latN_ns": round(pts[-1].latency_ns, 1),
        })
    print_table(f"scenario matrix ({len(specs)} scenarios, "
                f"CurveDB schema {db.schema})", rows[:16])
    print(f"... {len(rows) - 16} more curves; "
          f"meta={db.meta}")

    # shaped-physics headline checks
    def bw(key, k):
        return db.curves[key][k].bandwidth_gbps
    # a 50%-duty write burst degrades the observer less than steady writes
    assert bw("hbm:r|hbm:w@dc0.50", 7) > bw("hbm:r|hbm:w", 7)
    # more write share in the mix -> more WAWB amplification -> worse
    rf12, rf21 = TrafficShape.mixed(1, 2).tag(), TrafficShape.mixed(2, 1).tag()
    assert bw(f"hbm:r|hbm:r@{rf12}", 7) < bw(f"hbm:r|hbm:r@{rf21}", 7)

    # -- 2. batched vs naive dispatches, interpret backend ------------------
    ic = coordinator(backend="interpret")
    small = scenario_matrix(pools=["hbm", "host"], buffer_bytes=64 << 10,
                            obs_strategies=("r", "w"),
                            stress_shapes=DEFAULT_STRESS_SHAPES[:8],
                            iters=2, max_stressors=1)
    res_b = ic.run_matrix(small, batched=True)
    res_n = ic.run_matrix(small, batched=False)
    print(f"interpret sweep: {len(small)} scenarios -> "
          f"batched {res_b.stats.measure_dispatches} dispatches vs "
          f"naive {res_n.stats.measure_dispatches}")
    assert res_b.stats.measure_dispatches < res_n.stats.measure_dispatches

    # -- 3. placement under shaped contention -------------------------------
    adv = PlacementAdvisor(db, coord.platform, pools=["hbm", "host"])
    heap = MemObject("heap", 1 << 20, bytes_per_step=1 << 20)
    for shape in (TrafficShape.steady(), TrafficShape.burst(0.5),
                  TrafficShape.mixed(1, 2)):
        strat = "r" if shape.kind == "mixed" else "w"
        c = ContentionSpec.shaped(7, "hbm", strat, shape)
        t = adv.predict_ns(heap, "hbm", c)
        print(f"heap@hbm under {strat}{'@' + shape.tag() if shape.tag() else '':9s}"
              f" stress: {t / 1e3:8.1f} us/step")
    return rows


if __name__ == "__main__":
    main()
