"""Fig. 6/7 — heterogeneous bandwidth/latency: observed pool != stressed
pool.  The paper's counterintuitive result: saturating the SLOW module
(PL-DRAM) degrades the FAST module (DRAM), because slow transactions
occupy shared CCI queue entries longer ("Obs: DRAM, Int: PL-DRAM" red
curves).  The reverse case barely reacts.
"""
from repro.core.coordinator import ActivitySpec
from benchmarks.common import coordinator, ladder_rows, print_table

BUF = 4 << 20


def main() -> list:
    zc = coordinator("zcu102")
    rows = []
    for obs, intf in (("dram", "pl-dram"), ("pl-dram", "dram")):
        for strat in ("s", "l"):
            rows += ladder_rows(
                zc, ActivitySpec(strat, obs, BUF),
                ActivitySpec("x", intf, BUF),
                f"obs:{obs}/int:{intf}/({strat},x)")
    v5e = coordinator()
    for obs, intf in (("hbm", "host"), ("host", "hbm")):
        rows += ladder_rows(
            v5e, ActivitySpec("s", obs, 64 << 20),
            ActivitySpec("x", intf, 64 << 20),
            f"obs:{obs}/int:{intf}/(s,x)")
    print_table("Fig.6/7 heterogeneous ladders", rows)

    def pick(case, k, field):
        return next(r[field] for r in rows
                    if r["case"] == case and r["stressors"] == k)

    # DRAM observed under PL-DRAM stress: bandwidth drops, latency rises
    assert pick("obs:dram/int:pl-dram/(s,x)", 3, "bw_GBps") < \
        pick("obs:dram/int:pl-dram/(s,x)", 0, "bw_GBps")
    assert pick("obs:dram/int:pl-dram/(l,x)", 3, "lat_ns") > \
        pick("obs:dram/int:pl-dram/(l,x)", 0, "lat_ns")
    return rows


if __name__ == "__main__":
    main()
