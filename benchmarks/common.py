"""Shared helpers for the paper-figure benchmarks.

Every benchmark prints a small CSV table and returns the rows, so
``benchmarks.run`` can aggregate and EXPERIMENTS.md can quote them.
Backends: the contention ladders use the queueing model (the `simulate`
backend — this container has one CPU device); fig10 additionally
*executes* the Pallas kernels (interpret mode) to cross-validate.
"""
from __future__ import annotations

import sys
from typing import Dict, Iterable, List

from repro.core.coordinator import (ActivitySpec, CoreCoordinator,
                                    ExperimentConfig)
from repro.core.devicetree import detect_platform
from repro.core.pools import PoolManager


def coordinator(platform: str = None, backend: str = "simulate"):
    plat = detect_platform(platform)
    return CoreCoordinator(PoolManager(plat), plat, backend=backend)


def ladder_rows(coord, main: ActivitySpec, stress: ActivitySpec,
                label: str, iters: int = 500) -> List[Dict]:
    res = coord.run(ExperimentConfig(main=main, stress=stress, iters=iters))
    rows = []
    for s in res.scenarios:
        rows.append({
            "case": label,
            "stressors": s.n_stressors,
            "bw_GBps": round(s.modeled_bw_gbps, 3),
            "lat_ns": round(s.modeled_lat_ns, 2),
            "stress_bw_GBps": round(s.stress_bw_gbps, 3),
        })
    return rows


def print_table(title: str, rows: Iterable[Dict]) -> List[Dict]:
    rows = list(rows)
    print(f"\n## {title}")
    if not rows:
        print("(no rows)")
        return rows
    cols = []
    for r in rows:
        for c in r:
            if c not in cols:
                cols.append(c)
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r.get(c, "")) for c in cols))
    sys.stdout.flush()
    return rows
