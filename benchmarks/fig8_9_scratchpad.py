"""Fig. 8/9 — scratchpad (OCM/BRAM) analysis with non-cacheable ops.

Paper: 32 KiB buffers (below L1/L2!), so only the non-cacheable
strategies (s/x/y for bandwidth, m for latency) reach the module.  OCM
beats BRAM in bandwidth and keeps tighter latency under interference.
The v5e analog probes VMEM (software-managed scratchpad) vs HBM
streaming.
"""
from repro.core.coordinator import ActivitySpec
from benchmarks.common import coordinator, ladder_rows, print_table

BUF = 32 << 10


def main() -> list:
    zc = coordinator("zcu102")
    rows = []
    for mem in ("ocm", "bram"):
        for a, b in (("s", "s"), ("s", "x"), ("s", "y"), ("x", "y")):
            rows += ladder_rows(zc, ActivitySpec(a, mem, BUF),
                                ActivitySpec(b, mem, BUF),
                                f"zcu102/{mem}/({a},{b})")
        rows += ladder_rows(zc, ActivitySpec("m", mem, BUF),
                            ActivitySpec("x", mem, BUF),
                            f"zcu102/{mem}/(m,x)")
    v5e = coordinator()
    for a, b in (("s", "s"), ("s", "y")):
        rows += ladder_rows(v5e, ActivitySpec(a, "vmem", BUF),
                            ActivitySpec(b, "hbm", 64 << 20),
                            f"v5e/vmem/({a},{b})")
    print_table("Fig.8/9 scratchpad bandwidth/latency", rows)

    def bw(case, k):
        return next(r["bw_GBps"] for r in rows
                    if r["case"] == case and r["stressors"] == k)

    assert bw("zcu102/ocm/(s,s)", 0) > bw("zcu102/bram/(s,s)", 0), \
        "paper: OCM bandwidth consistently above BRAM"
    lat_ocm = next(r["lat_ns"] for r in rows
                   if r["case"] == "zcu102/ocm/(m,x)" and r["stressors"] == 3)
    lat_bram = next(r["lat_ns"] for r in rows
                    if r["case"] == "zcu102/bram/(m,x)" and r["stressors"] == 3)
    assert lat_ocm < lat_bram, "paper: BRAM more interference-sensitive"
    return rows


if __name__ == "__main__":
    main()
