"""Roofline table — aggregates the dry-run artifacts (deliverable g).

Reads experiments/dryrun/*.json (produced by repro.launch.dryrun) and
prints the per-(arch x shape x mesh) roofline terms, dominant bottleneck
and MODEL_FLOPS/HLO_FLOPs ratio.  Single-pod rows are the §Roofline
table; multipod rows prove the pod axis shards.
"""
import glob
import json
import os

from benchmarks.common import print_table

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")


def load_rows(mesh_filter=None):
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            d = json.load(f)
        if mesh_filter and ("multipod" if d.get("multi_pod") else "pod") \
                != mesh_filter:
            continue
        rows.append({
            "arch": d["arch"], "shape": d["shape"],
            "mesh": "2x16x16" if d.get("multi_pod") else "16x16",
            "GiB_per_dev": round(d["bytes_per_device"] / 2**30, 2),
            "compute_ms": round(d["t_compute"] * 1e3, 2),
            "memory_ms": round(d["t_memory"] * 1e3, 2),
            "collective_ms": round(d["t_collective"] * 1e3, 2),
            "bottleneck": d["bottleneck"],
            "useful": round(d["useful_ratio"], 3),
            "roofline": round(d["roofline_fraction"], 3),
        })
    return rows


def main() -> list:
    rows = load_rows()
    if not rows:
        print(f"(no dry-run artifacts in {DRYRUN_DIR}; run "
              f"`python -m repro.launch.dryrun --all` first)")
        return []
    print_table("Roofline terms per (arch x shape x mesh)", rows)
    pods = [r for r in rows if r["mesh"] == "16x16"]
    if pods:
        worst = min(pods, key=lambda r: r["roofline"])
        coll = max(pods, key=lambda r: r["collective_ms"])
        print(f"\nworst roofline fraction: {worst['arch']}/{worst['shape']}"
              f" = {worst['roofline']}")
        print(f"most collective-bound: {coll['arch']}/{coll['shape']}"
              f" X={coll['collective_ms']}ms")
    return rows


if __name__ == "__main__":
    main()
