"""Executable multi-engine contention: the spmd backend's ladder.

Runs a k=0..3 stressor ladder where every rung is ONE fused shard_map
dispatch over an 8-engine mesh — engine 0 measures, engines 1..k stress,
the rest idle, all sandwiched between the two psum barriers — and prints
the executed curve next to the queueing model's prediction.

The spmd backend needs a multi-device mesh.  Standalone this module
forces 8 host devices before touching jax:

    PYTHONPATH=src python -m benchmarks.spmd_ladder

Under ``benchmarks.run`` (whose process must keep seeing ONE device) it
re-executes itself in a subprocess with the devices forced.
"""
import os
import subprocess
import sys

_FORCE = "--xla_force_host_platform_device_count=8"

if __name__ == "__main__":
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = f"{flags} {_FORCE}".strip()

import jax  # noqa: E402  (after the device forcing above)

from benchmarks.common import print_table  # noqa: E402

BUF = 256 << 10


def _run() -> list:
    from repro.core.characterize import curvedb_from_result
    from repro.core.coordinator import CoreCoordinator
    from repro.core.scenarios import (ObserverSpec, ScenarioSpec,
                                      StressorSpec)

    spec = ScenarioSpec(
        "spmd-ladder",
        (ObserverSpec("r", "hbm", (BUF,)),      # bandwidth observer
         ObserverSpec("l", "hbm", (BUF,))),     # latency observer
        (StressorSpec("w", "hbm", BUF),),
        iters=20, max_stressors=3)

    spmd = CoreCoordinator(backend="spmd", faults=False, quality="off")
    res = spmd.run_matrix([spec])
    st = res.stats
    print(f"spmd ladder: {st.spmd_rungs} rungs "
          f"({st.n_ladders} observers x {st.spmd_rungs // st.n_ladders} "
          f"rungs) -> {st.measure_dispatches} fused whole-ladder "
          f"dispatches ({st.host_sync_dispatches} host syncs total), "
          f"{st.model_evals} model evals for comparison")
    # the dispatch accounting depends on the RESOLVED mode: the
    # sweep-batched default blocks the host once per distinct
    # role-program signature (here the two observers differ, so two
    # groups) with in-dispatch device clocks; installs without a
    # timestamp source honestly fall back to the legacy per-rung path
    # (warm + 3 timed syncs per rung)
    timing_source = res.runs[0].execution["timing_source"]
    if timing_source == "device":
        assert st.measure_dispatches == st.spmd_groups
        assert st.host_sync_dispatches == st.spmd_groups
        assert st.host_sync_dispatches <= st.n_ladders
    else:
        assert st.measure_dispatches == st.spmd_rungs
        assert st.host_sync_dispatches == 4 * st.spmd_rungs

    rows = []
    for run in res.runs:
        assert run.execution["fenced"]
        assert run.execution["timing_source"] == timing_source
        for s in run.scenarios:
            rows.append({
                "curve": run.key,
                "k": s.n_stressors,
                "source": s.source,
                "bw_GBps": round(s.main.bandwidth_gbps, 4),
                "lat_ns": round(s.main.latency_ns, 1),
                "model_bw": round(s.modeled_bw_gbps, 1),
                "model_lat": round(s.modeled_lat_ns, 1),
            })
    print_table("executed SPMD contention ladder (8 host engines)", rows)

    # persist the ladder we already executed (no re-run)
    db = curvedb_from_result(res, spmd.platform.name, backend="spmd")
    key = "hbm:r|hbm:w"
    ex = db.provenance[key]["execution"]
    print(f"CurveDB provenance for {key!r}: backend={ex['backend']} "
          f"activity={ex['activity']} coupled={ex['coupled']} "
          f"executed_rungs={ex['executed_rungs']} fenced={ex['fenced']} "
          f"timing_source={ex['timing_source']} "
          f"dispatches={ex['dispatches']}")
    return rows


def main() -> list:
    if len(jax.devices()) >= 2:
        return _run()
    # single-device harness process: re-exec with forced host devices.
    # Respect a pre-set device-count flag (like examples/
    # spmd_contention.py): appending a second
    # --xla_force_host_platform_device_count would either clobber the
    # user's choice or trip XLA's duplicate-flag parsing.  If the
    # pre-set flag is what pinned us below 2 devices, re-execing would
    # recurse forever — fail with the actionable message instead.
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" in flags:
        raise RuntimeError(
            f"spmd ladder needs >= 2 devices but XLA_FLAGS already pins "
            f"the host device count ({flags!r}); raise it to >= 2 or "
            f"unset the flag")
    env["XLA_FLAGS"] = f"{flags} {_FORCE}".strip()
    r = subprocess.run([sys.executable, "-m", "benchmarks.spmd_ladder"],
                       capture_output=True, text=True, timeout=600,
                       env=env)
    sys.stdout.write(r.stdout)
    if r.returncode != 0:
        raise RuntimeError(f"spmd_ladder subprocess failed:\n"
                           f"{r.stderr[-2000:]}")
    return []


if __name__ == "__main__":
    main()
