"""AdamW with ZeRO-1-shardable f32 moments + warmup-cosine schedule.

Moments are stored f32 regardless of param dtype (bf16 training).  With
``zero1`` the moment PartitionSpecs additionally shard the largest
already-unsharded axis over the ``data`` mesh axis — the optimizer-state
partitioning of ZeRO stage 1 expressed declaratively (GSPMD inserts the
reduce-scatter/all-gather pair around the update).
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig

Params = Any


class OptState(NamedTuple):
    m: Params
    v: Params
    step: jnp.ndarray          # scalar int32


def init_opt_state(params: Params) -> OptState:
    zeros = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(m=zeros,
                    v=jax.tree.map(jnp.copy, zeros),
                    step=jnp.zeros((), jnp.int32))


def opt_state_struct(param_structs: Params) -> OptState:
    f32 = jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), param_structs)
    return OptState(m=f32, v=f32,
                    step=jax.ShapeDtypeStruct((), jnp.int32))


# ---------------------------------------------------------------------------
# Schedule
# ---------------------------------------------------------------------------


def warmup_cosine(tcfg: TrainConfig):
    peak, warm, total = tcfg.learning_rate, tcfg.warmup_steps, \
        tcfg.total_steps

    def lr(step):
        step = step.astype(jnp.float32)
        warm_lr = peak * (step + 1.0) / max(warm, 1)
        prog = jnp.clip((step - warm) / max(total - warm, 1), 0.0, 1.0)
        cos_lr = 0.1 * peak + 0.9 * peak * 0.5 * (
            1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warm, warm_lr, cos_lr)

    return lr


# ---------------------------------------------------------------------------
# Update
# ---------------------------------------------------------------------------


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    params: Params,
    grads: Params,
    opt: OptState,
    tcfg: TrainConfig,
    lr_fn=None,
) -> Tuple[Params, OptState, Dict[str, jnp.ndarray]]:
    lr_fn = lr_fn or warmup_cosine(tcfg)
    step = opt.step + 1
    lr = lr_fn(opt.step)
    b1, b2, eps, wd = tcfg.beta1, tcfg.beta2, tcfg.eps, tcfg.weight_decay

    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, tcfg.grad_clip / (gnorm + 1e-9)) \
        if tcfg.grad_clip > 0 else jnp.float32(1.0)

    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m = b1 * m + (1.0 - b1) * g
        v = b2 * v + (1.0 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps) + wd * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt.m)
    flat_v = jax.tree.leaves(opt.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    stats = {"grad_norm": gnorm, "lr": lr,
             "update_scale": clip}
    return new_p, OptState(new_m, new_v, step), stats


# ---------------------------------------------------------------------------
# ZeRO-1 specs: shard moments over "data" on the largest replicated axis
# ---------------------------------------------------------------------------


def zero1_specs(param_specs, param_structs, mesh) -> Any:
    from jax.sharding import PartitionSpec as P
    data = mesh.shape.get("data", 1) if hasattr(mesh, "shape") else 1

    def shard_one(spec: "P", struct) -> "P":
        if data <= 1:
            return spec
        spec_t = tuple(spec) + (None,) * (len(struct.shape) - len(tuple(spec)))
        best, best_dim = None, 0
        for i, (ax, dim) in enumerate(zip(spec_t, struct.shape)):
            if ax is None and dim % data == 0 and dim > best_dim:
                best, best_dim = i, dim
        if best is None:
            return P(*spec_t)
        new = list(spec_t)
        new[best] = "data"
        return P(*new)

    return jax.tree.map(shard_one, param_specs, param_structs)


def opt_specs(param_specs, param_structs, mesh, *, zero1: bool) -> OptState:
    from jax.sharding import PartitionSpec as P
    mom = zero1_specs(param_specs, param_structs, mesh) if zero1 \
        else param_specs
    return OptState(m=mom, v=jax.tree.map(lambda s: s, mom), step=P())
