"""Core Coordinator — scenario ladders with the barrier "sandwich".

Mirrors the paper's §III-D: an *Experiment Instantiator* validates the
configuration and binds workloads; a *Multi-Engine Synchronizer* enforces
the four measurement invariants.  On a TPU slice the synchronizer is an
SPMD program over a 1-D "engine" mesh where engine 0 runs the main
activity and engines 1..k the stress activity — the measured region is
sandwiched between two all-reduce barriers, the collective analog of the
paper's spin-lock sandwich:

  (1) measurement starts only after every engine passed the start
      barrier (psum #1);
  (2) the scenario is stable: one fused SPMD program, lockstep engines;
  (3) the stop barrier (psum #2) completes only after every engine's
      activity finished — measurement closes before teardown;
  (4) the next scenario is a new program dispatch, which cannot begin
      until the previous one fully retired (host blocks on the result).

Backends: ``simulate`` (closed queueing network, repro.core.simulate),
``interpret`` (executes the observed activity's Pallas kernels in
interpret mode; contended rungs fall back to the model), ``tpu`` (same
code path on real hardware), and ``spmd`` — which *executes*
contention ladders on an ("engine",) mesh: observer + coupled sibling
observers + live stressor engines, rung activities from the real
Pallas kernel library (jnp fallback via ``compat.pallas_supported``),
measured region dataflow-fenced between two psum barriers.

The spmd machinery itself lives in :mod:`repro.core.exec` as an
explicit plan -> build -> dispatch -> assemble pipeline (see that
package's docstring for the module map); this class is the thin facade
tying the stages together and the home of the queueing-network model.
The default dispatch mode (``spmd_dispatch="batched"``) applies
SWEEP-LEVEL megabatching — the planner groups ladders by role-program
signature and every group executes as ONE stacked dispatch — and, when
the mesh is wide enough (``spmd_pack="auto"``), the planner's
engine-subset width-packing transform additionally runs several
same-signature shallow ladders SIDE BY SIDE on disjoint engine subsets
of that one dispatch, each subset with its own grouped-psum sandwich.
Programs are AOT-compiled once per signature and an opt-in persistent
compile cache (``compile_cache_dir=``) spans processes.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax

from repro.core import simulate as sim
from repro.core.devicetree import Platform, detect_platform
from repro.core.exec import journal as exec_journal
from repro.core.exec import plan as exec_plan
from repro.core.exec import resilience as exec_resilience
from repro.core.exec.assemble import (MatrixResult, ScenarioResult,
                                      ScenarioRun, assemble_runs)
from repro.core.exec.dispatch import Dispatcher, DispatchStats
from repro.core.exec.fence import (_shard_map_bodies,
                                   measured_region_is_fenced)
from repro.core.exec.plan import effective_duty as _effective_duty
from repro.core.exec.program import (_SPMD_CHASES, _SPMD_STREAM_2X,
                                     build_ladder_program,
                                     build_rung_operands,
                                     build_rung_program,
                                     build_scenario_program,
                                     spmd_branch_fn)
from repro.core.pools import MemoryPool, PoolManager
from repro.core.scenarios import (ObserverSpec, ScenarioSpec, StressorSpec,
                                  TrafficShape)
from repro.core.workloads import (WorkloadResult, make_shaped_workload,
                                  measure_group)

# long-standing import surface: tests and benchmarks reach these via
# the coordinator module (the implementations moved to repro.core.exec)
_spmd_branch_fn = spmd_branch_fn
_build_rung_operands = build_rung_operands

__all__ = [
    "ActivitySpec", "CoreCoordinator", "DispatchStats",
    "ExperimentConfig", "ExperimentResult", "MatrixResult",
    "ScenarioResult", "ScenarioRun", "ValidationError",
    "build_ladder_program", "build_rung_program",
    "build_scenario_program", "measured_region_is_fenced",
]

# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ActivitySpec:
    strategy: str              # Table-I letter
    pool: str                  # pool name ("hbm", "host", ...)
    buffer_bytes: int
    # optional traffic-shape parameters (ScenarioSpec DSL; the defaults
    # reproduce the seed's steady streams exactly)
    read_fraction: Optional[float] = None   # mixed r/w ratio
    duty_cycle: float = 1.0                 # bursty/duty-cycled
    stride: int = 1                         # strided pointer-chase

    def describe(self) -> str:
        return f"({self.strategy},{self.pool},{self.buffer_bytes >> 10}K)"

    def shape(self) -> Optional[TrafficShape]:
        """The TrafficShape these fields encode (None = steady)."""
        if self.read_fraction is not None:
            # surface grid points carry BOTH a mix and a duty cycle —
            # dropping the duty here would silently rebuild a hotter
            # shape than the one that ran
            return TrafficShape(kind="mixed",
                                read_fraction=self.read_fraction,
                                duty_cycle=self.duty_cycle)
        if self.duty_cycle < 1.0:
            return TrafficShape(kind="burst", duty_cycle=self.duty_cycle)
        if self.stride > 1:
            return TrafficShape(kind="strided", stride=self.stride)
        return None

    @staticmethod
    def from_stressor(s: StressorSpec) -> "ActivitySpec":
        return ActivitySpec(
            s.strategy, s.pool, s.buffer_bytes,
            read_fraction=(s.shape.read_fraction
                           if s.shape.kind == "mixed" else None),
            duty_cycle=s.shape.duty_cycle,
            stride=s.shape.stride)


@dataclass(frozen=True)
class ExperimentConfig:
    main: ActivitySpec
    stress: ActivitySpec
    iters: int = 500
    scenarios: Optional[int] = None      # default: platform.n_engines
    counters: Tuple[str, ...] = ("WALL_NS", "HLO_FLOPS", "HLO_BYTES",
                                 "TRANSACTIONS", "NS_PER_TX")


@dataclass
class ExperimentResult:
    config: ExperimentConfig
    scenarios: List[ScenarioResult] = field(default_factory=list)

    def bandwidth_curve(self) -> List[Tuple[int, float]]:
        return [(s.n_stressors,
                 s.modeled_bw_gbps or s.main.bandwidth_gbps)
                for s in self.scenarios]

    def latency_curve(self) -> List[Tuple[int, float]]:
        return [(s.n_stressors, s.modeled_lat_ns or s.main.latency_ns)
                for s in self.scenarios]


class ValidationError(ValueError):
    pass


# ---------------------------------------------------------------------------


class CoreCoordinator:
    # compiled spmd programs kept per coordinator (LRU): fused ladder
    # programs are expensive to trace, and back-to-back run_matrix
    # calls must not re-trace/re-transfer what they just built.  Each
    # entry also holds its placed operands, so the cap is a MEMORY
    # bound; the legacy per-rung path needs K entries per ladder where
    # the fused paths need one (raise ``spmd_cache_cap`` to trade
    # memory for re-compiles).
    _SPMD_CACHE_CAP = 32

    def __init__(self, pool_mgr: Optional[PoolManager] = None,
                 platform: Optional[Platform] = None,
                 backend: str = "auto",
                 spmd_activity: str = "auto",
                 spmd_dispatch: str = "batched",
                 spmd_samples: int = 3,
                 spmd_cache_cap: Optional[int] = None,
                 spmd_pack: str = "auto",
                 compile_cache_dir: Optional[str] = None,
                 faults=None,
                 retry: Optional[exec_resilience.RetryPolicy] = None,
                 quality="auto"):
        self.platform = platform or detect_platform()
        self.pools = pool_mgr or PoolManager(self.platform)
        if backend == "auto":
            backend = "tpu" if jax.default_backend() == "tpu" else "simulate"
        assert backend in ("simulate", "interpret", "tpu", "spmd"), backend
        # what fills the spmd backend's rung measured regions: real
        # Pallas kernels ("pallas") or pure-jnp traffic loops ("jnp");
        # "auto" probes compat.pallas_supported() and falls back
        # honestly, stamped into ``execution["activity"]`` provenance
        assert spmd_activity in ("auto", "pallas", "jnp"), spmd_activity
        # sweep dispatch granularity: "batched" (default) stacks
        # same-signature ladders into ONE dispatch per group, "ladder"
        # fuses one ladder per dispatch, "rung" is the legacy
        # one-dispatch-per-rung path.  "batched"/"ladder" need an
        # in-dispatch timestamp source and fall back to "rung" when
        # compat.device_clock_source() reports none; the resolved
        # choice lands in ``execution["timing_source"]``.
        assert spmd_dispatch in ("batched", "ladder", "rung"), spmd_dispatch
        assert spmd_samples >= 1, spmd_samples
        # engine-subset width-packing (the planner transform): "auto"
        # packs same-signature shallow ladders side by side whenever
        # the mesh is at least twice a ladder's width ("off" disables;
        # bools accepted).  Packing changes no dispatch-count
        # accounting — a packed dispatch still counts ONE host sync
        # for its whole group — it trades scan waves for mesh width.
        if isinstance(spmd_pack, bool):
            spmd_pack = "auto" if spmd_pack else "off"
        assert spmd_pack in ("auto", "off"), spmd_pack
        self.backend = backend
        self.spmd_activity = spmd_activity
        self.spmd_dispatch = spmd_dispatch
        self.spmd_samples = spmd_samples
        self.spmd_pack = spmd_pack
        self.spmd_cache_cap = (spmd_cache_cap if spmd_cache_cap
                               is not None else self._SPMD_CACHE_CAP)
        assert self.spmd_cache_cap >= 1, self.spmd_cache_cap
        # resilience wiring (exec.resilience): deterministic fault
        # injection (None reads REPRO_FAULT_SPEC), retry/degradation
        # policy, and the per-rung measurement quality gate
        self.fault_spec = exec_resilience.resolve_faults(faults)
        self.retry_policy = retry or exec_resilience.RetryPolicy()
        self.quality_gate = exec_resilience.resolve_gate(quality)
        # stage 3 of the exec pipeline: program/operand LRU, AOT
        # compile, opt-in persistent compile cache, dispatch + decode
        self._dispatcher = Dispatcher(self.spmd_cache_cap, spmd_samples,
                                      compile_cache_dir,
                                      faults=(self.fault_spec.injector()
                                              if self.fault_spec
                                              else None))
        self.compile_cache_dir = compile_cache_dir
        self.persistent_cache_enabled = \
            self._dispatcher.persistent_cache_enabled

    def _resolved_activity(self) -> str:
        """The rung-activity implementation the spmd backend will use."""
        from repro import compat
        if self.spmd_activity != "auto":
            return self.spmd_activity
        return "pallas" if compat.pallas_supported() else "jnp"

    def _resolved_dispatch(self) -> str:
        """The spmd dispatch mode that will actually run: the fused
        paths need an in-dispatch timestamp source (without one, only
        the host-timed per-rung path is honest)."""
        from repro import compat
        if self.spmd_dispatch == "rung":
            return "rung"
        if compat.device_clock_source() == "none":
            return "rung"
        return self.spmd_dispatch

    # -- spmd program cache (LRU, coordinator lifetime; the storage
    # -- lives on the Dispatcher, these delegates are the stable API) --
    @property
    def _spmd_programs(self):
        return self._dispatcher.cache.entries

    def _program_cache_get(self, key: Tuple,
                           stats: Optional[DispatchStats] = None):
        return self._dispatcher.cache.get(key, stats)

    def _program_cache_put(self, key: Tuple, entry) -> None:
        self._dispatcher.cache.put(key, entry)

    # -- Experiment Instantiator ----------------------------------------
    def validate(self, cfg: ExperimentConfig) -> None:
        from repro.core.workloads import _REGISTRY
        for which, spec in (("main", cfg.main), ("stress", cfg.stress)):
            if spec.strategy not in _REGISTRY:
                raise ValidationError(
                    f"{which}: unknown strategy {spec.strategy!r}")
            pool = self.pools.pool(spec.pool)   # raises PoolError if absent
            if spec.strategy != "i" and spec.buffer_bytes > pool.available:
                raise ValidationError(
                    f"{which}: buffer {spec.buffer_bytes}B exceeds free "
                    f"space in pool {spec.pool} ({pool.available}B)")
        if cfg.iters <= 0:
            raise ValidationError("iters must be positive")
        n = cfg.scenarios if cfg.scenarios is not None \
            else self.platform.n_engines
        if not 1 <= n <= self.platform.n_engines:
            raise ValidationError(
                f"scenarios must be in [1, {self.platform.n_engines}]")

    # -- scenario ladder ----------------------------------------------------
    def run(self, cfg: ExperimentConfig) -> ExperimentResult:
        self.validate(cfg)
        n_scen = cfg.scenarios if cfg.scenarios is not None \
            else self.platform.n_engines
        result = ExperimentResult(cfg)

        main_pool = self.pools.pool(cfg.main.pool)
        stress_pool = self.pools.pool(cfg.stress.pool)

        measured: Optional[WorkloadResult] = None
        if self.backend in ("interpret", "tpu"):
            wl = make_shaped_workload(cfg.main.strategy, main_pool,
                                      cfg.main.buffer_bytes,
                                      cfg.main.shape())
            try:
                measured = wl.run(cfg.iters)
            finally:
                wl.release()

        for k in range(n_scen):
            modeled = self._model_scenario(cfg, main_pool, stress_pool, k)  # noqa: E501
            main_res = measured if measured is not None else WorkloadResult(
                cfg.main.strategy, cfg.main.pool, cfg.main.buffer_bytes,
                cfg.iters, 0, 0.0, 0)
            result.scenarios.append(ScenarioResult(
                n_stressors=k,
                main=main_res,
                modeled_bw_gbps=modeled[0],
                modeled_lat_ns=modeled[1],
                stress_bw_gbps=modeled[2],
            ))
        # per-scenario/experiment teardown (paper §III-A step 6) is done by
        # wl.release() above; pools stay clean for the next experiment.
        return result

    def _model_scenario(self, cfg: ExperimentConfig, main_pool: MemoryPool,
                        stress_pool: MemoryPool,
                        k: int) -> Tuple[float, float, float]:
        obs_node = self._model_node(cfg.main, main_pool,
                                    other=cfg.stress, other_engines=k)
        stress_node = self._model_node(cfg.stress, stress_pool,
                                       other=cfg.main, other_engines=1)
        classes = [sim.ActivityClass(
            "obs", obs_node, cfg.main.strategy, 1,
            read_fraction=cfg.main.read_fraction,
            duty_cycle=cfg.main.duty_cycle, stride=cfg.main.stride)]
        if k and cfg.stress.strategy != "i":
            classes.append(sim.ActivityClass(
                "stress", stress_node, cfg.stress.strategy, k,
                read_fraction=cfg.stress.read_fraction,
                duty_cycle=cfg.stress.duty_cycle,
                stride=cfg.stress.stride))
        res = sim.simulate_scenario(self.platform, classes)
        obs = res.get("obs")
        stress = res.get("stress")
        return (obs.bw_gbps if obs else 0.0,
                obs.lat_ns if obs else 0.0,
                stress.bw_gbps if stress else 0.0)

    # -- cache semantics ------------------------------------------------------
    _CACHEABLE = ("r", "w", "l", "c", "b")

    def _model_node(self, spec: ActivitySpec, pool: MemoryPool,
                    other: Optional[ActivitySpec] = None,
                    other_engines: int = 0):
        """Where does this activity's traffic actually land?

        Cacheable strategies on small buffers hit the platform's cache
        (transparent shared L2 on the ZCU102; software-managed private
        VMEM residency on v5e) — UNLESS, for a *shared* cache, the
        combined cacheable footprint exceeds it (inter-engine evictions,
        the red case of Fig. 12)."""
        node = pool.node
        if node.kind in ("vmem", "cache"):
            return node
        if spec.strategy not in self._CACHEABLE:
            return node

        cache_name = getattr(self.platform, "cache_node", None)
        if cache_name:                     # transparent shared cache
            cache = self.platform.memories[cache_name]
            if spec.buffer_bytes > cache.size_bytes:
                return node
            footprint = spec.buffer_bytes
            if other is not None and other.strategy in self._CACHEABLE:
                other_pool = self.pools.pool(other.pool)
                if other_pool.node.kind not in ("vmem", "cache"):
                    footprint += other_engines * other.buffer_bytes
            return cache if footprint <= cache.size_bytes else node

        # v5e: private VMEM residency, no cross-engine eviction
        from repro.core.workloads import models_as_vmem
        vmem = self.platform.memories.get("vmem")
        if vmem is not None and models_as_vmem(spec.buffer_bytes):
            return vmem
        return node

    # -- ladder sweep used by characterize.py ------------------------------
    def ladder(self, main: ActivitySpec, stress: ActivitySpec,
               iters: int = 500) -> ExperimentResult:
        return self.run(ExperimentConfig(main=main, stress=stress,
                                         iters=iters))

    # ==================================================================
    # ScenarioSpec matrix execution (the v2 characterization engine)
    # ==================================================================

    def validate_spec(self, spec: ScenarioSpec) -> None:
        from repro.core.workloads import _REGISTRY
        # exact-duplicate observers would alias one curve key per
        # buffer and silently overwrite each other's ladders in
        # CurveDB — reject up front (observers differing in ANY field
        # are legitimate twins and key distinctly via the buf= suffix)
        seen = set()
        for obs in spec.observers:
            if obs in seen:
                raise ValidationError(
                    f"{spec.name}: duplicate observer "
                    f"({obs.pool}:{obs.strategy}"
                    f"{'@' + obs.shape.tag() if obs.shape.tag() else ''}, "
                    f"buffers={obs.buffers}) — its curves would alias "
                    f"the first occurrence's keys")
            seen.add(obs)
        for obs in spec.observers:
            if obs.strategy not in _REGISTRY:
                raise ValidationError(
                    f"{spec.name}: unknown observer strategy "
                    f"{obs.strategy!r}")
            pool = self.pools.pool(obs.pool)
            for b in obs.buffers:
                if obs.strategy != "i" and b > pool.available:
                    raise ValidationError(
                        f"{spec.name}: observer buffer {b}B exceeds pool "
                        f"{obs.pool} ({pool.available}B free)")
        for s in spec.stressors:
            if s.strategy not in _REGISTRY:
                raise ValidationError(
                    f"{spec.name}: unknown stressor strategy "
                    f"{s.strategy!r}")
            self.pools.pool(s.pool)
        if spec.iters <= 0:
            raise ValidationError(f"{spec.name}: iters must be positive")
        if spec.max_stressors is not None and not (
                0 <= spec.max_stressors < self.platform.n_engines):
            raise ValidationError(
                f"{spec.name}: max_stressors out of "
                f"[0, {self.platform.n_engines})")

    def _obs_activity(self, observer: ObserverSpec,
                      buffer_bytes: int) -> ActivitySpec:
        sh = observer.shape
        return ActivitySpec(
            observer.strategy, observer.pool, buffer_bytes,
            read_fraction=(sh.read_fraction if sh.kind == "mixed"
                           else None),
            duty_cycle=sh.duty_cycle, stride=sh.stride)

    def _model_spec_scenario(self, spec: ScenarioSpec,
                             observer: ObserverSpec, buffer_bytes: int,
                             k: int) -> Tuple[float, float, float]:
        """Model one rung: one observer + k stress engines distributed
        round-robin over the stressor ensemble — plus, for a *coupled*
        multi-observer scenario, one always-on single-engine class per
        sibling observer (:func:`sim.co_observer_class`), exactly like
        the spmd backend's executed rungs.  ``spec.coupled=False``
        keeps the historical stressor-only semantics."""
        obs_act = self._obs_activity(observer, buffer_bytes)
        obs_pool = self.pools.pool(observer.pool)
        first = spec.stressors[0] if spec.stressors else None
        obs_node = self._model_node(
            obs_act, obs_pool,
            other=ActivitySpec.from_stressor(first) if first else None,
            other_engines=k)
        classes = [sim.ActivityClass(
            "obs", obs_node, obs_act.strategy, 1,
            read_fraction=obs_act.read_fraction,
            duty_cycle=obs_act.duty_cycle, stride=obs_act.stride)]
        for j, sib in enumerate(self._coupled_siblings(spec, observer)):
            if sib.strategy == "i":
                continue
            act = self._obs_activity(sib, sib.buffers[0])
            node = self._model_node(act, self.pools.pool(sib.pool),
                                    other=obs_act, other_engines=1)
            classes.append(sim.co_observer_class(
                f"co{j}", node, act.strategy,
                read_fraction=act.read_fraction,
                duty_cycle=act.duty_cycle, stride=act.stride))
        m = len(spec.stressors)
        if k and m:
            share = [k // m + (1 if j < k % m else 0) for j in range(m)]
            for j, (s, e) in enumerate(zip(spec.stressors, share)):
                if e == 0 or s.strategy == "i":
                    continue
                act = ActivitySpec.from_stressor(s)
                node = self._model_node(act, self.pools.pool(s.pool),
                                        other=obs_act, other_engines=1)
                classes.append(sim.ActivityClass(
                    f"stress{j}", node, s.strategy, e,
                    read_fraction=act.read_fraction,
                    duty_cycle=act.duty_cycle, stride=act.stride))
        res = sim.simulate_scenario(self.platform, classes)
        obs = res.get("obs")
        stress_bw = sum(r.bw_gbps for n, r in res.items()
                        if n.startswith("stress"))
        return (obs.bw_gbps if obs else 0.0,
                obs.lat_ns if obs else 0.0,
                stress_bw)

    @staticmethod
    def _coupled_siblings(spec: ScenarioSpec,
                          observer: ObserverSpec) -> Tuple[ObserverSpec, ...]:
        """The sibling observers sharing this observer's measured
        region (the logic lives on :meth:`ScenarioSpec.coupled_siblings`
        so the sweep-level grouping signature can reuse it)."""
        return spec.coupled_siblings(observer)

    def _ladder_depth(self, spec: ScenarioSpec) -> int:
        mesh = self._spmd_engines() if self.backend == "spmd" else None
        return exec_plan.ladder_depth(spec, self.platform.n_engines,
                                      mesh)

    def run_matrix(self, specs: List[ScenarioSpec], *,
                   batched: bool = True, journal=None) -> MatrixResult:
        """Execute a scenario matrix.

        The measured observer pass is where executable backends spend
        their dispatches; ``batched=True`` groups same-signature
        observers (strategy, shape, row count, residency, effective
        memory placement) and measures each group with ONE jit'd
        vmapped pass, instead of the naive one-dispatch-per-scenario
        Python loop.  Multi-observer scenarios contribute one ladder
        per (observer, buffer) and their observers join the same
        signature groups.

        Backends: ``simulate``/``interpret``/``tpu`` model the
        contention ladder per rung (interpret/tpu additionally measure
        the uncontended observer); ``spmd`` *executes* every rung and
        its curves carry ``source == "executed"``.  On the spmd
        backend ``batched=True`` (with ``spmd_dispatch="batched"``)
        applies SWEEP-LEVEL megabatching: the planner stacks
        same-signature ladders into ONE dispatch per group — and
        width-packs shallow groups onto disjoint engine subsets
        (``spmd_pack``) — so a sweep costs ~one host-synchronous
        dispatch per distinct signature; ``batched=False`` degrades to
        one fused dispatch per ladder.  Every curve's ``execution``
        provenance records the backend, executed-vs-modeled rungs,
        effective ``coupled`` state, the rung ``activity``, and — for
        spmd — ``batched``/``group_size``/``aot`` plus the
        width-packing slot ``packed``/``subset_width``/
        ``subset_index``.

        Execution is resilient (see :mod:`repro.core.exec.resilience`):
        a failed dispatch retries with backoff, degrades down the
        packed->batched->ladder->rung->modeled ladder isolated to its
        signature group, and noisy rungs re-measure under the quality
        gate; pass ``journal=<path>`` (spmd fused paths) to make the
        sweep crash-resumable via a :class:`SweepJournal` sidecar."""
        if journal is not None and self.backend != "spmd":
            raise ValidationError(
                "journal= requires the spmd backend (other backends "
                "model and have nothing to resume)")
        for spec in specs:
            self.validate_spec(spec)
        triples = [(spec, obs, b) for spec in specs
                   for obs in spec.observers for b in obs.buffers]
        stats = DispatchStats(n_scenarios=len(specs),
                              n_ladders=len(triples))

        measured: Dict[int, WorkloadResult] = {}
        executed: Dict[Tuple[int, int], WorkloadResult] = {}
        fenced_by_triple: Dict[int, bool] = {}
        timing_by_triple: Dict[int, Dict[str, Any]] = {}
        if self.backend in ("interpret", "tpu"):
            # the measured pass runs the real Pallas kernel library
            activity = "pallas"
            measured = self._measure_triples(triples, batched, stats)
        elif self.backend == "spmd":
            activity = self._resolved_activity()
            executed, fenced_by_triple, timing_by_triple = \
                self._execute_spmd(triples, stats, activity,
                                   batched=batched, journal=journal)
        else:
            activity = "none"       # nothing executes on this backend

        runs = assemble_runs(
            triples, backend=self.backend, activity=activity,
            stats=stats, depth_fn=self._ladder_depth,
            model_fn=self._model_spec_scenario, measured=measured,
            executed=executed, fenced_by_triple=fenced_by_triple,
            timing_by_triple=timing_by_triple,
            n_engines=(self._spmd_engines()
                       if self.backend == "spmd" else None),
            operand_kinds_fn=(self._operand_memory_kinds
                              if self.backend == "spmd" else None))
        return MatrixResult(runs=runs, stats=stats)

    def _operand_memory_kinds(self, spec: ScenarioSpec,
                              obs: ObserverSpec) -> List[str]:
        return sorted(
            {self.pools.pool(p).effective_memory_kind() or "default"
             for p in ([obs.pool]
                       + [o.pool for o in
                          self._coupled_siblings(spec, obs)]
                       + [s.pool for s in spec.stressors])})

    def _measure_triples(self, triples, batched: bool,
                         stats: DispatchStats) -> Dict[int, WorkloadResult]:
        """The measured observer pass over all (spec, observer, buffer)
        triples (uncontended: single real device).  Grouping comes from
        the SAME planner as the spmd backend
        (:func:`repro.core.exec.plan.observer_groups`)."""
        measured: Dict[int, WorkloadResult] = {}
        if not batched:
            for i, (spec, obs, buf) in enumerate(triples):
                wl = make_shaped_workload(
                    obs.strategy, self.pools.pool(obs.pool), buf,
                    obs.shape)
                try:
                    measured[i] = wl.run(spec.iters)
                finally:
                    wl.release()
                stats.measure_dispatches += 1
            return measured

        groups = exec_plan.observer_groups(triples, self.pools)
        for (strategy, shape, buf, iters, _kind, _vm), idxs in \
                groups.items():
            member_pools = [self.pools.pool(triples[i][1].pool)
                            for i in idxs]
            results, dispatches = measure_group(
                strategy, member_pools[0], buf, len(idxs), iters,
                shape=shape, member_pools=member_pools)
            stats.measure_dispatches += dispatches
            for i, res in zip(idxs, results):
                measured[i] = res
        return measured

    # -- the spmd backend: executable multi-engine contention -----------

    def _spmd_engines(self) -> int:
        return max(1, min(self.platform.n_engines, len(jax.devices())))

    def _spmd_group_key(self, spec: ScenarioSpec, obs: ObserverSpec,
                        buf: int) -> Tuple:
        """Sweep-level grouping key (see
        :func:`repro.core.exec.plan.group_key`)."""
        return exec_plan.group_key(spec, obs, buf, self.pools)

    # rung role expansion (see exec.plan.rung_roles)
    _rung_roles = staticmethod(exec_plan.rung_roles)

    def _execute_spmd(
        self, triples, stats: DispatchStats, activity: str = "jnp",
        batched: bool = True, journal=None,
    ) -> Tuple[Dict[Tuple[int, int], WorkloadResult], Dict[int, bool],
               Dict[int, Dict[str, Any]]]:
        """Execute every (spec, observer, buffer) triple's contention
        ladder on the engine mesh through the exec pipeline: the
        planner builds a DispatchPlan (one dispatch per same-signature
        group when ``spmd_dispatch="batched"``, per triple under
        ``"ladder"``), width-packing re-plans shallow groups onto
        disjoint engine subsets, and the resilient executor
        (:mod:`repro.core.exec.journal`) builds, fence-verifies, runs,
        retries/degrades and optionally journals each planned dispatch
        (``"rung"`` is the legacy host-clocked one-dispatch-per-rung
        path).  Returns per-(triple, rung) observer results,
        per-triple verified fence state, and per-triple timing
        provenance."""
        n_eng = self._spmd_engines()
        if n_eng < 2:
            raise ValidationError(
                "spmd backend needs >= 2 devices; start the process with "
                "XLA_FLAGS=--xla_force_host_platform_device_count=8 "
                "(CPU container) or run on a real multi-device slice")
        dispatch = self._resolved_dispatch()
        if dispatch == "batched" and not batched:
            dispatch = "ladder"       # megabatching explicitly disabled
        if dispatch in ("batched", "ladder"):
            plan = exec_plan.build_plan(
                triples, n_eng, self.pools, self.platform.n_engines,
                grouped=(dispatch == "batched"))
            if dispatch == "batched":
                stats.spmd_groups += len(plan.dispatches)
                if self.spmd_pack == "auto":
                    plan = exec_plan.pack_engine_subsets(plan)
            return exec_journal.execute_plan(
                self._dispatcher, plan, n_eng=n_eng, activity=activity,
                mode=dispatch, stats=stats, policy=self.retry_policy,
                gate=self.quality_gate, journal=journal)
        if journal is not None:
            raise ValidationError(
                "journal= needs a fused dispatch path "
                "(spmd_dispatch='batched' or 'ladder'), not 'rung'")
        return exec_journal.execute_rung_path(
            self._dispatcher, triples, n_eng=n_eng, activity=activity,
            stats=stats, depth_fn=self._ladder_depth, pools=self.pools,
            policy=self.retry_policy, gate=self.quality_gate)
