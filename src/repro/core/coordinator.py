"""Core Coordinator — scenario ladders with the barrier "sandwich".

Mirrors the paper's §III-D: an *Experiment Instantiator* validates the
configuration and binds workloads; a *Multi-Engine Synchronizer* enforces
the four measurement invariants.  On a TPU slice the synchronizer is an
SPMD program over a 1-D "engine" mesh where engine 0 runs the main
activity and engines 1..k the stress activity — the measured region is
sandwiched between two all-reduce barriers, the collective analog of the
paper's spin-lock sandwich:

  (1) measurement starts only after every engine passed the start
      barrier (psum #1);
  (2) the scenario is stable: one fused SPMD program, engines run
      lockstep until their activity completes;
  (3) the stop barrier (psum #2) completes only after every engine's
      activity finished — measurement closes before anything is torn
      down;
  (4) the next scenario is a new program dispatch, which cannot begin
      until the previous one fully retired (host blocks on the result).

Backends:
  * ``simulate``  — closed queueing network (repro.core.simulate); full
                    contention ladders at modeled v5e scale.
  * ``interpret`` — really executes the observed activity's Pallas
                    kernels (interpret mode, this container's CPU);
                    contention scenarios beyond 0 stressors fall back to
                    the model (single real device).
  * ``tpu``       — same SPMD program, real hardware (not available in
                    this container; code path kept identical).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import simulate as sim
from repro.core.devicetree import Platform, detect_platform
from repro.core.pools import MemoryPool, PoolManager
from repro.core.workloads import Workload, WorkloadResult, make_workload

# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ActivitySpec:
    strategy: str              # Table-I letter
    pool: str                  # pool name ("hbm", "host", ...)
    buffer_bytes: int

    def describe(self) -> str:
        return f"({self.strategy},{self.pool},{self.buffer_bytes >> 10}K)"


@dataclass(frozen=True)
class ExperimentConfig:
    main: ActivitySpec
    stress: ActivitySpec
    iters: int = 500
    scenarios: Optional[int] = None      # default: platform.n_engines
    counters: Tuple[str, ...] = ("WALL_NS", "HLO_FLOPS", "HLO_BYTES",
                                 "TRANSACTIONS", "NS_PER_TX")


@dataclass
class ScenarioResult:
    n_stressors: int
    main: WorkloadResult
    modeled_bw_gbps: float = 0.0
    modeled_lat_ns: float = 0.0
    stress_bw_gbps: float = 0.0


@dataclass
class ExperimentResult:
    config: ExperimentConfig
    scenarios: List[ScenarioResult] = field(default_factory=list)

    def bandwidth_curve(self) -> List[Tuple[int, float]]:
        return [(s.n_stressors,
                 s.modeled_bw_gbps or s.main.bandwidth_gbps)
                for s in self.scenarios]

    def latency_curve(self) -> List[Tuple[int, float]]:
        return [(s.n_stressors, s.modeled_lat_ns or s.main.latency_ns)
                for s in self.scenarios]


class ValidationError(ValueError):
    pass


# ---------------------------------------------------------------------------


class CoreCoordinator:
    def __init__(self, pool_mgr: Optional[PoolManager] = None,
                 platform: Optional[Platform] = None,
                 backend: str = "auto"):
        self.platform = platform or detect_platform()
        self.pools = pool_mgr or PoolManager(self.platform)
        if backend == "auto":
            backend = "tpu" if jax.default_backend() == "tpu" else "simulate"
        assert backend in ("simulate", "interpret", "tpu"), backend
        self.backend = backend

    # -- Experiment Instantiator ----------------------------------------
    def validate(self, cfg: ExperimentConfig) -> None:
        from repro.core.workloads import _REGISTRY
        for which, spec in (("main", cfg.main), ("stress", cfg.stress)):
            if spec.strategy not in _REGISTRY:
                raise ValidationError(
                    f"{which}: unknown strategy {spec.strategy!r}")
            pool = self.pools.pool(spec.pool)   # raises PoolError if absent
            if spec.strategy != "i" and spec.buffer_bytes > pool.available:
                raise ValidationError(
                    f"{which}: buffer {spec.buffer_bytes}B exceeds free "
                    f"space in pool {spec.pool} ({pool.available}B)")
        if cfg.iters <= 0:
            raise ValidationError("iters must be positive")
        n = cfg.scenarios if cfg.scenarios is not None \
            else self.platform.n_engines
        if not 1 <= n <= self.platform.n_engines:
            raise ValidationError(
                f"scenarios must be in [1, {self.platform.n_engines}]")

    # -- scenario ladder ----------------------------------------------------
    def run(self, cfg: ExperimentConfig) -> ExperimentResult:
        self.validate(cfg)
        n_scen = cfg.scenarios if cfg.scenarios is not None \
            else self.platform.n_engines
        result = ExperimentResult(cfg)

        main_pool = self.pools.pool(cfg.main.pool)
        stress_pool = self.pools.pool(cfg.stress.pool)

        measured: Optional[WorkloadResult] = None
        if self.backend in ("interpret", "tpu"):
            wl = make_workload(cfg.main.strategy, main_pool,
                               cfg.main.buffer_bytes)
            try:
                measured = wl.run(cfg.iters)
            finally:
                wl.release()

        for k in range(n_scen):
            modeled = self._model_scenario(cfg, main_pool, stress_pool, k)  # noqa: E501
            main_res = measured if measured is not None else WorkloadResult(
                cfg.main.strategy, cfg.main.pool, cfg.main.buffer_bytes,
                cfg.iters, 0, 0.0, 0)
            result.scenarios.append(ScenarioResult(
                n_stressors=k,
                main=main_res,
                modeled_bw_gbps=modeled[0],
                modeled_lat_ns=modeled[1],
                stress_bw_gbps=modeled[2],
            ))
        # per-scenario/experiment teardown (paper §III-A step 6) is done by
        # wl.release() above; pools stay clean for the next experiment.
        return result

    def _model_scenario(self, cfg: ExperimentConfig, main_pool: MemoryPool,
                        stress_pool: MemoryPool,
                        k: int) -> Tuple[float, float, float]:
        obs_node = self._model_node(cfg.main, main_pool,
                                    other=cfg.stress, other_engines=k)
        stress_node = self._model_node(cfg.stress, stress_pool,
                                       other=cfg.main, other_engines=1)
        classes = [sim.ActivityClass("obs", obs_node, cfg.main.strategy, 1)]
        if k and cfg.stress.strategy != "i":
            classes.append(sim.ActivityClass(
                "stress", stress_node, cfg.stress.strategy, k))
        res = sim.simulate_scenario(self.platform, classes)
        obs = res.get("obs")
        stress = res.get("stress")
        return (obs.bw_gbps if obs else 0.0,
                obs.lat_ns if obs else 0.0,
                stress.bw_gbps if stress else 0.0)

    # -- cache semantics ------------------------------------------------------
    _CACHEABLE = ("r", "w", "l")

    def _model_node(self, spec: ActivitySpec, pool: MemoryPool,
                    other: Optional[ActivitySpec] = None,
                    other_engines: int = 0):
        """Where does this activity's traffic actually land?

        Cacheable strategies on small buffers hit the platform's cache
        (transparent shared L2 on the ZCU102; software-managed private
        VMEM residency on v5e) — UNLESS, for a *shared* cache, the
        combined cacheable footprint exceeds it (inter-engine evictions,
        the red case of Fig. 12)."""
        node = pool.node
        if node.kind in ("vmem", "cache"):
            return node
        if spec.strategy not in self._CACHEABLE:
            return node

        cache_name = getattr(self.platform, "cache_node", None)
        if cache_name:                     # transparent shared cache
            cache = self.platform.memories[cache_name]
            if spec.buffer_bytes > cache.size_bytes:
                return node
            footprint = spec.buffer_bytes
            if other is not None and other.strategy in self._CACHEABLE:
                other_pool = self.pools.pool(other.pool)
                if other_pool.node.kind not in ("vmem", "cache"):
                    footprint += other_engines * other.buffer_bytes
            return cache if footprint <= cache.size_bytes else node

        # v5e: private VMEM residency, no cross-engine eviction
        from repro.core.workloads import models_as_vmem
        vmem = self.platform.memories.get("vmem")
        if vmem is not None and models_as_vmem(spec.buffer_bytes):
            return vmem
        return node

    # -- ladder sweep used by characterize.py ------------------------------
    def ladder(self, main: ActivitySpec, stress: ActivitySpec,
               iters: int = 500) -> ExperimentResult:
        return self.run(ExperimentConfig(main=main, stress=stress,
                                         iters=iters))


# ---------------------------------------------------------------------------
# The SPMD scenario program (the spin-lock sandwich, collective edition).
# Built for any 1-D mesh of engines; dry-runnable on host devices and
# executable unchanged on a real slice.
# ---------------------------------------------------------------------------


def build_scenario_program(n_engines: int, n_stressors: int,
                           main_fn, stress_fn, idle_fn):
    """Returns f(main_x, stress_x) -> (main_out, barrier) running under
    ``shard_map`` over an ("engine",) mesh: engine 0 = observed, engines
    1..n_stressors = stress, rest idle.  The measured region is fenced by
    two psum barriers (invariants 1-4 above)."""
    from jax.sharding import Mesh, PartitionSpec as P
    shard_map = jax.shard_map

    devs = jax.devices()[:n_engines]
    mesh = Mesh(np.array(devs), ("engine",))

    def per_engine(main_x, stress_x):
        eng = jax.lax.axis_index("engine")
        # barrier #1: every engine signals ready before measurement starts
        ready = jax.lax.psum(jnp.ones((), jnp.int32), "engine")

        def run_main(_):
            return main_fn(main_x)

        def run_stress(_):
            return stress_fn(stress_x)

        def run_idle(_):
            return idle_fn(stress_x)

        branch = jnp.where(eng == 0, 0,
                           jnp.where(eng <= n_stressors, 1, 2))
        out = jax.lax.switch(branch, [run_main, run_stress, run_idle],
                             operand=None)
        # barrier #2: measurement closes only after every engine finished
        done = jax.lax.psum(jnp.ones((), jnp.int32), "engine")
        return out, ready + done

    f = shard_map(per_engine, mesh=mesh,
                  in_specs=(P("engine"), P("engine")),
                  out_specs=(P("engine"), P()))
    return mesh, f
