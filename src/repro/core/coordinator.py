"""Core Coordinator — scenario ladders with the barrier "sandwich".

Mirrors the paper's §III-D: an *Experiment Instantiator* validates the
configuration and binds workloads; a *Multi-Engine Synchronizer* enforces
the four measurement invariants.  On a TPU slice the synchronizer is an
SPMD program over a 1-D "engine" mesh where engine 0 runs the main
activity and engines 1..k the stress activity — the measured region is
sandwiched between two all-reduce barriers, the collective analog of the
paper's spin-lock sandwich:

  (1) measurement starts only after every engine passed the start
      barrier (psum #1);
  (2) the scenario is stable: one fused SPMD program, engines run
      lockstep until their activity completes;
  (3) the stop barrier (psum #2) completes only after every engine's
      activity finished — measurement closes before anything is torn
      down;
  (4) the next scenario is a new program dispatch, which cannot begin
      until the previous one fully retired (host blocks on the result).

Backends:
  * ``simulate``  — closed queueing network (repro.core.simulate); full
                    contention ladders at modeled v5e scale.
  * ``interpret`` — really executes the observed activity's Pallas
                    kernels (interpret mode, this container's CPU);
                    contention scenarios beyond 0 stressors fall back to
                    the model (single real device).
  * ``tpu``       — same SPMD program, real hardware (not available in
                    this container; code path kept identical).
  * ``spmd``      — *executes* contention ladders on an ("engine",)
                    mesh: observer + coupled sibling observers + live
                    stressor engines, rung activities built from the
                    real Pallas kernel library (pure-jnp fallback via
                    ``compat.pallas_supported``), measured region
                    dataflow-fenced between two psum barriers.  The
                    default dispatch mode (``spmd_dispatch="batched"``)
                    applies SWEEP-LEVEL megabatching: ``run_matrix``
                    groups ladders by role-program signature and runs
                    each group as ONE stacked dispatch — the fused
                    ladder's ``lax.scan`` over per-rung role tables
                    gains a leading scenario axis, every scanned rung
                    of every stacked ladder keeps its own psum
                    sandwich and in-dispatch ``compat.device_clock``
                    stamp pair, so a whole sweep costs ~one
                    host-synchronous dispatch per distinct signature.
                    ``spmd_dispatch="ladder"`` keeps the one-fused-
                    dispatch-per-ladder mode, ``"rung"`` the legacy
                    one-dispatch-per-rung path with host wall-clock
                    timing.  Programs are AOT-compiled once per
                    signature (``compat.aot_compile``) and an opt-in
                    persistent compile cache
                    (``compile_cache_dir=``) reuses cacheable
                    executables across processes.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import simulate as sim
from repro.core.devicetree import Platform, detect_platform
from repro.core.pools import MemoryPool, PoolManager
from repro.core.scenarios import (ObserverSpec, ScenarioSpec, StressorSpec,
                                  TrafficShape)
from repro.core.workloads import (LINE_BYTES, Workload, WorkloadResult,
                                  make_shaped_workload, make_workload,
                                  measure_group, resolve_strategy,
                                  rows_for as _wl_rows)

# ---------------------------------------------------------------------------


def _effective_duty(shape) -> float:
    """Duty cycle of a role's traffic shape, with the degenerate-value
    guard every call site must share: absent shapes and 0/None duties
    count as always-on.  Work balancing *divides* by this (a 0-duty
    role would otherwise get an infinite iteration budget) and the
    observer's ``n_active`` stamping multiplies by it — both sides of
    the accounting must use the same number."""
    if shape is None:
        return 1.0
    return getattr(shape, "duty_cycle", 1.0) or 1.0


@dataclass(frozen=True)
class ActivitySpec:
    strategy: str              # Table-I letter
    pool: str                  # pool name ("hbm", "host", ...)
    buffer_bytes: int
    # optional traffic-shape parameters (ScenarioSpec DSL; the defaults
    # reproduce the seed's steady streams exactly)
    read_fraction: Optional[float] = None   # mixed r/w ratio
    duty_cycle: float = 1.0                 # bursty/duty-cycled
    stride: int = 1                         # strided pointer-chase

    def describe(self) -> str:
        return f"({self.strategy},{self.pool},{self.buffer_bytes >> 10}K)"

    def shape(self) -> Optional[TrafficShape]:
        """The TrafficShape these fields encode (None = steady)."""
        if self.read_fraction is not None:
            # surface grid points carry BOTH a mix and a duty cycle —
            # dropping the duty here would silently rebuild a hotter
            # shape than the one that ran
            return TrafficShape(kind="mixed",
                                read_fraction=self.read_fraction,
                                duty_cycle=self.duty_cycle)
        if self.duty_cycle < 1.0:
            return TrafficShape(kind="burst", duty_cycle=self.duty_cycle)
        if self.stride > 1:
            return TrafficShape(kind="strided", stride=self.stride)
        return None

    @staticmethod
    def from_stressor(s: StressorSpec) -> "ActivitySpec":
        return ActivitySpec(
            s.strategy, s.pool, s.buffer_bytes,
            read_fraction=(s.shape.read_fraction
                           if s.shape.kind == "mixed" else None),
            duty_cycle=s.shape.duty_cycle,
            stride=s.shape.stride)


@dataclass(frozen=True)
class ExperimentConfig:
    main: ActivitySpec
    stress: ActivitySpec
    iters: int = 500
    scenarios: Optional[int] = None      # default: platform.n_engines
    counters: Tuple[str, ...] = ("WALL_NS", "HLO_FLOPS", "HLO_BYTES",
                                 "TRANSACTIONS", "NS_PER_TX")


@dataclass
class ScenarioResult:
    n_stressors: int
    main: WorkloadResult
    modeled_bw_gbps: float = 0.0
    modeled_lat_ns: float = 0.0
    stress_bw_gbps: float = 0.0
    # where this rung's curve value comes from: "modeled" (queueing
    # network; `main` is at most an uncontended measurement) or
    # "executed" (`main` IS the observer measured under n_stressors
    # live stress engines — the spmd backend)
    source: str = "modeled"


@dataclass
class ExperimentResult:
    config: ExperimentConfig
    scenarios: List[ScenarioResult] = field(default_factory=list)

    def bandwidth_curve(self) -> List[Tuple[int, float]]:
        return [(s.n_stressors,
                 s.modeled_bw_gbps or s.main.bandwidth_gbps)
                for s in self.scenarios]

    def latency_curve(self) -> List[Tuple[int, float]]:
        return [(s.n_stressors, s.modeled_lat_ns or s.main.latency_ns)
                for s in self.scenarios]


class ValidationError(ValueError):
    pass


# ---------------------------------------------------------------------------


class CoreCoordinator:
    # compiled spmd programs kept per coordinator (LRU): fused ladder
    # programs are expensive to trace, and back-to-back run_matrix
    # calls must not re-trace/re-transfer what they just built.  Each
    # entry also holds its placed operand arrays, so the bound is a
    # MEMORY bound; the fused path needs one entry per ladder
    # signature where the legacy per-rung path needs K — big sweeps
    # can overflow the default on the legacy path (raise
    # ``spmd_cache_cap`` to trade memory for re-compiles).
    _SPMD_CACHE_CAP = 32

    def __init__(self, pool_mgr: Optional[PoolManager] = None,
                 platform: Optional[Platform] = None,
                 backend: str = "auto",
                 spmd_activity: str = "auto",
                 spmd_dispatch: str = "batched",
                 spmd_samples: int = 3,
                 spmd_cache_cap: Optional[int] = None,
                 compile_cache_dir: Optional[str] = None):
        self.platform = platform or detect_platform()
        self.pools = pool_mgr or PoolManager(self.platform)
        if backend == "auto":
            backend = "tpu" if jax.default_backend() == "tpu" else "simulate"
        assert backend in ("simulate", "interpret", "tpu", "spmd"), backend
        self.backend = backend
        # what fills the spmd backend's rung measured regions: real
        # Pallas kernels ("pallas": stream/chase/copy, compiled on TPU
        # and interpret-mode elsewhere) or the pure-jnp traffic loops
        # ("jnp", the PR-2 stand-ins).  "auto" probes the host backend
        # via compat.pallas_supported() and falls back honestly; the
        # resolved choice is stamped into every executed curve's
        # ``execution["activity"]`` provenance.
        assert spmd_activity in ("auto", "pallas", "jnp"), spmd_activity
        self.spmd_activity = spmd_activity
        # how the spmd backend dispatches a sweep: "batched" (default)
        # groups same-signature ladders ACROSS the whole matrix and
        # executes each group as ONE stacked dispatch (the fused
        # ladder's lax.scan gains a leading scenario axis — ~1 dispatch
        # per distinct role-program signature per sweep); "ladder"
        # fuses the K rungs of ONE ladder into one dispatch (scanned
        # psum sandwiches, per-rung in-dispatch device_clock timing);
        # "rung" is the legacy one-dispatch-per-rung path (host
        # wall-clock, median-of-3).  "batched"/"ladder" need an
        # in-dispatch timestamp source and fall back to "rung"
        # honestly when compat.device_clock_source() reports none; the
        # resolved choice lands in every curve's
        # ``execution["timing_source"]`` ("device" vs "host"), and the
        # batched path additionally stamps ``execution["batched"]`` /
        # ``["group_size"]``.
        assert spmd_dispatch in ("batched", "ladder", "rung"), spmd_dispatch
        assert spmd_samples >= 1, spmd_samples
        self.spmd_dispatch = spmd_dispatch
        self.spmd_samples = spmd_samples
        self.spmd_cache_cap = (spmd_cache_cap if spmd_cache_cap
                               is not None else self._SPMD_CACHE_CAP)
        assert self.spmd_cache_cap >= 1, self.spmd_cache_cap
        # (program key) -> [mesh, fn, fenced, xf, xi, aot]; mutable
        # entries because donated dispatches rebind the operand arrays
        from collections import OrderedDict
        self._spmd_programs: "OrderedDict[Tuple, list]" = OrderedDict()
        # opt-in persistent compile cache: repeated harness/CI/process
        # runs reuse on-disk XLA executables for cacheable programs.
        # NOTE: the underlying JAX config is PROCESS-GLOBAL — enabling
        # it here serves every compile in the process (other
        # coordinators included), and a second coordinator with a
        # different dir re-points the whole process; the attribute
        # below records only what THIS coordinator requested
        # (compat.persistent_cache documents scope + the host-callback
        # caveat)
        self.compile_cache_dir = compile_cache_dir
        if compile_cache_dir:
            from repro import compat
            self.persistent_cache_enabled = compat.persistent_cache(
                compile_cache_dir)
        else:
            self.persistent_cache_enabled = False

    def _resolved_activity(self) -> str:
        """The rung-activity implementation the spmd backend will use."""
        from repro import compat
        if self.spmd_activity != "auto":
            return self.spmd_activity
        return "pallas" if compat.pallas_supported() else "jnp"

    def _resolved_dispatch(self) -> str:
        """The spmd dispatch mode that will actually run: the fused
        ladder and the sweep-batched stacking both need an in-dispatch
        timestamp source (per-rung elapsed comes from device_clock
        deltas; without one, only the host-timed per-rung path is
        honest)."""
        from repro import compat
        if self.spmd_dispatch == "rung":
            return "rung"
        if compat.device_clock_source() == "none":
            return "rung"
        return self.spmd_dispatch

    # -- spmd program cache (LRU, coordinator lifetime) -----------------
    def _program_cache_get(self, key: Tuple,
                           stats: Optional["DispatchStats"] = None):
        entry = self._spmd_programs.get(key)
        if entry is not None:
            self._spmd_programs.move_to_end(key)
            if stats is not None:
                stats.program_cache_hits += 1
        return entry

    def _program_cache_put(self, key: Tuple, entry: list) -> None:
        self._spmd_programs[key] = entry
        self._spmd_programs.move_to_end(key)
        while len(self._spmd_programs) > self.spmd_cache_cap:
            _k, evicted = self._spmd_programs.popitem(last=False)
            # the cap is a MEMORY bound: dropping only the dict entry
            # would leave the evicted program's placed (and possibly
            # donation-aliased) operand buffers alive on the devices
            # until Python GC got around to them — delete the device
            # buffers eagerly so a capped cache cannot pin memory for
            # programs it no longer holds
            for arr in evicted[3:5]:
                delete = getattr(arr, "delete", None)
                if delete is not None:
                    try:
                        delete()
                    except Exception:
                        pass        # already consumed by donation

    # -- Experiment Instantiator ----------------------------------------
    def validate(self, cfg: ExperimentConfig) -> None:
        from repro.core.workloads import _REGISTRY
        for which, spec in (("main", cfg.main), ("stress", cfg.stress)):
            if spec.strategy not in _REGISTRY:
                raise ValidationError(
                    f"{which}: unknown strategy {spec.strategy!r}")
            pool = self.pools.pool(spec.pool)   # raises PoolError if absent
            if spec.strategy != "i" and spec.buffer_bytes > pool.available:
                raise ValidationError(
                    f"{which}: buffer {spec.buffer_bytes}B exceeds free "
                    f"space in pool {spec.pool} ({pool.available}B)")
        if cfg.iters <= 0:
            raise ValidationError("iters must be positive")
        n = cfg.scenarios if cfg.scenarios is not None \
            else self.platform.n_engines
        if not 1 <= n <= self.platform.n_engines:
            raise ValidationError(
                f"scenarios must be in [1, {self.platform.n_engines}]")

    # -- scenario ladder ----------------------------------------------------
    def run(self, cfg: ExperimentConfig) -> ExperimentResult:
        self.validate(cfg)
        n_scen = cfg.scenarios if cfg.scenarios is not None \
            else self.platform.n_engines
        result = ExperimentResult(cfg)

        main_pool = self.pools.pool(cfg.main.pool)
        stress_pool = self.pools.pool(cfg.stress.pool)

        measured: Optional[WorkloadResult] = None
        if self.backend in ("interpret", "tpu"):
            wl = make_shaped_workload(cfg.main.strategy, main_pool,
                                      cfg.main.buffer_bytes,
                                      cfg.main.shape())
            try:
                measured = wl.run(cfg.iters)
            finally:
                wl.release()

        for k in range(n_scen):
            modeled = self._model_scenario(cfg, main_pool, stress_pool, k)  # noqa: E501
            main_res = measured if measured is not None else WorkloadResult(
                cfg.main.strategy, cfg.main.pool, cfg.main.buffer_bytes,
                cfg.iters, 0, 0.0, 0)
            result.scenarios.append(ScenarioResult(
                n_stressors=k,
                main=main_res,
                modeled_bw_gbps=modeled[0],
                modeled_lat_ns=modeled[1],
                stress_bw_gbps=modeled[2],
            ))
        # per-scenario/experiment teardown (paper §III-A step 6) is done by
        # wl.release() above; pools stay clean for the next experiment.
        return result

    def _model_scenario(self, cfg: ExperimentConfig, main_pool: MemoryPool,
                        stress_pool: MemoryPool,
                        k: int) -> Tuple[float, float, float]:
        obs_node = self._model_node(cfg.main, main_pool,
                                    other=cfg.stress, other_engines=k)
        stress_node = self._model_node(cfg.stress, stress_pool,
                                       other=cfg.main, other_engines=1)
        classes = [sim.ActivityClass(
            "obs", obs_node, cfg.main.strategy, 1,
            read_fraction=cfg.main.read_fraction,
            duty_cycle=cfg.main.duty_cycle, stride=cfg.main.stride)]
        if k and cfg.stress.strategy != "i":
            classes.append(sim.ActivityClass(
                "stress", stress_node, cfg.stress.strategy, k,
                read_fraction=cfg.stress.read_fraction,
                duty_cycle=cfg.stress.duty_cycle,
                stride=cfg.stress.stride))
        res = sim.simulate_scenario(self.platform, classes)
        obs = res.get("obs")
        stress = res.get("stress")
        return (obs.bw_gbps if obs else 0.0,
                obs.lat_ns if obs else 0.0,
                stress.bw_gbps if stress else 0.0)

    # -- cache semantics ------------------------------------------------------
    _CACHEABLE = ("r", "w", "l", "c", "b")

    def _model_node(self, spec: ActivitySpec, pool: MemoryPool,
                    other: Optional[ActivitySpec] = None,
                    other_engines: int = 0):
        """Where does this activity's traffic actually land?

        Cacheable strategies on small buffers hit the platform's cache
        (transparent shared L2 on the ZCU102; software-managed private
        VMEM residency on v5e) — UNLESS, for a *shared* cache, the
        combined cacheable footprint exceeds it (inter-engine evictions,
        the red case of Fig. 12)."""
        node = pool.node
        if node.kind in ("vmem", "cache"):
            return node
        if spec.strategy not in self._CACHEABLE:
            return node

        cache_name = getattr(self.platform, "cache_node", None)
        if cache_name:                     # transparent shared cache
            cache = self.platform.memories[cache_name]
            if spec.buffer_bytes > cache.size_bytes:
                return node
            footprint = spec.buffer_bytes
            if other is not None and other.strategy in self._CACHEABLE:
                other_pool = self.pools.pool(other.pool)
                if other_pool.node.kind not in ("vmem", "cache"):
                    footprint += other_engines * other.buffer_bytes
            return cache if footprint <= cache.size_bytes else node

        # v5e: private VMEM residency, no cross-engine eviction
        from repro.core.workloads import models_as_vmem
        vmem = self.platform.memories.get("vmem")
        if vmem is not None and models_as_vmem(spec.buffer_bytes):
            return vmem
        return node

    # -- ladder sweep used by characterize.py ------------------------------
    def ladder(self, main: ActivitySpec, stress: ActivitySpec,
               iters: int = 500) -> ExperimentResult:
        return self.run(ExperimentConfig(main=main, stress=stress,
                                         iters=iters))

    # ==================================================================
    # ScenarioSpec matrix execution (the v2 characterization engine)
    # ==================================================================

    def validate_spec(self, spec: ScenarioSpec) -> None:
        from repro.core.workloads import _REGISTRY
        # exact-duplicate observers (same pool/strategy/shape/buffers)
        # would alias one curve key per buffer and silently overwrite
        # each other's ladders in CurveDB — reject them up front
        # (observers differing in ANY field, e.g. buffer ladders, are
        # legitimate twins and key distinctly via the buf= suffix)
        seen = set()
        for obs in spec.observers:
            if obs in seen:
                raise ValidationError(
                    f"{spec.name}: duplicate observer "
                    f"({obs.pool}:{obs.strategy}"
                    f"{'@' + obs.shape.tag() if obs.shape.tag() else ''}, "
                    f"buffers={obs.buffers}) — its curves would alias "
                    f"the first occurrence's keys")
            seen.add(obs)
        for obs in spec.observers:
            if obs.strategy not in _REGISTRY:
                raise ValidationError(
                    f"{spec.name}: unknown observer strategy "
                    f"{obs.strategy!r}")
            pool = self.pools.pool(obs.pool)
            for b in obs.buffers:
                if obs.strategy != "i" and b > pool.available:
                    raise ValidationError(
                        f"{spec.name}: observer buffer {b}B exceeds pool "
                        f"{obs.pool} ({pool.available}B free)")
        for s in spec.stressors:
            if s.strategy not in _REGISTRY:
                raise ValidationError(
                    f"{spec.name}: unknown stressor strategy "
                    f"{s.strategy!r}")
            self.pools.pool(s.pool)
        if spec.iters <= 0:
            raise ValidationError(f"{spec.name}: iters must be positive")
        if spec.max_stressors is not None and not (
                0 <= spec.max_stressors < self.platform.n_engines):
            raise ValidationError(
                f"{spec.name}: max_stressors out of "
                f"[0, {self.platform.n_engines})")

    def _obs_activity(self, observer: ObserverSpec,
                      buffer_bytes: int) -> ActivitySpec:
        sh = observer.shape
        return ActivitySpec(
            observer.strategy, observer.pool, buffer_bytes,
            read_fraction=(sh.read_fraction if sh.kind == "mixed"
                           else None),
            duty_cycle=sh.duty_cycle, stride=sh.stride)

    def _model_spec_scenario(self, spec: ScenarioSpec,
                             observer: ObserverSpec, buffer_bytes: int,
                             k: int) -> Tuple[float, float, float]:
        """Model one rung of the ladder: one observer + k stress engines
        distributed round-robin over the stressor ensemble — plus, for a
        *coupled* multi-observer scenario, one always-on single-engine
        class per sibling observer (:func:`sim.co_observer_class`): the
        siblings are part of this observer's measured region at every
        rung, exactly like the spmd backend's executed rungs.  With
        ``spec.coupled=False`` each observer sees only the stressor
        ensemble (the historical semantics)."""
        obs_act = self._obs_activity(observer, buffer_bytes)
        obs_pool = self.pools.pool(observer.pool)
        first = spec.stressors[0] if spec.stressors else None
        obs_node = self._model_node(
            obs_act, obs_pool,
            other=ActivitySpec.from_stressor(first) if first else None,
            other_engines=k)
        classes = [sim.ActivityClass(
            "obs", obs_node, obs_act.strategy, 1,
            read_fraction=obs_act.read_fraction,
            duty_cycle=obs_act.duty_cycle, stride=obs_act.stride)]
        for j, sib in enumerate(self._coupled_siblings(spec, observer)):
            if sib.strategy == "i":
                continue
            act = self._obs_activity(sib, sib.buffers[0])
            node = self._model_node(act, self.pools.pool(sib.pool),
                                    other=obs_act, other_engines=1)
            classes.append(sim.co_observer_class(
                f"co{j}", node, act.strategy,
                read_fraction=act.read_fraction,
                duty_cycle=act.duty_cycle, stride=act.stride))
        m = len(spec.stressors)
        if k and m:
            share = [k // m + (1 if j < k % m else 0) for j in range(m)]
            for j, (s, e) in enumerate(zip(spec.stressors, share)):
                if e == 0 or s.strategy == "i":
                    continue
                act = ActivitySpec.from_stressor(s)
                node = self._model_node(act, self.pools.pool(s.pool),
                                        other=obs_act, other_engines=1)
                classes.append(sim.ActivityClass(
                    f"stress{j}", node, s.strategy, e,
                    read_fraction=act.read_fraction,
                    duty_cycle=act.duty_cycle, stride=act.stride))
        res = sim.simulate_scenario(self.platform, classes)
        obs = res.get("obs")
        stress_bw = sum(r.bw_gbps for n, r in res.items()
                        if n.startswith("stress"))
        return (obs.bw_gbps if obs else 0.0,
                obs.lat_ns if obs else 0.0,
                stress_bw)

    @staticmethod
    def _coupled_siblings(spec: ScenarioSpec,
                          observer: ObserverSpec) -> Tuple[ObserverSpec, ...]:
        """The sibling observers sharing this observer's measured
        region (the logic lives on :meth:`ScenarioSpec.coupled_siblings`
        so the sweep-level grouping signature can reuse it)."""
        return spec.coupled_siblings(observer)

    def _ladder_depth(self, spec: ScenarioSpec) -> int:
        n = (spec.max_stressors + 1 if spec.max_stressors is not None
             else self.platform.n_engines)
        n = min(n, self.platform.n_engines)
        if self.backend == "spmd":
            # rung k needs k stress engines + 1 observer on the mesh —
            # plus one engine per coupled sibling observer, which runs
            # live inside every rung (same count for every observer)
            n_sib = len(spec.observers) - 1 if spec.coupled else 0
            n = min(n, self._spmd_engines() - n_sib)
        return max(1, n)

    def run_matrix(self, specs: List[ScenarioSpec], *,
                   batched: bool = True) -> "MatrixResult":
        """Execute a scenario matrix.

        The measured observer pass is where executable backends spend
        their dispatches; ``batched=True`` groups same-signature
        observers (strategy, shape, row count, residency, effective
        memory placement) and measures each group with ONE jit'd
        vmapped pass, instead of the naive one-dispatch-per-scenario
        Python loop.  Multi-observer scenarios contribute one ladder
        per (observer, buffer) and their observers join the same
        signature groups.

        Backends: ``simulate``/``interpret``/``tpu`` model the
        contention ladder per rung (interpret/tpu additionally measure
        the uncontended observer); ``spmd`` *executes* every rung —
        observer + coupled sibling observers + k live stressor engines
        between two psum barriers — and the resulting curves carry
        ``source == "executed"``.  On the spmd backend ``batched=True``
        additionally applies SWEEP-LEVEL megabatching (the default
        ``spmd_dispatch="batched"``): ladders are grouped by
        role-program signature across the whole matrix and every group
        executes as ONE stacked dispatch, so a sweep costs ~one
        host-synchronous dispatch per distinct signature instead of
        one per ladder; ``batched=False`` degrades the batched mode to
        one fused dispatch per ladder.  Every curve's ``execution``
        provenance records the backend, executed-vs-modeled rungs,
        effective ``coupled`` state, the rung ``activity`` ("pallas"
        kernels, "jnp" fallback loops, or "none"), and — for spmd —
        ``batched``/``group_size``/``aot``."""
        for spec in specs:
            self.validate_spec(spec)
        triples = [(spec, obs, b) for spec in specs
                   for obs in spec.observers for b in obs.buffers]
        stats = DispatchStats(n_scenarios=len(specs),
                              n_ladders=len(triples))

        measured: Dict[int, WorkloadResult] = {}
        executed: Dict[Tuple[int, int], WorkloadResult] = {}
        fenced_by_triple: Dict[int, bool] = {}
        timing_by_triple: Dict[int, Dict[str, Any]] = {}
        if self.backend in ("interpret", "tpu"):
            # the measured pass runs the real Pallas kernel library
            activity = "pallas"
            measured = self._measure_triples(triples, batched, stats)
        elif self.backend == "spmd":
            activity = self._resolved_activity()
            executed, fenced_by_triple, timing_by_triple = \
                self._execute_spmd(triples, stats, activity,
                                   batched=batched)
        else:
            activity = "none"       # nothing executes on this backend

        runs: List[ScenarioRun] = []
        for i, (spec, obs, buf) in enumerate(triples):
            n_scen = self._ladder_depth(spec)
            scenarios = []
            exec_rungs = []
            for k in range(n_scen):
                bw, lat, sbw = self._model_spec_scenario(spec, obs, buf, k)
                stats.model_evals += 1
                ex = executed.get((i, k))
                main_res = ex if ex is not None else (
                    measured.get(i) or WorkloadResult(
                        obs.strategy, obs.pool, buf, spec.iters, 0, 0.0,
                        0))
                if ex is not None:
                    exec_rungs.append(k)
                scenarios.append(ScenarioResult(
                    n_stressors=k, main=main_res, modeled_bw_gbps=bw,
                    modeled_lat_ns=lat, stress_bw_gbps=sbw,
                    source="executed" if ex is not None else "modeled"))
            execution = {
                "backend": self.backend,
                "executed_rungs": exec_rungs,
                "modeled_rungs": [k for k in range(n_scen)
                                  if k not in exec_rungs],
                "measured_uncontended": i in measured,
                # whether this curve's siblings were part of its
                # measured region / queueing network (effective
                # coupling: a single-observer spec couples nothing)
                "coupled": bool(spec.coupled and len(spec.observers) > 1),
                # what fills the measured region: "pallas" (real
                # kernels), "jnp" (traffic loops), "none" (modeled)
                "activity": activity,
            }
            if self.backend == "spmd":
                execution["n_engines"] = self._spmd_engines()
                # the structurally VERIFIED fence state of this
                # ladder's executed programs (jaxpr dataflow check)
                execution["fenced"] = fenced_by_triple.get(i, False)
                # how the executed rungs were timed: "device" (fused
                # ladder, in-dispatch device_clock deltas) or "host"
                # (legacy per-rung wall clock), plus the per-rung
                # sample spreads and the host-synchronous dispatch
                # count this ladder cost
                execution.update(timing_by_triple.get(i, {}))
                execution["operand_memory_kinds"] = sorted(
                    {self.pools.pool(p).effective_memory_kind()
                     or "default"
                     for p in ([obs.pool]
                               + [o.pool for o in
                                  self._coupled_siblings(spec, obs)]
                               + [s.pool for s in spec.stressors])})
            runs.append(ScenarioRun(spec=spec, buffer_bytes=buf,
                                    key=spec.key_for(obs, buf),
                                    observer=obs,
                                    scenarios=scenarios,
                                    execution=execution))
        return MatrixResult(runs=runs, stats=stats)

    def _measure_triples(self, triples, batched: bool,
                         stats: "DispatchStats") -> Dict[int, WorkloadResult]:
        """The measured observer pass over all (spec, observer, buffer)
        triples (uncontended: single real device)."""
        measured: Dict[int, WorkloadResult] = {}
        if not batched:
            for i, (spec, obs, buf) in enumerate(triples):
                wl = make_shaped_workload(
                    obs.strategy, self.pools.pool(obs.pool), buf,
                    obs.shape)
                try:
                    measured[i] = wl.run(spec.iters)
                finally:
                    wl.release()
                stats.measure_dispatches += 1
            return measured

        # Group signature: everything that changes the compiled measured
        # pass or the numbers stamped on its results.  ``iters`` is part
        # of the signature — members must be measured at THEIR OWN
        # budget, not silently at the group max.  The pool appears only
        # through its *effective* placement: observers from different
        # pools whose arrays land in the same physical memory (e.g. hbm
        # + emulated host on this container) legally share one stacked
        # vmapped batch; pools that really differ split.
        groups: Dict[Tuple, List[int]] = {}
        for i, (spec, obs, buf) in enumerate(triples):
            pool = self.pools.pool(obs.pool)
            sig = (obs.strategy, obs.shape, buf, spec.iters,
                   pool.effective_memory_kind(),
                   pool.node.kind == "vmem")
            groups.setdefault(sig, []).append(i)
        for (strategy, shape, buf, iters, _kind, _vm), idxs in \
                groups.items():
            member_pools = [self.pools.pool(triples[i][1].pool)
                            for i in idxs]
            results, dispatches = measure_group(
                strategy, member_pools[0], buf, len(idxs), iters,
                shape=shape, member_pools=member_pools)
            stats.measure_dispatches += dispatches
            for i, res in zip(idxs, results):
                measured[i] = res
        return measured

    # -- the spmd backend: executable multi-engine contention -----------

    def _spmd_engines(self) -> int:
        return max(1, min(self.platform.n_engines, len(jax.devices())))

    def _execute_spmd(
        self, triples, stats: "DispatchStats", activity: str = "jnp",
        batched: bool = True,
    ) -> Tuple[Dict[Tuple[int, int], WorkloadResult], Dict[int, bool],
               Dict[int, Dict[str, Any]]]:
        """Execute every (spec, observer, buffer) triple's contention
        ladder on the engine mesh — same-signature ladders stacked into
        ONE dispatch per group (``spmd_dispatch="batched"``, the
        default), the whole ladder as one fused dispatch per triple
        (``"ladder"``), or one dispatch per rung (``"rung"``, the
        legacy path).  Returns the per-(triple, rung) observer results,
        the verified fence state per triple, and per-triple timing
        provenance (source, sample spreads, host-synchronous dispatch
        counts, batching/AOT state)."""
        n_eng = self._spmd_engines()
        if n_eng < 2:
            raise ValidationError(
                "spmd backend needs >= 2 devices; start the process with "
                "XLA_FLAGS=--xla_force_host_platform_device_count=8 "
                "(CPU container) or run on a real multi-device slice")
        executed: Dict[Tuple[int, int], WorkloadResult] = {}
        fenced_by_triple: Dict[int, bool] = {}
        timing_by_triple: Dict[int, Dict[str, Any]] = {}
        dispatch = self._resolved_dispatch()
        if dispatch == "batched" and not batched:
            dispatch = "ladder"       # megabatching explicitly disabled
        if dispatch == "batched":
            from collections import OrderedDict
            groups: "OrderedDict[Tuple, List[int]]" = OrderedDict()
            for i, (spec, obs, buf) in enumerate(triples):
                key = self._spmd_group_key(spec, obs, buf)
                groups.setdefault(key, []).append(i)
            stats.spmd_groups += len(groups)
            for idxs in groups.values():
                members = [triples[i] for i in idxs]
                results, fenced, timings = self._run_spmd_group(
                    members, n_eng, stats, activity)
                for g, i in enumerate(idxs):
                    for k, res in enumerate(results[g]):
                        executed[(i, k)] = res
                    fenced_by_triple[i] = fenced
                    timing_by_triple[i] = timings[g]
            return executed, fenced_by_triple, timing_by_triple
        for i, (spec, obs, buf) in enumerate(triples):
            if dispatch == "ladder":
                results, fenced, timing = self._run_spmd_ladder(
                    spec, obs, buf, n_eng, stats, activity)
                for k, res in enumerate(results):
                    executed[(i, k)] = res
            else:
                fenced, timing = True, {
                    "timing_source": "host",
                    "samples": self.spmd_samples,
                    "rung_time_spread_ns": [], "dispatches": 0,
                    "batched": False, "group_size": 1, "aot": True}
                for k in range(self._ladder_depth(spec)):
                    res, rung_fenced, spread, rung_aot = \
                        self._run_spmd_rung(spec, obs, buf, k, n_eng,
                                            stats, activity=activity)
                    executed[(i, k)] = res
                    fenced = fenced and rung_fenced
                    timing["aot"] = timing["aot"] and rung_aot
                    timing["rung_time_spread_ns"].append(spread)
                    # 1 warm + the timed samples
                    timing["dispatches"] += 1 + self.spmd_samples
            fenced_by_triple[i] = fenced
            timing_by_triple[i] = timing
        return executed, fenced_by_triple, timing_by_triple

    def _spmd_group_key(self, spec: ScenarioSpec, obs: ObserverSpec,
                        buf: int) -> Tuple:
        """Sweep-level grouping key: triples with equal keys expand to
        the SAME per-rung role tables and operand placement, so their
        ladders legally stack into one batched dispatch.  The
        spec-level role signature (pool-free — see
        :meth:`ScenarioSpec.ladder_signature`) is refined by each role
        pool's *effective* memory kind: pools that differ only in name
        but land in one physical memory merge (like the interpret
        path's signature groups); pools that really differ split."""
        kinds = tuple(self.pools.pool(p).effective_memory_kind()
                      for p in spec.role_pools(obs))
        return (spec.ladder_signature(obs, buf), kinds)

    def _build_ladder_entry(self, per_rung, n_eng: int, activity: str,
                            samples: int, kind: Optional[str],
                            group: int, stats: "DispatchStats") -> list:
        """Build, fence-verify, place and (where the installed JAX
        allows) AOT-compile one fused ladder program — ``group > 1``
        stacks the scan table for a whole same-signature group, the
        scanned edition of a leading scenario axis.  The program is
        traced exactly ONCE (``compat.aot_trace``): the same trace
        feeds the structural fence walk and ``lower().compile()``."""
        from repro import compat

        deep_roles = per_rung[-1][0]
        rows_max = max(r[2] for r in deep_roles)
        xf, xi = _build_rung_operands(deep_roles, n_eng, rows_max)
        branch_fns: List = []
        branch_of: Dict[Tuple, int] = {}
        table = np.zeros((len(per_rung), n_eng), np.int32)
        for k, (roles, _pools) in enumerate(per_rung):
            for e, sig in enumerate(roles):
                if sig not in branch_of:
                    branch_of[sig] = len(branch_fns)
                    branch_fns.append(_spmd_branch_fn(
                        *sig, activity=activity))
                table[k, e] = branch_of[sig]
        if group > 1:
            # the leading scenario axis: ladder g's rungs are scan
            # steps [g*K, (g+1)*K) — every stacked rung keeps its own
            # psum sandwich and stamp pair, and the scan carry
            # serializes ladder g+1 behind ladder g exactly like rung
            # k+1 behind rung k (invariant 4, across the whole group)
            table = np.tile(table, (group, 1))
        mesh, fn = build_ladder_program(
            n_eng, branch_fns, table, samples=samples,
            donate=compat.donation_supported())
        # commit the operands onto the mesh BEFORE tracing: the AOT
        # executable is specialized to the placed shardings, and the
        # fence walk sees the same program the dispatch runs
        from jax.sharding import PartitionSpec as P
        sharding = compat.named_sharding(mesh, P("engine"), kind)
        xf = jax.device_put(xf, sharding)
        xi = jax.device_put(xi, sharding)
        jax.block_until_ready((xf, xi))
        traced = compat.aot_trace(fn, xf, xi)
        # provenance records the VERIFIED fence state of every scanned
        # rung of every stacked ladder, not an assertion (compat
        # degradation is honestly reported as unfenced)
        fenced = measured_region_is_fenced(
            fn, xf, xi, jaxpr=getattr(traced, "jaxpr", None))
        compiled = compat.aot_compile(fn, xf, xi, traced=traced)
        stats.programs_built += 1
        if compiled is not None:
            stats.aot_compiles += 1
        return [mesh, compiled if compiled is not None else fn, fenced,
                xf, xi, compiled is not None]

    def _dispatch_ladder_entry(self, entry: list, group: int,
                               n_scen: int, samples: int,
                               stats: "DispatchStats"):
        """ONE host-synchronous dispatch executes ``group`` stacked
        ladders of ``n_scen`` rungs each; returns the per-(ladder,
        rung) elapsed medians and sample spreads decoded from engine
        0's in-dispatch stamp pairs."""
        _mesh, call, fenced, xf, xi = entry[:5]
        out = jax.block_until_ready(call(xf, xi))
        stats.host_sync_dispatches += 1
        stats.measure_dispatches += 1
        stats.spmd_rungs += group * n_scen
        # donated dispatch consumed the cached operands; rebind the
        # returned (aliased in place where donation is real) arrays
        entry[3], entry[4] = out[3], out[4]
        # engine 0 is the observer: its [s, ns] stamp pairs bracket
        # each scanned sandwich, stop stamp taken after the stop psum
        # (i.e. when the SLOWEST engine finished — paper invariant 3)
        t0 = np.asarray(out[1])[0].reshape(group, n_scen, samples, 2)
        t1 = np.asarray(out[2])[0].reshape(group, n_scen, samples, 2)
        d = ((t1[..., 0].astype(np.int64) - t0[..., 0]) * 1_000_000_000
             + (t1[..., 1] - t0[..., 1]))
        med = np.median(d, axis=2)                      # (group, n_scen)
        spread = d.max(axis=2) - d.min(axis=2)
        return med, spread, fenced

    def _run_spmd_group(self, members, n_eng: int,
                        stats: "DispatchStats", activity: str = "jnp",
                        ) -> Tuple[List[List[WorkloadResult]], bool,
                                   List[Dict[str, Any]]]:
        """A whole same-signature ladder GROUP as one stacked dispatch:
        the fused ladder program's scan gains a leading scenario axis
        (ladder-major step order), every stacked rung keeps its own
        psum sandwich + device_clock stamp pair, and the host blocks
        ONCE for the entire group.  A 64-ladder sweep with S distinct
        signatures costs S host-synchronous dispatches and S cache
        entries instead of 64 — the sweep-level extension of the
        per-ladder fusion, attacking the warm-path dispatch tax."""
        spec0, obs0, buf0 = members[0]
        group = len(members)
        n_scen = self._ladder_depth(spec0)
        samples = self.spmd_samples
        per_rung = [self._rung_roles(spec0, obs0, buf0, k, n_eng)
                    for k in range(n_scen)]
        kind = self._operand_kind(
            [p for _r, pools in per_rung for p in pools])
        key = ("batched", n_eng, activity, kind, samples, group,
               tuple(tuple(r) for r, _p in per_rung))
        entry = self._program_cache_get(key, stats)
        if entry is None:
            entry = self._build_ladder_entry(per_rung, n_eng, activity,
                                             samples, kind, group, stats)
            self._program_cache_put(key, entry)
        aot = entry[5]
        med, spread, fenced = self._dispatch_ladder_entry(
            entry, group, n_scen, samples, stats)
        results: List[List[WorkloadResult]] = []
        timings: List[Dict[str, Any]] = []
        for g, (spec, obs, buf) in enumerate(members):
            results.append([
                self._observer_result(obs, buf, spec.iters,
                                      float(max(med[g, k], 1.0)))
                for k in range(n_scen)])
            timings.append({
                "timing_source": "device",
                "samples": samples,
                "rung_time_spread_ns": [int(s) for s in spread[g]],
                "dispatches": 1,
                "batched": True,
                "group_size": group,
                "aot": aot,
            })
        return results, fenced, timings

    def _rung_roles(self, spec: ScenarioSpec, obs: ObserverSpec,
                    buf: int, k: int, n_eng: int,
                    ) -> Tuple[List[Tuple], List[str]]:
        """The per-engine role layout of rung k: engine 0 runs the
        observer, the next engines its coupled sibling observers (every
        observer of a coupled multi-observer spec is live inside every
        sibling's measured region), then k stressor engines (ensemble
        round-robin), the rest idle.  Returns ``(roles, role_pools)``
        with one ``(strategy, shape, rows, iters)`` tuple per engine.

        Sibling and stressor iteration budgets are work-balanced
        against the passes the observer branch will actually execute
        (its duty cycle included, via :func:`_effective_duty` on BOTH
        sides of the division) so role imbalance does not masquerade
        as contention; residual per-kind speed differences (a chase
        row costs more than a stream row) remain and are what the
        in-dispatch rung clocks measure."""
        iters = spec.iters
        obs_rows = _wl_rows(buf)
        roles: List[Tuple] = [(obs.strategy, obs.shape, obs_rows, iters)]
        role_pools = [obs.pool]
        m = len(spec.stressors)
        obs_work = obs_rows * max(
            1, round(iters * _effective_duty(obs.shape)))
        for sib in self._coupled_siblings(spec, obs)[:n_eng - 1]:
            sib_rows = _wl_rows(sib.buffers[0])
            sib_iters = max(1, round(
                obs_work / (sib_rows * _effective_duty(sib.shape))))
            roles.append((sib.strategy, sib.shape, sib_rows, sib_iters))
            role_pools.append(sib.pool)
        for e in range(min(k, n_eng - len(roles))):
            if m:
                s = spec.stressors[e % m]
                s_rows = _wl_rows(s.buffer_bytes)
                s_iters = max(1, round(
                    obs_work / (s_rows * _effective_duty(s.shape))))
                roles.append((s.strategy, s.shape, s_rows, s_iters))
                role_pools.append(s.pool)
            else:
                roles.append(("i", None, 1, iters))
                role_pools.append(obs.pool)
        while len(roles) < n_eng:
            roles.append(("i", None, 1, iters))
            role_pools.append(obs.pool)
        return roles, role_pools

    def _operand_kind(self, role_pools) -> Optional[str]:
        """Per-pool operand placement: when every engine's pool lands
        in one effective memory kind, the stacked operands carry that
        kind's sharding into the fused dispatch; mixed-pool programs
        fall back to the default memory (one stacked array has one
        memory kind — per-engine kinds need a real multi-chip slice
        and per-pool operand splitting, the remaining ROADMAP item)."""
        kinds = {self.pools.pool(p).effective_memory_kind()
                 for p in role_pools}
        return kinds.pop() if len(kinds) == 1 else None

    def _observer_result(self, obs: ObserverSpec, buf: int, iters: int,
                         elapsed: float) -> WorkloadResult:
        """Stamp one executed rung's observer measurement.  Uses the
        RESOLVED strategy letter, like the interpret-path group
        measurement does: the executed branch for a mixed 'r' observer
        is the 'b' loop, and provenance must say so."""
        obs_rows = _wl_rows(buf)
        strat = resolve_strategy(obs.strategy, obs.shape)
        n_active = max(1, int(round(iters * _effective_duty(obs.shape))))
        if strat in _SPMD_CHASES:
            # elapsed spans n_active full traversals: bytes and
            # transactions both scale with it (latency = elapsed/tx)
            return WorkloadResult(strat, obs.pool, buf, iters,
                                  obs_rows * LINE_BYTES * n_active,
                                  elapsed,
                                  transactions=obs_rows * n_active)
        mult = 2 if strat in _SPMD_STREAM_2X else 1
        return WorkloadResult(strat, obs.pool, buf, iters,
                              mult * obs_rows * LINE_BYTES * n_active,
                              elapsed, 0)

    def _run_spmd_ladder(self, spec: ScenarioSpec, obs: ObserverSpec,
                         buf: int, n_eng: int, stats: "DispatchStats",
                         activity: str = "jnp",
                         ) -> Tuple[List[WorkloadResult], bool,
                                    Dict[str, Any]]:
        """The ENTIRE ladder (rungs k=0..K-1) as ONE fused dispatch.

        :func:`build_ladder_program` scans over the K per-rung role
        tables inside a single ``shard_map``; every scan step keeps its
        own psum sandwich, and per-rung elapsed time is captured
        IN-dispatch by ``compat.device_clock`` deltas — ``spmd_samples``
        sandwiched repetitions per rung, median taken on the host.
        Versus the legacy per-rung path this turns 4·K host-synchronous
        round-trips per ladder into one, and removes Python dispatch
        jitter from the measured region entirely (the fidelity gap the
        kernel-level framework exists to close).

        The compiled program and its placed (donated, where the backend
        supports donation) operands live in the coordinator-level LRU
        cache, so repeated ``run_matrix`` calls re-dispatch without
        re-tracing or re-transferring."""
        n_scen = self._ladder_depth(spec)
        samples = self.spmd_samples
        per_rung = [self._rung_roles(spec, obs, buf, k, n_eng)
                    for k in range(n_scen)]
        # ONE operand set serves every scanned rung: placement must
        # agree across the whole ladder, not per rung.  (The DEEPEST
        # rung holds every engine's non-idle role — shallower rungs
        # only flip engines back to idle — so its layout decides
        # operand shapes and chase chains inside the builder.)
        kind = self._operand_kind(
            [p for _r, pools in per_rung for p in pools])
        key = ("ladder", n_eng, activity, kind, samples,
               tuple(tuple(r) for r, _p in per_rung))
        entry = self._program_cache_get(key, stats)
        if entry is None:
            entry = self._build_ladder_entry(per_rung, n_eng, activity,
                                             samples, kind, 1, stats)
            self._program_cache_put(key, entry)
        aot = entry[5]
        # ONE host-synchronous dispatch measures the whole ladder (no
        # warm-up run: compilation happens before execution, and the
        # per-rung median over `samples` in-dispatch repetitions
        # absorbs first-touch effects)
        med, spread, fenced = self._dispatch_ladder_entry(
            entry, 1, n_scen, samples, stats)
        results = [self._observer_result(obs, buf, spec.iters,
                                         float(max(med[0, k], 1.0)))
                   for k in range(n_scen)]
        timing = {
            "timing_source": "device",
            "samples": samples,
            "rung_time_spread_ns": [int(s) for s in spread[0]],
            "dispatches": 1,
            "batched": False,
            "group_size": 1,
            "aot": aot,
        }
        return results, fenced, timing

    def _run_spmd_rung(self, spec: ScenarioSpec, obs: ObserverSpec,
                       buf: int, k: int, n_eng: int,
                       stats: "DispatchStats",
                       activity: str = "jnp",
                       ) -> Tuple[WorkloadResult, bool, int, bool]:
        """The legacy per-rung path: one rung, one fused program —
        all branches of a single ``shard_map`` dispatch whose measured
        region sits between the two psum barriers of
        :func:`build_rung_program` (the returned bool is the
        structurally *verified* fence state of this rung's program,
        the final int the spread of the host wall-time samples).

        The wall time of the dispatch is the measured region: host
        ``perf_counter_ns`` around ``block_until_ready``, median of
        ``spmd_samples`` — which costs 1 + ``spmd_samples`` host
        round-trips per rung (4 at the default) and includes Python
        dispatch jitter.  The fused ladder path
        (:meth:`_run_spmd_ladder`) replaces both; this path is kept
        for comparison (``benchmarks/perf_harness.py``) and as the
        fallback where no in-dispatch timestamp source exists."""
        import time as _time

        from repro import compat

        roles, role_pools = self._rung_roles(spec, obs, buf, k, n_eng)
        rows_max = max(r[2] for r in roles)
        # the kind joins the cache key: identical role programs from
        # differently-placed pools must not share operands
        kind = self._operand_kind(role_pools)
        program_key = ("rung", n_eng, activity, kind, tuple(roles))
        entry = self._program_cache_get(program_key, stats)

        if entry is not None:
            # operands are fully determined by the cache key (chain
            # seeds are engine indices): reuse the placed arrays too —
            # no host-side rebuild, no repeated host->device transfer
            _mesh, fn, fenced, xf, xi, aot = entry
        else:
            xf, xi = _build_rung_operands(roles, n_eng, rows_max)
            branch_fns: List = []
            engine_branch: List[int] = []
            branch_of: Dict[Tuple, int] = {}
            for sig in roles:
                if sig not in branch_of:
                    branch_of[sig] = len(branch_fns)
                    branch_fns.append(_spmd_branch_fn(
                        *sig, activity=activity))
                engine_branch.append(branch_of[sig])
            mesh, fn = build_rung_program(n_eng, branch_fns,
                                          engine_branch)
            # commit the operands onto the mesh BEFORE the measured
            # region: a host array would be re-transferred inside
            # every timed call, and the transfer (which scales with
            # the widest role, not the observer) would dominate the
            # measurement
            from jax.sharding import PartitionSpec as P
            sharding = compat.named_sharding(mesh, P("engine"), kind)
            xf = jax.device_put(xf, sharding)
            xi = jax.device_put(xi, sharding)
            jax.block_until_ready((xf, xi))
            # one trace serves the fence walk AND the AOT compile; the
            # rung programs carry no host callbacks, so with a
            # persistent cache enabled the compile is also reused
            # across processes.  provenance records the VERIFIED fence
            # state, not an assertion (compat.optimization_barrier
            # degrades to identity on JAX releases without the op —
            # there the psum folds away and this honestly reports
            # unfenced)
            traced = compat.aot_trace(fn, xf, xi)
            fenced = measured_region_is_fenced(
                fn, xf, xi, jaxpr=getattr(traced, "jaxpr", None))
            compiled = compat.aot_compile(fn, xf, xi, traced=traced)
            stats.programs_built += 1
            if compiled is not None:
                stats.aot_compiles += 1
            aot = compiled is not None
            fn = compiled if compiled is not None else fn
            self._program_cache_put(program_key,
                                    [mesh, fn, fenced, xf, xi, aot])
        jax.block_until_ready(fn(xf, xi))          # warm (+ compile
        samples = []                               # when not AOT-built)
        for _ in range(self.spmd_samples):
            t0 = _time.perf_counter_ns()
            jax.block_until_ready(fn(xf, xi))
            samples.append(_time.perf_counter_ns() - t0)
        stats.host_sync_dispatches += 1 + self.spmd_samples
        stats.measure_dispatches += 1
        stats.spmd_rungs += 1
        elapsed = float(np.median(samples))
        res = self._observer_result(obs, buf, spec.iters, elapsed)
        return res, fenced, int(max(samples) - min(samples)), aot


# ---------------------------------------------------------------------------
# Matrix-run result containers
# ---------------------------------------------------------------------------


@dataclass
class ScenarioRun:
    """One (scenario, observer, buffer) ladder."""
    spec: ScenarioSpec
    buffer_bytes: int
    key: str
    observer: Optional[ObserverSpec] = None   # which observer this curve is
    scenarios: List[ScenarioResult] = field(default_factory=list)
    # executed-vs-modeled provenance, persisted into CurveDB v2:
    # {"backend", "executed_rungs", "modeled_rungs", ...}
    execution: Dict[str, Any] = field(default_factory=dict)

    def bandwidth_curve(self) -> List[Tuple[int, float]]:
        return [(s.n_stressors,
                 s.main.bandwidth_gbps if s.source == "executed"
                 else (s.modeled_bw_gbps or s.main.bandwidth_gbps))
                for s in self.scenarios]

    def latency_curve(self) -> List[Tuple[int, float]]:
        return [(s.n_stressors,
                 s.main.latency_ns if s.source == "executed"
                 else (s.modeled_lat_ns or s.main.latency_ns))
                for s in self.scenarios]


@dataclass
class DispatchStats:
    """Execution accounting for the matrix runner: the batched runner's
    claim ("fewer dispatches than the per-point loop") and the spmd
    backend's claim ("one fused SPMD dispatch per ladder rung") are
    checked against these numbers in the tests."""
    n_scenarios: int = 0            # ScenarioSpecs in the matrix
    n_ladders: int = 0              # (spec, observer, buffer) ladders
    measure_dispatches: int = 0     # timed executable measurement passes
    model_evals: int = 0            # queueing-network solves
    spmd_rungs: int = 0             # ladder rungs executed on the mesh
    # host-blocking spmd program executions: the sweep-batched path
    # does ONE per same-signature ladder GROUP (~ one per distinct
    # program signature per sweep), the fused ladder path one per
    # ladder, the legacy path 4 per RUNG (warm + 3 timed);
    # benchmarks/perf_harness.py holds each contender to its number
    host_sync_dispatches: int = 0
    # compiled spmd programs (+ placed operands) reused from the
    # coordinator-level LRU cache — across rungs, ladders, AND
    # back-to-back run_matrix calls on one coordinator
    program_cache_hits: int = 0
    # sweep-level megabatching: distinct role-program signatures this
    # run stacked ladders under (0 on the non-batched paths)
    spmd_groups: int = 0
    # spmd programs actually traced + compiled this run (cache
    # misses), and how many of those went through the AOT
    # lower().compile() pipeline (compat.aot_compile) — together with
    # host_sync_dispatches these make the dispatch-vs-compile
    # attribution in BENCH_spmd.json explicit
    programs_built: int = 0
    aot_compiles: int = 0


@dataclass
class MatrixResult:
    runs: List[ScenarioRun] = field(default_factory=list)
    stats: DispatchStats = field(default_factory=DispatchStats)


# ---------------------------------------------------------------------------
# The SPMD scenario program (the spin-lock sandwich, collective edition).
# Built for any 1-D mesh of engines; executable on forced host devices in
# this container and unchanged on a real slice.  The ``spmd`` backend
# dispatches one of these programs per ladder rung.
# ---------------------------------------------------------------------------

_SPMD_CHASES = ("l", "m", "t")      # latency walks: dependent gathers
_SPMD_STREAM_2X = ("c", "x")        # copy/rmw touch two lines per line


def _build_rung_operands(roles, n_eng: int,
                         rows_max: int) -> Tuple[np.ndarray, np.ndarray]:
    """Per-engine operands for one SPMD program: a float stream buffer
    and an int chase chain (seeded by engine index), padded to the
    widest role.  Operands are fully determined by the role layout, so
    cached programs can reuse their placed arrays verbatim."""
    from repro.kernels import ops as kops

    xf = np.broadcast_to(
        np.arange(rows_max * LINE_BYTES // 4, dtype=np.float32)
        .reshape(rows_max, LINE_BYTES // 4),
        (n_eng, rows_max, LINE_BYTES // 4)).copy()
    xi = np.zeros((n_eng, rows_max, LINE_BYTES // 4), np.int32)
    for e, (strategy, shape, rows, _ri) in enumerate(roles):
        if resolve_strategy(strategy, shape) in _SPMD_CHASES:
            if resolve_strategy(strategy, shape) == "t":
                chain = kops.strided_chain_buffer(
                    rows, getattr(shape, "stride", 8) or 8)
            else:
                chain = kops.chain_buffer(rows, seed=e)
            xi[e, :rows, :chain.shape[1]] = chain
    return xf, xi


def _spmd_branch_fn(strategy: str, shape, rows: int, iters: int,
                    activity: str = "jnp"):
    """Per-engine activity for one SPMD rung: ``(xf, xi) -> f32``.

    All branches take the SAME operand pair and return a scalar so
    ``lax.switch`` can fuse them; each closes over its own static row
    count and iteration budget.  Loop bodies either carry the buffer or
    re-issue it through ``optimization_barrier`` so XLA cannot hoist
    the memory traffic out of the loop.

    ``activity="pallas"`` builds the branch from the real kernel
    library (:mod:`repro.kernels.stream` / ``chase``: mixed-stream,
    copy, seeded write streams, strided/Sattolo chases — compiled on
    TPU, interpret-mode elsewhere); ``"jnp"`` is the pure-jnp traffic
    loop fallback for hosts where Pallas is unavailable
    (``compat.pallas_supported``)."""
    from repro import compat

    strat = resolve_strategy(strategy, shape)
    n = max(1, int(round(iters * _effective_duty(shape))))

    if activity == "pallas" and strategy != "i":
        return _pallas_branch_fn(strat, shape, rows, n)

    if strategy == "i":
        def idle(xf, xi):
            def body(_, acc):
                return acc * 0.999 + 1.0
            # seeded from the (barrier-fenced) operand: even idle
            # engines enter their spin only after the start barrier
            return jax.lax.fori_loop(0, n * 8, body, xf[0, 0] * 1e-30)
        return idle

    if strat in _SPMD_CHASES:
        def chase(xf, xi):
            chain = xi[:rows, 0]

            def step(_, idx):
                return chain[idx]

            def cycle(_, carry):
                idx, acc = carry
                idx = jax.lax.fori_loop(0, rows, step, idx)
                return idx, acc + idx.astype(jnp.float32)

            _, acc = jax.lax.fori_loop(
                0, n, cycle, (jnp.int32(0), jnp.float32(0.0)))
            return acc
        return chase

    if strat in ("w", "y"):
        def write(xf, xi):
            def body(_, x):
                return x + 1.0
            x = jax.lax.fori_loop(0, n, body, xf[:rows])
            return x[0, 0]
        return write

    if strat in ("c", "x", "b"):
        def readwrite(xf, xi):
            def body(_, x):
                return x * 1.0000001 + 0.25
            x = jax.lax.fori_loop(0, n, body, xf[:rows])
            return x[0, 0]
        return readwrite

    def read(xf, xi):
        x = xf[:rows]

        def body(_, acc):
            # re-issue the buffer each pass: the barrier pins the reads
            # inside the loop (a bare sum would be loop-invariant)
            xx = compat.optimization_barrier(x)
            return acc * 0.5 + jnp.sum(xx)

        return jax.lax.fori_loop(0, n, body, jnp.float32(0.0))
    return read


def _pallas_branch_fn(strat: str, shape, rows: int, n: int):
    """Pallas-kernel edition of one rung activity (resolved strategy
    letter ``strat``, ``n`` active passes): the branch's memory traffic
    is the real kernel library, not a jnp stand-in.  Every branch keeps
    a dataflow edge from its (barrier-fenced) operands into each
    kernel call — carried loop state where the kernel's output feeds
    the next pass (copy/rmw/seeded write), ``optimization_barrier``
    re-issue where it cannot (reads, mixed streams, chases) — so the
    extended jaxpr fence check can verify every ``pallas_call``
    consumes fenced data."""
    from repro import compat
    from repro.kernels import chase as _kchase
    from repro.kernels import ops as kops
    from repro.kernels import stream as _kstream
    from repro.core.workloads import _fits_vmem

    interp = not kops.on_tpu()
    blk = min(512, rows)

    if strat in _SPMD_CHASES:
        vmem = strat == "l" and _fits_vmem(rows * LINE_BYTES)
        kern = _kchase.chase_vmem if vmem else _kchase.chase_hbm

        def chase(xf, xi):
            buf = xi[:rows]

            def cycle(_, acc):
                # re-issued buffer: one dependent full traversal per
                # pass, not hoistable/CSE-able across passes
                bb = compat.optimization_barrier(buf)
                idx = kern(bb, n_steps=rows, interpret=interp)
                return acc + idx.astype(jnp.float32)

            return jax.lax.fori_loop(0, n, cycle, jnp.float32(0.0))
        return chase

    if strat == "y":
        def write_stream(xf, xi):
            def body(_, acc):
                # the seed depends on the previous pass, serialising
                # the passes; the kernel's stores depend on the seed
                seed = xf[:1, :1] + acc * 1e-30
                out = _kstream.write_hbm_seeded(
                    seed, rows, block_rows=blk, interpret=interp)
                return acc * 0.5 + out[0, 0]

            return jax.lax.fori_loop(0, n, body, jnp.float32(0.0))
        return write_stream

    if strat in ("w", "x"):
        def rmw(xf, xi):
            def body(_, x):
                # write-allocate: read + write back, carried so pass
                # t+1 depends on pass t's stores.  Deliberate for 'w'
                # too (matching the jnp fallback branch): a cacheable
                # write allocates the line, so its memory traffic IS
                # read+write — the interpret backend's pure-store 'w'
                # kernel is the approximation, not this.  Useful-bytes
                # accounting stays the registry's convention: 'w'
                # counts the written lines (1x), 'x' both (2x,
                # _SPMD_STREAM_2X) — same elapsed, different useful BW.
                return _kstream.rmw_hbm(x, block_rows=blk,
                                        interpret=interp)

            x = jax.lax.fori_loop(0, n, body, xf[:rows])
            return x[0, 0]
        return rmw

    if strat == "c":
        def copy(xf, xi):
            def body(_, x):
                return _kstream.copy_hbm(x, block_rows=blk,
                                         interpret=interp)

            x = jax.lax.fori_loop(0, n, body, xf[:rows])
            return x[0, 0]
        return copy

    if strat == "b":
        rf = (shape.read_fraction
              if getattr(shape, "kind", None) == "mixed" else 0.5)

        def mixed(xf, xi):
            x = xf[:rows]

            def body(_, acc):
                xx = compat.optimization_barrier(x)
                # the seed fences the write half of the mix (its store
                # kernel consumes no other operand)
                s, out = _kstream.mixed_hbm(
                    xx, read_fraction=rf, block_rows=blk,
                    interpret=interp, seed=xx[:1, :1])
                # consume one written row: keeps the store kernel live
                # under DCE without re-reading the whole destination
                return acc * 0.5 + s + jnp.sum(out[:1])

            return jax.lax.fori_loop(0, n, body, jnp.float32(0.0))
        return mixed

    def read(xf, xi):                   # r / s: pure read stream
        x = xf[:rows]

        def body(_, acc):
            xx = compat.optimization_barrier(x)
            return acc * 0.5 + _kstream.read_hbm(xx, block_rows=blk,
                                                 interpret=interp)

        return jax.lax.fori_loop(0, n, body, jnp.float32(0.0))
    return read


def build_rung_program(n_engines: int, branch_fns, engine_branch):
    """One fused SPMD rung over an ("engine",) mesh.

    Returns ``(mesh, f)`` with ``f(xf, xi) -> (per_engine_out, barrier)``
    jit-compiled: engine ``e`` runs ``branch_fns[engine_branch[e]]`` on
    its shard of the operands.  The measured region is *provably*
    sandwiched (invariants 1-4 of the module docstring):

      start — every engine all-reduces a token derived from its live
          operand data (psum #1; a constant token would fold away at
          trace time), and the operands are re-issued through
          ``optimization_barrier`` together with that token, so every
          activity's operands carry a dataflow dependency on the
          collective: XLA cannot schedule measured work before the
          barrier completes;
      stop — the activity outputs are all-reduced (psum #2) into the
          returned barrier value, so the dispatch only retires after
          every engine's activity finished, and the next rung (a new
          dispatch) cannot begin until the host unblocks.

    :func:`measured_region_is_fenced` asserts the start edge
    structurally (jaxpr dataflow), which the tests pin down.
    """
    from jax.sharding import PartitionSpec as P

    from repro import compat

    devs = jax.devices()[:n_engines]
    mesh = compat.make_mesh_from_devices(devs, ("engine",))
    table = jnp.asarray(list(engine_branch), jnp.int32)

    def per_engine(xf, xi):
        xf, xi = xf[0], xi[0]
        # barrier #1 (see docstring): data-derived token, all-reduced,
        # then threaded into every operand
        token = jax.lax.psum(xf[0, 0] + xi[0, 0].astype(xf.dtype),
                             "engine")
        xf, xi, token = compat.optimization_barrier((xf, xi, token))
        eng = jax.lax.axis_index("engine")
        out = jax.lax.switch(table[eng], branch_fns, xf, xi)
        # barrier #2: consumes every engine's finished activity.  (The
        # start token is alive through the operands' barrier edge; only
        # the stop psum — statically replicated — is returned.)
        done = jax.lax.psum(out, "engine")
        return out[None], done

    # check_rep=False: no replication rule is registered for
    # pallas_call, so Pallas rung activities cannot trace under the
    # checker; the stop psum still replicates `done` at runtime
    f = compat.shard_map(per_engine, mesh=mesh,
                         in_specs=(P("engine"), P("engine")),
                         out_specs=(P("engine"), P()),
                         check_rep=False)
    return mesh, jax.jit(f)


def build_ladder_program(n_engines: int, branch_fns, branch_table,
                         samples: int = 3, donate: bool = False):
    """The WHOLE contention ladder as one fused SPMD dispatch.

    ``branch_table`` is a (K, n_engines) int table: scan step for rung
    ``k`` runs ``branch_fns[branch_table[k][e]]`` on engine ``e``'s
    shard.  Each rung is repeated ``samples`` times, and EVERY repeat
    is its own psum sandwich — the scanned edition of
    :func:`build_rung_program`'s spin-lock-sandwich invariants:

      start — every sample's token psum is derived from live operand
          data AND the loop carry (a loop-invariant psum would be
          hoisted out of the scan), and the operands are re-issued with
          an exact-zero contribution from the start timestamp, so no
          engine's measured work can begin before the barrier completed
          and the stamp's buffer was actually filled;
      stop — the activity outputs are all-reduced (psum #2) and the
          carry value-consumes the stop timestamp, so sample s+1's
          start barrier cannot open until sample s fully retired —
          invariant 4, enforced in-dispatch by dataflow instead of a
          host round-trip per rung.

    Per-rung elapsed time comes from ``compat.device_clock`` stamp
    pairs taken inside the dispatch (engine 0's stop stamp follows the
    stop psum, i.e. the SLOWEST engine's finish), returned as
    ``(n_eng, K*samples, 2)`` int32 ``[s, ns]`` arrays alongside the
    per-engine activity outputs.  Host-side cost of a whole ladder: ONE
    synchronous dispatch, versus 4·K for the per-rung path.

    Returns ``(mesh, fn)`` with ``fn(xf, xi) ->
    (outs, t0s, t1s, xf, xi)``; the operands are passed through (and
    donated when ``donate=True``) so callers can cache and rebind them
    without any host->device re-transfer.
    :func:`measured_region_is_fenced` verifies the sandwich of every
    scanned step structurally (the scan body carries the psum fence)."""
    from jax.sharding import PartitionSpec as P

    from repro import compat

    devs = jax.devices()[:n_engines]
    mesh = compat.make_mesh_from_devices(devs, ("engine",))
    table = np.repeat(np.asarray(branch_table, np.int32),
                      int(samples), axis=0)
    table_j = jnp.asarray(table)

    def per_engine(xf, xi):
        xf, xi = xf[0], xi[0]
        eng = jax.lax.axis_index("engine")

        def clock(dep):
            # only the OBSERVER engine pays the stamp cost (on the
            # callback fallback each stamp is a host round-trip; 2
            # per engine per sample would dominate small rungs); the
            # other engines still serialize on it through the carry
            # -> token psum collective below
            return jax.lax.cond(eng == 0, compat.device_clock,
                                lambda _d: jnp.zeros((2,), jnp.int32),
                                dep)

        def step(carry, row):
            # barrier #1: data-derived, carry-dependent, all-reduced
            token = jax.lax.psum(
                xf[0, 0] + xi[0, 0].astype(xf.dtype) + carry * 1e-30,
                "engine")
            t0 = clock(token)
            # thread the start stamp into every operand as an EXACT
            # zero: min(t, 0) == 0 at runtime (monotonic clock parts
            # are non-negative) but XLA cannot fold it away — the
            # activity cannot start until the stamp exists.  A
            # scheduling-only edge is not enough: the callback
            # fallback fills its result buffer asynchronously.
            z = jnp.minimum(t0[0] + t0[1], 0)
            xf_, xi_, _tok = compat.optimization_barrier(
                (xf + z.astype(xf.dtype), xi + z, token))
            out = jax.lax.switch(row[eng], branch_fns, xf_, xi_)
            # barrier #2: consumes every engine's finished activity
            done = jax.lax.psum(out, "engine")
            t1 = clock(done)
            # the carry value-consumes the stop stamp: the next
            # sample's start barrier waits for this one to retire
            carry = (done * 1e-30
                     + jnp.minimum(t1[0] + t1[1], 0).astype(xf.dtype))
            return carry, (out, t0, t1)

        _c, (outs, t0s, t1s) = jax.lax.scan(step, jnp.float32(0.0),
                                            table_j)
        return outs[None], t0s[None], t1s[None], xf[None], xi[None]

    f = compat.shard_map(per_engine, mesh=mesh,
                         in_specs=(P("engine"), P("engine")),
                         out_specs=(P("engine", None),
                                    P("engine", None, None),
                                    P("engine", None, None),
                                    P("engine"), P("engine")),
                         check_rep=False)
    kw = {"donate_argnums": (0, 1)} if donate else {}
    return mesh, jax.jit(f, **kw)


def build_scenario_program(n_engines: int, n_stressors: int,
                           main_fn, stress_fn, idle_fn):
    """Returns f(main_x, stress_x) -> (main_out, barrier) running under
    ``shard_map`` over an ("engine",) mesh: engine 0 = observed, engines
    1..n_stressors = stress, rest idle.  The measured region is fenced by
    two psum barriers (invariants 1-4 above) — and the fence is
    dataflow-enforced: the start psum is derived from live operand data
    and re-issued into the operands via ``optimization_barrier``, so
    the activities cannot be hoisted above it (the historical version
    computed a psum nothing depended on, which JAX folds away at trace
    time — invariant 1 was unenforced)."""
    from jax.sharding import PartitionSpec as P

    from repro import compat

    devs = jax.devices()[:n_engines]
    mesh = compat.make_mesh_from_devices(devs, ("engine",))

    def per_engine(main_x, stress_x):
        eng = jax.lax.axis_index("engine")
        # barrier #1: every engine signals ready before measurement
        # starts, and the measured operands depend on the collective
        seed = (jnp.ravel(main_x)[0].astype(jnp.float32)
                + jnp.ravel(stress_x)[0].astype(jnp.float32))
        ready = jax.lax.psum(seed, "engine")
        main_x, stress_x, ready = compat.optimization_barrier(
            (main_x, stress_x, ready))

        def run_main(m, _s):
            return main_fn(m)

        def run_stress(_m, s):
            return stress_fn(s)

        def run_idle(_m, s):
            return idle_fn(s)

        branch = jnp.where(eng == 0, 0,
                           jnp.where(eng <= n_stressors, 1, 2))
        # operands passed positionally: the `operand=` kwarg is
        # deprecated drift (the grep lint in tests/test_compat.py
        # rejects it)
        out = jax.lax.switch(branch, [run_main, run_stress, run_idle],
                             main_x, stress_x)
        # barrier #2: measurement closes only after every engine
        # finished — `done` consumes each engine's activity output.
        # (`ready` stays alive through the operand barrier edge; the
        # returned value is the stop psum, which is statically
        # replicated.)
        done = jax.lax.psum(jnp.ravel(out)[0].astype(jnp.float32),
                            "engine")
        return out, done

    f = compat.shard_map(per_engine, mesh=mesh,
                         in_specs=(P("engine"), P("engine")),
                         out_specs=(P("engine"), P()))
    return mesh, f


# ---------------------------------------------------------------------------
# Structural fence verification (sandwich invariant 1, as a jaxpr check)
# ---------------------------------------------------------------------------


def measured_region_is_fenced(fn, *example_args, jaxpr=None) -> bool:
    """Does the measured output depend — through DATAFLOW, not just
    program order — on the start-barrier psum?

    Walks the traced jaxpr: inside every ``shard_map`` body, takes the
    first psum equation (the start barrier), computes the forward
    dataflow closure of its outputs, and requires (a) the body's first
    output (the measured activity result) to lie inside that closure,
    and (b) every ``pallas_call`` reachable after the barrier —
    recursing through switch branches and loop bodies — to consume at
    least one operand inside the closure.  (b) extends the check past
    the ``pallas_call`` boundary: a kernel is the *actual* memory
    traffic of a Pallas rung activity, and one fed only by constants
    (e.g. a no-operand write stream) could be hoisted above the
    barrier even though the switch output downstream of it still
    "depends" on the fence.  A program whose barrier is advisory only
    — the pre-fix ``build_scenario_program``, where ``out`` had no
    data dependency on ``ready`` — returns False: XLA was free to
    begin the measured activity before the stressors were running.

    Fused whole-ladder programs (:func:`build_ladder_program`) carry
    their psum sandwiches INSIDE a ``lax.scan``: there the check
    recurses into every psum-bearing scan/while body and requires the
    step itself to pass — the step's first output is the loop carry,
    which by construction value-consumes the stop barrier and stamp,
    so verifying the body verifies EVERY scanned rung sample (one body
    serves all steps structurally) — including every ladder of a
    sweep-batched stacked program, whose scan table merely gains a
    leading scenario axis.  Pass ``jaxpr=`` (a ClosedJaxpr, e.g. from
    ``compat.aot_trace(fn, *args).jaxpr``) to reuse an existing trace
    instead of paying a second one here."""
    closed = jaxpr if jaxpr is not None \
        else jax.make_jaxpr(fn)(*example_args)
    bodies = _shard_map_bodies(closed.jaxpr)
    if not bodies:
        return False
    return all(_first_out_depends_on_psum(b) for b in bodies)


def _sub_jaxprs(params: Dict[str, Any]):
    for v in params.values():
        for u in (v if isinstance(v, (tuple, list)) else (v,)):
            inner = getattr(u, "jaxpr", u)
            if hasattr(inner, "eqns"):
                yield inner


def _shard_map_bodies(jaxpr) -> List[Any]:
    out = []
    for eqn in jaxpr.eqns:
        for inner in _sub_jaxprs(eqn.params):
            if "shard_map" in eqn.primitive.name:
                out.append(inner)
            else:
                out.extend(_shard_map_bodies(inner))
    return out


def _jaxpr_has_psum(jaxpr) -> bool:
    for eqn in jaxpr.eqns:
        if "psum" in eqn.primitive.name:
            return True
        for inner in _sub_jaxprs(eqn.params):
            if _jaxpr_has_psum(inner):
                return True
    return False


def _first_out_depends_on_psum(body) -> bool:
    live: set = set()
    seen_psum = False
    kernels_ok = True
    for eqn in body.eqns:
        invars = [v for v in eqn.invars if not hasattr(v, "val")]
        if not seen_psum and "psum" in eqn.primitive.name:
            seen_psum = True
            live.update(eqn.outvars)
            continue
        if not seen_psum and eqn.primitive.name in ("scan", "while"):
            inners = [j for j in _sub_jaxprs(eqn.params)
                      if _jaxpr_has_psum(j)]
            if inners:
                # a scanned/looped sandwich (the fused whole-ladder
                # program): every step must pass the same check — its
                # first output is the loop carry, which must consume
                # the step's own stop barrier, and every kernel inside
                # the step must consume fence-dependent operands.  One
                # body serves all steps, so this verifies every rung.
                if all(_first_out_depends_on_psum(j) for j in inners):
                    seen_psum = True
                    live.update(eqn.outvars)
                else:
                    kernels_ok = False
                continue
        if seen_psum:
            kernels_ok = kernels_ok and _kernels_fenced_in_eqn(eqn, live)
            if any(v in live for v in invars):
                live.update(eqn.outvars)
    out0 = body.outvars[0]
    return out0 in live and kernels_ok


def _is_live(v, live) -> bool:
    return not hasattr(v, "val") and v in live


def _kernels_fenced_in_eqn(eqn, live) -> bool:
    """Fence-reachability of the kernels *inside* one equation: a
    ``pallas_call`` must consume at least one fence-dependent operand;
    any other equation recurses into its sub-jaxprs (switch/cond
    branches, while/scan loop bodies, inner pjit calls) with the live
    set mapped onto the inner binders.  The mapping aligns outer
    operands to inner invars from the END — exact for pjit/scan, and
    for cond/switch (whose leading index operand has no binder) and
    while bodies (whose leading cond-consts belong to the other
    jaxpr) it aligns the carried values correctly, which is where the
    fenced operands live."""
    if "pallas_call" in eqn.primitive.name:
        return any(_is_live(v, live) for v in eqn.invars)
    ok = True
    for inner in _sub_jaxprs(eqn.params):
        inner_live = {iv for iv, ov in zip(reversed(inner.invars),
                                           reversed(eqn.invars))
                      if _is_live(ov, live)}
        ok = ok and _kernels_fenced_in_jaxpr(inner, inner_live)
    return ok


def _kernels_fenced_in_jaxpr(jaxpr, live) -> bool:
    live = set(live)
    ok = True
    for eqn in jaxpr.eqns:
        ok = ok and _kernels_fenced_in_eqn(eqn, live)
        if any(_is_live(v, live) for v in eqn.invars):
            live.update(eqn.outvars)
    return ok
