"""Core Coordinator — scenario ladders with the barrier "sandwich".

Mirrors the paper's §III-D: an *Experiment Instantiator* validates the
configuration and binds workloads; a *Multi-Engine Synchronizer* enforces
the four measurement invariants.  On a TPU slice the synchronizer is an
SPMD program over a 1-D "engine" mesh where engine 0 runs the main
activity and engines 1..k the stress activity — the measured region is
sandwiched between two all-reduce barriers, the collective analog of the
paper's spin-lock sandwich:

  (1) measurement starts only after every engine passed the start
      barrier (psum #1);
  (2) the scenario is stable: one fused SPMD program, engines run
      lockstep until their activity completes;
  (3) the stop barrier (psum #2) completes only after every engine's
      activity finished — measurement closes before anything is torn
      down;
  (4) the next scenario is a new program dispatch, which cannot begin
      until the previous one fully retired (host blocks on the result).

Backends:
  * ``simulate``  — closed queueing network (repro.core.simulate); full
                    contention ladders at modeled v5e scale.
  * ``interpret`` — really executes the observed activity's Pallas
                    kernels (interpret mode, this container's CPU);
                    contention scenarios beyond 0 stressors fall back to
                    the model (single real device).
  * ``tpu``       — same SPMD program, real hardware (not available in
                    this container; code path kept identical).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import simulate as sim
from repro.core.devicetree import Platform, detect_platform
from repro.core.pools import MemoryPool, PoolManager
from repro.core.scenarios import (ObserverSpec, ScenarioSpec, StressorSpec,
                                  TrafficShape)
from repro.core.workloads import (Workload, WorkloadResult,
                                  make_shaped_workload, make_workload,
                                  measure_group)

# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ActivitySpec:
    strategy: str              # Table-I letter
    pool: str                  # pool name ("hbm", "host", ...)
    buffer_bytes: int
    # optional traffic-shape parameters (ScenarioSpec DSL; the defaults
    # reproduce the seed's steady streams exactly)
    read_fraction: Optional[float] = None   # mixed r/w ratio
    duty_cycle: float = 1.0                 # bursty/duty-cycled
    stride: int = 1                         # strided pointer-chase

    def describe(self) -> str:
        return f"({self.strategy},{self.pool},{self.buffer_bytes >> 10}K)"

    def shape(self) -> Optional[TrafficShape]:
        """The TrafficShape these fields encode (None = steady)."""
        if self.read_fraction is not None:
            return TrafficShape(kind="mixed",
                                read_fraction=self.read_fraction)
        if self.duty_cycle < 1.0:
            return TrafficShape(kind="burst", duty_cycle=self.duty_cycle)
        if self.stride > 1:
            return TrafficShape(kind="strided", stride=self.stride)
        return None

    @staticmethod
    def from_stressor(s: StressorSpec) -> "ActivitySpec":
        return ActivitySpec(
            s.strategy, s.pool, s.buffer_bytes,
            read_fraction=(s.shape.read_fraction
                           if s.shape.kind == "mixed" else None),
            duty_cycle=s.shape.duty_cycle,
            stride=s.shape.stride)


@dataclass(frozen=True)
class ExperimentConfig:
    main: ActivitySpec
    stress: ActivitySpec
    iters: int = 500
    scenarios: Optional[int] = None      # default: platform.n_engines
    counters: Tuple[str, ...] = ("WALL_NS", "HLO_FLOPS", "HLO_BYTES",
                                 "TRANSACTIONS", "NS_PER_TX")


@dataclass
class ScenarioResult:
    n_stressors: int
    main: WorkloadResult
    modeled_bw_gbps: float = 0.0
    modeled_lat_ns: float = 0.0
    stress_bw_gbps: float = 0.0


@dataclass
class ExperimentResult:
    config: ExperimentConfig
    scenarios: List[ScenarioResult] = field(default_factory=list)

    def bandwidth_curve(self) -> List[Tuple[int, float]]:
        return [(s.n_stressors,
                 s.modeled_bw_gbps or s.main.bandwidth_gbps)
                for s in self.scenarios]

    def latency_curve(self) -> List[Tuple[int, float]]:
        return [(s.n_stressors, s.modeled_lat_ns or s.main.latency_ns)
                for s in self.scenarios]


class ValidationError(ValueError):
    pass


# ---------------------------------------------------------------------------


class CoreCoordinator:
    def __init__(self, pool_mgr: Optional[PoolManager] = None,
                 platform: Optional[Platform] = None,
                 backend: str = "auto"):
        self.platform = platform or detect_platform()
        self.pools = pool_mgr or PoolManager(self.platform)
        if backend == "auto":
            backend = "tpu" if jax.default_backend() == "tpu" else "simulate"
        assert backend in ("simulate", "interpret", "tpu"), backend
        self.backend = backend

    # -- Experiment Instantiator ----------------------------------------
    def validate(self, cfg: ExperimentConfig) -> None:
        from repro.core.workloads import _REGISTRY
        for which, spec in (("main", cfg.main), ("stress", cfg.stress)):
            if spec.strategy not in _REGISTRY:
                raise ValidationError(
                    f"{which}: unknown strategy {spec.strategy!r}")
            pool = self.pools.pool(spec.pool)   # raises PoolError if absent
            if spec.strategy != "i" and spec.buffer_bytes > pool.available:
                raise ValidationError(
                    f"{which}: buffer {spec.buffer_bytes}B exceeds free "
                    f"space in pool {spec.pool} ({pool.available}B)")
        if cfg.iters <= 0:
            raise ValidationError("iters must be positive")
        n = cfg.scenarios if cfg.scenarios is not None \
            else self.platform.n_engines
        if not 1 <= n <= self.platform.n_engines:
            raise ValidationError(
                f"scenarios must be in [1, {self.platform.n_engines}]")

    # -- scenario ladder ----------------------------------------------------
    def run(self, cfg: ExperimentConfig) -> ExperimentResult:
        self.validate(cfg)
        n_scen = cfg.scenarios if cfg.scenarios is not None \
            else self.platform.n_engines
        result = ExperimentResult(cfg)

        main_pool = self.pools.pool(cfg.main.pool)
        stress_pool = self.pools.pool(cfg.stress.pool)

        measured: Optional[WorkloadResult] = None
        if self.backend in ("interpret", "tpu"):
            wl = make_shaped_workload(cfg.main.strategy, main_pool,
                                      cfg.main.buffer_bytes,
                                      cfg.main.shape())
            try:
                measured = wl.run(cfg.iters)
            finally:
                wl.release()

        for k in range(n_scen):
            modeled = self._model_scenario(cfg, main_pool, stress_pool, k)  # noqa: E501
            main_res = measured if measured is not None else WorkloadResult(
                cfg.main.strategy, cfg.main.pool, cfg.main.buffer_bytes,
                cfg.iters, 0, 0.0, 0)
            result.scenarios.append(ScenarioResult(
                n_stressors=k,
                main=main_res,
                modeled_bw_gbps=modeled[0],
                modeled_lat_ns=modeled[1],
                stress_bw_gbps=modeled[2],
            ))
        # per-scenario/experiment teardown (paper §III-A step 6) is done by
        # wl.release() above; pools stay clean for the next experiment.
        return result

    def _model_scenario(self, cfg: ExperimentConfig, main_pool: MemoryPool,
                        stress_pool: MemoryPool,
                        k: int) -> Tuple[float, float, float]:
        obs_node = self._model_node(cfg.main, main_pool,
                                    other=cfg.stress, other_engines=k)
        stress_node = self._model_node(cfg.stress, stress_pool,
                                       other=cfg.main, other_engines=1)
        classes = [sim.ActivityClass(
            "obs", obs_node, cfg.main.strategy, 1,
            read_fraction=cfg.main.read_fraction,
            duty_cycle=cfg.main.duty_cycle, stride=cfg.main.stride)]
        if k and cfg.stress.strategy != "i":
            classes.append(sim.ActivityClass(
                "stress", stress_node, cfg.stress.strategy, k,
                read_fraction=cfg.stress.read_fraction,
                duty_cycle=cfg.stress.duty_cycle,
                stride=cfg.stress.stride))
        res = sim.simulate_scenario(self.platform, classes)
        obs = res.get("obs")
        stress = res.get("stress")
        return (obs.bw_gbps if obs else 0.0,
                obs.lat_ns if obs else 0.0,
                stress.bw_gbps if stress else 0.0)

    # -- cache semantics ------------------------------------------------------
    _CACHEABLE = ("r", "w", "l", "c", "b")

    def _model_node(self, spec: ActivitySpec, pool: MemoryPool,
                    other: Optional[ActivitySpec] = None,
                    other_engines: int = 0):
        """Where does this activity's traffic actually land?

        Cacheable strategies on small buffers hit the platform's cache
        (transparent shared L2 on the ZCU102; software-managed private
        VMEM residency on v5e) — UNLESS, for a *shared* cache, the
        combined cacheable footprint exceeds it (inter-engine evictions,
        the red case of Fig. 12)."""
        node = pool.node
        if node.kind in ("vmem", "cache"):
            return node
        if spec.strategy not in self._CACHEABLE:
            return node

        cache_name = getattr(self.platform, "cache_node", None)
        if cache_name:                     # transparent shared cache
            cache = self.platform.memories[cache_name]
            if spec.buffer_bytes > cache.size_bytes:
                return node
            footprint = spec.buffer_bytes
            if other is not None and other.strategy in self._CACHEABLE:
                other_pool = self.pools.pool(other.pool)
                if other_pool.node.kind not in ("vmem", "cache"):
                    footprint += other_engines * other.buffer_bytes
            return cache if footprint <= cache.size_bytes else node

        # v5e: private VMEM residency, no cross-engine eviction
        from repro.core.workloads import models_as_vmem
        vmem = self.platform.memories.get("vmem")
        if vmem is not None and models_as_vmem(spec.buffer_bytes):
            return vmem
        return node

    # -- ladder sweep used by characterize.py ------------------------------
    def ladder(self, main: ActivitySpec, stress: ActivitySpec,
               iters: int = 500) -> ExperimentResult:
        return self.run(ExperimentConfig(main=main, stress=stress,
                                         iters=iters))

    # ==================================================================
    # ScenarioSpec matrix execution (the v2 characterization engine)
    # ==================================================================

    def validate_spec(self, spec: ScenarioSpec) -> None:
        from repro.core.workloads import _REGISTRY
        obs = spec.observer
        if obs.strategy not in _REGISTRY:
            raise ValidationError(
                f"{spec.name}: unknown observer strategy "
                f"{obs.strategy!r}")
        pool = self.pools.pool(obs.pool)
        for b in obs.buffers:
            if obs.strategy != "i" and b > pool.available:
                raise ValidationError(
                    f"{spec.name}: observer buffer {b}B exceeds pool "
                    f"{obs.pool} ({pool.available}B free)")
        for s in spec.stressors:
            if s.strategy not in _REGISTRY:
                raise ValidationError(
                    f"{spec.name}: unknown stressor strategy "
                    f"{s.strategy!r}")
            self.pools.pool(s.pool)
        if spec.iters <= 0:
            raise ValidationError(f"{spec.name}: iters must be positive")
        if spec.max_stressors is not None and not (
                0 <= spec.max_stressors < self.platform.n_engines):
            raise ValidationError(
                f"{spec.name}: max_stressors out of "
                f"[0, {self.platform.n_engines})")

    def _obs_activity(self, spec: ScenarioSpec,
                      buffer_bytes: int) -> ActivitySpec:
        sh = spec.observer.shape
        return ActivitySpec(
            spec.observer.strategy, spec.observer.pool, buffer_bytes,
            read_fraction=(sh.read_fraction if sh.kind == "mixed"
                           else None),
            duty_cycle=sh.duty_cycle, stride=sh.stride)

    def _model_spec_scenario(self, spec: ScenarioSpec, buffer_bytes: int,
                             k: int) -> Tuple[float, float, float]:
        """Model one rung of the ladder: observer + k stress engines
        distributed round-robin over the stressor ensemble."""
        obs_act = self._obs_activity(spec, buffer_bytes)
        obs_pool = self.pools.pool(spec.observer.pool)
        first = spec.stressors[0] if spec.stressors else None
        obs_node = self._model_node(
            obs_act, obs_pool,
            other=ActivitySpec.from_stressor(first) if first else None,
            other_engines=k)
        classes = [sim.ActivityClass(
            "obs", obs_node, obs_act.strategy, 1,
            read_fraction=obs_act.read_fraction,
            duty_cycle=obs_act.duty_cycle, stride=obs_act.stride)]
        m = len(spec.stressors)
        if k and m:
            share = [k // m + (1 if j < k % m else 0) for j in range(m)]
            for j, (s, e) in enumerate(zip(spec.stressors, share)):
                if e == 0 or s.strategy == "i":
                    continue
                act = ActivitySpec.from_stressor(s)
                node = self._model_node(act, self.pools.pool(s.pool),
                                        other=obs_act, other_engines=1)
                classes.append(sim.ActivityClass(
                    f"stress{j}", node, s.strategy, e,
                    read_fraction=act.read_fraction,
                    duty_cycle=act.duty_cycle, stride=act.stride))
        res = sim.simulate_scenario(self.platform, classes)
        obs = res.get("obs")
        stress_bw = sum(r.bw_gbps for n, r in res.items()
                        if n.startswith("stress"))
        return (obs.bw_gbps if obs else 0.0,
                obs.lat_ns if obs else 0.0,
                stress_bw)

    def run_matrix(self, specs: List[ScenarioSpec], *,
                   batched: bool = True) -> "MatrixResult":
        """Execute a scenario matrix.

        The measured observer pass is where executable backends spend
        their dispatches; ``batched=True`` groups same-signature
        observers (strategy, shape, row count, residency, pool) and
        measures each group with ONE jit'd vmapped pass, instead of the
        naive one-dispatch-per-scenario Python loop.  The contention
        ladder itself is modeled per scenario on every backend (single
        real device)."""
        for spec in specs:
            self.validate_spec(spec)
        pairs = [(spec, b) for spec in specs
                 for b in spec.observer.buffers]
        stats = DispatchStats(n_scenarios=len(pairs))

        measured: Dict[int, WorkloadResult] = {}
        if self.backend in ("interpret", "tpu"):
            measured = self._measure_pairs(pairs, batched, stats)

        runs: List[ScenarioRun] = []
        for i, (spec, buf) in enumerate(pairs):
            n_scen = (spec.max_stressors + 1
                      if spec.max_stressors is not None
                      else self.platform.n_engines)
            n_scen = min(n_scen, self.platform.n_engines)
            main_res = measured.get(i) or WorkloadResult(
                spec.observer.strategy, spec.observer.pool, buf,
                spec.iters, 0, 0.0, 0)
            scenarios = []
            for k in range(n_scen):
                bw, lat, sbw = self._model_spec_scenario(spec, buf, k)
                stats.model_evals += 1
                scenarios.append(ScenarioResult(
                    n_stressors=k, main=main_res, modeled_bw_gbps=bw,
                    modeled_lat_ns=lat, stress_bw_gbps=sbw))
            runs.append(ScenarioRun(spec=spec, buffer_bytes=buf,
                                    key=spec.key(buf),
                                    scenarios=scenarios))
        return MatrixResult(runs=runs, stats=stats)

    def _measure_pairs(self, pairs, batched: bool,
                       stats: "DispatchStats") -> Dict[int, WorkloadResult]:
        """The measured observer pass over all (spec, buffer) pairs."""
        measured: Dict[int, WorkloadResult] = {}
        if not batched:
            for i, (spec, buf) in enumerate(pairs):
                wl = make_shaped_workload(
                    spec.observer.strategy,
                    self.pools.pool(spec.observer.pool), buf,
                    spec.observer.shape)
                try:
                    measured[i] = wl.run(spec.iters)
                finally:
                    wl.release()
                stats.measure_dispatches += 1
            return measured

        groups: Dict[Tuple, List[int]] = {}
        for i, (spec, buf) in enumerate(pairs):
            obs = spec.observer
            sig = (obs.strategy, obs.shape, obs.pool, buf)
            groups.setdefault(sig, []).append(i)
        for (strategy, shape, pool_name, buf), idxs in groups.items():
            iters = max(pairs[i][0].iters for i in idxs)
            results, dispatches = measure_group(
                strategy, self.pools.pool(pool_name), buf, len(idxs),
                iters, shape=shape)
            stats.measure_dispatches += dispatches
            for i, res in zip(idxs, results):
                measured[i] = res
        return measured


# ---------------------------------------------------------------------------
# Matrix-run result containers
# ---------------------------------------------------------------------------


@dataclass
class ScenarioRun:
    """One (scenario, observer-buffer) ladder."""
    spec: ScenarioSpec
    buffer_bytes: int
    key: str
    scenarios: List[ScenarioResult] = field(default_factory=list)

    def bandwidth_curve(self) -> List[Tuple[int, float]]:
        return [(s.n_stressors, s.modeled_bw_gbps or s.main.bandwidth_gbps)
                for s in self.scenarios]

    def latency_curve(self) -> List[Tuple[int, float]]:
        return [(s.n_stressors, s.modeled_lat_ns or s.main.latency_ns)
                for s in self.scenarios]


@dataclass
class DispatchStats:
    """Execution accounting for the matrix runner: the batched runner's
    claim ("fewer dispatches than the per-point loop") is checked
    against these numbers in the tests."""
    n_scenarios: int = 0
    measure_dispatches: int = 0     # timed executable kernel passes
    model_evals: int = 0            # queueing-network solves


@dataclass
class MatrixResult:
    runs: List[ScenarioRun] = field(default_factory=list)
    stats: DispatchStats = field(default_factory=DispatchStats)


# ---------------------------------------------------------------------------
# The SPMD scenario program (the spin-lock sandwich, collective edition).
# Built for any 1-D mesh of engines; dry-runnable on host devices and
# executable unchanged on a real slice.
# ---------------------------------------------------------------------------


def build_scenario_program(n_engines: int, n_stressors: int,
                           main_fn, stress_fn, idle_fn):
    """Returns f(main_x, stress_x) -> (main_out, barrier) running under
    ``shard_map`` over an ("engine",) mesh: engine 0 = observed, engines
    1..n_stressors = stress, rest idle.  The measured region is fenced by
    two psum barriers (invariants 1-4 above)."""
    from jax.sharding import PartitionSpec as P

    from repro import compat

    devs = jax.devices()[:n_engines]
    mesh = compat.make_mesh_from_devices(devs, ("engine",))

    def per_engine(main_x, stress_x):
        eng = jax.lax.axis_index("engine")
        # barrier #1: every engine signals ready before measurement starts
        ready = jax.lax.psum(jnp.ones((), jnp.int32), "engine")

        def run_main(_):
            return main_fn(main_x)

        def run_stress(_):
            return stress_fn(stress_x)

        def run_idle(_):
            return idle_fn(stress_x)

        branch = jnp.where(eng == 0, 0,
                           jnp.where(eng <= n_stressors, 1, 2))
        out = jax.lax.switch(branch, [run_main, run_stress, run_idle],
                             operand=None)
        # barrier #2: measurement closes only after every engine finished
        done = jax.lax.psum(jnp.ones((), jnp.int32), "engine")
        return out, ready + done

    f = compat.shard_map(per_engine, mesh=mesh,
                         in_specs=(P("engine"), P("engine")),
                         out_specs=(P("engine"), P()))
    return mesh, f
