"""Core Coordinator — scenario ladders with the barrier "sandwich".

Mirrors the paper's §III-D: an *Experiment Instantiator* validates the
configuration and binds workloads; a *Multi-Engine Synchronizer* enforces
the four measurement invariants.  On a TPU slice the synchronizer is an
SPMD program over a 1-D "engine" mesh where engine 0 runs the main
activity and engines 1..k the stress activity — the measured region is
sandwiched between two all-reduce barriers, the collective analog of the
paper's spin-lock sandwich:

  (1) measurement starts only after every engine passed the start
      barrier (psum #1);
  (2) the scenario is stable: one fused SPMD program, engines run
      lockstep until their activity completes;
  (3) the stop barrier (psum #2) completes only after every engine's
      activity finished — measurement closes before anything is torn
      down;
  (4) the next scenario is a new program dispatch, which cannot begin
      until the previous one fully retired (host blocks on the result).

Backends:
  * ``simulate``  — closed queueing network (repro.core.simulate); full
                    contention ladders at modeled v5e scale.
  * ``interpret`` — really executes the observed activity's Pallas
                    kernels (interpret mode, this container's CPU);
                    contention scenarios beyond 0 stressors fall back to
                    the model (single real device).
  * ``tpu``       — same SPMD program, real hardware (not available in
                    this container; code path kept identical).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import simulate as sim
from repro.core.devicetree import Platform, detect_platform
from repro.core.pools import MemoryPool, PoolManager
from repro.core.scenarios import (ObserverSpec, ScenarioSpec, StressorSpec,
                                  TrafficShape)
from repro.core.workloads import (LINE_BYTES, Workload, WorkloadResult,
                                  make_shaped_workload, make_workload,
                                  measure_group, resolve_strategy)
from repro.core.workloads import _rows as _wl_rows

# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ActivitySpec:
    strategy: str              # Table-I letter
    pool: str                  # pool name ("hbm", "host", ...)
    buffer_bytes: int
    # optional traffic-shape parameters (ScenarioSpec DSL; the defaults
    # reproduce the seed's steady streams exactly)
    read_fraction: Optional[float] = None   # mixed r/w ratio
    duty_cycle: float = 1.0                 # bursty/duty-cycled
    stride: int = 1                         # strided pointer-chase

    def describe(self) -> str:
        return f"({self.strategy},{self.pool},{self.buffer_bytes >> 10}K)"

    def shape(self) -> Optional[TrafficShape]:
        """The TrafficShape these fields encode (None = steady)."""
        if self.read_fraction is not None:
            return TrafficShape(kind="mixed",
                                read_fraction=self.read_fraction)
        if self.duty_cycle < 1.0:
            return TrafficShape(kind="burst", duty_cycle=self.duty_cycle)
        if self.stride > 1:
            return TrafficShape(kind="strided", stride=self.stride)
        return None

    @staticmethod
    def from_stressor(s: StressorSpec) -> "ActivitySpec":
        return ActivitySpec(
            s.strategy, s.pool, s.buffer_bytes,
            read_fraction=(s.shape.read_fraction
                           if s.shape.kind == "mixed" else None),
            duty_cycle=s.shape.duty_cycle,
            stride=s.shape.stride)


@dataclass(frozen=True)
class ExperimentConfig:
    main: ActivitySpec
    stress: ActivitySpec
    iters: int = 500
    scenarios: Optional[int] = None      # default: platform.n_engines
    counters: Tuple[str, ...] = ("WALL_NS", "HLO_FLOPS", "HLO_BYTES",
                                 "TRANSACTIONS", "NS_PER_TX")


@dataclass
class ScenarioResult:
    n_stressors: int
    main: WorkloadResult
    modeled_bw_gbps: float = 0.0
    modeled_lat_ns: float = 0.0
    stress_bw_gbps: float = 0.0
    # where this rung's curve value comes from: "modeled" (queueing
    # network; `main` is at most an uncontended measurement) or
    # "executed" (`main` IS the observer measured under n_stressors
    # live stress engines — the spmd backend)
    source: str = "modeled"


@dataclass
class ExperimentResult:
    config: ExperimentConfig
    scenarios: List[ScenarioResult] = field(default_factory=list)

    def bandwidth_curve(self) -> List[Tuple[int, float]]:
        return [(s.n_stressors,
                 s.modeled_bw_gbps or s.main.bandwidth_gbps)
                for s in self.scenarios]

    def latency_curve(self) -> List[Tuple[int, float]]:
        return [(s.n_stressors, s.modeled_lat_ns or s.main.latency_ns)
                for s in self.scenarios]


class ValidationError(ValueError):
    pass


# ---------------------------------------------------------------------------


class CoreCoordinator:
    def __init__(self, pool_mgr: Optional[PoolManager] = None,
                 platform: Optional[Platform] = None,
                 backend: str = "auto"):
        self.platform = platform or detect_platform()
        self.pools = pool_mgr or PoolManager(self.platform)
        if backend == "auto":
            backend = "tpu" if jax.default_backend() == "tpu" else "simulate"
        assert backend in ("simulate", "interpret", "tpu", "spmd"), backend
        self.backend = backend

    # -- Experiment Instantiator ----------------------------------------
    def validate(self, cfg: ExperimentConfig) -> None:
        from repro.core.workloads import _REGISTRY
        for which, spec in (("main", cfg.main), ("stress", cfg.stress)):
            if spec.strategy not in _REGISTRY:
                raise ValidationError(
                    f"{which}: unknown strategy {spec.strategy!r}")
            pool = self.pools.pool(spec.pool)   # raises PoolError if absent
            if spec.strategy != "i" and spec.buffer_bytes > pool.available:
                raise ValidationError(
                    f"{which}: buffer {spec.buffer_bytes}B exceeds free "
                    f"space in pool {spec.pool} ({pool.available}B)")
        if cfg.iters <= 0:
            raise ValidationError("iters must be positive")
        n = cfg.scenarios if cfg.scenarios is not None \
            else self.platform.n_engines
        if not 1 <= n <= self.platform.n_engines:
            raise ValidationError(
                f"scenarios must be in [1, {self.platform.n_engines}]")

    # -- scenario ladder ----------------------------------------------------
    def run(self, cfg: ExperimentConfig) -> ExperimentResult:
        self.validate(cfg)
        n_scen = cfg.scenarios if cfg.scenarios is not None \
            else self.platform.n_engines
        result = ExperimentResult(cfg)

        main_pool = self.pools.pool(cfg.main.pool)
        stress_pool = self.pools.pool(cfg.stress.pool)

        measured: Optional[WorkloadResult] = None
        if self.backend in ("interpret", "tpu"):
            wl = make_shaped_workload(cfg.main.strategy, main_pool,
                                      cfg.main.buffer_bytes,
                                      cfg.main.shape())
            try:
                measured = wl.run(cfg.iters)
            finally:
                wl.release()

        for k in range(n_scen):
            modeled = self._model_scenario(cfg, main_pool, stress_pool, k)  # noqa: E501
            main_res = measured if measured is not None else WorkloadResult(
                cfg.main.strategy, cfg.main.pool, cfg.main.buffer_bytes,
                cfg.iters, 0, 0.0, 0)
            result.scenarios.append(ScenarioResult(
                n_stressors=k,
                main=main_res,
                modeled_bw_gbps=modeled[0],
                modeled_lat_ns=modeled[1],
                stress_bw_gbps=modeled[2],
            ))
        # per-scenario/experiment teardown (paper §III-A step 6) is done by
        # wl.release() above; pools stay clean for the next experiment.
        return result

    def _model_scenario(self, cfg: ExperimentConfig, main_pool: MemoryPool,
                        stress_pool: MemoryPool,
                        k: int) -> Tuple[float, float, float]:
        obs_node = self._model_node(cfg.main, main_pool,
                                    other=cfg.stress, other_engines=k)
        stress_node = self._model_node(cfg.stress, stress_pool,
                                       other=cfg.main, other_engines=1)
        classes = [sim.ActivityClass(
            "obs", obs_node, cfg.main.strategy, 1,
            read_fraction=cfg.main.read_fraction,
            duty_cycle=cfg.main.duty_cycle, stride=cfg.main.stride)]
        if k and cfg.stress.strategy != "i":
            classes.append(sim.ActivityClass(
                "stress", stress_node, cfg.stress.strategy, k,
                read_fraction=cfg.stress.read_fraction,
                duty_cycle=cfg.stress.duty_cycle,
                stride=cfg.stress.stride))
        res = sim.simulate_scenario(self.platform, classes)
        obs = res.get("obs")
        stress = res.get("stress")
        return (obs.bw_gbps if obs else 0.0,
                obs.lat_ns if obs else 0.0,
                stress.bw_gbps if stress else 0.0)

    # -- cache semantics ------------------------------------------------------
    _CACHEABLE = ("r", "w", "l", "c", "b")

    def _model_node(self, spec: ActivitySpec, pool: MemoryPool,
                    other: Optional[ActivitySpec] = None,
                    other_engines: int = 0):
        """Where does this activity's traffic actually land?

        Cacheable strategies on small buffers hit the platform's cache
        (transparent shared L2 on the ZCU102; software-managed private
        VMEM residency on v5e) — UNLESS, for a *shared* cache, the
        combined cacheable footprint exceeds it (inter-engine evictions,
        the red case of Fig. 12)."""
        node = pool.node
        if node.kind in ("vmem", "cache"):
            return node
        if spec.strategy not in self._CACHEABLE:
            return node

        cache_name = getattr(self.platform, "cache_node", None)
        if cache_name:                     # transparent shared cache
            cache = self.platform.memories[cache_name]
            if spec.buffer_bytes > cache.size_bytes:
                return node
            footprint = spec.buffer_bytes
            if other is not None and other.strategy in self._CACHEABLE:
                other_pool = self.pools.pool(other.pool)
                if other_pool.node.kind not in ("vmem", "cache"):
                    footprint += other_engines * other.buffer_bytes
            return cache if footprint <= cache.size_bytes else node

        # v5e: private VMEM residency, no cross-engine eviction
        from repro.core.workloads import models_as_vmem
        vmem = self.platform.memories.get("vmem")
        if vmem is not None and models_as_vmem(spec.buffer_bytes):
            return vmem
        return node

    # -- ladder sweep used by characterize.py ------------------------------
    def ladder(self, main: ActivitySpec, stress: ActivitySpec,
               iters: int = 500) -> ExperimentResult:
        return self.run(ExperimentConfig(main=main, stress=stress,
                                         iters=iters))

    # ==================================================================
    # ScenarioSpec matrix execution (the v2 characterization engine)
    # ==================================================================

    def validate_spec(self, spec: ScenarioSpec) -> None:
        from repro.core.workloads import _REGISTRY
        for obs in spec.observers:
            if obs.strategy not in _REGISTRY:
                raise ValidationError(
                    f"{spec.name}: unknown observer strategy "
                    f"{obs.strategy!r}")
            pool = self.pools.pool(obs.pool)
            for b in obs.buffers:
                if obs.strategy != "i" and b > pool.available:
                    raise ValidationError(
                        f"{spec.name}: observer buffer {b}B exceeds pool "
                        f"{obs.pool} ({pool.available}B free)")
        for s in spec.stressors:
            if s.strategy not in _REGISTRY:
                raise ValidationError(
                    f"{spec.name}: unknown stressor strategy "
                    f"{s.strategy!r}")
            self.pools.pool(s.pool)
        if spec.iters <= 0:
            raise ValidationError(f"{spec.name}: iters must be positive")
        if spec.max_stressors is not None and not (
                0 <= spec.max_stressors < self.platform.n_engines):
            raise ValidationError(
                f"{spec.name}: max_stressors out of "
                f"[0, {self.platform.n_engines})")

    def _obs_activity(self, observer: ObserverSpec,
                      buffer_bytes: int) -> ActivitySpec:
        sh = observer.shape
        return ActivitySpec(
            observer.strategy, observer.pool, buffer_bytes,
            read_fraction=(sh.read_fraction if sh.kind == "mixed"
                           else None),
            duty_cycle=sh.duty_cycle, stride=sh.stride)

    def _model_spec_scenario(self, spec: ScenarioSpec,
                             observer: ObserverSpec, buffer_bytes: int,
                             k: int) -> Tuple[float, float, float]:
        """Model one rung of the ladder: one observer + k stress engines
        distributed round-robin over the stressor ensemble.  Each
        observer of a multi-observer scenario sees ONLY the stressor
        ensemble — on every backend.  The interpret backend shares one
        uncontended vmapped pass across same-signature observers, and
        the spmd backend executes each observer's ladder as its own
        rung dispatches; co-observers are never part of each other's
        measured region (ROADMAP open item)."""
        obs_act = self._obs_activity(observer, buffer_bytes)
        obs_pool = self.pools.pool(observer.pool)
        first = spec.stressors[0] if spec.stressors else None
        obs_node = self._model_node(
            obs_act, obs_pool,
            other=ActivitySpec.from_stressor(first) if first else None,
            other_engines=k)
        classes = [sim.ActivityClass(
            "obs", obs_node, obs_act.strategy, 1,
            read_fraction=obs_act.read_fraction,
            duty_cycle=obs_act.duty_cycle, stride=obs_act.stride)]
        m = len(spec.stressors)
        if k and m:
            share = [k // m + (1 if j < k % m else 0) for j in range(m)]
            for j, (s, e) in enumerate(zip(spec.stressors, share)):
                if e == 0 or s.strategy == "i":
                    continue
                act = ActivitySpec.from_stressor(s)
                node = self._model_node(act, self.pools.pool(s.pool),
                                        other=obs_act, other_engines=1)
                classes.append(sim.ActivityClass(
                    f"stress{j}", node, s.strategy, e,
                    read_fraction=act.read_fraction,
                    duty_cycle=act.duty_cycle, stride=act.stride))
        res = sim.simulate_scenario(self.platform, classes)
        obs = res.get("obs")
        stress_bw = sum(r.bw_gbps for n, r in res.items()
                        if n.startswith("stress"))
        return (obs.bw_gbps if obs else 0.0,
                obs.lat_ns if obs else 0.0,
                stress_bw)

    def _ladder_depth(self, spec: ScenarioSpec) -> int:
        n = (spec.max_stressors + 1 if spec.max_stressors is not None
             else self.platform.n_engines)
        n = min(n, self.platform.n_engines)
        if self.backend == "spmd":
            # rung k needs k stress engines + 1 observer on the mesh
            n = min(n, self._spmd_engines())
        return max(1, n)

    def run_matrix(self, specs: List[ScenarioSpec], *,
                   batched: bool = True) -> "MatrixResult":
        """Execute a scenario matrix.

        The measured observer pass is where executable backends spend
        their dispatches; ``batched=True`` groups same-signature
        observers (strategy, shape, row count, residency, effective
        memory placement) and measures each group with ONE jit'd
        vmapped pass, instead of the naive one-dispatch-per-scenario
        Python loop.  Multi-observer scenarios contribute one ladder
        per (observer, buffer) and their observers join the same
        signature groups.

        Backends: ``simulate``/``interpret``/``tpu`` model the
        contention ladder per rung (interpret/tpu additionally measure
        the uncontended observer); ``spmd`` *executes* every rung —
        one fused shard_map dispatch over the engine mesh per rung,
        observer + k live stressor engines between two psum barriers —
        and the resulting curves carry ``source == "executed"``."""
        for spec in specs:
            self.validate_spec(spec)
        triples = [(spec, obs, b) for spec in specs
                   for obs in spec.observers for b in obs.buffers]
        stats = DispatchStats(n_scenarios=len(specs),
                              n_ladders=len(triples))

        measured: Dict[int, WorkloadResult] = {}
        executed: Dict[Tuple[int, int], WorkloadResult] = {}
        fenced_by_triple: Dict[int, bool] = {}
        if self.backend in ("interpret", "tpu"):
            measured = self._measure_triples(triples, batched, stats)
        elif self.backend == "spmd":
            executed, fenced_by_triple = self._execute_spmd(triples,
                                                            stats)

        runs: List[ScenarioRun] = []
        for i, (spec, obs, buf) in enumerate(triples):
            n_scen = self._ladder_depth(spec)
            scenarios = []
            exec_rungs = []
            for k in range(n_scen):
                bw, lat, sbw = self._model_spec_scenario(spec, obs, buf, k)
                stats.model_evals += 1
                ex = executed.get((i, k))
                main_res = ex if ex is not None else (
                    measured.get(i) or WorkloadResult(
                        obs.strategy, obs.pool, buf, spec.iters, 0, 0.0,
                        0))
                if ex is not None:
                    exec_rungs.append(k)
                scenarios.append(ScenarioResult(
                    n_stressors=k, main=main_res, modeled_bw_gbps=bw,
                    modeled_lat_ns=lat, stress_bw_gbps=sbw,
                    source="executed" if ex is not None else "modeled"))
            execution = {
                "backend": self.backend,
                "executed_rungs": exec_rungs,
                "modeled_rungs": [k for k in range(n_scen)
                                  if k not in exec_rungs],
                "measured_uncontended": i in measured,
            }
            if self.backend == "spmd":
                execution["n_engines"] = self._spmd_engines()
                # the structurally VERIFIED fence state of this
                # ladder's executed programs (jaxpr dataflow check)
                execution["fenced"] = fenced_by_triple.get(i, False)
            runs.append(ScenarioRun(spec=spec, buffer_bytes=buf,
                                    key=spec.key_for(obs, buf),
                                    observer=obs,
                                    scenarios=scenarios,
                                    execution=execution))
        return MatrixResult(runs=runs, stats=stats)

    def _measure_triples(self, triples, batched: bool,
                         stats: "DispatchStats") -> Dict[int, WorkloadResult]:
        """The measured observer pass over all (spec, observer, buffer)
        triples (uncontended: single real device)."""
        measured: Dict[int, WorkloadResult] = {}
        if not batched:
            for i, (spec, obs, buf) in enumerate(triples):
                wl = make_shaped_workload(
                    obs.strategy, self.pools.pool(obs.pool), buf,
                    obs.shape)
                try:
                    measured[i] = wl.run(spec.iters)
                finally:
                    wl.release()
                stats.measure_dispatches += 1
            return measured

        # Group signature: everything that changes the compiled measured
        # pass or the numbers stamped on its results.  ``iters`` is part
        # of the signature — members must be measured at THEIR OWN
        # budget, not silently at the group max.  The pool appears only
        # through its *effective* placement: observers from different
        # pools whose arrays land in the same physical memory (e.g. hbm
        # + emulated host on this container) legally share one stacked
        # vmapped batch; pools that really differ split.
        groups: Dict[Tuple, List[int]] = {}
        for i, (spec, obs, buf) in enumerate(triples):
            pool = self.pools.pool(obs.pool)
            sig = (obs.strategy, obs.shape, buf, spec.iters,
                   pool.effective_memory_kind(),
                   pool.node.kind == "vmem")
            groups.setdefault(sig, []).append(i)
        for (strategy, shape, buf, iters, _kind, _vm), idxs in \
                groups.items():
            member_pools = [self.pools.pool(triples[i][1].pool)
                            for i in idxs]
            results, dispatches = measure_group(
                strategy, member_pools[0], buf, len(idxs), iters,
                shape=shape, member_pools=member_pools)
            stats.measure_dispatches += dispatches
            for i, res in zip(idxs, results):
                measured[i] = res
        return measured

    # -- the spmd backend: executable multi-engine contention -----------

    def _spmd_engines(self) -> int:
        return max(1, min(self.platform.n_engines, len(jax.devices())))

    def _execute_spmd(
        self, triples, stats: "DispatchStats",
    ) -> Tuple[Dict[Tuple[int, int], WorkloadResult], Dict[int, bool]]:
        """Execute every ladder rung of every (spec, observer, buffer)
        triple as ONE fused SPMD dispatch over the engine mesh.
        Returns the per-(triple, rung) observer results and the
        verified fence state per triple."""
        n_eng = self._spmd_engines()
        if n_eng < 2:
            raise ValidationError(
                "spmd backend needs >= 2 devices; start the process with "
                "XLA_FLAGS=--xla_force_host_platform_device_count=8 "
                "(CPU container) or run on a real multi-device slice")
        executed: Dict[Tuple[int, int], WorkloadResult] = {}
        fenced_by_triple: Dict[int, bool] = {}
        # program cache across rungs/triples with identical role
        # signatures: one mesh+jit+fence-trace per distinct program,
        # however many curves reuse it (dispatch accounting unchanged)
        programs: Dict[Tuple, Tuple] = {}
        for i, (spec, obs, buf) in enumerate(triples):
            fenced = True
            for k in range(self._ladder_depth(spec)):
                executed[(i, k)], rung_fenced = self._run_spmd_rung(
                    spec, obs, buf, k, n_eng, programs)
                fenced = fenced and rung_fenced
                stats.measure_dispatches += 1
                stats.spmd_rungs += 1
            fenced_by_triple[i] = fenced
        return executed, fenced_by_triple

    def _run_spmd_rung(self, spec: ScenarioSpec, obs: ObserverSpec,
                       buf: int, k: int, n_eng: int,
                       programs: Optional[Dict[Tuple, Tuple]] = None,
                       ) -> Tuple[WorkloadResult, bool]:
        """One rung, one fused program: engine 0 runs the observer,
        engines 1..k the stressor ensemble (round-robin), the rest idle
        — all branches of a single ``shard_map`` dispatch whose
        measured region sits between the two psum barriers of
        :func:`build_rung_program` (the spin-lock sandwich, collective
        edition, dataflow-enforced; the returned bool is the
        structurally *verified* fence state of this rung's program).

        The wall time of the dispatch is the measured region: it closes
        at the stop barrier, i.e. when the SLOWEST engine finishes
        (paper invariant 3).  Stressor iteration budgets are therefore
        work-balanced against the observer's (equal line-touch totals)
        so role imbalance does not masquerade as contention; residual
        per-kind speed differences (a chase row costs more than a
        stream row) remain — per-engine device-side timing is the
        ROADMAP item."""
        import time as _time

        from repro.kernels import ops as kops

        iters = spec.iters
        obs_rows = _wl_rows(buf)
        roles = [(obs.strategy, obs.shape, obs_rows, iters)]
        m = len(spec.stressors)
        # balance against the passes the observer branch will actually
        # execute (its duty cycle included), and divide out each
        # stressor's own duty — the branch fns apply duty internally
        obs_duty = getattr(obs.shape, "duty_cycle", 1.0)
        obs_work = obs_rows * max(1, round(iters * obs_duty))
        for e in range(k):
            if m:
                s = spec.stressors[e % m]
                s_rows = _wl_rows(s.buffer_bytes)
                s_duty = getattr(s.shape, "duty_cycle", 1.0) or 1.0
                s_iters = max(1, round(obs_work / (s_rows * s_duty)))
                roles.append((s.strategy, s.shape, s_rows, s_iters))
            else:
                roles.append(("i", None, 1, iters))
        while len(roles) < n_eng:
            roles.append(("i", None, 1, iters))

        rows_max = max(r[2] for r in roles)
        program_key = (n_eng, tuple(roles))
        cached = programs.get(program_key) if programs is not None \
            else None

        # per-engine operands: a float stream buffer and an int chase
        # chain, padded to the widest role.  (Per-pool memory kinds are
        # not addressable per-engine on a host-device mesh; the pools'
        # effective placement on this container is the default memory
        # anyway, and the curve records its pool label from the spec.)
        xf = np.broadcast_to(
            np.arange(rows_max * LINE_BYTES // 4, dtype=np.float32)
            .reshape(rows_max, LINE_BYTES // 4),
            (n_eng, rows_max, LINE_BYTES // 4)).copy()
        xi = np.zeros((n_eng, rows_max, LINE_BYTES // 4), np.int32)
        for e, (strategy, shape, rows, _ri) in enumerate(roles):
            if resolve_strategy(strategy, shape) in _SPMD_CHASES:
                if resolve_strategy(strategy, shape) == "t":
                    chain = kops.strided_chain_buffer(
                        rows, getattr(shape, "stride", 8) or 8)
                else:
                    chain = kops.chain_buffer(rows, seed=e)
                xi[e, :rows, :chain.shape[1]] = chain

        if cached is not None:
            mesh, fn, fenced = cached
        else:
            branch_fns: List = []
            engine_branch: List[int] = []
            branch_of: Dict[Tuple, int] = {}
            for strategy, shape, rows, role_iters in roles:
                sig = (strategy, shape, rows, role_iters)
                if sig not in branch_of:
                    branch_of[sig] = len(branch_fns)
                    branch_fns.append(_spmd_branch_fn(
                        strategy, shape, rows, role_iters))
                engine_branch.append(branch_of[sig])
            mesh, fn = build_rung_program(n_eng, branch_fns,
                                          engine_branch)
            # provenance records the VERIFIED fence state, not an
            # assertion (compat.optimization_barrier degrades to
            # identity on JAX releases without the op — there the psum
            # folds away and this honestly reports unfenced)
            fenced = measured_region_is_fenced(fn, xf, xi)
            if programs is not None:
                programs[program_key] = (mesh, fn, fenced)
        # commit the operands onto the mesh BEFORE the measured region:
        # a host array would be re-transferred inside every timed call,
        # and the transfer (which scales with the widest role, not the
        # observer) would dominate the measurement
        from jax.sharding import PartitionSpec as P
        sharding = jax.sharding.NamedSharding(mesh, P("engine"))
        xf = jax.device_put(xf, sharding)
        xi = jax.device_put(xi, sharding)
        jax.block_until_ready((xf, xi))
        jax.block_until_ready(fn(xf, xi))          # compile + warm
        samples = []
        for _ in range(3):
            t0 = _time.perf_counter_ns()
            jax.block_until_ready(fn(xf, xi))
            samples.append(_time.perf_counter_ns() - t0)
        elapsed = float(np.median(samples))

        strat = resolve_strategy(obs.strategy, obs.shape)
        duty = getattr(obs.shape, "duty_cycle", 1.0)
        n_active = max(1, int(round(iters * duty)))
        # stamp the RESOLVED strategy letter, like the interpret-path
        # group measurement does: the executed branch for a mixed 'r'
        # observer is the 'b' loop, and provenance must say so
        if strat in _SPMD_CHASES:
            # elapsed spans n_active full traversals: bytes and
            # transactions both scale with it (latency = elapsed/tx)
            res = WorkloadResult(strat, obs.pool, buf, iters,
                                 obs_rows * LINE_BYTES * n_active,
                                 elapsed,
                                 transactions=obs_rows * n_active)
        else:
            mult = 2 if strat in _SPMD_STREAM_2X else 1
            res = WorkloadResult(strat, obs.pool, buf, iters,
                                 mult * obs_rows * LINE_BYTES * n_active,
                                 elapsed, 0)
        return res, fenced


# ---------------------------------------------------------------------------
# Matrix-run result containers
# ---------------------------------------------------------------------------


@dataclass
class ScenarioRun:
    """One (scenario, observer, buffer) ladder."""
    spec: ScenarioSpec
    buffer_bytes: int
    key: str
    observer: Optional[ObserverSpec] = None   # which observer this curve is
    scenarios: List[ScenarioResult] = field(default_factory=list)
    # executed-vs-modeled provenance, persisted into CurveDB v2:
    # {"backend", "executed_rungs", "modeled_rungs", ...}
    execution: Dict[str, Any] = field(default_factory=dict)

    def bandwidth_curve(self) -> List[Tuple[int, float]]:
        return [(s.n_stressors,
                 s.main.bandwidth_gbps if s.source == "executed"
                 else (s.modeled_bw_gbps or s.main.bandwidth_gbps))
                for s in self.scenarios]

    def latency_curve(self) -> List[Tuple[int, float]]:
        return [(s.n_stressors,
                 s.main.latency_ns if s.source == "executed"
                 else (s.modeled_lat_ns or s.main.latency_ns))
                for s in self.scenarios]


@dataclass
class DispatchStats:
    """Execution accounting for the matrix runner: the batched runner's
    claim ("fewer dispatches than the per-point loop") and the spmd
    backend's claim ("one fused SPMD dispatch per ladder rung") are
    checked against these numbers in the tests."""
    n_scenarios: int = 0            # ScenarioSpecs in the matrix
    n_ladders: int = 0              # (spec, observer, buffer) ladders
    measure_dispatches: int = 0     # timed executable kernel passes
    model_evals: int = 0            # queueing-network solves
    spmd_rungs: int = 0             # fused SPMD rung dispatches


@dataclass
class MatrixResult:
    runs: List[ScenarioRun] = field(default_factory=list)
    stats: DispatchStats = field(default_factory=DispatchStats)


# ---------------------------------------------------------------------------
# The SPMD scenario program (the spin-lock sandwich, collective edition).
# Built for any 1-D mesh of engines; executable on forced host devices in
# this container and unchanged on a real slice.  The ``spmd`` backend
# dispatches one of these programs per ladder rung.
# ---------------------------------------------------------------------------

_SPMD_CHASES = ("l", "m", "t")      # latency walks: dependent gathers
_SPMD_STREAM_2X = ("c", "x")        # copy/rmw touch two lines per line


def _spmd_branch_fn(strategy: str, shape, rows: int, iters: int):
    """Per-engine activity for one SPMD rung: ``(xf, xi) -> f32``.

    Pure-jnp traffic loops (no Pallas: every branch must trace under
    ``shard_map``'s switch on any backend).  All branches take the SAME
    operand pair and return a scalar so ``lax.switch`` can fuse them;
    each closes over its own static row count and iteration budget.
    Loop bodies either carry the buffer or re-issue it through
    ``optimization_barrier`` so XLA cannot hoist the memory traffic out
    of the loop."""
    from repro import compat

    strat = resolve_strategy(strategy, shape)
    duty = getattr(shape, "duty_cycle", 1.0) if shape is not None else 1.0
    n = max(1, int(round(iters * duty)))

    if strategy == "i":
        def idle(xf, xi):
            def body(_, acc):
                return acc * 0.999 + 1.0
            # seeded from the (barrier-fenced) operand: even idle
            # engines enter their spin only after the start barrier
            return jax.lax.fori_loop(0, n * 8, body, xf[0, 0] * 1e-30)
        return idle

    if strat in _SPMD_CHASES:
        def chase(xf, xi):
            chain = xi[:rows, 0]

            def step(_, idx):
                return chain[idx]

            def cycle(_, carry):
                idx, acc = carry
                idx = jax.lax.fori_loop(0, rows, step, idx)
                return idx, acc + idx.astype(jnp.float32)

            _, acc = jax.lax.fori_loop(
                0, n, cycle, (jnp.int32(0), jnp.float32(0.0)))
            return acc
        return chase

    if strat in ("w", "y"):
        def write(xf, xi):
            def body(_, x):
                return x + 1.0
            x = jax.lax.fori_loop(0, n, body, xf[:rows])
            return x[0, 0]
        return write

    if strat in ("c", "x", "b"):
        def readwrite(xf, xi):
            def body(_, x):
                return x * 1.0000001 + 0.25
            x = jax.lax.fori_loop(0, n, body, xf[:rows])
            return x[0, 0]
        return readwrite

    def read(xf, xi):
        x = xf[:rows]

        def body(_, acc):
            # re-issue the buffer each pass: the barrier pins the reads
            # inside the loop (a bare sum would be loop-invariant)
            xx = compat.optimization_barrier(x)
            return acc * 0.5 + jnp.sum(xx)

        return jax.lax.fori_loop(0, n, body, jnp.float32(0.0))
    return read


def build_rung_program(n_engines: int, branch_fns, engine_branch):
    """One fused SPMD rung over an ("engine",) mesh.

    Returns ``(mesh, f)`` with ``f(xf, xi) -> (per_engine_out, barrier)``
    jit-compiled: engine ``e`` runs ``branch_fns[engine_branch[e]]`` on
    its shard of the operands.  The measured region is *provably*
    sandwiched (invariants 1-4 of the module docstring):

      start — every engine all-reduces a token derived from its live
          operand data (psum #1; a constant token would fold away at
          trace time), and the operands are re-issued through
          ``optimization_barrier`` together with that token, so every
          activity's operands carry a dataflow dependency on the
          collective: XLA cannot schedule measured work before the
          barrier completes;
      stop — the activity outputs are all-reduced (psum #2) into the
          returned barrier value, so the dispatch only retires after
          every engine's activity finished, and the next rung (a new
          dispatch) cannot begin until the host unblocks.

    :func:`measured_region_is_fenced` asserts the start edge
    structurally (jaxpr dataflow), which the tests pin down.
    """
    from jax.sharding import PartitionSpec as P

    from repro import compat

    devs = jax.devices()[:n_engines]
    mesh = compat.make_mesh_from_devices(devs, ("engine",))
    table = jnp.asarray(list(engine_branch), jnp.int32)

    def per_engine(xf, xi):
        xf, xi = xf[0], xi[0]
        # barrier #1 (see docstring): data-derived token, all-reduced,
        # then threaded into every operand
        token = jax.lax.psum(xf[0, 0] + xi[0, 0].astype(xf.dtype),
                             "engine")
        xf, xi, token = compat.optimization_barrier((xf, xi, token))
        eng = jax.lax.axis_index("engine")
        out = jax.lax.switch(table[eng], branch_fns, xf, xi)
        # barrier #2: consumes every engine's finished activity.  (The
        # start token is alive through the operands' barrier edge; only
        # the stop psum — statically replicated — is returned.)
        done = jax.lax.psum(out, "engine")
        return out[None], done

    f = compat.shard_map(per_engine, mesh=mesh,
                         in_specs=(P("engine"), P("engine")),
                         out_specs=(P("engine"), P()))
    return mesh, jax.jit(f)


def build_scenario_program(n_engines: int, n_stressors: int,
                           main_fn, stress_fn, idle_fn):
    """Returns f(main_x, stress_x) -> (main_out, barrier) running under
    ``shard_map`` over an ("engine",) mesh: engine 0 = observed, engines
    1..n_stressors = stress, rest idle.  The measured region is fenced by
    two psum barriers (invariants 1-4 above) — and the fence is
    dataflow-enforced: the start psum is derived from live operand data
    and re-issued into the operands via ``optimization_barrier``, so
    the activities cannot be hoisted above it (the historical version
    computed a psum nothing depended on, which JAX folds away at trace
    time — invariant 1 was unenforced)."""
    from jax.sharding import PartitionSpec as P

    from repro import compat

    devs = jax.devices()[:n_engines]
    mesh = compat.make_mesh_from_devices(devs, ("engine",))

    def per_engine(main_x, stress_x):
        eng = jax.lax.axis_index("engine")
        # barrier #1: every engine signals ready before measurement
        # starts, and the measured operands depend on the collective
        seed = (jnp.ravel(main_x)[0].astype(jnp.float32)
                + jnp.ravel(stress_x)[0].astype(jnp.float32))
        ready = jax.lax.psum(seed, "engine")
        main_x, stress_x, ready = compat.optimization_barrier(
            (main_x, stress_x, ready))

        def run_main(m, _s):
            return main_fn(m)

        def run_stress(_m, s):
            return stress_fn(s)

        def run_idle(_m, s):
            return idle_fn(s)

        branch = jnp.where(eng == 0, 0,
                           jnp.where(eng <= n_stressors, 1, 2))
        # operands passed positionally: the `operand=` kwarg is
        # deprecated drift (the grep lint in tests/test_compat.py
        # rejects it)
        out = jax.lax.switch(branch, [run_main, run_stress, run_idle],
                             main_x, stress_x)
        # barrier #2: measurement closes only after every engine
        # finished — `done` consumes each engine's activity output.
        # (`ready` stays alive through the operand barrier edge; the
        # returned value is the stop psum, which is statically
        # replicated.)
        done = jax.lax.psum(jnp.ravel(out)[0].astype(jnp.float32),
                            "engine")
        return out, done

    f = compat.shard_map(per_engine, mesh=mesh,
                         in_specs=(P("engine"), P("engine")),
                         out_specs=(P("engine"), P()))
    return mesh, f


# ---------------------------------------------------------------------------
# Structural fence verification (sandwich invariant 1, as a jaxpr check)
# ---------------------------------------------------------------------------


def measured_region_is_fenced(fn, *example_args) -> bool:
    """Does the measured output depend — through DATAFLOW, not just
    program order — on the start-barrier psum?

    Walks the traced jaxpr: inside every ``shard_map`` body, takes the
    first psum equation (the start barrier), computes the forward
    dataflow closure of its outputs, and requires the body's first
    output (the measured activity result) to lie inside that closure.
    A program whose barrier is advisory only — the pre-fix
    ``build_scenario_program``, where ``out`` had no data dependency on
    ``ready`` — returns False: XLA was free to begin the measured
    activity before the stressors were running."""
    closed = jax.make_jaxpr(fn)(*example_args)
    bodies = _shard_map_bodies(closed.jaxpr)
    if not bodies:
        return False
    return all(_first_out_depends_on_psum(b) for b in bodies)


def _sub_jaxprs(params: Dict[str, Any]):
    for v in params.values():
        for u in (v if isinstance(v, (tuple, list)) else (v,)):
            inner = getattr(u, "jaxpr", u)
            if hasattr(inner, "eqns"):
                yield inner


def _shard_map_bodies(jaxpr) -> List[Any]:
    out = []
    for eqn in jaxpr.eqns:
        for inner in _sub_jaxprs(eqn.params):
            if "shard_map" in eqn.primitive.name:
                out.append(inner)
            else:
                out.extend(_shard_map_bodies(inner))
    return out


def _first_out_depends_on_psum(body) -> bool:
    live: set = set()
    seen_psum = False
    for eqn in body.eqns:
        invars = [v for v in eqn.invars if not hasattr(v, "val")]
        if not seen_psum and "psum" in eqn.primitive.name:
            seen_psum = True
            live.update(eqn.outvars)
            continue
        if seen_psum and any(v in live for v in invars):
            live.update(eqn.outvars)
    out0 = body.outvars[0]
    return out0 in live
