"""Closed queueing-network model of the heterogeneous memory system.

The ``simulate`` backend: this container has one CPU device, so the
multi-engine contention ladders cannot be *executed* here.  They are
instead *modeled* as a multiclass closed queueing network solved with
approximate Mean-Value Analysis (Bard–Schweitzer AMVA), extended with a
shared-entry blocking term that captures the paper's key microarchitectural
finding: transactions to a slow memory hold shared interconnect queue
entries for their full downstream round-trip, throttling traffic to fast
memories that merely *share the bus* (MEMSCOPE §IV-B(4), Fig. 6/7).

Model structure (per platform device tree):
  * one FCFS station per memory module     (service = line/peak_bw)
  * one FCFS station per interconnect port (service = line/port_bw)
  * a per-class delay term                 (base_latency, no queueing)
  * route: off-core transactions traverse the shared port (noc / CCI),
    then the module's last-hop port (pcie, ici) if different, then the
    module; VMEM traffic stays on the core port.
  * shared-port entry blocking: entries held per class = X_c * (downstream
    round trip), total capped at ``queue_entries``; excess demand appears
    as pre-bus waiting time.

Customers of class c = outstanding transactions of one activity
(population = n_engines x per-engine MLP; latency workloads have MLP=1 by
construction — that is their definition).
"""
from __future__ import annotations

import dataclasses
import logging
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.devicetree import MemoryNode, Platform

log = logging.getLogger(__name__)

# traffic multiplier per access strategy: transactions on the memory
# station per *useful* line delivered (WAWB: a write miss = read + victim
# writeback; write-streaming bypasses the allocate read; copy moves a
# read line plus an allocated write line per two useful lines).
STRATEGY_TRAFFIC = {
    "r": 1.0, "s": 1.0, "l": 1.0, "m": 1.0, "t": 1.0,
    "w": 2.0, "x": 2.0,
    "y": 1.0,
    "c": 1.5,
    "b": 1.5,          # default 1:1 mix; read_fraction overrides
    "i": 0.0,
}

# traffic cost of one pure read / one pure (allocating) write line — the
# endpoints a mixed read/write ratio interpolates between
_READ_TRAFFIC, _WRITE_TRAFFIC = 1.0, 2.0

# per-engine MLP by strategy kind: latency chases are serialised (one
# outstanding transaction — that is the measurement method), bandwidth
# streams run at the module's MLP limit.  Write-streaming (y) is *posted*
# — stores never wait for a reply, so a y-engine keeps twice the
# transactions in flight (this is what makes dc-zva streams the most
# aggressive stressor in Fig. 8/13).
def strategy_mlp(strategy: str, node: MemoryNode) -> int:
    if strategy in ("l", "m", "t"):
        return 1
    if strategy == "i":
        return 0
    if strategy == "y":
        return 2 * node.max_mlp
    return node.max_mlp


@dataclass(frozen=True)
class ActivityClass:
    """One class of customers in the closed network.

    The optional *traffic-shape* parameters generalise the steady
    streams of the seed model:

    read_fraction  mixed read/write ratio: the per-line traffic
                   interpolates between a pure read (1 Tx) and a pure
                   write-allocate (2 Tx).  ``None`` = use the
                   strategy's native multiplier.
    duty_cycle     bursty/duty-cycled issue: the class only keeps
                   ``duty_cycle`` of its MLP in flight on time-average
                   (a burst's off phase holds zero entries), shrinking
                   its customer population.
    stride         pointer-chase hop distance in lines: hops beyond
                   one line forfeit row-buffer/prefetch locality, so
                   the per-transaction base latency grows with the hop
                   distance (logarithmically saturating).
    """
    name: str
    node: MemoryNode
    strategy: str
    n_engines: int
    read_fraction: Optional[float] = None
    duty_cycle: float = 1.0
    stride: int = 1

    def population(self) -> int:
        pop = self.n_engines * strategy_mlp(self.strategy, self.node)
        if self.duty_cycle < 1.0 and pop:
            pop = max(1, int(round(pop * self.duty_cycle)))
        return pop

    @property
    def traffic(self) -> float:
        if self.read_fraction is not None:
            return (self.read_fraction * _READ_TRAFFIC
                    + (1.0 - self.read_fraction) * _WRITE_TRAFFIC)
        return STRATEGY_TRAFFIC[self.strategy]

    def base_latency_ns(self) -> float:
        z = self.node.base_latency_ns
        stride = self.stride
        if stride <= 1 and self.strategy == "t":
            stride = _DEFAULT_T_STRIDE    # the t workload's default hop
        if stride > 1:
            z *= 1.0 + _STRIDE_LATENCY_ALPHA * math.log2(
                min(stride, _STRIDE_SATURATION))
        return z


# locality-loss penalty per doubling of the chase hop distance, and the
# distance beyond which a longer stride cannot hurt further (every hop
# already misses the row buffer / defeats the prefetcher)
_STRIDE_LATENCY_ALPHA = 0.12
_STRIDE_SATURATION = 64
_DEFAULT_T_STRIDE = 8     # matches workloads._mk_strided's default


@dataclass
class ClassResult:
    name: str
    x_tx_per_ns: float        # transaction throughput
    r_ns: float               # per-transaction round trip (queueing incl.)
    bw_gbps: float            # useful bytes / s extracted by the class
    lat_ns: float             # per-access latency (for latency workloads)
    entry_wait_ns: float      # time waiting for a shared bus entry


def _route(platform: Platform, cls: "ActivityClass") -> List[str]:
    """Port visits for one class.  Cacheable strategies (and zva write
    streams) traverse the shared-cache bank port when the platform has a
    transparent cache; cache-target classes stop there."""
    node = cls.node
    r: List[str] = []
    cache_name = getattr(platform, "cache_node", None)
    if cache_name and cache_name in platform.memories:
        cache_port = platform.memories[cache_name].port
        if (cls.strategy in ("r", "w", "l", "y", "c", "b")
                and node.port != "core" and cache_port not in r):
            r.append(cache_port)
    if node.kind == "cache":
        return r or [node.port]
    if node.port == "core":
        return r + ["core"]
    shared = getattr(platform, "shared_port", "noc")
    if shared in platform.ports and shared not in r:
        r.append(shared)
    if node.port not in r:
        r.append(node.port)
    return r


def simulate_scenario(
    platform: Platform,
    classes: List[ActivityClass],
    *,
    tol: float = 1e-9,
    max_iter: int = 5000,
) -> Dict[str, ClassResult]:
    """Solve the network for one scenario (one set of concurrent classes)."""
    classes = [c for c in classes if c.population() > 0]
    if not classes:
        return {}
    line = platform.line_bytes

    # stations: ports then memories
    stations: List[Tuple[str, float]] = []   # (name, service_ns)
    for pname, port in platform.ports.items():
        stations.append((f"port:{pname}", line / port.bw_gbps))
    for mname, mem in platform.memories.items():
        stations.append((f"mem:{mname}", line / mem.peak_bw_gbps))
    s_index = {name: i for i, (name, _) in enumerate(stations)}

    # demands D[c][s] (visits x service x traffic) and delay Z[c]
    C = len(classes)
    S = len(stations)
    D = [[0.0] * S for _ in range(C)]
    Z = [0.0] * C
    N = [float(c.population()) for c in classes]
    for ci, c in enumerate(classes):
        t = max(c.traffic, 1e-12)
        for pname in _route(platform, c):
            D[ci][s_index[f"port:{pname}"]] = \
                stations[s_index[f"port:{pname}"]][1] * t
        D[ci][s_index[f"mem:{c.node.name}"]] = \
            stations[s_index[f"mem:{c.node.name}"]][1] * t
        Z[ci] = c.base_latency_ns()

    # Bard–Schweitzer AMVA with shared-entry blocking on the shared port
    # and posted-write-stream blocking on the cache bank port.
    Q = [[N[ci] / S for _ in range(S)] for ci in range(C)]
    entry_wait = [0.0] * C      # shared-port (CCI/noc) entry wait
    bank_wait = [0.0] * C       # cache-bank writeback-buffer wait
    shared = getattr(platform, "shared_port", "noc")
    shared_station = s_index.get(f"port:{shared}")
    entries = (platform.ports[shared].queue_entries
               if shared in platform.ports else math.inf)
    cache_name = getattr(platform, "cache_node", None)
    bank_station = None
    bank_entries = math.inf
    if cache_name and cache_name in platform.memories:
        bank_port = platform.memories[cache_name].port
        bank_station = s_index.get(f"port:{bank_port}")
        if bank_port in platform.ports:
            bank_entries = platform.ports[bank_port].queue_entries

    X = [0.0] * C
    R = [[0.0] * S for _ in range(C)]
    for _ in range(max_iter):
        max_delta = 0.0
        for ci in range(C):
            for si in range(S):
                if D[ci][si] == 0.0:
                    R[ci][si] = 0.0
                    continue
                q_others = sum(Q[cj][si] for cj in range(C))
                q_others -= Q[ci][si] / max(N[ci], 1.0)
                R[ci][si] = D[ci][si] * (1.0 + q_others)
            r_total = sum(R[ci]) + Z[ci] + entry_wait[ci] + bank_wait[ci]
            x_new = N[ci] / r_total
            max_delta = max(max_delta, abs(x_new - X[ci]))
            X[ci] = x_new
            for si in range(S):
                Q[ci][si] = X[ci] * R[ci][si]

        # ---- shared-entry blocking update ----------------------------
        # An entry is held from bus admission until the memory reply, so
        # entries held by class c = X_c * downstream_c (Little).  When the
        # wanted in-flight population exceeds the entry count, arrivals
        # wait for *any* entry to free: the expected wait is the overflow
        # times the bus-wide MEAN holding time — which a slow-memory
        # class inflates for everyone (the paper's Fig. 6/7 mechanism).
        if shared_station is not None and math.isfinite(entries):
            uses_bus = [D[ci][shared_station] > 0.0 for ci in range(C)]
            holds = []
            total_x = 0.0
            for ci in range(C):
                if not uses_bus[ci]:
                    holds.append(0.0)
                    continue
                downstream = sum(R[ci]) + Z[ci]
                holds.append(X[ci] * downstream)
                total_x += X[ci]
            used = sum(holds)
            if used > entries and total_x > 0.0:
                mean_hold = used / total_x
                target = (used - entries) * mean_hold / entries
                for ci in range(C):
                    if uses_bus[ci]:
                        entry_wait[ci] += 0.3 * (target - entry_wait[ci])
            else:
                for ci in range(C):
                    entry_wait[ci] *= 0.7

        # ---- cache-bank writeback-buffer blocking (Fig. 13) -----------
        # Posted write streams (y) hold a bank writeback-buffer slot for
        # the full downstream drain; ordinary misses release the bank
        # after the tag access (they wait in MSHRs instead).  When the
        # streams' in-flight population exceeds the buffer count, the
        # bank pipeline stalls for EVERY class that touches the cache —
        # which is why partitioning cannot mitigate it.
        if bank_station is not None and math.isfinite(bank_entries):
            y_pop = 0.0
            y_x = 0.0
            drain_acc = 0.0
            for ci, c in enumerate(classes):
                if c.strategy == "y" and D[ci][bank_station] > 0.0:
                    y_pop += N[ci]
                    y_x += X[ci]
                    # time to drain downstream once a buffer is held —
                    # excludes the buffer wait itself (else runaway)
                    drain_acc += X[ci] * (sum(R[ci]) + Z[ci]
                                          + entry_wait[ci])
            if y_pop > bank_entries and y_x > 0.0:
                mean_drain = drain_acc / y_x
                target = (y_pop - bank_entries) * mean_drain / bank_entries
                for ci in range(C):
                    if D[ci][bank_station] > 0.0:
                        bank_wait[ci] += 0.3 * (target - bank_wait[ci])
            else:
                for ci in range(C):
                    bank_wait[ci] *= 0.7
        if max_delta < tol:
            break

    out: Dict[str, ClassResult] = {}
    for ci, c in enumerate(classes):
        r_total = sum(R[ci]) + Z[ci] + entry_wait[ci] + bank_wait[ci]
        useful_bw = X[ci] * line / max(c.traffic, 1e-12)
        out[c.name] = ClassResult(
            name=c.name,
            x_tx_per_ns=X[ci],
            r_ns=r_total,
            bw_gbps=useful_bw,          # bytes/ns == GB/s
            lat_ns=r_total * max(c.traffic, 1e-12),
            entry_wait_ns=entry_wait[ci],
        )
    return out


def co_observer_class(name: str, node: MemoryNode, strategy: str, *,
                      read_fraction: Optional[float] = None,
                      duty_cycle: float = 1.0,
                      stride: int = 1) -> ActivityClass:
    """The queueing-network term for one *coupled* co-observer.

    A sibling observer of a coupled multi-observer scenario is always
    on — it occupies exactly one engine at its strategy's native MLP at
    EVERY ladder rung (unlike the stressor ensemble, which grows with
    the rung index).  This mirrors the spmd backend's executed rungs,
    where every sibling runs as a live engine inside the measured
    region; an uncoupled scenario simply omits these classes (the
    historical semantics)."""
    return ActivityClass(name, node, strategy, 1,
                         read_fraction=read_fraction,
                         duty_cycle=duty_cycle, stride=stride)


# ---------------------------------------------------------------------------
# Surface-calibrated mode (CurveDB v3)
# ---------------------------------------------------------------------------


@dataclass
class SurfaceCalibration:
    """A platform re-fit to a measured bandwidth–latency surface.

    ``platform`` carries the rescaled per-module service rates;
    ``scale_bw`` / ``scale_lat`` record the fitted per-pool factors and
    ``residual_bw`` / ``residual_lat`` the relative error still left at
    the surface's uncontended edge after the fit (the fidelity number
    the tests hold the mode to)."""
    platform: Platform
    scale_bw: Dict[str, float] = field(default_factory=dict)
    scale_lat: Dict[str, float] = field(default_factory=dict)
    residual_bw: Dict[str, float] = field(default_factory=dict)
    residual_lat: Dict[str, float] = field(default_factory=dict)


def _modeled_edge(platform: Platform, pool: str) -> Tuple[float, float]:
    """The model's own uncontended edge for one pool: the bandwidth a
    single streaming reader extracts, and the latency a single
    serialized chaser sees (the two measurement methods the surface's
    n_stressors=0 edge was characterized with)."""
    node = platform.memories[pool]
    bw = simulate_scenario(
        platform, [ActivityClass("obs", node, "r", 1)])["obs"].bw_gbps
    lat = simulate_scenario(
        platform, [ActivityClass("obs", node, "l", 1)])["obs"].lat_ns
    return bw, lat


def calibrate_to_surface(platform: Platform, db, *,
                         pools: Optional[List[str]] = None,
                         rounds: int = 4) -> SurfaceCalibration:
    """Fit per-class service rates to a measured surface edge.

    For every characterized pool, rescales the memory node's
    ``peak_bw_gbps`` (the FCFS station's service rate) and
    ``base_latency_ns`` (the per-class delay term) until the model's
    uncontended edge reproduces the surface's measured
    ``n_stressors=0`` edge.  The two knobs interact (latency feeds the
    bandwidth edge and queueing feeds the latency edge), so the fit
    runs a short fixpoint iteration instead of a one-shot division.

    ``db`` is any CurveDB (v1/v2/v3) — the v3 surface interpolates its
    rw_ratio/inject_rate axes at the pure-read full-duty corner, which
    is exactly the edge the model's single-reader class reproduces.
    """
    cal = SurfaceCalibration(platform=platform)
    names = pools if pools is not None else db.observer_pools()
    names = [p for p in names if p in platform.memories]

    def edge(pool: str, obs_strat: str) -> float:
        # the n_stressors=0 edge is uncontended, so ANY characterized
        # stressor pairing for this observer carries it — but prefer a
        # pairing the surface resolves WITHOUT extrapolating, tolerate
        # pairings that only exist under a shape tag, and ignore
        # variant surfaces (structured qualifiers like "worstcase":
        # calibration fits the mean surface, not an envelope)
        pairings = sorted({(k.stress_pool, k.stress_strat, k.tag)
                           for k in db.surfaces
                           if k.obs_pool == pool
                           and k.obs_strat == obs_strat
                           and (not k.qualifier
                                or any(c in k.qualifier for c in ":|@"))})
        if not pairings:
            raise KeyError(
                f"pool {pool!r} has no {obs_strat!r}-observer surface "
                f"pairings at all; have "
                f"{sorted(k.to_string() for k in db.surfaces)}")
        fallback: Optional[Tuple[str, str, float]] = None
        for sp, ss, tag in pairings:
            try:
                q = db.query(pool, 0, obs_strat=obs_strat,
                             stress_pool=sp, stress_strat=ss,
                             shape_tag=tag)
            except KeyError:
                continue    # tagged-only pairing with no steady fallback
            v = q.bandwidth_gbps if obs_strat == "r" else q.latency_ns
            if not q.extrapolated:
                return v
            if fallback is None:
                fallback = (sp, ss, v)
        if fallback is None:
            raise KeyError(
                f"no resolvable {obs_strat!r} pairing for pool {pool!r}")
        sp, ss, v = fallback
        log.warning("calibrate_to_surface: every %r pairing for pool %r "
                    "extrapolates at the n_stressors=0 edge; using "
                    "(%s, %s)", obs_strat, pool, sp, ss)
        return v

    measured: Dict[str, Tuple[float, float]] = {}
    for pool in names:
        try:
            bw, lat = edge(pool, "r"), edge(pool, "l")
        except KeyError as exc:
            # pool not characterized with both probes: skip the fit for
            # it, loudly — a silent skip here masked real coverage gaps
            log.warning("calibrate_to_surface: skipping pool %r: %s",
                        pool, exc)
            continue
        if bw > 0.0 and lat > 0.0:
            measured[pool] = (bw, lat)

    plat = platform
    for _ in range(max(1, rounds)):
        mems = dict(plat.memories)
        for pool, (m_bw, m_lat) in measured.items():
            mod_bw, mod_lat = _modeled_edge(plat, pool)
            node = mems[pool]
            mems[pool] = dataclasses.replace(
                node,
                peak_bw_gbps=node.peak_bw_gbps * m_bw / max(mod_bw, 1e-12),
                base_latency_ns=(node.base_latency_ns
                                 * m_lat / max(mod_lat, 1e-12)))
        plat = dataclasses.replace(plat, memories=mems)

    for pool, (m_bw, m_lat) in measured.items():
        mod_bw, mod_lat = _modeled_edge(plat, pool)
        cal.scale_bw[pool] = (plat.memories[pool].peak_bw_gbps
                              / platform.memories[pool].peak_bw_gbps)
        cal.scale_lat[pool] = (plat.memories[pool].base_latency_ns
                               / platform.memories[pool].base_latency_ns)
        cal.residual_bw[pool] = abs(mod_bw - m_bw) / m_bw
        cal.residual_lat[pool] = abs(mod_lat - m_lat) / m_lat
    cal.platform = plat
    return cal


def scenario_ladder(
    platform: Platform,
    *,
    obs_node: MemoryNode,
    obs_strategy: str,
    stress_node: MemoryNode,
    stress_strategy: str,
    max_stressors: Optional[int] = None,
    co_observers: Optional[List[Tuple[MemoryNode, str]]] = None,
) -> List[Dict[str, ClassResult]]:
    """The paper's best->worst scenario sequence: 0..p-1 stressor
    engines.  ``co_observers`` — (node, strategy) pairs — adds coupled
    sibling observers present at every rung (see
    :func:`co_observer_class`)."""
    p = platform.n_engines if max_stressors is None else max_stressors + 1
    results = []
    for k in range(p):
        classes = [ActivityClass("obs", obs_node, obs_strategy, 1)]
        for j, (node, strat) in enumerate(co_observers or ()):
            if strat != "i":
                classes.append(co_observer_class(f"co{j}", node, strat))
        if k and stress_strategy != "i":
            classes.append(
                ActivityClass("stress", stress_node, stress_strategy, k))
        results.append(simulate_scenario(platform, classes))
    return results
