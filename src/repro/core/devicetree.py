"""Platform description + auto-detection — the device-tree analog.

MEMSCOPE discovers memory modules from the kernel device tree (DTB nodes
with ``compatible = "mempool"``).  Our platforms are described by the same
kind of declarative tree (a dict / JSON file with one node per memory
module), and ``detect_platform()`` auto-builds the description for the
runtime it finds — exactly the role the DTB plays for the kernel module.

Each node records the *modeled* temporal characteristics used by the
queueing simulator (``repro.core.simulate``) and by the roofline; on real
TPU hardware the same numbers are the published v5e specs.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax

# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MemoryNode:
    """One memory module (a DTB ``mempool`` node)."""
    name: str                 # pool name, e.g. "hbm"
    kind: str                 # hbm | vmem | host | peer
    size_bytes: int
    peak_bw_gbps: float       # sustained sequential bandwidth, GB/s
    base_latency_ns: float    # unloaded round-trip latency
    port: str = "noc"         # shared interconnect this module hangs off
    max_mlp: int = 16         # per-engine outstanding-transaction limit
    memory_kind: Optional[str] = None   # jax memory kind ("device", ...)

    @property
    def reg(self) -> str:
        """DTS-style reg string (size only; PA base is virtualised)."""
        return f"<0x0 0x{self.size_bytes:x}>"


@dataclass(frozen=True)
class InterconnectNode:
    """A shared transaction port (the CCI analog)."""
    name: str
    bw_gbps: float
    queue_entries: int        # shared outstanding-transaction entries


@dataclass(frozen=True)
class Platform:
    name: str
    n_engines: int            # traffic-generating compute engines ("cores")
    line_bytes: int           # transaction granularity
    memories: Dict[str, MemoryNode]
    ports: Dict[str, InterconnectNode]
    peak_flops: float = 0.0   # per engine, FLOP/s (bf16)
    shared_port: str = "noc"  # the CCI analog every off-core Tx traverses
    # name of a *transparent shared cache* node (ZCU102: "l2").  None on
    # v5e: VMEM is a private software-managed scratchpad, so hit-path
    # bank contention structurally cannot arise there (DESIGN.md
    # §hardware-adaptation) — cacheable small buffers simply become
    # VMEM-resident with no cross-engine cache coupling.
    cache_node: Optional[str] = None

    def node(self, name: str) -> MemoryNode:
        if name not in self.memories:
            raise KeyError(
                f"no memory node {name!r}; available: "
                f"{sorted(self.memories)}")
        return self.memories[name]

    def to_json(self) -> str:
        return json.dumps({
            "name": self.name,
            "n_engines": self.n_engines,
            "line_bytes": self.line_bytes,
            "peak_flops": self.peak_flops,
            "shared_port": self.shared_port,
            "memories": {k: dataclasses.asdict(v)
                         for k, v in self.memories.items()},
            "ports": {k: dataclasses.asdict(v)
                      for k, v in self.ports.items()},
        }, indent=2)

    @staticmethod
    def from_json(text: str) -> "Platform":
        d = json.loads(text)
        return Platform(
            name=d["name"], n_engines=d["n_engines"],
            line_bytes=d["line_bytes"],
            peak_flops=d.get("peak_flops", 0.0),
            shared_port=d.get("shared_port", "noc"),
            memories={k: MemoryNode(**v) for k, v in d["memories"].items()},
            ports={k: InterconnectNode(**v)
                   for k, v in d["ports"].items()},
        )


# ---------------------------------------------------------------------------
# The modeled TPU v5e platform (DESIGN.md §2 mapping table).
#
# Numbers: HBM bw/size and bf16 FLOPs are published v5e specs; VMEM size is
# the documented 128 MiB; VMEM bandwidth/latency, host-PCIe and ICI figures
# are modeling estimates (marked in DESIGN.md).  The 512-byte line is the
# natural TPU transaction granularity (one (8,128)·f32 VREG tile row ≈ a
# DMA burst), the analog of the 64-byte ARM cache line.
# ---------------------------------------------------------------------------

TPU_V5E = Platform(
    name="tpu-v5e",
    n_engines=8,              # engines per *measurement group*: 8 cores of a
                              # 2x4 slice drive contention ladders (paper: 4)
    line_bytes=512,
    peak_flops=197e12,
    # max_mlp calibration: TPU DMA queues pipeline deeply (hundreds of
    # outstanding 512B-line transactions), unlike a CPU core's ~6-entry
    # LSQ — this is WHY TPUs hide HBM latency, and it is the recorded
    # hardware-adaptation delta vs. the paper's ARM numbers.  Values are
    # set so a single stream reaches the plausible fraction of peak
    # (hbm: ~340 GB/s single DMA stream; host: ~8 GB/s PCIe stream) and
    # full 8-engine ladders saturate the module.
    memories={
        "hbm": MemoryNode("hbm", "hbm", 16 << 30, 819.0, 390.0,
                          port="noc", max_mlp=256, memory_kind="device"),
        "vmem": MemoryNode("vmem", "vmem", 128 << 20, 11_000.0, 35.0,
                           port="core", max_mlp=256, memory_kind=None),
        "host": MemoryNode("host", "host", 256 << 30, 28.0, 2_100.0,
                           port="pcie", max_mlp=32,
                           memory_kind="pinned_host"),
        "peer": MemoryNode("peer", "peer", 16 << 30, 45.0, 1_400.0,
                           port="ici", max_mlp=32, memory_kind=None),
    },
    ports={
        "noc": InterconnectNode("noc", 1_600.0, 64),
        "core": InterconnectNode("core", 22_000.0, 16),
        "pcie": InterconnectNode("pcie", 32.0, 32),
        "ici": InterconnectNode("ici", 50.0, 32),
    },
)

# The ZCU102 platform from the paper (used to sanity-check the simulator
# against the paper's published curves — Fig. 4/5, Tables II/III, and the
# cache experiments Fig. 10-13: the shared L2 appears as a "cache"-kind
# node whose single bank port every cacheable access traverses).
ZCU102 = Platform(
    name="zcu102",
    n_engines=4,              # quad Cortex-A53
    line_bytes=64,
    peak_flops=12e9,
    memories={
        "dram": MemoryNode("dram", "hbm", 256 << 20, 4.8, 150.0,
                           port="cci", max_mlp=6, memory_kind="device"),
        "pl-dram": MemoryNode("pl-dram", "host", 256 << 20, 1.6, 380.0,
                              port="cci", max_mlp=6, memory_kind=None),
        "ocm": MemoryNode("ocm", "vmem", 128 << 10, 3.2, 120.0,
                          port="cci", max_mlp=4, memory_kind=None),
        "bram": MemoryNode("bram", "vmem", 1 << 20, 1.2, 200.0,
                           port="cci", max_mlp=4, memory_kind=None),
        # the unified 16-way 1 MiB LLC; single-banked on this SoC —
        # calibrated so 1 core extracts ~21 GB/s hitting in L2 and 4
        # contending cores see the paper's ~3.2x cycles/access blow-up
        "l2": MemoryNode("l2", "cache", 1 << 20, 27.0, 30.0,
                         port="l2bank", max_mlp=12, memory_kind=None),
    },
    ports={"cci": InterconnectNode("cci", 9.6, 16),
           # 12 writeback-buffer entries: one y-stream engine (posted MLP
           # 12) fits exactly — reproducing the paper's Fig. 13 boundary
           # (identical at 1 stressor, collapse at >= 2)
           "l2bank": InterconnectNode("l2bank", 27.0, 12)},
    shared_port="cci",
    cache_node="l2",
)


def zcu102_partitioned() -> Platform:
    """The Minerva-Jailhouse page-coloring setup of §IV-D: 1/4 of the LLC
    (256 KiB) exported as the *private cache pool* (pvtpool); the shared
    part shrinks to 768 KiB.  pvtpool is just another heterogeneous
    memory module from MEMSCOPE's point of view."""
    mems = dict(ZCU102.memories)
    mems["l2"] = dataclasses.replace(mems["l2"], size_bytes=768 << 10)
    mems["pvtpool"] = MemoryNode("pvtpool", "cache", 256 << 10, 27.0, 30.0,
                                 port="l2bank", max_mlp=12,
                                 memory_kind=None)
    return dataclasses.replace(ZCU102, name="zcu102-partitioned",
                               memories=mems)


def detect_platform(override: Optional[str] = None) -> Platform:
    """Auto-detect like MEMSCOPE reads the DTB at module load.

    On a real TPU backend returns the v5e tree; off-TPU returns the same
    *modeled* tree (the simulate backend supplies the temporal behaviour).
    """
    if override == "zcu102":
        return ZCU102
    if override in (None, "tpu-v5e"):
        return TPU_V5E
    raise KeyError(f"unknown platform {override!r}")
