"""MEMSCOPE core — the paper's contribution as a composable subsystem.

devicetree   platform description + auto-detect (DTB analog)
pools        Memory Pool Manager (genpool analog) + upool export
workloads    Workload Library (Table-I access strategies)
coordinator  Core Coordinator: scenario ladders + barrier sandwich
counters     perf-counter analog (AOT cost analysis + wall timers)
simulate     closed queueing-network model (contention at v5e scale)
characterize performance curves + Little's-law MLP (CurveDB)
placement    characterization-driven Placement Advisor (upool payoff)
interface    debugfs-entry analog (config strings, results, CLI)
"""
from repro.core.coordinator import (  # noqa: F401
    ActivitySpec, CoreCoordinator, ExperimentConfig, ExperimentResult,
)
from repro.core.devicetree import Platform, detect_platform  # noqa: F401
from repro.core.pools import PoolManager  # noqa: F401
