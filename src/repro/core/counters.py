"""Performance-counter analog — AOT program analysis + wall-clock timers.

MEMSCOPE samples ARMv8 PMU events around the measured region.  A TPU
exposes no user PMU, but an AOT-compiled XLA program is *fully analysable
before it runs*: ``cost_analysis()`` gives exact FLOPs and bytes touched,
``memory_analysis()`` gives the allocation picture, and the lowered HLO
names every collective.  Together with wall-clock sandwich timing these
cover the paper's Table-IV methodology (cycles, mem accesses, cache
refills -> flops, HBM bytes, per-access cycles).

Six "counters" per activity, mirroring the 6-counter/core ARM PMU limit.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro import compat

MAX_COUNTERS = 6   # ARM PMU exposes 6 programmable counters per core

#: available events (the pmevtyper analog)
EVENTS = (
    "WALL_NS",          # measured region wall time
    "HLO_FLOPS",        # cost_analysis flops
    "HLO_BYTES",        # cost_analysis bytes accessed
    "TRANSACTIONS",     # bytes / line_bytes
    "NS_PER_TX",        # wall / transactions
    "PEAK_MEMORY",      # memory_analysis temp+arg bytes
)


@dataclass
class CounterSample:
    events: Dict[str, float] = field(default_factory=dict)

    def __getitem__(self, k: str) -> float:
        return self.events[k]

    def as_row(self) -> str:
        return " ".join(f"{k}={v:.4g}" for k, v in self.events.items())


def select_events(names: Tuple[str, ...]) -> Tuple[str, ...]:
    bad = [n for n in names if n not in EVENTS]
    if bad:
        raise KeyError(f"unknown events {bad}; available {EVENTS}")
    if len(names) > MAX_COUNTERS:
        raise ValueError(
            f"at most {MAX_COUNTERS} counters per core (got {len(names)})")
    return names


def cost_of(fn: Callable, *args, **kw) -> Dict[str, float]:
    """AOT cost analysis of fn(*args) without executing it."""
    lowered = jax.jit(fn).lower(*args, **kw)
    compiled = lowered.compile()
    cost = compat.cost_analysis(compiled)
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    mem = compiled.memory_analysis()
    peak = 0.0
    if mem is not None:
        peak = float(
            getattr(mem, "temp_size_in_bytes", 0) +
            getattr(mem, "argument_size_in_bytes", 0) +
            getattr(mem, "output_size_in_bytes", 0))
    return {"HLO_FLOPS": flops, "HLO_BYTES": byts, "PEAK_MEMORY": peak}


def sample(fn: Callable, *args, iters: int = 10, line_bytes: int = 512,
           events: Tuple[str, ...] = EVENTS[:MAX_COUNTERS],
           **kw) -> CounterSample:
    """Run fn under the selected counters (compile excluded from timing)."""
    events = select_events(tuple(events))
    static = cost_of(fn, *args, **kw)
    jfn = jax.jit(fn)
    jfn(*args, **kw).block_until_ready()
    t0 = time.perf_counter_ns()
    for _ in range(iters):
        out = jfn(*args, **kw)
    jax.tree.map(
        lambda x: x.block_until_ready() if hasattr(x, "block_until_ready")
        else x, out)
    wall = (time.perf_counter_ns() - t0) / iters

    tx = static["HLO_BYTES"] / line_bytes
    all_events = {
        "WALL_NS": wall,
        "HLO_FLOPS": static["HLO_FLOPS"],
        "HLO_BYTES": static["HLO_BYTES"],
        "TRANSACTIONS": tx,
        "NS_PER_TX": wall / tx if tx else 0.0,
        "PEAK_MEMORY": static["PEAK_MEMORY"],
    }
    return CounterSample({k: all_events[k] for k in events})
