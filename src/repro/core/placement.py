"""Placement Advisor — characterization-driven memory management.

The upool payoff (paper §IV-E): once the curves are known, framework
objects are *deliberately* placed across heterogeneous memories — and the
right answer is often counter-intuitive (Fig. 14: allocate the victim's
heap in the module the stressors are NOT hammering... which can be the
nominally slower one).

The advisor solves a small assignment problem: given
  * memory objects (size, bytes moved per step, latency sensitivity),
  * candidate pools with capacities,
  * an expected contention level (stressor count + their target pool),
it minimises the predicted per-step time

    t(obj, pool) = traffic_bytes / eff_bw(pool | contention)
                 + lat_weight * eff_lat(pool | contention) * dependent_accesses

greedily by "regret density" (largest time delta between best and
second-best pool per byte first), respecting capacities.

Framework integration: ``repro.serve.engine`` asks the advisor where the
KV cache goes (HBM vs. host, under decode-time contention); the train
loop asks where optimizer state lives (ZeRO-offload decision).
"""
from __future__ import annotations

import logging
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.characterize import CurveDB
from repro.core.devicetree import Platform

log = logging.getLogger(__name__)


@dataclass(frozen=True)
class MemObject:
    """One placeable framework object."""
    name: str
    size_bytes: int
    bytes_per_step: float          # streaming traffic it generates
    dependent_accesses: float = 0.0  # serialized (latency-bound) accesses
    pinned_pool: Optional[str] = None  # force placement (escape hatch)


@dataclass(frozen=True)
class ContentionSpec:
    """Expected background load while this application runs.

    ``rw_ratio`` / ``inject_rate`` are surface coordinates (CurveDB
    v3): the stressors' read share of line-touches and their injection
    duty.  The cost model interpolates the characterized surface at
    these coordinates instead of snapping to the nearest tagged curve.
    ``stress_shape_tag`` still selects a legacy per-shape curve exactly
    (e.g. ``"st8"`` for a strided chase — see ``TrafficShape.tag()``)
    when one was characterized.
    """
    n_stressors: int = 0
    stress_pool: str = "hbm"
    stress_strategy: str = "w"
    stress_shape_tag: str = ""
    rw_ratio: Optional[float] = None
    inject_rate: Optional[float] = None

    @staticmethod
    def shaped(n_stressors: int, stress_pool: str, stress_strategy: str,
               shape) -> "ContentionSpec":
        """Build from a :class:`repro.core.scenarios.TrafficShape`:
        mixed/burst shapes become surface coordinates (interpolated),
        and every shape also carries its tag so legacy per-shape
        curves keep resolving exactly."""
        rw = shape.read_fraction if shape.kind == "mixed" else None
        ir = shape.duty_cycle if shape.duty_cycle != 1.0 else None
        return ContentionSpec(n_stressors, stress_pool, stress_strategy,
                              stress_shape_tag=shape.tag(),
                              rw_ratio=rw, inject_rate=ir)


@dataclass
class PlacementDecision:
    pool: str
    predicted_step_ns: float
    alternatives: Dict[str, float] = field(default_factory=dict)
    # True when the winning pool's cost came from an extrapolated
    # surface query (coordinates beyond the characterized grid, or a
    # fallback past a missing axis) — the prediction is a clamp, not a
    # measurement
    extrapolated: bool = False


@dataclass
class PlacementPlan:
    decisions: Dict[str, PlacementDecision] = field(default_factory=dict)

    def pool_of(self, name: str) -> str:
        return self.decisions[name].pool

    def total_predicted_ns(self) -> float:
        return sum(d.predicted_step_ns for d in self.decisions.values())

    def report(self) -> str:
        lines = ["object              pool     t_pred(us)   alternatives"]
        for name, d in self.decisions.items():
            alts = " ".join(f"{p}:{t / 1e3:.1f}" for p, t in
                            sorted(d.alternatives.items()))
            lines.append(f"{name:19s} {d.pool:8s} "
                         f"{d.predicted_step_ns / 1e3:10.1f}   {alts}")
        return "\n".join(lines)


class PlacementAdvisor:
    """``pessimistic=True`` advises against the worst-case search
    envelope (``SurfaceKey(qualifier="worstcase")``) instead of the
    mean surface: the cost of a pool is what the ADVERSARIAL stressor
    mix does to it at the given stressor count, whatever mix the
    contention spec nominally expects.  Decisions fall back to the
    mean surface (flagged extrapolated) when no envelope was
    characterized for a pool.

    ``qualifier`` selects a variant surface for every cost query —
    serving passes :data:`repro.core.characterize.ONLINE_QUALIFIER` so
    that, once the contention watchdog has refreshed a cell, the
    re-advise runs against the LIVE measurement and falls through to
    the offline surface where no refresh has happened."""

    def __init__(self, db: CurveDB, platform: Platform,
                 pools: Optional[Sequence[str]] = None,
                 pessimistic: bool = False, qualifier: str = ""):
        self.db = db
        self.platform = platform
        self.pessimistic = pessimistic
        self.qualifier = qualifier
        self.pools = list(pools) if pools is not None else \
            db.observer_pools()

    # -- cost model ---------------------------------------------------------
    def _predict(self, obj: MemObject, pool: str,
                 contention: ContentionSpec) -> Tuple[float, bool]:
        """(predicted ns, extrapolated?) — both surface queries
        interpolated at the contention's coordinates."""
        kw = dict(stress_pool=contention.stress_pool,
                  stress_strat=contention.stress_strategy,
                  shape_tag=contention.stress_shape_tag,
                  rw_ratio=contention.rw_ratio,
                  inject_rate=contention.inject_rate,
                  qualifier=self.qualifier)
        if self.pessimistic:
            # the envelope is 1-axis (n_stressors): the adversarial
            # search already minimized/maximized over the mix, duty and
            # shape knobs, so the spec's mix coordinates do not apply
            kw.update(qualifier="worstcase", shape_tag="",
                      rw_ratio=None, inject_rate=None)
        bw_q = self.db.query(pool, contention.n_stressors,
                             obs_strat="r", **kw)
        lat_q = self.db.query(pool, contention.n_stressors,
                              obs_strat="l", **kw)
        stream_ns = obj.bytes_per_step / max(bw_q.bandwidth_gbps, 1e-9)
        lat_ns = obj.dependent_accesses * lat_q.latency_ns
        return stream_ns + lat_ns, bw_q.extrapolated or lat_q.extrapolated

    def predict_ns(self, obj: MemObject, pool: str,
                   contention: ContentionSpec) -> float:
        return self._predict(obj, pool, contention)[0]

    # -- solver ---------------------------------------------------------------
    def advise(self, objects: Sequence[MemObject],
               contention: ContentionSpec = ContentionSpec(),
               capacities: Optional[Dict[str, int]] = None) -> PlacementPlan:
        caps = dict(capacities) if capacities is not None else {
            p: self.platform.memories[p].size_bytes
            for p in self.pools if p in self.platform.memories}

        costs: Dict[str, Dict[str, float]] = {}
        extrap: Dict[str, Dict[str, bool]] = {}
        for obj in objects:
            costs[obj.name] = {}
            extrap[obj.name] = {}
            for p in self.pools:
                if p not in caps:
                    continue
                t, ex = self._predict(obj, p, contention)
                costs[obj.name][p] = t
                extrap[obj.name][p] = ex
            if not costs[obj.name] and obj.pinned_pool is None:
                raise RuntimeError(
                    f"no candidate pools for {obj.name!r}: advisor pools "
                    f"{self.pools} and capacity pools {sorted(caps)} "
                    f"have no common member")

        # pinned objects first
        plan = PlacementPlan()
        todo = []
        for obj in objects:
            if obj.pinned_pool is not None:
                p = obj.pinned_pool
                caps[p] = caps.get(p, 0) - obj.size_bytes
                plan.decisions[obj.name] = PlacementDecision(
                    p, costs[obj.name].get(p, 0.0), costs[obj.name],
                    extrapolated=extrap[obj.name].get(p, False))
            else:
                todo.append(obj)

        # greedy by regret: the object that loses most from a bad pool
        # gets first pick
        def regret(obj: MemObject) -> float:
            c = sorted(costs[obj.name].values())
            return (c[1] - c[0]) if len(c) > 1 else c[0]

        for obj in sorted(todo, key=regret, reverse=True):
            ranked = sorted(costs[obj.name].items(), key=lambda kv: kv[1])
            placed = False
            for pool, t in ranked:
                if caps.get(pool, 0) >= obj.size_bytes:
                    caps[pool] -= obj.size_bytes
                    ex = extrap[obj.name][pool]
                    if ex:
                        log.warning(
                            "placement of %r in %r relies on an "
                            "EXTRAPOLATED surface query (contention %r "
                            "beyond the characterized grid)",
                            obj.name, pool, contention)
                    plan.decisions[obj.name] = PlacementDecision(
                        pool, t, costs[obj.name], extrapolated=ex)
                    placed = True
                    break
            if not placed:
                raise RuntimeError(
                    f"object {obj.name} ({obj.size_bytes}B) fits no pool "
                    f"(free: { {p: c for p, c in caps.items()} })")
        return plan

    # -- the online re-advise (migration-guarded serving path) ---------------
    def readvise(self, objects: Sequence[MemObject],
                 contention: ContentionSpec,
                 current: Dict[str, str], *,
                 capacities: Optional[Dict[str, int]] = None,
                 min_gain_frac: float = 0.1) -> "ReadviseDecision":
        """Re-run the placement solve against the CURRENT placement
        with hysteresis: an object only *moves* when the fresh plan
        puts it elsewhere AND the predicted per-step gain of the move
        is at least ``min_gain_frac`` of its current predicted cost.
        Marginal flips are ``held`` (with the reason), so surface noise
        around a decision boundary cannot flap live caches between
        pools.  The solver itself is unchanged — this is a pure
        post-filter over :meth:`advise`."""
        plan = self.advise(objects, contention, capacities)
        moves: Dict[str, Tuple[str, str]] = {}
        held: Dict[str, str] = {}
        gain_ns = 0.0
        cur_total = 0.0
        for obj in objects:
            d = plan.decisions[obj.name]
            cur = current.get(obj.name)
            if cur is None:
                continue            # not currently placed: nothing to move
            cur_cost = d.alternatives.get(cur)
            if cur_cost is None:
                # current pool wasn't even a candidate (capacity lost?):
                # that is a forced move, not a hysteresis question
                moves[obj.name] = (cur, d.pool)
                continue
            cur_total += cur_cost
            if d.pool == cur:
                continue
            gain = cur_cost - d.predicted_step_ns
            frac = gain / max(cur_cost, 1e-9)
            if frac < min_gain_frac:
                held[obj.name] = (
                    f"predicted gain {frac:.1%} below the "
                    f"{min_gain_frac:.0%} hysteresis floor "
                    f"({cur} {cur_cost:.0f}ns -> {d.pool} "
                    f"{d.predicted_step_ns:.0f}ns)")
                continue
            moves[obj.name] = (cur, d.pool)
            gain_ns += gain
        return ReadviseDecision(
            plan=plan, moves=moves, held=held,
            predicted_gain_ns=gain_ns,
            predicted_gain_frac=gain_ns / max(cur_total, 1e-9))


@dataclass
class ReadviseDecision:
    """The hysteresis-filtered outcome of one re-advise pass."""
    plan: PlacementPlan
    moves: Dict[str, Tuple[str, str]]   # name -> (from_pool, to_pool)
    held: Dict[str, str]                # name -> why the flip was held
    predicted_gain_ns: float
    predicted_gain_frac: float


# ---------------------------------------------------------------------------
# Framework object profiles (what serve/train hand to the advisor)
# ---------------------------------------------------------------------------


def kv_cache_object(name: str, size_bytes: int,
                    bytes_read_per_token: float) -> MemObject:
    """Decode reads the whole cache once per generated token."""
    return MemObject(name=name, size_bytes=size_bytes,
                     bytes_per_step=bytes_read_per_token)


def optimizer_state_object(name: str, size_bytes: int) -> MemObject:
    """Touched exactly once per step (streamed read+write)."""
    return MemObject(name=name, size_bytes=size_bytes,
                     bytes_per_step=2.0 * size_bytes)


def params_object(name: str, size_bytes: int,
                  reads_per_step: float = 1.0) -> MemObject:
    return MemObject(name=name, size_bytes=size_bytes,
                     bytes_per_step=reads_per_step * size_bytes)
