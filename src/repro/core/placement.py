"""Placement Advisor — characterization-driven memory management.

The upool payoff (paper §IV-E): once the curves are known, framework
objects are *deliberately* placed across heterogeneous memories — and the
right answer is often counter-intuitive (Fig. 14: allocate the victim's
heap in the module the stressors are NOT hammering... which can be the
nominally slower one).

The advisor solves a small assignment problem: given
  * memory objects (size, bytes moved per step, latency sensitivity),
  * candidate pools with capacities,
  * an expected contention level (stressor count + their target pool),
it minimises the predicted per-step time

    t(obj, pool) = traffic_bytes / eff_bw(pool | contention)
                 + lat_weight * eff_lat(pool | contention) * dependent_accesses

greedily by "regret density" (largest time delta between best and
second-best pool per byte first), respecting capacities.

Framework integration: ``repro.serve.engine`` asks the advisor where the
KV cache goes (HBM vs. host, under decode-time contention); the train
loop asks where optimizer state lives (ZeRO-offload decision).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.characterize import CurveDB
from repro.core.devicetree import Platform


@dataclass(frozen=True)
class MemObject:
    """One placeable framework object."""
    name: str
    size_bytes: int
    bytes_per_step: float          # streaming traffic it generates
    dependent_accesses: float = 0.0  # serialized (latency-bound) accesses
    pinned_pool: Optional[str] = None  # force placement (escape hatch)


@dataclass(frozen=True)
class ContentionSpec:
    """Expected background load while this application runs.

    ``stress_shape_tag`` selects a shaped curve from a CurveDB v2
    (e.g. ``"rf0.50"`` for a 1:1 read/write mix, ``"dc0.50"`` for a
    50%-duty burst — see ``TrafficShape.tag()``); the lookup falls
    back to the steady curve when the shaped one was not characterized.
    """
    n_stressors: int = 0
    stress_pool: str = "hbm"
    stress_strategy: str = "w"
    stress_shape_tag: str = ""

    @staticmethod
    def shaped(n_stressors: int, stress_pool: str, stress_strategy: str,
               shape) -> "ContentionSpec":
        """Build from a :class:`repro.core.scenarios.TrafficShape`."""
        return ContentionSpec(n_stressors, stress_pool, stress_strategy,
                              stress_shape_tag=shape.tag())


@dataclass
class PlacementDecision:
    pool: str
    predicted_step_ns: float
    alternatives: Dict[str, float] = field(default_factory=dict)


@dataclass
class PlacementPlan:
    decisions: Dict[str, PlacementDecision] = field(default_factory=dict)

    def pool_of(self, name: str) -> str:
        return self.decisions[name].pool

    def total_predicted_ns(self) -> float:
        return sum(d.predicted_step_ns for d in self.decisions.values())

    def report(self) -> str:
        lines = ["object              pool     t_pred(us)   alternatives"]
        for name, d in self.decisions.items():
            alts = " ".join(f"{p}:{t / 1e3:.1f}" for p, t in
                            sorted(d.alternatives.items()))
            lines.append(f"{name:19s} {d.pool:8s} "
                         f"{d.predicted_step_ns / 1e3:10.1f}   {alts}")
        return "\n".join(lines)


class PlacementAdvisor:
    def __init__(self, db: CurveDB, platform: Platform,
                 pools: Optional[Sequence[str]] = None):
        self.db = db
        self.platform = platform
        self.pools = list(pools) if pools is not None else sorted(
            {k.split(":")[0] for k in db.curves})

    # -- cost model ---------------------------------------------------------
    def predict_ns(self, obj: MemObject, pool: str,
                   contention: ContentionSpec) -> float:
        bw = self.db.effective_bw(
            pool, contention.n_stressors,
            stress_pool=contention.stress_pool,
            stress_strat=contention.stress_strategy,
            shape_tag=contention.stress_shape_tag)
        lat = self.db.effective_lat(
            pool, contention.n_stressors,
            stress_pool=contention.stress_pool,
            stress_strat=contention.stress_strategy,
            shape_tag=contention.stress_shape_tag)
        stream_ns = obj.bytes_per_step / max(bw, 1e-9)
        lat_ns = obj.dependent_accesses * lat
        return stream_ns + lat_ns

    # -- solver ---------------------------------------------------------------
    def advise(self, objects: Sequence[MemObject],
               contention: ContentionSpec = ContentionSpec(),
               capacities: Optional[Dict[str, int]] = None) -> PlacementPlan:
        caps = dict(capacities) if capacities is not None else {
            p: self.platform.memories[p].size_bytes
            for p in self.pools if p in self.platform.memories}

        costs: Dict[str, Dict[str, float]] = {}
        for obj in objects:
            costs[obj.name] = {
                p: self.predict_ns(obj, p, contention)
                for p in self.pools if p in caps}

        # pinned objects first
        plan = PlacementPlan()
        todo = []
        for obj in objects:
            if obj.pinned_pool is not None:
                p = obj.pinned_pool
                caps[p] = caps.get(p, 0) - obj.size_bytes
                plan.decisions[obj.name] = PlacementDecision(
                    p, costs[obj.name].get(p, 0.0), costs[obj.name])
            else:
                todo.append(obj)

        # greedy by regret: the object that loses most from a bad pool
        # gets first pick
        def regret(obj: MemObject) -> float:
            c = sorted(costs[obj.name].values())
            return (c[1] - c[0]) if len(c) > 1 else c[0]

        for obj in sorted(todo, key=regret, reverse=True):
            ranked = sorted(costs[obj.name].items(), key=lambda kv: kv[1])
            placed = False
            for pool, t in ranked:
                if caps.get(pool, 0) >= obj.size_bytes:
                    caps[pool] -= obj.size_bytes
                    plan.decisions[obj.name] = PlacementDecision(
                        pool, t, costs[obj.name])
                    placed = True
                    break
            if not placed:
                raise RuntimeError(
                    f"object {obj.name} ({obj.size_bytes}B) fits no pool "
                    f"(free: { {p: c for p, c in caps.items()} })")
        return plan


# ---------------------------------------------------------------------------
# Framework object profiles (what serve/train hand to the advisor)
# ---------------------------------------------------------------------------


def kv_cache_object(name: str, size_bytes: int,
                    bytes_read_per_token: float) -> MemObject:
    """Decode reads the whole cache once per generated token."""
    return MemObject(name=name, size_bytes=size_bytes,
                     bytes_per_step=bytes_read_per_token)


def optimizer_state_object(name: str, size_bytes: int) -> MemObject:
    """Touched exactly once per step (streamed read+write)."""
    return MemObject(name=name, size_bytes=size_bytes,
                     bytes_per_step=2.0 * size_bytes)


def params_object(name: str, size_bytes: int,
                  reads_per_step: float = 1.0) -> MemObject:
    return MemObject(name=name, size_bytes=size_bytes,
                     bytes_per_step=reads_per_step * size_bytes)
