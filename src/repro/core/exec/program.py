"""program — stage 2 of the spmd execution pipeline.

Turns a :class:`~repro.core.exec.plan.PlannedDispatch` into a traced,
fence-verified, operand-placed :class:`CompiledProgram`: the per-engine
branch activities (Pallas kernel library or pure-jnp traffic loops),
the operand arrays, the fused SPMD program builders, and
:func:`build_ladder_entry` tying them together (trace once, feed the
same jaxpr to the structural fence walk and the AOT compile).

The psum sandwich invariants (module docstring of
:mod:`repro.core.coordinator`) are enforced here; width-packed
dispatches replace the global all-reduce with grouped collectives
(``compat.psum_grouped``) so each engine subset keeps its OWN sandwich.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.exec.fence import measured_region_is_fenced
from repro.core.exec.plan import (PlannedDispatch, effective_duty,
                                  merge_probe_operand_roles)
from repro.core.workloads import LINE_BYTES, resolve_strategy

_SPMD_CHASES = ("l", "m", "t")      # latency walks: dependent gathers
_SPMD_STREAM_2X = ("c", "x")        # copy/rmw touch two lines per line


def build_rung_operands(roles, n_eng: int,
                        rows_max: int) -> Tuple[np.ndarray, np.ndarray]:
    """Per-engine operands for one SPMD program: a float stream buffer
    and an int chase chain (seeded by engine index), padded to the
    widest role.  Operands are fully determined by the role layout, so
    cached programs can reuse their placed arrays verbatim."""
    from repro.kernels import ops as kops

    xf = np.broadcast_to(
        np.arange(rows_max * LINE_BYTES // 4, dtype=np.float32)
        .reshape(rows_max, LINE_BYTES // 4),
        (n_eng, rows_max, LINE_BYTES // 4)).copy()
    xi = np.zeros((n_eng, rows_max, LINE_BYTES // 4), np.int32)
    for e, (strategy, shape, rows, _ri) in enumerate(roles):
        if resolve_strategy(strategy, shape) in _SPMD_CHASES:
            if resolve_strategy(strategy, shape) == "t":
                chain = kops.strided_chain_buffer(
                    rows, getattr(shape, "stride", 8) or 8)
            else:
                chain = kops.chain_buffer(rows, seed=e)
            xi[e, :rows, :chain.shape[1]] = chain
    return xf, xi


def spmd_branch_fn(strategy: str, shape, rows: int, iters: int,
                   activity: str = "jnp"):
    """Per-engine activity for one SPMD rung: ``(xf, xi) -> f32``.

    All branches take the SAME operand pair and return a scalar so
    ``lax.switch`` can fuse them; each closes over its own static row
    count and iteration budget.  Loop bodies either carry the buffer or
    re-issue it through ``optimization_barrier`` so XLA cannot hoist
    the memory traffic out of the loop.

    ``activity="pallas"`` builds the branch from the real kernel
    library (:mod:`repro.kernels.stream` / ``chase``: mixed-stream,
    copy, seeded write streams, strided/Sattolo chases — compiled on
    TPU, interpret-mode elsewhere); ``"jnp"`` is the pure-jnp traffic
    loop fallback for hosts where Pallas is unavailable
    (``compat.pallas_supported``)."""
    from repro import compat

    strat = resolve_strategy(strategy, shape)
    n = max(1, int(round(iters * effective_duty(shape))))

    if activity == "pallas" and strategy != "i":
        return _pallas_branch_fn(strat, shape, rows, n)

    if strategy == "i":
        def idle(xf, xi):
            def body(_, acc):
                return acc * 0.999 + 1.0
            # seeded from the fenced operand: even idle engines enter
            # their spin only after the start barrier
            return jax.lax.fori_loop(0, n * 8, body, xf[0, 0] * 1e-30)
        return idle

    if strat in _SPMD_CHASES:
        def chase(xf, xi):
            chain = xi[:rows, 0]

            def step(_, idx):
                return chain[idx]

            def cycle(_, carry):
                idx, acc = carry
                idx = jax.lax.fori_loop(0, rows, step, idx)
                return idx, acc + idx.astype(jnp.float32)

            _, acc = jax.lax.fori_loop(
                0, n, cycle, (jnp.int32(0), jnp.float32(0.0)))
            return acc
        return chase

    if strat in ("w", "y"):
        def write(xf, xi):
            def body(_, x):
                return x + 1.0
            x = jax.lax.fori_loop(0, n, body, xf[:rows])
            return x[0, 0]
        return write

    if strat in ("c", "x", "b"):
        def readwrite(xf, xi):
            def body(_, x):
                return x * 1.0000001 + 0.25
            x = jax.lax.fori_loop(0, n, body, xf[:rows])
            return x[0, 0]
        return readwrite

    def read(xf, xi):
        x = xf[:rows]

        def body(_, acc):
            # re-issued buffer: barrier pins reads inside the loop
            xx = compat.optimization_barrier(x)
            return acc * 0.5 + jnp.sum(xx)

        return jax.lax.fori_loop(0, n, body, jnp.float32(0.0))
    return read


def _pallas_branch_fn(strat: str, shape, rows: int, n: int):
    """Pallas-kernel edition of one rung activity (resolved strategy
    letter ``strat``, ``n`` active passes): the branch's memory traffic
    is the real kernel library, not a jnp stand-in.  Every branch keeps
    a dataflow edge from its (barrier-fenced) operands into each
    kernel call — carried loop state where the kernel's output feeds
    the next pass (copy/rmw/seeded write), ``optimization_barrier``
    re-issue where it cannot (reads, mixed streams, chases) — so the
    extended jaxpr fence check can verify every ``pallas_call``
    consumes fenced data."""
    from repro import compat
    from repro.kernels import chase as _kchase
    from repro.kernels import ops as kops
    from repro.kernels import stream as _kstream
    from repro.core.workloads import _fits_vmem

    interp = not kops.on_tpu()
    blk = min(512, rows)

    if strat in _SPMD_CHASES:
        vmem = strat == "l" and _fits_vmem(rows * LINE_BYTES)
        kern = _kchase.chase_vmem if vmem else _kchase.chase_hbm

        def chase(xf, xi):
            buf = xi[:rows]

            def cycle(_, acc):
                # re-issued buffer: one dependent full traversal per
                # pass, not hoistable/CSE-able across passes
                bb = compat.optimization_barrier(buf)
                idx = kern(bb, n_steps=rows, interpret=interp)
                return acc + idx.astype(jnp.float32)

            return jax.lax.fori_loop(0, n, cycle, jnp.float32(0.0))
        return chase

    if strat == "y":
        def write_stream(xf, xi):
            def body(_, acc):
                # the seed depends on the previous pass, serialising
                # the passes; the kernel's stores depend on the seed
                seed = xf[:1, :1] + acc * 1e-30
                out = _kstream.write_hbm_seeded(
                    seed, rows, block_rows=blk, interpret=interp)
                return acc * 0.5 + out[0, 0]

            return jax.lax.fori_loop(0, n, body, jnp.float32(0.0))
        return write_stream

    if strat in ("w", "x"):
        def rmw(xf, xi):
            def body(_, x):
                # write-allocate: read + write back, carried so pass
                # t+1 depends on pass t's stores — deliberate for 'w'
                # too (a cacheable write allocates the line).  Useful-
                # bytes stays the registry's convention: 'w' counts
                # written lines (1x), 'x' both (2x, _SPMD_STREAM_2X).
                return _kstream.rmw_hbm(x, block_rows=blk,
                                        interpret=interp)

            x = jax.lax.fori_loop(0, n, body, xf[:rows])
            return x[0, 0]
        return rmw

    if strat == "c":
        def copy(xf, xi):
            def body(_, x):
                return _kstream.copy_hbm(x, block_rows=blk,
                                         interpret=interp)

            x = jax.lax.fori_loop(0, n, body, xf[:rows])
            return x[0, 0]
        return copy

    if strat == "b":
        rf = (shape.read_fraction
              if getattr(shape, "kind", None) == "mixed" else 0.5)

        def mixed(xf, xi):
            x = xf[:rows]

            def body(_, acc):
                xx = compat.optimization_barrier(x)
                # the seed fences the write half of the mix (its store
                # kernel consumes no other operand)
                s, out = _kstream.mixed_hbm(
                    xx, read_fraction=rf, block_rows=blk,
                    interpret=interp, seed=xx[:1, :1])
                # consume one written row: keeps the store kernel live
                # under DCE without re-reading the whole destination
                return acc * 0.5 + s + jnp.sum(out[:1])

            return jax.lax.fori_loop(0, n, body, jnp.float32(0.0))
        return mixed

    def read(xf, xi):                   # r / s: pure read stream
        x = xf[:rows]

        def body(_, acc):
            xx = compat.optimization_barrier(x)
            return acc * 0.5 + _kstream.read_hbm(xx, block_rows=blk,
                                                 interpret=interp)

        return jax.lax.fori_loop(0, n, body, jnp.float32(0.0))
    return read


def build_rung_program(n_engines: int, branch_fns, engine_branch):
    """One fused SPMD rung over an ("engine",) mesh.

    Returns ``(mesh, f)`` with ``f(xf, xi) -> (per_engine_out, barrier)``
    jit-compiled: engine ``e`` runs ``branch_fns[engine_branch[e]]`` on
    its shard of the operands.  The measured region is *provably*
    sandwiched (invariants 1-4 of the coordinator docstring):

      start — every engine all-reduces a token derived from its live
          operand data (psum #1; a constant token would fold away at
          trace time), and the operands are re-issued through
          ``optimization_barrier`` together with that token, so every
          activity's operands carry a dataflow dependency on the
          collective: XLA cannot schedule measured work before the
          barrier completes;
      stop — the activity outputs are all-reduced (psum #2) into the
          returned barrier value, so the dispatch only retires after
          every engine's activity finished, and the next rung (a new
          dispatch) cannot begin until the host unblocks.

    :func:`measured_region_is_fenced` asserts the start edge
    structurally (jaxpr dataflow), which the tests pin down.
    """
    from jax.sharding import PartitionSpec as P

    from repro import compat

    devs = jax.devices()[:n_engines]
    mesh = compat.make_mesh_from_devices(devs, ("engine",))
    table = jnp.asarray(list(engine_branch), jnp.int32)

    def per_engine(xf, xi):
        xf, xi = xf[0], xi[0]
        # barrier #1: data-derived token, all-reduced into operands
        token = jax.lax.psum(xf[0, 0] + xi[0, 0].astype(xf.dtype),
                             "engine")
        xf, xi, token = compat.optimization_barrier((xf, xi, token))
        eng = jax.lax.axis_index("engine")
        out = jax.lax.switch(table[eng], branch_fns, xf, xi)
        # barrier #2: consumes every engine's finished activity.  (The
        # start token is alive through the operands' barrier edge; only
        # the stop psum — statically replicated — is returned.)
        done = jax.lax.psum(out, "engine")
        return out[None], done

    # check_rep=False: pallas_call has no replication rule, so Pallas
    # rungs cannot trace under the checker; the stop psum still
    # replicates `done` at runtime
    f = compat.shard_map(per_engine, mesh=mesh,
                         in_specs=(P("engine"), P("engine")),
                         out_specs=(P("engine"), P()),
                         check_rep=False)
    return mesh, jax.jit(f)


def _subset_layout(n_engines: int, subsets):
    """(psum groups, clock-leader mask) of a packed mesh: each declared
    subset is its own barrier group with its first engine stamping the
    clock; leftover engines form one extra group (``axis_index_groups``
    must partition the whole axis) whose idle spin barriers only with
    itself.  Unpacked programs get ``groups=None`` (global psum) and
    engine 0 as the only leader — the same program text serves both."""
    if not subsets:
        leaders = np.zeros(n_engines, np.int32)
        leaders[0] = 1
        return None, leaders
    groups = [tuple(int(i) for i in s) for s in subsets]
    members = {i for g in groups for i in g}
    leftover = tuple(i for i in range(n_engines) if i not in members)
    if leftover:
        groups.append(leftover)
    leaders = np.zeros(n_engines, np.int32)
    for s in subsets:
        leaders[int(s[0])] = 1
    return tuple(groups), leaders


def build_ladder_program(n_engines: int, branch_fns, branch_table,
                         samples: int = 3, donate: bool = False,
                         subsets=None):
    """The WHOLE contention ladder as one fused SPMD dispatch.

    ``branch_table`` is a (K, n_engines) int table: scan step for rung
    ``k`` runs ``branch_fns[branch_table[k][e]]`` on engine ``e``'s
    shard.  Each rung is repeated ``samples`` times, and EVERY repeat
    is its own psum sandwich — the scanned edition of
    :func:`build_rung_program`'s spin-lock-sandwich invariants:

      start — every sample's token psum is derived from live operand
          data AND the loop carry (a loop-invariant psum would be
          hoisted out of the scan), and the operands are re-issued with
          an exact-zero contribution from the start timestamp, so no
          engine's measured work can begin before the barrier completed
          and the stamp's buffer was actually filled;
      stop — the activity outputs are all-reduced (psum #2) and the
          carry value-consumes the stop timestamp, so sample s+1's
          start barrier cannot open until sample s fully retired —
          invariant 4, enforced in-dispatch by dataflow instead of a
          host round-trip per rung.

    ``subsets`` width-packs the dispatch: both psums become grouped
    collectives (``compat.psum_grouped``) with one group per declared
    engine subset, so each subset runs an INDEPENDENT sandwich — the
    ladders packed side by side neither wait for each other's barriers
    nor observe each other's stamps — and each subset's first engine
    stamps its own clock pairs.  Unpacked programs (``subsets=None``)
    keep the global psum and engine-0 clock: the degenerate one-subset
    geometry.

    Per-rung elapsed time comes from ``compat.device_clock`` stamp
    pairs taken inside the dispatch (each leader's stop stamp follows
    its group's stop psum, i.e. its SLOWEST engine's finish), returned
    as ``(n_eng, K*samples, 2)`` int32 ``[s, ns]`` arrays alongside the
    per-engine activity outputs.  Returns ``(mesh, fn)`` with
    ``fn(xf, xi) -> (outs, t0s, t1s, xf, xi)``; the operands are
    passed through (and donated when ``donate=True``) so callers can
    cache and rebind them without any host->device re-transfer."""
    from jax.sharding import PartitionSpec as P

    from repro import compat

    devs = jax.devices()[:n_engines]
    mesh = compat.make_mesh_from_devices(devs, ("engine",))
    table = np.repeat(np.asarray(branch_table, np.int32),
                      int(samples), axis=0)
    table_j = jnp.asarray(table)
    groups, leader_mask = _subset_layout(n_engines, subsets)
    leaders_j = jnp.asarray(leader_mask)

    def per_engine(xf, xi):
        xf, xi = xf[0], xi[0]
        eng = jax.lax.axis_index("engine")

        def clock(dep):
            # only each subset's LEADER engine pays the stamp cost
            # (callback stamps are host round-trips); its siblings
            # still serialize on it via the carry -> token psum below
            return jax.lax.cond(leaders_j[eng] == 1,
                                compat.device_clock,
                                lambda _d: jnp.zeros((2,), jnp.int32),
                                dep)

        def step(carry, row):
            # barrier #1: data-derived, carry-dependent, reduced over
            # this engine's subset (globally when unpacked)
            token = compat.psum_grouped(
                xf[0, 0] + xi[0, 0].astype(xf.dtype) + carry * 1e-30,
                "engine", groups)
            t0 = clock(token)
            # thread the start stamp into every operand as an EXACT
            # zero: min(t, 0) == 0 at runtime (monotonic clock parts
            # are non-negative) but XLA cannot fold it away — the
            # activity cannot start until the stamp exists.  A
            # scheduling-only edge is not enough: the callback
            # fallback fills its result buffer asynchronously.
            z = jnp.minimum(t0[0] + t0[1], 0)
            xf_, xi_, _tok = compat.optimization_barrier(
                (xf + z.astype(xf.dtype), xi + z, token))
            out = jax.lax.switch(row[eng], branch_fns, xf_, xi_)
            # barrier #2: consumes every subset engine's finished
            # activity
            done = compat.psum_grouped(out, "engine", groups)
            t1 = clock(done)
            # the carry value-consumes the stop stamp: the next
            # sample's start barrier waits for this one to retire
            carry = (done * 1e-30
                     + jnp.minimum(t1[0] + t1[1], 0).astype(xf.dtype))
            return carry, (out, t0, t1)

        _c, (outs, t0s, t1s) = jax.lax.scan(step, jnp.float32(0.0),
                                            table_j)
        return outs[None], t0s[None], t1s[None], xf[None], xi[None]

    f = compat.shard_map(per_engine, mesh=mesh,
                         in_specs=(P("engine"), P("engine")),
                         out_specs=(P("engine", None),
                                    P("engine", None, None),
                                    P("engine", None, None),
                                    P("engine"), P("engine")),
                         check_rep=False)
    kw = {"donate_argnums": (0, 1)} if donate else {}
    return mesh, jax.jit(f, **kw)


def build_scenario_program(n_engines: int, n_stressors: int,
                           main_fn, stress_fn, idle_fn):
    """Returns f(main_x, stress_x) -> (main_out, barrier) running under
    ``shard_map`` over an ("engine",) mesh: engine 0 = observed, engines
    1..n_stressors = stress, rest idle.  The measured region is fenced by
    two psum barriers (invariants 1-4 above) — and the fence is
    dataflow-enforced: the start psum is derived from live operand data
    and re-issued into the operands via ``optimization_barrier``, so
    the activities cannot be hoisted above it (the historical version
    computed a psum nothing depended on, which JAX folds away at trace
    time — invariant 1 was unenforced)."""
    from jax.sharding import PartitionSpec as P

    from repro import compat

    devs = jax.devices()[:n_engines]
    mesh = compat.make_mesh_from_devices(devs, ("engine",))

    def per_engine(main_x, stress_x):
        eng = jax.lax.axis_index("engine")
        # barrier #1: every engine signals ready before measurement
        # starts, and the measured operands depend on the collective
        seed = (jnp.ravel(main_x)[0].astype(jnp.float32)
                + jnp.ravel(stress_x)[0].astype(jnp.float32))
        ready = jax.lax.psum(seed, "engine")
        main_x, stress_x, ready = compat.optimization_barrier(
            (main_x, stress_x, ready))

        def run_main(m, _s):
            return main_fn(m)

        def run_stress(_m, s):
            return stress_fn(s)

        def run_idle(_m, s):
            return idle_fn(s)

        branch = jnp.where(eng == 0, 0,
                           jnp.where(eng <= n_stressors, 1, 2))
        # operands positional: the `operand=` kwarg is lint-rejected
        # deprecated drift (tests/test_compat.py)
        out = jax.lax.switch(branch, [run_main, run_stress, run_idle],
                             main_x, stress_x)
        # barrier #2: `done` consumes every engine's finished activity
        # output; only the statically-replicated stop psum is returned
        # (`ready` stays alive through the operand barrier edge)
        done = jax.lax.psum(jnp.ravel(out)[0].astype(jnp.float32),
                            "engine")
        return out, done

    f = compat.shard_map(per_engine, mesh=mesh,
                         in_specs=(P("engine"), P("engine")),
                         out_specs=(P("engine"), P()))
    return mesh, f


# ---------------------------------------------------------------------------
# Built programs
# ---------------------------------------------------------------------------


class CompiledProgram:
    """One built ladder program with its placed operands — the cache
    entry the dispatcher runs.  Kept list-indexable (``entry[3]``,
    ``entry[3:5]``, item assignment) because the LRU treats entries
    generically: eviction deletes the operand buffers by position, and
    donated dispatches rebind them in place."""

    _FIELDS = ("mesh", "call", "fenced", "xf", "xi", "aot")
    __slots__ = _FIELDS

    def __init__(self, mesh, call, fenced, xf, xi, aot):
        self.mesh = mesh
        self.call = call
        self.fenced = fenced
        self.xf = xf
        self.xi = xi
        self.aot = aot

    def __len__(self) -> int:
        return len(self._FIELDS)

    def __iter__(self):
        return (getattr(self, f) for f in self._FIELDS)

    def __getitem__(self, i):
        if isinstance(i, slice):
            return [getattr(self, f) for f in self._FIELDS[i]]
        return getattr(self, self._FIELDS[i])

    def __setitem__(self, i, value):
        setattr(self, self._FIELDS[i], value)


def build_ladder_entry(planned: PlannedDispatch, n_eng: int,
                       activity: str, samples: int,
                       stats) -> CompiledProgram:
    """Build, fence-verify, place and (where the installed JAX allows)
    AOT-compile one planned dispatch's fused ladder program.

    The planned rung table is expanded to the full mesh: width-packed
    dispatches tile the subset-width roles across ``n_subsets``
    disjoint engine slices (leftover engines idle in their own barrier
    group) and scan-stack ``waves`` repeats; unpacked group dispatches
    reduce to the leading-scenario-axis stacking (one wave per
    ladder).  Probe batches (``planned.probe``) carry their scan rows
    verbatim — already at full packed width, one heterogeneous row per
    step, no tiling — and seed operands from the MERGED role layout so
    one operand set serves every row (``merge_probe_operand_roles``).
    The program is traced exactly ONCE (``compat.aot_trace``):
    the same trace feeds the structural fence walk — packed dispatches
    pass their subsets so EVERY subset's sandwich is verified
    independently — and ``lower().compile()``."""
    from repro import compat

    idle_iters = planned.rungs[0][0][3]
    full_rungs = []
    for roles in planned.rungs:
        row = (list(roles) if planned.probe
               else list(roles) * planned.n_subsets)
        while len(row) < n_eng:
            row.append(("i", None, 1, idle_iters))
        full_rungs.append(tuple(row))

    if planned.probe:
        op_roles = merge_probe_operand_roles(full_rungs)
        rows_max = max(r[2] for row in full_rungs for r in row)
    else:
        op_roles = full_rungs[-1]
        rows_max = max(r[2] for r in op_roles)
    xf, xi = build_rung_operands(op_roles, n_eng, rows_max)
    branch_fns: List = []
    branch_of: Dict[Tuple, int] = {}
    table = np.zeros((len(full_rungs), n_eng), np.int32)
    for k, roles in enumerate(full_rungs):
        for e, sig in enumerate(roles):
            if sig not in branch_of:
                branch_of[sig] = len(branch_fns)
                branch_fns.append(spmd_branch_fn(
                    *sig, activity=activity))
            table[k, e] = branch_of[sig]
    if planned.waves > 1 and not planned.probe:
        # the leading scenario axis: wave w's rungs are scan steps
        # [w*K, (w+1)*K) — every stacked rung keeps its own psum
        # sandwich and stamp pair, and the scan carry serializes wave
        # w+1 behind wave w exactly like rung k+1 behind rung k
        # (invariant 4, across the whole group).  Probe batches list
        # every wave's row explicitly, so their table stacks as-is.
        table = np.tile(table, (planned.waves, 1))
    subsets = planned.subsets()
    mesh, fn = build_ladder_program(
        n_eng, branch_fns, table, samples=samples,
        donate=compat.donation_supported(), subsets=subsets)
    # commit the operands onto the mesh BEFORE tracing: the AOT
    # executable is specialized to the placed shardings, and the
    # fence walk sees the same program the dispatch runs
    from jax.sharding import PartitionSpec as P
    sharding = compat.named_sharding(mesh, P("engine"), planned.kind)
    xf = jax.device_put(xf, sharding)
    xi = jax.device_put(xi, sharding)
    jax.block_until_ready((xf, xi))
    traced = compat.aot_trace(fn, xf, xi)
    # provenance records the VERIFIED fence state of every scanned
    # rung of every stacked ladder — including, for packed programs,
    # per-subset isolation of every psum sandwich — not an assertion
    # (compat degradation is honestly reported as unfenced)
    fenced = measured_region_is_fenced(
        fn, xf, xi, jaxpr=getattr(traced, "jaxpr", None),
        subsets=subsets)
    compiled = compat.aot_compile(fn, xf, xi, traced=traced)
    stats.programs_built += 1
    if compiled is not None:
        stats.aot_compiles += 1
    return CompiledProgram(mesh, compiled if compiled is not None
                           else fn, fenced, xf, xi,
                           compiled is not None)
