"""assemble — stage 4 of the spmd execution pipeline.

Folds the dispatch results back into user-facing structures: per-rung
:class:`ScenarioResult`s, per-ladder :class:`ScenarioRun`s with their
``execution`` provenance dict (backend, executed-vs-modeled rungs,
fence state, timing source, width-packing slot), and the
:class:`MatrixResult` that ``run_matrix`` returns.  The observer
measurement stamping (:func:`observer_result`) lives here too: it is
the boundary where raw elapsed nanoseconds become WorkloadResults.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from repro.core.exec.dispatch import DispatchStats
from repro.core.exec.plan import effective_duty
from repro.core.exec.program import _SPMD_CHASES, _SPMD_STREAM_2X
from repro.core.scenarios import ObserverSpec, ScenarioSpec
from repro.core.workloads import (LINE_BYTES, WorkloadResult,
                                  resolve_strategy, rows_for as _wl_rows)


@dataclass
class ScenarioResult:
    n_stressors: int
    main: WorkloadResult
    modeled_bw_gbps: float = 0.0
    modeled_lat_ns: float = 0.0
    stress_bw_gbps: float = 0.0
    # where this rung's curve value comes from: "modeled" (queueing
    # network; `main` is at most an uncontended measurement) or
    # "executed" (`main` IS the observer measured under n_stressors
    # live stress engines — the spmd backend)
    source: str = "modeled"


@dataclass
class ScenarioRun:
    """One (scenario, observer, buffer) ladder."""
    spec: ScenarioSpec
    buffer_bytes: int
    key: str
    observer: Optional[ObserverSpec] = None   # which observer this curve is
    scenarios: List[ScenarioResult] = field(default_factory=list)
    # executed-vs-modeled provenance, persisted into CurveDB v2:
    # {"backend", "executed_rungs", "modeled_rungs", ...}
    execution: Dict[str, Any] = field(default_factory=dict)

    def bandwidth_curve(self) -> List[Tuple[int, float]]:
        return [(s.n_stressors,
                 s.main.bandwidth_gbps if s.source == "executed"
                 else (s.modeled_bw_gbps or s.main.bandwidth_gbps))
                for s in self.scenarios]

    def latency_curve(self) -> List[Tuple[int, float]]:
        return [(s.n_stressors,
                 s.main.latency_ns if s.source == "executed"
                 else (s.modeled_lat_ns or s.main.latency_ns))
                for s in self.scenarios]


@dataclass
class MatrixResult:
    runs: List[ScenarioRun] = field(default_factory=list)
    stats: DispatchStats = field(default_factory=DispatchStats)


def observer_result(obs: ObserverSpec, buf: int, iters: int,
                    elapsed: float) -> WorkloadResult:
    """Stamp one executed rung's observer measurement.  Uses the
    RESOLVED strategy letter, like the interpret-path group
    measurement does: the executed branch for a mixed 'r' observer
    is the 'b' loop, and provenance must say so."""
    obs_rows = _wl_rows(buf)
    strat = resolve_strategy(obs.strategy, obs.shape)
    n_active = max(1, int(round(iters * effective_duty(obs.shape))))
    if strat in _SPMD_CHASES:
        # elapsed spans n_active full traversals: bytes and
        # transactions both scale with it (latency = elapsed/tx)
        return WorkloadResult(strat, obs.pool, buf, iters,
                              obs_rows * LINE_BYTES * n_active,
                              elapsed,
                              transactions=obs_rows * n_active)
    mult = 2 if strat in _SPMD_STREAM_2X else 1
    return WorkloadResult(strat, obs.pool, buf, iters,
                          mult * obs_rows * LINE_BYTES * n_active,
                          elapsed, 0)


def assemble_runs(triples, *, backend: str, activity: str,
                  stats: DispatchStats, depth_fn, model_fn,
                  measured: Dict[int, WorkloadResult],
                  executed: Dict[Tuple[int, int], WorkloadResult],
                  fenced_by_triple: Dict[int, bool],
                  timing_by_triple: Dict[int, Dict[str, Any]],
                  n_engines: Optional[int] = None,
                  operand_kinds_fn=None) -> List[ScenarioRun]:
    """Stage 4: (per-triple measurements, per-rung executions, fence +
    timing provenance) -> the per-ladder ScenarioRuns ``run_matrix``
    returns.  ``depth_fn(spec)`` gives the ladder depth,
    ``model_fn(spec, obs, buf, k)`` the queueing-network rung
    prediction (counted into ``stats.model_evals`` here), and — on the
    spmd backend — ``operand_kinds_fn(spec, obs)`` the sorted operand
    memory kinds for the provenance dict."""
    runs: List[ScenarioRun] = []
    for i, (spec, obs, buf) in enumerate(triples):
        n_scen = depth_fn(spec)
        scenarios = []
        exec_rungs = []
        for k in range(n_scen):
            bw, lat, sbw = model_fn(spec, obs, buf, k)
            stats.model_evals += 1
            ex = executed.get((i, k))
            main_res = ex if ex is not None else (
                measured.get(i) or WorkloadResult(
                    obs.strategy, obs.pool, buf, spec.iters, 0, 0.0,
                    0))
            if ex is not None:
                exec_rungs.append(k)
            scenarios.append(ScenarioResult(
                n_stressors=k, main=main_res, modeled_bw_gbps=bw,
                modeled_lat_ns=lat, stress_bw_gbps=sbw,
                source="executed" if ex is not None else "modeled"))
        execution = {
            "backend": backend,
            "executed_rungs": exec_rungs,
            "modeled_rungs": [k for k in range(n_scen)
                              if k not in exec_rungs],
            "measured_uncontended": i in measured,
            # whether this curve's siblings were part of its
            # measured region / queueing network (effective
            # coupling: a single-observer spec couples nothing)
            "coupled": bool(spec.coupled and len(spec.observers) > 1),
            # what fills the measured region: "pallas" (real
            # kernels), "jnp" (traffic loops), "none" (modeled)
            "activity": activity,
        }
        if backend == "spmd":
            execution["n_engines"] = n_engines
            # the structurally VERIFIED fence state of this
            # ladder's executed programs (jaxpr dataflow check)
            execution["fenced"] = fenced_by_triple.get(i, False)
            # how the executed rungs were timed: "device" (fused
            # ladder, in-dispatch device_clock deltas) or "host"
            # (legacy per-rung wall clock), plus the per-rung
            # sample spreads, the host-synchronous dispatch count
            # this ladder cost, and its width-packing slot
            # (packed / subset_width / subset_index)
            execution.update(timing_by_triple.get(i, {}))
            if operand_kinds_fn is not None:
                execution["operand_memory_kinds"] = \
                    operand_kinds_fn(spec, obs)
        runs.append(ScenarioRun(spec=spec, buffer_bytes=buf,
                                key=spec.key_for(obs, buf),
                                observer=obs,
                                scenarios=scenarios,
                                execution=execution))
    return runs
