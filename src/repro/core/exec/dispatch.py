"""dispatch — stage 3 of the spmd execution pipeline.

Owns everything between a built program and its numbers: the
coordinator-level program/operand LRU (:class:`ProgramCache`), AOT
compile + persistent-cache opt-in, donation rebind, the
host-synchronous dispatch itself, and the
(waves, subsets, rungs, samples) clock decode mapping each stacked
ladder's stamp pairs back to per-rung elapsed medians.
"""
from __future__ import annotations

import hashlib
import time as _time
from collections import OrderedDict
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.exec.fence import measured_region_is_fenced
from repro.core.exec.plan import PlannedDispatch
from repro.core.exec.program import (CompiledProgram, build_ladder_entry,
                                     build_rung_operands,
                                     build_rung_program, spmd_branch_fn)


def _fault_site(key: Tuple) -> str:
    """Stable fault-injection site id for a program cache key.  The
    key's repr is deterministic (frozen dataclasses and primitives
    only), so the same dispatch gets the same site in every process —
    which is what makes a seeded fault schedule byte-reproducible."""
    return hashlib.sha256(repr(key).encode()).hexdigest()[:16]


@dataclass
class DispatchStats:
    """Execution accounting for the matrix runner: the batched runner's
    claim ("fewer dispatches than the per-point loop") and the spmd
    backend's claim ("one fused SPMD dispatch per ladder rung") are
    checked against these numbers in the tests."""
    n_scenarios: int = 0            # ScenarioSpecs in the matrix
    n_ladders: int = 0              # (spec, observer, buffer) ladders
    measure_dispatches: int = 0     # timed executable measurement passes
    model_evals: int = 0            # queueing-network solves
    spmd_rungs: int = 0             # ladder rungs executed on the mesh
    # host-blocking spmd program executions: the sweep-batched path
    # does ONE per same-signature ladder GROUP (~ one per distinct
    # program signature per sweep) — width-packed or not: a packed
    # dispatch running P ladders side by side still counts ONE — the
    # fused ladder path one per ladder, the legacy path 4 per RUNG
    # (warm + 3 timed); benchmarks/perf_harness.py holds each
    # contender to its number
    host_sync_dispatches: int = 0
    # compiled spmd programs (+ placed operands) reused from the
    # coordinator-level LRU cache — across rungs, ladders, AND
    # back-to-back run_matrix calls on one coordinator
    program_cache_hits: int = 0
    # sweep-level megabatching: distinct role-program signatures this
    # run stacked ladders under (0 on the non-batched paths)
    spmd_groups: int = 0
    # spmd programs actually traced + compiled this run (cache
    # misses), and how many of those went through the AOT
    # lower().compile() pipeline (compat.aot_compile) — together with
    # host_sync_dispatches these make the dispatch-vs-compile
    # attribution in BENCH_spmd.json explicit
    programs_built: int = 0
    aot_compiles: int = 0
    # engine-subset width-packing: ladders that ran side by side on a
    # disjoint engine subset of a packed dispatch, and the widest
    # subset used (0 when nothing packed this run)
    packed_ladders: int = 0
    subset_width: int = 0
    # the resilience layer (exec.resilience): faults consumed from the
    # injector, failed attempts retried, ladders that finished BELOW
    # their planned dispatch level, ladders that fell all the way to
    # the modeled floor, quality-gate re-measurements (each one is an
    # extra honest host_sync_dispatch) + rungs still noisy after them,
    # and ladders restored from a sweep journal instead of re-executed
    faults_injected: int = 0
    retried_dispatches: int = 0
    degraded_ladders: int = 0
    modeled_floor_ladders: int = 0
    noisy_remeasures: int = 0
    noisy_rungs: int = 0
    resumed_ladders: int = 0

    def resilience_clean(self) -> bool:
        """True while no fault, retry, degradation or re-measurement
        has perturbed the dispatch accounting — the strict
        one-sync-per-group equalities only hold then."""
        return not (self.faults_injected or self.retried_dispatches
                    or self.degraded_ladders or self.noisy_remeasures)


class ProgramCache:
    """LRU over built spmd programs + their placed operands, keyed by
    program signature.  Entries are mutable (lists or
    :class:`CompiledProgram`s): donated dispatches rebind the operand
    arrays in place.  The cap is a MEMORY bound: eviction eagerly
    deletes the evicted entry's device buffers — dropping only the
    dict entry would leave the placed (and possibly donation-aliased)
    operands alive on the devices until Python GC got around to
    them."""

    def __init__(self, cap: int):
        assert cap >= 1, cap
        self.cap = cap
        self.entries: "OrderedDict[Tuple, Any]" = OrderedDict()

    def get(self, key: Tuple, stats: Optional[DispatchStats] = None):
        entry = self.entries.get(key)
        if entry is not None:
            self.entries.move_to_end(key)
            if stats is not None:
                stats.program_cache_hits += 1
        return entry

    def put(self, key: Tuple, entry) -> None:
        self.entries[key] = entry
        self.entries.move_to_end(key)
        while len(self.entries) > self.cap:
            _k, evicted = self.entries.popitem(last=False)
            for arr in evicted[3:5]:
                delete = getattr(arr, "delete", None)
                if delete is not None:
                    try:
                        delete()
                    except Exception:
                        pass        # already consumed by donation


class Dispatcher:
    """Stage 3: run planned dispatches.  Holds the program LRU and the
    per-coordinator dispatch knobs (sample count, opt-in persistent
    compile cache); the coordinator facade delegates here."""

    def __init__(self, cache_cap: int, samples: int,
                 compile_cache_dir: Optional[str] = None,
                 faults=None):
        assert samples >= 1, samples
        self.cache = ProgramCache(cache_cap)
        self.samples = samples
        # the fault-injection seam (exec.resilience.FaultInjector or
        # None): consulted at the compile / dispatch / decode sites of
        # both dispatch paths.  Deterministic — draws are pure hashes
        # of (seed, site, phase, attempt) — and duck-typed, so this
        # module never imports the resilience layer
        self.faults = faults
        # NOTE: the underlying JAX config is PROCESS-GLOBAL — enabling
        # it here serves every compile in the process (other
        # dispatchers included), and a second dispatcher with a
        # different dir re-points the whole process; the attribute
        # records only what THIS dispatcher requested
        # (compat.persistent_cache documents scope + the host-callback
        # caveat)
        self.compile_cache_dir = compile_cache_dir
        if compile_cache_dir:
            from repro import compat
            self.persistent_cache_enabled = compat.persistent_cache(
                compile_cache_dir)
        else:
            self.persistent_cache_enabled = False

    def _fault(self, site: str, phase: str, stats: DispatchStats):
        """Consult the fault-injection seam.  Raising phases
        ("compile"/"dispatch") raise the injector's fault; the
        "decode" phase returns the fault kind so the caller can
        corrupt the decoded timings instead (a corrupted-timing fault
        must produce bad VALUES — detection is the resilience layer's
        validator, not an exception)."""
        if self.faults is None:
            return None
        kind = self.faults.check(site, phase)
        if kind is not None:
            stats.faults_injected += 1
            if phase != "decode":
                raise self.faults.error(kind, site)
        return kind

    # -- the fused/batched/packed path ---------------------------------

    def run_planned(self, planned: PlannedDispatch, n_eng: int,
                    activity: str, mode: str, stats: DispatchStats,
                    ) -> Tuple[np.ndarray, np.ndarray, bool, bool]:
        """Execute one planned dispatch: build (or fetch) its program,
        run it with ONE host-synchronous call, and decode each stacked
        ladder's in-dispatch stamp pairs.  Returns
        ``(med, spread, fenced, aot)`` with ``med``/``spread`` of
        shape (group, n_scen) nanoseconds."""
        key = planned.cache_key(mode, n_eng, activity, self.samples)
        site = _fault_site(key)
        entry = self.cache.get(key, stats)
        if entry is None:
            self._fault(site, "compile", stats)
            entry = build_ladder_entry(planned, n_eng, activity,
                                       self.samples, stats)
            self.cache.put(key, entry)
        aot = entry[5]
        _mesh, call, fenced, xf, xi = entry[:5]
        self._fault(site, "dispatch", stats)
        out = jax.block_until_ready(call(xf, xi))
        stats.host_sync_dispatches += 1
        stats.measure_dispatches += 1
        stats.spmd_rungs += planned.group * planned.n_scen
        if planned.packed:
            stats.packed_ladders += planned.group
            stats.subset_width = max(stats.subset_width,
                                     planned.subset_width)
        # donated dispatch consumed the cached operands; rebind the
        # returned (aliased in place where donation is real) arrays
        entry[3], entry[4] = out[3], out[4]
        # each subset's LEADER engine is its observer: its [s, ns]
        # stamp pairs bracket each scanned sandwich, stop stamp taken
        # after the subset's stop psum (i.e. when its SLOWEST engine
        # finished — paper invariant 3).  Ladder g ran in wave g//P on
        # subset g%P; the trailing spare subsets of a ragged last wave
        # executed but are not decoded.
        t0s = np.asarray(out[1])
        t1s = np.asarray(out[2])
        k, s = planned.n_scen, self.samples
        med = np.zeros((planned.group, k))
        spread = np.zeros((planned.group, k), np.int64)
        for g in range(planned.group):
            wave, subset = planned.member_slot(g)
            lead = subset * planned.subset_width
            t0 = t0s[lead].reshape(planned.waves, k, s, 2)[wave]
            t1 = t1s[lead].reshape(planned.waves, k, s, 2)[wave]
            d = ((t1[..., 0].astype(np.int64) - t0[..., 0])
                 * 1_000_000_000 + (t1[..., 1] - t0[..., 1]))
            med[g] = np.median(d, axis=1)
            spread[g] = d.max(axis=1) - d.min(axis=1)
        if self._fault(site, "decode", stats):
            med = -np.abs(med)      # corrupted timings: non-positive
        return med, spread, fenced, aot

    # -- the legacy per-rung path ---------------------------------------

    def run_rung(self, roles, n_eng: int, activity: str,
                 kind: Optional[str], stats: DispatchStats,
                 ) -> Tuple[float, bool, int, bool]:
        """One rung, one fused program — all branches of a single
        ``shard_map`` dispatch whose measured region sits between the
        two psum barriers of ``build_rung_program`` (the returned bool
        is the structurally *verified* fence state of this rung's
        program, the final int the spread of the host wall-time
        samples).

        The wall time of the dispatch is the measured region: host
        ``perf_counter_ns`` around ``block_until_ready``, median of
        ``samples`` — which costs 1 + ``samples`` host round-trips per
        rung (4 at the default) and includes Python dispatch jitter.
        The fused ladder path replaces both; this path is kept for
        comparison (``benchmarks/perf_harness.py``) and as the
        fallback where no in-dispatch timestamp source exists."""
        from repro import compat

        roles = tuple(roles)
        rows_max = max(r[2] for r in roles)
        # the kind joins the cache key: identical role programs from
        # differently-placed pools must not share operands
        key = ("rung", n_eng, activity, kind, roles)
        site = _fault_site(key)
        entry = self.cache.get(key, stats)

        if entry is not None:
            # operands are fully determined by the cache key (chain
            # seeds are engine indices): reuse the placed arrays too —
            # no host-side rebuild, no repeated host->device transfer
            _mesh, fn, fenced, xf, xi, aot = entry
        else:
            self._fault(site, "compile", stats)
            xf, xi = build_rung_operands(roles, n_eng, rows_max)
            branch_fns: List = []
            engine_branch: List[int] = []
            branch_of: Dict[Tuple, int] = {}
            for sig in roles:
                if sig not in branch_of:
                    branch_of[sig] = len(branch_fns)
                    branch_fns.append(spmd_branch_fn(
                        *sig, activity=activity))
                engine_branch.append(branch_of[sig])
            mesh, fn = build_rung_program(n_eng, branch_fns,
                                          engine_branch)
            # commit the operands onto the mesh BEFORE the measured
            # region: a host array would be re-transferred inside
            # every timed call, and the transfer (which scales with
            # the widest role, not the observer) would dominate the
            # measurement
            from jax.sharding import PartitionSpec as P
            sharding = compat.named_sharding(mesh, P("engine"), kind)
            xf = jax.device_put(xf, sharding)
            xi = jax.device_put(xi, sharding)
            jax.block_until_ready((xf, xi))
            # one trace serves the fence walk AND the AOT compile; the
            # rung programs carry no host callbacks, so with a
            # persistent cache enabled the compile is also reused
            # across processes.  provenance records the VERIFIED fence
            # state, not an assertion (compat.optimization_barrier
            # degrades to identity on JAX releases without the op —
            # there the psum folds away and this honestly reports
            # unfenced)
            traced = compat.aot_trace(fn, xf, xi)
            fenced = measured_region_is_fenced(
                fn, xf, xi, jaxpr=getattr(traced, "jaxpr", None))
            compiled = compat.aot_compile(fn, xf, xi, traced=traced)
            stats.programs_built += 1
            if compiled is not None:
                stats.aot_compiles += 1
            aot = compiled is not None
            fn = compiled if compiled is not None else fn
            self.cache.put(key, CompiledProgram(mesh, fn, fenced,
                                                xf, xi, aot))
        self._fault(site, "dispatch", stats)
        jax.block_until_ready(fn(xf, xi))          # warm (+ compile
        samples = []                               # when not AOT-built)
        for _ in range(self.samples):
            t0 = _time.perf_counter_ns()
            jax.block_until_ready(fn(xf, xi))
            samples.append(_time.perf_counter_ns() - t0)
        stats.host_sync_dispatches += 1 + self.samples
        stats.measure_dispatches += 1
        stats.spmd_rungs += 1
        elapsed = float(np.median(samples))
        if self._fault(site, "decode", stats):
            elapsed = -abs(elapsed)     # corrupted timing: non-positive
        return elapsed, fenced, int(max(samples) - min(samples)), aot
