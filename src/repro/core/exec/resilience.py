"""resilience — fault injection, retry-with-degradation, quality gate.

The paper owns its hardware; this reproduction earns the same
consistency through detection and recovery.  Everything wraps the
Dispatcher — degradation is a PLAN rewrite (planner-seam convention):

* :class:`FaultSpec`/:class:`FaultInjector` — deterministic seeded
  fault injection (compile/runtime/timeout/corrupt-timing rates) that
  plugs into ``Dispatcher(faults=...)``; every draw is a pure sha256
  of ``(seed, site, phase, attempt)``, so schedules are
  byte-reproducible and retry attempts see fresh draws.  Set via
  ``CoreCoordinator(faults=...)`` or ``REPRO_FAULT_SPEC`` (CI chaos).

* :func:`run_group` — retries a failed planned dispatch with capped
  exponential backoff, then degrades ``packed -> batched -> fused
  ladder -> per-rung -> modeled`` via the pure plan rewrites
  (``unpack_dispatch``/``split_ladders``), isolating failure to its
  signature group; provenance records ``attempts`` /
  ``degraded_from`` / ``fault_kind``.

* :class:`QualityGate` — per-rung ``rung_time_spread_ns`` vs a
  relative threshold; noisy device-timed groups re-measure up to N
  times (counted in ``stats.noisy_remeasures`` + extra
  ``host_sync_dispatches``; logical counters stay stable) before
  rungs are flagged ``noisy=True`` instead of silently persisted.

Sweep-level orchestration (plan execution + the crash-resume journal)
lives in the sibling :mod:`repro.core.exec.journal`.
"""
from __future__ import annotations

import hashlib
import logging
import math
import os
import time as _time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.exec import plan as exec_plan

log = logging.getLogger(__name__)

ENV_FAULT_SPEC = "REPRO_FAULT_SPEC"

#: the injectable fault kinds, in ladder order of their injection site
FAULT_KINDS = ("compile_error", "runtime_error", "timeout",
               "corrupt_timing")

_PHASE_KINDS = {"compile": ("compile_error",),
                "dispatch": ("runtime_error", "timeout"),
                "decode": ("corrupt_timing",)}

#: programming errors retrying cannot fix — surface immediately,
#: wrapped with the failing group's context
_NON_RETRYABLE = (ValueError, TypeError, KeyError, IndexError,
                  AttributeError, AssertionError)


class InjectedFault(RuntimeError):
    """A fault the :class:`FaultInjector` decided to fire."""

    def __init__(self, kind: str, site: str):
        self.kind = kind
        self.site = site
        super().__init__(f"injected {kind} at site {site}")


class _CorruptTiming(RuntimeError):
    """Decoded timings failed validation (non-finite/non-positive)."""


class GroupExecutionError(RuntimeError):
    """A dispatch failed — and the error names WHICH group (spec
    names, observer keys, buffers) instead of a bare XLA traceback."""

    def __init__(self, context: str, cause: BaseException):
        self.context = context
        self.cause = cause
        super().__init__(f"{context}: {cause!r}")


def group_context(entries) -> str:
    specs = sorted({e.spec.name for e in entries})
    observers = sorted({f"{e.observer.pool}:{e.observer.strategy}"
                        for e in entries})
    bufs = sorted({e.buffer_bytes for e in entries})
    return (f"dispatch group (specs={specs}, observers={observers}, "
            f"buffers={bufs})")


def classify_fault(exc: BaseException) -> str:
    kind = getattr(exc, "kind", None)
    if isinstance(kind, str) and kind in FAULT_KINDS:
        return kind
    if isinstance(exc, _CorruptTiming):
        return "corrupt_timing"
    if isinstance(exc, TimeoutError):
        return "timeout"
    return "runtime_error"


# ---------------------------------------------------------------------------
# Fault specification + deterministic injector
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FaultSpec:
    """Per-kind injection rates (probability per injection site visit)
    plus the seed every draw hashes against."""
    compile_error: float = 0.0
    runtime_error: float = 0.0
    timeout: float = 0.0
    corrupt_timing: float = 0.0
    seed: int = 0

    def __post_init__(self):
        for k in FAULT_KINDS:
            r = getattr(self, k)
            if not 0.0 <= r <= 1.0:
                raise ValueError(f"fault rate {k}={r} outside [0, 1]")

    def rate(self, kind: str) -> float:
        return float(getattr(self, kind))

    def injector(self) -> "FaultInjector":
        return FaultInjector(self)

    @staticmethod
    def parse(text: str) -> "FaultSpec":
        """Parse the ``REPRO_FAULT_SPEC`` spelling: comma-separated
        ``key=value`` over ``compile``/``runtime``/``timeout``/
        ``corrupt`` (long spellings accepted), ``seed``, and
        ``mixed=R`` splitting R evenly — e.g. ``"mixed=0.25,seed=3"``."""
        alias = {"compile": "compile_error", "runtime": "runtime_error",
                 "corrupt": "corrupt_timing"}
        vals: Dict[str, float] = {}
        seed, mixed = 0, None
        for part in text.split(","):
            part = part.strip()
            if not part:
                continue
            if "=" not in part:
                raise ValueError(f"fault spec field {part!r}: "
                                 f"expected key=value")
            k, v = (s.strip() for s in part.split("=", 1))
            k = alias.get(k, k)
            if k == "seed":
                seed = int(v)
            elif k == "mixed":
                mixed = float(v)
            elif k in FAULT_KINDS:
                vals[k] = float(v)
            else:
                raise ValueError(f"unknown fault spec field {k!r}")
        if mixed is not None:
            for k in FAULT_KINDS:
                vals.setdefault(k, mixed / len(FAULT_KINDS))
        return FaultSpec(seed=seed, **vals)

    @staticmethod
    def from_env(environ=None) -> Optional["FaultSpec"]:
        env = os.environ if environ is None else environ
        text = (env.get(ENV_FAULT_SPEC) or "").strip()
        if not text or text.lower() in ("0", "off", "none"):
            return None
        return FaultSpec.parse(text)


class FaultInjector:
    """Per-(site, phase) attempt counters over stateless hash draws:
    attempt ``a`` draws ``sha256(f"{seed}|{site}|{phase}|{a}")`` in
    [0, 1) — pure, so one seed gives byte-identical schedules for the
    same site visits, and a RETRY (attempt a+1) sees a fresh draw."""

    def __init__(self, spec: FaultSpec):
        self.spec = spec
        self._attempt: Dict[Tuple[str, str], int] = {}

    def draw(self, site: str, phase: str, attempt: int) -> float:
        msg = f"{self.spec.seed}|{site}|{phase}|{attempt}".encode()
        h = hashlib.sha256(msg).digest()
        return int.from_bytes(h[:8], "big") / 2.0 ** 64

    def check(self, site: str, phase: str) -> Optional[str]:
        key = (site, phase)
        attempt = self._attempt.get(key, 0)
        self._attempt[key] = attempt + 1
        u = self.draw(site, phase, attempt)
        acc = 0.0
        for kind in _PHASE_KINDS[phase]:
            acc += self.spec.rate(kind)
            if u < acc:
                return kind
        return None

    def error(self, kind: str, site: str) -> InjectedFault:
        return InjectedFault(kind, site)


# ---------------------------------------------------------------------------
# Retry policy + measurement quality gate
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RetryPolicy:
    """``retries`` extra attempts per ladder level with capped
    exponential backoff (``backoff_s * 2**n``, cap ``backoff_cap_s``);
    ``degrade=False`` disables the ladder (exhaustion goes straight to
    the floor), ``modeled_floor=False`` turns the floor into a raised
    :class:`GroupExecutionError` instead of modeled rungs."""
    retries: int = 1
    backoff_s: float = 0.05
    backoff_cap_s: float = 1.0
    degrade: bool = True
    modeled_floor: bool = True
    sleep: Callable[[float], None] = field(default=_time.sleep,
                                           repr=False)

    def pause(self, n: int) -> None:
        delay = min(self.backoff_cap_s, self.backoff_s * (2.0 ** n))
        if delay > 0:
            self.sleep(delay)


@dataclass(frozen=True)
class QualityGate:
    """Per-rung spread acceptance: a rung whose sample spread exceeds
    ``rel_spread`` times its median (and the absolute
    ``min_spread_ns`` floor — microsecond rungs jitter harmlessly) is
    *noisy*.  Device-timed dispatches re-measure up to ``remeasure``
    times, keeping each rung's lower-spread sample set; rungs still
    noisy after that are flagged ``noisy=True`` in provenance.  The
    default is a wide guard (spread 8x median) firing only on real
    interference, so zero-noise accounting normally holds exactly."""
    rel_spread: float = 8.0
    remeasure: int = 2
    min_spread_ns: float = 100_000.0

    def noisy(self, med: float, spread: float) -> bool:
        return (spread > self.min_spread_ns
                and spread > self.rel_spread * max(med, 1e-9))


def resolve_faults(faults, environ=None) -> Optional[FaultSpec]:
    """``CoreCoordinator(faults=...)`` resolution: ``None`` reads
    ``REPRO_FAULT_SPEC``; ``False``/``"off"`` disables even with the
    env var set; a string parses; a FaultSpec passes through."""
    if faults is None:
        return FaultSpec.from_env(environ)
    if faults is False or (isinstance(faults, str)
                           and faults.lower() in ("off", "none")):
        return None
    if isinstance(faults, str):
        return FaultSpec.parse(faults)
    if isinstance(faults, FaultSpec):
        return faults
    raise TypeError(f"faults must be None, False, 'off', a spec "
                    f"string or a FaultSpec — got {faults!r}")


def resolve_gate(quality) -> Optional[QualityGate]:
    if quality is None or quality == "auto":
        return QualityGate()
    if quality is False or quality == "off":
        return None
    if isinstance(quality, QualityGate):
        return quality
    raise TypeError(f"quality must be None, 'auto', 'off', False or a "
                    f"QualityGate — got {quality!r}")


# ---------------------------------------------------------------------------
# Resilient group execution (the retry-degradation ladder)
# ---------------------------------------------------------------------------


@dataclass
class EntryOutcome:
    """One ladder's final result: per-rung observer nanoseconds
    (``None`` = fell to the modeled floor for that rung) plus the full
    per-curve timing/resilience provenance dict."""
    entry: Any                          # plan.LadderEntry
    med: List[Optional[float]]
    fenced: bool
    timing: Dict[str, Any]


@dataclass
class _Ctx:
    dispatcher: Any
    n_eng: int
    activity: str
    mode: str
    stats: Any
    policy: RetryPolicy
    gate: Optional[QualityGate]


class _GroupState:
    """Mutable per-group resilience bookkeeping threaded through the
    degradation recursion (split children get a copy of the prefix)."""
    __slots__ = ("attempts", "fault_kind", "path", "remeasures")

    def __init__(self, attempts=0, fault_kind=None, path=None,
                 remeasures=0):
        self.attempts = attempts
        self.fault_kind = fault_kind
        self.path = list(path or ())
        self.remeasures = remeasures

    def child(self) -> "_GroupState":
        return _GroupState(self.attempts, self.fault_kind, self.path,
                           self.remeasures)

    def note(self, exc: BaseException) -> None:
        self.fault_kind = classify_fault(exc)

    def origin(self) -> Optional[str]:
        return self.path[0] if self.path else None


def _timings_ok(med) -> bool:
    a = np.asarray(med, dtype=float)
    return bool(np.all(np.isfinite(a)) and np.all(a > 0))


def run_group(dispatcher, planned, *, n_eng: int, activity: str,
              mode: str, stats, policy: Optional[RetryPolicy] = None,
              gate: Optional[QualityGate] = None) -> List[EntryOutcome]:
    """Execute one planned dispatch resiliently: retry with backoff,
    walk the degradation ladder on exhaustion, quality-gate the
    timings.  Always returns one outcome per planned entry (modeled
    floor outcomes carry ``med=[None, ...]``); raises only
    :class:`GroupExecutionError` (non-retryable programming errors,
    or fault exhaustion under ``modeled_floor=False``)."""
    ctx = _Ctx(dispatcher, n_eng, activity, mode, stats,
               policy or RetryPolicy(), gate)
    return _run_group(ctx, planned, _GroupState())


def _run_group(ctx: _Ctx, planned, state: _GroupState,
               ) -> List[EntryOutcome]:
    try:
        med, spread, fenced, aot = _attempt_planned(ctx, planned, state)
    except GroupExecutionError:
        raise
    except _NON_RETRYABLE as exc:
        raise GroupExecutionError(group_context(planned.entries),
                                  exc) from exc
    except Exception as exc:
        return _degrade(ctx, planned, state, exc)
    med, spread, noisy = _apply_gate(ctx, planned, med, spread, state)
    return _pack_outcomes(ctx, planned, med, spread, fenced, aot,
                          state, noisy)


def _attempt_planned(ctx: _Ctx, planned, state: _GroupState):
    last: Optional[BaseException] = None
    for a in range(max(0, ctx.policy.retries) + 1):
        if a:
            ctx.stats.retried_dispatches += 1
            ctx.policy.pause(a - 1)
        state.attempts += 1
        try:
            med, spread, fenced, aot = ctx.dispatcher.run_planned(
                planned, ctx.n_eng, ctx.activity, ctx.mode, ctx.stats)
        except _NON_RETRYABLE:
            raise
        except Exception as exc:
            state.note(exc)
            last = exc
            continue
        if not _timings_ok(med):
            last = _CorruptTiming(
                f"{group_context(planned.entries)}: non-positive/"
                f"non-finite decoded rung times")
            state.note(last)
            continue
        return med, spread, fenced, aot
    raise last


def _apply_gate(ctx: _Ctx, planned, med, spread, state: _GroupState):
    gate = ctx.gate
    noisy = _noisy_cells(gate, med, spread)
    tries = 0
    while noisy and gate is not None and tries < gate.remeasure:
        tries += 1
        ctx.stats.noisy_remeasures += 1
        state.remeasures += 1
        try:
            med2, spread2, _f, _a = ctx.dispatcher.run_planned(
                planned, ctx.n_eng, ctx.activity, ctx.mode, ctx.stats)
        except _NON_RETRYABLE:
            raise
        except Exception as exc:        # a fault burned the remeasure
            state.note(exc)
            break
        # the remeasure re-ran the SAME rungs: keep the logical
        # counters stable — host_sync_dispatches + noisy_remeasures
        # carry the honest extra cost
        ctx.stats.measure_dispatches -= 1
        ctx.stats.spmd_rungs -= planned.group * planned.n_scen
        if planned.packed:
            ctx.stats.packed_ladders -= planned.group
        if not _timings_ok(med2):
            state.fault_kind = "corrupt_timing"
            continue
        better = spread2 < spread       # keep each rung's calmer set
        med = np.where(better, med2, med)
        spread = np.where(better, spread2, spread)
        noisy = _noisy_cells(gate, med, spread)
    if noisy:
        ctx.stats.noisy_rungs += len(noisy)
        log.warning("quality gate: %d rung(s) of %s still noisy after "
                    "%d re-measurement(s)", len(noisy),
                    group_context(planned.entries), tries)
    return med, spread, noisy


def _noisy_cells(gate: Optional[QualityGate], med,
                 spread) -> List[Tuple[int, int]]:
    if gate is None:
        return []
    return [(g, k) for g in range(med.shape[0])
            for k in range(med.shape[1])
            if gate.noisy(float(med[g, k]), float(spread[g, k]))]


def _degrade(ctx: _Ctx, planned, state: _GroupState,
             exc: BaseException) -> List[EntryOutcome]:
    log.warning("resilient dispatch: %s failed after %d attempt(s) "
                "(%s); degrading", group_context(planned.entries),
                state.attempts, state.fault_kind)
    if not ctx.policy.degrade:
        if ctx.policy.modeled_floor:
            return _modeled_outcomes(ctx, planned, state)
        raise GroupExecutionError(group_context(planned.entries),
                                  exc) from exc
    if planned.packed and not planned.probe:
        state.path.append("packed")
        return _run_group(ctx, exec_plan.unpack_dispatch(planned),
                          state)
    if planned.group > 1:
        state.path.append("packed" if planned.packed else "batched")
        outs: List[EntryOutcome] = []
        for sub in exec_plan.split_ladders(planned):
            outs.extend(_run_group(ctx, sub, state.child()))
        return outs
    state.path.append("ladder")
    return _run_rungs(ctx, planned, state)


def _attempt_rung(ctx: _Ctx, roles, kind, state: _GroupState):
    last: Optional[BaseException] = None
    for a in range(max(0, ctx.policy.retries) + 1):
        if a:
            ctx.stats.retried_dispatches += 1
            ctx.policy.pause(a - 1)
        state.attempts += 1
        try:
            elapsed, fenced, spread, aot = ctx.dispatcher.run_rung(
                roles, ctx.n_eng, ctx.activity, kind, ctx.stats)
        except _NON_RETRYABLE:
            raise
        except Exception as exc:
            state.note(exc)
            last = exc
            continue
        if not (math.isfinite(elapsed) and elapsed > 0):
            last = _CorruptTiming(f"non-positive rung time {elapsed}")
            state.note(last)
            continue
        return elapsed, fenced, spread, aot
    raise last


def _run_rungs(ctx: _Ctx, planned, state: _GroupState,
               ) -> List[EntryOutcome]:
    """The per-rung degradation floor: the single remaining ladder
    runs rung by rung on the host-timed legacy path; a rung that
    exhausts its retries is modeled (the rest of the ladder still
    measures)."""
    entry = planned.entries[0]
    med: List[Optional[float]] = []
    spreads: List[int] = []
    noisy_ks: List[int] = []
    fenced_all, aot_all, dispatches = True, True, 0
    for k in range(planned.n_scen):
        roles = exec_plan.rung_row(planned, k, ctx.n_eng)
        try:
            elapsed, fenced, spread, aot = _attempt_rung(
                ctx, roles, planned.kind, state)
        except GroupExecutionError:
            raise
        except _NON_RETRYABLE as exc:
            raise GroupExecutionError(group_context(planned.entries),
                                      exc) from exc
        except Exception as exc:
            if not ctx.policy.modeled_floor:
                raise GroupExecutionError(
                    group_context(planned.entries), exc) from exc
            state.note(exc)
            med.append(None)
            continue
        med.append(float(elapsed))
        spreads.append(int(spread))
        fenced_all = fenced_all and fenced
        aot_all = aot_all and aot
        dispatches += 1 + ctx.dispatcher.samples
        if ctx.gate is not None and ctx.gate.noisy(elapsed, spread):
            noisy_ks.append(k)          # host path: flag, no remeasure
    executed_any = any(m is not None for m in med)
    if noisy_ks:
        ctx.stats.noisy_rungs += len(noisy_ks)
    if not executed_any:
        state.path.append("rung")
        ctx.stats.modeled_floor_ladders += 1
    if state.path:
        ctx.stats.degraded_ladders += 1
    timing = {
        "timing_source": "host" if executed_any else "none",
        "samples": ctx.dispatcher.samples,
        "rung_time_spread_ns": spreads,
        "dispatches": dispatches,
        "remeasures": state.remeasures,
        "batched": False, "group_size": 1,
        "aot": aot_all if executed_any else False,
        "packed": False, "subset_width": ctx.n_eng, "subset_index": 0,
        "attempts": state.attempts,
        "degraded_from": state.origin(),
        "fault_kind": state.fault_kind,
        "noisy": bool(noisy_ks), "noisy_rungs": noisy_ks,
    }
    return [EntryOutcome(entry, med, fenced_all and executed_any,
                         timing)]


def _modeled_outcomes(ctx: _Ctx, planned,
                      state: _GroupState) -> List[EntryOutcome]:
    ctx.stats.modeled_floor_ladders += planned.group
    if state.path:
        ctx.stats.degraded_ladders += planned.group
    outs = []
    for e in planned.entries:
        timing = {
            "timing_source": "none",
            "samples": ctx.dispatcher.samples,
            "rung_time_spread_ns": [], "dispatches": 0,
            "remeasures": state.remeasures,
            "batched": False, "group_size": 1, "aot": False,
            "packed": False, "subset_width": ctx.n_eng,
            "subset_index": 0,
            "attempts": state.attempts,
            "degraded_from": state.origin(),
            "fault_kind": state.fault_kind,
            "noisy": False, "noisy_rungs": [],
        }
        outs.append(EntryOutcome(e, [None] * planned.n_scen, False,
                                 timing))
    return outs


def _pack_outcomes(ctx: _Ctx, planned, med, spread, fenced: bool,
                   aot: bool, state: _GroupState,
                   noisy) -> List[EntryOutcome]:
    noisy_by_g: Dict[int, List[int]] = {}
    for g, k in noisy:
        noisy_by_g.setdefault(g, []).append(k)
    if state.path:
        ctx.stats.degraded_ladders += planned.group
    outs = []
    for g, e in enumerate(planned.entries):
        _wave, subset = planned.member_slot(g)
        ks = noisy_by_g.get(g, [])
        timing = {
            "timing_source": "device",
            "samples": ctx.dispatcher.samples,
            "rung_time_spread_ns": [int(s) for s in spread[g]],
            "dispatches": 1 + state.remeasures,
            "remeasures": state.remeasures,
            "batched": ctx.mode == "batched",
            "group_size": planned.group,
            "aot": aot,
            "packed": planned.packed,
            "subset_width": planned.subset_width,
            "subset_index": subset,
            "attempts": state.attempts,
            "degraded_from": state.origin(),
            "fault_kind": state.fault_kind,
            "noisy": bool(ks), "noisy_rungs": ks,
        }
        outs.append(EntryOutcome(e, [float(m) for m in med[g]], fenced,
                                 timing))
    return outs
