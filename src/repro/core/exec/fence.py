"""fence — structural verification of the measured-region sandwich.

Sandwich invariant 1 (no engine's measured work can begin before the
start barrier) as a jaxpr dataflow check, plus — for width-packed
dispatches — per-subset isolation: every psum sandwich must be grouped
exactly along the declared engine subsets, and no collective may move
data across a subset boundary.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax


def measured_region_is_fenced(fn, *example_args, jaxpr=None,
                              subsets: Optional[Sequence[Sequence[int]]]
                              = None) -> bool:
    """Does the measured output depend — through DATAFLOW, not just
    program order — on the start-barrier psum?

    Walks the traced jaxpr: inside every ``shard_map`` body, takes the
    first psum equation (the start barrier), computes the forward
    dataflow closure of its outputs, and requires (a) the body's first
    output (the measured activity result) to lie inside that closure,
    and (b) every ``pallas_call`` reachable after the barrier —
    recursing through switch branches and loop bodies — to consume at
    least one operand inside the closure.  (b) extends the check past
    the ``pallas_call`` boundary: a kernel is the *actual* memory
    traffic of a Pallas rung activity, and one fed only by constants
    (e.g. a no-operand write stream) could be hoisted above the
    barrier even though the switch output downstream of it still
    "depends" on the fence.  A program whose barrier is advisory only
    — the pre-fix ``build_scenario_program``, where ``out`` had no
    data dependency on ``ready`` — returns False: XLA was free to
    begin the measured activity before the stressors were running.

    Fused whole-ladder programs (``build_ladder_program``) carry
    their psum sandwiches INSIDE a ``lax.scan``: there the check
    recurses into every psum-bearing scan/while body and requires the
    step itself to pass — the step's first output is the loop carry,
    which by construction value-consumes the stop barrier and stamp,
    so verifying the body verifies EVERY scanned rung sample (one body
    serves all steps structurally) — including every ladder of a
    sweep-batched stacked program, whose scan table merely gains a
    leading scenario axis.

    ``subsets`` declares a width-packed program's disjoint engine
    subsets (e.g. ``((0, 1), (2, 3))``); when given, each subset's
    fence is verified INDEPENDENTLY: every psum inside the measured
    region must carry ``axis_index_groups`` in which each declared
    subset appears as exactly one group (its own sandwich) and every
    other group is disjoint from all subsets (leftover engines may
    barrier among themselves), and no other collective may move data
    across a subset boundary.  A global psum, a group spanning two
    subsets, a group splitting one subset, or a cross-subset
    ``ppermute`` all make the packed measurement unattributable to one
    mesh slice — each returns False.

    Pass ``jaxpr=`` (a ClosedJaxpr, e.g. from
    ``compat.aot_trace(fn, *args).jaxpr``) to reuse an existing trace
    instead of paying a second one here."""
    closed = jaxpr if jaxpr is not None \
        else jax.make_jaxpr(fn)(*example_args)
    bodies = _shard_map_bodies(closed.jaxpr)
    if not bodies:
        return False
    if not all(_first_out_depends_on_psum(b) for b in bodies):
        return False
    if subsets:
        decl = tuple(tuple(int(i) for i in s) for s in subsets)
        return all(_collectives_respect_subsets(b, decl)
                   for b in bodies)
    return True


def _sub_jaxprs(params: Dict[str, Any]):
    for v in params.values():
        for u in (v if isinstance(v, (tuple, list)) else (v,)):
            inner = getattr(u, "jaxpr", u)
            if hasattr(inner, "eqns"):
                yield inner


def _shard_map_bodies(jaxpr) -> List[Any]:
    out = []
    for eqn in jaxpr.eqns:
        for inner in _sub_jaxprs(eqn.params):
            if "shard_map" in eqn.primitive.name:
                out.append(inner)
            else:
                out.extend(_shard_map_bodies(inner))
    return out


def _jaxpr_has_psum(jaxpr) -> bool:
    for eqn in jaxpr.eqns:
        if "psum" in eqn.primitive.name:
            return True
        for inner in _sub_jaxprs(eqn.params):
            if _jaxpr_has_psum(inner):
                return True
    return False


def _first_out_depends_on_psum(body) -> bool:
    live: set = set()
    seen_psum = False
    kernels_ok = True
    for eqn in body.eqns:
        invars = [v for v in eqn.invars if not hasattr(v, "val")]
        if not seen_psum and "psum" in eqn.primitive.name:
            seen_psum = True
            live.update(eqn.outvars)
            continue
        if not seen_psum and eqn.primitive.name in ("scan", "while"):
            inners = [j for j in _sub_jaxprs(eqn.params)
                      if _jaxpr_has_psum(j)]
            if inners:
                # a scanned/looped sandwich (the fused whole-ladder
                # program): every step must pass the same check — its
                # first output is the loop carry, which must consume
                # the step's own stop barrier, and every kernel inside
                # the step must consume fence-dependent operands.  One
                # body serves all steps, so this verifies every rung.
                if all(_first_out_depends_on_psum(j) for j in inners):
                    seen_psum = True
                    live.update(eqn.outvars)
                else:
                    kernels_ok = False
                continue
        if seen_psum:
            kernels_ok = kernels_ok and _kernels_fenced_in_eqn(eqn, live)
            if any(v in live for v in invars):
                live.update(eqn.outvars)
    out0 = body.outvars[0]
    return out0 in live and kernels_ok


def _is_live(v, live) -> bool:
    return not hasattr(v, "val") and v in live


def _kernels_fenced_in_eqn(eqn, live) -> bool:
    """Fence-reachability of the kernels *inside* one equation: a
    ``pallas_call`` must consume at least one fence-dependent operand;
    any other equation recurses into its sub-jaxprs (switch/cond
    branches, while/scan loop bodies, inner pjit calls) with the live
    set mapped onto the inner binders.  The mapping aligns outer
    operands to inner invars from the END — exact for pjit/scan, and
    for cond/switch (whose leading index operand has no binder) and
    while bodies (whose leading cond-consts belong to the other
    jaxpr) it aligns the carried values correctly, which is where the
    fenced operands live."""
    if "pallas_call" in eqn.primitive.name:
        return any(_is_live(v, live) for v in eqn.invars)
    ok = True
    for inner in _sub_jaxprs(eqn.params):
        inner_live = {iv for iv, ov in zip(reversed(inner.invars),
                                           reversed(eqn.invars))
                      if _is_live(ov, live)}
        ok = ok and _kernels_fenced_in_jaxpr(inner, inner_live)
    return ok


def _kernels_fenced_in_jaxpr(jaxpr, live) -> bool:
    live = set(live)
    ok = True
    for eqn in jaxpr.eqns:
        ok = ok and _kernels_fenced_in_eqn(eqn, live)
        if any(_is_live(v, live) for v in eqn.invars):
            live.update(eqn.outvars)
    return ok


# ---------------------------------------------------------------------------
# Packed-subset isolation
# ---------------------------------------------------------------------------

# cross-engine data-movement primitives whose grouping must respect the
# declared subsets (matched by substring against primitive names, which
# drift across jax versions: psum / psum_invariant / all_gather ...)
_GROUPED_COLLECTIVES = ("psum", "pmax", "pmin", "pmean", "all_gather",
                        "all_to_all", "reduce_scatter")


def _subset_of(idx: int, subsets) -> Optional[int]:
    for j, s in enumerate(subsets):
        if idx in s:
            return j
    return None            # leftover engine (idles outside all subsets)


def _psum_groups_isolate(groups, subsets) -> bool:
    """A fence psum isolates the declared subsets iff each subset is
    exactly one of its groups (every subset gets its OWN sandwich —
    neither merged with a sibling nor split in half) and every other
    group is disjoint from all subsets (leftover engines barriering
    among themselves are harmless)."""
    if groups is None:
        return len(subsets) <= 1
    declared = set(subsets)
    gset = {tuple(int(i) for i in g) for g in groups}
    if not declared <= gset:
        return False
    members = {i for s in subsets for i in s}
    return all(not (set(g) & members) for g in gset - declared)


def _gather_groups_isolate(groups, subsets) -> bool:
    """Non-barrier collectives (gathers, all-to-alls) leak operand data
    between their group's members, so each group must stay WITHIN one
    subset (or within the leftover engines) — weaker than the psum
    rule, which additionally demands a sandwich per subset."""
    if groups is None:
        return len(subsets) <= 1
    for g in groups:
        owners = {_subset_of(int(i), subsets) for i in g}
        if len(owners) > 1:
            return False
    return True


def _eqn_respects_subsets(eqn, subsets) -> bool:
    name = eqn.primitive.name
    if "ppermute" in name:
        perm = eqn.params.get("perm") or ()
        return all(_subset_of(int(s), subsets)
                   == _subset_of(int(d), subsets) for s, d in perm)
    if any(c in name for c in _GROUPED_COLLECTIVES):
        groups = eqn.params.get("axis_index_groups")
        if "psum" in name:
            return _psum_groups_isolate(groups, subsets)
        return _gather_groups_isolate(groups, subsets)
    return True


def _collectives_respect_subsets(jaxpr, subsets) -> bool:
    for eqn in jaxpr.eqns:
        if not _eqn_respects_subsets(eqn, subsets):
            return False
        for inner in _sub_jaxprs(eqn.params):
            if not _collectives_respect_subsets(inner, subsets):
                return False
    return True
