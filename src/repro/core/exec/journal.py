"""journal — sweep-level resilient execution + crash-resume journal.

The sweep layer above :mod:`repro.core.exec.resilience`:
:func:`execute_plan` runs every planned dispatch of a DispatchPlan
through the resilient group path and folds outcomes into the
coordinator's triple-indexed maps; :func:`execute_rung_path` is the
legacy host-timed one-dispatch-per-rung loop behind the same retry
discipline; :class:`SweepJournal` is the append-only JSON-lines
sidecar that makes a killed sweep resumable — completed dispatch
groups restore VALUE-identically (exact decoded floats round-trip
through JSON) and only missing groups execute, with warm program/AOT
caches making the restart cheap.
"""
from __future__ import annotations

import hashlib
import json
import logging
import os
from typing import Any, Dict, List, Optional, Tuple

from repro.core.exec import plan as exec_plan
from repro.core.exec.assemble import observer_result
from repro.core.exec.resilience import (
    EntryOutcome, GroupExecutionError, QualityGate, RetryPolicy,
    _Ctx, _GroupState, _NON_RETRYABLE, _attempt_rung, run_group)

log = logging.getLogger(__name__)


def entry_key(e) -> str:
    """Stable journal identity of one (spec, observer, buffer) ladder:
    spec name + CurveDB curve key + buffer (the curve key alone can
    legally collide across distinctly-named specs)."""
    return "|".join((e.spec.name,
                     e.spec.key_for(e.observer, e.buffer_bytes),
                     str(e.buffer_bytes)))


def plan_fingerprint(plan, n_eng: int, mode: str, activity: str,
                     samples: int) -> str:
    keys = sorted(entry_key(e) for d in plan.dispatches
                  for e in d.entries)
    doc = json.dumps([n_eng, mode, activity, samples, keys])
    return hashlib.sha256(doc.encode()).hexdigest()[:32]


class SweepJournal:
    """Append-only JSON-lines sweep journal: a fingerprint header,
    then one line per completed dispatch group carrying every member
    ladder's exact decoded timings and provenance.  Restoring replays
    those floats verbatim, so a resumed sweep's finished curves are
    VALUE-EQUAL to the run that wrote them."""

    VERSION = 1

    def __init__(self, path: str, fingerprint: str,
                 done: Dict[str, Dict[str, Any]]):
        self.path = path
        self.fingerprint = fingerprint
        self._done = done

    @classmethod
    def open(cls, path, fingerprint: str) -> "SweepJournal":
        path = os.fspath(path)
        done: Dict[str, Dict[str, Any]] = {}
        if os.path.exists(path) and os.path.getsize(path) > 0:
            with open(path, encoding="utf-8") as f:
                lines = f.read().splitlines()
            try:
                head = json.loads(lines[0])
            except ValueError:
                raise ValueError(f"sweep journal {path!r}: unreadable "
                                 f"header — delete it to start over")
            if head.get("fingerprint") != fingerprint:
                raise ValueError(
                    f"sweep journal {path!r} belongs to a different "
                    f"sweep (matrix/mode/mesh changed) — delete it or "
                    f"pass a fresh path")
            for line in lines[1:]:
                try:
                    rec = json.loads(line)
                except ValueError:
                    break               # torn tail line from a crash
                for ent in rec.get("entries", ()):
                    done[ent["key"]] = ent
            return cls(path, fingerprint, done)
        with open(path, "w", encoding="utf-8") as f:
            f.write(json.dumps({"journal": "repro-sweep",
                                "version": cls.VERSION,
                                "fingerprint": fingerprint}) + "\n")
            f.flush()
            os.fsync(f.fileno())
        return cls(path, fingerprint, done)

    def lookup(self, planned) -> Optional[List[Dict[str, Any]]]:
        """All of this dispatch's ladders, if EVERY one completed in a
        previous run (partial groups re-execute whole — a dispatch is
        the atomic unit of work)."""
        recs = []
        for e in planned.entries:
            r = self._done.get(entry_key(e))
            if r is None:
                return None
            recs.append(r)
        return recs

    def record(self, planned, outcomes: List[EntryOutcome]) -> None:
        ents = [{"key": entry_key(o.entry), "med": o.med,
                 "fenced": o.fenced, "timing": o.timing}
                for o in outcomes]
        with open(self.path, "a", encoding="utf-8") as f:
            f.write(json.dumps({"entries": ents}) + "\n")
            f.flush()
            os.fsync(f.fileno())
        for ent in ents:
            self._done[ent["key"]] = ent


def _fold(outcome: EntryOutcome, executed, fenced_by, timing_by):
    e = outcome.entry
    for k, m in enumerate(outcome.med):
        if m is not None:
            executed[(e.index, k)] = observer_result(
                e.observer, e.buffer_bytes, e.spec.iters,
                float(max(m, 1.0)))
    fenced_by[e.index] = outcome.fenced
    timing_by[e.index] = outcome.timing


def execute_plan(dispatcher, plan, *, n_eng: int, activity: str,
                 mode: str, stats, policy: Optional[RetryPolicy] = None,
                 gate: Optional[QualityGate] = None, journal=None,
                 ) -> Tuple[Dict, Dict, Dict]:
    """Run every planned dispatch of a DispatchPlan resiliently and
    fold the outcomes into the coordinator's
    ``(executed, fenced_by_triple, timing_by_triple)`` maps.  With a
    ``journal`` (path or open :class:`SweepJournal`), completed groups
    from a previous run restore value-identically and each newly
    completed group is journaled before the next starts."""
    executed: Dict[Tuple[int, int], Any] = {}
    fenced_by: Dict[int, bool] = {}
    timing_by: Dict[int, Dict[str, Any]] = {}
    jr: Optional[SweepJournal] = None
    if journal is not None:
        jr = journal if isinstance(journal, SweepJournal) else \
            SweepJournal.open(journal, plan_fingerprint(
                plan, n_eng, mode, activity, dispatcher.samples))
    for planned in plan.dispatches:
        if jr is not None:
            recs = jr.lookup(planned)
            if recs is not None:
                for e, r in zip(planned.entries, recs):
                    _fold(EntryOutcome(
                        e, [None if m is None else float(m)
                            for m in r["med"]],
                        bool(r["fenced"]), dict(r["timing"])),
                        executed, fenced_by, timing_by)
                stats.resumed_ladders += planned.group
                continue
        outcomes = run_group(dispatcher, planned, n_eng=n_eng,
                             activity=activity, mode=mode, stats=stats,
                             policy=policy, gate=gate)
        for o in outcomes:
            _fold(o, executed, fenced_by, timing_by)
        if jr is not None:
            jr.record(planned, outcomes)
    return executed, fenced_by, timing_by


def execute_rung_path(dispatcher, triples, *, n_eng: int, activity: str,
                      stats, depth_fn, pools,
                      policy: Optional[RetryPolicy] = None,
                      gate: Optional[QualityGate] = None,
                      ) -> Tuple[Dict, Dict, Dict]:
    """The legacy host-timed one-dispatch-per-rung path, now behind
    the same retry/flagging discipline: a faulted rung retries with
    backoff and then models (isolated to its rung); noisy host-timed
    rungs are flagged without re-measurement."""
    ctx = _Ctx(dispatcher, n_eng, activity, "rung", stats,
               policy or RetryPolicy(), gate)
    executed: Dict[Tuple[int, int], Any] = {}
    fenced_by: Dict[int, bool] = {}
    timing_by: Dict[int, Dict[str, Any]] = {}
    for i, (spec, obs, buf) in enumerate(triples):
        state = _GroupState()
        fenced = True
        noisy_ks: List[int] = []
        timing: Dict[str, Any] = {
            "timing_source": "host", "samples": dispatcher.samples,
            "rung_time_spread_ns": [], "dispatches": 0,
            "batched": False, "group_size": 1, "aot": True,
            "packed": False, "subset_width": n_eng, "subset_index": 0}
        for k in range(depth_fn(spec)):
            roles, role_pools = exec_plan.rung_roles(spec, obs, buf, k,
                                                     n_eng)
            kind = exec_plan.operand_kind(role_pools, pools)
            try:
                elapsed, rung_fenced, spread, rung_aot = _attempt_rung(
                    ctx, roles, kind, state)
            except _NON_RETRYABLE as exc:
                raise GroupExecutionError(
                    f"dispatch group (specs=[{spec.name!r}], observers="
                    f"[{obs.pool!r}:{obs.strategy!r}], buffers=[{buf}])",
                    exc) from exc
            except Exception as exc:
                if not ctx.policy.modeled_floor:
                    raise GroupExecutionError(
                        f"dispatch group (specs=[{spec.name!r}], "
                        f"observers=[{obs.pool!r}:{obs.strategy!r}], "
                        f"buffers=[{buf}])", exc) from exc
                state.note(exc)
                log.warning("rung %d of %s faulted (%s); modeled",
                            k, spec.name, state.fault_kind)
                continue
            executed[(i, k)] = observer_result(obs, buf, spec.iters,
                                               elapsed)
            fenced = fenced and rung_fenced
            timing["aot"] = timing["aot"] and rung_aot
            timing["rung_time_spread_ns"].append(spread)
            # 1 warm + the timed samples
            timing["dispatches"] += 1 + dispatcher.samples
            if gate is not None and gate.noisy(elapsed, spread):
                noisy_ks.append(k)
        if noisy_ks:
            stats.noisy_rungs += len(noisy_ks)
        timing.update({"remeasures": 0, "attempts": state.attempts,
                       "degraded_from": state.origin(),
                       "fault_kind": state.fault_kind,
                       "noisy": bool(noisy_ks),
                       "noisy_rungs": noisy_ks})
        fenced_by[i] = fenced
        timing_by[i] = timing
    return executed, fenced_by, timing_by
