"""plan — stage 1 of the spmd execution pipeline.

Turns (specs -> (spec, observer, buffer) triples -> signature groups)
into a declarative :class:`DispatchPlan`: a sequence of
:class:`PlannedDispatch`es, each describing ONE host-synchronous mesh
dispatch — which ladders it stacks, the per-rung per-engine role
tables, the operand memory kind, and the mesh geometry (how many
engine subsets run side by side, how many scan-stacked waves).

Nothing in here touches jax: the plan is pure data, so planner
transforms compose.  The first such transform is
:func:`pack_engine_subsets` (engine-subset width-packing): on meshes
with at least twice a ladder's width of engines, several same-signature
shallow ladders run side by side on disjoint engine subsets of one
dispatch — each subset keeps its own psum sandwich via grouped
collectives — instead of scan-stacking every ladder behind the last.
Future planner transforms slot in the same way: multi-host sharding
splits a plan's dispatches across processes, and the worst-case
contention search emits its "next grid" as a plan.

The interpret/tpu measured pass groups through :func:`observer_groups`
in this module too, so grouping logic lives in exactly one place.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, replace
from typing import Any, Dict, List, Optional, Tuple

from repro.core.scenarios import ObserverSpec, ScenarioSpec
from repro.core.workloads import resolve_strategy, rows_for as _wl_rows

# ---------------------------------------------------------------------------


def effective_duty(shape) -> float:
    """Duty cycle of a role's traffic shape, with the degenerate-value
    guard every call site must share: absent shapes and 0/None duties
    count as always-on.  Work balancing *divides* by this (a 0-duty
    role would otherwise get an infinite iteration budget) and the
    observer's ``n_active`` stamping multiplies by it — both sides of
    the accounting must use the same number."""
    if shape is None:
        return 1.0
    return getattr(shape, "duty_cycle", 1.0) or 1.0


def ladder_depth(spec: ScenarioSpec, platform_engines: int,
                 mesh_engines: Optional[int] = None) -> int:
    """Rungs this spec's ladder measures: ``max_stressors + 1`` capped
    by the platform, and — on the spmd backend (``mesh_engines``
    given) — by the mesh: rung k needs k stress engines + 1 observer,
    plus one engine per coupled sibling observer, which runs live
    inside every rung (same count for every observer)."""
    n = (spec.max_stressors + 1 if spec.max_stressors is not None
         else platform_engines)
    n = min(n, platform_engines)
    if mesh_engines is not None:
        n = min(n, mesh_engines - spec.n_coupled_siblings)
    return max(1, n)


def rung_roles(spec: ScenarioSpec, obs: ObserverSpec, buf: int, k: int,
               width: int) -> Tuple[List[Tuple], List[str]]:
    """The per-engine role layout of rung k, padded to ``width``
    engines: engine 0 runs the observer, the next engines its coupled
    sibling observers (every observer of a coupled multi-observer spec
    is live inside every sibling's measured region), then k stressor
    engines (ensemble round-robin), the rest idle.  Returns
    ``(roles, role_pools)`` with one ``(strategy, shape, rows, iters)``
    tuple per engine.

    Sibling and stressor iteration budgets are work-balanced against
    the passes the observer branch will actually execute (its duty
    cycle included, via :func:`effective_duty` on BOTH sides of the
    division) so role imbalance does not masquerade as contention;
    residual per-kind speed differences (a chase row costs more than a
    stream row) remain and are what the in-dispatch rung clocks
    measure."""
    iters = spec.iters
    obs_rows = _wl_rows(buf)
    roles: List[Tuple] = [(obs.strategy, obs.shape, obs_rows, iters)]
    role_pools = [obs.pool]
    m = len(spec.stressors)
    obs_work = obs_rows * max(
        1, round(iters * effective_duty(obs.shape)))
    for sib in spec.coupled_siblings(obs)[:width - 1]:
        sib_rows = _wl_rows(sib.buffers[0])
        sib_iters = max(1, round(
            obs_work / (sib_rows * effective_duty(sib.shape))))
        roles.append((sib.strategy, sib.shape, sib_rows, sib_iters))
        role_pools.append(sib.pool)
    for e in range(min(k, width - len(roles))):
        if m:
            s = spec.stressors[e % m]
            s_rows = _wl_rows(s.buffer_bytes)
            s_iters = max(1, round(
                obs_work / (s_rows * effective_duty(s.shape))))
            roles.append((s.strategy, s.shape, s_rows, s_iters))
            role_pools.append(s.pool)
        else:
            roles.append(("i", None, 1, iters))
            role_pools.append(obs.pool)
    while len(roles) < width:
        roles.append(("i", None, 1, iters))
        role_pools.append(obs.pool)
    return roles, role_pools


def group_key(spec: ScenarioSpec, obs: ObserverSpec, buf: int,
              pools) -> Tuple:
    """Sweep-level grouping key: triples with equal keys expand to the
    SAME per-rung role tables and operand placement, so their ladders
    legally stack into one batched dispatch.  The spec-level role
    signature (pool-free — see :meth:`ScenarioSpec.ladder_signature`)
    is refined by each role pool's *effective* memory kind: pools that
    differ only in name but land in one physical memory merge (like
    the interpret path's signature groups); pools that really differ
    split."""
    kinds = tuple(pools.pool(p).effective_memory_kind()
                  for p in spec.role_pools(obs))
    return (spec.ladder_signature(obs, buf), kinds)


def operand_kind(role_pools, pools) -> Optional[str]:
    """Per-pool operand placement: when every engine's pool lands in
    one effective memory kind, the stacked operands carry that kind's
    sharding into the fused dispatch; mixed-pool programs fall back to
    the default memory (one stacked array has one memory kind —
    per-engine kinds need a real multi-chip slice and per-pool operand
    splitting, the remaining ROADMAP item)."""
    kinds = {pools.pool(p).effective_memory_kind() for p in role_pools}
    return kinds.pop() if len(kinds) == 1 else None


def observer_groups(triples, pools) -> "OrderedDict[Tuple, List[int]]":
    """The interpret/tpu measured pass's signature groups — the same
    planner owns every grouping decision.  Group signature: everything
    that changes the compiled measured pass or the numbers stamped on
    its results.  ``iters`` is part of the signature — members must be
    measured at THEIR OWN budget, not silently at the group max.  The
    pool appears only through its *effective* placement: observers
    from different pools whose arrays land in the same physical memory
    legally share one stacked vmapped batch; pools that really differ
    split."""
    groups: "OrderedDict[Tuple, List[int]]" = OrderedDict()
    for i, (spec, obs, buf) in enumerate(triples):
        pool = pools.pool(obs.pool)
        sig = (obs.strategy, obs.shape, buf, spec.iters,
               pool.effective_memory_kind(), pool.node.kind == "vmem")
        groups.setdefault(sig, []).append(i)
    return groups


# ---------------------------------------------------------------------------
# The plan data model
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LadderEntry:
    """One (spec, observer, buffer) contention ladder in the matrix."""
    index: int                  # position in the matrix's triple list
    spec: ScenarioSpec
    observer: ObserverSpec
    buffer_bytes: int


@dataclass(frozen=True)
class PlannedDispatch:
    """ONE host-synchronous mesh dispatch, fully described as data.

    ``rungs`` holds the per-rung role tuples at ``subset_width``
    engines; the program builder tiles them across ``n_subsets``
    disjoint engine subsets (width-packed dispatches) and idles any
    leftover engines, then scan-stacks the whole table ``waves``
    times.  Unpacked dispatches are the degenerate geometry: one
    subset as wide as the mesh, one wave per stacked ladder.

    ``probe=True`` marks a :func:`probe_batch` dispatch, whose rows are
    already laid out at FULL packed width (``n_subsets * subset_width``
    engines, one row per scan step): the builder pads each row to the
    mesh and stacks them verbatim instead of tiling/repeating."""
    entries: Tuple[LadderEntry, ...]
    rungs: Tuple[Tuple[Tuple, ...], ...]    # (n_scen, subset_width)
    n_scen: int
    ladder_width: int       # engines one ladder really occupies
    subset_width: int       # engines per subset (mesh width unpacked)
    n_subsets: int          # ladders side by side per wave (1 unpacked)
    waves: int              # scan-stacked repeats of the rung table
    kind: Optional[str]     # operand memory kind (None = mixed pools)
    packed: bool = False
    probe: bool = False

    @property
    def group(self) -> int:
        return len(self.entries)

    def subsets(self) -> Optional[Tuple[Tuple[int, ...], ...]]:
        """Engine-index tuples of the real (decoded) subsets; ``None``
        for unpacked dispatches (global psum sandwich)."""
        if not self.packed:
            return None
        return tuple(tuple(range(j * self.subset_width,
                                 (j + 1) * self.subset_width))
                     for j in range(self.n_subsets))

    def member_slot(self, g: int) -> Tuple[int, int]:
        """(wave, subset) coordinates of stacked ladder ``g``."""
        return g // self.n_subsets, g % self.n_subsets

    def cache_key(self, mode: str, n_eng: int, activity: str,
                  samples: int) -> Tuple:
        return (mode, n_eng, activity, self.kind, samples, self.group,
                self.n_subsets, self.subset_width, self.waves,
                self.probe, self.rungs)


@dataclass(frozen=True)
class DispatchPlan:
    n_engines: int
    dispatches: Tuple[PlannedDispatch, ...]


def _plan_dispatch(entries: List[LadderEntry], n_eng: int, pools,
                   platform_engines: int) -> PlannedDispatch:
    """One dispatch for a (possibly singleton) same-signature group:
    roles expanded at mesh width, one wave per stacked ladder."""
    first = entries[0]
    spec, obs, buf = first.spec, first.observer, first.buffer_bytes
    n_scen = ladder_depth(spec, platform_engines, n_eng)
    per_rung = [rung_roles(spec, obs, buf, k, n_eng)
                for k in range(n_scen)]
    kind = operand_kind([p for _r, ps in per_rung for p in ps], pools)
    return PlannedDispatch(
        entries=tuple(entries),
        rungs=tuple(tuple(r) for r, _p in per_rung),
        n_scen=n_scen,
        ladder_width=1 + spec.n_coupled_siblings + (n_scen - 1),
        subset_width=n_eng, n_subsets=1, waves=len(entries),
        kind=kind, packed=False)


def build_plan(triples, n_eng: int, pools, platform_engines: int, *,
               grouped: bool = True) -> DispatchPlan:
    """Stage 1: the whole matrix as a DispatchPlan.  ``grouped=True``
    (the sweep-batched mode) stacks same-signature ladders into one
    dispatch per distinct :func:`group_key`; ``grouped=False`` plans
    one dispatch per ladder (the fused-per-ladder mode)."""
    entries = [LadderEntry(i, spec, obs, buf)
               for i, (spec, obs, buf) in enumerate(triples)]
    if not grouped:
        return DispatchPlan(n_eng, tuple(
            _plan_dispatch([e], n_eng, pools, platform_engines)
            for e in entries))
    groups: "OrderedDict[Tuple, List[LadderEntry]]" = OrderedDict()
    for e in entries:
        key = group_key(e.spec, e.observer, e.buffer_bytes, pools)
        groups.setdefault(key, []).append(e)
    return DispatchPlan(n_eng, tuple(
        _plan_dispatch(members, n_eng, pools, platform_engines)
        for members in groups.values()))


# ---------------------------------------------------------------------------
# Planner transforms
# ---------------------------------------------------------------------------


def pack_engine_subsets(plan: DispatchPlan, *,
                        min_group: int = 2) -> DispatchPlan:
    """Engine-subset width-packing, as a PURE plan transform.

    A dispatch whose ladders occupy ``W = ladder_width`` engines on a
    mesh with ``n_engines >= 2 * W`` wastes most of the mesh idling:
    the stacked scan runs one ladder at a time with ``n_engines - W``
    engines spinning.  This transform re-plans such a group to run
    ``P = min(n_engines // W, group)`` ladders SIDE BY SIDE on
    disjoint W-engine subsets of one dispatch — the rung table shrinks
    to natural ladder width (the trailing idle padding drops off), the
    program builder tiles it across the P subsets, and the scan stacks
    only ``ceil(group / P)`` waves instead of ``group``.  An 8-device
    mesh running 2-engine rungs executes 4 ladders per dispatch
    instead of 1.

    Each packed subset keeps an INDEPENDENT psum sandwich (grouped
    collectives — see ``build_ladder_program(subsets=...)``), and the
    fence checker verifies every subset's sandwich separately, so a
    packed ladder's measurement is attributable to exactly its own
    engine slice.  Dispatches that cannot pack (mesh too narrow,
    singleton groups, already packed) pass through unchanged — as do
    probe-batch dispatches, whose rows are already laid out at full
    packed width by :func:`probe_batch`."""
    out = []
    for d in plan.dispatches:
        w, g = d.ladder_width, d.group
        if (d.packed or d.probe or w < 1 or plan.n_engines < 2 * w
                or g < min_group):
            out.append(d)
            continue
        p = min(plan.n_engines // w, g)
        out.append(replace(
            d,
            rungs=tuple(r[:w] for r in d.rungs),
            subset_width=w, n_subsets=p,
            waves=-(-g // p),           # ceil(group / P)
            packed=True))
    return replace(plan, dispatches=tuple(out))


def unpack_dispatch(d: PlannedDispatch) -> PlannedDispatch:
    """The inverse degradation rewrite of :func:`pack_engine_subsets`:
    re-plan a width-packed dispatch at the degenerate one-subset
    geometry (global psum sandwich, one scan wave per stacked ladder).

    The rung rows stay at their truncated natural width — the program
    builder pads every row back to the mesh with the same idle role
    the original unpacked plan carried (observer ``iters``), so the
    rewritten dispatch compiles to exactly the program the group would
    have run had packing never happened.  The resilience layer uses
    this as the first rung of the retry-degradation ladder: a packed
    dispatch that keeps faulting falls back to plain batched stacking.
    Unpacked and probe dispatches pass through unchanged (probe rows
    are laid out at full packed width — see :func:`split_probes`)."""
    if not d.packed or d.probe:
        return d
    return replace(d, subset_width=d.ladder_width, n_subsets=1,
                   waves=d.group, packed=False)


def split_ladders(d: PlannedDispatch) -> Tuple[PlannedDispatch, ...]:
    """Degradation rewrite: one single-ladder dispatch per stacked
    entry (the ``batched -> fused ladder`` step of the resilience
    ladder).  Every member of a batched group shares ONE rung table —
    that is what made them a group — so the split is pure geometry:
    the same rungs, one entry, one wave.  All the splits also share
    one program-cache key (entries are not part of the key), so a
    healthy split re-dispatches without re-tracing.  Packed dispatches
    unpack first; probe batches go through :func:`split_probes`."""
    if d.probe:
        return split_probes(d)
    base = unpack_dispatch(d)
    return tuple(replace(base, entries=(e,), waves=1)
                 for e in base.entries)


def split_probes(d: PlannedDispatch) -> Tuple[PlannedDispatch, ...]:
    """Degradation rewrite for probe batches: one single-probe
    dispatch per entry.  Probe rows are laid out at FULL packed width
    (``n_subsets * subset_width`` engines, slot ``g % P`` of wave
    ``g // P``), so probe ``g``'s roles are a contiguous slice of its
    wave's row; the single-probe dispatch carries that slice as its
    one scan row (the builder pads it back to the mesh) behind a
    global sandwich."""
    if not d.probe:
        return split_ladders(d)
    w = d.subset_width
    out = []
    for g, e in enumerate(d.entries):
        wave, slot = d.member_slot(g)
        row = d.rungs[wave][slot * w:(slot + 1) * w]
        out.append(replace(d, entries=(e,), rungs=(tuple(row),),
                           ladder_width=w, subset_width=w, n_subsets=1,
                           waves=1, packed=False))
    return tuple(out)


def rung_row(d: PlannedDispatch, k: int, n_eng: int) -> Tuple[Tuple, ...]:
    """Rung ``k``'s role row padded to the mesh — the per-rung
    degradation floor hands this straight to ``Dispatcher.run_rung``.
    Probe dispatches have exactly one row (``n_scen == 1``)."""
    row = list(d.rungs[0 if d.probe else k])
    idle = ("i", None, 1, d.rungs[0][0][3])
    while len(row) < n_eng:
        row.append(idle)
    return tuple(row)


# ---------------------------------------------------------------------------
# Probe batching (the worst-case search's planner transform)
# ---------------------------------------------------------------------------


def probe_batch(probes, n_eng: int, pools,
                platform_engines: int) -> PlannedDispatch:
    """ONE host-synchronous dispatch for a heterogeneous probe batch.

    ``probes`` is a sequence of ``(spec, observer, buffer_bytes, k)``
    tuples, each asking for a SINGLE contention rung (observer + ``k``
    live stressor engines at the spec's shape) — the worst-case search
    emits every iteration's candidate coordinates this way.  Unlike
    :func:`build_plan`'s same-signature stacking, the probes may carry
    DIFFERENT shapes, strategies and stressor counts: the per-rung
    branch table is pure data, so heterogeneous rungs legally stack as
    scan steps of one program.

    Geometry: every probe occupies one ``subset_width``-wide slot
    (the widest probe's natural width; narrower probes idle-pad their
    slot).  When the mesh fits ``P >= 2`` slots the batch width-packs —
    ``P`` probes run side by side per scan wave, each slot with its own
    grouped-psum sandwich — otherwise the degenerate one-slot geometry
    scan-stacks one probe per wave behind a global sandwich.  Each row
    of ``rungs`` is one scan step at FULL packed width
    (``n_subsets * subset_width``); a ragged last wave idle-fills its
    spare slots.  ``member_slot`` and the dispatcher's clock decode
    work unchanged: probe ``g`` is wave ``g // P``, slot ``g % P``,
    ``n_scen == 1``.

    The dispatch reuses the builder/dispatcher verbatim — no new
    execution machinery — so a search iteration costs exactly one
    host sync (``DispatchStats.host_sync_dispatches += 1``)."""
    probes = list(probes)
    if not probes:
        raise ValueError("probe_batch needs at least one probe")
    widths = []
    for spec, obs, buf, k in probes:
        depth = ladder_depth(spec, platform_engines, n_eng)
        if not 0 <= k < depth:
            raise ValueError(
                f"probe {spec.name!r}: k={k} outside this mesh's ladder "
                f"depth [0, {depth})")
        widths.append(1 + spec.n_coupled_siblings + k)
    w = max(widths)
    p = max(1, min(n_eng // w, len(probes)))
    if p == 1:
        w = n_eng               # degenerate slot: global psum sandwich
    waves = -(-len(probes) // p)
    idle = ("i", None, 1, probes[0][0].iters)
    rows: List[Tuple[Tuple, ...]] = []
    role_pools: List[str] = []
    for v in range(waves):
        row: List[Tuple] = []
        for j in range(p):
            g = v * p + j
            if g < len(probes):
                spec, obs, buf, k = probes[g]
                roles, rp = rung_roles(spec, obs, buf, k, w)
                row.extend(roles)
                role_pools.extend(rp)
            else:
                row.extend([idle] * w)
        rows.append(tuple(row))
    merge_probe_operand_roles(rows)     # raise on chain conflicts now
    return PlannedDispatch(
        entries=tuple(LadderEntry(g, spec, obs, buf)
                      for g, (spec, obs, buf, _k) in enumerate(probes)),
        rungs=tuple(rows),
        n_scen=1,
        ladder_width=w, subset_width=w, n_subsets=p, waves=waves,
        kind=operand_kind(role_pools, pools),
        packed=p > 1, probe=True)


def _chain_req(role) -> Optional[Tuple]:
    """The pointer-chain an engine running ``role`` needs seeded into
    its int operand: ``None`` for streams/idle, ``("stride", s, rows)``
    for strided chases, ``("cycle", rows)`` for seeded Sattolo walks."""
    strategy, shape, rows, _iters = role
    strat = resolve_strategy(strategy, shape)
    if strat == "t":
        return ("stride", getattr(shape, "stride", 8) or 8, rows)
    if strat in ("l", "m"):
        return ("cycle", rows)
    return None


def merge_probe_operand_roles(rows) -> List[Tuple]:
    """One operand-seeding role per engine serving EVERY scan row of a
    probe batch.  Operands are built once per dispatch, so an engine
    whose rows disagree on the chain they need (different stride or
    traversal length — a truncated Sattolo cycle is not a cycle) has no
    single valid operand: that is a planning error, raised here with
    the conflicting requirements named.  Streams only ever read the
    shared float buffer, so a chase row and a stream row on one engine
    coexist; among chain-free rows the widest wins (row count only
    feeds the operand padding)."""
    width = max(len(r) for r in rows)
    merged: List[Optional[Tuple]] = [None] * width
    chains: List[Optional[Tuple]] = [None] * width
    for row in rows:
        for e, role in enumerate(row):
            req = _chain_req(role)
            if req is not None:
                if chains[e] is not None and chains[e] != req:
                    raise ValueError(
                        f"probe batch: engine {e} needs conflicting "
                        f"chase chains {chains[e]} and {req} across "
                        f"scan rows — split these probes into "
                        f"separate batches")
                if chains[e] is None:
                    chains[e] = req
                    merged[e] = role
            elif chains[e] is None and (merged[e] is None
                                        or role[2] > merged[e][2]):
                merged[e] = role
    return [m if m is not None else ("i", None, 1, 1) for m in merged]
