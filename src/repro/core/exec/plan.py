"""plan — stage 1 of the spmd execution pipeline.

Turns (specs -> (spec, observer, buffer) triples -> signature groups)
into a declarative :class:`DispatchPlan`: a sequence of
:class:`PlannedDispatch`es, each describing ONE host-synchronous mesh
dispatch — which ladders it stacks, the per-rung per-engine role
tables, the operand memory kind, and the mesh geometry (how many
engine subsets run side by side, how many scan-stacked waves).

Nothing in here touches jax: the plan is pure data, so planner
transforms compose.  The first such transform is
:func:`pack_engine_subsets` (engine-subset width-packing): on meshes
with at least twice a ladder's width of engines, several same-signature
shallow ladders run side by side on disjoint engine subsets of one
dispatch — each subset keeps its own psum sandwich via grouped
collectives — instead of scan-stacking every ladder behind the last.
Future planner transforms slot in the same way: multi-host sharding
splits a plan's dispatches across processes, and the worst-case
contention search emits its "next grid" as a plan.

The interpret/tpu measured pass groups through :func:`observer_groups`
in this module too, so grouping logic lives in exactly one place.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, replace
from typing import Any, Dict, List, Optional, Tuple

from repro.core.scenarios import ObserverSpec, ScenarioSpec
from repro.core.workloads import rows_for as _wl_rows

# ---------------------------------------------------------------------------


def effective_duty(shape) -> float:
    """Duty cycle of a role's traffic shape, with the degenerate-value
    guard every call site must share: absent shapes and 0/None duties
    count as always-on.  Work balancing *divides* by this (a 0-duty
    role would otherwise get an infinite iteration budget) and the
    observer's ``n_active`` stamping multiplies by it — both sides of
    the accounting must use the same number."""
    if shape is None:
        return 1.0
    return getattr(shape, "duty_cycle", 1.0) or 1.0


def ladder_depth(spec: ScenarioSpec, platform_engines: int,
                 mesh_engines: Optional[int] = None) -> int:
    """Rungs this spec's ladder measures: ``max_stressors + 1`` capped
    by the platform, and — on the spmd backend (``mesh_engines``
    given) — by the mesh: rung k needs k stress engines + 1 observer,
    plus one engine per coupled sibling observer, which runs live
    inside every rung (same count for every observer)."""
    n = (spec.max_stressors + 1 if spec.max_stressors is not None
         else platform_engines)
    n = min(n, platform_engines)
    if mesh_engines is not None:
        n = min(n, mesh_engines - spec.n_coupled_siblings)
    return max(1, n)


def rung_roles(spec: ScenarioSpec, obs: ObserverSpec, buf: int, k: int,
               width: int) -> Tuple[List[Tuple], List[str]]:
    """The per-engine role layout of rung k, padded to ``width``
    engines: engine 0 runs the observer, the next engines its coupled
    sibling observers (every observer of a coupled multi-observer spec
    is live inside every sibling's measured region), then k stressor
    engines (ensemble round-robin), the rest idle.  Returns
    ``(roles, role_pools)`` with one ``(strategy, shape, rows, iters)``
    tuple per engine.

    Sibling and stressor iteration budgets are work-balanced against
    the passes the observer branch will actually execute (its duty
    cycle included, via :func:`effective_duty` on BOTH sides of the
    division) so role imbalance does not masquerade as contention;
    residual per-kind speed differences (a chase row costs more than a
    stream row) remain and are what the in-dispatch rung clocks
    measure."""
    iters = spec.iters
    obs_rows = _wl_rows(buf)
    roles: List[Tuple] = [(obs.strategy, obs.shape, obs_rows, iters)]
    role_pools = [obs.pool]
    m = len(spec.stressors)
    obs_work = obs_rows * max(
        1, round(iters * effective_duty(obs.shape)))
    for sib in spec.coupled_siblings(obs)[:width - 1]:
        sib_rows = _wl_rows(sib.buffers[0])
        sib_iters = max(1, round(
            obs_work / (sib_rows * effective_duty(sib.shape))))
        roles.append((sib.strategy, sib.shape, sib_rows, sib_iters))
        role_pools.append(sib.pool)
    for e in range(min(k, width - len(roles))):
        if m:
            s = spec.stressors[e % m]
            s_rows = _wl_rows(s.buffer_bytes)
            s_iters = max(1, round(
                obs_work / (s_rows * effective_duty(s.shape))))
            roles.append((s.strategy, s.shape, s_rows, s_iters))
            role_pools.append(s.pool)
        else:
            roles.append(("i", None, 1, iters))
            role_pools.append(obs.pool)
    while len(roles) < width:
        roles.append(("i", None, 1, iters))
        role_pools.append(obs.pool)
    return roles, role_pools


def group_key(spec: ScenarioSpec, obs: ObserverSpec, buf: int,
              pools) -> Tuple:
    """Sweep-level grouping key: triples with equal keys expand to the
    SAME per-rung role tables and operand placement, so their ladders
    legally stack into one batched dispatch.  The spec-level role
    signature (pool-free — see :meth:`ScenarioSpec.ladder_signature`)
    is refined by each role pool's *effective* memory kind: pools that
    differ only in name but land in one physical memory merge (like
    the interpret path's signature groups); pools that really differ
    split."""
    kinds = tuple(pools.pool(p).effective_memory_kind()
                  for p in spec.role_pools(obs))
    return (spec.ladder_signature(obs, buf), kinds)


def operand_kind(role_pools, pools) -> Optional[str]:
    """Per-pool operand placement: when every engine's pool lands in
    one effective memory kind, the stacked operands carry that kind's
    sharding into the fused dispatch; mixed-pool programs fall back to
    the default memory (one stacked array has one memory kind —
    per-engine kinds need a real multi-chip slice and per-pool operand
    splitting, the remaining ROADMAP item)."""
    kinds = {pools.pool(p).effective_memory_kind() for p in role_pools}
    return kinds.pop() if len(kinds) == 1 else None


def observer_groups(triples, pools) -> "OrderedDict[Tuple, List[int]]":
    """The interpret/tpu measured pass's signature groups — the same
    planner owns every grouping decision.  Group signature: everything
    that changes the compiled measured pass or the numbers stamped on
    its results.  ``iters`` is part of the signature — members must be
    measured at THEIR OWN budget, not silently at the group max.  The
    pool appears only through its *effective* placement: observers
    from different pools whose arrays land in the same physical memory
    legally share one stacked vmapped batch; pools that really differ
    split."""
    groups: "OrderedDict[Tuple, List[int]]" = OrderedDict()
    for i, (spec, obs, buf) in enumerate(triples):
        pool = pools.pool(obs.pool)
        sig = (obs.strategy, obs.shape, buf, spec.iters,
               pool.effective_memory_kind(), pool.node.kind == "vmem")
        groups.setdefault(sig, []).append(i)
    return groups


# ---------------------------------------------------------------------------
# The plan data model
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LadderEntry:
    """One (spec, observer, buffer) contention ladder in the matrix."""
    index: int                  # position in the matrix's triple list
    spec: ScenarioSpec
    observer: ObserverSpec
    buffer_bytes: int


@dataclass(frozen=True)
class PlannedDispatch:
    """ONE host-synchronous mesh dispatch, fully described as data.

    ``rungs`` holds the per-rung role tuples at ``subset_width``
    engines; the program builder tiles them across ``n_subsets``
    disjoint engine subsets (width-packed dispatches) and idles any
    leftover engines, then scan-stacks the whole table ``waves``
    times.  Unpacked dispatches are the degenerate geometry: one
    subset as wide as the mesh, one wave per stacked ladder."""
    entries: Tuple[LadderEntry, ...]
    rungs: Tuple[Tuple[Tuple, ...], ...]    # (n_scen, subset_width)
    n_scen: int
    ladder_width: int       # engines one ladder really occupies
    subset_width: int       # engines per subset (mesh width unpacked)
    n_subsets: int          # ladders side by side per wave (1 unpacked)
    waves: int              # scan-stacked repeats of the rung table
    kind: Optional[str]     # operand memory kind (None = mixed pools)
    packed: bool = False

    @property
    def group(self) -> int:
        return len(self.entries)

    def subsets(self) -> Optional[Tuple[Tuple[int, ...], ...]]:
        """Engine-index tuples of the real (decoded) subsets; ``None``
        for unpacked dispatches (global psum sandwich)."""
        if not self.packed:
            return None
        return tuple(tuple(range(j * self.subset_width,
                                 (j + 1) * self.subset_width))
                     for j in range(self.n_subsets))

    def member_slot(self, g: int) -> Tuple[int, int]:
        """(wave, subset) coordinates of stacked ladder ``g``."""
        return g // self.n_subsets, g % self.n_subsets

    def cache_key(self, mode: str, n_eng: int, activity: str,
                  samples: int) -> Tuple:
        return (mode, n_eng, activity, self.kind, samples, self.group,
                self.n_subsets, self.subset_width, self.waves,
                self.rungs)


@dataclass(frozen=True)
class DispatchPlan:
    n_engines: int
    dispatches: Tuple[PlannedDispatch, ...]


def _plan_dispatch(entries: List[LadderEntry], n_eng: int, pools,
                   platform_engines: int) -> PlannedDispatch:
    """One dispatch for a (possibly singleton) same-signature group:
    roles expanded at mesh width, one wave per stacked ladder."""
    first = entries[0]
    spec, obs, buf = first.spec, first.observer, first.buffer_bytes
    n_scen = ladder_depth(spec, platform_engines, n_eng)
    per_rung = [rung_roles(spec, obs, buf, k, n_eng)
                for k in range(n_scen)]
    kind = operand_kind([p for _r, ps in per_rung for p in ps], pools)
    return PlannedDispatch(
        entries=tuple(entries),
        rungs=tuple(tuple(r) for r, _p in per_rung),
        n_scen=n_scen,
        ladder_width=1 + spec.n_coupled_siblings + (n_scen - 1),
        subset_width=n_eng, n_subsets=1, waves=len(entries),
        kind=kind, packed=False)


def build_plan(triples, n_eng: int, pools, platform_engines: int, *,
               grouped: bool = True) -> DispatchPlan:
    """Stage 1: the whole matrix as a DispatchPlan.  ``grouped=True``
    (the sweep-batched mode) stacks same-signature ladders into one
    dispatch per distinct :func:`group_key`; ``grouped=False`` plans
    one dispatch per ladder (the fused-per-ladder mode)."""
    entries = [LadderEntry(i, spec, obs, buf)
               for i, (spec, obs, buf) in enumerate(triples)]
    if not grouped:
        return DispatchPlan(n_eng, tuple(
            _plan_dispatch([e], n_eng, pools, platform_engines)
            for e in entries))
    groups: "OrderedDict[Tuple, List[LadderEntry]]" = OrderedDict()
    for e in entries:
        key = group_key(e.spec, e.observer, e.buffer_bytes, pools)
        groups.setdefault(key, []).append(e)
    return DispatchPlan(n_eng, tuple(
        _plan_dispatch(members, n_eng, pools, platform_engines)
        for members in groups.values()))


# ---------------------------------------------------------------------------
# Planner transforms
# ---------------------------------------------------------------------------


def pack_engine_subsets(plan: DispatchPlan, *,
                        min_group: int = 2) -> DispatchPlan:
    """Engine-subset width-packing, as a PURE plan transform.

    A dispatch whose ladders occupy ``W = ladder_width`` engines on a
    mesh with ``n_engines >= 2 * W`` wastes most of the mesh idling:
    the stacked scan runs one ladder at a time with ``n_engines - W``
    engines spinning.  This transform re-plans such a group to run
    ``P = min(n_engines // W, group)`` ladders SIDE BY SIDE on
    disjoint W-engine subsets of one dispatch — the rung table shrinks
    to natural ladder width (the trailing idle padding drops off), the
    program builder tiles it across the P subsets, and the scan stacks
    only ``ceil(group / P)`` waves instead of ``group``.  An 8-device
    mesh running 2-engine rungs executes 4 ladders per dispatch
    instead of 1.

    Each packed subset keeps an INDEPENDENT psum sandwich (grouped
    collectives — see ``build_ladder_program(subsets=...)``), and the
    fence checker verifies every subset's sandwich separately, so a
    packed ladder's measurement is attributable to exactly its own
    engine slice.  Dispatches that cannot pack (mesh too narrow,
    singleton groups, already packed) pass through unchanged."""
    out = []
    for d in plan.dispatches:
        w, g = d.ladder_width, d.group
        if (d.packed or w < 1 or plan.n_engines < 2 * w
                or g < min_group):
            out.append(d)
            continue
        p = min(plan.n_engines // w, g)
        out.append(replace(
            d,
            rungs=tuple(r[:w] for r in d.rungs),
            subset_width=w, n_subsets=p,
            waves=-(-g // p),           # ceil(group / P)
            packed=True))
    return replace(plan, dispatches=tuple(out))
