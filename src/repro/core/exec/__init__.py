"""The spmd execution pipeline: plan -> build -> dispatch -> assemble.

One stage per module, each consuming the previous stage's declarative
output, so ROADMAP items inject themselves into exactly one seam:

* :mod:`repro.core.exec.plan` — (specs -> triples -> signature groups)
  as a :class:`DispatchPlan` of :class:`PlannedDispatch`es, plus the
  pure planner transforms (engine-subset width-packing lives here).
* :mod:`repro.core.exec.program` — branch/activity builders, operand
  construction, the SPMD program builders, and
  :func:`build_ladder_entry` producing a traced + fence-verified
  :class:`CompiledProgram`.
* :mod:`repro.core.exec.fence` — the structural psum-sandwich checker
  (:func:`measured_region_is_fenced`), packed-subset aware.
* :mod:`repro.core.exec.dispatch` — the program/operand LRU, AOT
  compile + persistent-cache opt-in, dispatch, and the
  (waves, subsets, rungs, samples) clock decode.
* :mod:`repro.core.exec.assemble` — ScenarioRun / execution-provenance
  construction from the dispatch results.
* :mod:`repro.core.exec.resilience` — fault injection, retry with the
  packed->batched->ladder->rung->modeled degradation ladder, and the
  per-rung measurement quality gate.
* :mod:`repro.core.exec.journal` — sweep-level resilient plan
  execution and the crash-resume :class:`SweepJournal`.

``CoreCoordinator`` (repro.core.coordinator) is the thin facade over
this package; its public API is unchanged.
"""
from repro.core.exec.assemble import (MatrixResult, ScenarioResult,
                                      ScenarioRun, assemble_runs,
                                      observer_result)
from repro.core.exec.dispatch import Dispatcher, DispatchStats, ProgramCache
from repro.core.exec.fence import measured_region_is_fenced
from repro.core.exec.journal import (SweepJournal, entry_key,
                                     execute_plan, execute_rung_path,
                                     plan_fingerprint)
from repro.core.exec.plan import (DispatchPlan, LadderEntry,
                                  PlannedDispatch, build_plan,
                                  effective_duty, group_key, ladder_depth,
                                  observer_groups, pack_engine_subsets,
                                  rung_roles, rung_row, split_ladders,
                                  split_probes, unpack_dispatch)
from repro.core.exec.resilience import (FaultInjector, FaultSpec,
                                        GroupExecutionError,
                                        InjectedFault, QualityGate,
                                        RetryPolicy, run_group,
                                        resolve_faults, resolve_gate)
from repro.core.exec.program import (CompiledProgram, build_ladder_entry,
                                     build_ladder_program,
                                     build_rung_operands,
                                     build_rung_program,
                                     build_scenario_program,
                                     spmd_branch_fn)

__all__ = [
    "MatrixResult", "ScenarioResult", "ScenarioRun", "assemble_runs",
    "observer_result", "Dispatcher", "DispatchStats", "ProgramCache",
    "measured_region_is_fenced", "DispatchPlan", "LadderEntry",
    "PlannedDispatch", "build_plan", "effective_duty", "group_key",
    "ladder_depth", "observer_groups", "pack_engine_subsets",
    "rung_roles", "rung_row", "split_ladders", "split_probes",
    "unpack_dispatch", "CompiledProgram", "build_ladder_entry",
    "build_ladder_program", "build_rung_operands", "build_rung_program",
    "build_scenario_program", "spmd_branch_fn", "FaultInjector",
    "FaultSpec", "GroupExecutionError", "InjectedFault", "QualityGate",
    "RetryPolicy", "run_group", "resolve_faults", "resolve_gate",
    "SweepJournal", "entry_key", "execute_plan", "execute_rung_path",
    "plan_fingerprint",
]
