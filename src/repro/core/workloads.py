"""Workload Library — registry of micro-benchmark activities (Table I).

Each workload is keyed by its access-strategy letter and binds a memory
pool + buffer size to a runnable activity.  Workloads carry:

* a **buffer initialiser** (the paper's configurable init: sequential
  ints for bandwidth sanity-checking, a Sattolo chain for latency);
* an **executable** (jit'd Pallas kernel, interpret=True off-TPU) used by
  the ``interpret``/``tpu`` backends;
* the **queueing-class parameters** (strategy letter, traffic multiplier,
  MLP) consumed by the ``simulate`` backend.

The cacheable strategies (r/w/l) become VMEM-resident kernels when the
buffer fits the VMEM budget and HBM-streaming kernels otherwise — the
software-managed-hierarchy analog of "whether the buffer fits in L2",
which is exactly how the paper's Fig. 5 buffer-size sweeps behave.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.devicetree import MemoryNode
from repro.core.pools import Allocation, MemoryPool
from repro.kernels import ops

LANE = 128
LINE_BYTES = LANE * 4          # one (1,128) f32 row = 512 B "line"
VMEM_BUDGET = 64 << 20         # "cache size": cacheable buffers <= this
                               # are VMEM-resident (the L2-fit analog)
_EXEC_VMEM_CAP = 4 << 20       # interpret-mode practicality cap (CPU)


@dataclass
class WorkloadResult:
    strategy: str
    pool: str
    buffer_bytes: int
    iters: int
    bytes_moved: int           # useful bytes touched (all iters)
    elapsed_ns: float          # wall time (interpret/tpu backends)
    transactions: int          # dependent loads for latency workloads

    @property
    def bandwidth_gbps(self) -> float:
        if self.elapsed_ns <= 0:
            return 0.0
        return self.bytes_moved / self.elapsed_ns

    @property
    def latency_ns(self) -> float:
        if self.transactions <= 0:
            return 0.0
        return self.elapsed_ns / self.transactions


@dataclass
class Workload:
    """A bound activity: strategy letter + pool + buffer."""
    strategy: str
    pool: MemoryPool
    buffer_bytes: int
    description: str
    run_fn: Callable[[int], WorkloadResult]
    alloc: Optional[Allocation] = None
    is_memory_bound: bool = True

    def run(self, iters: int = 500) -> WorkloadResult:
        return self.run_fn(iters)

    def release(self) -> None:
        if self.alloc is not None:
            self.pool.free(self.alloc)
            self.alloc = None

    @property
    def node(self) -> MemoryNode:
        return self.pool.node


# ---------------------------------------------------------------------------
# Buffer initialisers (paper: "Configurable Buffer Initialization")
# ---------------------------------------------------------------------------


def bw_buffer_init(shape, dtype):
    """Sequential integers — lets experiments sanity-check corruption."""
    n = int(np.prod(shape))
    return jnp.arange(n, dtype=jnp.float32).reshape(shape).astype(dtype)


def latency_buffer_init(n_lines: int, seed: int = 0):
    return jnp.asarray(ops.chain_buffer(n_lines, seed))


# ---------------------------------------------------------------------------
# Factory
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[..., Workload]] = {}


def register_strategy(letter: str):
    def deco(fn):
        _REGISTRY[letter] = fn
        return fn
    return deco


def strategies() -> Dict[str, str]:
    return {k: (v.__doc__ or "").strip().splitlines()[0]
            for k, v in sorted(_REGISTRY.items())}


def make_workload(strategy: str, pool: MemoryPool, buffer_bytes: int,
                  **kw) -> Workload:
    if strategy not in _REGISTRY:
        raise KeyError(
            f"unknown access strategy {strategy!r}; have "
            f"{sorted(_REGISTRY)}")
    return _REGISTRY[strategy](pool, buffer_bytes, **kw)


def resolve_strategy(strategy: str, shape=None) -> str:
    """The strategy letter a (strategy, TrafficShape) pair actually
    executes as: mixed shapes run the ``b`` mixed-stream workload,
    strided shapes the ``t`` strided chase, everything else the plain
    strategy.  The single source of truth for this mapping — the
    batched group measurement below and the coordinator's spmd branch
    builder both consume it, so every backend executes the same kernel
    class for a given spec."""
    kind = getattr(shape, "kind", "steady") if shape is not None \
        else "steady"
    return {"mixed": "b", "strided": "t"}.get(kind, strategy)


def make_shaped_workload(strategy: str, pool: MemoryPool, buffer_bytes: int,
                         shape=None, **kw) -> Workload:
    """Bind a (strategy, TrafficShape) pair to an executable workload.

    Steady shapes resolve to the plain strategy; mixed ratios map onto
    the ``b`` mixed-stream workload, strided shapes onto the ``t``
    strided chase, and bursty shapes wrap the base workload with
    duty-cycled accounting (the off phase is pure idle, so the
    time-averaged bandwidth scales by the duty cycle)."""
    if shape is None or getattr(shape, "is_steady", True):
        return make_workload(strategy, pool, buffer_bytes, **kw)
    if shape.kind == "mixed":
        return make_workload("b", pool, buffer_bytes,
                             read_fraction=shape.read_fraction, **kw)
    if shape.kind == "strided":
        return make_workload("t", pool, buffer_bytes,
                             stride=shape.stride, **kw)
    if shape.kind == "burst":
        wl = make_workload(strategy, pool, buffer_bytes, **kw)
        return _duty_cycled(wl, shape.duty_cycle)
    raise KeyError(f"unknown traffic shape kind {shape.kind!r}")


def _duty_cycled(wl: Workload, duty: float) -> Workload:
    import dataclasses
    base_run = wl.run_fn

    def run(iters):
        res = base_run(iters)
        idle_ns = res.elapsed_ns * (1.0 - duty) / duty
        return dataclasses.replace(res, elapsed_ns=res.elapsed_ns + idle_ns)

    wl.run_fn = run
    wl.description = f"{wl.description} (duty={duty:g})"
    return wl


# ---------------------------------------------------------------------------
# Batched group measurement (the matrix runner's fast path)
# ---------------------------------------------------------------------------

# observer strategies whose measured pass maps over a stacked input
# array, so G same-shape scenarios collapse into ONE jit'd vmapped
# dispatch (read-like paths; chases keep per-member Sattolo chains) —
# write-like paths and the deterministic strided chase ('t', whose
# members are bit-identical) carry no distinct batched input, so their
# group measures once and shares the result.
_VMAP_READS = ("r", "s", "c", "x", "b")
_VMAP_CHASES = ("l", "m")


# batched measurement stacks member buffers into one array; cap the
# stack so a big group cannot out-allocate the device (the naive path
# only ever holds ONE member buffer)
_BATCH_BYTES_CAP = 1 << 30


def measure_group(strategy: str, pool: MemoryPool, buffer_bytes: int,
                  n_members: int, iters: int, *, shape=None,
                  seeds: Optional[list] = None,
                  member_pools: Optional[list] = None) -> Tuple[list, int]:
    """Measure ``n_members`` same-signature observers with jit'd
    ``vmap`` passes over the stacked member buffers (chases keep
    per-member chains, so different seeds/strides stay distinct).

    ``member_pools`` (optional, len ``n_members``) supports
    *heterogeneous* groups: observers from different pools whose
    placement lands in the same physical memory (the caller groups by
    :meth:`MemoryPool.effective_memory_kind`, so this never stacks
    buffers that would really live in different memories).  Each
    member's result is labeled with its own pool name.

    Returns ``(results, n_dispatches)``.  Normally one dispatch covers
    the whole group; groups whose stacked footprint would exceed the
    batch byte cap or the pool's free space split into chunks (the
    naive path only ever holds ONE member buffer, so the batched path
    must not out-allocate it unboundedly), each chunk one dispatch.
    The group's wall time is split evenly (members are identical up to
    buffer content, and on hardware they run as concurrent engines of
    one fused pass)."""
    strat = resolve_strategy(strategy, shape)
    if strat not in _VMAP_READS + _VMAP_CHASES:
        # write-like path stacks no buffers: one measurement serves
        # the whole group regardless of member size
        chunk = n_members
    else:
        member_bytes = _rows(buffer_bytes) * LINE_BYTES
        budget = min(_BATCH_BYTES_CAP, max(pool.available, member_bytes))
        chunk = max(1, min(n_members, budget // member_bytes))
    results: list = []
    dispatches = 0
    for start in range(0, n_members, chunk):
        g = min(chunk, n_members - start)
        results.extend(_measure_chunk(
            strategy, pool, buffer_bytes, g, iters, shape=shape,
            seeds=(seeds[start:start + g] if seeds is not None
                   else list(range(start, start + g))),
            pool_names=([p.node.name for p in
                         member_pools[start:start + g]]
                        if member_pools is not None else None)))
        dispatches += 1
    return results, dispatches


def _measure_chunk(strategy: str, pool: MemoryPool, buffer_bytes: int,
                   n_members: int, iters: int, *, shape=None,
                   seeds: Optional[list] = None,
                   pool_names: Optional[list] = None) -> list:
    rows = _rows(buffer_bytes)
    g = n_members
    names = pool_names or [pool.node.name] * g
    vmem = _fits_vmem(buffer_bytes) or pool.node.kind == "vmem"
    blk = min(512, rows)
    strat = resolve_strategy(strategy, shape)

    duty = shape.duty_cycle if (shape is not None
                                and shape.kind == "burst") else 1.0

    if strat in _VMAP_CHASES:
        seeds = seeds or list(range(g))
        bufs = np.stack([ops.chain_buffer(rows, s) for s in seeds])
        bufs = pool.place(jnp.asarray(bufs))
        fn = ops.chase_vmem if (strat == "l" and vmem) else ops.chase_hbm
        batched = jax.jit(jax.vmap(
            lambda b: fn(b, n_steps=rows)))
        t = _timed(batched, bufs, iters=max(1, iters // 10))
        # /g assumes the g chains execute back-to-back within the pass,
        # which holds for the emulated backends this container runs
        # (test_batched_chase_latency_matches_naive guards it); a
        # compiled TPU vmap may overlap chains and would need its own
        # accounting.
        per = (t / g) / duty
        return [WorkloadResult(strat, name, buffer_bytes, iters,
                               rows * LINE_BYTES, per, transactions=rows)
                for name in names]

    if strat in _VMAP_READS:
        x = pool.place(bw_buffer_init((g, rows, LANE), jnp.float32))
        scale = 1.0
        useful = rows * LINE_BYTES
        if strat == "b":
            rf = (shape.read_fraction
                  if shape is not None and shape.kind == "mixed" else 0.5)
            batched = jax.jit(jax.vmap(
                lambda a: ops.stream_mixed(a, read_fraction=rf,
                                           block_rows=blk)))
        elif strat == "c":
            batched = jax.jit(jax.vmap(
                lambda a: ops.stream_copy(a, block_rows=blk)))
            useful = 2 * rows * LINE_BYTES
        elif strat == "x":
            batched = jax.jit(jax.vmap(
                lambda a: ops.stream_rmw(a, block_rows=blk)))
            useful = 2 * rows * LINE_BYTES
        elif vmem and strat == "r":
            batched = jax.jit(jax.vmap(
                lambda a: ops.vmem_read(a, repeats=8)))
            scale = 1.0 / 8.0               # 8 on-chip re-reads per call
        else:
            batched = jax.jit(jax.vmap(
                lambda a: ops.stream_read(a, block_rows=blk)))
        t = _timed(batched, x, iters=iters) * scale
        per = (t / g) / duty
        return [WorkloadResult(strat, name, buffer_bytes, iters,
                               useful * iters, per * iters, 0)
                for name in names]

    # write-like paths (w/x/y/i...): no batched input array — one
    # measurement, shared by every identical member (relabeled with
    # each member's own pool for heterogeneous groups).
    wl = make_shaped_workload(strategy, pool, buffer_bytes, shape)
    try:
        res = wl.run(iters)
    finally:
        wl.release()
    import dataclasses
    return [res if name == res.pool else dataclasses.replace(res, pool=name)
            for name in names]


def _rows(buffer_bytes: int) -> int:
    rows = max(1, buffer_bytes // LINE_BYTES)
    # keep divisible by the largest block we use
    block = 512 if rows >= 512 else rows
    return (rows // block) * block or rows


def rows_for(buffer_bytes: int) -> int:
    """Public spelling of the buffer->line-rows mapping every backend
    shares (block-aligned row count for a byte budget); the spmd rung
    builder and the batched measured pass must agree on it exactly."""
    return _rows(buffer_bytes)


def _timed(fn, *args, iters: int, **kw) -> float:
    """Median-of-3 wall time for `iters` back-to-back calls, ns."""
    jax.block_until_ready(fn(*args, **kw))       # compile + warm
    samples = []
    for _ in range(3):
        t0 = time.perf_counter_ns()
        for _ in range(iters):
            out = fn(*args, **kw)
        jax.block_until_ready(out)
        samples.append((time.perf_counter_ns() - t0) / iters)
    return float(np.median(samples))


def _fits_vmem(buffer_bytes: int) -> bool:
    """Executable-kernel residency choice (capped for CPU interpret)."""
    return buffer_bytes < min(VMEM_BUDGET, _EXEC_VMEM_CAP)


def models_as_vmem(buffer_bytes: int) -> bool:
    """Modeling-side 'fits the cache' rule (the Fig. 5 sweep knee)."""
    return buffer_bytes < VMEM_BUDGET


# ---- bandwidth strategies ---------------------------------------------------


@register_strategy("r")
def _mk_r(pool, buffer_bytes, **kw):
    """sequential reads (cacheable) — read bandwidth"""
    rows = _rows(buffer_bytes)
    alloc = pool.alloc((rows, LANE), jnp.float32, init=bw_buffer_init,
                       tag="bw:r")
    x = alloc.array if alloc.array is not None else bw_buffer_init(
        (rows, LANE), jnp.float32)
    vmem = _fits_vmem(buffer_bytes) or pool.node.kind == "vmem"

    def run(iters):
        if vmem:
            t = _timed(ops.vmem_read, x, repeats=8, iters=iters) / 8
        else:
            t = _timed(ops.stream_read, x, block_rows=min(512, rows),
                       iters=iters)
        return WorkloadResult("r", pool.node.name, buffer_bytes, iters,
                              rows * LINE_BYTES * iters, t * iters, 0)

    return Workload("r", pool, buffer_bytes,
                    "sequential cacheable read", run, alloc)


@register_strategy("w")
def _mk_w(pool, buffer_bytes, **kw):
    """sequential writes (cacheable, write-allocate) — write bandwidth"""
    rows = _rows(buffer_bytes)
    alloc = pool.alloc((rows, LANE), jnp.float32, tag="bw:w")
    vmem = _fits_vmem(buffer_bytes) or pool.node.kind == "vmem"

    def run(iters):
        if vmem:
            t = _timed(ops.vmem_write, rows=rows, repeats=8,
                       iters=iters) / 8
        else:
            t = _timed(ops.stream_write, rows=rows,
                       block_rows=min(512, rows), iters=iters)
        return WorkloadResult("w", pool.node.name, buffer_bytes, iters,
                              rows * LINE_BYTES * iters, t * iters, 0)

    return Workload("w", pool, buffer_bytes,
                    "sequential cacheable write", run, alloc)


@register_strategy("s")
def _mk_s(pool, buffer_bytes, **kw):
    """non-cacheable sequential read (always streams from the module)"""
    rows = _rows(buffer_bytes)
    alloc = pool.alloc((rows, LANE), jnp.float32, init=bw_buffer_init,
                       tag="bw:s")
    x = alloc.array if alloc.array is not None else bw_buffer_init(
        (rows, LANE), jnp.float32)

    def run(iters):
        t = _timed(ops.stream_read, x, block_rows=min(512, rows),
                   iters=iters)
        return WorkloadResult("s", pool.node.name, buffer_bytes, iters,
                              rows * LINE_BYTES * iters, t * iters, 0)

    return Workload("s", pool, buffer_bytes, "non-cacheable read", run,
                    alloc)


@register_strategy("x")
def _mk_x(pool, buffer_bytes, **kw):
    """non-cacheable write (write-allocate: line read+written)"""
    rows = _rows(buffer_bytes)
    alloc = pool.alloc((rows, LANE), jnp.float32, init=bw_buffer_init,
                       tag="bw:x")
    x = alloc.array if alloc.array is not None else bw_buffer_init(
        (rows, LANE), jnp.float32)

    def run(iters):
        t = _timed(ops.stream_rmw, x, block_rows=min(512, rows),
                   iters=iters)
        return WorkloadResult("x", pool.node.name, buffer_bytes, iters,
                              2 * rows * LINE_BYTES * iters, t * iters, 0)

    return Workload("x", pool, buffer_bytes,
                    "non-cacheable write (allocate)", run, alloc)


@register_strategy("y")
def _mk_y(pool, buffer_bytes, **kw):
    """write-streaming (no write-allocate — the dc zva analog)"""
    rows = _rows(buffer_bytes)
    alloc = pool.alloc((rows, LANE), jnp.float32, tag="bw:y")

    def run(iters):
        t = _timed(ops.stream_write, rows=rows, block_rows=min(512, rows),
                   iters=iters)
        return WorkloadResult("y", pool.node.name, buffer_bytes, iters,
                              rows * LINE_BYTES * iters, t * iters, 0)

    return Workload("y", pool, buffer_bytes, "write-streaming", run, alloc)


@register_strategy("c")
def _mk_c(pool, buffer_bytes, **kw):
    """copy stream (read every line, write it elsewhere) — STREAM copy"""
    rows = _rows(buffer_bytes)
    alloc = pool.alloc((rows, LANE), jnp.float32, init=bw_buffer_init,
                       tag="bw:c")
    x = alloc.array if alloc.array is not None else bw_buffer_init(
        (rows, LANE), jnp.float32)

    def run(iters):
        t = _timed(ops.stream_copy, x, block_rows=min(512, rows),
                   iters=iters)
        return WorkloadResult("c", pool.node.name, buffer_bytes, iters,
                              2 * rows * LINE_BYTES * iters, t * iters, 0)

    return Workload("c", pool, buffer_bytes, "copy stream", run, alloc)


@register_strategy("b")
def _mk_mixed(pool, buffer_bytes, *, read_fraction: float = 0.5, **kw):
    """mixed read/write blocks at a configurable r:w ratio"""
    rows = _rows(buffer_bytes)
    alloc = pool.alloc((rows, LANE), jnp.float32, init=bw_buffer_init,
                       tag="bw:b")
    x = alloc.array if alloc.array is not None else bw_buffer_init(
        (rows, LANE), jnp.float32)
    rf = max(0.0, min(1.0, read_fraction))

    def run(iters):
        t = _timed(ops.stream_mixed, x, read_fraction=rf,
                   block_rows=min(512, rows), iters=iters)
        return WorkloadResult("b", pool.node.name, buffer_bytes, iters,
                              rows * LINE_BYTES * iters, t * iters, 0)

    return Workload("b", pool, buffer_bytes,
                    f"mixed r/w stream (rf={rf:g})", run, alloc)


@register_strategy("t")
def _mk_strided(pool, buffer_bytes, *, stride: int = 8, **kw):
    """strided pointer chase (constant hop distance, non-cacheable)"""
    rows = _rows(buffer_bytes)
    alloc = pool.alloc((rows, LANE), jnp.int32, tag="lat:t")
    buf = jnp.asarray(ops.strided_chain_buffer(rows, stride))

    def run(iters):
        steps = rows
        t = _timed(ops.chase_hbm, buf, n_steps=steps,
                   iters=max(1, iters // 10))
        return WorkloadResult("t", pool.node.name, buffer_bytes,
                              iters, rows * LINE_BYTES, t,
                              transactions=steps)

    return Workload("t", pool, buffer_bytes,
                    f"strided pointer-chase (x{stride})", run, alloc)


# ---- latency strategies -----------------------------------------------------


@register_strategy("l")
def _mk_l(pool, buffer_bytes, *, seed: int = 0, **kw):
    """data-dependent pointer chase (cacheable) — latency"""
    rows = _rows(buffer_bytes)
    alloc = pool.alloc((rows, LANE), jnp.int32, tag="lat:l")
    buf = latency_buffer_init(rows, seed)
    vmem = _fits_vmem(buffer_bytes) or pool.node.kind == "vmem"

    def run(iters):
        steps = rows                      # one full cycle per iteration
        fn = ops.chase_vmem if vmem else ops.chase_hbm
        t = _timed(fn, buf, n_steps=steps, iters=max(1, iters // 10))
        return WorkloadResult("l", pool.node.name, buffer_bytes,
                              iters, rows * LINE_BYTES, t,
                              transactions=steps)

    return Workload("l", pool, buffer_bytes, "pointer-chase latency", run,
                    alloc)


@register_strategy("m")
def _mk_m(pool, buffer_bytes, *, seed: int = 0, **kw):
    """non-cacheable pointer chase — module latency"""
    rows = _rows(buffer_bytes)
    alloc = pool.alloc((rows, LANE), jnp.int32, tag="lat:m")
    buf = latency_buffer_init(rows, seed)

    def run(iters):
        steps = rows
        t = _timed(ops.chase_hbm, buf, n_steps=steps,
                   iters=max(1, iters // 10))
        return WorkloadResult("m", pool.node.name, buffer_bytes,
                              iters, rows * LINE_BYTES, t,
                              transactions=steps)

    return Workload("m", pool, buffer_bytes,
                    "non-cacheable pointer-chase", run, alloc)


# ---- memory-idle -------------------------------------------------------------


@register_strategy("i")
def _mk_idle(pool, buffer_bytes, **kw):
    """memory-idle MXU busy loop (zero memory traffic)"""
    a = jnp.eye(128, dtype=jnp.float32) * 0.99

    def run(iters):
        t = _timed(lambda aa: ops.mxu_probe(aa, iters=64), a, iters=iters)
        return WorkloadResult("i", pool.node.name, 0, iters, 0, t * iters,
                              0)

    return Workload("i", pool, 0, "memory-idle busy loop", run, None,
                    is_memory_bound=False)
