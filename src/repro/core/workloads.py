"""Workload Library — registry of micro-benchmark activities (Table I).

Each workload is keyed by its access-strategy letter and binds a memory
pool + buffer size to a runnable activity.  Workloads carry:

* a **buffer initialiser** (the paper's configurable init: sequential
  ints for bandwidth sanity-checking, a Sattolo chain for latency);
* an **executable** (jit'd Pallas kernel, interpret=True off-TPU) used by
  the ``interpret``/``tpu`` backends;
* the **queueing-class parameters** (strategy letter, traffic multiplier,
  MLP) consumed by the ``simulate`` backend.

The cacheable strategies (r/w/l) become VMEM-resident kernels when the
buffer fits the VMEM budget and HBM-streaming kernels otherwise — the
software-managed-hierarchy analog of "whether the buffer fits in L2",
which is exactly how the paper's Fig. 5 buffer-size sweeps behave.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.devicetree import MemoryNode
from repro.core.pools import Allocation, MemoryPool
from repro.kernels import ops

LANE = 128
LINE_BYTES = LANE * 4          # one (1,128) f32 row = 512 B "line"
VMEM_BUDGET = 64 << 20         # "cache size": cacheable buffers <= this
                               # are VMEM-resident (the L2-fit analog)
_EXEC_VMEM_CAP = 4 << 20       # interpret-mode practicality cap (CPU)


@dataclass
class WorkloadResult:
    strategy: str
    pool: str
    buffer_bytes: int
    iters: int
    bytes_moved: int           # useful bytes touched (all iters)
    elapsed_ns: float          # wall time (interpret/tpu backends)
    transactions: int          # dependent loads for latency workloads

    @property
    def bandwidth_gbps(self) -> float:
        if self.elapsed_ns <= 0:
            return 0.0
        return self.bytes_moved / self.elapsed_ns

    @property
    def latency_ns(self) -> float:
        if self.transactions <= 0:
            return 0.0
        return self.elapsed_ns / self.transactions


@dataclass
class Workload:
    """A bound activity: strategy letter + pool + buffer."""
    strategy: str
    pool: MemoryPool
    buffer_bytes: int
    description: str
    run_fn: Callable[[int], WorkloadResult]
    alloc: Optional[Allocation] = None
    is_memory_bound: bool = True

    def run(self, iters: int = 500) -> WorkloadResult:
        return self.run_fn(iters)

    def release(self) -> None:
        if self.alloc is not None:
            self.pool.free(self.alloc)
            self.alloc = None

    @property
    def node(self) -> MemoryNode:
        return self.pool.node


# ---------------------------------------------------------------------------
# Buffer initialisers (paper: "Configurable Buffer Initialization")
# ---------------------------------------------------------------------------


def bw_buffer_init(shape, dtype):
    """Sequential integers — lets experiments sanity-check corruption."""
    n = int(np.prod(shape))
    return jnp.arange(n, dtype=jnp.float32).reshape(shape).astype(dtype)


def latency_buffer_init(n_lines: int, seed: int = 0):
    return jnp.asarray(ops.chain_buffer(n_lines, seed))


# ---------------------------------------------------------------------------
# Factory
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[..., Workload]] = {}


def register_strategy(letter: str):
    def deco(fn):
        _REGISTRY[letter] = fn
        return fn
    return deco


def strategies() -> Dict[str, str]:
    return {k: (v.__doc__ or "").strip().splitlines()[0]
            for k, v in sorted(_REGISTRY.items())}


def make_workload(strategy: str, pool: MemoryPool, buffer_bytes: int,
                  **kw) -> Workload:
    if strategy not in _REGISTRY:
        raise KeyError(
            f"unknown access strategy {strategy!r}; have "
            f"{sorted(_REGISTRY)}")
    return _REGISTRY[strategy](pool, buffer_bytes, **kw)


def _rows(buffer_bytes: int) -> int:
    rows = max(1, buffer_bytes // LINE_BYTES)
    # keep divisible by the largest block we use
    block = 512 if rows >= 512 else rows
    return (rows // block) * block or rows


def _timed(fn, *args, iters: int, **kw) -> float:
    """Median-of-3 wall time for `iters` back-to-back calls, ns."""
    fn(*args, **kw).block_until_ready()          # compile + warm
    samples = []
    for _ in range(3):
        t0 = time.perf_counter_ns()
        for _ in range(iters):
            out = fn(*args, **kw)
        out.block_until_ready()
        samples.append((time.perf_counter_ns() - t0) / iters)
    return float(np.median(samples))


def _fits_vmem(buffer_bytes: int) -> bool:
    """Executable-kernel residency choice (capped for CPU interpret)."""
    return buffer_bytes < min(VMEM_BUDGET, _EXEC_VMEM_CAP)


def models_as_vmem(buffer_bytes: int) -> bool:
    """Modeling-side 'fits the cache' rule (the Fig. 5 sweep knee)."""
    return buffer_bytes < VMEM_BUDGET


# ---- bandwidth strategies ---------------------------------------------------


@register_strategy("r")
def _mk_r(pool, buffer_bytes, **kw):
    """sequential reads (cacheable) — read bandwidth"""
    rows = _rows(buffer_bytes)
    alloc = pool.alloc((rows, LANE), jnp.float32, init=bw_buffer_init,
                       tag="bw:r")
    x = alloc.array if alloc.array is not None else bw_buffer_init(
        (rows, LANE), jnp.float32)
    vmem = _fits_vmem(buffer_bytes) or pool.node.kind == "vmem"

    def run(iters):
        if vmem:
            t = _timed(ops.vmem_read, x, repeats=8, iters=iters) / 8
        else:
            t = _timed(ops.stream_read, x, block_rows=min(512, rows),
                       iters=iters)
        return WorkloadResult("r", pool.node.name, buffer_bytes, iters,
                              rows * LINE_BYTES * iters, t * iters, 0)

    return Workload("r", pool, buffer_bytes,
                    "sequential cacheable read", run, alloc)


@register_strategy("w")
def _mk_w(pool, buffer_bytes, **kw):
    """sequential writes (cacheable, write-allocate) — write bandwidth"""
    rows = _rows(buffer_bytes)
    alloc = pool.alloc((rows, LANE), jnp.float32, tag="bw:w")
    vmem = _fits_vmem(buffer_bytes) or pool.node.kind == "vmem"

    def run(iters):
        if vmem:
            t = _timed(ops.vmem_write, rows=rows, repeats=8,
                       iters=iters) / 8
        else:
            t = _timed(ops.stream_write, rows=rows,
                       block_rows=min(512, rows), iters=iters)
        return WorkloadResult("w", pool.node.name, buffer_bytes, iters,
                              rows * LINE_BYTES * iters, t * iters, 0)

    return Workload("w", pool, buffer_bytes,
                    "sequential cacheable write", run, alloc)


@register_strategy("s")
def _mk_s(pool, buffer_bytes, **kw):
    """non-cacheable sequential read (always streams from the module)"""
    rows = _rows(buffer_bytes)
    alloc = pool.alloc((rows, LANE), jnp.float32, init=bw_buffer_init,
                       tag="bw:s")
    x = alloc.array if alloc.array is not None else bw_buffer_init(
        (rows, LANE), jnp.float32)

    def run(iters):
        t = _timed(ops.stream_read, x, block_rows=min(512, rows),
                   iters=iters)
        return WorkloadResult("s", pool.node.name, buffer_bytes, iters,
                              rows * LINE_BYTES * iters, t * iters, 0)

    return Workload("s", pool, buffer_bytes, "non-cacheable read", run,
                    alloc)


@register_strategy("x")
def _mk_x(pool, buffer_bytes, **kw):
    """non-cacheable write (write-allocate: line read+written)"""
    rows = _rows(buffer_bytes)
    alloc = pool.alloc((rows, LANE), jnp.float32, init=bw_buffer_init,
                       tag="bw:x")
    x = alloc.array if alloc.array is not None else bw_buffer_init(
        (rows, LANE), jnp.float32)

    def run(iters):
        t = _timed(ops.stream_rmw, x, block_rows=min(512, rows),
                   iters=iters)
        return WorkloadResult("x", pool.node.name, buffer_bytes, iters,
                              2 * rows * LINE_BYTES * iters, t * iters, 0)

    return Workload("x", pool, buffer_bytes,
                    "non-cacheable write (allocate)", run, alloc)


@register_strategy("y")
def _mk_y(pool, buffer_bytes, **kw):
    """write-streaming (no write-allocate — the dc zva analog)"""
    rows = _rows(buffer_bytes)
    alloc = pool.alloc((rows, LANE), jnp.float32, tag="bw:y")

    def run(iters):
        t = _timed(ops.stream_write, rows=rows, block_rows=min(512, rows),
                   iters=iters)
        return WorkloadResult("y", pool.node.name, buffer_bytes, iters,
                              rows * LINE_BYTES * iters, t * iters, 0)

    return Workload("y", pool, buffer_bytes, "write-streaming", run, alloc)


# ---- latency strategies -----------------------------------------------------


@register_strategy("l")
def _mk_l(pool, buffer_bytes, *, seed: int = 0, **kw):
    """data-dependent pointer chase (cacheable) — latency"""
    rows = _rows(buffer_bytes)
    alloc = pool.alloc((rows, LANE), jnp.int32, tag="lat:l")
    buf = latency_buffer_init(rows, seed)
    vmem = _fits_vmem(buffer_bytes) or pool.node.kind == "vmem"

    def run(iters):
        steps = rows                      # one full cycle per iteration
        fn = ops.chase_vmem if vmem else ops.chase_hbm
        t = _timed(fn, buf, n_steps=steps, iters=max(1, iters // 10))
        return WorkloadResult("l", pool.node.name, buffer_bytes,
                              iters, rows * LINE_BYTES, t,
                              transactions=steps)

    return Workload("l", pool, buffer_bytes, "pointer-chase latency", run,
                    alloc)


@register_strategy("m")
def _mk_m(pool, buffer_bytes, *, seed: int = 0, **kw):
    """non-cacheable pointer chase — module latency"""
    rows = _rows(buffer_bytes)
    alloc = pool.alloc((rows, LANE), jnp.int32, tag="lat:m")
    buf = latency_buffer_init(rows, seed)

    def run(iters):
        steps = rows
        t = _timed(ops.chase_hbm, buf, n_steps=steps,
                   iters=max(1, iters // 10))
        return WorkloadResult("m", pool.node.name, buffer_bytes,
                              iters, rows * LINE_BYTES, t,
                              transactions=steps)

    return Workload("m", pool, buffer_bytes,
                    "non-cacheable pointer-chase", run, alloc)


# ---- memory-idle -------------------------------------------------------------


@register_strategy("i")
def _mk_idle(pool, buffer_bytes, **kw):
    """memory-idle MXU busy loop (zero memory traffic)"""
    a = jnp.eye(128, dtype=jnp.float32) * 0.99

    def run(iters):
        t = _timed(lambda aa: ops.mxu_probe(aa, iters=64), a, iters=iters)
        return WorkloadResult("i", pool.node.name, 0, iters, 0, t * iters,
                              0)

    return Workload("i", pool, 0, "memory-idle busy loop", run, None,
                    is_memory_bound=False)
