"""Declarative scenario DSL — the characterization matrix, as data.

The seed hard-coded one scenario family: a 4-tuple cross-product
(obs_pool x obs_strategy x stress_pool x stress_strategy) of *steady*
streams.  Real contention is richer ("A Mess of Memory System
Benchmarking": bandwidth-latency surfaces are only meaningful swept
across read/write ratios and traffic shapes; worst-case SoC analysis
needs bursty and copy-style interference).  This module makes the
scenario the unit of configuration:

* :class:`TrafficShape`   — HOW an activity touches memory: steady,
  mixed read/write ratio (2:1, 1:1, 1:2, ...), bursty/duty-cycled,
  or strided (pointer-chase hop distance).
* :class:`ObserverSpec`   — the measured activity: strategy letter,
  pool, and a *buffer-size ladder*.
* :class:`StressorSpec`   — one member of the stressor ensemble.
* :class:`ScenarioSpec`   — observer + stressor ensemble + iteration
  budget; serialisable, hashable, and the key-provider for CurveDB v2.

Specs are plain frozen dataclasses with exact dict round-trips
(:func:`ScenarioSpec.to_dict` / :func:`ScenarioSpec.from_dict`), so a
scenario matrix can be checked into a JSON file, diffed, and replayed.

Adding a new traffic shape (see README "Scenario DSL"):
  1. give it a ``kind`` + parameters here (and a ``tag`` spelling),
  2. teach the queueing model its traffic/population effect
     (``repro.core.simulate``),
  3. optionally give it an executable kernel (``repro.kernels``) and
     register the workload (``repro.core.workloads``).
"""
from __future__ import annotations

from dataclasses import asdict, dataclass, field, replace
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

SCHEMA_VERSION = 2


# ---------------------------------------------------------------------------
# Traffic shapes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TrafficShape:
    """How an activity's transactions are distributed in kind and time.

    kind          "steady" | "mixed" | "burst" | "strided"
    read_fraction fraction of line-touches that are reads (mixed): a
                  2:1 read:write mix is read_fraction=2/3.
    duty_cycle    fraction of wall time the activity is issuing (burst);
                  1.0 = steady.
    burst_len     iterations per active burst (executable backends).
    stride        lines skipped per pointer-chase hop (strided).
    """
    kind: str = "steady"
    read_fraction: float = 1.0
    duty_cycle: float = 1.0
    burst_len: int = 64
    stride: int = 1

    def __post_init__(self):
        if self.kind not in ("steady", "mixed", "burst", "strided"):
            raise ValueError(f"unknown traffic shape kind {self.kind!r}")
        if not 0.0 <= self.read_fraction <= 1.0:
            raise ValueError(f"read_fraction out of [0,1]: "
                             f"{self.read_fraction}")
        if not 0.0 < self.duty_cycle <= 1.0:
            raise ValueError(f"duty_cycle out of (0,1]: {self.duty_cycle}")
        if self.stride < 1:
            raise ValueError(f"stride must be >= 1: {self.stride}")

    # -- constructors -------------------------------------------------------
    @staticmethod
    def steady() -> "TrafficShape":
        return TrafficShape()

    @staticmethod
    def mixed(reads: int, writes: int) -> "TrafficShape":
        """Mixed read/write ratio, e.g. ``mixed(2, 1)`` for 2:1."""
        if reads < 0 or writes < 0 or reads + writes == 0:
            raise ValueError(f"bad ratio {reads}:{writes}")
        return TrafficShape(kind="mixed",
                            read_fraction=reads / (reads + writes))

    @staticmethod
    def burst(duty_cycle: float, burst_len: int = 64) -> "TrafficShape":
        return TrafficShape(kind="burst", duty_cycle=duty_cycle,
                            burst_len=burst_len)

    @staticmethod
    def strided(stride: int) -> "TrafficShape":
        return TrafficShape(kind="strided", stride=stride)

    # -- identity ----------------------------------------------------------
    @property
    def is_steady(self) -> bool:
        return self.kind == "steady"

    def tag(self) -> str:
        """Short spelling used inside CurveDB keys ('' for steady)."""
        if self.kind == "steady":
            return ""
        if self.kind == "mixed":
            return f"rf{self.read_fraction:.2f}"
        if self.kind == "burst":
            return f"dc{self.duty_cycle:.2f}"
        return f"st{self.stride}"


# ---------------------------------------------------------------------------
# Activities
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ObserverSpec:
    """The measured activity: one strategy on one pool, swept over a
    buffer-size ladder (a single size is a 1-rung ladder)."""
    strategy: str
    pool: str
    buffers: Tuple[int, ...]
    shape: TrafficShape = field(default_factory=TrafficShape)

    def __post_init__(self):
        object.__setattr__(self, "buffers", tuple(self.buffers))
        if not self.buffers:
            raise ValueError("observer needs at least one buffer size")


@dataclass(frozen=True)
class StressorSpec:
    """One member of the stressor ensemble."""
    strategy: str
    pool: str
    buffer_bytes: int
    shape: TrafficShape = field(default_factory=TrafficShape)

    def descriptor(self) -> str:
        t = self.shape.tag()
        base = f"{self.pool}:{self.strategy}"
        return f"{base}@{t}" if t else base


@dataclass(frozen=True)
class ScenarioSpec:
    """One named scenario: observer + stressor ensemble + budget."""
    name: str
    observer: ObserverSpec
    stressors: Tuple[StressorSpec, ...] = ()
    iters: int = 500
    max_stressors: Optional[int] = None     # ladder depth; None = n_engines

    def __post_init__(self):
        object.__setattr__(self, "stressors", tuple(self.stressors))

    # -- CurveDB keying ------------------------------------------------------
    def key(self, buffer_bytes: Optional[int] = None) -> str:
        """Curve key.  For a steady observer + single steady stressor
        this is EXACTLY the v1 key format
        ``obs_pool:obs_strat|stress_pool:stress_strat`` so v1 consumers
        (placement, MLP tables) keep resolving; shaped/ensemble
        scenarios append their shape tags."""
        obs = f"{self.observer.pool}:{self.observer.strategy}"
        t = self.observer.shape.tag()
        if t:
            obs = f"{obs}@{t}"
        if self.stressors:
            stress = "+".join(s.descriptor() for s in self.stressors)
        else:
            stress = "none:i"
        key = f"{obs}|{stress}"
        if buffer_bytes is not None and len(self.observer.buffers) > 1:
            key = f"{key}|buf={buffer_bytes}"
        return key

    # -- serialisation -------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return asdict(self)

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "ScenarioSpec":
        obs = d["observer"]
        observer = ObserverSpec(
            strategy=obs["strategy"], pool=obs["pool"],
            buffers=tuple(obs["buffers"]),
            shape=TrafficShape(**obs.get("shape", {})))
        stressors = tuple(
            StressorSpec(strategy=s["strategy"], pool=s["pool"],
                         buffer_bytes=s["buffer_bytes"],
                         shape=TrafficShape(**s.get("shape", {})))
            for s in d.get("stressors", ()))
        return ScenarioSpec(name=d["name"], observer=observer,
                            stressors=stressors,
                            iters=d.get("iters", 500),
                            max_stressors=d.get("max_stressors"))


# ---------------------------------------------------------------------------
# Matrix builders
# ---------------------------------------------------------------------------


#: The default stressor shape ensemble: the seed's steady ladder plus the
#: three new traffic-shape families (mixed r/w ratio, bursty, copy) and a
#: strided chase.  (strategy letter, shape) pairs.
DEFAULT_STRESS_SHAPES: Tuple[Tuple[str, TrafficShape], ...] = (
    ("r", TrafficShape.steady()),
    ("w", TrafficShape.steady()),
    ("y", TrafficShape.steady()),
    ("c", TrafficShape.steady()),           # copy: read + write stream
    ("r", TrafficShape.mixed(2, 1)),        # 2:1 read:write
    ("r", TrafficShape.mixed(1, 1)),
    ("r", TrafficShape.mixed(1, 2)),
    ("w", TrafficShape.burst(0.5)),         # duty-cycled write stress
    ("m", TrafficShape.strided(8)),         # strided pointer-chase
)


def scenario_matrix(
    *,
    pools: Sequence[str],
    buffer_bytes: int,
    obs_strategies: Sequence[str] = ("r", "w", "l"),
    stress_shapes: Sequence[Tuple[str, TrafficShape]] = DEFAULT_STRESS_SHAPES,
    stress_pools: Optional[Sequence[str]] = None,
    iters: int = 500,
    max_stressors: Optional[int] = None,
    name_prefix: str = "",
) -> List[ScenarioSpec]:
    """The full cross-product matrix as a flat spec list.

    Replaces the seed's hard-coded 4-tuple loop: every combination of
    (observer pool, observer strategy, stressor pool, stressor
    strategy+shape) becomes one named :class:`ScenarioSpec`.
    """
    specs: List[ScenarioSpec] = []
    s_pools = list(stress_pools) if stress_pools is not None else list(pools)
    for op in pools:
        for ostrat in obs_strategies:
            for sp in s_pools:
                for sstrat, shape in stress_shapes:
                    tag = shape.tag()
                    name = f"{name_prefix}{op}.{ostrat}|{sp}.{sstrat}"
                    if tag:
                        name = f"{name}@{tag}"
                    specs.append(ScenarioSpec(
                        name=name,
                        observer=ObserverSpec(ostrat, op, (buffer_bytes,)),
                        stressors=(StressorSpec(sstrat, sp, buffer_bytes,
                                                shape),),
                        iters=iters,
                        max_stressors=max_stressors))
    return specs


def save_matrix(specs: Iterable[ScenarioSpec], path: str) -> None:
    import json
    with open(path, "w") as f:
        json.dump({"schema": SCHEMA_VERSION,
                   "scenarios": [s.to_dict() for s in specs]}, f, indent=1)


def load_matrix(path: str) -> List[ScenarioSpec]:
    import json
    with open(path) as f:
        d = json.load(f)
    return [ScenarioSpec.from_dict(s) for s in d["scenarios"]]
