"""Declarative scenario DSL — the characterization matrix, as data.

The seed hard-coded one scenario family: a 4-tuple cross-product
(obs_pool x obs_strategy x stress_pool x stress_strategy) of *steady*
streams.  Real contention is richer ("A Mess of Memory System
Benchmarking": bandwidth-latency surfaces are only meaningful swept
across read/write ratios and traffic shapes; worst-case SoC analysis
needs bursty and copy-style interference).  This module makes the
scenario the unit of configuration:

* :class:`TrafficShape`   — HOW an activity touches memory: steady,
  mixed read/write ratio (2:1, 1:1, 1:2, ...), bursty/duty-cycled,
  or strided (pointer-chase hop distance).
* :class:`ObserverSpec`   — the measured activity: strategy letter,
  pool, and a *buffer-size ladder*.
* :class:`StressorSpec`   — one member of the stressor ensemble.
* :class:`ScenarioSpec`   — observer(s) + stressor ensemble + iteration
  budget; serialisable, hashable, and the key-provider for CurveDB v2.
  A scenario may carry SEVERAL observers (measure many pools at once);
  each observer keys its own curve via :meth:`ScenarioSpec.key_for`.

Specs are plain frozen dataclasses with exact dict round-trips
(:func:`ScenarioSpec.to_dict` / :func:`ScenarioSpec.from_dict`), so a
scenario matrix can be checked into a JSON file, diffed, and replayed.

Adding a new traffic shape (see README "Scenario DSL"):
  1. give it a ``kind`` + parameters here (and a ``tag`` spelling),
  2. teach the queueing model its traffic/population effect
     (``repro.core.simulate``),
  3. optionally give it an executable kernel (``repro.kernels``) and
     register the workload (``repro.core.workloads``).
"""
from __future__ import annotations

from dataclasses import asdict, dataclass, field, replace
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

SCHEMA_VERSION = 2


def _exact(v: float) -> str:
    """Shortest fixed-point spelling that round-trips ``v`` exactly."""
    for prec in (2, 3, 4, 6):
        s = f"{v:.{prec}f}"
        if float(s) == v:
            return s
    return repr(v)


# ---------------------------------------------------------------------------
# Traffic shapes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TrafficShape:
    """How an activity's transactions are distributed in kind and time.

    kind          "steady" | "mixed" | "burst" | "strided"
    read_fraction fraction of line-touches that are reads (mixed): a
                  2:1 read:write mix is read_fraction=2/3.
    duty_cycle    fraction of wall time the activity is issuing (burst);
                  1.0 = steady.
    burst_len     iterations per active burst (executable backends).
    stride        lines skipped per pointer-chase hop (strided).
    """
    kind: str = "steady"
    read_fraction: float = 1.0
    duty_cycle: float = 1.0
    burst_len: int = 64
    stride: int = 1

    def __post_init__(self):
        if self.kind not in ("steady", "mixed", "burst", "strided"):
            raise ValueError(f"unknown traffic shape kind {self.kind!r}")
        if not 0.0 <= self.read_fraction <= 1.0:
            raise ValueError(f"read_fraction out of [0,1]: "
                             f"{self.read_fraction}")
        if not 0.0 < self.duty_cycle <= 1.0:
            raise ValueError(f"duty_cycle out of (0,1]: {self.duty_cycle}")
        if self.stride < 1:
            raise ValueError(f"stride must be >= 1: {self.stride}")

    # -- constructors -------------------------------------------------------
    @staticmethod
    def steady() -> "TrafficShape":
        return TrafficShape()

    @staticmethod
    def mixed(reads: int, writes: int) -> "TrafficShape":
        """Mixed read/write ratio, e.g. ``mixed(2, 1)`` for 2:1."""
        if reads < 0 or writes < 0 or reads + writes == 0:
            raise ValueError(f"bad ratio {reads}:{writes}")
        return TrafficShape(kind="mixed",
                            read_fraction=reads / (reads + writes))

    @staticmethod
    def burst(duty_cycle: float, burst_len: int = 64) -> "TrafficShape":
        return TrafficShape(kind="burst", duty_cycle=duty_cycle,
                            burst_len=burst_len)

    @staticmethod
    def strided(stride: int) -> "TrafficShape":
        return TrafficShape(kind="strided", stride=stride)

    @staticmethod
    def traffic(rw_ratio: float, inject_rate: float = 1.0) -> "TrafficShape":
        """One bandwidth–latency *surface* grid point: a mixed stream
        issuing ``rw_ratio`` reads per line-touch at ``inject_rate``
        duty (Mess-style surfaces sweep both axes at once, so the two
        parameters combine in a single shape)."""
        return TrafficShape(kind="mixed", read_fraction=rw_ratio,
                            duty_cycle=inject_rate)

    # -- identity ----------------------------------------------------------
    @property
    def is_steady(self) -> bool:
        return self.kind == "steady"

    def tag(self) -> str:
        """Short spelling used inside CurveDB keys ('' for steady).

        The parameter spelling must round-trip the float exactly —
        distinct ratios MUST NOT alias one key (two different mixed
        ratios landing on the same ``rf`` spelling would collide in
        CurveDB and trip the characterize_matrix collision guard).
        Common ratios keep the short 2-decimal form (``rf0.50``);
        non-terminating ones widen until exact (``rf0.6666666666666666``).
        """
        if self.kind == "steady":
            return ""
        if self.kind == "mixed":
            tag = f"rf{_exact(self.read_fraction)}"
            # surface grid points carry both axes; a duty-cycled mix
            # must not alias the always-on mix of the same ratio
            if self.duty_cycle != 1.0:
                tag = f"{tag}dc{_exact(self.duty_cycle)}"
            return tag
        if self.kind == "burst":
            tag = f"dc{_exact(self.duty_cycle)}"
            # non-default burst lengths are part of the identity too
            return tag if self.burst_len == 64 else f"{tag}x{self.burst_len}"
        tag = f"st{self.stride}"
        # a duty-cycled strided chase (the search's inject_rate knob on
        # the stride arm) must not alias the always-on chase of the
        # same stride
        return tag if self.duty_cycle == 1.0 else \
            f"{tag}dc{_exact(self.duty_cycle)}"


# ---------------------------------------------------------------------------
# Activities
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ObserverSpec:
    """The measured activity: one strategy on one pool, swept over a
    buffer-size ladder (a single size is a 1-rung ladder)."""
    strategy: str
    pool: str
    buffers: Tuple[int, ...]
    shape: TrafficShape = field(default_factory=TrafficShape)

    def __post_init__(self):
        object.__setattr__(self, "buffers", tuple(self.buffers))
        if not self.buffers:
            raise ValueError("observer needs at least one buffer size")


@dataclass(frozen=True)
class StressorSpec:
    """One member of the stressor ensemble."""
    strategy: str
    pool: str
    buffer_bytes: int
    shape: TrafficShape = field(default_factory=TrafficShape)

    def descriptor(self) -> str:
        t = self.shape.tag()
        base = f"{self.pool}:{self.strategy}"
        return f"{base}@{t}" if t else base


@dataclass(frozen=True)
class ScenarioSpec:
    """One named scenario: observer(s) + stressor ensemble + budget.

    ``observer`` accepts either a single :class:`ObserverSpec` or a
    tuple of them — a *multi-observer* scenario measures several pools
    at once (each observer gets its own CurveDB curve, all collapsed
    into the matrix runner's same-signature vmapped passes).  The first
    observer stays the ``observer`` attribute (v1-compatible keying);
    the rest land in ``co_observers``.

    ``coupled`` (default True) makes co-observers part of each other's
    measured region: on the spmd backend every sibling observer runs as
    a live engine inside each observer's rung dispatch, and on modeled
    backends each sibling contributes an always-on single-engine class
    to the queueing network.  ``coupled=False`` restores the historical
    semantics (each observer sees only the stressor ensemble); curves
    record which one produced them via the CurveDB ``execution``
    provenance entry.
    """
    name: str
    observer: ObserverSpec
    stressors: Tuple[StressorSpec, ...] = ()
    iters: int = 500
    max_stressors: Optional[int] = None     # ladder depth; None = n_engines
    co_observers: Tuple[ObserverSpec, ...] = ()
    coupled: bool = True

    def __post_init__(self):
        obs, co = self.observer, tuple(self.co_observers)
        if not isinstance(obs, ObserverSpec):
            seq = tuple(obs)
            if not seq:
                raise ValueError(f"{self.name}: need at least one observer")
            obs, co = seq[0], tuple(seq[1:]) + co
            object.__setattr__(self, "observer", obs)
        object.__setattr__(self, "co_observers", co)
        object.__setattr__(self, "stressors", tuple(self.stressors))

    @property
    def observers(self) -> Tuple[ObserverSpec, ...]:
        """All measured activities, primary first."""
        return (self.observer,) + self.co_observers

    @property
    def n_coupled_siblings(self) -> int:
        """Engines each observer's ladder devotes to live sibling
        observers — 0 when uncoupled or single-observer.  The planner
        sizes ladder widths (and packing subsets) from this."""
        return len(self.observers) - 1 if self.coupled else 0

    def coupled_siblings(self,
                         observer: ObserverSpec) -> Tuple[ObserverSpec, ...]:
        """The sibling observers sharing ``observer``'s measured region
        (empty when the scenario is uncoupled).  Drops exactly ONE
        occurrence of the measured observer — by identity when it is
        one of this spec's own entries (so value-equal twins still see
        each other), by value for reconstructed/deserialized equal
        observers."""
        if not self.coupled:
            return ()
        rest = list(self.observers)
        for i, o in enumerate(rest):
            if o is observer:
                del rest[i]
                break
        else:
            for i, o in enumerate(rest):
                if o == observer:
                    del rest[i]
                    break
        return tuple(rest)

    # -- cross-ladder grouping (sweep-level megabatching) -------------------
    def role_pools(self, observer: ObserverSpec) -> Tuple[str, ...]:
        """Every pool a ladder of this (spec, observer) pair can place
        an engine's operands in, in role order: the observer first (idle
        engines share its pool), then coupled siblings, then the
        stressor ensemble."""
        return (observer.pool,
                *(o.pool for o in self.coupled_siblings(observer)),
                *(s.pool for s in self.stressors))

    def ladder_signature(self, observer: ObserverSpec,
                         buffer_bytes: int) -> Tuple:
        """Hashable *role-program* identity of this (spec, observer,
        buffer) ladder, for sweep-level grouping: two triples with equal
        signatures AND equal per-pool effective memory kinds (see
        :meth:`role_pools`) expand to identical per-rung role tables at
        any mesh size, so their ladders legally stack into ONE batched
        SPMD dispatch.  Pool *names* are deliberately absent — pools
        that differ only in name but land in the same physical memory
        merge, exactly like the interpret path's signature groups;
        anything that changes the compiled program or the stamped
        numbers (strategies, shapes, buffer sizes, iteration budgets,
        ladder depth, sibling coupling) splits."""
        return (
            (observer.strategy, observer.shape, int(buffer_bytes)),
            tuple((o.strategy, o.shape, o.buffers[0])
                  for o in self.coupled_siblings(observer)),
            tuple((s.strategy, s.shape, s.buffer_bytes)
                  for s in self.stressors),
            self.iters,
            self.max_stressors,
        )

    # -- CurveDB keying ------------------------------------------------------
    def _stress_key(self) -> str:
        if self.stressors:
            return "+".join(s.descriptor() for s in self.stressors)
        return "none:i"

    def key_for(self, observer: ObserverSpec,
                buffer_bytes: Optional[int] = None) -> str:
        """Per-observer curve key (multi-observer scenarios yield one
        curve per observer, all sharing the stressor half).  The
        ``buf=`` suffix appears for multi-buffer ladders AND whenever a
        sibling observer shares this observer's pool/strategy/shape —
        two observers differing only in buffer size must not alias one
        curve key."""
        obs = f"{observer.pool}:{observer.strategy}"
        t = observer.shape.tag()
        if t:
            obs = f"{obs}@{t}"
        key = f"{obs}|{self._stress_key()}"
        # count by VALUE, not identity: key_for must return the stored
        # key for a reconstructed/deserialized equal observer too
        twins = sum(1 for o in self.observers
                    if o.pool == observer.pool
                    and o.strategy == observer.strategy
                    and o.shape.tag() == t)
        if buffer_bytes is not None and (len(observer.buffers) > 1
                                         or twins > 1):
            key = f"{key}|buf={buffer_bytes}"
        return key

    def key(self, buffer_bytes: Optional[int] = None) -> str:
        """Curve key of the primary observer.  For a steady observer +
        single steady stressor this is EXACTLY the v1 key format
        ``obs_pool:obs_strat|stress_pool:stress_strat`` so v1 consumers
        (placement, MLP tables) keep resolving; shaped/ensemble
        scenarios append their shape tags."""
        return self.key_for(self.observer, buffer_bytes)

    # -- serialisation -------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return asdict(self)

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "ScenarioSpec":
        stressors = tuple(
            StressorSpec(strategy=s["strategy"], pool=s["pool"],
                         buffer_bytes=s["buffer_bytes"],
                         shape=TrafficShape(**s.get("shape", {})))
            for s in d.get("stressors", ()))
        return ScenarioSpec(name=d["name"],
                            observer=_obs_from_dict(d["observer"]),
                            stressors=stressors,
                            iters=d.get("iters", 500),
                            max_stressors=d.get("max_stressors"),
                            co_observers=tuple(
                                _obs_from_dict(o)
                                for o in d.get("co_observers", ())),
                            coupled=d.get("coupled", True))


def _obs_from_dict(obs: Dict[str, Any]) -> ObserverSpec:
    return ObserverSpec(strategy=obs["strategy"], pool=obs["pool"],
                        buffers=tuple(obs["buffers"]),
                        shape=TrafficShape(**obs.get("shape", {})))


# ---------------------------------------------------------------------------
# Matrix builders
# ---------------------------------------------------------------------------


#: The default stressor shape ensemble: the seed's steady ladder plus the
#: three new traffic-shape families (mixed r/w ratio, bursty, copy) and a
#: strided chase.  (strategy letter, shape) pairs.
DEFAULT_STRESS_SHAPES: Tuple[Tuple[str, TrafficShape], ...] = (
    ("r", TrafficShape.steady()),
    ("w", TrafficShape.steady()),
    ("y", TrafficShape.steady()),
    ("c", TrafficShape.steady()),           # copy: read + write stream
    ("r", TrafficShape.mixed(2, 1)),        # 2:1 read:write
    ("r", TrafficShape.mixed(1, 1)),
    ("r", TrafficShape.mixed(1, 2)),
    ("w", TrafficShape.burst(0.5)),         # duty-cycled write stress
    ("m", TrafficShape.strided(8)),         # strided pointer-chase
)


def scenario_matrix(
    *,
    pools: Sequence[str],
    buffer_bytes: int,
    obs_strategies: Sequence[str] = ("r", "w", "l"),
    stress_shapes: Sequence[Tuple[str, TrafficShape]] = DEFAULT_STRESS_SHAPES,
    stress_pools: Optional[Sequence[str]] = None,
    iters: int = 500,
    max_stressors: Optional[int] = None,
    name_prefix: str = "",
) -> List[ScenarioSpec]:
    """The full cross-product matrix as a flat spec list.

    Replaces the seed's hard-coded 4-tuple loop: every combination of
    (observer pool, observer strategy, stressor pool, stressor
    strategy+shape) becomes one named :class:`ScenarioSpec`.
    """
    specs: List[ScenarioSpec] = []
    s_pools = list(stress_pools) if stress_pools is not None else list(pools)
    for op in pools:
        for ostrat in obs_strategies:
            for sp in s_pools:
                for sstrat, shape in stress_shapes:
                    tag = shape.tag()
                    name = f"{name_prefix}{op}.{ostrat}|{sp}.{sstrat}"
                    if tag:
                        name = f"{name}@{tag}"
                    specs.append(ScenarioSpec(
                        name=name,
                        observer=ObserverSpec(ostrat, op, (buffer_bytes,)),
                        stressors=(StressorSpec(sstrat, sp, buffer_bytes,
                                                shape),),
                        iters=iters,
                        max_stressors=max_stressors))
    return specs


#: Default surface grid (Mess-style): read/write mix from pure-write to
#: pure-read, injection rate from a 25% duty trickle to full blast.
DEFAULT_RW_RATIOS: Tuple[float, ...] = (0.0, 0.5, 1.0)
DEFAULT_INJECT_RATES: Tuple[float, ...] = (0.25, 0.5, 1.0)


def surface_matrix(
    *,
    pools: Sequence[str],
    buffer_bytes: int,
    obs_strategies: Sequence[str] = ("r", "l"),
    stress_pools: Optional[Sequence[str]] = None,
    rw_ratios: Sequence[float] = DEFAULT_RW_RATIOS,
    inject_rates: Sequence[float] = DEFAULT_INJECT_RATES,
    iters: int = 500,
    max_stressors: Optional[int] = None,
    name_prefix: str = "surface.",
) -> List[ScenarioSpec]:
    """The rf x dc x stressor-count grid behind ``characterize_surface``.

    Every grid cell is one :class:`ScenarioSpec` whose single stressor
    is the ``b`` mixed stream at ``TrafficShape.traffic(rf, dc)`` — the
    cell's ladder supplies the ``n_stressors`` axis, the shape supplies
    the other two.  Only the shape varies across cells, so the whole
    grid runs through the coordinator's sweep-batched dispatch with one
    stacked program per distinct (rf, dc) signature.
    """
    specs: List[ScenarioSpec] = []
    s_pools = list(stress_pools) if stress_pools is not None else list(pools)
    for op in pools:
        for ostrat in obs_strategies:
            for sp in s_pools:
                for rf in rw_ratios:
                    for dc in inject_rates:
                        shape = TrafficShape.traffic(rf, dc)
                        specs.append(ScenarioSpec(
                            name=(f"{name_prefix}{op}.{ostrat}|{sp}.b"
                                  f"@{shape.tag()}"),
                            observer=ObserverSpec(ostrat, op,
                                                  (buffer_bytes,)),
                            stressors=(StressorSpec("b", sp, buffer_bytes,
                                                    shape),),
                            iters=iters,
                            max_stressors=max_stressors))
    return specs


def save_matrix(specs: Iterable[ScenarioSpec], path: str) -> None:
    import json
    with open(path, "w") as f:
        json.dump({"schema": SCHEMA_VERSION,
                   "scenarios": [s.to_dict() for s in specs]}, f, indent=1)


def load_matrix(path: str) -> List[ScenarioSpec]:
    import json
    with open(path) as f:
        d = json.load(f)
    return [ScenarioSpec.from_dict(s) for s in d["scenarios"]]
