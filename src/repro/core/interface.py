"""User-space interface — the debugfs entries, as strings + a CLI.

Five entries, mirroring /sys/kernel/debug/membench:

  experiment   write: positional config string; read: last parsed config
  pools        read-only pool listing (id, size, free, allocs)
  perfcount    write: comma-separated event list; read: current selection
  results      read-only formatted results of the last experiment
  cmd          write: start | validate | erase

Config-string grammar (positional, like the paper's sscanf format)::

    <main_strat>,<main_pool>,<main_bytes> <stress_strat>,<stress_pool>,
    <stress_bytes> [iters=<n>] [scenarios=<n>]

Sizes accept K/M/G suffixes.  Example::

    l,hbm,4M w,host,4M iters=500
"""
from __future__ import annotations

import argparse
import re
import sys
from typing import Dict, Optional, Tuple

from repro.core.coordinator import (ActivitySpec, CoreCoordinator,
                                    ExperimentConfig, ExperimentResult,
                                    ValidationError)
from repro.core.counters import EVENTS, MAX_COUNTERS, select_events
from repro.core.devicetree import detect_platform
from repro.core.pools import PoolManager

_SIZE = {"": 1, "K": 1 << 10, "M": 1 << 20, "G": 1 << 30}


def parse_size(s: str) -> int:
    m = re.fullmatch(r"(\d+)([KMG]?)", s.strip(), re.I)
    if not m:
        raise ValueError(f"bad size {s!r}")
    return int(m.group(1)) * _SIZE[m.group(2).upper()]


def parse_activity(s: str) -> ActivitySpec:
    parts = s.split(",")
    if len(parts) != 3:
        raise ValueError(
            f"activity must be <strat>,<pool>,<bytes>: got {s!r}")
    return ActivitySpec(parts[0].strip(), parts[1].strip(),
                        parse_size(parts[2]))


def parse_experiment(line: str) -> ExperimentConfig:
    toks = line.split()
    if len(toks) < 2:
        raise ValueError(
            "need two activities: '<main> <stress> [iters=..] "
            "[scenarios=..]'")
    main = parse_activity(toks[0])
    stress = parse_activity(toks[1])
    kw: Dict[str, int] = {}
    for t in toks[2:]:
        k, _, v = t.partition("=")
        if k not in ("iters", "scenarios"):
            raise ValueError(f"unknown option {k!r}")
        kw[k] = int(v)
    return ExperimentConfig(main=main, stress=stress,
                            iters=kw.get("iters", 500),
                            scenarios=kw.get("scenarios"))


def format_experiment(cfg: ExperimentConfig) -> str:
    extra = f" iters={cfg.iters}"
    if cfg.scenarios is not None:
        extra += f" scenarios={cfg.scenarios}"
    return (f"{cfg.main.strategy},{cfg.main.pool},{cfg.main.buffer_bytes} "
            f"{cfg.stress.strategy},{cfg.stress.pool},"
            f"{cfg.stress.buffer_bytes}{extra}")


def format_results(res: ExperimentResult) -> str:
    cfg = res.config
    lines = [f"# config: {format_experiment(cfg)}",
             "stressors  bw_GBps    lat_ns   stress_bw_GBps"]
    for s in res.scenarios:
        lines.append(f"{s.n_stressors:9d}  {s.modeled_bw_gbps:8.3f} "
                     f"{s.modeled_lat_ns:9.1f}  {s.stress_bw_gbps:8.3f}")
    return "\n".join(lines)


class MemscopeInterface:
    """Holds the debugfs-entry state machine."""

    def __init__(self, coordinator: Optional[CoreCoordinator] = None):
        self.coord = coordinator or CoreCoordinator()
        self._experiment: Optional[ExperimentConfig] = None
        self._events: Tuple[str, ...] = EVENTS[:MAX_COUNTERS]
        self._results: Optional[ExperimentResult] = None

    # entry: experiment -------------------------------------------------
    def write_experiment(self, line: str) -> None:
        self._experiment = parse_experiment(line)

    def read_experiment(self) -> str:
        if self._experiment is None:
            return "(no experiment configured)"
        return format_experiment(self._experiment)

    # entry: pools --------------------------------------------------------
    def read_pools(self) -> str:
        return self.coord.pools.status()

    # entry: perfcount ------------------------------------------------------
    def write_perfcount(self, line: str) -> None:
        self._events = select_events(
            tuple(e.strip() for e in line.split(",") if e.strip()))

    def read_perfcount(self) -> str:
        return ",".join(self._events)

    # entry: cmd --------------------------------------------------------------
    def write_cmd(self, cmd: str) -> str:
        cmd = cmd.strip()
        if cmd == "validate":
            if self._experiment is None:
                return "ERR no experiment configured"
            try:
                self.coord.validate(self._experiment)
                return "OK valid"
            except (ValidationError, Exception) as e:  # noqa: BLE001
                return f"ERR {e}"
        if cmd == "start":
            if self._experiment is None:
                return "ERR no experiment configured"
            self._results = self.coord.run(self._experiment)
            return "OK complete"
        if cmd == "erase":
            self._results = None
            return "OK erased"
        return f"ERR unknown command {cmd!r}"

    # entry: results -------------------------------------------------------
    def read_results(self) -> str:
        if self._results is None:
            return "(no results)"
        return format_results(self._results)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.core.interface",
        description="MEMSCOPE-JAX experiment control")
    ap.add_argument("--experiment", help="config string (see module doc)")
    ap.add_argument("--cmd", default="start",
                    choices=["start", "validate", "erase"])
    ap.add_argument("--pools", action="store_true",
                    help="list pools and exit")
    ap.add_argument("--platform", default=None)
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "simulate", "interpret", "tpu"])
    args = ap.parse_args(argv)

    platform = detect_platform(args.platform)
    iface = MemscopeInterface(CoreCoordinator(
        PoolManager(platform), platform, backend=args.backend))

    if args.pools:
        print(iface.read_pools())
        return 0
    if not args.experiment:
        ap.error("--experiment required (or --pools)")
    iface.write_experiment(args.experiment)
    out = iface.write_cmd(args.cmd)
    print(out)
    if args.cmd == "start" and out.startswith("OK"):
        print(iface.read_results())
    return 0 if out.startswith("OK") else 1


if __name__ == "__main__":
    sys.exit(main())
