"""Adversarial worst-case contention search over the surface space.

Fixed characterization grids (``characterize_surface``) find *average*
corners; production placement needs the worst ones.  This module hunts
peak-interference configurations over the full probe coordinate space —
the :class:`SurfaceCoord` axes (``n_stressors``, ``rw_ratio``,
``inject_rate``) plus the remaining :class:`TrafficShape` knobs the
surface does not sweep (stressor strategy, chase stride) — with a
model-seeded acquisition loop instead of a sweep:

* the **prior** is the Bard–Schweitzer queueing model
  (:func:`repro.core.simulate.simulate_scenario`), calibrated to a
  measured CurveDB edge when one is supplied
  (:func:`~repro.core.simulate.calibrate_to_surface`);
* the strategy/stride knobs form a small set of **arms** played by a
  UCB bandit (one arm per iteration, so every probe of a batch shares
  one chain requirement and legally stacks — see
  :func:`repro.core.exec.plan.probe_batch`);
* within the chosen arm, lattice-sampled candidate coordinates are
  ranked by *acquired badness*: the model's predicted badness times a
  kernel-weighted measured/model residual correction times a novelty
  bonus for unexplored regions;
* each iteration executes as exactly ONE re-planned batched dispatch
  through the existing plan -> program -> fence -> dispatch -> assemble
  pipeline (``DispatchStats.host_sync_dispatches`` grows by one per
  iteration — asserted);
* the result is a per-observer **worst-case envelope**: a 1-axis
  (``n_stressors``) surface of the worst bandwidth/latency found at
  each stressor count, emitted into CurveDB under
  ``SurfaceKey(qualifier="worstcase")`` with full provenance
  (acquisition trace, probes executed, model-vs-measured gap per
  iteration).  ``PlacementAdvisor(pessimistic=True)`` advises against
  this envelope instead of the mean surface.

*Badness* is normalized per observer strategy so one bandit can rank
both: ``edge_bw / bw`` for bandwidth observers, ``lat / edge_lat`` for
latency observers (both ~1 uncontended, larger = worse), with the edge
taken from the (calibrated) model's own uncontended corner.

Determinism: every acquisition decision draws from one
``random.Random(spec.seed)`` stream and all scoring is pure arithmetic,
so two searches against the same CurveDB produce byte-identical
envelopes — on the modeled path (``execute=False``) bit-for-bit,
including across a save/load round-trip of the database.
"""
from __future__ import annotations

import logging
import math
import random
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

from repro.core.characterize import AXIS_N, CurveDB, Surface, SurfaceAxis, \
    SurfaceKey
from repro.core.exec import plan as exec_plan
from repro.core.exec import resilience as exec_resilience
from repro.core.exec.assemble import observer_result
from repro.core.exec.dispatch import DispatchStats
from repro.core.scenarios import ObserverSpec, ScenarioSpec, StressorSpec, \
    TrafficShape
from repro.core.simulate import ActivityClass, _modeled_edge, \
    calibrate_to_surface, simulate_scenario

log = logging.getLogger(__name__)

#: structured SurfaceKey qualifier the envelope is stored under
WORSTCASE_QUALIFIER = "worstcase"


# ---------------------------------------------------------------------------
# The search space
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SearchArm:
    """One discrete stressor-shape choice (strategy + chase stride).

    Arms quantize the knobs the surface's continuous axes do not carry.
    A probe batch plays ONE arm so all its stressors share a single
    pointer-chain requirement (mixed strides cannot share one operand —
    ``plan.merge_probe_operand_roles`` would refuse the batch)."""
    strategy: str
    stride: int = 1

    def label(self) -> str:
        return (f"{self.strategy}/st{self.stride}"
                if self.strategy == "t" else self.strategy)

    def shape(self, rw: float, ir: float) -> TrafficShape:
        if self.strategy == "t":
            return TrafficShape(kind="strided", stride=self.stride,
                                duty_cycle=ir)
        if self.strategy in ("w", "x", "y"):    # pure-write streams
            return TrafficShape.burst(ir) if ir != 1.0 else \
                TrafficShape.steady()
        return TrafficShape.traffic(rw, ir)

    def read_fraction(self, rw: float) -> Optional[float]:
        """The model-class read fraction this arm honours (mixed
        streams take the coordinate; pure strategies keep their native
        traffic multiplier)."""
        return rw if self.strategy in ("b", "c") else None


DEFAULT_ARMS: Tuple[SearchArm, ...] = (
    SearchArm("b"),             # mixed stream: rw_ratio is live
    SearchArm("y"),             # posted write stream (2x MLP)
    SearchArm("t", 8),          # default-stride pointer chase
    SearchArm("t", 64),         # locality-defeating wide chase
)


@dataclass(frozen=True)
class SearchSpec:
    """Budget, space bounds and every random choice's seed.

    The probe budget is ``iterations * batch`` coordinates (each
    coordinate is measured under every observer strategy inside the
    same batched dispatch)."""
    pool: str = "hbm"
    stress_pool: Optional[str] = None
    obs_strategies: Tuple[str, ...] = ("r", "l")
    iterations: int = 4
    batch: int = 4
    max_stressors: Optional[int] = None
    buffer_bytes: int = 256 << 10
    iters: int = 20
    seed: int = 0
    arms: Tuple[SearchArm, ...] = DEFAULT_ARMS
    explore: float = 0.35       # novelty bonus weight
    ucb: float = 0.8            # bandit exploration constant
    rw_step: float = 0.125      # rw_ratio lattice pitch
    ir_min: float = 0.25        # inject_rate lattice floor
    ir_step: float = 0.125


@dataclass(frozen=True)
class ProbePoint:
    """One executed (or modeled) probe: a full coordinate plus what was
    measured there and what the prior predicted."""
    iteration: int
    arm: str
    strategy: str
    stride: int
    n_stressors: int
    rw_ratio: float
    inject_rate: float
    obs_strat: str
    bandwidth_gbps: float
    latency_ns: float
    model_badness: float
    measured_badness: float

    def to_dict(self) -> Dict[str, Any]:
        return {k: getattr(self, k) for k in (
            "iteration", "arm", "strategy", "stride", "n_stressors",
            "rw_ratio", "inject_rate", "obs_strat", "bandwidth_gbps",
            "latency_ns", "model_badness", "measured_badness")}


@dataclass
class SearchResult:
    spec: SearchSpec
    envelope: Dict[SurfaceKey, Surface]
    points: List[ProbePoint]
    trace: List[Dict[str, Any]]
    stats: DispatchStats
    fenced: bool
    executed: bool

    def worst(self, obs_strat: str) -> ProbePoint:
        """The single worst probe found for one observer strategy."""
        pts = [p for p in self.points if p.obs_strat == obs_strat]
        if not pts:
            raise KeyError(f"no probes for observer {obs_strat!r}")
        return max(pts, key=lambda p: p.measured_badness)

    def install(self, db: CurveDB) -> List[SurfaceKey]:
        """Emit the envelope into ``db`` (same Surface/SurfaceKey API
        the mean surfaces use)."""
        for k, s in self.envelope.items():
            db.surfaces[k] = s
        return sorted(self.envelope)


# ---------------------------------------------------------------------------
# The model prior
# ---------------------------------------------------------------------------


def _model_rates(platform, pool: str, sp: str, ostrat: str, arm: SearchArm,
                 n: int, rw: float, ir: float) -> Tuple[float, float]:
    """(bw_gbps, lat_ns) the queueing model predicts for one observer
    under ``n`` arm-shaped stressors."""
    classes = [ActivityClass("obs", platform.memories[pool], ostrat, 1)]
    if n > 0:
        classes.append(ActivityClass(
            "stress", platform.memories[sp], arm.strategy, n,
            read_fraction=arm.read_fraction(rw), duty_cycle=ir,
            stride=arm.stride))
    res = simulate_scenario(platform, classes)["obs"]
    return res.bw_gbps, res.lat_ns


def _badness(ostrat: str, bw: float, lat: float,
             edges: Tuple[float, float]) -> float:
    """Normalized how-bad-is-this-corner: ~1 uncontended, larger =
    worse, comparable across observer strategies."""
    e_bw, e_lat = edges
    if ostrat == "l":
        return lat / max(e_lat, 1e-12)
    return e_bw / max(bw, 1e-12)


# ---------------------------------------------------------------------------
# Acquisition
# ---------------------------------------------------------------------------

_KERNEL_H = 0.2     # residual kernel width in normalized coordinates


def _coord_vec(arm_idx: int, n: int, rw: float, ir: float, max_n: int,
               n_arms: int) -> Tuple[float, ...]:
    return (arm_idx / max(1, n_arms - 1), n / max(1, max_n), rw, ir)


def _residual(observations, vec, ostrat: str) -> float:
    """Kernel-weighted mean of measured/model badness ratios near
    ``vec`` — the acquisition's learned correction of the prior."""
    num = den = 0.0
    for o_vec, o_strat, ratio in observations:
        if o_strat != ostrat:
            continue
        d2 = sum((a - b) ** 2 for a, b in zip(vec, o_vec))
        w = math.exp(-d2 / (2.0 * _KERNEL_H * _KERNEL_H))
        num += w * ratio
        den += w
    return num / den if den > 1e-12 else 1.0


def _novelty(observations, vec) -> float:
    """Distance to the nearest observation, saturated to [0, 1]."""
    if not observations:
        return 1.0
    d2min = min(sum((a - b) ** 2 for a, b in zip(vec, o_vec))
                for o_vec, _strat, _ratio in observations)
    return min(1.0, 4.0 * math.sqrt(d2min))


def _lattice_draw(rng: random.Random, spec: SearchSpec,
                  max_n: int) -> Tuple[int, float, float]:
    n = rng.randint(1, max_n)
    rw = round(rng.randint(0, int(round(1.0 / spec.rw_step)))
               * spec.rw_step, 6)
    ir_steps = int(round((1.0 - spec.ir_min) / spec.ir_step))
    ir = round(spec.ir_min + rng.randint(0, ir_steps) * spec.ir_step, 6)
    return n, rw, ir


# ---------------------------------------------------------------------------
# Probe execution (one batched dispatch per call)
# ---------------------------------------------------------------------------


def _probe_scenario(spec: SearchSpec, arm: SearchArm, ostrat: str, sp: str,
                    n: int, rw: float, ir: float, max_n: int,
                    it: int) -> ScenarioSpec:
    shape = arm.shape(rw, ir)
    tag = shape.tag()
    name = (f"wc{it}.{spec.pool}.{ostrat}|{sp}.{arm.strategy}"
            + (f"@{tag}" if tag else "") + f".n{n}")
    return ScenarioSpec(
        name=name,
        observer=ObserverSpec(ostrat, spec.pool, (spec.buffer_bytes,)),
        stressors=(StressorSpec(arm.strategy, sp, spec.buffer_bytes,
                                shape),),
        iters=spec.iters, max_stressors=max_n)


def measure_candidates(coord, spec: SearchSpec, arm: SearchArm, cands,
                       *, it: int = 0, stats: Optional[DispatchStats] = None,
                       ) -> Tuple[Dict[Tuple[int, str],
                                       Tuple[float, float]], bool]:
    """Measure every (n, rw, ir) candidate under every observer strategy
    with ONE host-synchronous batched dispatch
    (:func:`repro.core.exec.plan.probe_batch`).  Returns
    ``({(cand_index, obs_strat): (bw_gbps, lat_ns) | None}, fenced)``
    — a ``None`` value is a DEAD probe: its dispatch exhausted the
    resilience ladder (see :mod:`repro.core.exec.resilience`) and the
    caller must treat the arm as unplayed rather than fold a modeled
    number into the acquisition state.

    This is the only execution path of the search — the equal-budget
    fixed-grid baseline in ``benchmarks/worstcase_search.py`` measures
    its grid through the same call, so search and baseline pay the
    same per-probe cost.  On a clean dispatch (no faults, retries,
    degradations or re-measures) the 1-host-sync accounting is still
    asserted exactly."""
    stats = stats if stats is not None else DispatchStats()
    sp = spec.stress_pool or spec.pool
    n_eng = coord._spmd_engines()
    max_n = _max_stressors(coord, spec, executed=True)
    probes = []
    for n, rw, ir in cands:
        for o in spec.obs_strategies:
            ps = _probe_scenario(spec, arm, o, sp, n, rw, ir, max_n, it)
            probes.append((ps, ps.observer, ps.observer.buffers[0], n))
    planned = exec_plan.probe_batch(probes, n_eng, coord.pools,
                                    coord.platform.n_engines)
    before = stats.host_sync_dispatches
    dirty_before = (stats.faults_injected + stats.retried_dispatches
                    + stats.degraded_ladders + stats.noisy_remeasures)
    outcomes = exec_resilience.run_group(
        coord._dispatcher, planned, n_eng=n_eng,
        activity=coord._resolved_activity(), mode="batched",
        stats=stats, policy=getattr(coord, "retry_policy", None),
        gate=getattr(coord, "quality_gate", None))
    dirty = (stats.faults_injected + stats.retried_dispatches
             + stats.degraded_ladders + stats.noisy_remeasures
             - dirty_before)
    if not dirty and stats.host_sync_dispatches != before + 1:
        raise AssertionError(
            f"clean probe batch took "
            f"{stats.host_sync_dispatches - before} host syncs, "
            f"expected exactly 1")
    out: Dict[Tuple[int, str], Optional[Tuple[float, float]]] = {}
    fenced = True
    n_obs = len(spec.obs_strategies)
    for g, oc in enumerate(outcomes):
        ci, oi = divmod(g, n_obs)
        m = oc.med[0]
        if m is None:                   # probe died: modeled floor
            out[(ci, spec.obs_strategies[oi])] = None
            continue
        res = observer_result(oc.entry.observer, oc.entry.buffer_bytes,
                              oc.entry.spec.iters, float(max(m, 1.0)))
        out[(ci, spec.obs_strategies[oi])] = (res.bandwidth_gbps,
                                              res.latency_ns)
        fenced = fenced and oc.fenced
    return out, fenced


def _max_stressors(coord, spec: SearchSpec, *, executed: bool) -> int:
    cap = coord.platform.n_engines - 1
    if executed:
        cap = min(cap, coord._spmd_engines() - 1)
    if spec.max_stressors is not None:
        cap = min(cap, spec.max_stressors)
    return max(1, cap)


# ---------------------------------------------------------------------------
# The search loop
# ---------------------------------------------------------------------------


def worst_case_search(coord, spec: SearchSpec = SearchSpec(),
                      db: Optional[CurveDB] = None, *,
                      execute: Optional[bool] = None) -> SearchResult:
    """Hunt the worst contention corner within ``spec``'s budget.

    ``db`` (optional) calibrates the model prior to the measured
    surface edge before the search starts; the envelope can be
    installed back into the same database
    (:meth:`SearchResult.install`).  ``execute=None`` probes on the
    mesh when the coordinator's spmd backend has one (>= 2 devices)
    and falls back to the modeled path otherwise; ``execute=False``
    forces the deterministic modeled path (the acquisition loop runs
    identically — only the measurement is the model itself)."""
    platform = coord.platform
    if db is not None:
        try:
            platform = calibrate_to_surface(
                platform, db, pools=[spec.pool]).platform
        except (KeyError, ValueError) as exc:
            log.warning("worst_case_search: calibration skipped: %s", exc)
    if execute is None:
        try:
            import jax
            execute = (getattr(coord, "backend", None) == "spmd"
                       and len(jax.devices()) >= 2)
        except Exception:       # pragma: no cover - no jax at all
            execute = False
    sp = spec.stress_pool or spec.pool
    max_n = _max_stressors(coord, spec, executed=execute)
    edge = _modeled_edge(platform, spec.pool)
    edges = {o: edge for o in spec.obs_strategies}

    rng = random.Random(spec.seed)
    observations: List[Tuple[Tuple[float, ...], str, float]] = []
    points: List[ProbePoint] = []
    trace: List[Dict[str, Any]] = []
    stats = DispatchStats()
    fenced_all = True
    arm_plays = [0] * len(spec.arms)
    arm_value = [0.0] * len(spec.arms)

    for it in range(spec.iterations):
        # -- bandit: pick the arm (play each once, then UCB).  An arm
        # whose whole probe batch DIED never got a play recorded, so
        # the unplayed-first rule naturally replays it on the next
        # iteration instead of dividing by arm_plays == 0.
        unplayed = [i for i in range(len(spec.arms))
                    if arm_plays[i] == 0]
        if unplayed:
            ai = unplayed[0]
        else:
            total = sum(arm_plays)
            ai = max(range(len(spec.arms)),
                     key=lambda i: (arm_value[i] / arm_plays[i]
                                    + spec.ucb * math.sqrt(
                                        math.log(total) / arm_plays[i]),
                                    -i))
        arm = spec.arms[ai]

        # -- acquisition: rank lattice candidates under this arm --------
        seen, drawn = set(), []
        for _ in range(max(32, 8 * spec.batch)):
            c = _lattice_draw(rng, spec, max_n)
            if c not in seen:
                seen.add(c)
                drawn.append(c)
        scored = []
        for n, rw, ir in drawn:
            vec = _coord_vec(ai, n, rw, ir, max_n, len(spec.arms))
            model: Dict[str, Tuple[float, float, float]] = {}
            acq = 0.0
            for o in spec.obs_strategies:
                bw, lat = _model_rates(platform, spec.pool, sp, o, arm,
                                       n, rw, ir)
                mb = _badness(o, bw, lat, edges[o])
                model[o] = (bw, lat, mb)
                acq += (mb * _residual(observations, vec, o)
                        * (1.0 + spec.explore
                           * _novelty(observations, vec)))
            scored.append((acq, n, rw, ir, vec, model))
        scored.sort(key=lambda s: (-s[0], s[1], s[2], s[3]))
        chosen = scored[:spec.batch]

        # -- ONE batched dispatch for the whole iteration ---------------
        sync_before = stats.host_sync_dispatches
        if execute:
            results, fenced = measure_candidates(
                coord, spec, arm, [(n, rw, ir)
                                   for _a, n, rw, ir, _v, _m in chosen],
                it=it, stats=stats)
            fenced_all = fenced_all and fenced
        else:
            results = {(ci, o): model[o][:2]
                       for ci, (_a, _n, _rw, _ir, _v, model)
                       in enumerate(chosen) for o in spec.obs_strategies}

        # -- fold measurements back into the acquisition state ----------
        # (a dead probe — resilience ladder exhausted — contributes
        # nothing: folding its modeled floor would teach the bandit
        # the corner is harmless when in fact it is unmeasured)
        gaps: List[float] = []
        reward = 0.0
        alive = dead = 0
        for ci, (_acq, n, rw, ir, vec, model) in enumerate(chosen):
            for o in spec.obs_strategies:
                r = results[(ci, o)]
                if r is None:
                    dead += 1
                    continue
                bw, lat = r
                alive += 1
                mb = model[o][2]
                meas = _badness(o, bw, lat, edges[o])
                ratio = meas / max(mb, 1e-12)
                observations.append((vec, o, ratio))
                gaps.append(abs(ratio - 1.0))
                reward = max(reward, meas)
                points.append(ProbePoint(
                    iteration=it, arm=arm.label(),
                    strategy=arm.strategy, stride=arm.stride,
                    n_stressors=n, rw_ratio=rw, inject_rate=ir,
                    obs_strat=o, bandwidth_gbps=bw, latency_ns=lat,
                    model_badness=mb, measured_badness=meas))
        if alive:
            arm_plays[ai] += 1
            arm_value[ai] += reward
        trace.append({
            "iteration": it, "arm": arm.label(),
            "candidates": [[n, rw, ir]
                           for _a, n, rw, ir, _v, _m in chosen],
            "acquisition": [s[0] for s in chosen],
            "reward": reward,
            "model_gap": (sum(gaps) / len(gaps)) if gaps else 0.0,
            "host_sync_dispatches": (stats.host_sync_dispatches
                                     - sync_before if execute else 0),
            "dead_probes": dead,
        })

    envelope = _envelope(spec, sp, points, trace, executed=execute)
    if (execute and stats.resilience_clean()
            and stats.host_sync_dispatches != spec.iterations):
        raise AssertionError(
            f"search ran {stats.host_sync_dispatches} host syncs for "
            f"{spec.iterations} iterations — expected exactly one each")
    return SearchResult(spec=spec, envelope=envelope, points=points,
                        trace=trace, stats=stats, fenced=fenced_all,
                        executed=bool(execute))


def _envelope(spec: SearchSpec, sp: str, points: List[ProbePoint],
              trace: List[Dict[str, Any]], *,
              executed: bool) -> Dict[SurfaceKey, Surface]:
    """Per-observer worst-case envelope: the worst probe at each
    visited stressor count, as a 1-axis surface under the
    ``worstcase`` qualifier.  The stressor strategy in the key is the
    canonical ``"b"`` so the placement resolution ladder (which walks
    ``(strategy, "b")``) finds the envelope for ANY nominal stressor
    letter — the search already maximized over strategies."""
    out: Dict[SurfaceKey, Surface] = {}
    for o in spec.obs_strategies:
        pts = [p for p in points if p.obs_strat == o]
        if not pts:
            continue
        worst_at: Dict[int, ProbePoint] = {}
        for p in pts:
            cur = worst_at.get(p.n_stressors)
            if cur is None or p.measured_badness > cur.measured_badness:
                worst_at[p.n_stressors] = p
        ns = sorted(worst_at)
        key = SurfaceKey(spec.pool, o, sp, "b",
                         qualifier=WORSTCASE_QUALIFIER)
        out[key] = Surface(
            axes=(SurfaceAxis(AXIS_N, tuple(float(n) for n in ns)),),
            bandwidth_gbps=[worst_at[n].bandwidth_gbps for n in ns],
            latency_ns=[worst_at[n].latency_ns for n in ns],
            provenance={"worstcase": {
                "seed": spec.seed,
                "iterations": spec.iterations,
                "batch": spec.batch,
                "executed": executed,
                "acquisition_trace": trace,
                "probes": [p.to_dict() for p in pts],
                "worst": max(pts,
                             key=lambda p: p.measured_badness).to_dict(),
            }})
    return out
