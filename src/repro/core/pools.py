"""Memory Pool Manager — one allocator per detected memory module.

The genalloc/genpool analog: every :class:`MemoryNode` from the device
tree gets a :class:`MemoryPool` that (a) tracks allocations against the
module's capacity exactly like ``gen_pool_alloc/gen_pool_free``, and
(b) places JAX arrays on the right physical memory via sharding
``memory_kind`` (HBM = "device", host DRAM = "pinned_host").  VMEM is not
directly addressable from XLA programs, so its pool hands out *residency
descriptors* consumed by the Pallas workloads (BlockSpec decisions) —
the software-managed-scratchpad equivalent of an allocation.

``upool()`` exports a pool to applications — the ``/dev/upool<ID>`` mmap
analog: it returns a placement function usable by any framework object
(KV caches, optimizer state, ...).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.core.devicetree import MemoryNode, Platform, detect_platform


class PoolError(RuntimeError):
    pass


@dataclass
class Allocation:
    """A live allocation handle (the gen_pool_alloc return value)."""
    pool_id: int
    nbytes: int
    array: Optional[jax.Array] = None      # None for VMEM residency grants
    tag: str = ""


class MemoryPool:
    """Allocator over one memory module."""

    def __init__(self, pool_id: int, node: MemoryNode):
        self.id = pool_id
        self.node = node
        self.capacity = node.size_bytes
        self.allocated = 0
        self._handles: Dict[int, Allocation] = {}
        self._next = itertools.count()

    # -- genpool API ---------------------------------------------------
    def alloc(self, shape: Tuple[int, ...], dtype=jnp.float32, *,
              init: Optional[Callable[[Tuple[int, ...], Any], Any]] = None,
              tag: str = "") -> Allocation:
        nbytes = int(np.prod(shape)) * jnp.dtype(dtype).itemsize
        if self.allocated + nbytes > self.capacity:
            raise PoolError(
                f"pool {self.node.name}#{self.id}: alloc {nbytes}B exceeds "
                f"capacity ({self.allocated}/{self.capacity}B used)")
        arr = None
        if self.node.memory_kind is not None:
            data = (init(shape, dtype) if init is not None
                    else jnp.zeros(shape, dtype))
            arr = self._place(data)
        a = Allocation(self.id, nbytes, arr, tag)
        a.handle = next(self._next)
        self._handles[a.handle] = a
        self.allocated += nbytes
        return a

    def free(self, a: Allocation) -> None:
        if self._handles.pop(getattr(a, "handle", -1), None) is None:
            raise PoolError(f"double free / foreign handle in pool {self.id}")
        self.allocated -= a.nbytes
        a.array = None

    def destroy(self) -> None:
        self._handles.clear()
        self.allocated = 0

    # -- placement -------------------------------------------------------
    def place(self, data: jax.Array) -> jax.Array:
        """Place an array on this pool's memory kind (public hook for
        transient measurement buffers that bypass alloc accounting)."""
        return self._place(data)

    def _place(self, data: jax.Array) -> jax.Array:
        kind = self.node.memory_kind
        dev = jax.devices()[0]
        if kind in (None, "device"):
            return jax.device_put(data, dev)
        # compat degrades to default memory on backends without this kind
        # (CPU container): placement is emulated; accounting stays exact.
        try:
            return jax.device_put(
                data, compat.single_device_sharding(dev, kind))
        except (ValueError, RuntimeError):
            # kind advertised but transfer refused: same degradation
            return jax.device_put(data, dev)

    def effective_memory_kind(self) -> Optional[str]:
        """The memory kind :meth:`place` actually lands arrays in.

        ``None`` = the device's default memory.  Pools whose declared
        kind the backend cannot address (e.g. ``pinned_host`` on this
        CPU container) degrade to the default, so two pools with equal
        effective kinds are *execution-equivalent* — the matrix runner
        uses this to decide which observers may share one stacked
        vmapped measurement batch."""
        kind = self.node.memory_kind
        if kind in (None, "device"):
            return None
        if kind in compat.device_memory_kinds(jax.devices()[0]):
            return kind
        return None

    def sharding_for(self, mesh, spec) -> jax.sharding.NamedSharding:
        """NamedSharding carrying this pool's memory kind (upool export)."""
        kind = self.node.memory_kind
        if kind in (None, "device"):
            return jax.sharding.NamedSharding(mesh, spec)
        return compat.named_sharding(mesh, spec, kind)

    # -- status -----------------------------------------------------------
    @property
    def available(self) -> int:
        return self.capacity - self.allocated

    def status(self) -> str:
        n = self.node
        return (f"pool {self.id}: {n.name:8s} kind={n.kind:5s} "
                f"size={self.capacity >> 20} MiB "
                f"free={self.available >> 20} MiB "
                f"allocs={len(self._handles)}")


class PoolManager:
    """Auto-instantiates one pool per device-tree memory node."""

    def __init__(self, platform: Optional[Platform] = None):
        self.platform = platform or detect_platform()
        self._pools: Dict[str, MemoryPool] = {}
        for i, (name, node) in enumerate(
                sorted(self.platform.memories.items())):
            self._pools[name] = MemoryPool(i, node)

    def pool(self, name_or_id) -> MemoryPool:
        if isinstance(name_or_id, int):
            for p in self._pools.values():
                if p.id == name_or_id:
                    return p
            raise PoolError(f"no pool with id {name_or_id}")
        if name_or_id not in self._pools:
            raise PoolError(
                f"no pool {name_or_id!r}; have {sorted(self._pools)}")
        return self._pools[name_or_id]

    def pools(self) -> List[MemoryPool]:
        return sorted(self._pools.values(), key=lambda p: p.id)

    # the /dev/upool<ID> analog: applications get a placement handle
    def upool(self, name_or_id) -> "UserPool":
        return UserPool(self.pool(name_or_id))

    def status(self) -> str:
        return "\n".join(p.status() for p in self.pools())

    def destroy_all(self) -> None:
        for p in self.pools():
            p.destroy()


@dataclass
class UserPool:
    """User-space export of a pool (mmap-on-/dev/upool analog)."""
    pool: MemoryPool

    def place(self, tree, mesh=None, specs=None):
        """Place a pytree of arrays into this pool's memory."""
        if mesh is None:
            return jax.tree.map(self.pool._place, tree)
        return jax.tree.map(
            lambda x, sp: jax.device_put(
                x, self.pool.sharding_for(mesh, sp)), tree, specs)

    def sharding(self, mesh, spec):
        return self.pool.sharding_for(mesh, spec)

    @property
    def name(self) -> str:
        return self.pool.node.name
