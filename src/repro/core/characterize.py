"""Characterization driver — performance curves + Little's-law MLP.

Runs the full cross-product of scenario ladders (obs pool x obs strategy
x stress pool x stress strategy), persists the resulting *performance
curves* (the right-hand side of the paper's Fig. 1), and derives the
memory-level parallelism of each module via Little's law
(Tables II/III):  MLP = latency[ns/Tx] x bandwidth[Tx/ns].

The resulting :class:`CurveDB` is the contract consumed by the
:mod:`repro.core.placement` advisor.
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from typing import Dict, Iterable, List, Optional, Tuple

from repro.core.coordinator import (ActivitySpec, CoreCoordinator,
                                    ExperimentConfig)
from repro.core.devicetree import Platform

Key = Tuple[str, str, str, str]   # (obs_pool, obs_strat, stress_pool, stress_strat)


@dataclass
class CurvePoint:
    n_stressors: int
    bandwidth_gbps: float
    latency_ns: float


@dataclass
class CurveDB:
    platform: str
    curves: Dict[str, List[CurvePoint]] = field(default_factory=dict)

    @staticmethod
    def key(obs_pool: str, obs_strat: str, stress_pool: str,
            stress_strat: str) -> str:
        return f"{obs_pool}:{obs_strat}|{stress_pool}:{stress_strat}"

    def get(self, obs_pool: str, obs_strat: str, stress_pool: str,
            stress_strat: str) -> List[CurvePoint]:
        return self.curves[self.key(obs_pool, obs_strat, stress_pool,
                                    stress_strat)]

    # -- the numbers placement cares about --------------------------------
    def effective_bw(self, pool: str, n_stressors: int,
                     stress_pool: Optional[str] = None,
                     strat: str = "r", stress_strat: str = "w") -> float:
        pts = self.get(pool, strat, stress_pool or pool, stress_strat)
        k = min(n_stressors, len(pts) - 1)
        return pts[k].bandwidth_gbps

    def effective_lat(self, pool: str, n_stressors: int,
                      stress_pool: Optional[str] = None,
                      stress_strat: str = "w") -> float:
        pts = self.get(pool, "l", stress_pool or pool, stress_strat)
        k = min(n_stressors, len(pts) - 1)
        return pts[k].latency_ns

    # -- Little's law -------------------------------------------------------
    def mlp(self, pool: str, line_bytes: int,
            stress_strat: str = "r") -> float:
        """Avg MLP = Avg latency [ns/Tx] x Avg bandwidth [Tx/ns], computed
        at the worst-case scenario like Tables II/III."""
        lat = self.get(pool, "l", pool, stress_strat)[-1].latency_ns
        bw = self.get(pool, "r", pool, stress_strat)[-1].bandwidth_gbps
        return lat * (bw / line_bytes)

    # -- persistence ----------------------------------------------------------
    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump({"platform": self.platform,
                       "curves": {k: [asdict(p) for p in v]
                                  for k, v in self.curves.items()}}, f,
                      indent=1)

    @staticmethod
    def load(path: str) -> "CurveDB":
        with open(path) as f:
            d = json.load(f)
        return CurveDB(platform=d["platform"],
                       curves={k: [CurvePoint(**p) for p in v]
                               for k, v in d["curves"].items()})


DEFAULT_BW_STRATS = ("r", "w")
DEFAULT_STRESS_STRATS = ("r", "w", "y")


def characterize(
    coord: CoreCoordinator,
    *,
    pools: Optional[Iterable[str]] = None,
    # default above the VMEM/cache budget: the curves must characterize
    # the MODULE, not the cache in front of it (cache-fit behaviour is
    # the fig5 buffer sweep's subject instead)
    buffer_bytes: int = 256 << 20,
    obs_strategies: Tuple[str, ...] = DEFAULT_BW_STRATS + ("l",),
    stress_strategies: Tuple[str, ...] = DEFAULT_STRESS_STRATS,
    iters: int = 500,
) -> CurveDB:
    """Run the full ladder cross-product and build the curve database."""
    platform = coord.platform
    pool_names = list(pools) if pools is not None else [
        p.node.name for p in coord.pools.pools()
        if p.node.kind != "vmem"]      # vmem probed via small buffers
    db = CurveDB(platform=platform.name)
    for obs_pool in pool_names:
        cap = coord.pools.pool(obs_pool).node.size_bytes
        nbytes = min(buffer_bytes, cap // 2)
        for obs_strat in obs_strategies:
            for stress_pool in pool_names:
                s_cap = coord.pools.pool(stress_pool).node.size_bytes
                s_bytes = min(buffer_bytes, s_cap // 2)
                for stress_strat in stress_strategies:
                    res = coord.run(ExperimentConfig(
                        main=ActivitySpec(obs_strat, obs_pool, nbytes),
                        stress=ActivitySpec(stress_strat, stress_pool,
                                            s_bytes),
                        iters=iters))
                    pts = [CurvePoint(s.n_stressors,
                                      s.modeled_bw_gbps,
                                      s.modeled_lat_ns)
                           for s in res.scenarios]
                    db.curves[CurveDB.key(obs_pool, obs_strat,
                                          stress_pool, stress_strat)] = pts
    return db


def mlp_table(db: CurveDB, platform: Platform) -> str:
    """Tables II/III, for every characterized module."""
    lines = ["pool      pairing        lat(ns/Tx)  BW(Tx/ns)   MLP"]
    pools = sorted({k.split(":")[0] for k in db.curves})
    for pool in pools:
        for stress in ("r", "w"):
            try:
                lat = db.get(pool, "l", pool, stress)[-1].latency_ns
                bw = db.get(pool, "r", pool, stress)[-1].bandwidth_gbps
            except KeyError:
                continue
            tx = bw / platform.line_bytes
            lines.append(
                f"{pool:9s} (l,{stress})x(r,{stress})  {lat:10.2f}"
                f"  {tx:9.4f}  {lat * tx:5.2f}")
    return "\n".join(lines)
