"""Characterization driver — bandwidth–latency surfaces + Little's-law MLP.

v3: the paper's curves are 1-D slices of the object that actually
predicts application behaviour — the **bandwidth–latency surface**
swept over read/write ratio and injection rate ("A Mess of Memory
System Benchmarking").  This module stores that object directly:

* :class:`SurfaceAxis` / :class:`SurfaceCoord` — named, ordered
  coordinates (``n_stressors``, ``rw_ratio`` from ``TrafficShape.mix``,
  ``inject_rate`` from ``duty_cycle``).
* :class:`Surface` — a dense point grid over those axes with
  multilinear interpolation; queries beyond the characterized grid
  clamp to the nearest edge and are *flagged* as extrapolated.
* :class:`SurfaceKey` — the typed curve identity
  ``(obs_pool, obs_strat, stress_pool, stress_strat)`` that replaces
  the flat ``"pool:strat|pool:strat@tag"`` string-key scheme.  Legacy
  spellings survive only as a serialisation detail inside this class;
  consumers (placement, roofline, simulate, serve) query through the
  coordinate API and never string-split keys (enforced by a grep lint
  in the test suite).

Results persist as a **versioned CurveDB** (schema 3): surfaces keyed
by :class:`SurfaceKey` with per-surface provenance.  Schema-1 (seed)
and schema-2 files still load — each old curve becomes a 1-axis
surface — and a v3 database still *saves* as schema 2 for downgrade
(multi-axis surfaces slice back into tagged per-shape curves).

Execution goes through the coordinator's batched matrix runner;
:func:`characterize_surface` emits the rf x dc x stressor-count grid
and records the :class:`DispatchStats` proof that the sweep compiled
to one stacked dispatch per distinct ladder signature.
"""
from __future__ import annotations

import json
import os
import tempfile
from bisect import bisect_right
from dataclasses import asdict, dataclass, field
from itertools import product
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core.coordinator import CoreCoordinator, MatrixResult
from repro.core.devicetree import Platform
from repro.core.scenarios import (DEFAULT_INJECT_RATES, DEFAULT_RW_RATIOS,
                                  ObserverSpec, ScenarioSpec, StressorSpec,
                                  TrafficShape, surface_matrix)

#: CurveDB on-disk schema written by default (see CurveDB.save).
CURVEDB_SCHEMA = 3

#: Canonical axis names, in canonical grid order.
AXIS_N = "n_stressors"
AXIS_RW = "rw_ratio"
AXIS_IR = "inject_rate"

#: rw_ratio a pure-strategy stressor sits at on the surface's mix axis:
#: read-side strategies are the rw=1 edge, write/writeback streams the
#: rw=0 edge, copy/mixed streams the midpoint.  This is what lets ONE
#: measured surface answer queries phrased in legacy stressor letters.
STRATEGY_RW_RATIO = {"r": 1.0, "s": 1.0, "l": 1.0, "m": 1.0, "t": 1.0,
                     "w": 0.0, "x": 0.0, "y": 0.0, "c": 0.5, "b": 0.5}


@dataclass
class CurvePoint:
    n_stressors: int
    bandwidth_gbps: float
    latency_ns: float


# ---------------------------------------------------------------------------
# The coordinate system
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SurfaceAxis:
    """One named, ordered surface axis (strictly ascending grid values)."""
    name: str
    values: Tuple[float, ...]

    def __post_init__(self):
        vals = tuple(float(v) for v in self.values)
        object.__setattr__(self, "values", vals)
        if not vals:
            raise ValueError(f"axis {self.name!r} needs at least one value")
        if any(b <= a for a, b in zip(vals, vals[1:])):
            raise ValueError(
                f"axis {self.name!r} values must be strictly ascending: "
                f"{vals}")

    def locate(self, v: float) -> Tuple[int, int, float, bool]:
        """Bracketing indices + interpolation fraction for ``v``:
        ``(lo, hi, t, clamped)``.  Out-of-range coordinates clamp to
        the nearest edge with ``clamped=True`` — the caller surfaces
        that as an *extrapolated* query instead of silently returning
        the edge point (the seed's ``min(n, len-1)`` bug).

        A coordinate ON an edge (``rw_ratio=1.0`` on a grid ending at
        1.0, or any value of a single-point axis) is in-range, and so
        is one that differs from the edge only by float noise
        (``0.1 * 3 > 0.3``): the clamped flag uses a relative-epsilon
        comparison, not strict inequality."""
        vals = self.values
        eps = 1e-9 * max(1.0, abs(vals[0]), abs(vals[-1]))
        if v <= vals[0]:
            return 0, 0, 0.0, v < vals[0] - eps
        if v >= vals[-1]:
            last = len(vals) - 1
            return last, last, 0.0, v > vals[-1] + eps
        hi = bisect_right(vals, v)
        lo = hi - 1
        t = (v - vals[lo]) / (vals[hi] - vals[lo])
        return lo, hi, t, False


@dataclass(frozen=True)
class SurfaceCoord:
    """A named point in surface coordinate space (ordered name/value
    pairs).  Build with :meth:`of`; ``None`` values are dropped so
    callers can pass optional coordinates straight through."""
    coords: Tuple[Tuple[str, float], ...] = ()

    @staticmethod
    def of(**kw: Optional[float]) -> "SurfaceCoord":
        return SurfaceCoord(tuple((k, float(v)) for k, v in kw.items()
                                  if v is not None))

    def get(self, name: str) -> Optional[float]:
        for k, v in self.coords:
            if k == name:
                return v
        return None

    def names(self) -> Tuple[str, ...]:
        return tuple(k for k, _ in self.coords)

    def to_dict(self) -> Dict[str, float]:
        return dict(self.coords)


@dataclass(frozen=True)
class SurfaceQuery:
    """One interpolated surface reading.  ``extrapolated`` is True when
    any coordinate fell outside the characterized grid (nearest-edge
    clamp), or when the query asked for an axis the resolved surface
    does not carry (legacy fallback)."""
    bandwidth_gbps: float
    latency_ns: float
    extrapolated: bool
    coord: SurfaceCoord = SurfaceCoord()


@dataclass(frozen=True, order=True)
class SurfaceKey:
    """Typed curve identity.  ``tag`` carries a stressor shape tag for
    legacy per-shape curves ('' for steady / full surfaces).

    ``qualifier`` is overloaded two ways, told apart by spelling:

    * a *structured* qualifier (``"worstcase"`` — no ``:|@``
      characters) names a variant of the canonical surface and spells
      as ``base[@tag]#qualifier`` (legacy keys never contain ``#``);
    * a *verbatim* qualifier (contains ``:|@``) preserves the exact
      legacy spelling of keys that carry more than the canonical
      4-tuple (observer shape tags, stressor ensembles, ``buf=``
      ladder suffixes), so v1/v2 files round-trip byte-exactly."""
    obs_pool: str
    obs_strat: str
    stress_pool: str
    stress_strat: str
    tag: str = ""
    qualifier: str = ""

    def to_string(self) -> str:
        if self.qualifier and any(c in self.qualifier for c in ":|@"):
            return self.qualifier         # verbatim legacy spelling
        base = (f"{self.obs_pool}:{self.obs_strat}"
                f"|{self.stress_pool}:{self.stress_strat}")
        if self.tag:
            base = f"{base}@{self.tag}"
        return f"{base}#{self.qualifier}" if self.qualifier else base

    @staticmethod
    def from_string(key: str) -> "SurfaceKey":
        base, _, qual = key.partition("#")
        obs, _, stress = base.partition("|")
        op, _, orest = obs.partition(":")
        ostrat, _, otag = orest.partition("@")
        parts = stress.split("|")         # ["sp:ss@tag+...", "buf=..."]
        ensemble = parts[0].split("+")
        sp, _, srest = ensemble[0].partition(":")
        sstrat, _, stag = srest.partition("@")
        canonical = not otag and len(parts) == 1 and len(ensemble) == 1
        return SurfaceKey(op, ostrat, sp, sstrat, tag=stag,
                          qualifier=(qual if canonical else key))

    def with_tag(self, tag: str) -> "SurfaceKey":
        return SurfaceKey(self.obs_pool, self.obs_strat, self.stress_pool,
                          self.stress_strat, tag=tag)


def _cell(grid: Any, idx: Sequence[int]) -> float:
    for i in idx:
        grid = grid[i]
    return float(grid)


@dataclass
class Surface:
    """A dense bandwidth/latency grid over named ordered axes.

    ``bandwidth_gbps`` / ``latency_ns`` are nested lists indexed in
    axis order (JSON-native, so a surface file is diffable).  Queries
    interpolate multilinearly between bracketing grid cells; off-grid
    coordinates clamp to the nearest edge and flag the result as
    extrapolated.
    """
    axes: Tuple[SurfaceAxis, ...]
    bandwidth_gbps: Any
    latency_ns: Any
    provenance: Dict[str, Any] = field(default_factory=dict)

    def __post_init__(self):
        self.axes = tuple(self.axes)
        if not self.axes:
            raise ValueError("surface needs at least one axis")

    # -- axis helpers -------------------------------------------------------
    def axis(self, name: str) -> SurfaceAxis:
        for ax in self.axes:
            if ax.name == name:
                return ax
        raise KeyError(f"surface has no axis {name!r}; "
                       f"have {[a.name for a in self.axes]}")

    def has_axis(self, name: str) -> bool:
        return any(ax.name == name for ax in self.axes)

    @property
    def shape(self) -> Tuple[int, ...]:
        return tuple(len(ax.values) for ax in self.axes)

    # -- the query ----------------------------------------------------------
    def query(self, coord: SurfaceCoord) -> SurfaceQuery:
        """Multilinear interpolation at ``coord`` (every axis of this
        surface must be present; extra coordinate names are the
        caller's concern)."""
        brackets: List[Tuple[int, int, float]] = []
        clamped = False
        for ax in self.axes:
            v = coord.get(ax.name)
            if v is None:
                raise ValueError(
                    f"query missing coordinate {ax.name!r} "
                    f"(have {list(coord.names())})")
            lo, hi, t, cl = ax.locate(v)
            brackets.append((lo, hi, t))
            clamped = clamped or cl
        bw = self._interp(self.bandwidth_gbps, brackets)
        lat = self._interp(self.latency_ns, brackets)
        return SurfaceQuery(bw, lat, clamped, coord)

    @staticmethod
    def _interp(grid: Any, brackets: List[Tuple[int, int, float]]) -> float:
        total = 0.0
        for corner in product((0, 1), repeat=len(brackets)):
            w = 1.0
            idx = []
            for bit, (lo, hi, t) in zip(corner, brackets):
                w *= t if bit else (1.0 - t)
                idx.append(hi if bit else lo)
            if w == 0.0:
                continue
            total += w * _cell(grid, idx)
        return total

    # -- slicing back to legacy 1-axis curves --------------------------------
    def n_axis_points(self, idx: Tuple[int, ...] = ()) -> List[CurvePoint]:
        """The 1-axis (n_stressors) slice at fixed trailing indices."""
        n_ax = self.axes[0]
        if n_ax.name != AXIS_N:
            raise ValueError(f"first axis is {n_ax.name!r}, not {AXIS_N!r}")
        return [CurvePoint(int(n),
                           _cell(self.bandwidth_gbps, (i,) + idx),
                           _cell(self.latency_ns, (i,) + idx))
                for i, n in enumerate(n_ax.values)]

    # -- persistence --------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return {"axes": [{"name": ax.name, "values": list(ax.values)}
                         for ax in self.axes],
                "bandwidth_gbps": self.bandwidth_gbps,
                "latency_ns": self.latency_ns,
                "provenance": self.provenance}

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "Surface":
        return Surface(axes=tuple(SurfaceAxis(a["name"], tuple(a["values"]))
                                  for a in d["axes"]),
                       bandwidth_gbps=d["bandwidth_gbps"],
                       latency_ns=d["latency_ns"],
                       provenance=d.get("provenance", {}))

    @staticmethod
    def from_points(points: List[CurvePoint],
                    provenance: Optional[Dict[str, Any]] = None) -> "Surface":
        """A legacy curve as a 1-axis surface (v1/v2 forward-load)."""
        pts = sorted(points, key=lambda p: p.n_stressors)
        return Surface(
            axes=(SurfaceAxis(AXIS_N, tuple(float(p.n_stressors)
                                            for p in pts)),),
            bandwidth_gbps=[p.bandwidth_gbps for p in pts],
            latency_ns=[p.latency_ns for p in pts],
            provenance=provenance or {})


# ---------------------------------------------------------------------------
# The database
# ---------------------------------------------------------------------------


@dataclass
class CurveDB:
    platform: str
    surfaces: Dict[SurfaceKey, Surface] = field(default_factory=dict)
    schema: int = CURVEDB_SCHEMA
    meta: Dict[str, Any] = field(default_factory=dict)

    @staticmethod
    def key(obs_pool: str, obs_strat: str, stress_pool: str,
            stress_strat: str, shape_tag: str = "") -> SurfaceKey:
        return SurfaceKey(obs_pool, obs_strat, stress_pool, stress_strat,
                          tag=shape_tag)

    # -- legacy views --------------------------------------------------------
    def _slices(self) -> Iterable[Tuple[str, List[CurvePoint],
                                        Dict[str, Any]]]:
        """Every surface as (legacy key string, points, provenance)
        1-axis slices — multi-axis surfaces slice per (rw, ir) cell
        under the cell shape's tag spelling."""
        for key, surf in self.surfaces.items():
            if len(surf.axes) == 1:
                yield key.to_string(), surf.n_axis_points(), surf.provenance
                continue
            rw_ax = surf.axis(AXIS_RW)
            ir_ax = surf.axis(AXIS_IR) if surf.has_axis(AXIS_IR) else None
            cells = surf.provenance.get("cells", {})
            for j, rw in enumerate(rw_ax.values):
                irs = ir_ax.values if ir_ax is not None else (1.0,)
                for k, ir in enumerate(irs):
                    tag = TrafficShape.traffic(rw, ir).tag()
                    idx = (j, k) if ir_ax is not None else (j,)
                    yield (key.with_tag(tag).to_string(),
                           surf.n_axis_points(idx),
                           cells.get(tag, surf.provenance))

    @property
    def curves(self) -> Dict[str, List[CurvePoint]]:
        """Read-only legacy view: ``{key string: [CurvePoint, ...]}``."""
        return {k: pts for k, pts, _prov in self._slices()}

    @property
    def provenance(self) -> Dict[str, Dict[str, Any]]:
        """Read-only legacy view of per-curve provenance."""
        return {k: prov for k, _pts, prov in self._slices() if prov}

    def get(self, obs_pool: str, obs_strat: str, stress_pool: str,
            stress_strat: str, shape_tag: str = "") -> List[CurvePoint]:
        k = SurfaceKey(obs_pool, obs_strat, stress_pool, stress_strat,
                       tag=shape_tag)
        surf = self.surfaces.get(k)
        if surf is not None and len(surf.axes) == 1:
            return surf.n_axis_points()
        return self.curves[k.to_string()]

    def observer_pools(self) -> List[str]:
        """Every pool with at least one characterized surface."""
        return sorted({k.obs_pool for k in self.surfaces})

    # -- the coordinate query (what placement/roofline/simulate consume) -----
    def _resolve(self, obs_pool: str, obs_strat: str, stress_pool: str,
                 stress_strat: str, shape_tag: str, qualifier: str = "",
                 ) -> Tuple[SurfaceKey, Surface, bool, bool]:
        """Surface lookup with the v3 resolution ladder: exact shaped
        key -> exact steady key -> the canonical mixed surface (pure
        stressor strategies are edges of its rw_ratio axis).  Returns
        (key, surface, tag_matched, fell_back).

        A requested ``qualifier`` (e.g. ``"worstcase"``) prefers the
        qualified surface at every ladder step, then falls through to
        the unqualified ladder — the caller flags the fallback via
        ``key.qualifier != qualifier``."""
        quals = (qualifier, "") if qualifier else ("",)
        if shape_tag:
            for q in quals:
                k = SurfaceKey(obs_pool, obs_strat, stress_pool,
                               stress_strat, tag=shape_tag, qualifier=q)
                s = self.surfaces.get(k)
                if s is not None:
                    return k, s, True, False
        for q in quals:
            for sstrat in (stress_strat, "b"):
                k = SurfaceKey(obs_pool, obs_strat, stress_pool, sstrat,
                               qualifier=q)
                s = self.surfaces.get(k)
                if s is not None:
                    return k, s, False, bool(shape_tag)
        raise KeyError(
            f"no surface for ({obs_pool!r}, {obs_strat!r}, "
            f"{stress_pool!r}, {stress_strat!r}); have "
            f"{sorted(k.to_string() for k in self.surfaces)}")

    def query(self, pool: str, n_stressors: float, *,
              obs_strat: str = "r", stress_pool: Optional[str] = None,
              stress_strat: str = "w", rw_ratio: Optional[float] = None,
              inject_rate: Optional[float] = None,
              shape_tag: str = "", qualifier: str = "") -> SurfaceQuery:
        """One interpolated reading of the characterized surface.

        ``rw_ratio`` / ``inject_rate`` select the stressor traffic mix
        and injection duty on a swept surface; when the surface lacks
        the axis (a 1-axis legacy curve) an explicitly-requested
        coordinate flags the result as extrapolated instead of being
        silently dropped.  ``shape_tag`` keeps resolving legacy
        per-shape curves exactly.  ``qualifier`` selects a variant
        surface (e.g. the ``"worstcase"`` search envelope), flagging
        the result when only the unqualified surface exists."""
        sp = stress_pool or pool
        key, surf, tag_hit, fell_back = self._resolve(
            pool, obs_strat, sp, stress_strat, shape_tag, qualifier)
        flagged = fell_back or (bool(qualifier)
                                and key.qualifier != qualifier)
        coords: Dict[str, float] = {AXIS_N: float(n_stressors)}
        if surf.has_axis(AXIS_RW):
            coords[AXIS_RW] = (rw_ratio if rw_ratio is not None
                               else STRATEGY_RW_RATIO.get(stress_strat, 0.5))
        elif rw_ratio is not None and not tag_hit:
            flagged = True
        if surf.has_axis(AXIS_IR):
            coords[AXIS_IR] = (inject_rate if inject_rate is not None
                               else 1.0)
        elif inject_rate is not None and not tag_hit:
            flagged = True
        q = surf.query(SurfaceCoord.of(**coords))
        return SurfaceQuery(q.bandwidth_gbps, q.latency_ns,
                            q.extrapolated or flagged, q.coord)

    # -- the numbers placement cares about (thin interpolating queries) ------
    def effective_bw(self, pool: str, n_stressors: float,
                     stress_pool: Optional[str] = None,
                     strat: str = "r", stress_strat: str = "w",
                     shape_tag: str = "",
                     rw_ratio: Optional[float] = None,
                     inject_rate: Optional[float] = None,
                     qualifier: str = "") -> float:
        return self.query(pool, n_stressors, obs_strat=strat,
                          stress_pool=stress_pool, stress_strat=stress_strat,
                          rw_ratio=rw_ratio, inject_rate=inject_rate,
                          shape_tag=shape_tag,
                          qualifier=qualifier).bandwidth_gbps

    def effective_lat(self, pool: str, n_stressors: float,
                      stress_pool: Optional[str] = None,
                      stress_strat: str = "w",
                      shape_tag: str = "",
                      rw_ratio: Optional[float] = None,
                      inject_rate: Optional[float] = None,
                      qualifier: str = "") -> float:
        return self.query(pool, n_stressors, obs_strat="l",
                          stress_pool=stress_pool, stress_strat=stress_strat,
                          rw_ratio=rw_ratio, inject_rate=inject_rate,
                          shape_tag=shape_tag,
                          qualifier=qualifier).latency_ns

    # -- Little's law -------------------------------------------------------
    def _worst(self, pool: str, obs_strat: str,
               stress_strat: str) -> SurfaceQuery:
        surf = self._resolve(pool, obs_strat, pool, stress_strat, "")[1]
        n_max = surf.axis(AXIS_N).values[-1]
        return self.query(pool, n_max, obs_strat=obs_strat,
                          stress_strat=stress_strat)

    def mlp(self, pool: str, line_bytes: int,
            stress_strat: str = "r") -> float:
        """Avg MLP = Avg latency [ns/Tx] x Avg bandwidth [Tx/ns], computed
        at the worst-case scenario like Tables II/III."""
        lat = self._worst(pool, "l", stress_strat).latency_ns
        bw = self._worst(pool, "r", stress_strat).bandwidth_gbps
        return lat * (bw / line_bytes)

    # -- persistence ----------------------------------------------------------
    def save(self, path: str, schema: Optional[int] = None) -> None:
        """Write the database.  Default: the schema it carries (so
        legacy-loaded files re-save in their own format); pass
        ``schema=2`` to downgrade a v3 database — multi-axis surfaces
        slice back into tagged per-shape curves, losslessly for every
        grid point."""
        schema = self.schema if schema is None else schema
        if schema >= CURVEDB_SCHEMA:
            doc: Dict[str, Any] = {
                "schema": CURVEDB_SCHEMA,
                "platform": self.platform,
                "surfaces": [dict(key=asdict(k), **s.to_dict())
                             for k, s in self.surfaces.items()],
                "meta": self.meta}
        else:
            doc = {"schema": schema,
                   "platform": self.platform,
                   "curves": {k: [asdict(p) for p in v]
                              for k, v in self.curves.items()},
                   "provenance": self.provenance,
                   "meta": self.meta}
        # atomic: write a sibling temp file and rename over the
        # target, so a crash (or injected fault) mid-save leaves any
        # existing database intact instead of torn
        d = os.path.dirname(os.path.abspath(path))
        fd, tmp = tempfile.mkstemp(dir=d, prefix=".curvedb-",
                                   suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(doc, f, indent=1)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    @staticmethod
    def load(path: str) -> "CurveDB":
        with open(path) as f:
            d = json.load(f)
        # schema 1 (the seed format) has no "schema" key and no
        # provenance — old curve files keep working; v1/v2 curves each
        # become a 1-axis surface under their typed key
        schema = int(d.get("schema", 1))
        db = CurveDB(platform=d["platform"], schema=schema,
                     meta=d.get("meta", {}))
        if schema >= CURVEDB_SCHEMA:
            for entry in d["surfaces"]:
                db.surfaces[SurfaceKey(**entry["key"])] = \
                    Surface.from_dict(entry)
            return db
        prov = d.get("provenance", {})
        for k, pts in d["curves"].items():
            db.surfaces[SurfaceKey.from_string(k)] = Surface.from_points(
                [CurvePoint(**p) for p in pts], prov.get(k))
        return db


DEFAULT_BW_STRATS = ("r", "w")
DEFAULT_STRESS_STRATS = ("r", "w", "y")


def characterize(
    coord: CoreCoordinator,
    *,
    pools: Optional[Iterable[str]] = None,
    # default above the VMEM/cache budget: the curves must characterize
    # the MODULE, not the cache in front of it (cache-fit behaviour is
    # the fig5 buffer sweep's subject instead)
    buffer_bytes: int = 256 << 20,
    obs_strategies: Tuple[str, ...] = DEFAULT_BW_STRATS + ("l",),
    stress_strategies: Tuple[str, ...] = DEFAULT_STRESS_STRATS,
    stress_shapes: Optional[
        Iterable[Tuple[str, TrafficShape]]] = None,
    iters: int = 500,
    batched: bool = True,
    journal=None,
) -> CurveDB:
    """Build the curve database for the scenario matrix.

    Default matrix = the seed's steady cross-product (so existing
    consumers see identical keys); pass ``stress_shapes`` — e.g.
    :data:`repro.core.scenarios.DEFAULT_STRESS_SHAPES` — to add shaped
    stressor scenarios (mixed r/w ratios, bursts, copies, strided
    chases) on top.
    """
    platform = coord.platform
    pool_names = list(pools) if pools is not None else [
        p.node.name for p in coord.pools.pools()
        if p.node.kind != "vmem"]      # vmem probed via small buffers
    shapes: List[Tuple[str, TrafficShape]] = [
        (s, TrafficShape.steady()) for s in stress_strategies]
    if stress_shapes is not None:
        for pair in stress_shapes:
            if pair not in shapes:
                shapes.append(pair)

    specs: List[ScenarioSpec] = []
    for op in pool_names:
        cap = coord.pools.pool(op).node.size_bytes
        nbytes = min(buffer_bytes, cap // 2)
        for ostrat in obs_strategies:
            for sp in pool_names:
                s_cap = coord.pools.pool(sp).node.size_bytes
                s_bytes = min(buffer_bytes, s_cap // 2)
                for sstrat, shape in shapes:
                    spec = ScenarioSpec(
                        name=f"{op}.{ostrat}|{sp}.{sstrat}"
                             f"{('@' + shape.tag()) if shape.tag() else ''}",
                        observer=ObserverSpec(ostrat, op, (nbytes,)),
                        stressors=(StressorSpec(sstrat, sp, s_bytes,
                                                shape),),
                        iters=iters)
                    specs.append(spec)
    return characterize_matrix(coord, specs, batched=batched,
                               journal=journal)


def characterize_matrix(coord: CoreCoordinator,
                        specs: List[ScenarioSpec], *,
                        batched: bool = True,
                        journal=None) -> CurveDB:
    """Run an explicit scenario matrix and persist it as a CurveDB.

    Each curve's provenance records the scenario spec AND an
    ``execution`` entry (which backend produced it, which ladder rungs
    were *executed* vs *modeled*, what ``activity`` filled the measured
    region — "pallas" kernels vs "jnp" fallback loops — and whether
    co-observers were ``coupled`` into the measured region) — an
    spmd-backend curve whose every point came from a live fused
    multi-engine dispatch is distinguishable from a queueing-model
    curve after the fact, and a coupled curve from an uncoupled one.

    ``journal=<path>`` (spmd backend) makes the sweep crash-resumable:
    completed dispatch groups restore value-identically from the
    sidecar on re-run (see :class:`repro.core.exec.SweepJournal`)."""
    result: MatrixResult = coord.run_matrix(specs, batched=batched,
                                            journal=journal)
    return curvedb_from_result(result, coord.platform.name,
                               backend=coord.backend)


def _stats_meta(result: MatrixResult, backend: str) -> Dict[str, Any]:
    return {
        "backend": backend,
        "n_scenarios": result.stats.n_scenarios,
        "n_ladders": result.stats.n_ladders,
        "measure_dispatches": result.stats.measure_dispatches,
        "model_evals": result.stats.model_evals,
        "spmd_rungs": result.stats.spmd_rungs,
        "host_sync_dispatches": result.stats.host_sync_dispatches,
        "program_cache_hits": result.stats.program_cache_hits,
        # sweep-level megabatching + AOT attribution (PR 5): distinct
        # stacked-signature groups, programs actually compiled, and
        # how many compiled ahead of time
        "spmd_groups": result.stats.spmd_groups,
        "programs_built": result.stats.programs_built,
        "aot_compiles": result.stats.aot_compiles,
        # engine-subset width-packing (PR 7): ladders run side by side
        # on disjoint subsets, and the subset width they occupied
        "packed_ladders": result.stats.packed_ladders,
        "subset_width": result.stats.subset_width,
        # resilient execution (PR 9): injected faults, retries and
        # degradations survived, quality-gate activity, resumed groups
        "faults_injected": result.stats.faults_injected,
        "retried_dispatches": result.stats.retried_dispatches,
        "degraded_ladders": result.stats.degraded_ladders,
        "modeled_floor_ladders": result.stats.modeled_floor_ladders,
        "noisy_remeasures": result.stats.noisy_remeasures,
        "noisy_rungs": result.stats.noisy_rungs,
        "resumed_ladders": result.stats.resumed_ladders,
    }


def _run_entry(run) -> Dict[str, Any]:
    entry = run.spec.to_dict()
    entry["curve"] = {"observer": (asdict(run.observer)
                                   if run.observer is not None
                                   else None),
                      "buffer_bytes": run.buffer_bytes}
    return entry


def _run_points(run) -> List[CurvePoint]:
    # the curve methods pick executed values where the backend ran
    # the rung and modeled values elsewhere
    return [CurvePoint(k, bw, lat)
            for (k, bw), (_k, lat) in zip(run.bandwidth_curve(),
                                          run.latency_curve())]


def curvedb_from_result(result: MatrixResult, platform: str, *,
                        backend: str = "") -> CurveDB:
    """Persist an already-executed :class:`MatrixResult` as a CurveDB
    of 1-axis surfaces (no re-execution — callers that want both the
    runs and the DB pass their ``run_matrix`` result here instead of
    characterizing twice)."""
    db = CurveDB(platform=platform)
    db.meta = _stats_meta(result, backend)
    for run in result.runs:
        entry = _run_entry(run)
        key = SurfaceKey.from_string(run.key)
        prev = db.surfaces.get(key)
        if prev is not None and {k: v for k, v in prev.provenance.items()
                                 if k != "execution"} != entry:
            # distinct scenarios/observers/buffers aliasing one key
            # (e.g. shape tags rounding to the same spelling) must not
            # silently overwrite curves
            raise ValueError(
                f"curve key collision: {run.key!r} produced by both "
                f"{prev.provenance['name']!r} and {run.spec.name!r}")
        entry["execution"] = run.execution
        db.surfaces[key] = Surface.from_points(_run_points(run), entry)
    return db


# ---------------------------------------------------------------------------
# The surface sweep (the tentpole: rf x dc x stressor-count in one matrix)
# ---------------------------------------------------------------------------


def characterize_surface(
    coord: CoreCoordinator,
    *,
    pools: Optional[Iterable[str]] = None,
    stress_pools: Optional[Iterable[str]] = None,
    buffer_bytes: int = 256 << 20,
    obs_strategies: Tuple[str, ...] = ("r", "l"),
    rw_ratios: Sequence[float] = DEFAULT_RW_RATIOS,
    inject_rates: Sequence[float] = DEFAULT_INJECT_RATES,
    iters: int = 500,
    max_stressors: Optional[int] = None,
    batched: bool = True,
    journal=None,
) -> CurveDB:
    """Characterize full bandwidth–latency surfaces.

    Emits the rf x dc x stressor-count scenario grid
    (:func:`repro.core.scenarios.surface_matrix`) and runs it through
    the coordinator's sweep-batched dispatch in ONE ``run_matrix``
    call: the grid varies only ``TrafficShape``, so the spmd backend
    stacks every same-signature ladder group into one dispatch and the
    resulting ``meta`` records the :class:`DispatchStats` proof
    (``host_sync_dispatches`` == distinct signatures).

    Returns a CurveDB whose entries are dense 3-axis surfaces keyed
    ``(obs_pool, obs_strat, stress_pool, "b")`` — one surface per
    observer/stressor pool pairing, answering interpolated queries at
    any (n_stressors, rw_ratio, inject_rate) coordinate.
    """
    rws = tuple(sorted(float(v) for v in rw_ratios))
    irs = tuple(sorted(float(v) for v in inject_rates))
    if len(set(rws)) != len(rws) or len(set(irs)) != len(irs):
        raise ValueError("surface grid values must be unique")
    pool_names = list(pools) if pools is not None else [
        p.node.name for p in coord.pools.pools()
        if p.node.kind != "vmem"]
    s_pools = list(stress_pools) if stress_pools is not None else pool_names

    specs: List[ScenarioSpec] = []
    for op in pool_names:
        cap = coord.pools.pool(op).node.size_bytes
        nb_o = min(buffer_bytes, cap // 2)
        for sp in s_pools:
            s_cap = coord.pools.pool(sp).node.size_bytes
            nb = min(nb_o, s_cap // 2)
            specs.extend(surface_matrix(
                pools=[op], stress_pools=[sp], buffer_bytes=nb,
                obs_strategies=obs_strategies, rw_ratios=rws,
                inject_rates=irs, iters=iters,
                max_stressors=max_stressors))
    result = coord.run_matrix(specs, batched=batched, journal=journal)
    return surfacedb_from_result(result, coord.platform.name,
                                 rw_ratios=rws, inject_rates=irs,
                                 backend=coord.backend)


def surfacedb_from_result(result: MatrixResult, platform: str, *,
                          rw_ratios: Sequence[float],
                          inject_rates: Sequence[float],
                          backend: str = "") -> CurveDB:
    """Assemble an executed surface-grid :class:`MatrixResult` into
    dense 3-axis surfaces (axes: n_stressors, rw_ratio, inject_rate).
    Per-surface provenance keeps every grid cell's scenario spec and
    execution record under its shape tag."""
    rws = tuple(sorted(float(v) for v in rw_ratios))
    irs = tuple(sorted(float(v) for v in inject_rates))
    db = CurveDB(platform=platform)
    db.meta = _stats_meta(result, backend)
    db.meta["surface"] = {"rw_ratios": list(rws), "inject_rates": list(irs)}

    grouped: Dict[SurfaceKey, Dict[Tuple[float, float], Any]] = {}
    for run in result.runs:
        if len(run.spec.stressors) != 1 or run.observer is None:
            raise ValueError(
                f"{run.spec.name!r}: surface grids are single-stressor, "
                f"single-observer scenarios")
        s = run.spec.stressors[0]
        key = SurfaceKey(run.observer.pool, run.observer.strategy,
                         s.pool, s.strategy)
        cell = (s.shape.read_fraction, s.shape.duty_cycle)
        grouped.setdefault(key, {})[cell] = run

    for key, cells in grouped.items():
        missing = [(rf, dc) for rf in rws for dc in irs
                   if (rf, dc) not in cells]
        if missing:
            raise ValueError(
                f"surface {key.to_string()!r} missing grid cells "
                f"{missing}")
        first_pts = _run_points(cells[(rws[0], irs[0])])
        n_values = tuple(float(p.n_stressors) for p in first_pts)
        bw = []
        lat = []
        prov_cells: Dict[str, Any] = {}
        for i in range(len(n_values)):
            bw.append([[0.0] * len(irs) for _ in rws])
            lat.append([[0.0] * len(irs) for _ in rws])
        for j, rf in enumerate(rws):
            for k, dc in enumerate(irs):
                run = cells[(rf, dc)]
                pts = _run_points(run)
                if tuple(float(p.n_stressors) for p in pts) != n_values:
                    raise ValueError(
                        f"surface {key.to_string()!r}: ladder depth "
                        f"differs across grid cells")
                for i, p in enumerate(pts):
                    bw[i][j][k] = p.bandwidth_gbps
                    lat[i][j][k] = p.latency_ns
                entry = _run_entry(run)
                entry["execution"] = run.execution
                prov_cells[TrafficShape.traffic(rf, dc).tag()] = entry
        db.surfaces[key] = Surface(
            axes=(SurfaceAxis(AXIS_N, n_values),
                  SurfaceAxis(AXIS_RW, rws),
                  SurfaceAxis(AXIS_IR, irs)),
            bandwidth_gbps=bw, latency_ns=lat,
            provenance={"grid": {"rw_ratios": list(rws),
                                 "inject_rates": list(irs)},
                        "cells": prov_cells})
    return db


# ---------------------------------------------------------------------------
# Targeted-cell online refresh (serving-time re-characterization)
# ---------------------------------------------------------------------------

#: qualifier under which online re-characterization stores refreshed
#: surfaces (the serving watchdog's probe sweeps) — consumers opt in
#: via ``db.query(..., qualifier=ONLINE_QUALIFIER)``, which prefers the
#: online surface at every resolution-ladder step and falls through to
#: the offline one when no refresh has happened yet.
ONLINE_QUALIFIER = "online"


def refresh_surface_cells(
    coord: CoreCoordinator,
    db: CurveDB,
    *,
    pools: Iterable[str],
    rw_ratio: float,
    inject_rate: float,
    stress_pools: Optional[Iterable[str]] = None,
    obs_strategies: Tuple[str, ...] = ("r", "l"),
    buffer_bytes: int = 64 << 10,
    iters: int = 50,
    max_stressors: Optional[int] = None,
    qualifier: str = ONLINE_QUALIFIER,
    drift: Optional[Dict[str, Any]] = None,
    batched: bool = True,
    journal=None,
) -> Tuple[List[SurfaceKey], Dict[str, Any]]:
    """Re-characterize ONE surface grid cell at live coordinates.

    Instead of the full rf x dc grid, this sweeps only the
    ``(rw_ratio, inject_rate)`` cell the serving engine is actually
    operating at — a single-cell probe sweep small enough to run in
    the background of a serving loop.  Each refreshed surface is
    stored *into* ``db`` under ``qualifier`` (default
    :data:`ONLINE_QUALIFIER`) as a single-point rw/ir surface that
    REPLACES any previous online surface for the same pairing: the
    online qualifier always reflects the latest observed regime, it
    is not a merged history (the offline full-grid surface stays
    untouched underneath it).

    Provenance: each refreshed surface records ``provenance["online"]``
    with the refresh ordinal, the caller's ``drift`` evidence
    (observed-vs-predicted gap), and the sweep's resilience stats
    (faults injected, degradations, noisy rungs ...) so a surface that
    survived a chaotic probe sweep is distinguishable from a clean one.

    ``journal=<path>`` (spmd backend only) makes the probe sweep
    crash-resumable through :class:`repro.core.exec.SweepJournal` —
    a serving-engine restart resumes the sweep value-identically
    instead of restarting it.

    Returns ``(refreshed_keys, stats_meta)``.
    """
    rw = float(rw_ratio)
    ir = float(inject_rate)
    pool_names = list(pools)
    s_pools = list(stress_pools) if stress_pools is not None else pool_names

    specs: List[ScenarioSpec] = []
    for op in pool_names:
        cap = coord.pools.pool(op).node.size_bytes
        nb_o = min(buffer_bytes, cap // 2)
        for sp in s_pools:
            s_cap = coord.pools.pool(sp).node.size_bytes
            nb = min(nb_o, s_cap // 2)
            specs.extend(surface_matrix(
                pools=[op], stress_pools=[sp], buffer_bytes=nb,
                obs_strategies=obs_strategies, rw_ratios=(rw,),
                inject_rates=(ir,), iters=iters,
                max_stressors=max_stressors, name_prefix="online."))
    result = coord.run_matrix(specs, batched=batched, journal=journal)
    fresh = surfacedb_from_result(result, coord.platform.name,
                                  rw_ratios=(rw,), inject_rates=(ir,),
                                  backend=coord.backend)
    stats = _stats_meta(result, coord.backend)

    refreshed: List[SurfaceKey] = []
    for key, surf in fresh.surfaces.items():
        qkey = SurfaceKey(key.obs_pool, key.obs_strat, key.stress_pool,
                          key.stress_strat, tag=key.tag,
                          qualifier=qualifier)
        prev = db.surfaces.get(qkey)
        n_prev = (prev.provenance.get("online", {}).get("refreshes", 0)
                  if prev is not None else 0)
        surf.provenance["online"] = {
            "refreshes": n_prev + 1,
            "coord": {AXIS_RW: rw, AXIS_IR: ir},
            "drift": dict(drift or {}),
            "sweep": stats,
        }
        db.surfaces[qkey] = surf
        refreshed.append(qkey)
    return refreshed, stats


def mlp_table(db: CurveDB, platform: Platform) -> str:
    """Tables II/III, for every characterized module."""
    lines = ["pool      pairing        lat(ns/Tx)  BW(Tx/ns)   MLP"]
    for pool in db.observer_pools():
        for stress in ("r", "w"):
            try:
                lat = db._worst(pool, "l", stress).latency_ns
                bw = db._worst(pool, "r", stress).bandwidth_gbps
            except KeyError:
                continue
            tx = bw / platform.line_bytes
            lines.append(
                f"{pool:9s} (l,{stress})x(r,{stress})  {lat:10.2f}"
                f"  {tx:9.4f}  {lat * tx:5.2f}")
    return "\n".join(lines)
