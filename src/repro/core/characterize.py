"""Characterization driver — performance curves + Little's-law MLP.

v2: scenarios are declarative (:mod:`repro.core.scenarios`).  The
default matrix reproduces the seed's ladder cross-product (obs pool x
obs strategy x stress pool x stress strategy) and extends it with the
new traffic shapes (mixed read/write ratios, bursty/duty-cycled stress,
copy streams, strided chases).  Execution goes through the coordinator's
batched matrix runner — same-signature observers collapse into one
jit'd vmapped measured pass per group.

Results persist as a **versioned CurveDB** (schema 2): besides the
per-scenario curves it records each curve's full scenario provenance
(strategy letters, shape parameters, buffer sizes), so a curve file is
self-describing and replayable.  Schema-1 files (the seed format) still
load.  The CurveDB is the contract consumed by
:mod:`repro.core.placement`, :mod:`repro.analysis.roofline` and the
``benchmarks/fig*`` scripts.
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, Iterable, List, Optional, Tuple

from repro.core.coordinator import CoreCoordinator, MatrixResult
from repro.core.devicetree import Platform
from repro.core.scenarios import (SCHEMA_VERSION, ObserverSpec, ScenarioSpec,
                                  StressorSpec, TrafficShape,
                                  scenario_matrix)

Key = Tuple[str, str, str, str]   # (obs_pool, obs_strat, stress_pool, stress_strat)


@dataclass
class CurvePoint:
    n_stressors: int
    bandwidth_gbps: float
    latency_ns: float


@dataclass
class CurveDB:
    platform: str
    curves: Dict[str, List[CurvePoint]] = field(default_factory=dict)
    schema: int = SCHEMA_VERSION
    # per-curve scenario provenance (v2): key -> ScenarioSpec.to_dict()
    provenance: Dict[str, Dict[str, Any]] = field(default_factory=dict)
    meta: Dict[str, Any] = field(default_factory=dict)

    @staticmethod
    def key(obs_pool: str, obs_strat: str, stress_pool: str,
            stress_strat: str, shape_tag: str = "") -> str:
        base = f"{obs_pool}:{obs_strat}|{stress_pool}:{stress_strat}"
        return f"{base}@{shape_tag}" if shape_tag else base

    def get(self, obs_pool: str, obs_strat: str, stress_pool: str,
            stress_strat: str, shape_tag: str = "") -> List[CurvePoint]:
        return self.curves[self.key(obs_pool, obs_strat, stress_pool,
                                    stress_strat, shape_tag)]

    # -- the numbers placement cares about --------------------------------
    def effective_bw(self, pool: str, n_stressors: int,
                     stress_pool: Optional[str] = None,
                     strat: str = "r", stress_strat: str = "w",
                     shape_tag: str = "") -> float:
        pts = self._lookup(pool, strat, stress_pool or pool, stress_strat,
                           shape_tag)
        k = min(n_stressors, len(pts) - 1)
        return pts[k].bandwidth_gbps

    def effective_lat(self, pool: str, n_stressors: int,
                      stress_pool: Optional[str] = None,
                      stress_strat: str = "w",
                      shape_tag: str = "") -> float:
        pts = self._lookup(pool, "l", stress_pool or pool, stress_strat,
                           shape_tag)
        k = min(n_stressors, len(pts) - 1)
        return pts[k].latency_ns

    def _lookup(self, pool, strat, stress_pool, stress_strat,
                shape_tag) -> List[CurvePoint]:
        """Shaped curve when characterized, steady fallback otherwise."""
        if shape_tag:
            k = self.key(pool, strat, stress_pool, stress_strat, shape_tag)
            if k in self.curves:
                return self.curves[k]
        return self.get(pool, strat, stress_pool, stress_strat)

    # -- Little's law -------------------------------------------------------
    def mlp(self, pool: str, line_bytes: int,
            stress_strat: str = "r") -> float:
        """Avg MLP = Avg latency [ns/Tx] x Avg bandwidth [Tx/ns], computed
        at the worst-case scenario like Tables II/III."""
        lat = self.get(pool, "l", pool, stress_strat)[-1].latency_ns
        bw = self.get(pool, "r", pool, stress_strat)[-1].bandwidth_gbps
        return lat * (bw / line_bytes)

    # -- persistence ----------------------------------------------------------
    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump({"schema": self.schema,
                       "platform": self.platform,
                       "curves": {k: [asdict(p) for p in v]
                                  for k, v in self.curves.items()},
                       "provenance": self.provenance,
                       "meta": self.meta}, f, indent=1)

    @staticmethod
    def load(path: str) -> "CurveDB":
        with open(path) as f:
            d = json.load(f)
        # schema 1 (the seed format) has no "schema" key and no
        # provenance — load it as-is so old curve files keep working
        schema = int(d.get("schema", 1))
        return CurveDB(platform=d["platform"],
                       curves={k: [CurvePoint(**p) for p in v]
                               for k, v in d["curves"].items()},
                       schema=schema,
                       provenance=d.get("provenance", {}),
                       meta=d.get("meta", {}))


DEFAULT_BW_STRATS = ("r", "w")
DEFAULT_STRESS_STRATS = ("r", "w", "y")


def characterize(
    coord: CoreCoordinator,
    *,
    pools: Optional[Iterable[str]] = None,
    # default above the VMEM/cache budget: the curves must characterize
    # the MODULE, not the cache in front of it (cache-fit behaviour is
    # the fig5 buffer sweep's subject instead)
    buffer_bytes: int = 256 << 20,
    obs_strategies: Tuple[str, ...] = DEFAULT_BW_STRATS + ("l",),
    stress_strategies: Tuple[str, ...] = DEFAULT_STRESS_STRATS,
    stress_shapes: Optional[
        Iterable[Tuple[str, TrafficShape]]] = None,
    iters: int = 500,
    batched: bool = True,
) -> CurveDB:
    """Build the curve database for the scenario matrix.

    Default matrix = the seed's steady cross-product (so existing
    consumers see identical keys); pass ``stress_shapes`` — e.g.
    :data:`repro.core.scenarios.DEFAULT_STRESS_SHAPES` — to add shaped
    stressor scenarios (mixed r/w ratios, bursts, copies, strided
    chases) on top.
    """
    platform = coord.platform
    pool_names = list(pools) if pools is not None else [
        p.node.name for p in coord.pools.pools()
        if p.node.kind != "vmem"]      # vmem probed via small buffers
    shapes: List[Tuple[str, TrafficShape]] = [
        (s, TrafficShape.steady()) for s in stress_strategies]
    if stress_shapes is not None:
        for pair in stress_shapes:
            if pair not in shapes:
                shapes.append(pair)

    specs: List[ScenarioSpec] = []
    for op in pool_names:
        cap = coord.pools.pool(op).node.size_bytes
        nbytes = min(buffer_bytes, cap // 2)
        for ostrat in obs_strategies:
            for sp in pool_names:
                s_cap = coord.pools.pool(sp).node.size_bytes
                s_bytes = min(buffer_bytes, s_cap // 2)
                for sstrat, shape in shapes:
                    spec = ScenarioSpec(
                        name=f"{op}.{ostrat}|{sp}.{sstrat}"
                             f"{('@' + shape.tag()) if shape.tag() else ''}",
                        observer=ObserverSpec(ostrat, op, (nbytes,)),
                        stressors=(StressorSpec(sstrat, sp, s_bytes,
                                                shape),),
                        iters=iters)
                    specs.append(spec)
    return characterize_matrix(coord, specs, batched=batched)


def characterize_matrix(coord: CoreCoordinator,
                        specs: List[ScenarioSpec], *,
                        batched: bool = True) -> CurveDB:
    """Run an explicit scenario matrix and persist it as CurveDB v2.

    Each curve's provenance records the scenario spec AND an
    ``execution`` entry (which backend produced it, which ladder rungs
    were *executed* vs *modeled*, what ``activity`` filled the measured
    region — "pallas" kernels vs "jnp" fallback loops — and whether
    co-observers were ``coupled`` into the measured region) — an
    spmd-backend curve whose every point came from a live fused
    multi-engine dispatch is distinguishable from a queueing-model
    curve after the fact, and a coupled curve from an uncoupled one."""
    result: MatrixResult = coord.run_matrix(specs, batched=batched)
    return curvedb_from_result(result, coord.platform.name,
                               backend=coord.backend)


def curvedb_from_result(result: MatrixResult, platform: str, *,
                        backend: str = "") -> CurveDB:
    """Persist an already-executed :class:`MatrixResult` as CurveDB v2
    (no re-execution — callers that want both the runs and the DB pass
    their ``run_matrix`` result here instead of characterizing twice)."""
    db = CurveDB(platform=platform)
    db.meta = {
        "backend": backend,
        "n_scenarios": result.stats.n_scenarios,
        "n_ladders": result.stats.n_ladders,
        "measure_dispatches": result.stats.measure_dispatches,
        "model_evals": result.stats.model_evals,
        "spmd_rungs": result.stats.spmd_rungs,
        "host_sync_dispatches": result.stats.host_sync_dispatches,
        "program_cache_hits": result.stats.program_cache_hits,
        # sweep-level megabatching + AOT attribution (PR 5): distinct
        # stacked-signature groups, programs actually compiled, and
        # how many compiled ahead of time
        "spmd_groups": result.stats.spmd_groups,
        "programs_built": result.stats.programs_built,
        "aot_compiles": result.stats.aot_compiles,
    }
    for run in result.runs:
        # the curve methods pick executed values where the backend ran
        # the rung and modeled values elsewhere
        pts = [CurvePoint(k, bw, lat)
               for (k, bw), (_k, lat) in zip(run.bandwidth_curve(),
                                             run.latency_curve())]
        entry = run.spec.to_dict()
        entry["curve"] = {"observer": (asdict(run.observer)
                                       if run.observer is not None
                                       else None),
                          "buffer_bytes": run.buffer_bytes}
        prev = db.provenance.get(run.key)
        if prev is not None and {k: v for k, v in prev.items()
                                 if k != "execution"} != entry:
            # distinct scenarios/observers/buffers aliasing one key
            # (e.g. shape tags rounding to the same spelling) must not
            # silently overwrite curves
            raise ValueError(
                f"curve key collision: {run.key!r} produced by both "
                f"{prev['name']!r} and {run.spec.name!r}")
        db.curves[run.key] = pts
        entry["execution"] = run.execution
        db.provenance[run.key] = entry
    return db


def mlp_table(db: CurveDB, platform: Platform) -> str:
    """Tables II/III, for every characterized module."""
    lines = ["pool      pairing        lat(ns/Tx)  BW(Tx/ns)   MLP"]
    pools = sorted({k.split(":")[0] for k in db.curves})
    for pool in pools:
        for stress in ("r", "w"):
            try:
                lat = db.get(pool, "l", pool, stress)[-1].latency_ns
                bw = db.get(pool, "r", pool, stress)[-1].bandwidth_gbps
            except KeyError:
                continue
            tx = bw / platform.line_bytes
            lines.append(
                f"{pool:9s} (l,{stress})x(r,{stress})  {lat:10.2f}"
                f"  {tx:9.4f}  {lat * tx:5.2f}")
    return "\n".join(lines)
