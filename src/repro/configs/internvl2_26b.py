"""internvl2-26b — VLM: InternViT frontend (STUB) + InternLM2-20B backbone.

[arXiv:2404.16821; hf:OpenGVLab/InternVL2-26B]
48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.

The InternViT vision tower is a STUB per the assignment: ``input_specs()``
provides 256 precomputed patch embeddings per sequence, prepended to the
text-token embeddings. The LM backbone is fully real.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553,       # padded to 92672 for sharding (ModelConfig.padded_vocab)
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    act_fn="silu",
    frontend="vlm",
    n_prefix_embeds=256,
    source="arXiv:2404.16821",
))
