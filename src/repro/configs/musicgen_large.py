"""musicgen-large — decoder-only transformer over EnCodec tokens.

[arXiv:2306.05284; hf:facebook/musicgen-large]
48L d_model=2048 32H (kv=32, MHA) d_ff=8192 vocab=2048 (EnCodec codebook).

The EnCodec/conditioning frontend is a STUB per the assignment:
``input_specs()`` provides precomputed frame embeddings which are added to
the token embeddings (the backbone transformer is fully real).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    rope_theta=10_000.0,
    tie_embeddings=False,
    act_fn="gelu",
    frontend="audio",
    source="arXiv:2306.05284",
))
