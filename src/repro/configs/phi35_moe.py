"""phi3.5-moe-42b-a6.6b — MoE decoder, 16 experts top-2.

[hf:microsoft/Phi-3.5-MoE-instruct]
32L d_model=4096 32H (GQA kv=8) expert d_ff=6400 vocab=32064, 16e top-2.
"""
from repro.configs.base import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=0,                   # every FFN is MoE
    vocab_size=32064,
    rope_theta=10_000.0,
    tie_embeddings=False,
    act_fn="silu",
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=6400,
                  moe_every=1, capacity_factor=1.25),
    source="hf:microsoft/Phi-3.5-MoE-instruct",
))
