"""olmoe-1b-7b — MoE decoder, 64 experts top-8.

[arXiv:2409.02060; hf:allenai/OLMoE-1B-7B-0924]
16L d_model=2048 16H (kv=16, MHA) expert d_ff=1024 vocab=50304, 64e top-8.
"""
from repro.configs.base import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=0,
    vocab_size=50304,
    rope_theta=10_000.0,
    tie_embeddings=False,
    act_fn="silu",
    moe=MoEConfig(n_experts=64, top_k=8, d_ff_expert=1024,
                  moe_every=1, capacity_factor=1.25),
    source="arXiv:2409.02060",
))
