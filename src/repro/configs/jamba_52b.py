"""jamba-v0.1-52b — hybrid Mamba + attention (1:7), MoE 16e top-2.

[arXiv:2403.19887; hf:ai21labs/Jamba-v0.1]
32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536, MoE 16e top-2.

Layer pattern (period 8): attention at index 4 of each period, Mamba
elsewhere (1:7 attn:mamba). MoE replaces the FFN on every other layer
(odd indices). Jamba v0.1 uses Mamba-1 selective scan; we implement the
Mamba layers with the SSD scan (diagonal-A case) — see DESIGN.md
§Arch-applicability for the recorded adaptation.
"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    rope_theta=0.0,          # Jamba attention layers use no positional encoding
    tie_embeddings=False,
    act_fn="silu",
    attn_every=8,
    attn_offset=4,
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=14336,
                  moe_every=2, moe_offset=1, capacity_factor=1.25),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=64, n_groups=1),
    source="arXiv:2403.19887",
))
