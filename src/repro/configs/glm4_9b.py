"""glm4-9b — dense decoder, RoPE + GQA.

[hf:THUDM/glm-4-9b]
40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="glm4-9b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=151552,
    rope_theta=10_000.0,
    qkv_bias=True,           # GLM-4 uses add_qkv_bias
    tie_embeddings=False,
    act_fn="silu",
    source="hf:THUDM/glm-4-9b",
))
