"""mamba2-370m — attention-free SSM (SSD, state-space duality).

[arXiv:2405.21060]
48L d_model=1024 d_ff=0 vocab=50280, ssm_state=128, expand=2, headdim=64.
"""
from repro.configs.base import ModelConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,       # padded to 50432 for sharding
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1),
    source="arXiv:2405.21060",
))
