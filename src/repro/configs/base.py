"""Config system for the repro framework.

Every assigned architecture is expressed as a :class:`ModelConfig`; the four
assigned input-shape sets are :data:`SHAPES`.  ``reduced()`` produces a tiny
same-family config for CPU smoke tests; full configs are exercised only via
the AOT dry-run (``repro.launch.dryrun``).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Optional, Tuple

# ---------------------------------------------------------------------------
# Input shapes (assigned): seq_len x global_batch
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    # every `moe_every`-th layer is MoE (1 = all layers); offset selects which.
    moe_every: int = 1
    moe_offset: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    router_z_weight: float = 1e-3


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256  # SSD chunk length for training/prefill

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    # --- attention flavour ---
    rope_theta: float = 10_000.0
    rope_theta_local: float = 0.0  # 0 -> use rope_theta everywhere
    qkv_bias: bool = False
    sliding_window: int = 0  # 0 -> no local attention layers
    global_every: int = 0  # e.g. 6 -> layers 5,11,.. are global (5:1 local)
    attn_logit_softcap: float = 0.0
    qk_norm: bool = False
    tie_embeddings: bool = False
    scale_embed: bool = False  # multiply embeddings by sqrt(d_model) (gemma)
    act_fn: str = "silu"  # silu | gelu
    norm_eps: float = 1e-6
    # --- mixture of experts ---
    moe: Optional[MoEConfig] = None
    # --- state-space layers ---
    ssm: Optional[SSMConfig] = None
    # hybrid interleave: layer i is attention iff i % attn_every == attn_offset
    # (only used when family == "hybrid"); ssm archs have attn_every == 0.
    attn_every: int = 0
    attn_offset: int = 0
    # --- modality frontend stub ---
    frontend: str = "none"  # none | audio | vlm
    n_prefix_embeds: int = 0  # e.g. 256 ViT patch embeddings prepended
    # --- numerics ---
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # --- bookkeeping ---
    source: str = ""

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # ------------------------------------------------------------------
    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def padded_vocab(self) -> int:
        """Vocab padded for sharding (multiple of 256, Megatron-style)."""
        return _round_up(self.vocab_size, 256)

    def layer_kind(self, i: int) -> str:
        """'attn' | 'ssm' for layer index i."""
        if self.family == "ssm":
            return "ssm"
        if self.family == "hybrid" and self.attn_every:
            return "attn" if i % self.attn_every == self.attn_offset else "ssm"
        return "attn"

    def layer_is_moe(self, i: int) -> bool:
        if self.moe is None:
            return False
        return i % self.moe.moe_every == self.moe.moe_offset

    def layer_is_global_attn(self, i: int) -> bool:
        """Full-context attention (vs. sliding window) for layer i."""
        if self.sliding_window == 0 or self.global_every == 0:
            return True
        return (i + 1) % self.global_every == 0

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch can run long_500k (no full-attention prefill over
        the whole context on every layer and O(<L^2) overall)."""
        if self.family == "ssm":
            return True
        if self.family == "hybrid":
            return True  # few attention layers; decode is O(L) per token
        return self.sliding_window > 0 and self.global_every > 0

    # ------------------------------------------------------------------
    def shapes(self) -> List[str]:
        """Assigned shapes runnable for this arch (skips noted in DESIGN.md)."""
        out = []
        for s in SHAPE_ORDER:
            if s == "long_500k" and not self.sub_quadratic:
                continue
            out.append(s)
        return out

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        n_heads = min(self.n_heads, 4) if self.n_heads else 0
        kv = max(1, min(self.n_kv_heads, n_heads)) if n_heads else 0
        # keep GQA flavour: q:kv ratio > 1 when original had one
        if n_heads and self.n_kv_heads < self.n_heads:
            kv = max(1, n_heads // 2)
        moe = None
        if self.moe is not None:
            moe = replace(
                self.moe, n_experts=4, top_k=min(2, self.moe.top_k),
                d_ff_expert=64,
            )
        ssm = None
        if self.ssm is not None:
            ssm = replace(self.ssm, d_state=16, head_dim=16, chunk=8)
        n_layers = 2
        attn_every, attn_offset = self.attn_every, self.attn_offset
        if self.family == "hybrid":
            n_layers, attn_every, attn_offset = 4, 2, 1
        global_every = 2 if self.global_every else 0
        return replace(
            self,
            name=self.name + "-reduced",
            n_layers=n_layers,
            d_model=64,
            n_heads=n_heads,
            n_kv_heads=kv,
            head_dim=16,
            d_ff=128,
            vocab_size=256,
            moe=moe,
            ssm=ssm,
            attn_every=attn_every,
            attn_offset=attn_offset,
            global_every=global_every,
            sliding_window=8 if self.sliding_window else 0,
            n_prefix_embeds=4 if self.n_prefix_embeds else 0,
            param_dtype="float32",
            compute_dtype="float32",
        )

    # ------------------------------------------------------------------
    # Analytic parameter counts (used for MODEL_FLOPS in the roofline).
    # ------------------------------------------------------------------
    def param_counts(self) -> Dict[str, int]:
        d, hd = self.d_model, self.head_dim
        counts: Dict[str, int] = {}
        counts["embed"] = self.padded_vocab * d
        counts["unembed"] = 0 if self.tie_embeddings else self.padded_vocab * d
        per_layer_attn = 0
        if self.n_heads:
            q = d * self.n_heads * hd
            k = d * self.n_kv_heads * hd
            v = d * self.n_kv_heads * hd
            o = self.n_heads * hd * d
            bias = (self.n_heads + 2 * self.n_kv_heads) * hd if self.qkv_bias else 0
            per_layer_attn = q + k + v + o + bias
        per_layer_mlp = 3 * d * self.d_ff  # gated: w_in, w_gate, w_out
        per_layer_moe = 0
        if self.moe is not None:
            e, f = self.moe.n_experts, self.moe.d_ff_expert
            per_layer_moe = d * e + e * 3 * d * f  # router + experts
        per_layer_ssm = 0
        if self.ssm is not None:
            di = self.ssm.d_inner(d)
            nh = self.ssm.n_heads(d)
            ng, ds = self.ssm.n_groups, self.ssm.d_state
            zxbcdt = d * (2 * di + 2 * ng * ds + nh)
            conv = self.ssm.d_conv * (di + 2 * ng * ds)
            out = di * d
            per_layer_ssm = zxbcdt + conv + out + 2 * nh + di  # +A,dt_bias,norm
        attn_p = mlp_p = moe_p = ssm_p = norm_p = 0
        for i in range(self.n_layers):
            kind = self.layer_kind(i)
            if kind == "attn":
                attn_p += per_layer_attn
                norm_p += 2 * d
            else:
                ssm_p += per_layer_ssm
                norm_p += 2 * d
            if self.layer_is_moe(i):
                moe_p += per_layer_moe
            elif kind == "attn" or self.family != "ssm":
                mlp_p += per_layer_mlp
                norm_p += d
        if self.family == "ssm":
            mlp_p = 0  # mamba blocks have no separate FFN (d_ff == 0)
        counts.update(attn=attn_p, mlp=mlp_p, moe=moe_p, ssm=ssm_p,
                      norm=norm_p + d)  # final norm
        return counts

    def n_params(self) -> int:
        return sum(self.param_counts().values())

    def n_active_params(self) -> int:
        """Params touched per token (MoE experts scaled by top_k/E)."""
        c = self.param_counts()
        total = sum(v for k, v in c.items() if k != "moe")
        if self.moe is not None and c["moe"]:
            e, k = self.moe.n_experts, self.moe.top_k
            router = self.d_model * e * sum(
                1 for i in range(self.n_layers) if self.layer_is_moe(i))
            experts = c["moe"] - router
            total += router + experts * k // e
        return total


# ---------------------------------------------------------------------------
# Train / serve configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    microbatches: int = 1          # grad-accumulation steps inside train_step
    remat: str = "layer"           # none | layer | full
    zero1: bool = True             # shard optimizer state over data axis
    grad_compression: str = "none"  # none | int8_ef
    loss_chunk: int = 1024          # sequence chunk for cross-entropy
    seed: int = 0
    checkpoint_every: int = 100
    keep_checkpoints: int = 3


@dataclass(frozen=True)
class ServeConfig:
    max_seqs: int = 128
    prefill_chunk: int = 2048
    kv_cache_dtype: str = "bfloat16"
    kv_placement: str = "auto"      # auto | hbm | host (PlacementAdvisor)


@dataclass(frozen=True)
class MeshConfig:
    data: int = 16
    model: int = 16
    pods: int = 1

    @property
    def n_devices(self) -> int:
        return self.data * self.model * self.pods

    @property
    def axis_names(self) -> Tuple[str, ...]:
        return ("pod", "data", "model") if self.pods > 1 else ("data", "model")

    @property
    def shape(self) -> Tuple[int, ...]:
        if self.pods > 1:
            return (self.pods, self.data, self.model)
        return (self.data, self.model)


# Default per (arch-size) microbatch ladder: keeps activation residency
# bounded on a 16 GiB v5e chip (see DESIGN.md §6).
def default_microbatches(cfg: ModelConfig, shape: ShapeSpec,
                         mesh: MeshConfig) -> int:
    if shape.kind != "train":
        return 1
    dp = mesh.data * mesh.pods
    batch_per_replica = max(1, shape.global_batch // dp)
    tokens_per_replica = batch_per_replica * shape.seq_len
    # aim for <= 8192 tokens per microbatch per replica for d_model >= 4096,
    # <= 16384 otherwise
    target = 8_192 if cfg.d_model >= 4_096 else 16_384
    mb = max(1, tokens_per_replica // target)
    while batch_per_replica % mb != 0:
        mb -= 1
    return mb


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    from repro import configs as _c  # noqa: F401  (ensure modules imported)
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> List[str]:
    from repro import configs as _c  # noqa: F401
    return sorted(_REGISTRY)
