"""Config registry — one module per assigned architecture."""
from repro.configs.base import (  # noqa: F401
    SHAPES, SHAPE_ORDER, MeshConfig, ModelConfig, MoEConfig, SSMConfig,
    ServeConfig, ShapeSpec, TrainConfig, default_microbatches, get_config,
    list_configs, register,
)

# Import every arch module so registration side effects run.
from repro.configs import (  # noqa: F401
    gemma3_4b, gemma3_1b, qwen2_1_5b, glm4_9b, phi35_moe, olmoe_1b_7b,
    musicgen_large, internvl2_26b, mamba2_370m, jamba_52b,
)

ALL_ARCHS = [
    "gemma3-4b",
    "qwen2-1.5b",
    "gemma3-1b",
    "glm4-9b",
    "phi3.5-moe-42b-a6.6b",
    "olmoe-1b-7b",
    "musicgen-large",
    "internvl2-26b",
    "mamba2-370m",
    "jamba-v0.1-52b",
]
