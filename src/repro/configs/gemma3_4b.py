"""gemma3-4b — dense decoder, 5:1 local:global attention, 128k context.

[hf:google/gemma-3-4b-pt; unverified tier per assignment]
34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,            # gemma3 uses 256, decoupled from d_model/n_heads
    d_ff=10240,
    vocab_size=262144,
    rope_theta=1_000_000.0,  # global layers
    rope_theta_local=10_000.0,
    sliding_window=1024,
    global_every=6,          # 5 local : 1 global
    attn_logit_softcap=0.0,
    qk_norm=True,
    tie_embeddings=True,
    scale_embed=True,
    act_fn="gelu",
    source="hf:google/gemma-3-4b-pt",
))
