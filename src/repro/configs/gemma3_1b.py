"""gemma3-1b — dense decoder, 5:1 local:global attention, 128k (32k native).

[hf:google/gemma-3-1b-pt; unverified tier per assignment]
26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    rope_theta=1_000_000.0,
    rope_theta_local=10_000.0,
    sliding_window=512,
    global_every=6,
    qk_norm=True,
    tie_embeddings=True,
    scale_embed=True,
    act_fn="gelu",
    source="hf:google/gemma-3-1b-pt",
))
