"""qwen2-1.5b — dense decoder, GQA with QKV bias, full attention.

[arXiv:2407.10671; hf:Qwen/Qwen2-1.5B]
28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-1.5b",
    family="dense",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    rope_theta=1_000_000.0,
    qkv_bias=True,
    tie_embeddings=True,
    act_fn="silu",
    source="arXiv:2407.10671",
))
