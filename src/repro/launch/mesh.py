"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — the dry-run sets
``xla_force_host_platform_device_count`` *before* any jax init, and smoke
tests must keep seeing 1 device.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro import compat
from repro.configs.base import MeshConfig


def _mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    return compat.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """The assignment's target: one v5e pod (16x16 = 256 chips) or two
    pods (2x16x16 = 512 chips) with a leading "pod" data-parallel axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def mesh_from_config(mc: MeshConfig):
    return _mesh(mc.shape, mc.axis_names)


def make_host_mesh(data: int = 1, model: int = 1):
    """Whatever this host offers (CPU tests / examples): (data, model)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, n // data) if data else 1
    return _mesh((data, model), ("data", "model"))


def describe(mesh) -> str:
    return "x".join(f"{n}={s}" for n, s in
                    zip(mesh.axis_names, mesh.devices.shape))
