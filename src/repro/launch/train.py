"""End-to-end training driver.

Wires every substrate layer together: config -> mesh -> sharding rules ->
data pipeline -> jit'd train step (microbatched, remat, ZeRO-1, optional
int8-EF compression) -> resilient loop (checkpoint/restart, straggler
monitor) -> MEMSCOPE-advised placement of optimizer state.

On this CPU container run a reduced config::

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b \
        --reduced --steps 50 --batch 8 --seq 128

On a real slice drop ``--reduced`` and point --mesh at the pod.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import (SHAPES, MeshConfig, ShapeSpec, TrainConfig,
                                get_config)
from repro.core.characterize import CurveDB, characterize
from repro.core.coordinator import CoreCoordinator
from repro.core.placement import (ContentionSpec, PlacementAdvisor,
                                  optimizer_state_object, params_object)
from repro.data.pipeline import DataLoader
from repro.launch.mesh import describe, make_host_mesh, mesh_from_config
from repro.models import lm
from repro.parallel.sharding import make_rules
from repro.runtime.fault_tolerance import (ResilientLoop, StragglerMonitor,
                                           drill_at)
from repro.train import step as step_mod


def advise_placement(cfg, tcfg, verbose: bool = True):
    """MEMSCOPE loop: characterize -> advise where optimizer state lives.

    The decision is advisory on this container (CPU has one memory), but
    it is the real Fig.-14 pipeline: the curve DB comes from the
    contention simulator and the advisor solves the placement problem."""
    coord = CoreCoordinator(backend="simulate")
    db = characterize(coord, pools=["hbm", "host"],
                      obs_strategies=("r", "l"),
                      stress_strategies=("w",), iters=10)
    advisor = PlacementAdvisor(db, coord.platform)
    n_params = cfg.n_params()
    objs = [
        params_object("params", 2 * n_params, reads_per_step=2.0),
        optimizer_state_object("opt_m", 4 * n_params),
        optimizer_state_object("opt_v", 4 * n_params),
    ]
    plan = advisor.advise(objs, ContentionSpec(n_stressors=0))
    if verbose:
        print("[memscope] placement plan:")
        print(plan.report())
    return plan


def build(args):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_host_mesh(args.data, args.model)
    shape = ShapeSpec("cli", args.seq, args.batch, "train")
    rules = make_rules(cfg, mesh, global_batch=args.batch,
                       shape_kind="train")
    tcfg = TrainConfig(
        learning_rate=args.lr, total_steps=args.steps,
        warmup_steps=max(1, args.steps // 10),
        microbatches=args.microbatches, remat=args.remat,
        zero1=not args.no_zero1, grad_compression=args.compression,
        loss_chunk=min(1024, args.seq), seed=args.seed,
        checkpoint_every=args.checkpoint_every)
    return cfg, mesh, shape, rules, tcfg


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.launch.train")
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="layer", choices=["none", "layer"])
    ap.add_argument("--no-zero1", action="store_true")
    ap.add_argument("--compression", default="none",
                    choices=["none", "int8_ef"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--inject-fault-at", type=int, default=-1,
                    help="raise InjectedFault at this step once (drill)")
    ap.add_argument("--no-advice", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg, mesh, shape, rules, tcfg = build(args)
    print(f"[train] arch={cfg.name} params={cfg.n_params() / 1e6:.1f}M "
          f"mesh={describe(mesh)} steps={args.steps} "
          f"batch={args.batch}x{args.seq}")

    if not args.no_advice:
        advise_placement(cfg, tcfg)

    # --- state + shardings --------------------------------------------------
    state = step_mod.init_state(cfg, tcfg, jax.random.PRNGKey(tcfg.seed))
    specs = step_mod.state_specs(cfg, rules, tcfg, state["params"])
    shardings = jax.tree.map(lambda s, sp: NamedSharding(mesh, sp),
                             state, specs)
    state = jax.tree.map(lambda x, sh: jax.device_put(x, sh), state,
                         shardings)

    b_axes = rules.batch if rules.batch else None
    batch_sharding = NamedSharding(mesh, P(b_axes, None))
    loader = DataLoader(cfg, shape, mesh=mesh,
                        batch_sharding=batch_sharding, seed=tcfg.seed)

    step_fn = jax.jit(
        step_mod.make_train_step(cfg, rules, tcfg,
                                 microbatches=tcfg.microbatches),
        donate_argnums=(0,))

    def wrapped_step(state, batch):
        return step_fn(state, batch.tokens, batch.labels, batch.frontend)

    # --- resilient loop -------------------------------------------------------
    ckpt = CheckpointManager(args.ckpt_dir, keep=tcfg.keep_checkpoints)

    t0 = time.time()
    metrics_log = []

    def logging_step(state, batch):
        state, metrics = wrapped_step(state, batch)
        return state, metrics

    loop = ResilientLoop(
        logging_step, loader.device_batch, ckpt,
        checkpoint_every=tcfg.checkpoint_every,
        faults=None,  # resolve REPRO_FAULT_SPEC like the sweep dispatcher
        fault_hook=(drill_at(args.inject_fault_at)
                    if args.inject_fault_at >= 0 else None),
        monitor=StragglerMonitor())
    result = loop.run(state, args.steps)

    wall = time.time() - t0
    toks = args.steps * shape.tokens
    hist = result.metrics_history
    for i, m in enumerate(hist):
        if i % args.log_every == 0 or i == len(hist) - 1:
            print(f"  step {i:5d} loss={m.get('loss', float('nan')):.4f} "
                  f"gnorm={m.get('grad_norm', float('nan')):.3f}")
    print(f"[train] done: {result.final_step} steps in {wall:.1f}s "
          f"({toks / wall:.0f} tok/s), restarts={result.restarts}, "
          f"stragglers={len(result.straggler_events)}")
    first = next((m["loss"] for m in hist if "loss" in m), float("nan"))
    last = next((m["loss"] for m in reversed(hist) if "loss" in m),
                float("nan"))
    print(f"[train] loss {first:.4f} -> {last:.4f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
